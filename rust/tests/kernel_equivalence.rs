//! Property tests proving the optimized interpreter engine — blocked /
//! parallel matmul micro-kernels, fused `MatmulBias`/`BiasAct`
//! instructions, in-place elementwise execution, pooled buffers —
//! **bitwise-identical** to the retained scalar reference oracle
//! ([`Program::run_reference`]) over randomized programs and shapes,
//! including NaN propagation (the kernels have no zero-skip).
//!
//! Also proves the last-use liveness pass honest: an in-place write can
//! only target a register that no later instruction reads and that is
//! not a program output.

use kitsune::runtime::interp::{Act, Instr, Program, Reg};
use kitsune::runtime::Tensor;
use kitsune::session::fuse_program;

/// Deterministic xorshift (proptest is unavailable offline).
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next() % n.max(1) as u64) as usize
    }

    fn chance(&mut self, percent: u64) -> bool {
        self.next() % 100 < percent
    }

    /// Uniform in [-2, 2] — enough spread to exercise every activation
    /// branch without ln/cos.
    fn val(&mut self) -> f32 {
        ((self.next() >> 40) as f32 / (1u64 << 24) as f32) * 4.0 - 2.0
    }

    fn tensor(&mut self, dims: &[usize]) -> Tensor {
        let numel: usize = dims.iter().product::<usize>().max(1);
        Tensor::new(dims.to_vec(), (0..numel).map(|_| self.val()).collect()).unwrap()
    }
}

const ACTS: [Act; 6] = [Act::Relu, Act::Sigmoid, Act::Gelu, Act::Tanh, Act::Silu, Act::Exp];

fn act_instr(act: Act, a: Reg) -> Instr {
    match act {
        Act::Relu => Instr::Relu { a },
        Act::Sigmoid => Instr::Sigmoid { a },
        Act::Gelu => Instr::Gelu { a },
        Act::Tanh => Instr::Tanh { a },
        Act::Silu => Instr::Silu { a },
        Act::Exp => Instr::Exp { a },
    }
}

/// A random streaming-style SSA program plus matching inputs: a chain of
/// linear layers in fused or unfused form, grad-style binary side ops
/// against earlier same-shape registers, gram/colsum/loss side chains,
/// and randomized outputs (including duplicates and echoed inputs, which
/// exercise the engine's clone-on-output paths).
fn gen_case(rng: &mut Rng) -> (Program, Vec<Tensor>) {
    let rows = 1 + rng.below(8);
    let layers = 1 + rng.below(3);
    let mut dims = Vec::with_capacity(layers + 1);
    for _ in 0..=layers {
        dims.push(1 + rng.below(9));
    }

    let n_inputs = 1 + 2 * layers;
    let mut inputs: Vec<Tensor> = Vec::with_capacity(n_inputs);
    inputs.push(rng.tensor(&[rows, dims[0]]));
    for l in 0..layers {
        inputs.push(rng.tensor(&[dims[l], dims[l + 1]]));
        inputs.push(rng.tensor(&[dims[l + 1]]));
    }
    // NaN injection: diverged values must propagate identically through
    // both engines (no zero-skip, bit-equal payloads).
    if rng.chance(30) {
        let k = rng.below(inputs[0].data.len());
        inputs[0].data[k] = f32::NAN;
    }

    let mut instrs: Vec<Instr> = Vec::new();
    let mut shapes: Vec<Vec<usize>> = inputs.iter().map(|t| t.dims.clone()).collect();
    // `shapes` covers the whole register file, so a new instruction's
    // register index is simply shapes.len() after the push.

    let mut cur: Reg = 0;
    for l in 0..layers {
        let (w, b) = (1 + 2 * l, 2 + 2 * l);
        let out_shape = vec![rows, dims[l + 1]];
        cur = match rng.below(3) {
            0 => {
                // Fused matmul+bias, maybe a standalone activation.
                instrs.push(Instr::MatmulBias { a: cur, b: w, bias: b });
                shapes.push(out_shape.clone());
                let mut r = shapes.len() - 1;
                if rng.chance(60) {
                    instrs.push(act_instr(ACTS[rng.below(ACTS.len())], r));
                    shapes.push(out_shape);
                    r = shapes.len() - 1;
                }
                r
            }
            1 => {
                // Matmul + fused bias/activation epilogue.
                instrs.push(Instr::Matmul { a: cur, b: w });
                shapes.push(out_shape.clone());
                let mm = shapes.len() - 1;
                instrs.push(Instr::BiasAct {
                    a: mm,
                    bias: b,
                    act: ACTS[rng.below(ACTS.len())],
                });
                shapes.push(out_shape);
                shapes.len() - 1
            }
            _ => {
                // Fully unfused chain.
                instrs.push(Instr::Matmul { a: cur, b: w });
                shapes.push(out_shape.clone());
                let mm = shapes.len() - 1;
                instrs.push(Instr::AddBias { a: mm, bias: b });
                shapes.push(out_shape.clone());
                let mut r = shapes.len() - 1;
                if rng.chance(70) {
                    instrs.push(act_instr(ACTS[rng.below(ACTS.len())], r));
                    shapes.push(out_shape);
                    r = shapes.len() - 1;
                }
                r
            }
        };

        // Grad-style binary op against a random earlier register of the
        // same shape (keeps the chain's shape; exercises in-place map2,
        // including operands that are borrowed inputs).
        if rng.chance(35) {
            let same: Vec<Reg> = (0..shapes.len() - 1)
                .filter(|&r| shapes[r] == shapes[cur])
                .collect();
            if !same.is_empty() {
                let other = same[rng.below(same.len())];
                let instr = match rng.below(8) {
                    0 => Instr::Axpy { a: cur, b: other, c: -0.01 },
                    1 => Instr::Axpy { a: other, b: cur, c: 0.5 },
                    2 => Instr::ReluGrad { g: cur, act: other },
                    3 => Instr::SigmoidGrad { dy: other, y: cur },
                    4 => Instr::Mul { a: cur, b: other },
                    5 => Instr::Blend { a: other, b: cur, beta: 0.9 },
                    6 => Instr::ActGradI {
                        g: cur,
                        x: other,
                        act: ACTS[rng.below(ACTS.len())],
                    },
                    _ => Instr::MseGrad { y: cur, t: other },
                };
                instrs.push(instr);
                shapes.push(shapes[cur].clone());
                cur = shapes.len() - 1;
            }
        }

        // Scalar scale (training's gradient averaging), in-place capable.
        if rng.chance(15) {
            instrs.push(Instr::Scale { a: cur, c: -0.5 });
            shapes.push(shapes[cur].clone());
            cur = shapes.len() - 1;
        }

        // Side chains that leave `cur` untouched: scalar loss, bias-grad
        // reduction, gram matrices (the transpose-specialized kernels —
        // note both operands are the SAME register).
        if rng.chance(20) {
            let same: Vec<Reg> =
                (0..shapes.len()).filter(|&r| shapes[r] == shapes[cur] && r != cur).collect();
            if !same.is_empty() {
                let other = same[rng.below(same.len())];
                instrs.push(Instr::MseLoss { y: cur, t: other });
                shapes.push(Vec::new());
            }
        }
        if rng.chance(20) {
            instrs.push(Instr::ColSum { a: cur });
            shapes.push(vec![shapes[cur][1]]);
        }
        if rng.chance(15) {
            instrs.push(Instr::MatmulNt { a: cur, b: cur });
            shapes.push(vec![rows, rows]);
        }
        if rng.chance(15) {
            instrs.push(Instr::MatmulTn { a: cur, b: cur });
            let d = shapes[cur][1];
            shapes.push(vec![d, d]);
        }
    }

    let mut outputs: Vec<Reg> = vec![cur];
    for r in n_inputs..shapes.len() {
        if r != cur && rng.chance(15) {
            outputs.push(r);
        }
    }
    if rng.chance(10) {
        outputs.push(cur); // duplicate: exercises clone-on-relisted-output
    }
    if rng.chance(10) {
        outputs.push(0); // echoed input: exercises clone-of-borrowed
    }

    (Program { n_inputs, instrs, outputs }, inputs)
}

fn bits(t: &Tensor) -> Vec<u32> {
    t.data.iter().map(|v| v.to_bits()).collect()
}

fn assert_same(tag: &str, p: &Program, want: &[Tensor], got: &[Tensor]) {
    assert_eq!(want.len(), got.len(), "{tag}: output count\n{p:?}");
    for (oi, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(w.dims, g.dims, "{tag}: output {oi} dims\n{p:?}");
        assert_eq!(
            bits(w),
            bits(g),
            "{tag}: output {oi} diverged from the scalar reference\n{p:?}"
        );
    }
}

#[test]
fn randomized_programs_bitwise_match_reference() {
    let mut rng = Rng::new(0xA11CE);
    for trial in 0..150 {
        let (p, inputs) = gen_case(&mut rng);
        let want = p.run_reference(&inputs).unwrap();
        let got = p.run(&inputs).unwrap();
        assert_same(&format!("trial {trial} optimized"), &p, &want, &got);

        // The peephole-fused form is bitwise-identical too — on both
        // engines (the reference on the fused form defines its
        // semantics; the optimized engine must match it and the
        // original).
        let fused = fuse_program(&p);
        let got_fused = fused.run(&inputs).unwrap();
        assert_same(&format!("trial {trial} fused"), &fused, &want, &got_fused);
        let ref_fused = fused.run_reference(&inputs).unwrap();
        assert_same(&format!("trial {trial} fused-reference"), &fused, &want, &ref_fused);

        // Determinism: a second optimized run reproduces the first.
        let again = p.run(&inputs).unwrap();
        assert_same(&format!("trial {trial} rerun"), &p, &got, &again);
    }
}

#[test]
fn large_parallel_kernels_bitwise_match_reference() {
    // Shapes above the kernel's FLOP threshold, so the row-panel
    // scoped-thread path engages on multi-core hosts (and the blocked
    // serial path everywhere else) — the bits must match either way.
    // One NaN is planted to prove the parallel path has no zero-skip.
    let mut rng = Rng::new(0xBEEF);
    let cases: Vec<(Instr, Vec<usize>, Vec<usize>)> = vec![
        (Instr::Matmul { a: 0, b: 1 }, vec![160, 128], vec![128, 96]),
        (Instr::MatmulTn { a: 0, b: 1 }, vec![128, 160], vec![128, 96]),
        (Instr::MatmulNt { a: 0, b: 1 }, vec![160, 128], vec![96, 128]),
    ];
    for (instr, da, db) in cases {
        let p = Program { n_inputs: 2, instrs: vec![instr], outputs: vec![2] };
        let mut a = rng.tensor(&da);
        a.data[7] = f32::NAN;
        let b = rng.tensor(&db);
        let inputs = [a, b];
        let want = p.run_reference(&inputs).unwrap();
        let got = p.run(&inputs).unwrap();
        assert_same(&format!("{instr:?}"), &p, &want, &got);
        assert!(
            got[0].data.iter().any(|v| v.is_nan()),
            "{instr:?}: NaN must propagate through the contraction"
        );
    }

    // Fused bias epilogue at parallel scale.
    let p = Program {
        n_inputs: 3,
        instrs: vec![Instr::MatmulBias { a: 0, b: 1, bias: 2 }],
        outputs: vec![3],
    };
    let inputs = [rng.tensor(&[192, 144]), rng.tensor(&[144, 80]), rng.tensor(&[80])];
    let want = p.run_reference(&inputs).unwrap();
    let got = p.run(&inputs).unwrap();
    assert_same("MatmulBias(parallel)", &p, &want, &got);
}

/// Replicates the engine's in-place eligibility test for instruction
/// `idx` consuming operand `r` (see `take_if_dead` in runtime/interp.rs).
fn would_take_in_place(p: &Program, plan: &kitsune::runtime::interp::ExecPlan, idx: usize, r: Reg) -> bool {
    r >= p.n_inputs && plan.last_read[r] == Some(idx) && !plan.is_output[r]
}

#[test]
fn liveness_pass_never_aliases_a_live_register() {
    let mut rng = Rng::new(0x11FE);
    for trial in 0..150 {
        let (p, _inputs) = gen_case(&mut rng);
        let plan = p.plan();
        let n_regs = p.n_inputs + p.instrs.len();
        assert_eq!(plan.last_read.len(), n_regs);
        assert_eq!(plan.is_output.len(), n_regs);
        assert_eq!(plan.retire.len(), p.instrs.len());

        // last_read honesty: it IS the maximum reading instruction.
        for r in 0..n_regs {
            let brute: Option<usize> = p
                .instrs
                .iter()
                .enumerate()
                .filter(|(_, instr)| instr.reads().contains(&r))
                .map(|(i, _)| i)
                .last();
            assert_eq!(plan.last_read[r], brute, "trial {trial} reg {r}\n{p:?}");
        }

        // In-place safety: wherever the engine would take a register's
        // buffer, no later instruction reads it and it is not an output.
        for (idx, instr) in p.instrs.iter().enumerate() {
            for r in instr.reads() {
                if would_take_in_place(&p, &plan, idx, r) {
                    assert!(!p.outputs.contains(&r), "trial {trial}: output aliased\n{p:?}");
                    for (j, later) in p.instrs.iter().enumerate().skip(idx + 1) {
                        assert!(
                            !later.reads().contains(&r),
                            "trial {trial}: instr {j} reads reg {r} after its in-place \
                             consumption at {idx}\n{p:?}"
                        );
                    }
                }
            }
        }

        // Retirement lists only dead, non-output registers, each at its
        // last read.
        for (i, retired) in plan.retire.iter().enumerate() {
            for &r in retired {
                assert_eq!(plan.last_read[r], Some(i), "trial {trial}\n{p:?}");
                assert!(!plan.is_output[r], "trial {trial}\n{p:?}");
                assert!(r >= p.n_inputs, "trial {trial}: input retired\n{p:?}");
            }
        }
    }
}
