//! Property tests pinning the optimized interpreter engine to the
//! retained scalar reference oracle ([`Program::run_reference`]) under
//! the two-tier equivalence contract:
//!
//! * **Scalar path** (`KITSUNE_SIMD=0`, forced here via
//!   `simd::set_vector_enabled(false)`): blocked/parallel matmul
//!   micro-kernels, fused `MatmulBias`/`BiasAct` instructions, in-place
//!   elementwise execution and pooled buffers stay **bitwise-identical**
//!   to the oracle over randomized programs and shapes, including NaN
//!   propagation (the kernels have no zero-skip).
//! * **Vector path** (`simd::set_vector_enabled(true)`): results stay
//!   within [`simd::VECTOR_ULP_BOUND`] ULP of the same oracle
//!   ([`simd::engine_equivalence`] — bitwise again on hosts whose
//!   portable fallback keeps scalar op order), and fusion still never
//!   changes the *engine's* bits.
//!
//! Also proves the `Equivalence::Ulp` harness honest (an out-of-bound
//! kernel is rejected, not absorbed), the bf16/f16 storage conversions
//! exact (RNE, subnormals, NaN/Inf), a bf16 session end-to-end halves
//! its edge traffic, and the last-use liveness pass honest: an in-place
//! write can only target a register that no later instruction reads and
//! that is not a program output.

use kitsune::runtime::interp::{Act, ExecPlan, Instr, Program, Reg};
use kitsune::runtime::precision::{
    bf16_bits_to_f32, f16_bits_to_f32, f32_to_bf16_bits, f32_to_f16_bits,
};
use kitsune::runtime::simd::{self, Equivalence};
use kitsune::runtime::{Precision, Tensor};
use kitsune::session::{fuse_program, nerf_trunk_graph, Session};
use std::sync::Mutex;

/// `set_vector_enabled` is process-global; every test that executes
/// programs while pinning a specific engine mode serializes on this.
static ENGINE_LOCK: Mutex<()> = Mutex::new(());

fn engine_lock() -> std::sync::MutexGuard<'static, ()> {
    ENGINE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Scoped engine-mode override: restores the previous mode on drop
/// (also on panic, so one failing test cannot skew its siblings).
struct VectorMode(bool);

impl VectorMode {
    fn set(on: bool) -> Self {
        let prev = simd::vector_enabled();
        simd::set_vector_enabled(on);
        VectorMode(prev)
    }
}

impl Drop for VectorMode {
    fn drop(&mut self) {
        simd::set_vector_enabled(self.0);
    }
}

/// Deterministic xorshift (proptest is unavailable offline).
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next() % n.max(1) as u64) as usize
    }

    fn chance(&mut self, percent: u64) -> bool {
        self.next() % 100 < percent
    }

    /// Uniform in [-2, 2] — enough spread to exercise every activation
    /// branch without ln/cos.
    fn val(&mut self) -> f32 {
        ((self.next() >> 40) as f32 / (1u64 << 24) as f32) * 4.0 - 2.0
    }

    fn tensor(&mut self, dims: &[usize]) -> Tensor {
        self.tensor_scaled(dims, 1.0)
    }

    /// Entries in [-2·scale, 2·scale]. Vector-tier tests shrink the
    /// magnitudes so worst-case FMA drift provably sits inside the
    /// contract's absolute floor (a relative ULP bound is meaningless
    /// on a contraction output that cancelled toward zero).
    fn tensor_scaled(&mut self, dims: &[usize], scale: f32) -> Tensor {
        let numel: usize = dims.iter().product::<usize>().max(1);
        Tensor::new(dims.to_vec(), (0..numel).map(|_| self.val() * scale).collect()).unwrap()
    }
}

const ACTS: [Act; 6] = [Act::Relu, Act::Sigmoid, Act::Gelu, Act::Tanh, Act::Silu, Act::Exp];

fn act_instr(act: Act, a: Reg) -> Instr {
    match act {
        Act::Relu => Instr::Relu { a },
        Act::Sigmoid => Instr::Sigmoid { a },
        Act::Gelu => Instr::Gelu { a },
        Act::Tanh => Instr::Tanh { a },
        Act::Silu => Instr::Silu { a },
        Act::Exp => Instr::Exp { a },
    }
}

/// A random streaming-style SSA program plus matching inputs: a chain of
/// linear layers in fused or unfused form, grad-style binary side ops
/// against earlier same-shape registers, gram/colsum/loss side chains,
/// and randomized outputs (including duplicates and echoed inputs, which
/// exercise the engine's clone-on-output paths).
///
/// `vector_safe` shapes the case for an element-wise tier check against
/// the scalar oracle on the FMA vector path: entries shrink to
/// [-1/32, 1/32] (activations then keep every register O(1), so each
/// kernel's worst-case FMA drift provably stays inside the tier's
/// absolute floor or its ULP headroom) and the gram side-products are
/// skipped — they contract squared activations, the one construct whose
/// rounding drift scales with the term magnitudes while the output can
/// cancel toward zero, where no relative bound is meaningful.
fn gen_case(rng: &mut Rng, vector_safe: bool) -> (Program, Vec<Tensor>) {
    let scale = if vector_safe { 1.0 / 64.0 } else { 1.0 };
    let rows = 1 + rng.below(8);
    let layers = 1 + rng.below(3);
    let mut dims = Vec::with_capacity(layers + 1);
    for _ in 0..=layers {
        dims.push(1 + rng.below(9));
    }

    let n_inputs = 1 + 2 * layers;
    let mut inputs: Vec<Tensor> = Vec::with_capacity(n_inputs);
    inputs.push(rng.tensor_scaled(&[rows, dims[0]], scale));
    for l in 0..layers {
        inputs.push(rng.tensor_scaled(&[dims[l], dims[l + 1]], scale));
        inputs.push(rng.tensor_scaled(&[dims[l + 1]], scale));
    }
    // NaN injection: diverged values must propagate identically through
    // both engines (no zero-skip, bit-equal payloads).
    if rng.chance(30) {
        let k = rng.below(inputs[0].data.len());
        inputs[0].data[k] = f32::NAN;
    }

    let mut instrs: Vec<Instr> = Vec::new();
    let mut shapes: Vec<Vec<usize>> = inputs.iter().map(|t| t.dims.clone()).collect();
    // `shapes` covers the whole register file, so a new instruction's
    // register index is simply shapes.len() after the push.

    let mut cur: Reg = 0;
    for l in 0..layers {
        let (w, b) = (1 + 2 * l, 2 + 2 * l);
        let out_shape = vec![rows, dims[l + 1]];
        cur = match rng.below(3) {
            0 => {
                // Fused matmul+bias, maybe a standalone activation.
                instrs.push(Instr::MatmulBias { a: cur, b: w, bias: b });
                shapes.push(out_shape.clone());
                let mut r = shapes.len() - 1;
                if rng.chance(60) {
                    instrs.push(act_instr(ACTS[rng.below(ACTS.len())], r));
                    shapes.push(out_shape);
                    r = shapes.len() - 1;
                }
                r
            }
            1 => {
                // Matmul + fused bias/activation epilogue.
                instrs.push(Instr::Matmul { a: cur, b: w });
                shapes.push(out_shape.clone());
                let mm = shapes.len() - 1;
                instrs.push(Instr::BiasAct {
                    a: mm,
                    bias: b,
                    act: ACTS[rng.below(ACTS.len())],
                });
                shapes.push(out_shape);
                shapes.len() - 1
            }
            _ => {
                // Fully unfused chain.
                instrs.push(Instr::Matmul { a: cur, b: w });
                shapes.push(out_shape.clone());
                let mm = shapes.len() - 1;
                instrs.push(Instr::AddBias { a: mm, bias: b });
                shapes.push(out_shape.clone());
                let mut r = shapes.len() - 1;
                if rng.chance(70) {
                    instrs.push(act_instr(ACTS[rng.below(ACTS.len())], r));
                    shapes.push(out_shape);
                    r = shapes.len() - 1;
                }
                r
            }
        };

        // Grad-style binary op against a random earlier register of the
        // same shape (keeps the chain's shape; exercises in-place map2,
        // including operands that are borrowed inputs).
        if rng.chance(35) {
            let same: Vec<Reg> = (0..shapes.len() - 1)
                .filter(|&r| shapes[r] == shapes[cur])
                .collect();
            if !same.is_empty() {
                let other = same[rng.below(same.len())];
                let instr = match rng.below(8) {
                    0 => Instr::Axpy { a: cur, b: other, c: -0.01 },
                    1 => Instr::Axpy { a: other, b: cur, c: 0.5 },
                    2 => Instr::ReluGrad { g: cur, act: other },
                    3 => Instr::SigmoidGrad { dy: other, y: cur },
                    4 => Instr::Mul { a: cur, b: other },
                    5 => Instr::Blend { a: other, b: cur, beta: 0.9 },
                    6 => Instr::ActGradI {
                        g: cur,
                        x: other,
                        act: ACTS[rng.below(ACTS.len())],
                    },
                    _ => Instr::MseGrad { y: cur, t: other },
                };
                instrs.push(instr);
                shapes.push(shapes[cur].clone());
                cur = shapes.len() - 1;
            }
        }

        // Scalar scale (training's gradient averaging), in-place capable.
        if rng.chance(15) {
            instrs.push(Instr::Scale { a: cur, c: -0.5 });
            shapes.push(shapes[cur].clone());
            cur = shapes.len() - 1;
        }

        // Side chains that leave `cur` untouched: scalar loss, bias-grad
        // reduction, gram matrices (the transpose-specialized kernels —
        // note both operands are the SAME register).
        if rng.chance(20) {
            let same: Vec<Reg> =
                (0..shapes.len()).filter(|&r| shapes[r] == shapes[cur] && r != cur).collect();
            if !same.is_empty() {
                let other = same[rng.below(same.len())];
                instrs.push(Instr::MseLoss { y: cur, t: other });
                shapes.push(Vec::new());
            }
        }
        if rng.chance(20) {
            instrs.push(Instr::ColSum { a: cur });
            shapes.push(vec![shapes[cur][1]]);
        }
        if !vector_safe && rng.chance(15) {
            instrs.push(Instr::MatmulNt { a: cur, b: cur });
            shapes.push(vec![rows, rows]);
        }
        if !vector_safe && rng.chance(15) {
            instrs.push(Instr::MatmulTn { a: cur, b: cur });
            let d = shapes[cur][1];
            shapes.push(vec![d, d]);
        }
    }

    let mut outputs: Vec<Reg> = vec![cur];
    for r in n_inputs..shapes.len() {
        if r != cur && rng.chance(15) {
            outputs.push(r);
        }
    }
    if rng.chance(10) {
        outputs.push(cur); // duplicate: exercises clone-on-relisted-output
    }
    if rng.chance(10) {
        outputs.push(0); // echoed input: exercises clone-of-borrowed
    }

    (Program { n_inputs, instrs, outputs }, inputs)
}

fn bits(t: &Tensor) -> Vec<u32> {
    t.data.iter().map(|v| v.to_bits()).collect()
}

fn assert_same(tag: &str, p: &Program, want: &[Tensor], got: &[Tensor]) {
    assert_eq!(want.len(), got.len(), "{tag}: output count\n{p:?}");
    for (oi, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(w.dims, g.dims, "{tag}: output {oi} dims\n{p:?}");
        assert_eq!(
            bits(w),
            bits(g),
            "{tag}: output {oi} diverged from the scalar reference\n{p:?}"
        );
    }
}

fn assert_tier(tag: &str, p: &Program, tier: Equivalence, want: &[Tensor], got: &[Tensor]) {
    assert_eq!(want.len(), got.len(), "{tag}: output count\n{p:?}");
    for (oi, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(w.dims, g.dims, "{tag}: output {oi} dims\n{p:?}");
        tier.check(&g.data, &w.data)
            .unwrap_or_else(|e| panic!("{tag}: output {oi}: {e}\n{p:?}"));
    }
}

#[test]
fn randomized_programs_scalar_path_bitwise_matches_reference() {
    let _serial = engine_lock();
    let _mode = VectorMode::set(false);
    let mut rng = Rng::new(0xA11CE);
    for trial in 0..150 {
        let (p, inputs) = gen_case(&mut rng, false);
        let want = p.run_reference(&inputs).unwrap();
        let got = p.run(&inputs).unwrap();
        assert_same(&format!("trial {trial} optimized"), &p, &want, &got);

        // The peephole-fused form is bitwise-identical too — on both
        // engines (the reference on the fused form defines its
        // semantics; the optimized engine must match it and the
        // original).
        let fused = fuse_program(&p);
        let got_fused = fused.run(&inputs).unwrap();
        assert_same(&format!("trial {trial} fused"), &fused, &want, &got_fused);
        let ref_fused = fused.run_reference(&inputs).unwrap();
        assert_same(&format!("trial {trial} fused-reference"), &fused, &want, &ref_fused);

        // Determinism: a second optimized run reproduces the first.
        let again = p.run(&inputs).unwrap();
        assert_same(&format!("trial {trial} rerun"), &p, &got, &again);
    }
}

#[test]
fn randomized_programs_vector_path_is_ulp_bounded() {
    let _serial = engine_lock();
    let _mode = VectorMode::set(true);
    // Ulp(VECTOR_ULP_BOUND) on FMA hosts; Bitwise where the portable
    // fallback (plain mul+add, scalar op order) is what actually runs.
    let tier = simd::engine_equivalence();
    let mut rng = Rng::new(0x5EED5);
    // Tier checks against the scalar oracle use vector-safe cases (see
    // `gen_case`), where the FMA paths' worst-case drift provably fits
    // the contract even on outputs that cancel toward zero.
    for trial in 0..80 {
        let (p, inputs) = gen_case(&mut rng, true);
        let want = p.run_reference(&inputs).unwrap();
        let got = p.run(&inputs).unwrap();
        assert_tier(&format!("trial {trial} vector"), &p, tier, &want, &got);

        // The fused form sits inside the same tier: fused kernels
        // decompose into exactly the unfused vector sweeps.
        let fused = fuse_program(&p);
        let got_fused = fused.run(&inputs).unwrap();
        assert_tier(&format!("trial {trial} vector fused"), &fused, tier, &want, &got_fused);

        // Determinism: a second vector run reproduces the first.
        let again = p.run(&inputs).unwrap();
        assert_same(&format!("trial {trial} vector rerun"), &p, &got, &again);
    }
    // Engine-internal invariants hold bitwise at ANY magnitude (the
    // same sweeps run in the same order): fusion must not change the
    // engine's bits, and reruns must reproduce the first answer —
    // NaN injection and full [-2, 2] dynamics included.
    for trial in 0..80 {
        let (p, inputs) = gen_case(&mut rng, false);
        let got = p.run(&inputs).unwrap();
        let fused = fuse_program(&p);
        let got_fused = fused.run(&inputs).unwrap();
        assert_same(&format!("trial {trial} full-range fused"), &fused, &got, &got_fused);
        let again = p.run(&inputs).unwrap();
        assert_same(&format!("trial {trial} full-range rerun"), &p, &got, &again);
    }
}

#[test]
fn large_parallel_kernels_hold_their_tier() {
    // Shapes above the kernel's FLOP threshold, so the row-panel
    // scoped-thread path engages on multi-core hosts (and the blocked
    // serial path everywhere else) — scalar mode must match the oracle
    // bitwise either way, vector mode within its ULP tier. Entries are
    // scaled to [-1/32, 1/32] so the k=128..144 contractions' worst-case
    // FMA drift provably sits inside the tier's absolute floor even on
    // cancelled outputs (scalar-mode bitwise is scale-independent). One
    // NaN is planted to prove neither path has a zero-skip.
    const SCALE: f32 = 1.0 / 64.0;
    let _serial = engine_lock();
    let mut rng = Rng::new(0xBEEF);
    let cases: Vec<(Instr, Vec<usize>, Vec<usize>)> = vec![
        (Instr::Matmul { a: 0, b: 1 }, vec![160, 128], vec![128, 96]),
        (Instr::MatmulTn { a: 0, b: 1 }, vec![128, 160], vec![128, 96]),
        (Instr::MatmulNt { a: 0, b: 1 }, vec![160, 128], vec![96, 128]),
    ];
    let mut programs: Vec<(String, Program, Vec<Tensor>)> = Vec::new();
    for (instr, da, db) in cases {
        let p = Program { n_inputs: 2, instrs: vec![instr.clone()], outputs: vec![2] };
        let mut a = rng.tensor_scaled(&da, SCALE);
        a.data[7] = f32::NAN;
        let b = rng.tensor_scaled(&db, SCALE);
        programs.push((format!("{instr:?}"), p, vec![a, b]));
    }
    // Fused bias epilogue at parallel scale.
    programs.push((
        "MatmulBias(parallel)".to_string(),
        Program {
            n_inputs: 3,
            instrs: vec![Instr::MatmulBias { a: 0, b: 1, bias: 2 }],
            outputs: vec![3],
        },
        vec![
            rng.tensor_scaled(&[192, 144], SCALE),
            rng.tensor_scaled(&[144, 80], SCALE),
            rng.tensor_scaled(&[80], SCALE),
        ],
    ));

    for (tag, p, inputs) in &programs {
        let want = p.run_reference(inputs).unwrap();
        {
            let _mode = VectorMode::set(false);
            let got = p.run(inputs).unwrap();
            assert_same(&format!("{tag} scalar"), p, &want, &got);
        }
        {
            let _mode = VectorMode::set(true);
            let tier = simd::engine_equivalence();
            let got = p.run(inputs).unwrap();
            assert_tier(&format!("{tag} vector"), p, tier, &want, &got);
            assert!(
                got[0].data.iter().any(|v| v.is_nan()),
                "{tag}: NaN must propagate through the vector contraction"
            );
        }
    }
}

#[test]
fn ulp_tier_rejects_out_of_bound_kernels() {
    // Harness honesty: the Ulp tier is a bound, not a rubber stamp. A
    // "kernel" drifting past VECTOR_ULP_BOUND (well above the absolute
    // floor) must be rejected.
    let want = [1.0f32, 2.0, 3.0];
    let mut broken = want;
    broken[1] = f32::from_bits(want[1].to_bits() + simd::VECTOR_ULP_BOUND + 1);
    assert!(
        (broken[1] - want[1]).abs() > simd::ULP_ABS_FLOOR,
        "test premise: drift must clear the absolute floor"
    );
    assert!(Equivalence::Ulp(simd::VECTOR_ULP_BOUND).check(&broken, &want).is_err());
    assert!(Equivalence::Bitwise.check(&broken, &want).is_err());

    // Within the bound: Ulp passes, Bitwise still refuses.
    let mut close = want;
    close[2] = f32::from_bits(want[2].to_bits() + 3);
    assert!(Equivalence::Ulp(simd::VECTOR_ULP_BOUND).check(&close, &want).is_ok());
    assert!(Equivalence::Bitwise.check(&close, &want).is_err());

    // NaN discipline: one-sided NaN never passes (even with an infinite
    // bound); paired NaNs are 0 ULP apart regardless of payload.
    assert!(Equivalence::Ulp(u32::MAX).check(&[f32::NAN], &[1.0]).is_err());
    assert!(Equivalence::Ulp(0).check(&[f32::NAN], &[-f32::NAN]).is_ok());

    // The absolute floor only absorbs sub-1e-6 cancellation noise.
    assert!(Equivalence::Ulp(0).check(&[5.0e-7], &[1.0e-7]).is_ok());
    assert!(Equivalence::Ulp(0).check(&[5.0e-3], &[1.0e-3]).is_err());

    // Length mismatches are structural failures, not element noise.
    assert!(Equivalence::Ulp(u32::MAX).check(&[1.0, 2.0], &[1.0]).is_err());
}

#[test]
fn f16_conversion_is_exact_rne_with_specials() {
    // Exhaustive involution: every f16 bit pattern widens exactly and
    // narrows back to itself — except signaling NaNs, which come back
    // quieted with their payload preserved.
    for h in 0..=u16::MAX {
        let x = f16_bits_to_f32(h);
        let h2 = f32_to_f16_bits(x);
        let exp = (h >> 10) & 0x1F;
        let man = h & 0x03FF;
        if exp == 31 && man != 0 && man & 0x0200 == 0 {
            assert_eq!(h2, h | 0x0200, "sNaN {h:#06x} must quiet, payload kept");
        } else {
            assert_eq!(h2, h, "f16 {h:#06x} must round-trip exactly");
        }
    }

    // Round-to-nearest-even at the mantissa boundary.
    assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
    let tie_down = 1.0 + 0.5f32.powi(11); // halfway to 1+2^-10 -> even (down)
    assert_eq!(f32_to_f16_bits(tie_down), 0x3C00);
    let tie_up = 1.0 + 3.0 * 0.5f32.powi(11); // halfway -> even (up)
    assert_eq!(f32_to_f16_bits(tie_up), 0x3C02);

    // Subnormals: smallest subnormal, underflow-to-zero tie, and the
    // value just past the tie.
    assert_eq!(f32_to_f16_bits(0.5f32.powi(24)), 0x0001);
    assert_eq!(f32_to_f16_bits(0.5f32.powi(25)), 0x0000); // tie -> even (zero)
    assert_eq!(f32_to_f16_bits(1.5 * 0.5f32.powi(25)), 0x0001);
    assert_eq!(f32_to_f16_bits(-0.0), 0x8000);

    // Overflow: max finite is 65504; the RNE cutover to Inf is 65520.
    assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF);
    assert_eq!(f32_to_f16_bits(65519.0), 0x7BFF);
    assert_eq!(f32_to_f16_bits(65520.0), 0x7C00);
    assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
    assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xFC00);
    let n = f32_to_f16_bits(f32::NAN);
    assert_eq!(n & 0x7C00, 0x7C00, "NaN keeps the all-ones exponent");
    assert_ne!(n & 0x03FF, 0, "NaN must not collapse to infinity");

    // Quantize is idempotent on specials too.
    for x in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, 65519.0, 1.0e-8] {
        let q = Precision::F16.quantize(x);
        assert_eq!(q.to_bits(), Precision::F16.quantize(q).to_bits(), "{x}");
    }
}

#[test]
fn bf16_conversion_is_exact_rne_with_specials() {
    // Exhaustive involution over every bf16 bit pattern; NaNs without
    // the quiet bit come back quieted with their payload preserved.
    for b in 0..=u16::MAX {
        let x = bf16_bits_to_f32(b);
        let b2 = f32_to_bf16_bits(x);
        let exp = (b >> 7) & 0xFF;
        let man = b & 0x7F;
        if exp == 0xFF && man != 0 && b & 0x0040 == 0 {
            assert_eq!(b2, b | 0x0040, "sNaN {b:#06x} must quiet, payload kept");
        } else {
            assert_eq!(b2, b, "bf16 {b:#06x} must round-trip exactly");
        }
    }

    // RNE at the bf16 mantissa boundary (tie exactly between steps).
    assert_eq!(f32_to_bf16_bits(1.0), 0x3F80);
    assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3F80_8000)), 0x3F80); // tie -> even
    assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3F81_8000)), 0x3F82); // tie -> even
    assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3F80_8001)), 0x3F81); // past tie

    // bf16 shares f32's subnormal exponents: a representable subnormal
    // survives exactly; overflow carries into the Inf encoding.
    assert_eq!(f32_to_bf16_bits(f32::from_bits(0x0001_0000)), 0x0001);
    assert_eq!(bf16_bits_to_f32(0x0001), f32::from_bits(0x0001_0000));
    assert_eq!(f32_to_bf16_bits(f32::MAX), 0x7F80); // rounds up to +Inf
    assert_eq!(f32_to_bf16_bits(f32::INFINITY), 0x7F80);
    assert_eq!(f32_to_bf16_bits(-0.0), 0x8000);
    let n = f32_to_bf16_bits(f32::NAN);
    assert_eq!(n & 0x7F80, 0x7F80);
    assert_ne!(n & 0x007F, 0, "NaN must not collapse to infinity");
}

#[test]
fn bf16_inference_halves_edge_traffic_end_to_end() {
    // The NeRF trunk, streamed warm, once per storage mode: bf16 must
    // run end to end and move exactly half the edge bytes (same tile
    // count, same dims, every payload charged at its storage width).
    let traffic = |prec: Precision| {
        let session = Session::builder()
            .graph(nerf_trunk_graph(64, 6, 16, 3))
            .tile_rows(4)
            .precision(prec)
            .build()
            .unwrap();
        assert_eq!(session.precision(), prec);
        let tiles = session.make_tiles(8, 0xF00D).unwrap();
        let out = session.run(tiles).unwrap();
        assert_eq!(out.outputs.len(), 8);
        for t in &out.outputs {
            assert!(
                t.data.iter().all(|v| v.is_finite()),
                "{prec:?} inference produced non-finite output"
            );
        }
        let snap = session.telemetry().expect("warm session").traffic.snapshot();
        session.shutdown();
        snap.source_bytes + snap.onchip_bytes + snap.sink_bytes
    };
    let full = traffic(Precision::F32);
    let half = traffic(Precision::Bf16);
    assert!(full > 0, "f32 run must account edge traffic");
    assert_eq!(half * 2, full, "bf16 tiles must cross every edge at half width");
}

/// Replicates the engine's in-place eligibility test for instruction
/// `idx` consuming operand `r` (see `take_if_dead` in runtime/interp.rs).
fn would_take_in_place(p: &Program, plan: &ExecPlan, idx: usize, r: Reg) -> bool {
    r >= p.n_inputs && plan.last_read[r] == Some(idx) && !plan.is_output[r]
}

#[test]
fn liveness_pass_never_aliases_a_live_register() {
    let mut rng = Rng::new(0x11FE);
    for trial in 0..150 {
        let (p, _inputs) = gen_case(&mut rng, false);
        let plan = p.plan();
        let n_regs = p.n_inputs + p.instrs.len();
        assert_eq!(plan.last_read.len(), n_regs);
        assert_eq!(plan.is_output.len(), n_regs);
        assert_eq!(plan.retire.len(), p.instrs.len());

        // last_read honesty: it IS the maximum reading instruction.
        for r in 0..n_regs {
            let brute: Option<usize> = p
                .instrs
                .iter()
                .enumerate()
                .filter(|(_, instr)| instr.reads().contains(&r))
                .map(|(i, _)| i)
                .last();
            assert_eq!(plan.last_read[r], brute, "trial {trial} reg {r}\n{p:?}");
        }

        // In-place safety: wherever the engine would take a register's
        // buffer, no later instruction reads it and it is not an output.
        for (idx, instr) in p.instrs.iter().enumerate() {
            for r in instr.reads() {
                if would_take_in_place(&p, &plan, idx, r) {
                    assert!(!p.outputs.contains(&r), "trial {trial}: output aliased\n{p:?}");
                    for (j, later) in p.instrs.iter().enumerate().skip(idx + 1) {
                        assert!(
                            !later.reads().contains(&r),
                            "trial {trial}: instr {j} reads reg {r} after its in-place \
                             consumption at {idx}\n{p:?}"
                        );
                    }
                }
            }
        }

        // Retirement lists only dead, non-output registers, each at its
        // last read.
        for (i, retired) in plan.retire.iter().enumerate() {
            for &r in retired {
                assert_eq!(plan.last_read[r], Some(i), "trial {trial}\n{p:?}");
                assert!(!plan.is_output[r], "trial {trial}\n{p:?}");
                assert!(r >= p.n_inputs, "trial {trial}: input retired\n{p:?}");
            }
        }
    }
}
