//! Integration over the pure-Rust interpreter backend: a synthetic
//! artifact manifest (no HLO files, no Python, no PJRT) drives the same
//! ArtifactStore + coordinator stack as the real AOT artifacts —
//! forward numerics, SGD training descent, spatial-pipeline equivalence,
//! and the typed error surface. Everything here runs on a fresh offline
//! checkout; nothing is skipped.

use kitsune::coordinator::cli::{build_nerf_pipeline, input_tiles};
use kitsune::coordinator::{run_serial, run_streaming};
use kitsune::runtime::{ArtifactStore, InterpBackend, Rng, RuntimeError, Tensor};
use kitsune::session::{nerf_trunk_graph, Session};
use std::path::PathBuf;

const IN: usize = 6;
const HIDDEN: usize = 16;
const OUT: usize = 3;
const TILE: usize = 8;
const BATCH: usize = 32;

/// Write a small-shape manifest mirroring `python/compile/aot.py`'s ABI
/// into a fresh temp directory, and return the directory.
fn synth_artifacts(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "kitsune_interp_test_{}_{tag}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let p = |dims: &[usize]| -> String {
        let ds: Vec<String> = dims.iter().map(|d| d.to_string()).collect();
        format!("f32[{}]", ds.join(","))
    };
    let params = [
        p(&[IN, HIDDEN]),
        p(&[HIDDEN]),
        p(&[HIDDEN, HIDDEN]),
        p(&[HIDDEN]),
        p(&[HIDDEN, HIDDEN]),
        p(&[HIDDEN]),
        p(&[HIDDEN, OUT]),
        p(&[OUT]),
    ]
    .join(",");
    let manifest = [
        format!("nerf_forward\tnerf_forward.hlo.txt\tin={},{params}\tout=1", p(&[TILE * 2, IN])),
        format!(
            "nerf_forward_pallas\tnerf_forward_pallas.hlo.txt\tin={},{params}\tout=1",
            p(&[TILE * 2, IN])
        ),
        format!(
            "train_step\ttrain_step.hlo.txt\tin={},{},{params}\tout=9",
            p(&[BATCH, IN]),
            p(&[BATCH, OUT])
        ),
        format!(
            "stage_trunk0\tstage_trunk0.hlo.txt\tin={},{},{},{},{}\tout=1",
            p(&[TILE, IN]),
            p(&[IN, HIDDEN]),
            p(&[HIDDEN]),
            p(&[HIDDEN, HIDDEN]),
            p(&[HIDDEN])
        ),
        format!(
            "stage_trunk1\tstage_trunk1.hlo.txt\tin={},{},{}\tout=1",
            p(&[TILE, HIDDEN]),
            p(&[HIDDEN, HIDDEN]),
            p(&[HIDDEN])
        ),
        format!(
            "stage_head\tstage_head.hlo.txt\tin={},{},{}\tout=1",
            p(&[TILE, HIDDEN]),
            p(&[HIDDEN, OUT]),
            p(&[OUT])
        ),
    ]
    .join("\n");
    std::fs::write(dir.join("manifest.txt"), manifest + "\n").unwrap();
    dir
}

fn store(tag: &str) -> ArtifactStore {
    ArtifactStore::load_with(synth_artifacts(tag), Box::new(InterpBackend::new())).unwrap()
}

#[test]
fn interp_store_loads_all_entries() {
    let store = store("entries");
    assert_eq!(store.backend_name(), "interp");
    assert_eq!(store.platform(), "interp");
    for want in [
        "nerf_forward",
        "nerf_forward_pallas",
        "train_step",
        "stage_trunk0",
        "stage_trunk1",
        "stage_head",
    ] {
        assert!(store.entry_names().contains(&want), "missing {want}");
    }
}

#[test]
fn forward_outputs_in_unit_range_and_pallas_variant_matches() {
    let store = store("fwd");
    let spec = store.spec("nerf_forward").unwrap().clone();
    let mut rng = Rng::new(5);
    let inputs: Vec<Tensor> = spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, t)| {
            if i == 0 {
                let numel: usize = t.dims.iter().product();
                Tensor {
                    dims: t.dims.clone(),
                    data: (0..numel).map(|_| rng.normal()).collect(),
                    prec: kitsune::runtime::Precision::F32,
                }
            } else {
                rng.he_tensor(&t.dims)
            }
        })
        .collect();
    let y = store.run_f32("nerf_forward", &inputs).unwrap();
    assert_eq!(y.len(), 1);
    assert_eq!(y[0].dims, vec![TILE * 2, OUT]);
    assert!(y[0].data.iter().all(|&v| (0.0..=1.0).contains(&v)), "sigmoid range");
    // The pallas-path entry is numerically identical by construction.
    let y2 = store.run_f32("nerf_forward_pallas", &inputs).unwrap();
    assert_eq!(y[0].data, y2[0].data);
}

#[test]
fn train_step_descends_through_store() {
    // Mirror of `integration_runtime::train_step_descends_through_pjrt`,
    // running on the interpreter against a fixed batch.
    let store = store("train");
    let spec = store.spec("train_step").unwrap().clone();
    let mut rng = Rng::new(42);
    let x = Tensor {
        dims: spec.inputs[0].dims.clone(),
        data: (0..spec.inputs[0].numel()).map(|_| rng.normal()).collect(),
        prec: kitsune::runtime::Precision::F32,
    };
    let y = Tensor {
        dims: spec.inputs[1].dims.clone(),
        data: (0..spec.inputs[1].numel()).map(|_| rng.uniform()).collect(),
        prec: kitsune::runtime::Precision::F32,
    };
    let mut params: Vec<Tensor> =
        spec.inputs[2..].iter().map(|t| rng.he_tensor(&t.dims)).collect();
    let mut losses = Vec::new();
    for _ in 0..60 {
        let mut args = vec![x.clone(), y.clone()];
        args.extend(params.iter().cloned());
        let mut outs = store.run_f32("train_step", &args).unwrap();
        losses.push(outs.remove(0).scalar_value());
        params = outs;
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.999),
        "no descent: {losses:?}"
    );
}

#[test]
fn spatial_pipeline_matches_serial_bitwise_on_interp() {
    // The full coordinator path — ring queues, stage threads, ordered
    // sink — over interpreter-backed stage executables.
    let store = store("pipe");
    let pipeline = build_nerf_pipeline(&store, 2).unwrap();
    let inputs = input_tiles(&store, "stage_trunk0", 24).unwrap();
    let serial = run_serial(&store, &pipeline, inputs.clone()).unwrap();
    let streamed = run_streaming(&store, &pipeline, inputs).unwrap();
    assert_eq!(streamed.outputs.len(), serial.outputs.len());
    for (a, b) in streamed.outputs.iter().zip(&serial.outputs) {
        assert_eq!(a.dims, b.dims);
        assert_eq!(a.data, b.data, "tile outputs must be bit-identical");
    }
    for m in &streamed.metrics {
        assert_eq!(m.tiles, 24, "stage {}", m.name);
    }
}

#[test]
fn session_lowering_reproduces_hand_built_pipeline_bitwise() {
    // The tentpole contract: a graph compiled and lowered through the
    // session façade must reproduce — bit for bit — what the legacy
    // hand-stitched pipeline (manifest entries + explicit stage list)
    // computes. Same He seed (0xC0FFEE), same input stream (0xFEED), so
    // the two paths are numerically the same factorized MLP.
    let store = store("session_equiv");
    let legacy_pipeline = build_nerf_pipeline(&store, 2).unwrap();
    let inputs = input_tiles(&store, "stage_trunk0", 16).unwrap();
    let legacy = run_streaming(&store, &legacy_pipeline, inputs).unwrap();

    let session = Session::builder()
        .graph(nerf_trunk_graph(64, IN, HIDDEN, OUT))
        .tile_rows(TILE)
        .build()
        .unwrap();
    let out = session.run(session.make_tiles(16, 0xFEED).unwrap()).unwrap();
    assert_eq!(out.outputs.len(), legacy.outputs.len());
    for (a, b) in out.outputs.iter().zip(&legacy.outputs) {
        assert_eq!(a.dims, b.dims);
        assert_eq!(
            a.data, b.data,
            "compiled-lowered session must reproduce the hand-built artifact pipeline"
        );
    }
}

#[test]
fn run_rejects_wrong_arity_shape_and_unknown_entry() {
    let store = store("reject");
    let err = store.run_f32("nerf_forward", &[]).unwrap_err();
    assert!(err.to_string().contains("inputs"), "{err}");
    let spec = store.spec("stage_trunk1").unwrap().clone();
    let mut bad: Vec<Tensor> = spec.inputs.iter().map(|t| Tensor::zeros(&t.dims)).collect();
    bad[0] = Tensor::zeros(&[1, 1]);
    let err = store.run_f32("stage_trunk1", &bad).unwrap_err();
    assert!(err.to_string().contains("dims"), "{err}");
    let err = store.run_f32("nope", &[]).unwrap_err();
    assert!(matches!(
        err.downcast_ref::<RuntimeError>(),
        Some(RuntimeError::UnknownEntry { .. })
    ));
}

#[test]
fn missing_artifacts_is_a_typed_clean_error() {
    let err = ArtifactStore::load("definitely-not-an-artifact-dir").unwrap_err();
    match err.downcast_ref::<RuntimeError>() {
        Some(RuntimeError::ArtifactsMissing { dir }) => {
            assert!(dir.ends_with("definitely-not-an-artifact-dir"));
        }
        other => panic!("expected ArtifactsMissing, got {other:?}"),
    }
    // The message tells the user the fix and that it is optional — no raw
    // io error chain.
    let msg = err.to_string();
    assert!(msg.contains("make artifacts"), "{msg}");
    assert!(!msg.to_lowercase().contains("os error"), "{msg}");
}

#[test]
fn unsupported_manifest_entry_fails_with_typed_error() {
    let dir = std::env::temp_dir().join(format!(
        "kitsune_interp_test_{}_unsupported",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.txt"),
        "exotic_entry\texotic_entry.hlo.txt\tin=f32[4,4]\tout=1\n",
    )
    .unwrap();
    let err = ArtifactStore::load_with(&dir, Box::new(InterpBackend::new())).unwrap_err();
    match err.downcast_ref::<RuntimeError>() {
        Some(RuntimeError::UnsupportedEntry { name, backend }) => {
            assert_eq!(name, "exotic_entry");
            assert_eq!(*backend, "interp");
        }
        other => panic!("expected UnsupportedEntry, got {other:?}"),
    }
    assert!(err.to_string().contains("pjrt"), "{err}");
}
