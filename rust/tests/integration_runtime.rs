//! Integration over the real runtime + coordinator: AOT artifacts loaded
//! through the active backend (PJRT under `--features pjrt`, the pure-Rust
//! interpreter otherwise), the Pallas-kernel path checked against the
//! reference path, training descending, and the spatial pipeline matching
//! serial execution bit for bit.
//!
//! These tests require `make artifacts`; they are skipped (pass trivially
//! with a notice) when the artifact directory is absent so `cargo test`
//! works in a fresh offline checkout. The skip signal is the *typed*
//! [`RuntimeError::ArtifactsMissing`] — anything else is a real failure
//! worth surfacing. Backend-independent coverage of the same scenarios
//! lives in `interp_runtime.rs`, which never skips.

use kitsune::coordinator::cli::{build_nerf_pipeline, input_tiles};
use kitsune::coordinator::{run_serial, run_streaming};
use kitsune::runtime::{ArtifactStore, Rng, RuntimeError, Tensor};

fn store() -> Option<ArtifactStore> {
    match ArtifactStore::load("artifacts") {
        Ok(s) => Some(s),
        Err(e) => {
            assert!(
                matches!(
                    e.downcast_ref::<RuntimeError>(),
                    Some(RuntimeError::ArtifactsMissing { .. })
                ),
                "artifact load failed for a reason other than a fresh checkout: {e:?}"
            );
            eprintln!("skipping runtime test: {e}");
            None
        }
    }
}

#[test]
fn manifest_has_all_entries() {
    let Some(store) = store() else { return };
    let names = store.entry_names();
    for want in [
        "nerf_forward",
        "nerf_forward_pallas",
        "train_step",
        "stage_trunk0",
        "stage_trunk1",
        "stage_head",
    ] {
        assert!(names.contains(&want), "missing entry {want}: {names:?}");
    }
}

#[test]
fn pallas_kernel_path_matches_reference_through_pjrt() {
    // The L1 Pallas kernel, lowered inside the L2 model and compiled by
    // XLA, must agree with the pure-jnp path — end to end through the
    // Rust runtime, not just in pytest.
    let Some(store) = store() else { return };
    let spec = store.spec("nerf_forward").unwrap().clone();
    let mut rng = Rng::new(123);
    let inputs: Vec<Tensor> = spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, t)| {
            if i == 0 {
                let numel: usize = t.dims.iter().product();
                Tensor {
                    dims: t.dims.clone(),
                    data: (0..numel).map(|_| rng.normal()).collect(),
                    prec: kitsune::runtime::Precision::F32,
                }
            } else {
                rng.he_tensor(&t.dims)
            }
        })
        .collect();
    let y_ref = store.run_f32("nerf_forward", &inputs).unwrap();
    let y_pal = store.run_f32("nerf_forward_pallas", &inputs).unwrap();
    assert_eq!(y_ref[0].dims, y_pal[0].dims);
    let max_err = y_ref[0]
        .data
        .iter()
        .zip(&y_pal[0].data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-4, "pallas vs ref max err {max_err}");
}

#[test]
fn outputs_in_unit_range() {
    // nerf_forward ends in a sigmoid: outputs must be in (0, 1).
    let Some(store) = store() else { return };
    let spec = store.spec("nerf_forward").unwrap().clone();
    let mut rng = Rng::new(5);
    let inputs: Vec<Tensor> = spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, t)| {
            if i == 0 {
                let numel: usize = t.dims.iter().product();
                Tensor {
                    dims: t.dims.clone(),
                    data: (0..numel).map(|_| rng.normal()).collect(),
                    prec: kitsune::runtime::Precision::F32,
                }
            } else {
                rng.he_tensor(&t.dims)
            }
        })
        .collect();
    let y = store.run_f32("nerf_forward", &inputs).unwrap();
    assert!(y[0].data.iter().all(|&v| (0.0..=1.0).contains(&v)));
}

#[test]
fn train_step_descends_through_pjrt() {
    let Some(store) = store() else { return };
    let spec = store.spec("train_step").unwrap().clone();
    let mut rng = Rng::new(42);
    let x_dims = &spec.inputs[0].dims;
    let y_dims = &spec.inputs[1].dims;
    let x = Tensor {
        dims: x_dims.clone(),
        data: (0..x_dims.iter().product::<usize>()).map(|_| rng.normal()).collect(),
        prec: kitsune::runtime::Precision::F32,
    };
    let y = Tensor {
        dims: y_dims.clone(),
        data: (0..y_dims.iter().product::<usize>()).map(|_| rng.uniform()).collect(),
        prec: kitsune::runtime::Precision::F32,
    };
    let mut params: Vec<Tensor> =
        spec.inputs[2..].iter().map(|t| rng.he_tensor(&t.dims)).collect();
    let mut losses = Vec::new();
    for _ in 0..12 {
        let mut args = vec![x.clone(), y.clone()];
        args.extend(params.iter().cloned());
        let mut outs = store.run_f32("train_step", &args).unwrap();
        losses.push(outs.remove(0).scalar_value());
        params = outs;
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.999),
        "no descent: {losses:?}"
    );
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn spatial_pipeline_matches_serial_bitwise() {
    let Some(store) = store() else { return };
    let pipeline = build_nerf_pipeline(&store, 2).unwrap();
    let inputs = input_tiles(&store, "stage_trunk0", 24).unwrap();
    let serial = run_serial(&store, &pipeline, inputs.clone()).unwrap();
    let streamed = run_streaming(&store, &pipeline, inputs).unwrap();
    assert_eq!(streamed.outputs.len(), serial.outputs.len());
    for (a, b) in streamed.outputs.iter().zip(&serial.outputs) {
        assert_eq!(a.dims, b.dims);
        assert_eq!(a.data, b.data, "tile outputs must be bit-identical");
    }
    // Every stage processed every tile exactly once.
    for m in &streamed.metrics {
        assert_eq!(m.tiles, 24, "stage {}", m.name);
    }
}

#[test]
fn pipeline_worker_scaling_preserves_results() {
    // The ILP-allocation analog: changing per-stage worker counts must
    // never change the answer, only the schedule.
    let Some(store) = store() else { return };
    let inputs = input_tiles(&store, "stage_trunk0", 16).unwrap();
    let p1 = build_nerf_pipeline(&store, 1).unwrap();
    let p3 = build_nerf_pipeline(&store, 3).unwrap();
    let r1 = run_streaming(&store, &p1, inputs.clone()).unwrap();
    let r3 = run_streaming(&store, &p3, inputs).unwrap();
    for (a, b) in r1.outputs.iter().zip(&r3.outputs) {
        assert_eq!(a.data, b.data);
    }
}

#[test]
fn run_rejects_wrong_arity_and_shape() {
    let Some(store) = store() else { return };
    let err = store.run_f32("nerf_forward", &[]).unwrap_err();
    assert!(err.to_string().contains("inputs"), "{err}");
    let spec = store.spec("stage_trunk1").unwrap().clone();
    let mut bad: Vec<Tensor> = spec.inputs.iter().map(|t| Tensor::zeros(&t.dims)).collect();
    bad[0] = Tensor::zeros(&[1, 1]);
    let err = store.run_f32("stage_trunk1", &bad).unwrap_err();
    assert!(err.to_string().contains("dims"), "{err}");
    assert!(store.run_f32("nope", &[]).is_err());
}
