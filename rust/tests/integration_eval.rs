//! Integration: compiler → simulator → reports, asserting the paper's
//! evaluation *shape* (who wins, roughly by how much, where the
//! exceptions are) on the A100 config.

use kitsune::apps;
use kitsune::exec::geomean;
use kitsune::report::{evaluate_app, evaluate_suite};
use kitsune::sim::GpuConfig;

#[test]
fn inference_suite_shape_matches_paper() {
    let cfg = GpuConfig::a100();
    let evals = evaluate_suite(&apps::inference_suite(), &cfg).unwrap();

    // Every app: Kitsune reduces DRAM traffic vs BSP (Table 2).
    for e in &evals {
        assert!(
            e.kitsune_traffic_reduction() >= 0.0,
            "{}: negative traffic reduction",
            e.name
        );
    }

    // Paper Fig 11: geomean e2e speedup ~1.5x; vertical fusion weaker
    // (~1.14x); Llama-Ctx the weakest app.
    let ki: Vec<f64> = evals.iter().map(|e| e.kitsune_speedup()).collect();
    let vf: Vec<f64> = evals.iter().map(|e| e.vertical_speedup()).collect();
    let ki_gm = geomean(&ki);
    let vf_gm = geomean(&vf);
    assert!(ki_gm > 1.25 && ki_gm < 2.2, "kitsune geomean {ki_gm}");
    assert!(vf_gm > 1.0 && vf_gm < 1.5, "vertical geomean {vf_gm}");
    assert!(ki_gm > vf_gm, "kitsune must beat vertical fusion");

    let llctx = evals.iter().find(|e| e.name == "LL-CTX").unwrap();
    let min_speedup = ki.iter().copied().fold(f64::INFINITY, f64::min);
    assert!(
        (llctx.kitsune_speedup() - min_speedup).abs() < 0.15,
        "LL-CTX should be (near-)weakest: {} vs min {min_speedup}",
        llctx.kitsune_speedup()
    );

    // NERF: near-total traffic elimination (paper: 98.6%).
    let nerf = evals.iter().find(|e| e.name == "NERF").unwrap();
    assert!(
        nerf.kitsune_traffic_reduction() > 0.8,
        "NERF traffic reduction {}",
        nerf.kitsune_traffic_reduction()
    );
    // LL-TOK: ~no traffic reduction (paper: 0.07%) — weights dominate.
    let lltok = evals.iter().find(|e| e.name == "LL-TOK").unwrap();
    assert!(
        lltok.kitsune_traffic_reduction() < 0.05,
        "LL-TOK traffic reduction {}",
        lltok.kitsune_traffic_reduction()
    );
}

#[test]
fn training_suite_shape_matches_paper() {
    let cfg = GpuConfig::a100();
    let evals = evaluate_suite(&apps::training_suite(), &cfg).unwrap();

    // Vertical fusion barely helps training (fwd-only; paper Fig 14).
    for e in &evals {
        assert!(
            e.vertical_speedup() < 1.2,
            "{}: VF training speedup {} too high",
            e.name,
            e.vertical_speedup()
        );
        assert!(
            e.kitsune_speedup() > 1.0,
            "{}: kitsune training speedup {}",
            e.name,
            e.kitsune_speedup()
        );
    }
    // DLRM: weakest training speedup (unfused interaction backward —
    // the paper's Amdahl effect).
    let dlrm = evals.iter().find(|e| e.name == "DLRM").unwrap();
    let min = evals
        .iter()
        .map(|e| e.kitsune_speedup())
        .fold(f64::INFINITY, f64::min);
    assert!(
        dlrm.kitsune_speedup() < min + 0.4,
        "DLRM should be near-weakest: {} vs {min}",
        dlrm.kitsune_speedup()
    );
}

#[test]
fn utilization_quadrants_improve_under_kitsune() {
    // Paper Figs 3 vs 13: Kitsune cuts time spent with both resources low.
    let cfg = GpuConfig::a100();
    let suite = apps::inference_suite();
    let mut bsp_low = 0.0;
    let mut kitsune_low = 0.0;
    for (name, g) in &suite {
        let e = evaluate_app(name, g, &cfg).unwrap();
        bsp_low += e.bsp.sim.quadrants.normalized().both_low;
        kitsune_low += e.kitsune.sim.quadrants.normalized().both_low;
    }
    assert!(
        kitsune_low < bsp_low,
        "kitsune both-low {kitsune_low} !< bsp {bsp_low}"
    );
}

#[test]
fn sensitivity_kitsune_converts_cheap_resources_better() {
    // Paper §1(5): with 2x SMs + 2x L2 BW (DRAM fixed), Kitsune gains
    // more than baseline execution does.
    let base = GpuConfig::a100();
    let upgraded = GpuConfig::a100().scale_compute(2.0).scale_l2_bw(2.0);
    let suite = apps::inference_suite();
    let mut bsp_gain = Vec::new();
    let mut ki_gain = Vec::new();
    for (name, g) in &suite {
        let e0 = evaluate_app(name, g, &base).unwrap();
        let e1 = evaluate_app(name, g, &upgraded).unwrap();
        bsp_gain.push(e0.bsp.sim.elapsed_s / e1.bsp.sim.elapsed_s);
        ki_gain.push(e0.kitsune.sim.elapsed_s / e1.kitsune.sim.elapsed_s);
    }
    let b = geomean(&bsp_gain);
    let k = geomean(&ki_gain);
    assert!(k > b, "kitsune sensitivity gain {k} !> baseline {b}");
}

#[test]
fn evaluation_is_deterministic() {
    let cfg = GpuConfig::a100();
    let (name, g) = &apps::inference_suite()[2];
    let a = evaluate_app(name, g, &cfg).unwrap();
    let b = evaluate_app(name, g, &cfg).unwrap();
    assert_eq!(a.kitsune.sim.elapsed_s, b.kitsune.sim.elapsed_s);
    assert_eq!(a.kitsune.sim.dram_bytes, b.kitsune.sim.dram_bytes);
    assert_eq!(a.bsp.sim.elapsed_s, b.bsp.sim.elapsed_s);
}

#[test]
fn table2_coverage_bands() {
    let cfg = GpuConfig::a100();
    let evals = evaluate_suite(&apps::inference_suite(), &cfg).unwrap();
    for e in &evals {
        let cov = e.kitsune_fused_ops as f64 / e.n_ops as f64;
        // Paper Table 2 inference coverage: 70-100%.
        assert!(cov >= 0.6, "{}: kitsune coverage {cov}", e.name);
    }
    // NERF reaches (near-)full coverage.
    let nerf = evals.iter().find(|e| e.name == "NERF").unwrap();
    assert!(nerf.kitsune_fused_ops as f64 / nerf.n_ops as f64 > 0.9);
}
