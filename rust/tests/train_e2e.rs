//! End-to-end training on the real DAG pipeline (`kitsune::train`):
//!
//! * the NERF training *app* (the paper's Fig 12/14 subject) lowers to a
//!   genuine streaming pipeline — no `NotStreamable` — with multicast
//!   fan-out and skip-link queue edges;
//! * pipeline-executed gradients match the serial oracle **bitwise**
//!   (same stage programs, same tile order, same fold — the
//!   `kernel_equivalence` contract lifted to whole training steps);
//! * gradients match central finite differences of the streamed loss;
//! * `Trainer` drives ≥ 10 optimizer steps and the loss descends, on a
//!   tiny NeRF (skip concat exercised) and a dense DLRM MLP;
//! * gather-bearing apps (full DLRM) fall back to `simulate()` with a
//!   typed reason naming the offending op.

use kitsune::apps::{dlrm, nerf};
use kitsune::session::{Session, SessionError};
use kitsune::train::{serial_step, split_batch, OptimizerKind, TrainBatch};

/// A NeRF small enough for interpreter-speed training, with the skip
/// concat (multicast + slice backward) still in play.
fn tiny_nerf() -> kitsune::graph::Graph {
    nerf::training(&nerf::NerfConfig {
        batch: 64,
        pos_enc: 8,
        dir_enc: 4,
        hidden: 16,
        depth: 3,
        skip_at: 1,
    })
}

#[test]
fn nerf_app_training_builds_real_streaming_pipeline() {
    // The acceptance shape: the full NERF training app — 69 ops, skip
    // concat, multicast backward — lowers with no NotStreamable and
    // stands up a warm DAG pool.
    let session = Session::builder().app("NERF").training(true).build().unwrap();
    assert!(
        session.is_trainable(),
        "NERF training must stream: {:?}",
        session.not_streamable_reason()
    );
    assert!(session.not_streamable_reason().is_none());
    let plan = session.train_plan().unwrap();
    assert!(plan.pipeline.stages.len() > 20, "real stage count: {}", plan.pipeline.stages.len());
    assert!(plan.n_multicasts() > 0, "backward passes multicast saved activations");
    assert!(plan.n_skip_links() > 0, "saved activations ride skip links to their wgrads");
    // Gradients tapped for every live parameter (weights + biases of the
    // trunk, feat and rgb layers), plus the loss tap.
    assert!(plan.taps.len() > 10, "{:?}", plan.taps.len());
    // One worker per stage plus the sink, spawned at build.
    assert_eq!(session.threads_spawned(), plan.pipeline.stages.len() + 1);
    session.shutdown();
}

#[test]
fn pipeline_gradients_match_serial_oracle_bitwise() {
    let session = Session::builder().graph(tiny_nerf()).tile_rows(16).build().unwrap();
    let plan = session.train_plan().unwrap();
    let batch = session.make_train_batch(42).unwrap();
    let tiles = split_batch(plan, &batch).unwrap();
    let mut trainer = session.trainer().unwrap();

    // Oracle over the same initial parameters, same tiles.
    let params0: Vec<_> = trainer.params().into_iter().map(|(_, t)| t).collect();
    let serial = serial_step(plan, &params0, &tiles).unwrap();
    let stats = trainer.step(&batch).unwrap();
    assert_eq!(stats.tiles, plan.n_tiles());
    assert_eq!(
        stats.loss.to_bits(),
        serial.loss.to_bits(),
        "pipeline loss must match the serial oracle bitwise"
    );
    assert!(!stats.grads.is_empty());
    for (name, grad) in &stats.grads {
        let pi = plan.params.iter().position(|p| &p.name == name).unwrap();
        let want = serial.grads[pi].as_ref().expect("oracle gradient present");
        let gb: Vec<u32> = grad.data.iter().map(|v| v.to_bits()).collect();
        let wb: Vec<u32> = want.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(gb, wb, "gradient `{name}` must match the oracle bitwise");
    }

    // Second step: the warm pool must see the *updated* parameters.
    let params1: Vec<_> = trainer.params().into_iter().map(|(_, t)| t).collect();
    let serial2 = serial_step(plan, &params1, &tiles).unwrap();
    let stats2 = trainer.step(&batch).unwrap();
    assert_eq!(
        stats2.loss.to_bits(),
        serial2.loss.to_bits(),
        "step 2 must run against the optimizer-updated parameters"
    );
    session.shutdown();
}

#[test]
fn pipeline_gradients_match_finite_differences() {
    // Cold session: the plan alone drives the serial executor, which the
    // bitwise test above ties to the pipeline.
    let session =
        Session::builder().graph(tiny_nerf()).tile_rows(16).warm(false).build().unwrap();
    let plan = session.train_plan().unwrap();
    let batch = TrainBatch::synthetic(plan, 7);
    let tiles = split_batch(plan, &batch).unwrap();
    let params0: Vec<_> = plan.params.iter().map(|p| p.init.clone()).collect();
    let base = serial_step(plan, &params0, &tiles).unwrap();

    let loss_at = |params: &[kitsune::runtime::Tensor]| -> f64 {
        serial_step(plan, params, &tiles).unwrap().loss as f64
    };
    let eps = 1e-3f64;
    // A spread of parameters: first trunk weight, a bias, the head weight.
    let picks: Vec<usize> = vec![0, 1, plan.params.len() - 2];
    for pi in picks {
        let numel = params0[pi].data.len();
        let grad = base.grads[pi].as_ref().expect("gradient tapped");
        for &k in &[0usize, numel / 2, numel - 1] {
            let mut plus = params0.clone();
            plus[pi].data[k] += eps as f32;
            let mut minus = params0.clone();
            minus[pi].data[k] -= eps as f32;
            let fd = (loss_at(&plus) - loss_at(&minus)) / (2.0 * eps);
            let analytic = grad.data[k] as f64;
            assert!(
                (fd - analytic).abs() < 1e-3 + 0.08 * analytic.abs(),
                "param {pi} (`{}`)[{k}]: finite-diff {fd} vs analytic {analytic}",
                plan.params[pi].name
            );
        }
    }
}

#[test]
fn trainer_descends_on_tiny_nerf() {
    let session = Session::builder().graph(tiny_nerf()).tile_rows(16).build().unwrap();
    let mut trainer = session.trainer_with(OptimizerKind::adam(1e-2)).unwrap();
    let batch = session.make_train_batch(0xF00D).unwrap();
    let mut losses = Vec::new();
    for _ in 0..12 {
        let stats = trainer.step(&batch).unwrap();
        assert!(stats.loss.is_finite());
        losses.push(stats.loss);
    }
    assert_eq!(trainer.steps(), 12, "≥ 10 optimizer steps drove the warm pipeline");
    assert!(
        *losses.last().unwrap() < losses[0] * 0.95,
        "loss must descend: {losses:?}"
    );
    session.shutdown();
}

#[test]
fn trainer_descends_on_dense_dlrm_with_momentum() {
    let g = dlrm::dense_training(&dlrm::DlrmConfig {
        batch: 64,
        dense_features: 8,
        bottom_mlp: vec![16, 8],
        top_mlp: vec![16, 1],
        ..dlrm::DlrmConfig::default()
    });
    let session = Session::builder().graph(g).tile_rows(16).build().unwrap();
    assert!(session.is_trainable(), "{:?}", session.not_streamable_reason());
    let mut trainer = session
        .trainer_with(OptimizerKind::Sgd { lr: 0.1, momentum: 0.8 })
        .unwrap();
    let batch = session.make_train_batch(0xD1CE).unwrap();
    let mut losses = Vec::new();
    for _ in 0..20 {
        losses.push(trainer.step(&batch).unwrap().loss);
    }
    assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
    assert!(
        *losses.last().unwrap() < losses[0] * 0.95,
        "momentum SGD must descend: {losses:?}"
    );
    session.shutdown();
}

#[test]
fn backpressure_with_tiny_queues_still_completes() {
    // More in-flight tiles than any ring can hold: the microbatch must
    // drain through backpressure (blocking pushes) without wedging —
    // the unit-rate dataflow graph is deadlock-free for any capacity ≥ 1.
    let g = dlrm::dense_training(&dlrm::DlrmConfig {
        batch: 96,
        dense_features: 6,
        bottom_mlp: vec![8],
        top_mlp: vec![8, 1],
        ..dlrm::DlrmConfig::default()
    });
    let session = Session::builder().graph(g).tile_rows(8).queue_capacity(2).build().unwrap();
    let plan = session.train_plan().unwrap();
    assert!(plan.n_tiles() > plan.pipeline.queue_capacity * 2, "{}", plan.n_tiles());
    let mut trainer = session.trainer().unwrap();
    let batch = session.make_train_batch(3).unwrap();
    for _ in 0..2 {
        let stats = trainer.step(&batch).unwrap();
        assert!(stats.loss.is_finite());
        assert_eq!(stats.tiles, 12);
    }
    session.shutdown();
}

#[test]
fn gather_apps_fall_back_with_reason_naming_the_op() {
    // Full DLRM training carries embedding-bag gathers: §5.1-excluded,
    // so the session keeps simulate() and the reason names the gather.
    let session = Session::builder().app("DLRM").training(true).build().unwrap();
    assert!(!session.is_trainable());
    let reason = session.not_streamable_reason().expect("typed fallback reason");
    assert!(reason.contains("gather"), "{reason}");
    assert!(reason.contains("emb"), "reason names the node: {reason}");
    let err = session.trainer().unwrap_err();
    assert!(matches!(
        err.downcast_ref::<SessionError>(),
        Some(SessionError::NotStreamable { .. })
    ));
    // The documented fallback still works.
    assert!(session.simulate().is_ok());
}

#[test]
fn cold_training_session_has_plan_but_no_trainer() {
    let session =
        Session::builder().graph(tiny_nerf()).tile_rows(16).warm(false).build().unwrap();
    assert!(session.is_trainable());
    assert_eq!(session.threads_spawned(), 0);
    let err = session.trainer().unwrap_err();
    assert!(matches!(err.downcast_ref::<SessionError>(), Some(SessionError::Cold)));
}

#[test]
fn default_tile_rows_divides_odd_batches() {
    // batch 100: floor(100/16) = 6 does not divide 100 — the default must
    // fall back to a divisor (5) instead of rejecting the graph.
    let g = nerf::training(&nerf::NerfConfig {
        batch: 100,
        pos_enc: 8,
        dir_enc: 4,
        hidden: 16,
        depth: 2,
        skip_at: 1,
    });
    let session = Session::builder().graph(g).warm(false).build().unwrap();
    let plan = session
        .train_plan()
        .unwrap_or_else(|| panic!("odd batch must stream: {:?}", session.not_streamable_reason()));
    assert_eq!(plan.batch_rows % plan.tile_rows, 0);
    assert_eq!(plan.tile_rows, 5);
}

#[test]
fn train_batch_and_split_validate_shapes() {
    let session =
        Session::builder().graph(tiny_nerf()).tile_rows(16).warm(false).build().unwrap();
    let plan = session.train_plan().unwrap();
    // Sources: pos_enc, dir_enc, target — in graph order, target last.
    let names: Vec<&str> = plan.sources.iter().map(|s| s.name.as_str()).collect();
    assert_eq!(names, vec!["pos_enc", "dir_enc", "target"]);
    let batch = session.make_train_batch(1).unwrap();
    let tiles = split_batch(plan, &batch).unwrap();
    assert_eq!(tiles.len(), 3);
    assert!(tiles.iter().all(|per| per.len() == plan.n_tiles()));
    assert_eq!(tiles[0][0].dims, vec![plan.tile_rows, 8]);
    // Wrong dims are rejected.
    let mut bad = batch.clone();
    bad.inputs[0] = kitsune::runtime::Tensor::zeros(&[4, 8]);
    assert!(split_batch(plan, &bad).is_err());
}
