//! Deterministic fault injection through the supervised dataflow stack
//! (`kitsune::fault`):
//!
//! * an injected panic at *each* stage of the lowered NeRF-trunk pipeline
//!   fails exactly the afflicted ticket — typed, downcastable to
//!   `RuntimeError::StageFailed` — while neighbor tickets complete, and
//!   the supervised restart returns the pipeline to `Healthy`;
//! * a `queue_close` structural fault resolves every ticket typed (the
//!   "shut down" rendering) with zero hung waiters and zero leaked
//!   in-flight tiles;
//! * a NaN loss / NaN gradient skips the optimizer update with the
//!   parameters bitwise unchanged, and descent resumes on the next step;
//! * a stage panic inside the training DAG fails the step typed, the
//!   next step runs clean, and health is restored;
//! * the serve tier retries a request against a `Failed` pipeline until
//!   the retry budget is spent, then resolves it typed, preserving the
//!   `admitted == completed + failed + shed` invariant.
//!
//! Every wait in this file is bounded: a hang is a test failure, not a
//! stuck CI job — that is the satellite "tickets never hang" pin.

use kitsune::apps::nerf;
use kitsune::fault::{FailureCause, FaultPlan, Health};
use kitsune::runtime::RuntimeError;
use kitsune::serve::{ServeConfig, ServeError, Server};
use kitsune::session::{nerf_trunk_graph, BatchResult, Session, Ticket};
use kitsune::train::{OptimizerKind, StepOutcome};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Bounded ticket wait: resolves within 30 s or the test fails. Hung
/// tickets are the bug class this suite exists to catch.
fn wait_bounded(t: Ticket) -> kitsune::Result<BatchResult> {
    match t.wait_timeout(Duration::from_secs(30)) {
        Ok(r) => r,
        Err(_) => panic!("ticket failed to resolve within 30s — hung ticket"),
    }
}

/// Poll until the session reports `Healthy` (bounded).
fn await_healthy(session: &Session) {
    let t0 = Instant::now();
    while !session.health().is_healthy() {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "pipeline health did not recover: {:?}",
            session.health()
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Extract the typed stage failure from an `anyhow` error, or fail.
fn stage_failure(err: &anyhow::Error) -> kitsune::fault::StageFailure {
    match err.downcast_ref::<RuntimeError>() {
        Some(RuntimeError::StageFailed(f)) => f.clone(),
        other => panic!("expected RuntimeError::StageFailed, got {other:?} ({err:#})"),
    }
}

/// The tiny NeRF training graph from `train_e2e` — skip concat and
/// multicast backward in play, small enough for interpreter speed.
fn tiny_nerf() -> kitsune::graph::Graph {
    nerf::training(&nerf::NerfConfig {
        batch: 64,
        pos_enc: 8,
        dir_enc: 4,
        hidden: 16,
        depth: 3,
        skip_at: 1,
    })
}

#[test]
fn injected_panic_at_each_stage_fails_only_the_afflicted_ticket() {
    // Stage count of the lowered trunk (probe is cold: no pools spawned).
    let probe = Session::builder()
        .graph(nerf_trunk_graph(64, 6, 16, 3))
        .tile_rows(4)
        .warm(false)
        .build()
        .unwrap();
    let n_stages = probe.pipeline().unwrap().stages.len();
    assert!(n_stages >= 4, "nerf trunk must lower to >= 4 stages, got {n_stages}");

    for si in 0..n_stages {
        // One worker per stage: per-stage tile ordinals match submission
        // order, so `panic_at(si, 2)` deterministically strikes the third
        // single-tile batch.
        let session = Session::builder()
            .graph(nerf_trunk_graph(64, 6, 16, 3))
            .tile_rows(4)
            .workers(1)
            .fault_plan(FaultPlan::new().panic_at(si, 2))
            .build()
            .unwrap();
        let tiles = session.make_tiles(5, 0xBEEF).unwrap();
        let tickets: Vec<Ticket> =
            tiles.into_iter().map(|t| session.submit(vec![t]).unwrap()).collect();
        for (i, ticket) in tickets.into_iter().enumerate() {
            let r = wait_bounded(ticket);
            if i == 2 {
                let err = r.expect_err("afflicted ticket must fail typed");
                let failure = stage_failure(&err);
                assert_eq!(failure.stage_index, Some(si), "{failure}");
                assert_eq!(failure.tile_seq, Some(2), "{failure}");
                assert!(
                    matches!(&failure.cause, FailureCause::Panic(m) if m.contains("injected fault")),
                    "cause must carry the injection message: {failure}"
                );
            } else {
                let out = r.unwrap_or_else(|e| {
                    panic!("neighbor ticket {i} must complete (stage {si} injected): {e:#}")
                });
                assert_eq!(out.outputs.len(), 1);
            }
        }
        // Supervised restart: back to Healthy, and fresh work flows.
        await_healthy(&session);
        let more = session.make_tiles(2, 0xD00D).unwrap();
        let out = wait_bounded(session.submit(more).unwrap())
            .unwrap_or_else(|e| panic!("post-restart submit must succeed (stage {si}): {e:#}"));
        assert_eq!(out.outputs.len(), 2);
        session.shutdown();
    }
}

#[test]
fn queue_close_injection_resolves_every_ticket_typed_and_leaks_nothing() {
    let session = Session::builder()
        .graph(nerf_trunk_graph(64, 6, 16, 3))
        .tile_rows(4)
        .workers(1)
        .fault_plan(FaultPlan::new().queue_close(1))
        .build()
        .unwrap();
    // The structural fault fires at startup, before any traffic.
    assert!(
        matches!(session.health(), Health::Failed { .. }),
        "closed edge must fail the pipeline: {:?}",
        session.health()
    );
    let tiles = session.make_tiles(4, 1).unwrap();
    let err = wait_bounded(session.submit(tiles).unwrap())
        .expect_err("tickets behind a dead edge must fail, not hang");
    assert!(err.to_string().contains("shut down"), "{err:#}");
    let failure = stage_failure(&err);
    assert_eq!(failure.cause, FailureCause::QueueClosed, "{failure}");
    // Every tile resolved: the in-flight table drains to zero.
    let t0 = Instant::now();
    while session.in_flight() != 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "leaked in-flight tiles");
        std::thread::sleep(Duration::from_millis(1));
    }
    session.shutdown();
}

#[test]
fn nan_loss_skips_the_optimizer_update_params_bitwise_unchanged() {
    let session = Session::builder()
        .graph(tiny_nerf())
        .tile_rows(16)
        .fault_plan(FaultPlan::new().nan_loss(1))
        .build()
        .unwrap();
    let mut trainer = session.trainer_with(OptimizerKind::adam(1e-2)).unwrap();
    let batch = session.make_train_batch(0xF00D).unwrap();

    let s0 = trainer.step(&batch).unwrap();
    assert_eq!(s0.outcome, StepOutcome::Applied);
    let loss0 = s0.loss;
    assert!(loss0.is_finite());

    // Step 1: the injected NaN loss trips the non-finite guard.
    let before = trainer.params();
    let s1 = trainer.step(&batch).unwrap();
    assert!(s1.loss.is_nan(), "injected NaN loss must surface: {}", s1.loss);
    assert!(
        matches!(&s1.outcome, StepOutcome::Skipped { reason } if reason.contains("loss")),
        "{:?}",
        s1.outcome
    );
    assert!(s1.grads.is_empty(), "skipped step reports no applied gradients");
    assert_eq!(trainer.steps(), 1, "skipped step must not advance the optimizer");
    let after = trainer.params();
    for ((n0, t0), (n1, t1)) in before.iter().zip(&after) {
        assert_eq!(n0, n1);
        let b0: Vec<u32> = t0.data.iter().map(|v| v.to_bits()).collect();
        let b1: Vec<u32> = t1.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(b0, b1, "`{n0}` must be bitwise unchanged after a skipped step");
    }

    // Descent resumes from the uncorrupted parameters.
    let mut last = loss0;
    for _ in 0..6 {
        let s = trainer.step(&batch).unwrap();
        assert_eq!(s.outcome, StepOutcome::Applied);
        assert!(s.loss.is_finite());
        last = s.loss;
    }
    assert!(last < loss0, "descent must resume after the skipped step: {last} vs {loss0}");
    session.shutdown();
}

#[test]
fn nan_grad_skips_the_optimizer_update() {
    let session = Session::builder()
        .graph(tiny_nerf())
        .tile_rows(16)
        .fault_plan(FaultPlan::new().nan_grad(0))
        .build()
        .unwrap();
    let mut trainer = session.trainer().unwrap();
    let batch = session.make_train_batch(0xBAD).unwrap();
    let before = trainer.params();
    let s0 = trainer.step(&batch).unwrap();
    assert!(s0.loss.is_finite(), "only a gradient was corrupted");
    assert!(
        matches!(&s0.outcome, StepOutcome::Skipped { reason } if reason.contains("non-finite")),
        "{:?}",
        s0.outcome
    );
    assert_eq!(trainer.steps(), 0);
    let after = trainer.params();
    for ((n0, t0), (_, t1)) in before.iter().zip(&after) {
        let b0: Vec<u32> = t0.data.iter().map(|v| v.to_bits()).collect();
        let b1: Vec<u32> = t1.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(b0, b1, "`{n0}` must be bitwise unchanged");
    }
    // The guard is per-step: the next step applies normally.
    let s1 = trainer.step(&batch).unwrap();
    assert_eq!(s1.outcome, StepOutcome::Applied);
    session.shutdown();
}

#[test]
fn train_stage_panic_fails_the_step_typed_then_recovers() {
    let session = Session::builder()
        .graph(tiny_nerf())
        .tile_rows(16)
        .fault_plan(FaultPlan::new().panic_at(0, 0))
        .build()
        .unwrap();
    let mut trainer = session.trainer().unwrap();
    let batch = session.make_train_batch(42).unwrap();

    let err = trainer.step(&batch).expect_err("injected stage panic must fail the step");
    let failure = stage_failure(&err);
    assert_eq!(failure.stage_index, Some(0), "{failure}");
    assert!(matches!(failure.cause, FailureCause::Panic(_)), "{failure}");

    // The fault is one-shot: the next step runs clean over the same warm
    // pumps (per-tile poison never kills the train executor), and the
    // fully-live step restores health.
    let s = trainer.step(&batch).unwrap();
    assert_eq!(s.outcome, StepOutcome::Applied);
    assert!(s.loss.is_finite());
    assert!(session.health().is_healthy(), "{:?}", session.health());
    session.shutdown();
}

#[test]
fn serve_retries_then_resolves_typed_on_a_dead_model() {
    let session = Arc::new(
        Session::builder()
            .graph(nerf_trunk_graph(64, 6, 16, 3))
            .tile_rows(4)
            .workers(1)
            .fault_plan(FaultPlan::new().queue_close(1))
            .build()
            .unwrap(),
    );
    assert!(matches!(session.health(), Health::Failed { .. }));
    let cfg = ServeConfig { max_retries: 2, ..ServeConfig::default() };
    let server = Server::single("nerf", Arc::clone(&session), cfg);
    let tiles = session.make_tiles(2, 9).unwrap();
    let handle = server.submit("nerf", tiles, None).unwrap();
    match handle.wait() {
        Err(ServeError::Stage(msg)) => {
            assert!(msg.contains("edge 1"), "failure names the dead edge: {msg}")
        }
        other => panic!("expected a typed stage failure, got {other:?}"),
    }
    let stats = server.stats();
    assert_eq!(stats.admitted, 1);
    assert_eq!(stats.retried, 2, "the whole retry budget is consumed first");
    assert_eq!(stats.failed, 1);
    assert_eq!(
        stats.admitted,
        stats.resolved(),
        "admitted == completed + failed + shed must survive faults: {stats:?}"
    );
    server.shutdown();
    session.shutdown();
}

#[test]
fn fault_spec_grammar_round_trips() {
    let plan = FaultPlan::parse("panic:stage=2:tile=7, nan:loss:step=3; queue_close:edge=1")
        .unwrap();
    assert!(!plan.is_empty());
    assert!(plan.take_panic(2, 7));
    assert!(!plan.take_panic(2, 7), "specs are one-shot");
    assert!(plan.take_nan_loss(3));
    assert_eq!(plan.take_queue_closes(), vec![1]);
    // Whole-string parse: one malformed spec rejects the plan.
    assert!(FaultPlan::parse("panic:stage=two").is_err());
    assert!(FaultPlan::parse("nan:loss").is_err());
}
