//! Stress and contract tests for the `kitsune::serve` tier: bounded
//! admission under overload, exactly-once resolution of every admitted
//! request (completed / shed / deadline-exceeded — never hung), clean
//! shutdown under load with an empty in-flight table, and the model
//! registry's memory-budget eviction/refusal policy.

use kitsune::runtime::Tensor;
use kitsune::serve::{
    session_resident_bytes, BatchPolicy, ModelRegistry, ServeConfig, ServeError, Server,
};
use kitsune::session::{nerf_trunk_graph, Session};
use std::sync::Arc;
use std::time::Duration;

/// Small warm session: 4-stage trunk pipeline over 4x6 tiles.
fn small_session() -> Arc<Session> {
    Arc::new(
        Session::builder()
            .graph(nerf_trunk_graph(64, 6, 16, 3))
            .tile_rows(4)
            .workers(2)
            .build()
            .unwrap(),
    )
}

fn fast_config(queue_depth: usize) -> ServeConfig {
    ServeConfig {
        batch: BatchPolicy { max_tiles: 8, max_delay: Duration::from_micros(200) },
        queue_depth,
        default_deadline: None,
        max_retries: 1,
    }
}

#[test]
fn blocking_submit_completes_every_request_under_pressure() {
    // More concurrent clients than the queue admits at once: `submit`
    // must backpressure (block), never drop, and every request must
    // complete with its own outputs.
    let session = small_session();
    let server = Server::single("trunk", Arc::clone(&session), fast_config(4));
    const CLIENTS: usize = 6;
    const REQUESTS: usize = 8;
    const TILES: usize = 2;
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for c in 0..CLIENTS {
            let server = &server;
            let session = &session;
            joins.push(scope.spawn(move || {
                for r in 0..REQUESTS {
                    let tiles = session.make_tiles(TILES, 1 + (c * REQUESTS + r) as u64).unwrap();
                    let reply = server.submit("trunk", tiles, None).unwrap().wait().unwrap();
                    assert_eq!(reply.outputs.len(), TILES, "client {c} request {r}");
                    assert!(reply.latency > Duration::ZERO);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    });
    let stats = server.stats();
    assert_eq!(stats.admitted, (CLIENTS * REQUESTS) as u64);
    assert_eq!(stats.completed, stats.admitted);
    assert_eq!(stats.admitted, stats.resolved(), "every admitted request resolved");
    assert_eq!(stats.queue_depth, 0);
    assert_eq!(stats.in_flight_tiles, 0);
    assert_eq!(stats.latency.count, stats.completed);
    assert!(stats.latency.p99_ms >= stats.latency.p50_ms);
    server.shutdown();
    assert_eq!(session.in_flight(), 0, "no ticket leaks");
}

#[test]
fn try_submit_rejects_past_admission_limit_and_leaks_nothing() {
    // A burst far past the queue bound through the non-blocking path:
    // overflow is refused with the typed backpressure error, and the
    // requests that were admitted all resolve.
    let session = small_session();
    let server = Server::single("trunk", Arc::clone(&session), fast_config(2));
    let mut handles = Vec::new();
    let mut rejected = 0u64;
    for i in 0..64u64 {
        let tiles = session.make_tiles(1, i + 1).unwrap();
        match server.try_submit("trunk", tiles, None) {
            Ok(h) => handles.push(h),
            Err(ServeError::AdmissionRejected { depth, capacity }) => {
                assert!(depth >= capacity, "rejected below capacity: {depth}/{capacity}");
                assert_eq!(capacity, 2);
                rejected += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    let admitted = handles.len() as u64;
    for h in handles {
        let reply = h.wait().expect("admitted requests complete");
        assert_eq!(reply.outputs.len(), 1);
    }
    let stats = server.stats();
    assert_eq!(stats.admitted, admitted);
    assert_eq!(stats.rejected, rejected);
    assert_eq!(stats.admitted, stats.resolved());
    server.shutdown();
    assert_eq!(session.in_flight(), 0);
}

#[test]
fn hopeless_deadlines_are_shed_with_typed_errors() {
    let session = small_session();
    let server = Server::single("trunk", Arc::clone(&session), fast_config(64));
    // One deadline-free request primes the service-time estimate.
    let tiles = session.make_tiles(4, 1).unwrap();
    let reply = server.submit("trunk", tiles, None).unwrap().wait().unwrap();
    assert_eq!(reply.outputs.len(), 4);
    // A 1 ns budget can never be met: refused at admission (estimated
    // wait over budget) or shed at dispatch — either way the caller sees
    // DeadlineExceeded exactly once, never a hang.
    let tiles = session.make_tiles(4, 2).unwrap();
    let outcome = match server.try_submit("trunk", tiles, Some(Duration::from_nanos(1))) {
        Ok(handle) => handle.wait(),
        Err(e) => Err(e),
    };
    match outcome {
        Err(ServeError::DeadlineExceeded { .. }) => {}
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    // A generous deadline sails through.
    let tiles = session.make_tiles(4, 3).unwrap();
    let reply =
        server.submit("trunk", tiles, Some(Duration::from_secs(30))).unwrap().wait().unwrap();
    assert_eq!(reply.outputs.len(), 4);
    let stats = server.stats();
    assert_eq!(stats.refused_deadline + stats.shed_deadline, 1);
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.admitted, stats.resolved());
    server.shutdown();
    assert_eq!(session.in_flight(), 0);
}

#[test]
fn malformed_requests_get_typed_refusals() {
    let session = small_session();
    let server = Server::single("trunk", Arc::clone(&session), fast_config(16));
    match server.try_submit("trunk", Vec::new(), None) {
        Err(ServeError::BadRequest(msg)) => assert!(msg.contains("empty"), "{msg}"),
        other => panic!("expected BadRequest, got {other:?}"),
    }
    match server.try_submit("trunk", vec![Tensor::zeros(&[3, 3])], None) {
        Err(ServeError::BadRequest(msg)) => assert!(msg.contains("dims"), "{msg}"),
        other => panic!("expected BadRequest, got {other:?}"),
    }
    match server.try_submit("nope", session.make_tiles(1, 1).unwrap(), None) {
        Err(ServeError::UnknownModel { name, available }) => {
            assert_eq!(name, "nope");
            assert_eq!(available, vec!["trunk".to_string()]);
        }
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    // Refusals are not admissions; the tier stays reconciled.
    let stats = server.stats();
    assert_eq!(stats.admitted, 0);
    server.shutdown();
}

#[test]
fn shutdown_under_load_resolves_every_handle_and_drains() {
    // Clients hammer the tier while the main thread shuts it down:
    // every submission resolves as exactly one of completed / shed /
    // shutting-down — nothing hangs — and the pipeline's in-flight
    // table returns to empty.
    let session = small_session();
    let server = Server::single("trunk", Arc::clone(&session), fast_config(8));
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for c in 0..4usize {
            let server = &server;
            let session = &session;
            joins.push(scope.spawn(move || {
                let mut ok = 0usize;
                let mut cut = 0usize;
                for r in 0..24usize {
                    let tiles = session.make_tiles(2, 1 + (c * 24 + r) as u64).unwrap();
                    match server.submit("trunk", tiles, None) {
                        Ok(handle) => match handle.wait() {
                            Ok(reply) => {
                                assert_eq!(reply.outputs.len(), 2);
                                ok += 1;
                            }
                            Err(ServeError::ShuttingDown) => cut += 1,
                            Err(e) => panic!("client {c} request {r}: {e}"),
                        },
                        Err(ServeError::ShuttingDown) => cut += 1,
                        Err(e) => panic!("client {c} request {r}: {e}"),
                    }
                }
                (ok, cut)
            }));
        }
        // Let requests get in flight, then pull the plug mid-storm.
        std::thread::sleep(Duration::from_millis(15));
        server.shutdown();
        for j in joins {
            let (ok, cut) = j.join().unwrap();
            assert_eq!(ok + cut, 24, "every request resolved exactly once");
        }
    });
    // Idempotent, and the tier reconciles: all admitted requests ended
    // in a terminal bucket and no tickets leaked.
    server.shutdown();
    let stats = server.stats();
    assert_eq!(stats.admitted, stats.resolved(), "{stats:?}");
    assert_eq!(stats.queue_depth, 0);
    assert_eq!(stats.in_flight_tiles, 0);
    assert_eq!(session.in_flight(), 0, "in-flight table drained");
    match server.try_submit("trunk", session.make_tiles(1, 7).unwrap(), None) {
        Err(ServeError::ShuttingDown) => {}
        other => panic!("expected ShuttingDown, got {other:?}"),
    }
}

#[test]
fn registry_budget_evicts_lru_idle_then_refuses() {
    let a = small_session();
    let b = small_session();
    let bytes = session_resident_bytes(&a);
    assert!(bytes > 0, "a warm pipeline pins memory");
    // Room for one model, not two: inserting the second evicts the
    // (idle) first, LRU-style.
    let registry = ModelRegistry::new(Some(bytes + bytes / 2));
    assert!(registry.insert("a", Arc::clone(&a)).unwrap().is_empty());
    assert_eq!(registry.resident_bytes(), bytes);
    let evicted = registry.insert("b", Arc::clone(&b)).unwrap();
    assert_eq!(evicted, vec!["a".to_string()]);
    assert_eq!(registry.names(), vec!["b".to_string()]);
    match registry.get("a") {
        Err(ServeError::UnknownModel { available, .. }) => {
            assert_eq!(available, vec!["b".to_string()]);
        }
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    registry.get("b").unwrap();
    registry.shutdown_all();

    // A budget no model fits under refuses with the typed error.
    let c = small_session();
    let tiny = ModelRegistry::new(Some(1));
    match tiny.insert("c", c) {
        Err(ServeError::BudgetExceeded { requested, budget, .. }) => {
            assert_eq!(budget, 1);
            assert!(requested > 1);
        }
        other => panic!("expected BudgetExceeded, got {other:?}"),
    }
    assert!(tiny.is_empty());
}

#[test]
fn multi_model_serving_routes_by_name() {
    let registry = Arc::new(ModelRegistry::new(None));
    registry.insert("small", small_session()).unwrap();
    registry
        .insert(
            "wide",
            Arc::new(
                Session::builder()
                    .graph(nerf_trunk_graph(64, 6, 32, 3))
                    .tile_rows(4)
                    .workers(2)
                    .build()
                    .unwrap(),
            ),
        )
        .unwrap();
    let server = Server::new(Arc::clone(&registry), fast_config(16));
    for name in ["small", "wide"] {
        let session = registry.get(name).unwrap();
        let tiles = session.make_tiles(3, 11).unwrap();
        let reply = server.submit(name, tiles, None).unwrap().wait().unwrap();
        assert_eq!(reply.outputs.len(), 3, "model {name}");
    }
    let stats = server.stats();
    assert_eq!(stats.completed, 2);
    server.shutdown();
    for name in ["small", "wide"] {
        assert_eq!(registry.get(name).unwrap().in_flight(), 0);
    }
    registry.shutdown_all();
}
