//! Property-based tests (deterministic xorshift generator — proptest is
//! unavailable offline, so each property sweeps a seeded random space).
//!
//! Invariants covered: ILP optimality vs brute force, autodiff graph
//! validity/consistency, grid-scheduler pairing dominance, queue token
//! conservation under MPMC stress, and simulator work conservation.

use kitsune::compiler::{compile, SelectOptions};
use kitsune::graph::{training_graph, AutodiffOptions, EwKind, GraphBuilder, GraphKind, OpKind};
use kitsune::ilp::{solve_maxmin, AllocVar};
use kitsune::queue::RingQueue;
use kitsune::sim::{Engine, GpuConfig, GridScheduler, SchedPolicy, SmState};
use std::sync::Arc;

struct Rng(u64);
impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }
    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }
    fn f(&mut self) -> f64 {
        (self.next() >> 11) as f64 / (1u64 << 53) as f64
    }
}

#[test]
fn prop_ilp_matches_bruteforce() {
    // Max-min allocation from the solver == exhaustive optimum on random
    // small instances (single class; the class decomposition is trivial).
    let mut rng = Rng::new(0x1234);
    for trial in 0..200 {
        let n = rng.range(1, 3) as usize;
        let budget = rng.range(n as u64, 10) as usize;
        let vars: Vec<AllocVar> = (0..n)
            .map(|_| AllocVar {
                coeff: 0.1 + rng.f() * 3.0,
                class: 0,
                cap: rng.range(1, 10) as usize,
            })
            .collect();
        let got = solve_maxmin(&vars, &[budget]);
        // Brute force over all allocations.
        let mut best: Option<f64> = None;
        let caps: Vec<usize> = vars.iter().map(|v| v.cap).collect();
        let mut a = vec![1usize; n];
        loop {
            if a.iter().sum::<usize>() <= budget && a.iter().zip(&caps).all(|(x, c)| x <= c) {
                let t = vars
                    .iter()
                    .zip(&a)
                    .map(|(v, &ai)| v.coeff * ai as f64)
                    .fold(f64::INFINITY, f64::min);
                best = Some(best.map_or(t, |b: f64| b.max(t)));
            }
            // Increment the mixed-radix counter.
            let mut i = 0;
            loop {
                if i == n {
                    break;
                }
                a[i] += 1;
                if a[i] <= budget.min(caps[i]) {
                    break;
                }
                a[i] = 1;
                i += 1;
            }
            if i == n {
                break;
            }
        }
        match (got, best) {
            (Some(alloc), Some(b)) => assert!(
                (alloc.throughput - b).abs() < 1e-9,
                "trial {trial}: solver {} vs brute {b} ({vars:?}, budget {budget})",
                alloc.throughput
            ),
            (None, None) => {}
            (g, b) => panic!("trial {trial}: feasibility mismatch {g:?} vs {b:?}"),
        }
    }
}

#[test]
fn prop_autodiff_graphs_always_valid() {
    // Random MLP-ish forward graphs: the training graph must always
    // validate, grow, and contain one optimizer step per parameter.
    let mut rng = Rng::new(77);
    for trial in 0..60 {
        let mut b = GraphBuilder::new(format!("g{trial}"), GraphKind::Inference);
        let batch = 1 << rng.range(4, 9);
        let mut width = 1 << rng.range(4, 8);
        let x = b.input(&[batch as usize, width as usize], "x");
        let mut cur = x;
        for li in 0..rng.range(1, 5) {
            width = 1 << rng.range(4, 8);
            cur = b.linear(cur, width as usize, rng.next() % 2 == 0, &format!("l{li}"));
            match rng.next() % 4 {
                0 => cur = b.relu(cur, &format!("a{li}")),
                1 => cur = b.ew1(EwKind::Gelu, cur, &format!("a{li}")),
                2 => cur = b.layernorm(cur, &format!("n{li}")),
                _ => {}
            }
        }
        b.loss(cur, "loss");
        let fwd = b.finish();
        let tg = training_graph(&fwd, AutodiffOptions::default());
        assert!(tg.validate().is_empty(), "trial {trial}: {:?}", tg.validate());
        assert!(tg.n_compute_ops() > fwd.n_compute_ops());
        let n_params = fwd.nodes().iter().filter(|n| matches!(n.op, OpKind::Param)).count();
        let n_steps = tg
            .nodes()
            .iter()
            .filter(|n| matches!(n.op, OpKind::OptimizerUpdate))
            .count();
        assert_eq!(n_params, n_steps, "trial {trial}");
    }
}

#[test]
fn prop_compiled_apps_conserve_ops() {
    // For random graphs: every compute op lands in exactly one plan item.
    let mut rng = Rng::new(99);
    for trial in 0..25 {
        let mut b = GraphBuilder::new(format!("c{trial}"), GraphKind::Inference);
        let x = b.input(&[1024, 128], "x");
        let mut cur = x;
        for li in 0..rng.range(2, 8) {
            cur = b.linear(cur, (1 << rng.range(5, 9)) as usize, false, &format!("l{li}"));
            if rng.next() % 2 == 0 {
                cur = b.relu(cur, &format!("a{li}"));
            }
        }
        let g = b.finish();
        let cfg = GpuConfig::a100();
        let app = compile(&g, &cfg, &SelectOptions::default()).unwrap();
        let bsp_items = app
            .plan
            .iter()
            .filter(|p| matches!(p, kitsune::compiler::PlanItem::Bsp(_)))
            .count();
        assert_eq!(
            bsp_items + app.n_fused_ops(),
            g.n_compute_ops(),
            "trial {trial}"
        );
    }
}

#[test]
fn prop_dual_arbiter_pairs_at_least_as_well_as_round_robin() {
    use kitsune::graph::ResourceClass;
    let mut rng = Rng::new(0xABCD);
    for trial in 0..100 {
        let n_sms = rng.range(2, 16) as usize;
        let mut cfg = GpuConfig::a100();
        cfg.sm_count = n_sms;
        let seq: Vec<ResourceClass> = (0..rng.range(2, 24))
            .map(|_| {
                if rng.next() % 2 == 0 {
                    ResourceClass::Tensor
                } else {
                    ResourceClass::Simt
                }
            })
            .collect();
        let run = |policy: SchedPolicy| {
            let mut sched = GridScheduler::new(policy);
            let mut sms = vec![SmState::default(); n_sms];
            for &c in &seq {
                let _ = sched.place(c, 0, &mut sms, &cfg);
            }
            sms.iter().filter(|s| s.is_paired()).count()
        };
        let rr = run(SchedPolicy::RoundRobin);
        let da = run(SchedPolicy::DualArbiter);
        assert!(da >= rr, "trial {trial}: DA {da} < RR {rr} (seq {seq:?})");
    }
}

#[test]
fn prop_queue_mpmc_token_conservation() {
    let mut rng = Rng::new(31337);
    for trial in 0..20 {
        let cap = 1 << rng.range(1, 5);
        let producers = rng.range(1, 4) as usize;
        let consumers = rng.range(1, 4) as usize;
        let per = rng.range(100, 2000);
        let q: Arc<RingQueue<u64>> = RingQueue::with_capacity(cap);
        std::thread::scope(|s| {
            let mut cons = Vec::new();
            for _ in 0..consumers {
                let q = Arc::clone(&q);
                cons.push(s.spawn(move || {
                    let mut sum = 0u64;
                    while let Some(v) = q.pop() {
                        sum += v;
                    }
                    sum
                }));
            }
            let mut prods = Vec::new();
            for p in 0..producers {
                let q = Arc::clone(&q);
                prods.push(s.spawn(move || {
                    for i in 0..per {
                        q.push(p as u64 * per + i).unwrap();
                    }
                }));
            }
            for p in prods {
                p.join().unwrap();
            }
            q.close();
            let got: u64 = cons.into_iter().map(|c| c.join().unwrap()).sum();
            let want: u64 = (0..producers as u64)
                .map(|p| (0..per).map(|i| p * per + i).sum::<u64>())
                .sum();
            assert_eq!(got, want, "trial {trial}");
        });
    }
}

#[test]
fn prop_simulator_conserves_work() {
    // FLOPs and DRAM bytes retired by the engine equal the kernel totals,
    // for random kernels.
    use kitsune::graph::ResourceClass;
    use kitsune::sim::KernelDesc;
    let mut rng = Rng::new(4242);
    let e = Engine::new(GpuConfig::a100(), SchedPolicy::DualArbiter);
    for trial in 0..40 {
        let n_ctas = rng.range(1, 512) as usize;
        let k = KernelDesc {
            name: format!("k{trial}"),
            class: if rng.next() % 2 == 0 { ResourceClass::Tensor } else { ResourceClass::Simt },
            n_ctas,
            flops_per_cta: 1e6 * (1.0 + rng.f() * 100.0),
            dram_bytes_per_cta: 1e4 * (1.0 + rng.f() * 100.0),
            l2_bytes_per_cta: 1e4 * (1.0 + rng.f() * 100.0),
            smem_per_cta: (rng.range(0, 96) * 1024) as usize,
            pipe_utilization: 0.05 + rng.f() * 0.95,
        };
        let r = e.run_kernel(&k).unwrap();
        let rel = |a: f64, b: f64| (a - b).abs() / b.max(1.0);
        assert!(rel(r.flops, k.total_flops()) < 1e-6, "trial {trial} flops");
        assert!(rel(r.dram_bytes, k.total_dram_bytes()) < 1e-6, "trial {trial} dram");
        assert!(r.elapsed_s > 0.0);
    }
}
