//! Regression: an idle warm pipeline must burn ~no CPU.
//!
//! Before the queue got real parking, blocking `push`/`pop` fell into a
//! sleep-tiered spin loop, so a warm-but-idle session kept all stage
//! threads spinning. Now waiters register wakers / park on a condvar
//! after a short bounded spin, and the process-wide telemetry snapshot
//! (`kitsune::telemetry::snapshot().queue.idle_spins`) counts every
//! spin iteration — so "idle burns CPU" regressions show up as a
//! counter delta.
//!
//! This lives in its own integration-test binary so no sibling test's
//! queue traffic pollutes the process-wide counter window.

use kitsune::session::{nerf_trunk_graph, Session};
use std::time::Duration;

fn idle_spin_count() -> u64 {
    kitsune::telemetry::snapshot().queue.idle_spins
}

#[test]
fn idle_warm_pipeline_burns_no_spins() {
    let session = Session::builder()
        .graph(nerf_trunk_graph(64, 6, 16, 3))
        .tile_rows(4)
        .workers(2)
        .build()
        .unwrap();
    // Prime the pipeline so every pump has run at least once.
    let tiles = session.make_tiles(8, 7).unwrap();
    let out = session.submit(tiles).unwrap().wait().unwrap();
    assert_eq!(out.outputs.len(), 8);

    // Let in-flight pumps settle, then measure a quiet window.
    std::thread::sleep(Duration::from_millis(50));
    let before = idle_spin_count();
    std::thread::sleep(Duration::from_millis(400));
    let spins = idle_spin_count() - before;
    assert!(
        spins < 10_000,
        "idle warm pipeline spun {spins} times in 400ms — queue parking regressed"
    );
    session.shutdown();
}
