//! Stress tests for the unified work-stealing scheduler
//! (`kitsune::sched`) — the single pool under GEMM panels, session
//! stage pumps, and DAG training pumps:
//!
//! * nested fork-join produces the exact sequential result;
//! * a small pool drains a large oversubscribed task wave (stealing);
//! * a panicking task propagates to the scope caller;
//! * `join` results are deterministic across repeats;
//! * multi-pump DAG training stays bitwise-identical to the serial
//!   oracle (the sequence reorder buffer emits in order even when tiles
//!   complete out of order).

use kitsune::sched::{self, LiveCount, Scheduler};
use kitsune::session::Session;
use kitsune::train::{serial_step, split_batch};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Parallel recursive sum over a slice via nested `join` calls.
fn psum(xs: &[u64]) -> u64 {
    if xs.len() <= 16 {
        return xs.iter().sum();
    }
    let (lo, hi) = xs.split_at(xs.len() / 2);
    let (a, b) = sched::join(|| psum(lo), || psum(hi));
    a + b
}

#[test]
fn nested_fork_join_matches_sequential_sum() {
    let xs: Vec<u64> = (0..4096).map(|i| i * i + 1).collect();
    let want: u64 = xs.iter().sum();
    let sched = Scheduler::with_workers(4);
    let got = sched::with_scheduler(&sched, || psum(&xs));
    assert_eq!(got, want);
    sched.shutdown();
}

#[test]
fn oversubscribed_spawn_wave_drains_by_stealing() {
    // Far more tasks than workers; every task must run exactly once.
    let sched = Scheduler::with_workers(4);
    let hits = AtomicUsize::new(0);
    sched::scope_on(&sched, |s| {
        for _ in 0..200 {
            s.spawn(|| {
                // Spin a little so tasks overlap and queues go non-empty.
                for _ in 0..50 {
                    std::hint::spin_loop();
                }
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
    });
    assert_eq!(hits.load(Ordering::Relaxed), 200);
    assert_eq!(sched.panics(), 0);
    sched.shutdown();
}

#[test]
#[should_panic(expected = "boom")]
fn panic_in_scoped_task_propagates_to_caller() {
    let sched = Scheduler::with_workers(2);
    sched::scope_on(&sched, |s| {
        s.spawn(|| panic!("boom"));
        s.spawn(|| { /* healthy sibling still runs */ });
    });
}

#[test]
fn join_results_are_deterministic() {
    let sched = Scheduler::with_workers(3);
    sched::with_scheduler(&sched, || {
        for round in 0..64u64 {
            let (a, b) = sched::join(move || round * 3 + 1, move || round * 7 + 2);
            assert_eq!(a, round * 3 + 1);
            assert_eq!(b, round * 7 + 2);
        }
    });
    sched.shutdown();
}

#[test]
fn detached_spawns_complete_via_live_count() {
    let sched = Scheduler::with_workers(2);
    let live = LiveCount::new(64);
    let hits = Arc::new(AtomicUsize::new(0));
    for _ in 0..64 {
        let live = Arc::clone(&live);
        let hits = Arc::clone(&hits);
        sched.spawn(Box::new(move || {
            hits.fetch_add(1, Ordering::Relaxed);
            live.done();
        }));
    }
    live.wait_zero();
    assert_eq!(hits.load(Ordering::Relaxed), 64);
    sched.shutdown();
}

/// The tentpole ordering guarantee: with several pumps per training
/// stage, tiles may *compute* out of order, but the per-stage sequence
/// reorder buffer emits in arrival order — so whole training steps stay
/// bitwise-identical to the single-threaded serial oracle.
#[test]
fn multi_pump_training_matches_serial_oracle_bitwise() {
    let g = kitsune::apps::nerf::training(&kitsune::apps::nerf::NerfConfig {
        batch: 64,
        pos_enc: 8,
        dir_enc: 4,
        hidden: 16,
        depth: 3,
        skip_at: 1,
    });
    let session = Session::builder().graph(g).tile_rows(8).train_workers(3).build().unwrap();
    let plan = session.train_plan().unwrap();
    // 3 pumps per stage + the sink pump.
    assert_eq!(session.threads_spawned(), plan.pipeline.stages.len() * 3 + 1);

    let batch = session.make_train_batch(42).unwrap();
    let tiles = split_batch(plan, &batch).unwrap();
    let mut trainer = session.trainer().unwrap();

    for step in 0..2 {
        let params: Vec<_> = trainer.params().into_iter().map(|(_, t)| t).collect();
        let serial = serial_step(plan, &params, &tiles).unwrap();
        let stats = trainer.step(&batch).unwrap();
        assert_eq!(
            stats.loss.to_bits(),
            serial.loss.to_bits(),
            "step {step}: multi-pump loss must match the serial oracle bitwise"
        );
        for (name, grad) in &stats.grads {
            let pi = plan.params.iter().position(|p| &p.name == name).unwrap();
            let want = serial.grads[pi].as_ref().expect("oracle gradient present");
            let gb: Vec<u32> = grad.data.iter().map(|v| v.to_bits()).collect();
            let wb: Vec<u32> = want.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb, "step {step}: gradient `{name}` must match bitwise");
        }
    }
    session.shutdown();
}
