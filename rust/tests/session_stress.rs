//! Stress and contract tests for the `kitsune::session` façade: the
//! compiled-plan → spatial-pipeline lowering, the persistent (warm)
//! worker pool, and concurrent batch submission through one session —
//! N threads interleaving tickets, per-ticket output order, and the
//! no-respawn-on-submit guarantee.

use kitsune::runtime::Tensor;
use kitsune::session::{nerf_trunk_graph, Session, SessionError};

/// Small warm session: 4-stage trunk pipeline over 4x6 tiles.
fn small_session() -> Session {
    Session::builder()
        .graph(nerf_trunk_graph(64, 6, 16, 3))
        .tile_rows(4)
        .workers(2)
        .build()
        .unwrap()
}

#[test]
fn compiled_app_lowers_to_runnable_pipeline() {
    let session = small_session();
    // No hand-written stage list anywhere: the pipeline's stages and
    // entry names come from the compiled plan.
    let pipeline = session.pipeline().expect("trunk graph streams");
    assert_eq!(pipeline.stages.len(), 4, "{:?}", pipeline.stages);
    for s in &pipeline.stages {
        assert!(s.entry.starts_with("sf"), "synthesized entry name: {}", s.entry);
    }
    // And it runs: streamed output matches the serial baseline bitwise.
    let tiles = session.make_tiles(12, 9).unwrap();
    let serial = session.run_serial(tiles.clone()).unwrap();
    let streamed = session.run(tiles).unwrap();
    assert_eq!(streamed.outputs.len(), 12);
    for (a, b) in streamed.outputs.iter().zip(&serial.outputs) {
        assert_eq!(a.dims, b.dims);
        assert_eq!(a.data, b.data, "tile outputs must be bit-identical");
    }
    for m in &session.metrics() {
        assert_eq!(m.tiles, 12, "stage {}", m.name);
    }
}

#[test]
fn warm_submit_never_spawns_stage_threads() {
    let session = small_session();
    // All threads exist after build: 4 stages x 2 workers + 1 sink.
    let expected = session.pipeline().unwrap().stages.iter().map(|s| s.workers).sum::<usize>() + 1;
    let spawned_at_build = session.threads_spawned();
    assert_eq!(spawned_at_build, expected);
    for round in 0..8 {
        let out = session.run(session.make_tiles(5, round).unwrap()).unwrap();
        assert_eq!(out.outputs.len(), 5);
        assert_eq!(
            session.threads_spawned(),
            spawned_at_build,
            "submit round {round} spawned threads"
        );
    }
}

#[test]
fn concurrent_submissions_preserve_per_ticket_order() {
    // N client threads interleave batches through one warm session; each
    // ticket must return its own outputs, in its own submission order.
    let session = small_session();
    const CLIENTS: usize = 6;
    const BATCHES: usize = 4;
    const TILES: usize = 5;

    // Distinct deterministic inputs per (client, batch); expected outputs
    // computed serially up front against the same lowered stages.
    let batch_for = |c: usize, b: usize| -> Vec<Tensor> {
        session.make_tiles(TILES, 1 + (c * BATCHES + b) as u64).unwrap()
    };
    let mut expected = vec![vec![Vec::new(); BATCHES]; CLIENTS];
    for (c, per_client) in expected.iter_mut().enumerate() {
        for (b, slot) in per_client.iter_mut().enumerate() {
            *slot = session.run_serial(batch_for(c, b)).unwrap().outputs;
        }
    }

    let spawned = session.threads_spawned();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..CLIENTS {
            let session = &session;
            let batch_for = &batch_for;
            handles.push(scope.spawn(move || {
                // Submit all batches first (maximizing interleaving with
                // other clients), then wait on the tickets in order.
                let tickets: Vec<_> = (0..BATCHES)
                    .map(|b| session.submit(batch_for(c, b)).unwrap())
                    .collect();
                let outs: Vec<_> =
                    tickets.into_iter().map(|t| t.wait().unwrap().outputs).collect();
                (c, outs)
            }));
        }
        for h in handles {
            let (c, outs) = h.join().unwrap();
            for (b, got) in outs.iter().enumerate() {
                assert_eq!(got.len(), TILES);
                for (i, (a, e)) in got.iter().zip(&expected[c][b]).enumerate() {
                    assert_eq!(
                        a.data, e.data,
                        "client {c} batch {b} tile {i}: out-of-order or corrupted"
                    );
                }
            }
        }
    });
    // The whole stress run reused the pool stood up at build.
    assert_eq!(session.threads_spawned(), spawned);
    let total_tiles = CLIENTS * BATCHES * TILES;
    for m in &session.metrics() {
        assert_eq!(m.tiles, total_tiles, "stage {} tile accounting", m.name);
    }
}

#[test]
fn ticket_try_wait_polls_without_blocking() {
    let session = small_session();
    let ticket = session.submit(session.make_tiles(6, 21).unwrap()).unwrap();
    // Poll until done: try_wait hands the ticket back while tiles are in
    // flight instead of blocking, so a dispatcher can service other work.
    let mut ticket = ticket;
    let out = loop {
        match ticket.try_wait() {
            Ok(result) => break result.unwrap(),
            Err(t) => {
                assert!(!t.is_done());
                ticket = t;
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        }
    };
    assert_eq!(out.outputs.len(), 6);
    assert_eq!(session.in_flight(), 0, "in-flight table drains with the ticket");
}

#[test]
fn ticket_wait_timeout_returns_ticket_then_result() {
    let session = small_session();
    let ticket = session.submit(session.make_tiles(8, 22).unwrap()).unwrap();
    // A zero timeout on a just-submitted batch almost always hands the
    // ticket back; either way the ticket stays usable and a generous
    // timeout must then deliver the full batch.
    let ticket = match ticket.wait_timeout(std::time::Duration::ZERO) {
        Ok(result) => {
            assert_eq!(result.unwrap().outputs.len(), 8);
            assert_eq!(session.in_flight(), 0);
            return;
        }
        Err(t) => t,
    };
    let out = ticket.wait_timeout(std::time::Duration::from_secs(30)).unwrap_or_else(|_| {
        panic!("batch did not complete within 30s");
    });
    assert_eq!(out.unwrap().outputs.len(), 8);
    assert_eq!(session.in_flight(), 0);
}

#[test]
fn in_flight_counts_submitted_tiles_until_reaped() {
    let session = small_session();
    assert_eq!(session.in_flight(), 0);
    let t1 = session.submit(session.make_tiles(4, 31).unwrap()).unwrap();
    let t2 = session.submit(session.make_tiles(3, 32).unwrap()).unwrap();
    // Submission registers the tiles immediately (completion races the
    // assertion, so only an upper bound is stable here).
    assert!(session.in_flight() <= 7);
    t1.wait().unwrap();
    t2.wait().unwrap();
    assert_eq!(session.in_flight(), 0, "both tickets drained");
}

#[test]
fn submission_validates_tile_dims() {
    let session = small_session();
    let err = session.submit(vec![Tensor::zeros(&[3, 3])]).unwrap_err();
    assert!(err.to_string().contains("tile dims"), "{err}");
    // Empty batches are legal and complete immediately.
    let out = session.run(Vec::new()).unwrap();
    assert!(out.outputs.is_empty());
}

#[test]
fn shutdown_then_submit_fails_cleanly_and_is_idempotent() {
    let session = small_session();
    let out = session.run(session.make_tiles(4, 2).unwrap()).unwrap();
    assert_eq!(out.outputs.len(), 4);
    session.shutdown();
    session.shutdown(); // idempotent
    let err = session.submit(session.make_tiles(1, 3).unwrap()).unwrap_err();
    assert!(err.to_string().contains("shut down"), "{err}");
}

#[test]
fn shutdown_under_load_resolves_every_ticket() {
    // Clients hammer the session while the main thread shuts it down:
    // shutdown must drain every pump task before returning, and every
    // ticket must resolve — completed batches with full output, cut-off
    // batches with the typed shutdown error. Nothing may hang or panic.
    let session = small_session();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..4usize {
            let session = &session;
            handles.push(scope.spawn(move || {
                let mut ok = 0usize;
                let mut cut = 0usize;
                for b in 0..16usize {
                    // Tile synthesis is independent of the pool's state.
                    let tiles = session.make_tiles(3, (c * 16 + b) as u64 + 1).unwrap();
                    match session.submit(tiles) {
                        Ok(ticket) => match ticket.wait() {
                            Ok(out) => {
                                assert_eq!(out.outputs.len(), 3);
                                ok += 1;
                            }
                            Err(e) => {
                                assert!(e.to_string().contains("shut down"), "{e}");
                                cut += 1;
                            }
                        },
                        Err(e) => {
                            assert!(e.to_string().contains("shut down"), "{e}");
                            cut += 1;
                        }
                    }
                }
                (ok, cut)
            }));
        }
        // Let the clients get some batches in flight, then pull the plug.
        std::thread::sleep(std::time::Duration::from_millis(20));
        session.shutdown();
        for h in handles {
            let (ok, cut) = h.join().unwrap();
            assert_eq!(ok + cut, 16, "every ticket resolved exactly once");
        }
    });
    // Idempotent after the storm.
    session.shutdown();
}

#[test]
fn non_streamable_app_reports_typed_error_but_simulates() {
    // DLRM's embedding gathers are excluded from sf-nodes (§5.1), so its
    // plan has bulk-sync items: the session simulates but cannot stream.
    let session = Session::builder().app("DLRM").build().unwrap();
    assert!(!session.is_streamable());
    let err = session.submit(Vec::new()).unwrap_err();
    match err.downcast_ref::<SessionError>() {
        Some(SessionError::NotStreamable { reason }) => {
            assert!(!reason.is_empty());
        }
        other => panic!("expected NotStreamable, got {other:?}"),
    }
    let eval = session.simulate().unwrap();
    assert!(eval.kitsune_speedup() > 0.5, "simulation sane: {}", eval.kitsune_speedup());
}
