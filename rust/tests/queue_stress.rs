//! Multi-thread stress tests for the §4.1 ring queue at `capacity = 2` —
//! the paper's double-buffered configuration. Wraparound happens every
//! other handoff at this capacity, so these runs hammer the sequence-
//! number protocol exactly where an off-by-one would corrupt it, using
//! the *non-blocking* try_push/try_pop interface plus close-while-full
//! shutdown races.

use kitsune::queue::{PopError, PushError, RingQueue};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Deterministic xorshift, used to vary interleavings across trials.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }
}

#[test]
fn capacity2_try_interface_mpmc_conserves_tokens() {
    // 2 producers x 2 consumers over a 2-entry ring, try_* only: every
    // pushed token is popped exactly once, and sums match.
    for trial in 0..8u64 {
        let q: Arc<RingQueue<u64>> = RingQueue::with_capacity(2);
        assert_eq!(q.capacity(), 2);
        let n_per = 20_000u64;
        let pushed = Arc::new(AtomicU64::new(0));
        let popped = Arc::new(AtomicU64::new(0));
        let pop_count = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for p in 0..2u64 {
                let q = Arc::clone(&q);
                let pushed = Arc::clone(&pushed);
                s.spawn(move || {
                    let mut rng = Rng(trial * 4 + p + 1);
                    for i in 0..n_per {
                        let mut v = p * n_per + i;
                        loop {
                            match q.try_push(v) {
                                Ok(()) => break,
                                Err(PushError::Full(back)) => {
                                    v = back;
                                    if rng.next() % 4 == 0 {
                                        std::thread::yield_now();
                                    } else {
                                        std::hint::spin_loop();
                                    }
                                }
                                Err(PushError::Closed(_)) => {
                                    panic!("queue closed while producing")
                                }
                            }
                        }
                        pushed.fetch_add(v, Ordering::Relaxed);
                    }
                });
            }
            for c in 0..2u64 {
                let q = Arc::clone(&q);
                let popped = Arc::clone(&popped);
                let pop_count = Arc::clone(&pop_count);
                s.spawn(move || {
                    let mut rng = Rng(trial * 4 + c + 101);
                    loop {
                        match q.try_pop() {
                            Ok(v) => {
                                popped.fetch_add(v, Ordering::Relaxed);
                                pop_count.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(PopError::Empty) => {
                                if rng.next() % 4 == 0 {
                                    std::thread::yield_now();
                                } else {
                                    std::hint::spin_loop();
                                }
                            }
                            Err(PopError::Closed) => break,
                        }
                    }
                });
            }
            // Close only after both producers finish: scope threads for
            // producers are joined by... (we can't selectively join inside
            // scope) — so spawn a closer thread that waits on the count.
            let q2 = Arc::clone(&q);
            let pop_count2 = Arc::clone(&pop_count);
            s.spawn(move || {
                // Busy-wait until all tokens are through, then close so
                // consumers observe Closed after a full drain.
                while pop_count2.load(Ordering::Relaxed) < 2 * n_per as usize {
                    std::thread::yield_now();
                }
                q2.close();
            });
        });
        let total = 2 * n_per;
        assert_eq!(pop_count.load(Ordering::Relaxed) as u64, total, "trial {trial}");
        assert_eq!(
            pushed.load(Ordering::Relaxed),
            popped.load(Ordering::Relaxed),
            "trial {trial}: token sum mismatch"
        );
        assert_eq!(pushed.load(Ordering::Relaxed), total * (total - 1) / 2, "trial {trial}");
    }
}

#[test]
fn capacity2_wraparound_preserves_fifo_under_try_interleaving() {
    // SPSC at capacity 2: the consumer must observe strict FIFO order
    // across thousands of ring wraparounds driven by try_* retries.
    let q: Arc<RingQueue<usize>> = RingQueue::with_capacity(2);
    let n = 100_000usize;
    let producer = {
        let q = Arc::clone(&q);
        std::thread::spawn(move || {
            for i in 0..n {
                let mut v = i;
                loop {
                    match q.try_push(v) {
                        Ok(()) => break,
                        Err(PushError::Full(back)) => {
                            v = back;
                            std::hint::spin_loop();
                        }
                        Err(PushError::Closed(_)) => unreachable!("never closed here"),
                    }
                }
            }
            q.close();
        })
    };
    let mut expect = 0usize;
    loop {
        match q.try_pop() {
            Ok(v) => {
                assert_eq!(v, expect, "FIFO violated after wraparound");
                expect += 1;
            }
            Err(PopError::Empty) => std::hint::spin_loop(),
            Err(PopError::Closed) => break,
        }
    }
    assert_eq!(expect, n);
    producer.join().unwrap();
}

#[test]
fn close_while_full_races_hand_values_back() {
    // Producers blast a 2-entry queue while another thread closes it
    // mid-stream. Conservation: every token is either popped exactly once
    // or handed back through PushError::Closed — none vanish, none dup.
    for trial in 0..20u64 {
        let q: Arc<RingQueue<u64>> = RingQueue::with_capacity(2);
        let delivered_sum = Arc::new(AtomicU64::new(0));
        let delivered_n = Arc::new(AtomicUsize::new(0));
        let returned_sum = Arc::new(AtomicU64::new(0));
        let returned_n = Arc::new(AtomicUsize::new(0));
        let n_per = 4_000u64;
        let producers_left = Arc::new(AtomicUsize::new(2));
        std::thread::scope(|s| {
            for p in 0..2u64 {
                let q = Arc::clone(&q);
                let returned_sum = Arc::clone(&returned_sum);
                let returned_n = Arc::clone(&returned_n);
                let producers_left = Arc::clone(&producers_left);
                s.spawn(move || {
                    for i in 0..n_per {
                        let v = p * n_per + i;
                        // Blocking push: either delivered, or returned on
                        // close — the shutdown signal producers rely on.
                        if let Err(PushError::Closed(back)) = q.push(v) {
                            returned_sum.fetch_add(back, Ordering::Relaxed);
                            returned_n.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    producers_left.fetch_sub(1, Ordering::AcqRel);
                });
            }
            {
                let q = Arc::clone(&q);
                let delivered_sum = Arc::clone(&delivered_sum);
                let delivered_n = Arc::clone(&delivered_n);
                let producers_left = Arc::clone(&producers_left);
                s.spawn(move || {
                    // Drain until the queue is empty *and* no producer can
                    // land another straggler (a push that passed the
                    // closed-check just before close() completes later).
                    loop {
                        match q.try_pop() {
                            Ok(v) => {
                                delivered_sum.fetch_add(v, Ordering::Relaxed);
                                delivered_n.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(PopError::Empty) | Err(PopError::Closed) => {
                                if producers_left.load(Ordering::Acquire) == 0 && q.is_empty() {
                                    break;
                                }
                                std::thread::yield_now();
                            }
                        }
                    }
                });
            }
            {
                // Close at a pseudo-random point mid-stream — often while
                // the ring is full and producers are blocked on it.
                let q = Arc::clone(&q);
                s.spawn(move || {
                    let mut rng = Rng(0xC10C + trial);
                    let spins = 500 + rng.next() % 40_000;
                    for _ in 0..spins {
                        std::hint::spin_loop();
                    }
                    q.close();
                });
            }
        });
        let total_n = 2 * n_per as usize;
        let total_sum = {
            let t = 2 * n_per;
            t * (t - 1) / 2
        };
        assert_eq!(
            delivered_n.load(Ordering::Relaxed) + returned_n.load(Ordering::Relaxed),
            total_n,
            "trial {trial}: tokens lost or duplicated"
        );
        assert_eq!(
            delivered_sum.load(Ordering::Relaxed) + returned_sum.load(Ordering::Relaxed),
            total_sum,
            "trial {trial}: checksum mismatch"
        );
        // After close, pushes always report Closed and give the value back.
        assert!(matches!(q.try_push(7), Err(PushError::Closed(7))));
    }
}

#[test]
fn close_racing_park_on_space_always_fires_waker() {
    // A producer-side waker registered on a *full* queue races close():
    // whichever side wins, the one-shot waker must fire exactly once —
    // a lost wakeup here is a permanently stalled stage pump.
    for trial in 0..200u64 {
        let q: Arc<RingQueue<u64>> = RingQueue::with_capacity(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap(); // full: the waker cannot fire on space
        let fired = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            {
                let q = Arc::clone(&q);
                let fired = Arc::clone(&fired);
                s.spawn(move || {
                    q.park_on_space(Box::new(move || {
                        fired.fetch_add(1, Ordering::SeqCst);
                    }));
                });
            }
            {
                let q = Arc::clone(&q);
                s.spawn(move || {
                    let mut rng = Rng(0xFACE + trial);
                    let spins = rng.next() % 2_000;
                    for _ in 0..spins {
                        std::hint::spin_loop();
                    }
                    q.close();
                });
            }
        });
        // Both threads joined: close() fires registered wakers
        // synchronously, so a zero here is a lost wakeup.
        assert_eq!(
            fired.load(Ordering::SeqCst),
            1,
            "trial {trial}: close-vs-park_on_space race lost or duplicated the waker"
        );
    }
}

#[test]
fn close_racing_park_on_item_always_fires_waker() {
    // Consumer mirror: a waker registered on an *empty* queue races
    // close(); end-of-stream must always resume the parked consumer.
    for trial in 0..200u64 {
        let q: Arc<RingQueue<u64>> = RingQueue::with_capacity(2);
        let fired = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            {
                let q = Arc::clone(&q);
                let fired = Arc::clone(&fired);
                s.spawn(move || {
                    q.park_on_item(Box::new(move || {
                        fired.fetch_add(1, Ordering::SeqCst);
                    }));
                });
            }
            {
                let q = Arc::clone(&q);
                s.spawn(move || {
                    let mut rng = Rng(0x17E4 + trial);
                    let spins = rng.next() % 2_000;
                    for _ in 0..spins {
                        std::hint::spin_loop();
                    }
                    q.close();
                });
            }
        });
        assert_eq!(
            fired.load(Ordering::SeqCst),
            1,
            "trial {trial}: close-vs-park_on_item race lost or duplicated the waker"
        );
    }
}

#[test]
fn blocking_push_on_full_queue_unblocks_on_close() {
    // A blocking push parked on a full queue with no consumer must be
    // woken by close() and hand its value back — the shutdown path a
    // feeder thread relies on to not hang when the pipeline dies.
    for trial in 0..50u64 {
        let q: Arc<RingQueue<u64>> = RingQueue::with_capacity(2);
        q.try_push(10).unwrap();
        q.try_push(11).unwrap();
        std::thread::scope(|s| {
            let pusher = {
                let q = Arc::clone(&q);
                s.spawn(move || q.push(42))
            };
            {
                let q = Arc::clone(&q);
                s.spawn(move || {
                    let mut rng = Rng(0xB10C + trial);
                    let spins = rng.next() % 10_000;
                    for _ in 0..spins {
                        std::hint::spin_loop();
                    }
                    q.close();
                });
            }
            let res = pusher.join().unwrap();
            assert!(
                matches!(res, Err(PushError::Closed(42))),
                "trial {trial}: blocking push neither delivered nor returned: {res:?}"
            );
        });
        // Buffered items still drain after close (advisory close).
        assert_eq!(q.try_pop().unwrap(), 10);
        assert_eq!(q.try_pop().unwrap(), 11);
        assert!(matches!(q.try_pop(), Err(PopError::Closed)));
    }
}

#[test]
fn pop_many_spsc_preserves_fifo_across_bursts() {
    // Batched dequeue at capacity 2: bursts of size <= max, strict FIFO
    // across thousands of wraparounds, clean end-of-stream.
    let q: Arc<RingQueue<usize>> = RingQueue::with_capacity(2);
    let n = 50_000usize;
    let producer = {
        let q = Arc::clone(&q);
        std::thread::spawn(move || {
            for i in 0..n {
                q.push(i).unwrap();
            }
            q.close();
        })
    };
    let mut expect = 0usize;
    let mut burst = Vec::new();
    let mut max_burst = 0usize;
    loop {
        burst.clear();
        let got = q.pop_many(&mut burst, 4);
        if got == 0 {
            break;
        }
        assert_eq!(got, burst.len());
        assert!(got <= 4, "burst exceeded max");
        max_burst = max_burst.max(got);
        for v in burst.drain(..) {
            assert_eq!(v, expect, "FIFO violated inside a burst");
            expect += 1;
        }
    }
    assert_eq!(expect, n, "stream truncated");
    // End of stream is sticky.
    let mut tail = Vec::new();
    assert_eq!(q.pop_many(&mut tail, 8), 0);
    assert!(tail.is_empty());
    assert!(max_burst >= 1);
    producer.join().unwrap();
}

#[test]
fn pop_many_mpmc_conserves_tokens() {
    // 2 producers x 2 burst-draining consumers: every token popped
    // exactly once, sums conserved — the warm-worker drain pattern.
    for trial in 0..8u64 {
        let q: Arc<RingQueue<u64>> = RingQueue::with_capacity(4);
        let n_per = 20_000u64;
        let popped_sum = Arc::new(AtomicU64::new(0));
        let popped_n = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for p in 0..2u64 {
                let q = Arc::clone(&q);
                s.spawn(move || {
                    for i in 0..n_per {
                        q.push(p * n_per + i).unwrap();
                    }
                });
            }
            for c in 0..2u64 {
                let q = Arc::clone(&q);
                let popped_sum = Arc::clone(&popped_sum);
                let popped_n = Arc::clone(&popped_n);
                s.spawn(move || {
                    let mut rng = Rng(trial * 2 + c + 1);
                    let mut burst = Vec::new();
                    loop {
                        burst.clear();
                        // Vary burst sizes to shake out edge interleavings.
                        let max = 1 + (rng.next() % 7) as usize;
                        if q.pop_many(&mut burst, max) == 0 {
                            break;
                        }
                        for v in burst.drain(..) {
                            popped_sum.fetch_add(v, Ordering::Relaxed);
                            popped_n.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
            {
                // Close once everything is through so consumers observe a
                // full drain before end-of-stream.
                let q = Arc::clone(&q);
                let popped_n = Arc::clone(&popped_n);
                s.spawn(move || {
                    while popped_n.load(Ordering::Relaxed) < 2 * n_per as usize {
                        std::thread::yield_now();
                    }
                    q.close();
                });
            }
        });
        let total = 2 * n_per;
        assert_eq!(popped_n.load(Ordering::Relaxed) as u64, total, "trial {trial}");
        assert_eq!(
            popped_sum.load(Ordering::Relaxed),
            total * (total - 1) / 2,
            "trial {trial}: checksum mismatch"
        );
    }
}
