//! Cross-layer telemetry integration tests: counter reconciliation
//! (tiles in == tiles out per stage, bytes accounted == bytes moved),
//! Prometheus exposition content, and the Chrome-trace roundtrip
//! (schema validity, per-track monotone non-overlapping spans, stage
//! and worker name mapping).
//!
//! The trace sink is process-global and latches on first span, so every
//! test arms it first thing via `armed_trace_path()` — whichever test
//! thread wins the race sets one shared temp path, and spans from all
//! tests land in the same buffer (the roundtrip assertions are
//! "at least" style for exactly this reason). Tests also serialize on a
//! gate mutex: `Session::shutdown` flushes the armed trace file, so a
//! concurrent test could rewrite it mid-read otherwise.

use kitsune::apps::nerf;
use kitsune::session::{nerf_trunk_graph, Session};
use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard, OnceLock};

fn gate() -> MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn armed_trace_path() -> &'static PathBuf {
    static TRACE_PATH: OnceLock<PathBuf> = OnceLock::new();
    TRACE_PATH.get_or_init(|| {
        let name = format!("kitsune_trace_test_{}.json", std::process::id());
        let p = std::env::temp_dir().join(name);
        kitsune::telemetry::trace::enable(&p)
            .expect("trace sink latched off — is KITSUNE_TRACE set but empty?")
    })
}

/// A NeRF training graph small enough for interpreter-speed steps.
fn tiny_nerf_training() -> kitsune::graph::Graph {
    nerf::training(&nerf::NerfConfig {
        batch: 64,
        pos_enc: 8,
        dir_enc: 4,
        hidden: 16,
        depth: 3,
        skip_at: 1,
    })
}

#[test]
fn counters_reconcile_with_tile_flow() {
    let _gate = gate();
    armed_trace_path();
    let session = Session::builder()
        .graph(nerf_trunk_graph(64, 6, 16, 3))
        .tile_rows(4)
        .workers(2)
        .build()
        .unwrap();
    let n = 16usize;
    let tiles = session.make_tiles(n, 11).unwrap();
    let bytes_per_tile = (tiles[0].data.len() * 4) as u64;
    let out = session.submit(tiles).unwrap().wait().unwrap();
    assert_eq!(out.outputs.len(), n);

    let t = session.telemetry().expect("warm session registers telemetry");
    assert!(!t.stages.is_empty());

    // Tile conservation: every stage accepted and emitted exactly the
    // batch, and timed exactly that many kernel executions.
    for s in &t.stages {
        assert_eq!(s.tiles_in.get(), n as u64, "stage {} tiles_in", s.name);
        assert_eq!(s.tiles_out.get(), n as u64, "stage {} tiles_out", s.name);
        assert_eq!(s.compute.count(), n as u64, "stage {} compute samples", s.name);
    }

    // Every edge drained: one envelope per tile, pushed and popped.
    for e in &t.edges {
        assert_eq!(e.pushes.get(), n as u64, "edge {} pushes", e.label);
        assert_eq!(e.pops.get(), n as u64, "edge {} pops", e.label);
        assert!(e.bytes.get() > 0, "edge {} moved no bytes", e.label);
    }

    // Bytes accounted == bytes moved: the traffic classes are exactly
    // the per-kind edge byte sums (weights are accounted separately).
    let traffic = t.traffic.snapshot();
    let sum_kind = |k: kitsune::telemetry::EdgeKind| -> u64 {
        t.edges.iter().filter(|e| e.kind == k).map(|e| e.bytes.get()).sum()
    };
    assert_eq!(traffic.source_bytes, sum_kind(kitsune::telemetry::EdgeKind::Source));
    assert_eq!(traffic.onchip_bytes, sum_kind(kitsune::telemetry::EdgeKind::Interior));
    assert_eq!(traffic.sink_bytes, sum_kind(kitsune::telemetry::EdgeKind::Sink));

    // Source bytes are exactly the injected payloads, and weight bytes
    // are one full parameter re-read per tile.
    assert_eq!(traffic.source_bytes, bytes_per_tile * n as u64);
    let weights_per_tile: u64 = t.stages.iter().map(|s| s.weight_bytes_per_tile).sum();
    assert_eq!(traffic.weight_bytes, weights_per_tile * n as u64);

    // Dataflow keeps the interior traffic on-chip, so it must beat the
    // serial oracle (which pays every intermediate twice).
    assert!(traffic.onchip_bytes > 0, "trunk pipeline has interior edges");
    assert!(traffic.reduction() > 0.0, "reduction {}", traffic.reduction());
    session.shutdown();
}

#[test]
fn train_counters_reconcile_per_step() {
    let _gate = gate();
    armed_trace_path();
    let session =
        Session::builder().graph(tiny_nerf_training()).tile_rows(16).build().unwrap();
    let batch = session.make_train_batch(7).unwrap();
    let mut trainer = session.trainer().unwrap();
    let stats = trainer.step(&batch).unwrap();
    assert!(stats.tiles > 0);

    let t = session.telemetry().expect("warm DAG registers telemetry");
    let n_tiles = stats.tiles as u64;
    // Tile-set conservation through the DAG: every stage consumed and
    // produced one tile-set per streamed tile.
    for s in &t.stages {
        assert_eq!(s.tiles_in.get(), n_tiles, "stage {} tiles_in", s.name);
        assert_eq!(s.tiles_out.get(), n_tiles, "stage {} tiles_out", s.name);
    }
    let traffic = t.traffic.snapshot();
    assert!(traffic.source_bytes > 0, "feed loop accounts injected batches");
    assert!(traffic.sink_bytes > 0, "taps drain gradients to the sink");
    assert!(traffic.onchip_bytes > 0, "DAG edges carry intermediates");
    assert!(traffic.reduction() > 0.0);
    session.shutdown();
}

#[test]
fn prometheus_exposition_covers_live_sessions() {
    let _gate = gate();
    armed_trace_path();
    let session = Session::builder()
        .graph(nerf_trunk_graph(64, 6, 16, 3))
        .tile_rows(4)
        .workers(2)
        .build()
        .unwrap();
    let tiles = session.make_tiles(4, 3).unwrap();
    session.submit(tiles).unwrap().wait().unwrap();

    let text = kitsune::telemetry::prometheus();
    for family in [
        "kitsune_queue_ops_total",
        "kitsune_queue_idle_spins_total",
        "kitsune_worker_tasks_total",
        "kitsune_stage_tiles_total",
        "kitsune_edge_bytes_total",
        "kitsune_traffic_bytes_total",
    ] {
        assert!(text.contains(family), "exposition missing {family}");
    }
    let t = session.telemetry().unwrap();
    assert!(text.contains(&format!("pipeline=\"{}\"", t.name)), "pipeline label missing");
    for s in &t.stages {
        assert!(text.contains(&format!("stage=\"{}\"", s.name)), "stage {} missing", s.name);
    }
    session.shutdown();
}

// ------------------------------------------------------------------
// Chrome-trace roundtrip
// ------------------------------------------------------------------

/// One parsed trace line (the writer emits one event per line).
struct TraceEvent {
    ph: char,
    tid: u64,
    name: String,
    cat: Option<String>,
    ts: f64,
    dur: f64,
    /// For `M` thread_name metadata: the registered thread name.
    thread_name: Option<String>,
}

fn field_str(line: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\": \"");
    let i = line.find(&pat)? + pat.len();
    let rest = &line[i..];
    Some(rest[..rest.find('"')?].to_string())
}

fn field_num(line: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\": ");
    let i = line.find(&pat)? + pat.len();
    let rest = &line[i..];
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

fn parse_trace(content: &str) -> Vec<TraceEvent> {
    content
        .lines()
        .filter_map(|raw| {
            let line = raw.trim().trim_end_matches(',');
            if !line.starts_with("{\"ph\"") {
                return None;
            }
            let ph = field_str(line, "ph")?.chars().next()?;
            Some(TraceEvent {
                ph,
                tid: field_num(line, "tid")? as u64,
                name: field_str(line, "name")?,
                cat: field_str(line, "cat"),
                ts: field_num(line, "ts").unwrap_or(0.0),
                dur: field_num(line, "dur").unwrap_or(0.0),
                thread_name: line
                    .find("\"args\": {\"name\": \"")
                    .map(|i| i + "\"args\": {\"name\": \"".len())
                    .and_then(|i| {
                        let rest = &line[i..];
                        Some(rest[..rest.find('"')?].to_string())
                    }),
            })
        })
        .collect()
}

#[test]
fn trace_roundtrip_schema_tracks_and_names() {
    let _gate = gate();
    armed_trace_path();

    // Inference spans (cat "compute", one per stage kernel execution).
    let session = Session::builder()
        .graph(nerf_trunk_graph(64, 6, 16, 3))
        .tile_rows(4)
        .workers(2)
        .build()
        .unwrap();
    let tiles = session.make_tiles(8, 5).unwrap();
    session.submit(tiles).unwrap().wait().unwrap();
    let stage_names: Vec<String> = session.metrics().iter().map(|m| m.name.clone()).collect();
    assert!(!stage_names.is_empty());
    session.shutdown();

    // Training spans (cat "train", one per stage tile-set).
    let tsession =
        Session::builder().graph(tiny_nerf_training()).tile_rows(16).build().unwrap();
    let batch = tsession.make_train_batch(3).unwrap();
    tsession.trainer().unwrap().step(&batch).unwrap();
    tsession.shutdown();

    let path = kitsune::telemetry::trace::flush().unwrap().expect("sink is armed");
    let content = std::fs::read_to_string(&path).unwrap();

    // Envelope shape.
    assert!(content.starts_with("{\"traceEvents\": ["), "bad header");
    assert!(content.contains("\"displayTimeUnit\": \"ms\""));
    assert!(content.contains("\"dropped_events\": "));
    assert!(content.trim_end().ends_with('}'), "unterminated JSON object");
    // Balanced braces — cheap structural validity without a JSON parser
    // (no string in the trace may contain unescaped braces or quotes).
    let opens = content.matches('{').count();
    let closes = content.matches('}').count();
    assert_eq!(opens, closes, "unbalanced braces");

    let events = parse_trace(&content);
    let metas: Vec<&TraceEvent> = events.iter().filter(|e| e.ph == 'M').collect();
    let spans: Vec<&TraceEvent> = events.iter().filter(|e| e.ph == 'X').collect();
    assert!(!spans.is_empty(), "no spans recorded");

    // Every span's track is a registered, named thread; the pumps run
    // on the work-stealing pool, so its worker names must show up.
    for m in &metas {
        assert_eq!(m.name, "thread_name");
        assert!(m.thread_name.is_some(), "metadata without a thread name");
    }
    let meta_tids: Vec<u64> = metas.iter().map(|m| m.tid).collect();
    assert!(
        metas
            .iter()
            .any(|m| m.thread_name.as_deref().is_some_and(|n| n.starts_with("kitsune-sched-"))),
        "no scheduler worker track registered"
    );
    for s in &spans {
        assert!(meta_tids.contains(&s.tid), "span on unregistered track tid={}", s.tid);
        assert!(!s.name.is_empty());
        assert!(s.ts >= 0.0 && s.dur >= 0.0);
        let cat = s.cat.as_deref().unwrap_or("");
        assert!(!cat.is_empty(), "span {} missing category", s.name);
    }

    // Name mapping: every inference stage traced at least one compute
    // span, and the training step produced "train" spans.
    for name in &stage_names {
        assert!(
            spans.iter().any(|s| &s.name == name && s.cat.as_deref() == Some("compute")),
            "stage {name} has no compute span"
        );
    }
    assert!(spans.iter().any(|s| s.cat.as_deref() == Some("train")), "no training spans");

    // Per-track spans are monotone and non-overlapping once sorted by
    // start time (pumps run synchronously on their worker thread).
    // 2ns epsilon absorbs the 3-decimal rounding in the writer.
    let mut tids: Vec<u64> = spans.iter().map(|s| s.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    for tid in tids {
        let mut track: Vec<&&TraceEvent> = spans.iter().filter(|s| s.tid == tid).collect();
        track.sort_by(|a, b| a.ts.total_cmp(&b.ts));
        for w in track.windows(2) {
            assert!(
                w[1].ts + 0.002 >= w[0].ts + w[0].dur,
                "overlapping spans on tid {tid}: {} [{} +{}] then {} [{}]",
                w[0].name,
                w[0].ts,
                w[0].dur,
                w[1].name,
                w[1].ts
            );
        }
    }
}
