//! The persistent pipeline service: cooperative stage pumps and ring
//! queues stood up once at session build, serving concurrently submitted
//! batches until shutdown.
//!
//! This replaces the per-call thread scope of
//! [`crate::coordinator::run_streaming`] (spawn, stream, join — no warm
//! serving) with the paper's Fig 6 lifecycle: `cudaPipelineCreate` /
//! `AddKernel` happen once, then a stream of tiles flows through the
//! co-resident stages. Tiles are tagged with their owning [`Ticket`] and
//! in-batch index — the sequence-tagged in-flight table — so any number
//! of callers can interleave batches through the same warm pipeline and
//! each still receives its outputs in submission order.
//!
//! Stage workers are **pumps**: cooperative tasks on the shared
//! [`crate::sched`] work-stealing pool rather than dedicated threads.
//! A pump never blocks a pool worker — when its input queue is empty
//! (or its output queue full) it registers a one-shot waker with the
//! queue and returns the worker to the pool; the waker re-injects the
//! pump when the edge changes state. Stage compute and the
//! interpreter's GEMM row panels therefore share the same cores under
//! one scheduler, which is the whole point of the unified runtime.

use crate::coordinator::{SpatialPipeline, StageMetrics};
use crate::graph::ResourceClass;
use crate::queue::{PopError, PushError, RingQueue};
use crate::runtime::{ArtifactStore, Tensor};
use crate::sched::{self, LiveCount, Scheduler};
use anyhow::{anyhow, ensure, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One tile in flight: owning ticket, index within the batch, payload.
type Tile = (Arc<TicketInner>, usize, Tensor);

/// Result of one completed batch.
#[derive(Debug, Clone)]
pub struct BatchResult {
    /// Outputs in submission order (one per input tile).
    pub outputs: Vec<Tensor>,
    /// Wall time from submit to completion.
    pub elapsed_s: f64,
}

impl BatchResult {
    pub fn tiles_per_sec(&self) -> f64 {
        self.outputs.len() as f64 / self.elapsed_s.max(1e-12)
    }
}

/// In-flight table entry for one submitted batch: slots filled by the
/// sink thread as tiles complete (in any order), a countdown of
/// outstanding tiles, and the first error if a stage kernel failed.
struct TicketInner {
    state: Mutex<TicketState>,
    done: Condvar,
    /// The owning service's in-flight tile counter: incremented by the
    /// batch size at submit, decremented once per tile as it completes or
    /// fails — so [`PipelineService::in_flight`] reads exactly the number
    /// of tiles between `submit` and ticket resolution.
    depth: Arc<AtomicUsize>,
}

struct TicketState {
    outputs: Vec<Option<Tensor>>,
    remaining: usize,
    error: Option<String>,
}

impl TicketInner {
    fn new(n: usize, depth: Arc<AtomicUsize>) -> Self {
        depth.fetch_add(n, Ordering::SeqCst);
        TicketInner {
            state: Mutex::new(TicketState {
                outputs: vec![None; n],
                remaining: n,
                error: None,
            }),
            done: Condvar::new(),
            depth,
        }
    }

    /// Sink: deliver the finished tile for slot `idx`.
    fn complete(&self, idx: usize, t: Tensor) {
        let mut s = self.state.lock().unwrap();
        if s.outputs[idx].is_none() {
            s.remaining -= 1;
            self.depth.fetch_sub(1, Ordering::SeqCst);
        }
        s.outputs[idx] = Some(t);
        if s.remaining == 0 {
            self.done.notify_all();
        }
    }

    /// Account `n` tiles as failed/abandoned, recording the first error.
    fn fail_n(&self, n: usize, msg: String) {
        let mut s = self.state.lock().unwrap();
        if s.error.is_none() {
            s.error = Some(msg);
        }
        let dec = n.min(s.remaining);
        s.remaining -= dec;
        self.depth.fetch_sub(dec, Ordering::SeqCst);
        if s.remaining == 0 {
            self.done.notify_all();
        }
    }

    fn fail(&self, msg: String) {
        self.fail_n(1, msg);
    }
}

/// Handle to one submitted batch. [`Ticket::wait`] blocks until every
/// tile of the batch has drained from the pipeline.
pub struct Ticket {
    inner: Arc<TicketInner>,
    submitted: Instant,
}

impl Ticket {
    /// Block until the batch completes; outputs are in submission order.
    pub fn wait(self) -> Result<BatchResult> {
        let mut s = self.inner.state.lock().unwrap();
        while s.remaining > 0 {
            s = self.inner.done.wait(s).unwrap();
        }
        let result = Self::take_result(&mut s, &self.submitted);
        drop(s);
        result
    }

    /// Non-consuming poll: has every tile of the batch drained? A `true`
    /// here means [`Ticket::wait`]/[`Ticket::try_wait`] will not block.
    pub fn is_done(&self) -> bool {
        self.inner.state.lock().unwrap().remaining == 0
    }

    /// Non-blocking wait: the batch result if it has completed, else the
    /// ticket back — so a poller (e.g. the serve tier's dispatcher) can
    /// keep servicing other work and retry.
    pub fn try_wait(self) -> std::result::Result<Result<BatchResult>, Ticket> {
        let mut s = self.inner.state.lock().unwrap();
        if s.remaining > 0 {
            drop(s);
            return Err(self);
        }
        let result = Self::take_result(&mut s, &self.submitted);
        drop(s);
        Ok(result)
    }

    /// Bounded wait: block up to `timeout` for the batch to complete.
    /// Returns the ticket back on timeout so the caller decides what to
    /// do with the still-in-flight batch (the deadline path in
    /// [`crate::serve`] sheds the request but keeps draining the ticket).
    pub fn wait_timeout(
        self,
        timeout: Duration,
    ) -> std::result::Result<Result<BatchResult>, Ticket> {
        let deadline = Instant::now() + timeout;
        let mut s = self.inner.state.lock().unwrap();
        while s.remaining > 0 {
            let now = Instant::now();
            if now >= deadline {
                drop(s);
                return Err(self);
            }
            let (guard, _timed_out) =
                self.inner.done.wait_timeout(s, deadline - now).unwrap();
            s = guard;
        }
        let result = Self::take_result(&mut s, &self.submitted);
        drop(s);
        Ok(result)
    }

    fn take_result(s: &mut TicketState, submitted: &Instant) -> Result<BatchResult> {
        if let Some(e) = s.error.take() {
            return Err(anyhow!(e));
        }
        let outputs = s
            .outputs
            .iter_mut()
            .map(|o| o.take().expect("completed ticket has a hole"))
            .collect();
        Ok(BatchResult { outputs, elapsed_s: submitted.elapsed().as_secs_f64() })
    }
}

/// Per-stage counters, updated lock-free by the stage's workers.
struct StageStat {
    name: String,
    class: ResourceClass,
    workers: usize,
    tiles: AtomicUsize,
    busy_ns: AtomicU64,
    wait_ns: AtomicU64,
}

impl StageStat {
    fn snapshot(&self) -> StageMetrics {
        StageMetrics {
            name: self.name.clone(),
            class: self.class,
            workers: self.workers,
            tiles: self.tiles.load(Ordering::Relaxed),
            busy_s: self.busy_ns.load(Ordering::Relaxed) as f64 * 1e-9,
            wait_s: self.wait_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        }
    }
}

/// Persistent stage pumps + ring queues for one pipeline.
pub struct PipelineService {
    source: Arc<RingQueue<Tile>>,
    /// Countdown of live pump tasks; shutdown drains it to zero so no
    /// scheduler task still references stage state when it returns.
    live: Arc<LiveCount>,
    stats: Arc<Vec<StageStat>>,
    spawned: Arc<AtomicUsize>,
    /// Submit/shutdown synchronization. `RingQueue::close` is advisory
    /// (a push racing the close may land a value no consumer will pop —
    /// see the queue's memory-model caveat), so orderly shutdown must
    /// close from the producer side *after all pushes complete*: submits
    /// hold the read side across their pushes, shutdown takes the write
    /// side (waiting out in-flight submits) before closing the source.
    /// The flag is `true` once shut down.
    gate: std::sync::RwLock<bool>,
    tile_dims: Vec<usize>,
    /// Tiles submitted but not yet resolved (completed or failed) —
    /// the in-flight table depth, exposed for admission control.
    inflight: Arc<AtomicUsize>,
}

impl PipelineService {
    /// Stand up the stage pumps: one ring queue per stage boundary, each
    /// stage's workers as cooperative tasks on the current scheduler
    /// (see [`sched::current`]), plus one sink pump routing finished
    /// tiles back to their tickets. Tasks are created here — never on
    /// the submit path.
    pub fn start(
        store: Arc<ArtifactStore>,
        pipeline: &SpatialPipeline,
        tile_dims: Vec<usize>,
    ) -> Result<PipelineService> {
        let n_stages = pipeline.stages.len();
        ensure!(n_stages > 0, "pipeline service needs at least one stage");
        ensure!(
            pipeline.edges.is_empty(),
            "pipeline `{}` has explicit DAG queue edges (multicast/skip links); \
             the linear service cannot execute it — drive it through kitsune::train",
            pipeline.name
        );
        let queues: Vec<Arc<RingQueue<Tile>>> = (0..=n_stages)
            .map(|_| RingQueue::with_capacity(pipeline.queue_capacity))
            .collect();
        let stats: Arc<Vec<StageStat>> = Arc::new(
            pipeline
                .stages
                .iter()
                .map(|s| StageStat {
                    name: s.name.clone(),
                    class: s.class,
                    workers: s.workers,
                    tiles: AtomicUsize::new(0),
                    busy_ns: AtomicU64::new(0),
                    wait_ns: AtomicU64::new(0),
                })
                .collect(),
        );
        let scheduler = sched::current();
        let total_pumps = pipeline.stages.iter().map(|s| s.workers).sum::<usize>() + 1;
        let live = LiveCount::new(total_pumps);
        let spawned = Arc::new(AtomicUsize::new(0));

        for (si, stage) in pipeline.stages.iter().enumerate() {
            let shared = Arc::new(StageShared {
                store: Arc::clone(&store),
                entry: stage.entry.clone(),
                // Arc bump only — pumps borrow weights per tile.
                weights: Arc::clone(&stage.weights),
                in_q: Arc::clone(&queues[si]),
                out_q: Arc::clone(&queues[si + 1]),
                stats: Arc::clone(&stats),
                si,
                // Countdown latch: the stage's last pump to retire closes
                // the downstream queue, so sibling pushes are never cut
                // off.
                latch: AtomicUsize::new(stage.workers),
                live: Arc::clone(&live),
                sched: Arc::clone(&scheduler),
            });
            for _ in 0..stage.workers {
                let pump = StagePump {
                    shared: Arc::clone(&shared),
                    inbox: Vec::new(),
                    pending: None,
                    poisoned: false,
                    parked: None,
                };
                // Counted at the spawn site, so the census is exact the
                // moment start() returns (and any future spawn path must
                // go through the same accounting).
                spawned.fetch_add(1, Ordering::SeqCst);
                scheduler.spawn(Box::new(move || pump.run()));
            }
        }

        // Sink pump: route finished tiles back to their tickets, draining
        // bursts so completion costs one pop cycle per burst.
        let sink = SinkPump {
            q: Arc::clone(&queues[n_stages]),
            live: Arc::clone(&live),
            sched: Arc::clone(&scheduler),
        };
        spawned.fetch_add(1, Ordering::SeqCst);
        scheduler.spawn(Box::new(move || sink.run()));

        Ok(PipelineService {
            source: Arc::clone(&queues[0]),
            live,
            stats,
            spawned,
            gate: std::sync::RwLock::new(false),
            tile_dims,
            inflight: Arc::new(AtomicUsize::new(0)),
        })
    }

    /// Enqueue a batch of tiles. Returns immediately with a [`Ticket`];
    /// any number of threads may submit concurrently, and backpressure
    /// (full source queue) blocks the submitter, not the pipeline.
    pub fn submit(&self, inputs: Vec<Tensor>) -> Result<Ticket> {
        // Hold the gate's read side across the pushes so shutdown cannot
        // close the source queue mid-submit and strand a tile (the
        // queue's close is advisory — see the `gate` field docs).
        let gate = self.gate.read().unwrap();
        ensure!(!*gate, "session is shut down; no further submissions");
        for t in &inputs {
            ensure!(
                t.dims == self.tile_dims,
                "tile dims {:?} != pipeline input {:?}",
                t.dims,
                self.tile_dims
            );
        }
        let n = inputs.len();
        let inner = Arc::new(TicketInner::new(n, Arc::clone(&self.inflight)));
        let submitted = Instant::now();
        for (i, t) in inputs.into_iter().enumerate() {
            if let Err(PushError::Closed(_)) = self.source.push((Arc::clone(&inner), i, t)) {
                // Unreachable under the gate (close happens only after
                // in-flight submits finish), kept as belt-and-braces:
                // account this and all remaining tiles as failed so
                // wait() cannot hang.
                inner.fail_n(n - i, "session shut down during submit".to_string());
                break;
            }
        }
        Ok(Ticket { inner, submitted })
    }

    /// Per-stage metrics accumulated since the service started.
    pub fn metrics(&self) -> Vec<StageMetrics> {
        self.stats.iter().map(StageStat::snapshot).collect()
    }

    /// Tiles currently between `submit` and ticket resolution — the
    /// depth of the in-flight table. Zero on an idle pipeline; the serve
    /// tier's admission control reads this to estimate wait.
    pub fn in_flight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Total pump tasks this service has ever created (stage workers +
    /// sink). Constant after [`PipelineService::start`] returns — the
    /// warm-submit test asserts exactly this. (Kept under its historical
    /// name: pumps are the scheduler-task successors of the old
    /// dedicated worker threads, with the same census semantics.)
    pub fn threads_spawned(&self) -> usize {
        self.spawned.load(Ordering::SeqCst)
    }

    /// Close the source queue and drain every pump task. Idempotent.
    /// Waits out any in-flight `submit` first (producer-side close — see
    /// the `gate` field docs); tiles already in flight drain, and their
    /// tickets complete normally. When this returns, no scheduler task
    /// references this service's stage state any more. Must be called
    /// from outside the scheduler's worker pool (any user thread).
    pub fn shutdown(&self) {
        {
            let mut gate = self.gate.write().unwrap();
            if *gate {
                return;
            }
            *gate = true;
        }
        self.source.close();
        self.live.wait_zero();
    }
}

impl Drop for PipelineService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Tiles a stage pump drains per refill. Small enough that sibling
/// pumps of the same stage still share a burst-sized batch, large enough
/// to skip most per-tile queue entries.
const STAGE_BURST: usize = 4;

/// Tiles the sink drains per burst.
const SINK_BURST: usize = 64;

/// Tiles a stage pump processes before re-injecting itself into the
/// scheduler's FIFO, so sibling pumps get a turn even on a one-worker
/// pool.
const PUMP_YIELD_TILES: usize = 16;

/// Immutable state shared by all pumps of one stage.
struct StageShared {
    store: Arc<ArtifactStore>,
    entry: String,
    weights: Arc<Vec<Tensor>>,
    in_q: Arc<RingQueue<Tile>>,
    out_q: Arc<RingQueue<Tile>>,
    stats: Arc<Vec<StageStat>>,
    si: usize,
    latch: AtomicUsize,
    live: Arc<LiveCount>,
    sched: Arc<Scheduler>,
}

/// One cooperative stage worker. Owns its in-flight tiles; moves itself
/// between scheduler tasks and queue wakers, so exactly one incarnation
/// exists at any time and the body runs single-threaded without locks.
struct StagePump {
    shared: Arc<StageShared>,
    /// Tiles popped from the input edge but not yet processed.
    inbox: Vec<Tile>,
    /// Computed output awaiting space on the output edge.
    pending: Option<Tile>,
    /// Downstream closed mid-flight: drain remaining input by failing
    /// tickets instead of computing into a void.
    poisoned: bool,
    /// When the pump parked (for wait-time accounting on resume).
    parked: Option<Instant>,
}

impl StagePump {
    fn stat(&self) -> &StageStat {
        &self.shared.stats[self.shared.si]
    }

    /// Run until out of work (park on a queue waker), out of input
    /// (retire), or out of time-slice (re-inject). Never blocks.
    fn run(mut self) {
        if let Some(p0) = self.parked.take() {
            self.stat().wait_ns.fetch_add(p0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        let mut quota = PUMP_YIELD_TILES;
        loop {
            // 1. Flush the pending output first: it holds the loop
            // invariant that at most one computed tile is buffered.
            if let Some(tile) = self.pending.take() {
                match self.shared.out_q.try_push(tile) {
                    Ok(()) => {}
                    Err(PushError::Full(t)) => {
                        self.pending = Some(t);
                        return self.park_on_space();
                    }
                    Err(PushError::Closed((ticket, _, _))) => {
                        // Downstream closed mid-flight (shutdown): the
                        // tile cannot complete — fail its ticket so no
                        // waiter hangs.
                        ticket.fail("pipeline shut down mid-flight".to_string());
                        self.poisoned = true;
                    }
                }
            }
            // 2. Refill the inbox when empty.
            if self.inbox.is_empty() {
                match self.shared.in_q.try_pop_many(&mut self.inbox, STAGE_BURST) {
                    Ok(_) => {}
                    Err(PopError::Empty) => return self.park_on_item(),
                    Err(PopError::Closed) => return self.retire(),
                }
            }
            // 3. Process one tile (weights *borrowed*, tile moved —
            // nothing cloned at the stage boundary). Kernel failures
            // poison only the owning ticket — the pipeline keeps serving
            // other batches.
            let (ticket, idx, tile) = self.inbox.remove(0);
            if self.poisoned {
                ticket.fail("pipeline shut down mid-flight".to_string());
            } else {
                let b0 = Instant::now();
                let result = {
                    let weights = self.shared.weights.as_slice();
                    let mut args: Vec<&Tensor> = Vec::with_capacity(1 + weights.len());
                    args.push(&tile);
                    args.extend(weights.iter());
                    self.shared.store.run_f32_ref(&self.shared.entry, &args)
                };
                match result {
                    Ok(outs) => match outs.into_iter().next() {
                        Some(out) => {
                            self.stat()
                                .busy_ns
                                .fetch_add(b0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                            self.stat().tiles.fetch_add(1, Ordering::Relaxed);
                            self.pending = Some((ticket, idx, out));
                        }
                        None => ticket.fail(format!("{}: produced no output", self.shared.entry)),
                    },
                    Err(e) => {
                        ticket.fail(format!("stage {} failed: {e:#}", self.shared.entry));
                    }
                }
            }
            quota -= 1;
            if quota == 0 {
                return self.reinject();
            }
        }
    }

    /// Park until the input edge has data (or closes). The waker
    /// re-injects the pump; it is fired at most once, so exactly one
    /// incarnation of the pump ever exists.
    fn park_on_item(mut self) {
        self.parked = Some(Instant::now());
        let q = Arc::clone(&self.shared.in_q);
        let sched = Arc::clone(&self.shared.sched);
        q.park_on_item(Box::new(move || {
            sched.spawn(Box::new(move || self.run()));
        }));
    }

    /// Park until the output edge has space (or closes).
    fn park_on_space(mut self) {
        self.parked = Some(Instant::now());
        let q = Arc::clone(&self.shared.out_q);
        let sched = Arc::clone(&self.shared.sched);
        q.park_on_space(Box::new(move || {
            sched.spawn(Box::new(move || self.run()));
        }));
    }

    /// Time-slice expired: go to the back of the scheduler's FIFO.
    fn reinject(self) {
        let sched = Arc::clone(&self.shared.sched);
        sched.spawn(Box::new(move || self.run()));
    }

    /// Input closed and drained: fail anything still held (possible only
    /// when poisoned), let the stage's last pump close the downstream
    /// edge, and retire from the live count.
    fn retire(self) {
        debug_assert!(self.pending.is_none(), "retire with unflushed output");
        for (ticket, _, _) in self.inbox {
            ticket.fail("pipeline shut down mid-flight".to_string());
        }
        if let Some((ticket, _, _)) = self.pending {
            ticket.fail("pipeline shut down mid-flight".to_string());
        }
        let shared = self.shared;
        if shared.latch.fetch_sub(1, Ordering::AcqRel) == 1 {
            shared.out_q.close();
        }
        shared.live.done();
    }
}

/// Cooperative sink: drain bursts of finished tiles back to their
/// tickets; park on the sink edge when it idles, retire when it closes.
struct SinkPump {
    q: Arc<RingQueue<Tile>>,
    live: Arc<LiveCount>,
    sched: Arc<Scheduler>,
}

impl SinkPump {
    fn run(self) {
        let mut burst: Vec<Tile> = Vec::new();
        for _ in 0..PUMP_YIELD_TILES {
            burst.clear();
            match self.q.try_pop_many(&mut burst, SINK_BURST) {
                Ok(_) => {
                    for (ticket, idx, t) in burst.drain(..) {
                        ticket.complete(idx, t);
                    }
                }
                Err(PopError::Empty) => {
                    let q = Arc::clone(&self.q);
                    let sched = Arc::clone(&self.sched);
                    q.park_on_item(Box::new(move || {
                        sched.spawn(Box::new(move || self.run()));
                    }));
                    return;
                }
                Err(PopError::Closed) => {
                    self.live.done();
                    return;
                }
            }
        }
        // Time-slice expired with data still flowing: re-inject.
        let sched = Arc::clone(&self.sched);
        sched.spawn(Box::new(move || self.run()));
    }
}
