//! The persistent pipeline service: cooperative stage pumps and ring
//! queues stood up once at session build, serving concurrently submitted
//! batches until shutdown.
//!
//! This replaces the per-call thread scope of
//! [`crate::coordinator::run_streaming`] (spawn, stream, join — no warm
//! serving) with the paper's Fig 6 lifecycle: `cudaPipelineCreate` /
//! `AddKernel` happen once, then a stream of tiles flows through the
//! co-resident stages. Tiles are tagged with their owning [`Ticket`] and
//! in-batch index — the sequence-tagged in-flight table — so any number
//! of callers can interleave batches through the same warm pipeline and
//! each still receives its outputs in submission order.
//!
//! Stage workers are **pumps**: cooperative tasks on the shared
//! [`crate::sched`] work-stealing pool rather than dedicated threads.
//! A pump never blocks a pool worker — when its input queue is empty
//! (or its output queue full) it registers a one-shot waker with the
//! queue and returns the worker to the pool; the waker re-injects the
//! pump when the edge changes state. Stage compute and the
//! interpreter's GEMM row panels therefore share the same cores under
//! one scheduler, which is the whole point of the unified runtime.
//!
//! # Failure semantics
//!
//! Every stage execution runs inside [`crate::fault::catch_stage`]:
//! panics and kernel errors become a typed
//! [`StageFailure`] instead of unwinding into the scheduler. The failed
//! tile is forwarded downstream as [`Envelope::Poison`] — the edge's
//! sequence space stays dense, downstream stages skip the compute, and
//! the sink resolves exactly the afflicted slot of the owning ticket
//! with [`crate::runtime::RuntimeError::StageFailed`]. Unrelated
//! in-flight tiles complete normally: the pipeline degrades per-tile,
//! not per-process.
//!
//! The failing pump incarnation retires and the service *supervises*
//! it: the pipeline's [`HealthState`] transitions to `Degraded`, and a
//! replacement pump (same stage state, weights re-read from the shared
//! artifact binding on every tile) is respawned after an exponential
//! backoff, up to [`RestartPolicy::max_restarts`] per stage. A stage
//! that exhausts its budget turns the pipeline `Failed`: the dead pump
//! keeps draining its edge but converts every tile to poison, so every
//! ticket still resolves — typed, never hung.

use crate::coordinator::{SpatialPipeline, StageMetrics};
use crate::fault::{
    catch_stage, Envelope, FaultPlan, Health, HealthState, RestartPolicy, StageFailure,
};
use crate::graph::ResourceClass;
use crate::queue::{PopError, PushError, RingQueue};
use crate::runtime::{ArtifactStore, Precision, Tensor};
use crate::sched::{self, LiveCount, Scheduler};
use crate::telemetry::{
    trace, EdgeKind, EdgeStats, PipelineTelemetry, StageTelemetry, TrafficStats,
};
use anyhow::{anyhow, ensure, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Payload bytes of one envelope (poison records move no tensor data).
/// Charged at the tensor's *storage* width — a bf16/f16 tile crossing an
/// edge moves half the bytes of its f32 twin.
fn env_bytes(env: &Envelope<Tensor>) -> u64 {
    match env {
        Envelope::Ok(t) => t.payload_bytes(),
        Envelope::Poison(_) => 0,
    }
}

/// Account a successful push's payload against the queue's edge stats
/// and the pipeline's traffic classification.
fn account_push(q: &RingQueue<Tile>, traffic: &TrafficStats, bytes: u64) {
    if let Some(e) = q.telemetry() {
        e.bytes.add(bytes);
        traffic.record_edge(e.kind, bytes);
    }
}

/// One tile in flight: owning ticket, index within the batch, payload —
/// a live tensor or the poison record of the failure that consumed it.
type Tile = (Arc<TicketInner>, usize, Envelope<Tensor>);

/// Result of one completed batch.
#[derive(Debug, Clone)]
pub struct BatchResult {
    /// Outputs in submission order (one per input tile).
    pub outputs: Vec<Tensor>,
    /// Wall time from submit to completion.
    pub elapsed_s: f64,
}

impl BatchResult {
    pub fn tiles_per_sec(&self) -> f64 {
        self.outputs.len() as f64 / self.elapsed_s.max(1e-12)
    }
}

/// In-flight table entry for one submitted batch: slots filled by the
/// sink as tiles complete (in any order), a countdown of outstanding
/// tiles, and the first typed failure if any tile was lost.
struct TicketInner {
    state: Mutex<TicketState>,
    done: Condvar,
    /// The owning service's in-flight tile counter: incremented by the
    /// batch size at submit, decremented once per tile as it completes or
    /// fails — so [`PipelineService::in_flight`] reads exactly the number
    /// of tiles between `submit` and ticket resolution.
    depth: Arc<AtomicUsize>,
}

struct TicketState {
    outputs: Vec<Option<Tensor>>,
    /// Per-slot terminal-event guard: a tile resolves (completes or
    /// fails) exactly once, no matter which drain path delivers the
    /// event — the invariant behind "`Ticket::wait` never hangs".
    resolved: Vec<bool>,
    remaining: usize,
    error: Option<StageFailure>,
}

impl TicketInner {
    fn new(n: usize, depth: Arc<AtomicUsize>) -> Self {
        depth.fetch_add(n, Ordering::SeqCst);
        TicketInner {
            state: Mutex::new(TicketState {
                outputs: vec![None; n],
                resolved: vec![false; n],
                remaining: n,
                error: None,
            }),
            done: Condvar::new(),
            depth,
        }
    }

    /// Sink: deliver the finished tile for slot `idx`.
    fn complete(&self, idx: usize, t: Tensor) {
        let mut s = self.state.lock().unwrap();
        if s.resolved[idx] {
            return;
        }
        s.resolved[idx] = true;
        s.outputs[idx] = Some(t);
        s.remaining -= 1;
        self.depth.fetch_sub(1, Ordering::SeqCst);
        if s.remaining == 0 {
            self.done.notify_all();
        }
    }

    /// Resolve slot `idx` as failed, recording the first failure.
    fn fail_tile(&self, idx: usize, failure: StageFailure) {
        let mut s = self.state.lock().unwrap();
        if s.resolved[idx] {
            return;
        }
        s.resolved[idx] = true;
        s.remaining -= 1;
        self.depth.fetch_sub(1, Ordering::SeqCst);
        if s.error.is_none() {
            s.error = Some(failure);
        }
        if s.remaining == 0 {
            self.done.notify_all();
        }
    }
}

/// Handle to one submitted batch. [`Ticket::wait`] blocks until every
/// tile of the batch has drained from the pipeline.
pub struct Ticket {
    inner: Arc<TicketInner>,
    submitted: Instant,
}

impl Ticket {
    /// Block until the batch completes; outputs are in submission order.
    pub fn wait(self) -> Result<BatchResult> {
        let mut s = self.inner.state.lock().unwrap();
        while s.remaining > 0 {
            s = self.inner.done.wait(s).unwrap();
        }
        let result = Self::take_result(&mut s, &self.submitted);
        drop(s);
        result
    }

    /// Non-consuming poll: has every tile of the batch drained? A `true`
    /// here means [`Ticket::wait`]/[`Ticket::try_wait`] will not block.
    pub fn is_done(&self) -> bool {
        self.inner.state.lock().unwrap().remaining == 0
    }

    /// Non-blocking wait: the batch result if it has completed, else the
    /// ticket back — so a poller (e.g. the serve tier's dispatcher) can
    /// keep servicing other work and retry.
    pub fn try_wait(self) -> std::result::Result<Result<BatchResult>, Ticket> {
        let mut s = self.inner.state.lock().unwrap();
        if s.remaining > 0 {
            drop(s);
            return Err(self);
        }
        let result = Self::take_result(&mut s, &self.submitted);
        drop(s);
        Ok(result)
    }

    /// Bounded wait: block up to `timeout` for the batch to complete.
    /// Returns the ticket back on timeout so the caller decides what to
    /// do with the still-in-flight batch (the deadline path in
    /// [`crate::serve`] sheds the request but keeps draining the ticket).
    pub fn wait_timeout(
        self,
        timeout: Duration,
    ) -> std::result::Result<Result<BatchResult>, Ticket> {
        let deadline = Instant::now() + timeout;
        let mut s = self.inner.state.lock().unwrap();
        while s.remaining > 0 {
            let now = Instant::now();
            if now >= deadline {
                drop(s);
                return Err(self);
            }
            let (guard, _timed_out) =
                self.inner.done.wait_timeout(s, deadline - now).unwrap();
            s = guard;
        }
        let result = Self::take_result(&mut s, &self.submitted);
        drop(s);
        Ok(result)
    }

    fn take_result(s: &mut TicketState, submitted: &Instant) -> Result<BatchResult> {
        if let Some(failure) = s.error.take() {
            return Err(failure.into_error());
        }
        let outputs = s
            .outputs
            .iter_mut()
            .map(|o| o.take().expect("completed ticket has a hole"))
            .collect();
        Ok(BatchResult { outputs, elapsed_s: submitted.elapsed().as_secs_f64() })
    }
}

/// Persistent stage pumps + ring queues for one pipeline.
pub struct PipelineService {
    source: Arc<RingQueue<Tile>>,
    /// Countdown of live pump tasks; shutdown drains it to zero so no
    /// scheduler task still references stage state when it returns.
    live: Arc<LiveCount>,
    /// Per-stage/per-edge metrics and traffic accounting, registered
    /// with [`crate::telemetry::snapshot`] for the service's lifetime.
    telemetry: Arc<PipelineTelemetry>,
    /// Resource class per stage (parallel to `telemetry.stages`), kept
    /// for the [`StageMetrics`] view.
    classes: Vec<ResourceClass>,
    spawned: Arc<AtomicUsize>,
    /// Submit/shutdown synchronization. `RingQueue::close` is advisory
    /// (a push racing the close may land a value no consumer will pop —
    /// see the queue's memory-model caveat), so orderly shutdown must
    /// close from the producer side *after all pushes complete*: submits
    /// hold the read side across their pushes, shutdown takes the write
    /// side (waiting out in-flight submits) before closing the source.
    /// The flag is `true` once shut down.
    gate: std::sync::RwLock<bool>,
    tile_dims: Vec<usize>,
    /// Tiles submitted but not yet resolved (completed or failed) —
    /// the in-flight table depth, exposed for admission control.
    inflight: Arc<AtomicUsize>,
    /// `Healthy → Degraded (restarting) → Failed` for the whole pipeline.
    health: Arc<HealthState>,
    /// Storage width applied to tiles at the submit push (stage outputs
    /// are re-quantized by each pump; see [`StageShared::prec`]).
    prec: Precision,
}

impl PipelineService {
    /// Stand up the stage pumps: one ring queue per stage boundary, each
    /// stage's workers as cooperative tasks on the current scheduler
    /// (see [`sched::current`]), plus one sink pump routing finished
    /// tiles back to their tickets. Tasks are created here — never on
    /// the submit path.
    ///
    /// `plan` is the fault-injection harness (usually
    /// [`FaultPlan::from_env`] — empty unless `KITSUNE_FAULT` is set):
    /// armed `queue_close` specs fire here, at startup; armed panics
    /// fire inside the matching stage's compute fence.
    pub fn start(
        store: Arc<ArtifactStore>,
        pipeline: &SpatialPipeline,
        tile_dims: Vec<usize>,
        plan: Arc<FaultPlan>,
    ) -> Result<PipelineService> {
        Self::start_with_precision(store, pipeline, tile_dims, plan, Precision::F32)
    }

    /// [`PipelineService::start`] with an explicit storage precision for
    /// tiles crossing the pipeline's edges: in a 16-bit mode every tile
    /// is rounded to the bf16/f16 grid at the submit push and at each
    /// stage's output emission, so edge traffic is accounted (and the
    /// ring queues conceptually carry) the reduced width while stage
    /// kernels still compute in f32.
    pub fn start_with_precision(
        store: Arc<ArtifactStore>,
        pipeline: &SpatialPipeline,
        tile_dims: Vec<usize>,
        plan: Arc<FaultPlan>,
        prec: Precision,
    ) -> Result<PipelineService> {
        let n_stages = pipeline.stages.len();
        ensure!(n_stages > 0, "pipeline service needs at least one stage");
        ensure!(
            pipeline.edges.is_empty(),
            "pipeline `{}` has explicit DAG queue edges (multicast/skip links); \
             the linear service cannot execute it — drive it through kitsune::train",
            pipeline.name
        );
        let queues: Vec<Arc<RingQueue<Tile>>> = (0..=n_stages)
            .map(|_| RingQueue::with_capacity(pipeline.queue_capacity))
            .collect();
        let health = Arc::new(HealthState::default());
        // Injected structural faults: tear an edge down before the first
        // tile flows. Consumers of the edge retire at startup and the
        // close cascades; producers fail their tiles typed at the push.
        for e in plan.take_queue_closes() {
            if e < queues.len() {
                queues[e].close();
                health.fail(&format!("edge {e}"));
            }
        }
        let policy = RestartPolicy::from_env();
        let stage_telems: Vec<StageTelemetry> = pipeline
            .stages
            .iter()
            .map(|s| {
                let weight_bytes = s.weights.iter().map(Tensor::payload_bytes).sum();
                StageTelemetry::new(
                    s.name.clone(),
                    format!("{:?}", s.class).to_lowercase(),
                    s.workers,
                    weight_bytes,
                )
            })
            .collect();
        // Edge telemetry: queue 0 is host injection (off-chip-analog),
        // the last queue drains to the sink (off-chip-analog), everything
        // between is a stage-to-stage crossing (on-chip-analog).
        let edges: Vec<Arc<EdgeStats>> = (0..=n_stages)
            .map(|i| {
                let (label, kind) = if i == 0 {
                    (format!("source->{}", pipeline.stages[0].name), EdgeKind::Source)
                } else if i == n_stages {
                    (format!("{}->sink", pipeline.stages[i - 1].name), EdgeKind::Sink)
                } else {
                    (
                        format!(
                            "{}->{}",
                            pipeline.stages[i - 1].name,
                            pipeline.stages[i].name
                        ),
                        EdgeKind::Interior,
                    )
                };
                Arc::new(EdgeStats::new(label, kind, queues[i].capacity()))
            })
            .collect();
        for (q, e) in queues.iter().zip(&edges) {
            q.attach_telemetry(Arc::clone(e));
        }
        let telemetry = PipelineTelemetry::register(pipeline.name.clone(), stage_telems, edges);
        let classes: Vec<ResourceClass> = pipeline.stages.iter().map(|s| s.class).collect();
        let scheduler = sched::current();
        let total_pumps = pipeline.stages.iter().map(|s| s.workers).sum::<usize>() + 1;
        let live = LiveCount::new(total_pumps);
        let spawned = Arc::new(AtomicUsize::new(0));

        for (si, stage) in pipeline.stages.iter().enumerate() {
            let shared = Arc::new(StageShared {
                store: Arc::clone(&store),
                entry: stage.entry.clone(),
                // Arc bump only — pumps borrow weights per tile, so a
                // respawned pump re-binds the same artifact-store-backed
                // weight set without copying.
                weights: Arc::clone(&stage.weights),
                in_q: Arc::clone(&queues[si]),
                out_q: Arc::clone(&queues[si + 1]),
                telemetry: Arc::clone(&telemetry),
                si,
                // Countdown latch: the stage's last pump to retire closes
                // the downstream queue, so sibling pushes are never cut
                // off.
                latch: AtomicUsize::new(stage.workers),
                live: Arc::clone(&live),
                sched: Arc::clone(&scheduler),
                plan: Arc::clone(&plan),
                health: Arc::clone(&health),
                policy: policy.clone(),
                restarts: AtomicUsize::new(0),
                tiles_seen: AtomicU64::new(0),
                prec,
            });
            for _ in 0..stage.workers {
                let pump = StagePump {
                    shared: Arc::clone(&shared),
                    inbox: Vec::new(),
                    pending: None,
                    poisoned: false,
                    dead: None,
                    parked: None,
                };
                // Counted at the spawn site, so the census is exact the
                // moment start() returns (and any future spawn path must
                // go through the same accounting).
                spawned.fetch_add(1, Ordering::SeqCst);
                scheduler.spawn(Box::new(move || pump.run()));
            }
        }

        // Sink pump: route finished tiles back to their tickets, draining
        // bursts so completion costs one pop cycle per burst.
        let sink = SinkPump {
            q: Arc::clone(&queues[n_stages]),
            live: Arc::clone(&live),
            sched: Arc::clone(&scheduler),
        };
        spawned.fetch_add(1, Ordering::SeqCst);
        scheduler.spawn(Box::new(move || sink.run()));

        Ok(PipelineService {
            source: Arc::clone(&queues[0]),
            live,
            telemetry,
            classes,
            spawned,
            gate: std::sync::RwLock::new(false),
            tile_dims,
            inflight: Arc::new(AtomicUsize::new(0)),
            health,
            prec,
        })
    }

    /// Enqueue a batch of tiles. Returns immediately with a [`Ticket`];
    /// any number of threads may submit concurrently, and backpressure
    /// (full source queue) blocks the submitter, not the pipeline.
    pub fn submit(&self, inputs: Vec<Tensor>) -> Result<Ticket> {
        // Hold the gate's read side across the pushes so shutdown cannot
        // close the source queue mid-submit and strand a tile (the
        // queue's close is advisory — see the `gate` field docs).
        let gate = self.gate.read().unwrap();
        ensure!(!*gate, "session is shut down; no further submissions");
        for t in &inputs {
            ensure!(
                t.dims == self.tile_dims,
                "tile dims {:?} != pipeline input {:?}",
                t.dims,
                self.tile_dims
            );
        }
        let n = inputs.len();
        let inner = Arc::new(TicketInner::new(n, Arc::clone(&self.inflight)));
        let submitted = Instant::now();
        for (i, mut t) in inputs.into_iter().enumerate() {
            // Storage boundary: the tile enters the pipeline at the
            // session's storage width (identity for f32).
            t.quantize(self.prec);
            let item = (Arc::clone(&inner), i, Envelope::Ok(t));
            let bytes = env_bytes(&item.2);
            match self.source.push(item) {
                Ok(()) => {
                    account_push(&self.source, &self.telemetry.traffic, bytes);
                    continue;
                }
                Err(PushError::Full(_)) => unreachable!("blocking push returned Full"),
                Err(PushError::Closed(_)) => {}
            }
            {
                // The source is closed: either an injected edge-0 fault
                // or (belt-and-braces — the gate makes it unreachable) a
                // racing shutdown. Resolve this and every unpushed slot
                // typed so wait() cannot hang.
                for j in i..n {
                    inner.fail_tile(j, StageFailure::closed("source").at_index(0));
                }
                break;
            }
        }
        Ok(Ticket { inner, submitted })
    }

    /// Per-stage metrics accumulated since the service started (the
    /// compact [`StageMetrics`] view; full histograms and edge/traffic
    /// detail via [`PipelineService::telemetry`]).
    pub fn metrics(&self) -> Vec<StageMetrics> {
        self.telemetry
            .stages
            .iter()
            .zip(&self.classes)
            .map(|(t, &class)| StageMetrics {
                name: t.name.clone(),
                class,
                workers: t.workers,
                tiles: t.compute.count() as usize,
                busy_s: t.compute.sum_ns() as f64 * 1e-9,
                wait_s: (t.queue_wait.sum_ns() + t.emit.sum_ns()) as f64 * 1e-9,
            })
            .collect()
    }

    /// This pipeline's full telemetry (stages, edges, traffic) — also
    /// reachable process-wide via [`crate::telemetry::snapshot`].
    pub fn telemetry(&self) -> &Arc<PipelineTelemetry> {
        &self.telemetry
    }

    /// Tiles currently between `submit` and ticket resolution — the
    /// depth of the in-flight table. Zero on an idle pipeline; the serve
    /// tier's admission control reads this to estimate wait.
    pub fn in_flight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Current pipeline health (see [`Health`]): `Degraded` while a
    /// failed stage pump is being restarted, `Failed` once a stage
    /// exhausts its restart budget or a structural edge dies.
    pub fn health(&self) -> Health {
        self.health.snapshot()
    }

    /// Shared handle to the health machine (restart/failure counters).
    pub fn health_state(&self) -> Arc<HealthState> {
        Arc::clone(&self.health)
    }

    /// Total pump tasks this service has ever created (stage workers +
    /// sink). Constant after [`PipelineService::start`] returns — the
    /// warm-submit test asserts exactly this. (Kept under its historical
    /// name: pumps are the scheduler-task successors of the old
    /// dedicated worker threads, with the same census semantics.
    /// Supervised restarts re-inject the *same* pump object and are not
    /// new spawns.)
    pub fn threads_spawned(&self) -> usize {
        self.spawned.load(Ordering::SeqCst)
    }

    /// Close the source queue and drain every pump task. Idempotent.
    /// Waits out any in-flight `submit` first (producer-side close — see
    /// the `gate` field docs); tiles already in flight drain, and their
    /// tickets complete normally. When this returns, no scheduler task
    /// references this service's stage state any more. Must be called
    /// from outside the scheduler's worker pool (any user thread).
    pub fn shutdown(&self) {
        {
            let mut gate = self.gate.write().unwrap();
            if *gate {
                return;
            }
            *gate = true;
        }
        self.source.close();
        self.live.wait_zero();
    }
}

impl Drop for PipelineService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Tiles a stage pump drains per refill. Small enough that sibling
/// pumps of the same stage still share a burst-sized batch, large enough
/// to skip most per-tile queue entries.
const STAGE_BURST: usize = 4;

/// Tiles the sink drains per burst.
const SINK_BURST: usize = 64;

/// Tiles a stage pump processes before re-injecting itself into the
/// scheduler's FIFO, so sibling pumps get a turn even on a one-worker
/// pool.
const PUMP_YIELD_TILES: usize = 16;

/// Immutable state shared by all pumps of one stage.
struct StageShared {
    store: Arc<ArtifactStore>,
    entry: String,
    weights: Arc<Vec<Tensor>>,
    in_q: Arc<RingQueue<Tile>>,
    out_q: Arc<RingQueue<Tile>>,
    telemetry: Arc<PipelineTelemetry>,
    si: usize,
    latch: AtomicUsize,
    live: Arc<LiveCount>,
    sched: Arc<Scheduler>,
    /// Deterministic fault-injection harness (empty in production).
    plan: Arc<FaultPlan>,
    health: Arc<HealthState>,
    policy: RestartPolicy,
    /// Failures consumed from the stage's restart budget (shared across
    /// sibling pumps of the stage).
    restarts: AtomicUsize,
    /// Per-stage tile ordinal: the `tile=` coordinate of the injection
    /// grammar counts *computed* tiles on this stage, in pop order.
    tiles_seen: AtomicU64,
    /// Storage width for the stage's output tiles: quantized once per
    /// tile at emission, before the push is byte-accounted.
    prec: Precision,
}

/// One cooperative stage worker. Owns its in-flight tiles; moves itself
/// between scheduler tasks and queue wakers, so exactly one incarnation
/// exists at any time and the body runs single-threaded without locks.
struct StagePump {
    shared: Arc<StageShared>,
    /// Tiles popped from the input edge but not yet processed.
    inbox: Vec<Tile>,
    /// Computed output awaiting space on the output edge.
    pending: Option<Tile>,
    /// Downstream closed mid-flight: drain remaining input by failing
    /// tickets instead of computing into a void.
    poisoned: bool,
    /// The stage exhausted its restart budget: keep draining the edge,
    /// but forward every tile as poison carrying this failure, so every
    /// ticket behind the dead stage still resolves typed.
    dead: Option<StageFailure>,
    /// When and why the pump parked, for wait-time attribution on
    /// resume: input starvation (queue-wait) vs downstream backpressure
    /// (emit) vs supervised restart backoff.
    parked: Option<(Instant, ParkKind)>,
}

/// Why a pump left the scheduler (see [`StagePump::parked`]).
#[derive(Clone, Copy)]
enum ParkKind {
    /// Input edge empty: starvation — accounted as queue-wait.
    Item,
    /// Output edge full: backpressure — accounted as emit time.
    Space,
    /// Supervised restart backoff — accounted as queue-wait.
    Backoff,
}

impl StagePump {
    fn stat(&self) -> &StageTelemetry {
        &self.shared.telemetry.stages[self.shared.si]
    }

    /// The typed failure for a tile this pump must drop (downstream or
    /// upstream edge closed under it): poison keeps its original record,
    /// a live tile becomes a `QueueClosed` failure at this stage.
    fn drop_failure(&self, env: Envelope<Tensor>) -> StageFailure {
        match env {
            Envelope::Poison(f) => f,
            Envelope::Ok(_) => {
                StageFailure::closed(&self.shared.entry).at_index(self.shared.si)
            }
        }
    }

    /// Run until out of work (park on a queue waker), out of input
    /// (retire), or out of time-slice (re-inject). Never blocks.
    fn run(mut self) {
        if let Some((p0, kind)) = self.parked.take() {
            let waited = p0.elapsed();
            match kind {
                ParkKind::Item | ParkKind::Backoff => {
                    self.stat().queue_wait.record(waited);
                    if let Some(e) = self.shared.in_q.telemetry() {
                        e.empty_stall_ns.add(waited.as_nanos() as u64);
                    }
                }
                ParkKind::Space => {
                    self.stat().emit.record(waited);
                    if let Some(e) = self.shared.out_q.telemetry() {
                        e.full_stall_ns.add(waited.as_nanos() as u64);
                    }
                }
            }
        }
        let mut quota = PUMP_YIELD_TILES;
        loop {
            // 1. Flush the pending output first: it holds the loop
            // invariant that at most one computed tile is buffered.
            if let Some(tile) = self.pending.take() {
                let live = matches!(tile.2, Envelope::Ok(_));
                let bytes = env_bytes(&tile.2);
                match self.shared.out_q.try_push(tile) {
                    Ok(()) => {
                        account_push(&self.shared.out_q, &self.shared.telemetry.traffic, bytes);
                        if live {
                            self.stat().tiles_out.inc();
                        }
                    }
                    Err(PushError::Full(t)) => {
                        self.pending = Some(t);
                        return self.park_on_space();
                    }
                    Err(PushError::Closed((ticket, idx, env))) => {
                        // Downstream closed mid-flight (shutdown or an
                        // injected edge fault): the tile cannot reach the
                        // sink — resolve its slot here so no waiter hangs.
                        let f = self.drop_failure(env);
                        ticket.fail_tile(idx, f);
                        self.poisoned = true;
                    }
                }
            }
            // 2. Refill the inbox when empty.
            if self.inbox.is_empty() {
                match self.shared.in_q.try_pop_many(&mut self.inbox, STAGE_BURST) {
                    Ok(_) => {}
                    Err(PopError::Empty) => return self.park_on_item(),
                    Err(PopError::Closed) => return self.retire(),
                }
            }
            // 3. Process one tile (weights *borrowed*, tile moved —
            // nothing cloned at the stage boundary). Kernel failures and
            // panics poison only the owning tile — the pipeline keeps
            // serving other batches.
            let (ticket, idx, env) = self.inbox.remove(0);
            if self.poisoned {
                let f = self.drop_failure(env);
                ticket.fail_tile(idx, f);
            } else if let Some(dead) = &self.dead {
                let f = match env {
                    Envelope::Poison(p) => p,
                    Envelope::Ok(_) => dead.clone(),
                };
                self.pending = Some((ticket, idx, Envelope::Poison(f)));
            } else {
                match env {
                    // Poison from upstream: skip the compute, forward the
                    // record — the sink resolves the afflicted slot.
                    Envelope::Poison(f) => {
                        self.pending = Some((ticket, idx, Envelope::Poison(f)));
                    }
                    Envelope::Ok(tile) => {
                        let seq = self.shared.tiles_seen.fetch_add(1, Ordering::Relaxed);
                        self.stat().tiles_in.inc();
                        let b0 = Instant::now();
                        let shared = &self.shared;
                        let result =
                            catch_stage(&shared.entry, Some(shared.si), Some(seq), || {
                                shared.plan.maybe_panic(shared.si, seq);
                                let weights = shared.weights.as_slice();
                                let mut args: Vec<&Tensor> =
                                    Vec::with_capacity(1 + weights.len());
                                args.push(&tile);
                                args.extend(weights.iter());
                                let outs = shared.store.run_f32_ref(&shared.entry, &args)?;
                                outs.into_iter().next().ok_or_else(|| {
                                    anyhow!("{}: produced no output", shared.entry)
                                })
                            });
                        match result {
                            Ok(mut out) => {
                                // Storage boundary: stage outputs cross
                                // the ring queue at the session's width.
                                out.quantize(self.shared.prec);
                                let stat = self.stat();
                                stat.compute.record(b0.elapsed());
                                self.shared
                                    .telemetry
                                    .traffic
                                    .weight_bytes
                                    .add(stat.weight_bytes_per_tile);
                                trace::span("compute", &stat.name, Some(seq), b0);
                                self.pending = Some((ticket, idx, Envelope::Ok(out)));
                            }
                            Err(failure) => {
                                // Poison the afflicted tile, then hand this
                                // incarnation to the supervisor (restart
                                // with backoff, or go dead).
                                self.pending =
                                    Some((ticket, idx, Envelope::Poison(failure.clone())));
                                return self.supervise(failure);
                            }
                        }
                    }
                }
            }
            quota -= 1;
            if quota == 0 {
                return self.reinject();
            }
        }
    }

    /// A stage execution failed. Degrade the pipeline and either respawn
    /// this pump (same inbox/pending, weights re-bound from the shared
    /// artifact binding) after an exponential backoff, or — once the
    /// stage's restart budget is spent — mark the pipeline `Failed` and
    /// come back as a poison-forwarding drain so nothing behind the dead
    /// stage ever hangs.
    fn supervise(mut self, failure: StageFailure) {
        let shared = Arc::clone(&self.shared);
        shared.health.degrade(&shared.entry);
        let attempt = shared.restarts.fetch_add(1, Ordering::SeqCst);
        if attempt < shared.policy.max_restarts {
            let delay = shared.policy.backoff(attempt);
            self.parked = Some((Instant::now(), ParkKind::Backoff));
            // A detached timer thread, not a pool task: sleeping must not
            // occupy a scheduler worker. Bounded by the restart budget.
            std::thread::spawn(move || {
                std::thread::sleep(delay);
                let sched = Arc::clone(&self.shared.sched);
                let health = Arc::clone(&self.shared.health);
                sched.spawn(Box::new(move || {
                    health.restore();
                    self.run()
                }));
            });
        } else {
            shared.health.fail(&shared.entry);
            self.dead = Some(failure);
            self.run();
        }
    }

    /// Park until the input edge has data (or closes). The waker
    /// re-injects the pump; it is fired at most once, so exactly one
    /// incarnation of the pump ever exists.
    fn park_on_item(mut self) {
        self.parked = Some((Instant::now(), ParkKind::Item));
        let q = Arc::clone(&self.shared.in_q);
        let sched = Arc::clone(&self.shared.sched);
        q.park_on_item(Box::new(move || {
            sched.spawn(Box::new(move || self.run()));
        }));
    }

    /// Park until the output edge has space (or closes).
    fn park_on_space(mut self) {
        self.parked = Some((Instant::now(), ParkKind::Space));
        let q = Arc::clone(&self.shared.out_q);
        let sched = Arc::clone(&self.shared.sched);
        q.park_on_space(Box::new(move || {
            sched.spawn(Box::new(move || self.run()));
        }));
    }

    /// Time-slice expired: go to the back of the scheduler's FIFO.
    fn reinject(self) {
        let sched = Arc::clone(&self.shared.sched);
        sched.spawn(Box::new(move || self.run()));
    }

    /// Input closed and drained: resolve anything still held (possible
    /// only when poisoned), let the stage's last pump close the
    /// downstream edge, and retire from the live count.
    fn retire(mut self) {
        debug_assert!(
            self.pending.is_none() || self.poisoned,
            "retire with unflushed output"
        );
        for (ticket, idx, env) in std::mem::take(&mut self.inbox) {
            let f = match env {
                Envelope::Poison(f) => f,
                Envelope::Ok(_) => {
                    StageFailure::closed(&self.shared.entry).at_index(self.shared.si)
                }
            };
            ticket.fail_tile(idx, f);
        }
        if let Some((ticket, idx, env)) = self.pending.take() {
            let f = self.drop_failure(env);
            ticket.fail_tile(idx, f);
        }
        let shared = self.shared;
        if shared.latch.fetch_sub(1, Ordering::AcqRel) == 1 {
            shared.out_q.close();
        }
        shared.live.done();
    }
}

/// Cooperative sink: drain bursts of finished tiles back to their
/// tickets — completing live tiles, resolving poisoned ones with their
/// typed failure; park on the sink edge when it idles, retire when it
/// closes.
struct SinkPump {
    q: Arc<RingQueue<Tile>>,
    live: Arc<LiveCount>,
    sched: Arc<Scheduler>,
}

impl SinkPump {
    fn run(self) {
        let mut burst: Vec<Tile> = Vec::new();
        for _ in 0..PUMP_YIELD_TILES {
            burst.clear();
            match self.q.try_pop_many(&mut burst, SINK_BURST) {
                Ok(_) => {
                    for (ticket, idx, env) in burst.drain(..) {
                        match env {
                            Envelope::Ok(t) => ticket.complete(idx, t),
                            Envelope::Poison(f) => ticket.fail_tile(idx, f),
                        }
                    }
                }
                Err(PopError::Empty) => {
                    let q = Arc::clone(&self.q);
                    let sched = Arc::clone(&self.sched);
                    q.park_on_item(Box::new(move || {
                        sched.spawn(Box::new(move || self.run()));
                    }));
                    return;
                }
                Err(PopError::Closed) => {
                    self.live.done();
                    return;
                }
            }
        }
        // Time-slice expired with data still flowing: re-inject.
        let sched = Arc::clone(&self.sched);
        sched.spawn(Box::new(move || self.run()));
    }
}
