//! The persistent pipeline service: stage worker threads and ring queues
//! stood up once at session build, serving concurrently submitted batches
//! until shutdown.
//!
//! This replaces the per-call thread scope of
//! [`crate::coordinator::run_streaming`] (spawn, stream, join — no warm
//! serving) with the paper's Fig 6 lifecycle: `cudaPipelineCreate` /
//! `AddKernel` happen once, then a stream of tiles flows through the
//! co-resident stages. Tiles are tagged with their owning [`Ticket`] and
//! in-batch index — the sequence-tagged in-flight table — so any number
//! of callers can interleave batches through the same warm pipeline and
//! each still receives its outputs in submission order.

use crate::coordinator::{SpatialPipeline, StageMetrics};
use crate::graph::ResourceClass;
use crate::queue::{PushError, RingQueue};
use crate::runtime::{ArtifactStore, Tensor};
use anyhow::{anyhow, ensure, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// One tile in flight: owning ticket, index within the batch, payload.
type Tile = (Arc<TicketInner>, usize, Tensor);

/// Result of one completed batch.
#[derive(Debug, Clone)]
pub struct BatchResult {
    /// Outputs in submission order (one per input tile).
    pub outputs: Vec<Tensor>,
    /// Wall time from submit to completion.
    pub elapsed_s: f64,
}

impl BatchResult {
    pub fn tiles_per_sec(&self) -> f64 {
        self.outputs.len() as f64 / self.elapsed_s.max(1e-12)
    }
}

/// In-flight table entry for one submitted batch: slots filled by the
/// sink thread as tiles complete (in any order), a countdown of
/// outstanding tiles, and the first error if a stage kernel failed.
struct TicketInner {
    state: Mutex<TicketState>,
    done: Condvar,
}

struct TicketState {
    outputs: Vec<Option<Tensor>>,
    remaining: usize,
    error: Option<String>,
}

impl TicketInner {
    fn new(n: usize) -> Self {
        TicketInner {
            state: Mutex::new(TicketState {
                outputs: vec![None; n],
                remaining: n,
                error: None,
            }),
            done: Condvar::new(),
        }
    }

    /// Sink: deliver the finished tile for slot `idx`.
    fn complete(&self, idx: usize, t: Tensor) {
        let mut s = self.state.lock().unwrap();
        if s.outputs[idx].is_none() {
            s.remaining -= 1;
        }
        s.outputs[idx] = Some(t);
        if s.remaining == 0 {
            self.done.notify_all();
        }
    }

    /// Account `n` tiles as failed/abandoned, recording the first error.
    fn fail_n(&self, n: usize, msg: String) {
        let mut s = self.state.lock().unwrap();
        if s.error.is_none() {
            s.error = Some(msg);
        }
        s.remaining = s.remaining.saturating_sub(n);
        if s.remaining == 0 {
            self.done.notify_all();
        }
    }

    fn fail(&self, msg: String) {
        self.fail_n(1, msg);
    }
}

/// Handle to one submitted batch. [`Ticket::wait`] blocks until every
/// tile of the batch has drained from the pipeline.
pub struct Ticket {
    inner: Arc<TicketInner>,
    submitted: Instant,
}

impl Ticket {
    /// Block until the batch completes; outputs are in submission order.
    pub fn wait(self) -> Result<BatchResult> {
        let mut s = self.inner.state.lock().unwrap();
        while s.remaining > 0 {
            s = self.inner.done.wait(s).unwrap();
        }
        if let Some(e) = s.error.take() {
            return Err(anyhow!(e));
        }
        let outputs = s
            .outputs
            .iter_mut()
            .map(|o| o.take().expect("completed ticket has a hole"))
            .collect();
        Ok(BatchResult { outputs, elapsed_s: self.submitted.elapsed().as_secs_f64() })
    }
}

/// Per-stage counters, updated lock-free by the stage's workers.
struct StageStat {
    name: String,
    class: ResourceClass,
    workers: usize,
    tiles: AtomicUsize,
    busy_ns: AtomicU64,
    wait_ns: AtomicU64,
}

impl StageStat {
    fn snapshot(&self) -> StageMetrics {
        StageMetrics {
            name: self.name.clone(),
            class: self.class,
            workers: self.workers,
            tiles: self.tiles.load(Ordering::Relaxed),
            busy_s: self.busy_ns.load(Ordering::Relaxed) as f64 * 1e-9,
            wait_s: self.wait_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        }
    }
}

/// Persistent stage worker pools + ring queues for one pipeline.
pub struct PipelineService {
    source: Arc<RingQueue<Tile>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    stats: Arc<Vec<StageStat>>,
    spawned: Arc<AtomicUsize>,
    /// Submit/shutdown synchronization. `RingQueue::close` is advisory
    /// (a push racing the close may land a value no consumer will pop —
    /// see the queue's memory-model caveat), so orderly shutdown must
    /// close from the producer side *after all pushes complete*: submits
    /// hold the read side across their pushes, shutdown takes the write
    /// side (waiting out in-flight submits) before closing the source.
    /// The flag is `true` once shut down.
    gate: std::sync::RwLock<bool>,
    tile_dims: Vec<usize>,
}

impl PipelineService {
    /// Stand up the worker pools: one ring queue per stage boundary, each
    /// stage's workers as long-lived threads, plus one sink thread
    /// routing finished tiles back to their tickets. Threads are created
    /// here — never on the submit path.
    pub fn start(
        store: Arc<ArtifactStore>,
        pipeline: &SpatialPipeline,
        tile_dims: Vec<usize>,
    ) -> Result<PipelineService> {
        let n_stages = pipeline.stages.len();
        ensure!(n_stages > 0, "pipeline service needs at least one stage");
        ensure!(
            pipeline.edges.is_empty(),
            "pipeline `{}` has explicit DAG queue edges (multicast/skip links); \
             the linear service cannot execute it — drive it through kitsune::train",
            pipeline.name
        );
        let queues: Vec<Arc<RingQueue<Tile>>> = (0..=n_stages)
            .map(|_| RingQueue::with_capacity(pipeline.queue_capacity))
            .collect();
        let stats: Arc<Vec<StageStat>> = Arc::new(
            pipeline
                .stages
                .iter()
                .map(|s| StageStat {
                    name: s.name.clone(),
                    class: s.class,
                    workers: s.workers,
                    tiles: AtomicUsize::new(0),
                    busy_ns: AtomicU64::new(0),
                    wait_ns: AtomicU64::new(0),
                })
                .collect(),
        );
        let spawned = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();

        // If any spawn fails partway, already-spawned workers must not be
        // leaked blocked on never-closed queues: close every queue (pop
        // then returns None) and join the partial pool before erroring.
        let abort = |handles: Vec<JoinHandle<()>>, e: anyhow::Error| -> anyhow::Error {
            for q in &queues {
                q.close();
            }
            for h in handles {
                let _ = h.join();
            }
            e
        };

        for (si, stage) in pipeline.stages.iter().enumerate() {
            // Countdown latch: the stage's last worker to exit closes the
            // downstream queue, so sibling pushes are never cut off.
            let latch = Arc::new(AtomicUsize::new(stage.workers));
            for wi in 0..stage.workers {
                let in_q = Arc::clone(&queues[si]);
                let out_q = Arc::clone(&queues[si + 1]);
                let latch = Arc::clone(&latch);
                let store = Arc::clone(&store);
                let stats = Arc::clone(&stats);
                let entry = stage.entry.clone();
                // Arc bump only — the worker borrows weights per tile.
                let weights = Arc::clone(&stage.weights);
                let spawn_result = std::thread::Builder::new()
                    .name(format!("kitsune-{}-{wi}", stage.name))
                    .spawn(move || {
                        stage_worker(&store, &entry, &weights, &in_q, &out_q, &stats[si]);
                        if latch.fetch_sub(1, Ordering::AcqRel) == 1 {
                            out_q.close();
                        }
                    });
                let handle = match spawn_result {
                    Ok(h) => h,
                    Err(e) => return Err(abort(handles, anyhow!("spawning stage worker: {e}"))),
                };
                // Counted at the spawn site, so the census is exact the
                // moment start() returns (and any future spawn path must
                // go through the same accounting).
                spawned.fetch_add(1, Ordering::SeqCst);
                handles.push(handle);
            }
        }

        // Sink: route finished tiles back to their tickets, draining
        // bursts so completion costs one backoff cycle per burst.
        let sink_q = Arc::clone(&queues[n_stages]);
        let sink_result = std::thread::Builder::new()
            .name("kitsune-sink".to_string())
            .spawn(move || {
                let mut burst: Vec<Tile> = Vec::new();
                loop {
                    burst.clear();
                    if sink_q.pop_many(&mut burst, SINK_BURST) == 0 {
                        break;
                    }
                    for (ticket, idx, t) in burst.drain(..) {
                        ticket.complete(idx, t);
                    }
                }
            });
        match sink_result {
            Ok(h) => handles.push(h),
            Err(e) => return Err(abort(handles, anyhow!("spawning sink: {e}"))),
        }
        spawned.fetch_add(1, Ordering::SeqCst);

        Ok(PipelineService {
            source: Arc::clone(&queues[0]),
            handles: Mutex::new(handles),
            stats,
            spawned,
            gate: std::sync::RwLock::new(false),
            tile_dims,
        })
    }

    /// Enqueue a batch of tiles. Returns immediately with a [`Ticket`];
    /// any number of threads may submit concurrently, and backpressure
    /// (full source queue) blocks the submitter, not the pipeline.
    pub fn submit(&self, inputs: Vec<Tensor>) -> Result<Ticket> {
        // Hold the gate's read side across the pushes so shutdown cannot
        // close the source queue mid-submit and strand a tile (the
        // queue's close is advisory — see the `gate` field docs).
        let gate = self.gate.read().unwrap();
        ensure!(!*gate, "session is shut down; no further submissions");
        for t in &inputs {
            ensure!(
                t.dims == self.tile_dims,
                "tile dims {:?} != pipeline input {:?}",
                t.dims,
                self.tile_dims
            );
        }
        let n = inputs.len();
        let inner = Arc::new(TicketInner::new(n));
        let submitted = Instant::now();
        for (i, t) in inputs.into_iter().enumerate() {
            if let Err(PushError::Closed(_)) = self.source.push((Arc::clone(&inner), i, t)) {
                // Unreachable under the gate (close happens only after
                // in-flight submits finish), kept as belt-and-braces:
                // account this and all remaining tiles as failed so
                // wait() cannot hang.
                inner.fail_n(n - i, "session shut down during submit".to_string());
                break;
            }
        }
        Ok(Ticket { inner, submitted })
    }

    /// Per-stage metrics accumulated since the service started.
    pub fn metrics(&self) -> Vec<StageMetrics> {
        self.stats.iter().map(StageStat::snapshot).collect()
    }

    /// Total threads this service has ever spawned (stage workers +
    /// sink). Constant after [`PipelineService::start`] returns — the
    /// warm-submit test asserts exactly this.
    pub fn threads_spawned(&self) -> usize {
        self.spawned.load(Ordering::SeqCst)
    }

    /// Close the source queue and join every worker. Idempotent. Waits
    /// out any in-flight `submit` first (producer-side close — see the
    /// `gate` field docs); tiles already in flight drain, and their
    /// tickets complete normally.
    pub fn shutdown(&self) {
        {
            let mut gate = self.gate.write().unwrap();
            if *gate {
                return;
            }
            *gate = true;
        }
        self.source.close();
        let mut handles = self.handles.lock().unwrap();
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for PipelineService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Tiles a stage worker drains per backoff cycle. Small enough that
/// sibling workers of the same stage still share a burst-sized batch,
/// large enough to skip most per-tile backoff entries.
const STAGE_BURST: usize = 4;

/// Tiles the sink drains per backoff cycle.
const SINK_BURST: usize = 64;

/// One stage worker: drain a burst of tagged tiles, run the stage entry
/// over each (weights *borrowed*, tile moved — nothing cloned at the
/// stage boundary), forward the results. Kernel failures poison only the
/// owning ticket — the pipeline keeps serving other batches.
fn stage_worker(
    store: &ArtifactStore,
    entry: &str,
    weights: &[Tensor],
    in_q: &RingQueue<Tile>,
    out_q: &RingQueue<Tile>,
    stat: &StageStat,
) {
    let mut burst: Vec<Tile> = Vec::new();
    'serve: loop {
        let w0 = Instant::now();
        burst.clear();
        if in_q.pop_many(&mut burst, STAGE_BURST) == 0 {
            break;
        }
        stat.wait_ns.fetch_add(w0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let mut poisoned = false;
        for (ticket, idx, tile) in burst.drain(..) {
            if poisoned {
                // Downstream already closed: account the rest of the
                // burst as failed so no waiter hangs.
                ticket.fail("pipeline shut down mid-flight".to_string());
                continue;
            }
            let b0 = Instant::now();
            let result = {
                let mut args: Vec<&Tensor> = Vec::with_capacity(1 + weights.len());
                args.push(&tile);
                args.extend(weights.iter());
                store.run_f32_ref(entry, &args)
            };
            match result {
                Ok(outs) => match outs.into_iter().next() {
                    Some(out) => {
                        stat.busy_ns.fetch_add(b0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        stat.tiles.fetch_add(1, Ordering::Relaxed);
                        let w1 = Instant::now();
                        if let Err(PushError::Closed((t, _, _))) = out_q.push((ticket, idx, out)) {
                            // Downstream closed mid-flight (shutdown):
                            // the tile cannot complete — fail its ticket
                            // so no waiter hangs.
                            t.fail("pipeline shut down mid-flight".to_string());
                            poisoned = true;
                            continue;
                        }
                        stat.wait_ns.fetch_add(w1.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    }
                    None => ticket.fail(format!("{entry}: produced no output")),
                },
                Err(e) => ticket.fail(format!("stage {entry} failed: {e:#}")),
            }
        }
        if poisoned {
            break 'serve;
        }
    }
}
