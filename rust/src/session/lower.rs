//! Lowering the compiler's output onto the coordinator's runtime: a
//! [`CompiledApp`] (sf-nodes, designed pipeline stages, ILP allocations)
//! becomes a runnable [`SpatialPipeline`] whose stage kernels are
//! synthesized interpreter [`Program`]s — no hand-written stage lists, no
//! on-disk artifacts.
//!
//! This is the bridge the codebase was missing: the compiler's
//! [`crate::compiler::StageSpec`] (graph nodes grouped by Algorithm 1)
//! and the coordinator's [`crate::coordinator::StageSpec`] (an artifact
//! entry plus weights) were unrelated types, so the compiled plan only
//! ever drove the simulator while real pipelines were stitched by hand.
//! [`lower_app`] walks the compiled plan, checks that it streams (a
//! linear chain of row-wise stages), emits one SSA tensor program per
//! stage with He-initialized weights bound in, and returns the pipeline
//! the session's persistent worker pool executes.
//!
//! Graphs that cannot stream (bulk-sync plan items, batched matmuls,
//! fan-out/skip queue edges, ops without interpreter kernels) produce the
//! typed [`SessionError::NotStreamable`] — the session still simulates
//! them; it just cannot serve them for real.

use super::SessionError;
use crate::compiler::{design_pipeline, CompiledApp, PlanItem};
use crate::coordinator::{SpatialPipeline, StageSpec};
use crate::graph::{EwKind, Graph, NodeId, OpKind, ResourceClass};
use crate::runtime::interp::{Act, Instr, Program, Reg};
use crate::runtime::{EntrySpec, Precision, Rng, Tensor, TensorSpec};
use crate::Result;
use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::Arc;

/// Knobs for [`lower_app`], filled in by the session builder.
#[derive(Debug, Clone)]
pub struct LowerOptions {
    /// Worker threads per TENSOR-class stage (SIMT stages get 1) — the
    /// host analog of the ILP's per-stage CTA allocation.
    pub gemm_workers: usize,
    /// Ring-queue capacity between adjacent stages.
    pub queue_capacity: usize,
    /// Rows per streamed tile; default derives from the compiler's
    /// chosen tile count for the first pipeline.
    pub tile_rows: Option<usize>,
    /// Seed for He-initialized stage weights.
    pub seed: u64,
    /// Pump tasks per training-DAG stage (default 1). More than one
    /// lets tiles of a stage compute out of order; the executor's
    /// sequence reorder buffer restores emission order, so results stay
    /// bitwise-identical to the serial oracle.
    pub train_workers: usize,
    /// Storage width for stage weights and inter-stage tiles. 16-bit
    /// modes round values to the format's grid at the storage
    /// boundaries (weight creation, queue pushes) while kernels keep
    /// f32 accumulation — halving per-tile edge bytes.
    pub precision: Precision,
}

impl Default for LowerOptions {
    fn default() -> Self {
        LowerOptions {
            gemm_workers: 2,
            queue_capacity: 8,
            tile_rows: None,
            seed: 0xC0FFEE,
            train_workers: 1,
            precision: Precision::F32,
        }
    }
}

/// A compiled application lowered to runnable form.
pub struct LoweredApp {
    /// The coordinator pipeline (stage names/entries/classes/workers).
    pub pipeline: SpatialPipeline,
    /// Per-stage synthesized entries: manifest spec, SSA program, and the
    /// weight tensors to bind into the stage executable.
    pub entries: Vec<(EntrySpec, Program, Vec<Tensor>)>,
    /// Rows per streamed tile.
    pub tile_rows: usize,
    /// Trailing dim of the input tile (`[tile_rows, in_dim]`).
    pub in_dim: usize,
    /// Trailing dim of the output tile.
    pub out_dim: usize,
    /// Tile count the compiler sized queues for — a sensible batch size.
    pub suggested_tiles: usize,
}

/// Typed lowering failure; shared with the training lowering
/// (`crate::train::lower`), which produces the same error kind.
pub(crate) fn not_streamable(reason: impl Into<String>) -> anyhow::Error {
    SessionError::NotStreamable { reason: reason.into() }.into()
}

/// Lower `app` (compiled from `g`) into a runnable spatial pipeline.
pub fn lower_app(g: &Graph, app: &CompiledApp, opts: &LowerOptions) -> Result<LoweredApp> {
    // 1. The whole compute graph must stream: the plan may contain only
    //    spatial pipelines, in topological order.
    if app.pipelines.is_empty() {
        return Err(not_streamable("compiler selected no spatial pipelines"));
    }
    let mut order = Vec::new();
    for item in &app.plan {
        match item {
            PlanItem::Pipeline(i) => order.push(*i),
            PlanItem::Bsp(nid) => {
                return Err(not_streamable(format!(
                    "operator `{}` runs bulk-synchronous outside any pipeline",
                    g.node(*nid).name
                )))
            }
        }
    }

    // 2. Exactly one graph input feeds the stream.
    let input_ids: Vec<NodeId> = g
        .nodes()
        .iter()
        .filter(|n| matches!(n.op, OpKind::Input))
        .map(|n| n.id)
        .collect();
    if input_ids.len() != 1 {
        return Err(not_streamable(format!(
            "graph has {} input nodes; streaming needs exactly 1",
            input_ids.len()
        )));
    }
    let input = input_ids[0];

    if order.is_empty() {
        return Err(not_streamable("compiled plan has no pipeline items"));
    }

    // 3. Tile geometry: the compiler's chosen tile count for the first
    //    pipeline sets the default rows-per-tile.
    let rows = g.node(input).out.shape.leading();
    let in_dim = g.node(input).out.shape.trailing();
    let suggested_tiles = app.pipelines[order[0]]
        .desc
        .stages
        .first()
        .map(|s| s.n_tiles)
        .unwrap_or(1)
        .max(1);
    let tile_rows = opts.tile_rows.unwrap_or_else(|| (rows / suggested_tiles).max(1));

    // 4. Synthesize stages sf-node by sf-node, chaining the streamed
    //    value across stage (and sf-node) boundaries.
    let mut rng = Rng::new(opts.seed);
    let mut stages: Vec<StageSpec> = Vec::new();
    let mut entries: Vec<(EntrySpec, Program, Vec<Tensor>)> = Vec::new();
    let mut producer = input; // graph node whose value is on the stream
    let mut cur_dim = in_dim;
    for &pi in &order {
        let sf = &app.selection.sf_nodes[pi];
        let spec = design_pipeline(g, sf);
        // Linearity: only consecutive-stage queue edges, exactly one in.
        // Diagnostics name the concrete producer node and stage pair, and
        // distinguish fan-out from skip links — both lower fine on the
        // training DAG pipeline (`kitsune::train`), just not here.
        for e in &spec.edges {
            let fanout: Vec<usize> = spec
                .edges
                .iter()
                .filter(|e2| e2.producer_node == e.producer_node)
                .map(|e2| e2.to_stage)
                .collect();
            let node = g.node(e.producer_node);
            if fanout.len() > 1 {
                return Err(not_streamable(format!(
                    "pipeline sf{}: `{}` ({}) multicasts from stage {} to stages {:?}; \
                     linear streaming has no fan-out queues",
                    sf.id, node.name, node.op, e.from_stage, fanout
                )));
            }
            if e.to_stage != e.from_stage + 1 {
                return Err(not_streamable(format!(
                    "pipeline sf{}: `{}` ({}) rides a skip link from stage {} to stage {}, \
                     bypassing {} stage(s); linear streaming has only adjacent queues",
                    sf.id,
                    node.name,
                    node.op,
                    e.from_stage,
                    e.to_stage,
                    e.to_stage - e.from_stage - 1
                )));
            }
        }
        for (si, st) in spec.stages.iter().enumerate() {
            let n_in = spec.edges.iter().filter(|e| e.to_stage == si).count();
            if (si == 0 && n_in != 0) || (si > 0 && n_in != 1) {
                return Err(not_streamable(format!(
                    "pipeline sf{} stage {si} has {n_in} input queues; streaming needs a linear chain",
                    sf.id
                )));
            }
            let (program, weights, out_node) =
                synth_stage(g, &st.nodes, producer, &mut rng, opts.precision)?;
            let anchor = g.node(st.nodes[0]);
            let entry_name = format!("sf{}.s{}.{}", sf.id, si, anchor.name);
            entries.push((
                EntrySpec {
                    name: entry_name.clone(),
                    hlo_path: PathBuf::from("<session>"),
                    inputs: vec![TensorSpec {
                        dtype: "f32".to_string(),
                        dims: vec![tile_rows, cur_dim],
                    }],
                    n_outputs: 1,
                },
                program,
                weights,
            ));
            stages.push(StageSpec {
                name: format!("sf{}.s{}", sf.id, si),
                entry: entry_name,
                class: st.class,
                // Weights are bound inside the stage executable, so the
                // per-tile call carries only the streamed tile.
                weights: Arc::new(Vec::new()),
                workers: if st.class == ResourceClass::Tensor {
                    opts.gemm_workers.max(1)
                } else {
                    1
                },
            });
            producer = out_node;
            cur_dim = g.node(out_node).out.shape.trailing();
        }
    }
    if stages.is_empty() {
        return Err(not_streamable("compiled plan produced no stages"));
    }
    if !g.consumers(producer).is_empty() {
        return Err(not_streamable(format!(
            "stream ends at `{}`, which still has consumers",
            g.node(producer).name
        )));
    }

    Ok(LoweredApp {
        pipeline: SpatialPipeline {
            name: format!("{}::session", g.name),
            stages,
            queue_capacity: opts.queue_capacity.max(2),
            edges: Vec::new(),
        },
        entries,
        tile_rows,
        in_dim,
        out_dim: cur_dim,
        suggested_tiles,
    })
}

/// Synthesize one stage (a compiler stage's member nodes, anchor first)
/// into an SSA program over `[tile] ++ params`, returning the program,
/// the He-initialized weight tensors (program inputs `1..`, rounded to
/// `prec`'s storage grid), and the graph node whose value the stage
/// emits.
fn synth_stage(
    g: &Graph,
    nodes: &[NodeId],
    stream: NodeId,
    rng: &mut Rng,
    prec: Precision,
) -> Result<(Program, Vec<Tensor>, NodeId)> {
    let in_stage: HashSet<NodeId> = nodes.iter().copied().collect();

    // Parameters in deterministic first-use order become inputs 1..=P.
    let mut params: Vec<NodeId> = Vec::new();
    for &nid in nodes {
        for &i in &g.node(nid).inputs {
            if matches!(g.node(i).op, OpKind::Param) && !params.contains(&i) {
                params.push(i);
            }
        }
    }
    let n_inputs = 1 + params.len();
    let param_reg: HashMap<NodeId, Reg> =
        params.iter().enumerate().map(|(k, &p)| (p, 1 + k)).collect();

    let mut reg_of: HashMap<NodeId, Reg> = HashMap::new();
    let mut instrs: Vec<Instr> = Vec::new();
    for &nid in nodes {
        let node = g.node(nid);
        let resolve = |i: NodeId| -> Result<Reg> {
            if i == stream {
                return Ok(0);
            }
            if let Some(&r) = reg_of.get(&i) {
                return Ok(r);
            }
            Err(not_streamable(format!(
                "stage op `{}` consumes `{}`, which is neither the streamed value nor produced in-stage",
                node.name,
                g.node(i).name
            )))
        };
        let reg = match &node.op {
            OpKind::Matmul { b, .. } => {
                if *b != 1 {
                    return Err(not_streamable(format!(
                        "batched matmul `{}` cannot stream row tiles",
                        node.name
                    )));
                }
                let x = resolve(node.inputs[0])?;
                let w = *param_reg.get(&node.inputs[1]).ok_or_else(|| {
                    not_streamable(format!("matmul `{}` weight is not a parameter", node.name))
                })?;
                let mut r = n_inputs + instrs.len();
                instrs.push(Instr::Matmul { a: x, b: w });
                if let Some(&bias) = node.inputs.get(2) {
                    let bias_reg = *param_reg.get(&bias).ok_or_else(|| {
                        not_streamable(format!("matmul `{}` bias is not a parameter", node.name))
                    })?;
                    instrs.push(Instr::AddBias { a: r, bias: bias_reg });
                    r += 1;
                }
                r
            }
            OpKind::Elementwise(ew) => {
                if node.inputs.len() != 1 {
                    return Err(not_streamable(format!(
                        "elementwise `{}` ({ew:?}) is not unary",
                        node.name
                    )));
                }
                let a = resolve(node.inputs[0])?;
                let instr = match ew {
                    EwKind::Relu => Instr::Relu { a },
                    EwKind::Sigmoid => Instr::Sigmoid { a },
                    EwKind::Gelu => Instr::Gelu { a },
                    EwKind::Tanh => Instr::Tanh { a },
                    EwKind::Silu => Instr::Silu { a },
                    EwKind::Exp => Instr::Exp { a },
                    other => {
                        return Err(not_streamable(format!(
                            "elementwise `{}` ({other:?}) has no interpreter kernel",
                            node.name
                        )))
                    }
                };
                let r = n_inputs + instrs.len();
                instrs.push(instr);
                r
            }
            other => {
                return Err(not_streamable(format!(
                    "op `{}` ({}) has no streaming lowering",
                    node.name,
                    other.mnemonic()
                )))
            }
        };
        reg_of.insert(nid, reg);
    }

    // The stage's output: the unique member whose value leaves the stage
    // (graph output, or consumed by a later stage).
    let outs: Vec<NodeId> = nodes
        .iter()
        .copied()
        .filter(|&nid| {
            let cons = g.consumers(nid);
            cons.is_empty() || cons.iter().any(|c| !in_stage.contains(c))
        })
        .collect();
    if outs.len() != 1 {
        return Err(not_streamable(format!(
            "stage anchored at `{}` produces {} outputs; streaming needs exactly 1",
            g.node(nodes[0]).name,
            outs.len()
        )));
    }
    let out_node = outs[0];
    // Peephole-fuse the synthesized program: Matmul→AddBias and
    // AddBias→activation chains collapse into single instructions, so
    // the hot path makes one pass (and one buffer) where the naive
    // lowering made two or three.
    let program = fuse_program(&Program { n_inputs, instrs, outputs: vec![reg_of[&out_node]] });
    let weights: Vec<Tensor> = params
        .iter()
        .map(|&p| {
            let mut w = rng.he_tensor(g.node(p).out.shape.dims());
            w.quantize(prec);
            w
        })
        .collect();
    Ok((program, weights, out_node))
}

/// Peephole fusion over an SSA stage program: collapse `Matmul → AddBias`
/// into [`Instr::MatmulBias`], then any remaining `AddBias → activation`
/// (`Relu`/`Gelu`/`Silu`/`Tanh`/`Sigmoid`/`Exp`) into [`Instr::BiasAct`]. A
/// producer folds into its consumer only when the intermediate register
/// has exactly one use and is not a program output, so the rewrite is
/// observationally identical — and the fused kernels are bitwise-
/// identical to the unfused pair by construction (property-tested in
/// `tests/kernel_equivalence.rs`).
pub fn fuse_program(p: &Program) -> Program {
    let n_regs = p.n_inputs + p.instrs.len();
    let mut use_count = vec![0usize; n_regs];
    for instr in &p.instrs {
        for r in instr.reads() {
            if r < n_regs {
                use_count[r] += 1;
            }
        }
    }
    // Outputs count as uses: a register the caller observes cannot be
    // folded away.
    for &r in &p.outputs {
        if r < n_regs {
            use_count[r] += 1;
        }
    }
    // Index of the instruction defining a computed register.
    let def_of = |r: Reg| -> Option<usize> { r.checked_sub(p.n_inputs) };

    let mut replace: Vec<Option<Instr>> = vec![None; p.instrs.len()];
    let mut killed = vec![false; p.instrs.len()];

    // Pass 1: Matmul → AddBias  ⇒  MatmulBias.
    for i in 0..p.instrs.len() {
        if let Instr::AddBias { a, bias } = p.instrs[i] {
            if let Some(j) = def_of(a) {
                if j < i && use_count[a] == 1 && !killed[j] {
                    if let Instr::Matmul { a: x, b: w } = p.instrs[j] {
                        replace[i] = Some(Instr::MatmulBias { a: x, b: w, bias });
                        killed[j] = true;
                    }
                }
            }
        }
    }

    // Pass 2: AddBias → activation  ⇒  BiasAct, for bias adds still
    // standing (one already folded into a MatmulBias is gone, and a
    // MatmulBias result keeps its standalone activation — which the
    // engine then runs in place).
    for i in 0..p.instrs.len() {
        let fusable = match p.instrs[i] {
            Instr::Relu { a } => Some((a, Act::Relu)),
            Instr::Sigmoid { a } => Some((a, Act::Sigmoid)),
            Instr::Gelu { a } => Some((a, Act::Gelu)),
            Instr::Tanh { a } => Some((a, Act::Tanh)),
            Instr::Silu { a } => Some((a, Act::Silu)),
            Instr::Exp { a } => Some((a, Act::Exp)),
            _ => None,
        };
        if let Some((a, act)) = fusable {
            if let Some(j) = def_of(a) {
                if j < i && use_count[a] == 1 && !killed[j] && replace[j].is_none() {
                    if let Instr::AddBias { a: src, bias } = p.instrs[j] {
                        replace[i] = Some(Instr::BiasAct { a: src, bias, act });
                        killed[j] = true;
                    }
                }
            }
        }
    }

    // Emit surviving instructions, remapping registers around the holes
    // left by folded producers. A killed register is never referenced by
    // a surviving instruction or output (its single use was the fusing
    // consumer, whose replacement reads the producer's operands instead).
    let mut reg_map: Vec<Reg> = (0..n_regs).collect();
    let mut instrs = Vec::with_capacity(p.instrs.len());
    for i in 0..p.instrs.len() {
        let old_reg = p.n_inputs + i;
        if killed[i] {
            continue;
        }
        let instr = replace[i].unwrap_or(p.instrs[i]);
        let remapped = instr.remap(|r| if r < n_regs { reg_map[r] } else { r });
        reg_map[old_reg] = p.n_inputs + instrs.len();
        instrs.push(remapped);
    }
    let outputs =
        p.outputs.iter().map(|&r| if r < n_regs { reg_map[r] } else { r }).collect();
    Program { n_inputs: p.n_inputs, instrs, outputs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, SelectOptions};
    use crate::session::nerf_trunk_graph;
    use crate::sim::GpuConfig;

    fn lower_trunk() -> (Graph, LoweredApp) {
        let g = nerf_trunk_graph(64, 6, 16, 3);
        let app = compile(&g, &GpuConfig::a100(), &SelectOptions::default()).unwrap();
        let low = lower_app(
            &g,
            &app,
            &LowerOptions { tile_rows: Some(4), ..LowerOptions::default() },
        )
        .unwrap();
        (g, low)
    }

    #[test]
    fn trunk_lowers_to_linear_pipeline() {
        let (_, low) = lower_trunk();
        // 4 GEMM stages, each with its activation epilogue-fused.
        assert_eq!(low.pipeline.stages.len(), 4, "{:?}", low.pipeline.stages);
        assert_eq!(low.entries.len(), 4);
        assert_eq!(low.tile_rows, 4);
        assert_eq!(low.in_dim, 6);
        assert_eq!(low.out_dim, 3);
        for (spec, program, weights) in &low.entries {
            // One streamed input; weights bound, not passed per tile.
            assert_eq!(spec.inputs.len(), 1);
            assert_eq!(program.n_inputs, 1 + weights.len());
            assert_eq!(weights.len(), 2, "weight + bias per fused stage");
        }
        // Entry names are synthesized from the compiled plan, not typed in.
        assert!(low.pipeline.stages.iter().all(|s| s.entry.starts_with("sf")));
        // TENSOR stages get the GEMM worker count.
        assert!(low.pipeline.stages.iter().all(|s| s.workers >= 1));
        // The peephole fuser collapsed every Matmul→AddBias pair: no
        // standalone AddBias survives in a lowered stage program.
        for (_, program, _) in &low.entries {
            assert!(
                program.instrs.iter().any(|i| matches!(i, Instr::MatmulBias { .. })),
                "expected a fused MatmulBias in {:?}",
                program.instrs
            );
            assert!(
                !program.instrs.iter().any(|i| matches!(i, Instr::AddBias { .. })),
                "unfused AddBias survived in {:?}",
                program.instrs
            );
        }
    }

    #[test]
    fn fuser_collapses_chains_and_preserves_semantics() {
        use crate::runtime::Rng as TRng;
        // x @ w + b, gelu — with the matmul result ALSO an output, so the
        // matmul must NOT fold away; the bias+act pair still fuses.
        let guarded = Program {
            n_inputs: 3,
            instrs: vec![
                Instr::Matmul { a: 0, b: 1 },
                Instr::AddBias { a: 3, bias: 2 },
                Instr::Gelu { a: 4 },
            ],
            outputs: vec![3, 5],
        };
        let fused = fuse_program(&guarded);
        assert_eq!(fused.instrs.len(), 2, "{:?}", fused.instrs);
        assert!(matches!(fused.instrs[0], Instr::Matmul { .. }));
        assert!(matches!(fused.instrs[1], Instr::BiasAct { act: Act::Gelu, .. }));
        assert_eq!(fused.outputs, vec![3, 4]);

        // Plain chain: Matmul+AddBias fuse (MatmulBias), activation stays.
        let chain = Program {
            n_inputs: 3,
            instrs: vec![
                Instr::Matmul { a: 0, b: 1 },
                Instr::AddBias { a: 3, bias: 2 },
                Instr::Silu { a: 4 },
            ],
            outputs: vec![5],
        };
        let fused = fuse_program(&chain);
        assert_eq!(fused.instrs.len(), 2, "{:?}", fused.instrs);
        assert!(matches!(fused.instrs[0], Instr::MatmulBias { .. }));
        assert!(matches!(fused.instrs[1], Instr::Silu { .. }));
        assert_eq!(fused.outputs, vec![4]);

        // Both forms match the unfused scalar oracle under the live
        // equivalence tier (bitwise with the vector layer off, ULP-bounded
        // on the FMA paths) — and fusion itself never changes engine bits.
        let mut rng = TRng::new(41);
        let x = Tensor {
            dims: vec![6, 5],
            data: (0..30).map(|_| rng.normal()).collect(),
            prec: crate::runtime::Precision::F32,
        };
        let w = rng.he_tensor(&[5, 4]);
        let mut b = rng.he_tensor(&[4]);
        b.data.iter_mut().for_each(|v| *v = 0.2 * rng.normal());
        let inputs = [x, w, b];
        let tier = crate::runtime::engine_equivalence();
        let want = chain.run_reference(&inputs).unwrap();
        let got = fused.run(&inputs).unwrap();
        tier.check(&got[0].data, &want[0].data).expect("fused chain vs oracle");
        let unfused = chain.run(&inputs).unwrap();
        assert_eq!(unfused[0].data, got[0].data, "fusion must not change engine bits");
        let want_g = guarded.run_reference(&inputs).unwrap();
        let got_g = fuse_program(&guarded).run(&inputs).unwrap();
        tier.check(&got_g[0].data, &want_g[0].data).expect("guarded out 0 vs oracle");
        tier.check(&got_g[1].data, &want_g[1].data).expect("guarded out 1 vs oracle");
    }

    #[test]
    fn lowered_stages_compose_to_the_whole_model() {
        // Running the synthesized stage programs back-to-back implements
        // relu/relu/relu/sigmoid of the full MLP over a tile.
        let (_, low) = lower_trunk();
        let mut rng = Rng::new(3);
        let mut cur = Tensor {
            dims: vec![low.tile_rows, low.in_dim],
            data: (0..low.tile_rows * low.in_dim).map(|_| rng.normal()).collect(),
            prec: crate::runtime::Precision::F32,
        };
        for (_, program, weights) in &low.entries {
            cur = program.run_bound(&[cur], weights).unwrap().remove(0);
        }
        assert_eq!(cur.dims, vec![low.tile_rows, low.out_dim]);
        assert!(cur.data.iter().all(|v| (0.0..=1.0).contains(v)), "sigmoid head range");
    }

    #[test]
    fn multicast_diagnostics_name_the_node_and_stages() {
        use crate::graph::{GraphBuilder, GraphKind};
        // One ew output feeding two GEMMs (Fig 2(c)): the reason must name
        // the producer node, its op, and the fan-out stage pair — not the
        // old generic "multicast or skip link" string.
        let mut b = GraphBuilder::new("mc", GraphKind::Inference);
        let x = b.input(&[512, 512], "x");
        let e = b.relu(x, "act");
        let _m1 = b.linear(e, 512, false, "g1");
        let _m2 = b.linear(e, 512, false, "g2");
        let g = b.finish();
        let app = compile(&g, &GpuConfig::a100(), &SelectOptions::default()).unwrap();
        let err = lower_app(&g, &app, &LowerOptions::default()).unwrap_err();
        match err.downcast_ref::<SessionError>() {
            Some(SessionError::NotStreamable { reason }) => {
                assert!(reason.contains("`act`"), "{reason}");
                assert!(reason.contains("multicast"), "{reason}");
                assert!(reason.contains("ew:Relu"), "{reason}");
            }
            other => panic!("expected NotStreamable, got {other:?}"),
        }
    }

    #[test]
    fn graphs_with_bulk_sync_items_are_typed_not_streamable() {
        use crate::graph::{GraphBuilder, GraphKind};
        let mut b = GraphBuilder::new("mix", GraphKind::Inference);
        let idx = b.input(&[1024], "idx");
        let e = b.gather(idx, 10_000, 64, "emb"); // excluded from sf-nodes
        b.mlp(e, &[128, 64], EwKind::Relu, false, "mlp");
        let g = b.finish();
        let app = compile(&g, &GpuConfig::a100(), &SelectOptions::default()).unwrap();
        let err = lower_app(&g, &app, &LowerOptions::default()).unwrap_err();
        match err.downcast_ref::<SessionError>() {
            Some(SessionError::NotStreamable { reason }) => {
                assert!(reason.contains("bulk-synchronous"), "{reason}");
            }
            other => panic!("expected NotStreamable, got {other:?}"),
        }
    }
}
