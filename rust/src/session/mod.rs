//! `kitsune::session` — the single front door from graph to execution.
//!
//! The paper's Fig 6 host flow (`cudaPipelineCreate` → `AddKernel` →
//! launch) is a *persistent* spatial pipeline that amortizes setup across
//! a stream of tiles. This module is its host-level realization and the
//! one public API for running anything:
//!
//! ```no_run
//! use kitsune::session::Session;
//!
//! let session = Session::builder().app("nerf").build()?;   // compiles once
//! let eval = session.simulate()?;                          // §6 evaluation
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! For graphs that lower to a linear spatial pipeline, `build()` also
//! stands up the *warm* serving path: stage worker threads and ring
//! queues created once, then any number of callers stream batches
//! concurrently through [`Session::submit`] / [`Ticket::wait`] — no
//! thread is ever spawned on the submit path. [`Session::shutdown`] (or
//! `Drop`) tears the pool down.
//!
//! ```no_run
//! use kitsune::session::{nerf_trunk_graph, Session};
//!
//! let session = Session::builder()
//!     .graph(nerf_trunk_graph(8192, 60, 64, 3))
//!     .workers(2)
//!     .build()?;                                  // compile + lower + warm up
//! let tiles = session.make_tiles(64, 0xFEED)?;
//! let out = session.submit(tiles)?.wait()?;       // concurrent-safe
//! println!("{:.0} tiles/s", out.tiles_per_sec());
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! The lowering ([`lower`]) is the piece that makes this a single façade:
//! the compiler's [`CompiledApp`] plan is turned into the coordinator's
//! [`SpatialPipeline`] with synthesized interpreter stage kernels —
//! previously the compiled plan only ever drove the simulator while real
//! pipelines were hand-built stage lists.

pub mod lower;
pub mod service;

pub use lower::{fuse_program, lower_app, LowerOptions, LoweredApp};
pub use service::{BatchResult, PipelineService, Ticket};

use crate::apps;
use crate::compiler::{compile, CompiledApp, SelectOptions};
use crate::fault::{FaultPlan, Health};
use crate::coordinator::{run_serial, PipelineRun, SpatialPipeline, StageMetrics};
use crate::graph::{EwKind, Graph, GraphBuilder, GraphKind};
use crate::report::{evaluate_compiled, AppEval};
use crate::runtime::{bound_executable, ArtifactStore, Backend, Precision, Rng, Tensor};
use crate::sim::GpuConfig;
use crate::train::{
    lower_training, OptimizerKind, TrainBatch, TrainPlan, TrainService, Trainer,
};
use crate::Result;
use std::fmt;
use std::path::PathBuf;
use std::sync::Arc;

/// Typed session failure modes, downcastable from `anyhow::Error`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SessionError {
    /// `.app(name)` matched nothing in either suite.
    UnknownApp { name: String, available: Vec<String> },
    /// The graph compiled, but its plan cannot stream through a linear
    /// spatial pipeline. `simulate()` still works.
    NotStreamable { reason: String },
    /// The session was built without a graph (artifacts-only).
    NoGraph,
    /// The session was built with `warm(false)`; the streaming pool was
    /// never stood up.
    Cold,
}

impl fmt::Display for SessionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SessionError::UnknownApp { name, available } => {
                write!(f, "unknown app `{name}` — valid names: {}", available.join(", "))
            }
            SessionError::NotStreamable { reason } => write!(
                f,
                "graph cannot stream through a spatial pipeline: {reason} \
                 (Session::simulate still works)"
            ),
            SessionError::NoGraph => write!(
                f,
                "session has no graph — build with .app(..)/.graph(..), or use \
                 .artifacts(..) only for store access"
            ),
            SessionError::Cold => write!(
                f,
                "session was built cold (warm(false)) — rebuild warm to submit batches"
            ),
        }
    }
}

impl std::error::Error for SessionError {}

/// The NeRF-class trunk MLP (the family the AOT artifacts implement) as a
/// streamable graph: `in → hidden ×3 (ReLU) → out (sigmoid)`. The default
/// serving demo for `kitsune serve` and the examples.
pub fn nerf_trunk_graph(rows: usize, in_dim: usize, hidden: usize, out_dim: usize) -> Graph {
    let mut b = GraphBuilder::new("nerf-trunk", GraphKind::Inference);
    let x = b.input(&[rows, in_dim], "x");
    let mut h = x;
    for i in 0..3 {
        h = b.linear(h, hidden, true, &format!("trunk{i}"));
        h = b.relu(h, &format!("trunk{i}.act"));
    }
    let o = b.linear(h, out_dim, true, "head");
    b.ew1(EwKind::Sigmoid, o, "head.act");
    b.finish()
}

/// Builder mirroring Fig 6's host flow: declare what to run and how,
/// then `build()` compiles, lowers, and warms up — exactly once.
pub struct SessionBuilder {
    app: Option<String>,
    graph: Option<Graph>,
    training: bool,
    cfg: GpuConfig,
    select: SelectOptions,
    backend: Option<Box<dyn Backend>>,
    artifacts: Option<PathBuf>,
    gemm_workers: usize,
    queue_capacity: usize,
    tile_rows: Option<usize>,
    seed: u64,
    train_workers: usize,
    warm: bool,
    fault: Option<Arc<FaultPlan>>,
    precision: Precision,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            app: None,
            graph: None,
            training: false,
            cfg: GpuConfig::a100(),
            select: SelectOptions::default(),
            backend: None,
            artifacts: None,
            gemm_workers: 2,
            queue_capacity: 8,
            tile_rows: None,
            seed: 0xC0FFEE,
            train_workers: 1,
            warm: true,
            fault: None,
            precision: crate::runtime::precision::default_precision(),
        }
    }
}

impl SessionBuilder {
    /// Run a suite application by (case-insensitive) name. Searches the
    /// inference suite, then training — or only training under
    /// [`Self::training`]. Mutually exclusive with [`Self::graph`]
    /// (`graph` wins).
    pub fn app(mut self, name: impl Into<String>) -> Self {
        self.app = Some(name.into());
        self
    }

    /// Run an explicitly constructed graph.
    pub fn graph(mut self, g: Graph) -> Self {
        self.graph = Some(g);
        self
    }

    /// Restrict [`Self::app`] lookup to the training suite.
    pub fn training(mut self, training: bool) -> Self {
        self.training = training;
        self
    }

    /// Machine config for compilation and simulation (default: A100).
    pub fn config(mut self, cfg: GpuConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Subgraph-selection options for the compiler.
    pub fn select_options(mut self, select: SelectOptions) -> Self {
        self.select = select;
        self
    }

    /// Backend for loading [`Self::artifacts`] (default:
    /// `runtime::default_backend`). Synthesized stage programs always run
    /// on the in-process interpreter.
    pub fn backend(mut self, backend: Box<dyn Backend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Also load an AOT artifact directory, exposed via
    /// [`Session::artifacts`] (e.g. for `train_step`-style entries).
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = Some(dir.into());
        self
    }

    /// Worker threads per TENSOR-class stage (default 2) — the host
    /// analog of the ILP's per-stage CTA allocation.
    pub fn workers(mut self, n: usize) -> Self {
        self.gemm_workers = n.max(1);
        self
    }

    /// Ring-queue capacity between stages (default 8; min 2 =
    /// double-buffering as in paper Fig 4).
    pub fn queue_capacity(mut self, entries: usize) -> Self {
        self.queue_capacity = entries.max(2);
        self
    }

    /// Rows per streamed tile (default: derived from the compiler's tile
    /// count).
    pub fn tile_rows(mut self, rows: usize) -> Self {
        self.tile_rows = Some(rows.max(1));
        self
    }

    /// Seed for He-initialized stage weights.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Pump tasks per training-DAG stage (default 1). Raising this lets
    /// a stage's tiles compute out of order on the shared scheduler; the
    /// executor's sequence reorder buffer keeps emission — and therefore
    /// results — bitwise-identical to the serial oracle.
    pub fn train_workers(mut self, n: usize) -> Self {
        self.train_workers = n.max(1);
        self
    }

    /// `warm(false)` skips standing up the worker pool — compile/lower/
    /// simulate only (used by `kitsune compile`). Default: warm.
    pub fn warm(mut self, warm: bool) -> Self {
        self.warm = warm;
        self
    }

    /// Storage precision for stage weights and inter-stage tiles
    /// (default: the process-wide `KITSUNE_PRECISION`, itself defaulting
    /// to f32). In a 16-bit mode, values are rounded to the bf16/f16
    /// grid at weight creation and every queue push while kernels still
    /// accumulate in f32 — halving per-tile edge bytes in telemetry and
    /// the serve registry's resident-byte accounting.
    pub fn precision(mut self, prec: Precision) -> Self {
        self.precision = prec;
        self
    }

    /// Install a programmatic fault-injection plan for this session's
    /// pipelines (see [`crate::fault::FaultPlan`]). Defaults to the
    /// process-wide plan parsed from `KITSUNE_FAULT` (empty when unset),
    /// so production sessions pay one branch per tile on an empty plan.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault = Some(Arc::new(plan));
        self
    }

    /// Compile once, lower the compiled plan onto the coordinator, and
    /// (when the graph streams and the session is warm) stand up the
    /// persistent stage worker pools.
    pub fn build(self) -> Result<Session> {
        let SessionBuilder {
            app,
            graph,
            training,
            cfg,
            select,
            backend,
            artifacts,
            gemm_workers,
            queue_capacity,
            tile_rows,
            seed,
            train_workers,
            warm,
            fault,
            precision,
        } = self;
        let fault_plan = fault.unwrap_or_else(FaultPlan::from_env);

        let (name, graph) = match (graph, app) {
            (Some(g), _) => (g.name.clone(), Some(g)),
            (None, Some(app_name)) => {
                let found = if training {
                    apps::find_app(&app_name, true)
                } else {
                    apps::find_app(&app_name, false).or_else(|| apps::find_app(&app_name, true))
                };
                match found {
                    Some((n, g)) => (n, Some(g)),
                    None => {
                        return Err(SessionError::UnknownApp {
                            name: app_name,
                            available: apps::app_names(),
                        }
                        .into())
                    }
                }
            }
            (None, None) => {
                if artifacts.is_none() {
                    return Err(SessionError::NoGraph.into());
                }
                ("artifacts".to_string(), None)
            }
        };

        let aot = match &artifacts {
            Some(dir) => Some(Arc::new(match backend {
                Some(b) => ArtifactStore::load_with(dir, b)?,
                None => ArtifactStore::load(dir)?,
            })),
            None => None,
        };

        let mut compiled = None;
        let mut lowered = None;
        let mut service = None;
        let mut train = None;
        let mut not_streamable = None;
        if let Some(g) = &graph {
            let c = compile(g, &cfg, &select)?;
            let opts = LowerOptions {
                gemm_workers,
                queue_capacity,
                tile_rows,
                seed,
                train_workers,
                precision,
            };
            if g.backward_start.is_some() {
                // Training graphs lower onto the DAG pipeline (multicast +
                // skip links); the linear lowering below can never stream a
                // backward pass.
                match lower_training(g, &opts) {
                    Ok(plan) => {
                        let plan = Arc::new(plan);
                        let svc = if warm {
                            Some(TrainService::start(Arc::clone(&plan), Arc::clone(&fault_plan))?)
                        } else {
                            None
                        };
                        train = Some(TrainState { plan, service: svc });
                    }
                    Err(e) => {
                        if let Some(SessionError::NotStreamable { reason }) =
                            e.downcast_ref::<SessionError>()
                        {
                            not_streamable = Some(reason.clone());
                        } else {
                            return Err(e);
                        }
                    }
                }
                compiled = Some(c);
            } else {
                match lower_app(g, &c, &opts) {
                    Ok(low) => {
                        let LoweredApp {
                            pipeline,
                            entries,
                            tile_rows,
                            in_dim,
                            out_dim,
                            suggested_tiles,
                        } = low;
                        let execs = entries
                            .into_iter()
                            .map(|(spec, program, weights)| {
                                let exe = bound_executable(spec.name.clone(), program, weights);
                                (spec, exe)
                            })
                            .collect();
                        let store = Arc::new(ArtifactStore::from_executables("session", execs));
                        if warm {
                            service = Some(PipelineService::start_with_precision(
                                Arc::clone(&store),
                                &pipeline,
                                vec![tile_rows, in_dim],
                                Arc::clone(&fault_plan),
                                precision,
                            )?);
                        }
                        lowered = Some(LoweredState {
                            pipeline,
                            store,
                            tile_rows,
                            in_dim,
                            out_dim,
                            suggested_tiles,
                        });
                    }
                    Err(e) => {
                        if let Some(SessionError::NotStreamable { reason }) =
                            e.downcast_ref::<SessionError>()
                        {
                            not_streamable = Some(reason.clone());
                        } else {
                            return Err(e);
                        }
                    }
                }
                compiled = Some(c);
            }
        }

        Ok(Session {
            name,
            cfg,
            graph,
            compiled,
            lowered,
            service,
            train,
            aot,
            not_streamable,
            precision,
        })
    }
}

/// A training graph lowered onto the DAG pipeline, plus (when warm) its
/// persistent executor.
struct TrainState {
    plan: Arc<TrainPlan>,
    service: Option<TrainService>,
}

/// A compiled graph lowered to runnable form, plus its synthesized-entry
/// store.
struct LoweredState {
    pipeline: SpatialPipeline,
    store: Arc<ArtifactStore>,
    tile_rows: usize,
    in_dim: usize,
    out_dim: usize,
    suggested_tiles: usize,
}

/// One warm handle from graph to execution: compiled plan, lowered
/// pipeline, persistent worker pool, simulator access, and (optionally)
/// an AOT artifact store — see the module docs for the lifecycle.
pub struct Session {
    name: String,
    cfg: GpuConfig,
    graph: Option<Graph>,
    compiled: Option<CompiledApp>,
    lowered: Option<LoweredState>,
    service: Option<PipelineService>,
    train: Option<TrainState>,
    aot: Option<Arc<ArtifactStore>>,
    not_streamable: Option<String>,
    precision: Precision,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn config(&self) -> &GpuConfig {
        &self.cfg
    }

    /// Storage precision this session keeps weights and inter-stage
    /// tiles at (see [`SessionBuilder::precision`]).
    pub fn precision(&self) -> Precision {
        self.precision
    }

    pub fn graph(&self) -> Option<&Graph> {
        self.graph.as_ref()
    }

    /// The plan compiled at `build()` — selection, lowered sf-nodes, ILP
    /// allocations.
    pub fn compiled(&self) -> Option<&CompiledApp> {
        self.compiled.as_ref()
    }

    /// The coordinator pipeline the compiled plan lowered to, when the
    /// graph streams.
    pub fn pipeline(&self) -> Option<&SpatialPipeline> {
        self.lowered.as_ref().map(|l| &l.pipeline)
    }

    /// The AOT artifact store, when the builder was given `.artifacts`.
    pub fn artifacts(&self) -> Option<&ArtifactStore> {
        self.aot.as_deref()
    }

    /// Dims of one streamed input tile (`[tile_rows, in_dim]`).
    pub fn tile_dims(&self) -> Option<Vec<usize>> {
        self.lowered.as_ref().map(|l| vec![l.tile_rows, l.in_dim])
    }

    /// Trailing dim of one output tile.
    pub fn out_dim(&self) -> Option<usize> {
        self.lowered.as_ref().map(|l| l.out_dim)
    }

    /// Tile count the compiler sized queues for — a sensible batch size.
    pub fn suggested_tiles(&self) -> Option<usize> {
        self.lowered.as_ref().map(|l| l.suggested_tiles)
    }

    /// Whether `submit`/`run` are available.
    pub fn is_streamable(&self) -> bool {
        self.lowered.is_some()
    }

    /// Whether the graph lowered onto the *training* DAG pipeline —
    /// [`Session::trainer`] is available (warm sessions only).
    pub fn is_trainable(&self) -> bool {
        self.train.is_some()
    }

    /// The training plan the graph lowered to, when it did.
    pub fn train_plan(&self) -> Option<&TrainPlan> {
        self.train.as_ref().map(|t| t.plan.as_ref())
    }

    /// A training loop driver over this session's warm DAG pipeline,
    /// with the default optimizer (plain SGD at [`crate::train::DEFAULT_LR`]).
    pub fn trainer(&self) -> Result<Trainer<'_>> {
        self.trainer_with(OptimizerKind::default())
    }

    /// [`Session::trainer`] with an explicit optimizer configuration.
    pub fn trainer_with(&self, kind: OptimizerKind) -> Result<Trainer<'_>> {
        match &self.train {
            Some(TrainState { service: Some(svc), .. }) => Ok(Trainer::new(svc, kind)),
            Some(TrainState { service: None, .. }) => Err(SessionError::Cold.into()),
            None => Err(self.no_stream_err()),
        }
    }

    /// Deterministic synthetic full-batch training inputs matching the
    /// plan's sources (normal data, uniform `[0,1)` targets).
    pub fn make_train_batch(&self, seed: u64) -> Result<TrainBatch> {
        match self.train_plan() {
            Some(plan) => Ok(TrainBatch::synthetic(plan, seed)),
            None => Err(self.no_stream_err()),
        }
    }

    /// Why the graph cannot stream, when it cannot.
    pub fn not_streamable_reason(&self) -> Option<&str> {
        self.not_streamable.as_deref()
    }

    /// Run the §6 three-way evaluation (BSP / vertical fusion / Kitsune
    /// dataflow) on the simulator, reusing the plan compiled at build.
    pub fn simulate(&self) -> Result<AppEval> {
        let (g, c) = match (&self.graph, &self.compiled) {
            (Some(g), Some(c)) => (g, c.clone()),
            _ => return Err(SessionError::NoGraph.into()),
        };
        evaluate_compiled(&self.name, g, &self.cfg, c)
    }

    /// Enqueue a batch of tiles through the warm pipeline. Concurrent-
    /// safe; never spawns threads. See [`PipelineService::submit`].
    pub fn submit(&self, inputs: Vec<Tensor>) -> Result<Ticket> {
        match &self.service {
            Some(svc) => svc.submit(inputs),
            None => Err(self.no_stream_err()),
        }
    }

    /// Submit and wait: the one-call streaming path.
    pub fn run(&self, inputs: Vec<Tensor>) -> Result<BatchResult> {
        self.submit(inputs)?.wait()
    }

    /// Serial baseline over the same lowered stages — the bulk-sync
    /// analog, for speedup reporting.
    pub fn run_serial(&self, inputs: Vec<Tensor>) -> Result<PipelineRun> {
        match &self.lowered {
            Some(l) => run_serial(&l.store, &l.pipeline, inputs),
            None => Err(self.no_stream_err()),
        }
    }

    /// Per-stage metrics accumulated since build (warm sessions only).
    pub fn metrics(&self) -> Vec<StageMetrics> {
        self.service.as_ref().map(PipelineService::metrics).unwrap_or_default()
    }

    /// Cross-layer telemetry for the warm pipeline (inference service or
    /// training executor): per-stage tile counts and latency histograms,
    /// per-edge occupancy/stall counters, and the dataflow traffic
    /// accountant. `None` for cold / simulation-only sessions.
    pub fn telemetry(&self) -> Option<&Arc<crate::telemetry::PipelineTelemetry>> {
        if let Some(svc) = &self.service {
            return Some(svc.telemetry());
        }
        if let Some(TrainState { service: Some(svc), .. }) = &self.train {
            return Some(svc.telemetry());
        }
        None
    }

    /// Current health of the warm pipeline (inference service or
    /// training executor): `Degraded` while a failed stage is being
    /// restarted, `Failed` once a restart budget is exhausted or a
    /// structural edge died. Cold / simulation-only sessions report
    /// `Healthy`. The serve tier consults this to retry or shed admitted
    /// requests.
    pub fn health(&self) -> Health {
        if let Some(svc) = &self.service {
            return svc.health();
        }
        if let Some(TrainState { service: Some(svc), .. }) = &self.train {
            return svc.health();
        }
        Health::Healthy
    }

    /// Tiles currently in flight through the warm inference pipeline
    /// (submitted, not yet resolved). Zero for cold or training-only
    /// sessions and whenever the pipeline is idle — the serve tier's
    /// no-ticket-leak invariant checks exactly this.
    pub fn in_flight(&self) -> usize {
        self.service.as_ref().map(PipelineService::in_flight).unwrap_or(0)
    }

    /// Total threads the warm pools have ever spawned (inference pipeline
    /// and/or training DAG) — constant after `build()`; asserted by the
    /// warm-submit test.
    pub fn threads_spawned(&self) -> usize {
        self.service.as_ref().map(PipelineService::threads_spawned).unwrap_or(0)
            + self
                .train
                .as_ref()
                .and_then(|t| t.service.as_ref())
                .map(TrainService::threads_spawned)
                .unwrap_or(0)
    }

    /// Deterministic normal input tiles matching the pipeline's tile spec.
    pub fn make_tiles(&self, n: usize, seed: u64) -> Result<Vec<Tensor>> {
        let l = match &self.lowered {
            Some(l) => l,
            None => return Err(self.no_stream_err()),
        };
        let mut rng = Rng::new(seed);
        Ok((0..n)
            .map(|_| Tensor {
                dims: vec![l.tile_rows, l.in_dim],
                data: (0..l.tile_rows * l.in_dim).map(|_| rng.normal()).collect(),
                prec: crate::runtime::Precision::F32,
            })
            .collect())
    }

    /// Close the warm pool: in-flight batches drain, workers join,
    /// further submits fail. Idempotent; also runs on `Drop`.
    pub fn shutdown(&self) {
        if let Some(svc) = &self.service {
            svc.shutdown();
        }
        if let Some(TrainState { service: Some(svc), .. }) = &self.train {
            svc.shutdown();
        }
        // If tracing is armed (`KITSUNE_TRACE` or `telemetry::trace::enable`),
        // persist whatever spans accumulated so far. Idempotent: flush
        // rewrites the complete file each time, so multiple sessions (or
        // shutdown + Drop) just leave the latest superset on disk.
        let _ = crate::telemetry::trace::flush();
    }

    fn no_stream_err(&self) -> anyhow::Error {
        if let Some(reason) = &self.not_streamable {
            SessionError::NotStreamable { reason: reason.clone() }.into()
        } else if self.lowered.is_some() {
            SessionError::Cold.into()
        } else {
            SessionError::NoGraph.into()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_app_error_lists_both_suites() {
        let err = Session::builder().app("definitely-not-an-app").build().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("DLRM"), "{msg}");
        assert!(msg.contains("LLAMA (training)"), "{msg}");
        assert!(matches!(
            err.downcast_ref::<SessionError>(),
            Some(SessionError::UnknownApp { .. })
        ));
    }

    #[test]
    fn builder_without_source_is_a_typed_error() {
        let err = Session::builder().build().unwrap_err();
        assert!(matches!(err.downcast_ref::<SessionError>(), Some(SessionError::NoGraph)));
    }

    #[test]
    fn cold_session_compiles_but_does_not_spawn() {
        let session = Session::builder()
            .graph(nerf_trunk_graph(64, 6, 16, 3))
            .tile_rows(4)
            .warm(false)
            .build()
            .unwrap();
        assert!(session.is_streamable());
        assert!(session.compiled().is_some());
        assert_eq!(session.threads_spawned(), 0);
        let err = session.submit(Vec::new()).unwrap_err();
        assert!(matches!(err.downcast_ref::<SessionError>(), Some(SessionError::Cold)));
    }

    #[test]
    fn warm_session_round_trip_matches_serial() {
        let session = Session::builder()
            .graph(nerf_trunk_graph(64, 6, 16, 3))
            .tile_rows(4)
            .workers(2)
            .build()
            .unwrap();
        let tiles = session.make_tiles(10, 42).unwrap();
        let serial = session.run_serial(tiles.clone()).unwrap();
        let streamed = session.run(tiles).unwrap();
        assert_eq!(streamed.outputs.len(), 10);
        for (a, b) in streamed.outputs.iter().zip(&serial.outputs) {
            assert_eq!(a.data, b.data, "streamed output must match serial bitwise");
        }
        session.shutdown();
        let err = session.submit(Vec::new()).unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
    }

    #[test]
    fn training_flag_restricts_lookup() {
        let session = Session::builder().app("MGN").training(true).warm(false).build().unwrap();
        assert!(session.graph().unwrap().backward_start.is_some());
        // MGN training has gather/scatter aggregations: simulation-only.
        assert!(!session.is_streamable());
        assert!(session.not_streamable_reason().is_some());
    }
}
