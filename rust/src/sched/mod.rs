//! One unified work-stealing runtime: the single source of compute
//! threads in the crate.
//!
//! Kitsune's dataflow argument (§4 of the paper) is that spatial
//! execution wins by keeping every execution resource busy at once
//! instead of temporally multiplexing them per operator. Before this
//! module the host runtime fragmented the CPU exactly that way: the
//! interpreter spawned scoped threads per large GEMM, the session
//! pipeline kept dedicated per-stage worker threads, and the training
//! executor pinned one thread per DAG stage — idle cores in one layer
//! could not help another. `kitsune::sched` replaces all three thread
//! sources with one persistent pool:
//!
//! - per-worker deques with a shared injector: workers pop their own
//!   deque LIFO (cache-warm fork-join) and steal from the injector and
//!   other workers FIFO (fair pipeline pumps);
//! - idle workers park on a condvar (no spin-burn) and are woken by the
//!   first push;
//! - worker count defaults to the machine's available parallelism and
//!   can be overridden with `KITSUNE_WORKERS`;
//! - a scoped fork-join API ([`scope`]/[`join`]) lets panel-parallel
//!   GEMM borrow stack data without lifetime gymnastics, with a helping
//!   join (the waiting thread executes pool tasks) so scopes opened
//!   from pool workers cannot deadlock the pool.
//!
//! Stage pumps (see `session::service` and `train::exec`) run as
//! cooperative tasks on this pool: they never block a worker thread —
//! on an empty/full ring queue they register a waker with the queue and
//! return the worker to the pool.

use std::any::Any;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

/// A unit of work for the pool.
pub type Task = Box<dyn FnOnce() + Send + 'static>;

/// Hard cap on `KITSUNE_WORKERS` so a typo cannot fork-bomb the host.
pub const MAX_WORKERS: usize = 256;

/// The work-stealing scheduler. One global instance ([`Scheduler::global`])
/// backs all services by default; tests and benches can stand up private
/// pools with [`Scheduler::with_workers`] and route services onto them
/// with [`with_scheduler`].
pub struct Scheduler {
    /// Shared FIFO injector: external submissions and pump reschedules.
    /// FIFO here is a fairness requirement — cooperative pumps re-inject
    /// themselves, and LIFO would starve other pumps at 1 worker.
    injector: Mutex<VecDeque<Task>>,
    /// Per-worker deques: owner pops LIFO (back), thieves steal FIFO (front).
    locals: Vec<Mutex<VecDeque<Task>>>,
    idle_lock: Mutex<()>,
    idle_cv: Condvar,
    /// Workers currently inside the parking section.
    sleepers: AtomicUsize,
    /// Tasks pushed but not yet popped (incremented before push, so a
    /// parker that reads 0 after registering as a sleeper is guaranteed
    /// the producer's wake check will see it).
    pending: AtomicUsize,
    shutdown: AtomicBool,
    /// Tasks that panicked (scope tasks catch their own panics and
    /// re-raise at the join point instead; this counts detached tasks).
    panics: AtomicUsize,
    threads: Mutex<Vec<JoinHandle<()>>>,
    /// Per-worker busy/steal/park tallies (telemetry; relaxed counters).
    worker_stats: Vec<crate::telemetry::WorkerStats>,
}

struct WorkerCtx {
    sched: Arc<Scheduler>,
    /// `Some(i)` on pool worker `i`; `None` on an external thread that
    /// entered via [`with_scheduler`].
    index: Option<usize>,
}

thread_local! {
    static CURRENT: RefCell<Option<WorkerCtx>> = const { RefCell::new(None) };
}

static GLOBAL: OnceLock<Arc<Scheduler>> = OnceLock::new();

/// Worker tallies of the *global* pool for [`crate::telemetry::snapshot`]
/// — empty if the global pool has never been started (this never spawns
/// it).
pub fn worker_telemetry() -> Vec<crate::telemetry::WorkerSnapshot> {
    GLOBAL.get().map(|s| s.worker_telemetry()).unwrap_or_default()
}

/// Warn (once per variable, process-wide) that an environment override
/// could not be parsed, naming the bad value and the fallback in use.
/// Shared by `KITSUNE_WORKERS` here and the `KITSUNE_SERVE_*` knobs in
/// [`crate::serve`].
fn warn_bad_env_once(var: &str, raw: &str, fallback: usize) {
    warn_env_once(
        var,
        &format!(
            "kitsune: ignoring {var}={raw:?} (not a positive integer); \
             falling back to {fallback}"
        ),
    );
}

/// Emit `msg` to stderr at most once per process for `var` — the shared
/// warn-once policy behind every `KITSUNE_*` environment knob (worker
/// counts here, the serve knobs, and the `KITSUNE_FAULT` injection spec
/// in [`crate::fault`]).
pub fn warn_env_once(var: &str, msg: &str) {
    static WARNED: OnceLock<Mutex<Vec<String>>> = OnceLock::new();
    let mut warned = WARNED.get_or_init(|| Mutex::new(Vec::new())).lock().unwrap();
    if warned.iter().any(|v| v == var) {
        return;
    }
    warned.push(var.to_string());
    eprintln!("{msg}");
}

/// Resolve one `usize` environment override against its raw string
/// value: positive integers are clamped to `max`, anything else warns
/// once (naming the bad value and the fallback) and yields `fallback`.
/// Split out from [`env_usize`] so the parse/clamp/warn policy is unit
/// testable without mutating the process environment.
pub fn resolve_env_usize(var: &str, raw: &str, fallback: usize, max: usize) -> usize {
    match raw.trim().parse::<usize>() {
        Ok(n) if n >= 1 => n.min(max),
        _ => {
            warn_bad_env_once(var, raw, fallback);
            fallback
        }
    }
}

/// Read a `usize` knob from the environment: unset yields `fallback`,
/// set-but-unparseable warns once and yields `fallback`, valid values
/// clamp to `max`.
pub fn env_usize(var: &str, fallback: usize, max: usize) -> usize {
    match std::env::var(var) {
        Ok(raw) => resolve_env_usize(var, &raw, fallback, max),
        Err(_) => fallback,
    }
}

/// Resolve one on/off environment override against its raw string value.
/// Accepts `1`/`true`/`on`/`yes` and `0`/`false`/`off`/`no`
/// (case-insensitive, trimmed); anything else warns once (naming the
/// expected vocabulary and the fallback) and yields `fallback`. Split
/// out from [`env_switch`] for the same unit-testability reason as
/// [`resolve_env_usize`].
pub fn resolve_env_switch(var: &str, raw: &str, fallback: bool) -> bool {
    match raw.trim().to_ascii_lowercase().as_str() {
        "1" | "true" | "on" | "yes" => true,
        "0" | "false" | "off" | "no" => false,
        _ => {
            warn_env_once(var, &switch_warn_msg(var, raw, fallback));
            fallback
        }
    }
}

/// The exact warning line [`resolve_env_switch`] emits — split out so
/// the message contract (bad value named, expected vocabulary, fallback)
/// is unit testable without capturing stderr.
pub fn switch_warn_msg(var: &str, raw: &str, fallback: bool) -> String {
    format!(
        "kitsune: ignoring {var}={raw:?} (expected 0|1|true|false|on|off); \
         falling back to {fallback}"
    )
}

/// Read an on/off knob from the environment: unset yields `fallback`,
/// set-but-unparseable warns once and yields `fallback`.
pub fn env_switch(var: &str, fallback: bool) -> bool {
    match std::env::var(var) {
        Ok(raw) => resolve_env_switch(var, &raw, fallback),
        Err(_) => fallback,
    }
}

fn default_workers() -> usize {
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    env_usize("KITSUNE_WORKERS", host, MAX_WORKERS)
}

impl Scheduler {
    /// Stand up a private pool with exactly `n` workers (min 1).
    pub fn with_workers(n: usize) -> Arc<Scheduler> {
        let n = n.clamp(1, MAX_WORKERS);
        let sched = Arc::new(Scheduler {
            injector: Mutex::new(VecDeque::new()),
            locals: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            idle_lock: Mutex::new(()),
            idle_cv: Condvar::new(),
            sleepers: AtomicUsize::new(0),
            pending: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            panics: AtomicUsize::new(0),
            threads: Mutex::new(Vec::new()),
            worker_stats: (0..n).map(|_| crate::telemetry::WorkerStats::default()).collect(),
        });
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let s = Arc::clone(&sched);
            let h = std::thread::Builder::new()
                .name(format!("kitsune-sched-{i}"))
                .spawn(move || worker_loop(s, i))
                .expect("spawn kitsune-sched worker");
            handles.push(h);
        }
        *sched.threads.lock().unwrap() = handles;
        sched
    }

    /// The process-wide pool. Sized by `KITSUNE_WORKERS` if set, else the
    /// machine's available parallelism. Never shut down.
    pub fn global() -> Arc<Scheduler> {
        Arc::clone(GLOBAL.get_or_init(|| Scheduler::with_workers(default_workers())))
    }

    /// Per-worker busy/steal/park tallies for this pool.
    pub fn worker_telemetry(&self) -> Vec<crate::telemetry::WorkerSnapshot> {
        self.worker_stats.iter().enumerate().map(|(i, s)| s.snapshot(i)).collect()
    }

    /// Number of worker threads in this pool.
    pub fn workers(&self) -> usize {
        self.locals.len()
    }

    /// Detached tasks that panicked (scope-spawned tasks re-raise at the
    /// join point and are not counted here).
    pub fn panics(&self) -> usize {
        self.panics.load(Ordering::SeqCst)
    }

    /// Submit a detached task to the shared FIFO injector.
    pub fn spawn(&self, task: Task) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.injector.lock().unwrap().push_back(task);
        self.wake_one();
    }

    /// Push a scope task: LIFO onto the current worker's deque when the
    /// caller is a worker of this pool (cache-warm fork-join), else the
    /// injector.
    fn push_scoped(&self, task: Task) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        let local = CURRENT.with(|c| {
            c.borrow().as_ref().and_then(|ctx| {
                if std::ptr::eq(Arc::as_ptr(&ctx.sched), self) {
                    ctx.index
                } else {
                    None
                }
            })
        });
        match local {
            Some(i) => self.locals[i].lock().unwrap().push_back(task),
            None => self.injector.lock().unwrap().push_back(task),
        }
        self.wake_one();
    }

    fn wake_one(&self) {
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            let _g = self.idle_lock.lock().unwrap();
            self.idle_cv.notify_one();
        }
    }

    fn wake_all(&self) {
        let _g = self.idle_lock.lock().unwrap();
        self.idle_cv.notify_all();
    }

    /// Pop the next runnable task: own deque LIFO, then injector FIFO,
    /// then steal from other workers FIFO.
    fn find_task(&self, home: Option<usize>) -> Option<Task> {
        if let Some(h) = home {
            if let Some(t) = self.locals[h].lock().unwrap().pop_back() {
                self.pending.fetch_sub(1, Ordering::SeqCst);
                return Some(t);
            }
        }
        if let Some(t) = self.injector.lock().unwrap().pop_front() {
            self.pending.fetch_sub(1, Ordering::SeqCst);
            return Some(t);
        }
        let n = self.locals.len();
        let start = home.map_or(0, |h| h + 1);
        for off in 0..n {
            let i = (start + off) % n;
            if Some(i) == home {
                continue;
            }
            if let Some(t) = self.locals[i].lock().unwrap().pop_front() {
                self.pending.fetch_sub(1, Ordering::SeqCst);
                if let Some(h) = home {
                    self.worker_stats[h].steals.inc();
                }
                return Some(t);
            }
        }
        None
    }

    fn run_task(&self, task: Task) {
        if catch_unwind(AssertUnwindSafe(task)).is_err() {
            self.panics.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Stop the pool and join its threads. Only meaningful for private
    /// pools; must be called from a thread outside the pool. Remaining
    /// queued tasks are drained before the workers exit.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.wake_all();
        let handles = std::mem::take(&mut *self.threads.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

fn worker_loop(sched: Arc<Scheduler>, index: usize) {
    CURRENT.with(|c| {
        *c.borrow_mut() = Some(WorkerCtx { sched: Arc::clone(&sched), index: Some(index) });
    });
    let mut idle = 0u32;
    loop {
        if let Some(task) = sched.find_task(Some(index)) {
            idle = 0;
            let stats = &sched.worker_stats[index];
            stats.tasks.inc();
            let t0 = std::time::Instant::now();
            sched.run_task(task);
            stats.busy_ns.add(t0.elapsed().as_nanos().min(u128::from(u64::MAX)) as u64);
            continue;
        }
        if sched.shutdown.load(Ordering::SeqCst) {
            return;
        }
        idle += 1;
        if idle <= 16 {
            std::hint::spin_loop();
        } else if idle <= 64 {
            std::thread::yield_now();
        } else {
            // Park. The sleeper count is incremented *before* re-checking
            // `pending` under the idle lock; a producer increments
            // `pending` before its wake check reads `sleepers`, so in the
            // SeqCst total order at least one side sees the other — no
            // lost wakeup. The timeout is a pure backstop.
            let guard = sched.idle_lock.lock().unwrap();
            sched.sleepers.fetch_add(1, Ordering::SeqCst);
            if sched.pending.load(Ordering::SeqCst) == 0
                && !sched.shutdown.load(Ordering::SeqCst)
            {
                sched.worker_stats[index].parks.inc();
                let _ = sched.idle_cv.wait_timeout(guard, Duration::from_millis(10)).unwrap();
            }
            sched.sleepers.fetch_sub(1, Ordering::SeqCst);
            idle = 17; // back to the yield tier after waking
        }
    }
}

/// The scheduler the current thread is bound to: the pool this worker
/// belongs to, the pool installed by an enclosing [`with_scheduler`], or
/// the global pool.
pub fn current() -> Arc<Scheduler> {
    CURRENT
        .with(|c| c.borrow().as_ref().map(|ctx| Arc::clone(&ctx.sched)))
        .unwrap_or_else(Scheduler::global)
}

/// Run `f` with `sched` installed as the current thread's scheduler, so
/// services started inside (and [`scope`]/[`join`] calls) use it instead
/// of the global pool. Restores the previous binding on exit, including
/// on panic.
pub fn with_scheduler<R>(sched: &Arc<Scheduler>, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<WorkerCtx>);
    impl Drop for Restore {
        fn drop(&mut self) {
            let prev = self.0.take();
            let _ = CURRENT.try_with(|c| *c.borrow_mut() = prev);
        }
    }
    let prev = CURRENT.with(|c| {
        c.borrow_mut().replace(WorkerCtx { sched: Arc::clone(sched), index: None })
    });
    let _restore = Restore(prev);
    f()
}

struct ScopeLatch {
    remaining: AtomicUsize,
    /// First panic among the scope's tasks, tagged with the task's label
    /// so the re-raise names *which* fork-join branch died.
    panic: Mutex<Option<(String, Box<dyn Any + Send>)>>,
}

/// A fork-join scope over the pool: tasks spawned on it may borrow from
/// the enclosing stack frame (`'env`), and [`scope`] does not return
/// until every spawned task has finished.
pub struct Scope<'env> {
    sched: Arc<Scheduler>,
    latch: Arc<ScopeLatch>,
    /// Counter behind the default `task #N` labels of [`Scope::spawn`].
    next_task: AtomicUsize,
    /// Invariant over `'env`, like `std::thread::Scope`.
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'env> Scope<'env> {
    /// Spawn a task that may borrow from the scope's environment. Panics
    /// inside the task are captured and re-raised from [`scope`], labeled
    /// `task #N` in spawn order; use [`Scope::spawn_labeled`] to name the
    /// task something meaningful (e.g. which GEMM panel it computes).
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        let n = self.next_task.fetch_add(1, Ordering::Relaxed);
        self.spawn_labeled(format!("task #{n}"), f);
    }

    /// [`Scope::spawn`] with an explicit label, reported if the task
    /// panics (aligned with [`crate::fault::StageFailure`] semantics: a
    /// failure names the unit of work that died, not just the payload).
    pub fn spawn_labeled<F>(&self, label: impl Into<String>, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        let label = label.into();
        self.latch.remaining.fetch_add(1, Ordering::SeqCst);
        let latch = Arc::clone(&self.latch);
        let task: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            if let Err(p) = catch_unwind(AssertUnwindSafe(f)) {
                let mut slot = latch.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some((label, p));
                }
            }
            latch.remaining.fetch_sub(1, Ordering::SeqCst);
        });
        // SAFETY: `scope` joins every spawned task before returning (even
        // when the body or a task panics), so borrows of `'env` captured
        // by the task never outlive the frame they point into. This is
        // the same lifetime erasure `std::thread::scope` performs
        // internally.
        let task: Task = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Task>(task)
        };
        self.sched.push_scoped(task);
    }
}

/// Run a fork-join scope on the current scheduler (see [`current`]).
///
/// The calling thread *helps* while joining: it executes pool tasks
/// until all scope tasks have completed, so scopes opened from pool
/// workers (nested parallelism) cannot deadlock the pool, and external
/// callers contribute a core instead of blocking.
pub fn scope<'env, F, R>(f: F) -> R
where
    F: FnOnce(&Scope<'env>) -> R,
{
    scope_on(&current(), f)
}

/// [`scope`] on an explicit pool.
pub fn scope_on<'env, F, R>(sched: &Arc<Scheduler>, f: F) -> R
where
    F: FnOnce(&Scope<'env>) -> R,
{
    let latch = Arc::new(ScopeLatch {
        remaining: AtomicUsize::new(0),
        panic: Mutex::new(None),
    });
    let s = Scope {
        sched: Arc::clone(sched),
        latch: Arc::clone(&latch),
        next_task: AtomicUsize::new(0),
        _env: PhantomData,
    };
    let result = catch_unwind(AssertUnwindSafe(|| f(&s)));
    drop(s);
    // Helping join: run pool tasks while our scope tasks are in flight.
    let home = CURRENT.with(|c| {
        c.borrow().as_ref().and_then(|ctx| {
            if std::ptr::eq(Arc::as_ptr(&ctx.sched), Arc::as_ptr(sched)) {
                ctx.index
            } else {
                None
            }
        })
    });
    let mut idle = 0u32;
    while latch.remaining.load(Ordering::SeqCst) != 0 {
        if let Some(task) = sched.find_task(home) {
            idle = 0;
            sched.run_task(task);
        } else {
            idle += 1;
            if idle <= 32 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
    }
    let task_panic = latch.panic.lock().unwrap().take();
    match result {
        Err(p) => resume_unwind(p),
        Ok(r) => {
            if let Some((label, payload)) = task_panic {
                // Re-raise with the dying task's label and original
                // message as the payload, so callers (and the session
                // pumps' `catch_stage` fences above us) see *which*
                // fork-join branch died, not a bare payload.
                let msg = crate::fault::panic_message(payload.as_ref());
                panic!("sched::scope: {label} panicked: {msg}");
            }
            r
        }
    }
}

/// Run two closures, potentially in parallel, returning both results.
/// `a` may run on another worker; `b` runs on the calling thread.
pub fn join<A, B, RA, RB>(a: A, b: B) -> (RA, RB)
where
    A: FnOnce() -> RA + Send,
    B: FnOnce() -> RB,
    RA: Send,
{
    let mut ra: Option<RA> = None;
    let rb = scope(|s| {
        s.spawn(|| ra = Some(a()));
        b()
    });
    (ra.expect("sched::join: spawned closure joined"), rb)
}

/// Countdown used by services to drain their pool tasks at shutdown:
/// each pump calls [`LiveCount::done`] exactly once when it retires, and
/// `shutdown`/`Drop` block in [`LiveCount::wait_zero`] until no task
/// still references the service's stage state.
pub struct LiveCount {
    n: Mutex<usize>,
    cv: Condvar,
}

impl LiveCount {
    pub fn new(n: usize) -> Arc<LiveCount> {
        Arc::new(LiveCount { n: Mutex::new(n), cv: Condvar::new() })
    }

    /// Retire one participant.
    pub fn done(&self) {
        let mut g = self.n.lock().unwrap();
        *g = g.saturating_sub(1);
        if *g == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every participant has retired.
    pub fn wait_zero(&self) {
        let mut g = self.n.lock().unwrap();
        while *g != 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// An OS-thread scope for the deprecated dedicated-thread paths (the
/// legacy per-call `coordinator::runner`) and for test harnesses: same
/// API as `std::thread::scope`, routed through this module so every
/// thread the crate creates is accounted for in one place.
pub fn dedicated_scope<'env, F, R>(f: F) -> R
where
    F: for<'scope> FnOnce(&'scope std::thread::Scope<'scope, 'env>) -> R,
{
    std::thread::scope(f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn scope_runs_all_tasks_and_allows_borrows() {
        let sched = Scheduler::with_workers(2);
        let mut results = vec![0u64; 64];
        scope_on(&sched, |s| {
            for (i, slot) in results.iter_mut().enumerate() {
                s.spawn(move || *slot = (i as u64) * 3);
            }
        });
        for (i, v) in results.iter().enumerate() {
            assert_eq!(*v, (i as u64) * 3);
        }
        sched.shutdown();
    }

    #[test]
    fn join_returns_both_results() {
        let (a, b) = join(|| 2 + 2, || "ok");
        assert_eq!(a, 4);
        assert_eq!(b, "ok");
    }

    #[test]
    fn detached_spawn_executes() {
        let sched = Scheduler::with_workers(1);
        let hits = Arc::new(AtomicU64::new(0));
        for _ in 0..32 {
            let hits = Arc::clone(&hits);
            sched.spawn(Box::new(move || {
                hits.fetch_add(1, Ordering::SeqCst);
            }));
        }
        let t0 = std::time::Instant::now();
        while hits.load(Ordering::SeqCst) != 32 {
            assert!(t0.elapsed() < Duration::from_secs(10), "detached tasks stalled");
            std::thread::yield_now();
        }
        sched.shutdown();
    }

    #[test]
    fn with_scheduler_binds_current() {
        let sched = Scheduler::with_workers(1);
        with_scheduler(&sched, || {
            assert!(Arc::ptr_eq(&current(), &sched));
        });
        sched.shutdown();
    }

    #[test]
    fn env_override_clamps_to_max_workers() {
        // A huge-but-valid KITSUNE_WORKERS clamps instead of fork-bombing.
        assert_eq!(resolve_env_usize("KITSUNE_WORKERS", "99999", 4, MAX_WORKERS), MAX_WORKERS);
        // In-range values pass through (whitespace tolerated).
        assert_eq!(resolve_env_usize("KITSUNE_WORKERS", " 8 ", 4, MAX_WORKERS), 8);
        // Unparseable and zero values warn (once) and fall back.
        assert_eq!(resolve_env_usize("KITSUNE_WORKERS", "banana", 4, MAX_WORKERS), 4);
        assert_eq!(resolve_env_usize("KITSUNE_WORKERS", "0", 4, MAX_WORKERS), 4);
        assert_eq!(resolve_env_usize("KITSUNE_SERVE_QUEUE_DEPTH", "-3", 256, 1 << 20), 256);
    }

    #[test]
    fn env_switch_vocabulary_and_warn_message() {
        for raw in ["1", "true", "ON", " yes "] {
            assert!(resolve_env_switch("KITSUNE_SIMD", raw, false), "{raw:?}");
        }
        for raw in ["0", "false", "Off", "no"] {
            assert!(!resolve_env_switch("KITSUNE_SIMD", raw, true), "{raw:?}");
        }
        // Unrecognized values warn (once) and fall back — both ways.
        assert!(resolve_env_switch("KITSUNE_SIMD_TEST_A", "fast", true));
        assert!(!resolve_env_switch("KITSUNE_SIMD_TEST_B", "2", false));
        // The message names the variable, the bad value, the expected
        // vocabulary, and the fallback actually in use.
        let msg = switch_warn_msg("KITSUNE_SIMD", "fast", true);
        assert!(msg.contains("KITSUNE_SIMD=\"fast\""), "{msg}");
        assert!(msg.contains("0|1|true|false|on|off"), "{msg}");
        assert!(msg.contains("falling back to true"), "{msg}");
    }

    #[test]
    fn live_count_waits_for_all() {
        let live = LiveCount::new(3);
        let sched = Scheduler::with_workers(2);
        for _ in 0..3 {
            let live = Arc::clone(&live);
            sched.spawn(Box::new(move || live.done()));
        }
        live.wait_zero();
        sched.shutdown();
    }
}
