//! Operator → kernel lowering: how each graph node executes on the GPU.
//!
//! This is the simulator-facing equivalent of the paper's "dictionary of
//! kernel characteristics" (§5.3): for every op we derive grid size,
//! per-CTA work streams, shared-memory footprint, and the issue-pipe
//! utilization `u` that feeds `Speedup(a_i) = 1/u` in the load-balancing
//! ILP.

use crate::graph::{Graph, Node, OpKind, ResourceClass};
use crate::sim::{GpuConfig, KernelDesc};

/// GEMM output tile edge (CUTLASS-style 128×128 CTA tiles).
pub const GEMM_TILE: usize = 128;
/// Elements of output processed per elementwise/SIMT CTA.
pub const SIMT_ELEMS_PER_CTA: usize = 256 * 1024;
/// Outputs per CTA for reductions (few CTAs — the paper's Fig 2(b)
/// "a small number of CTAs end up performing a reduction").
pub const REDUCE_OUTS_PER_CTA: usize = 4096;
/// Cap on simulated CTAs per kernel: work is merged beyond this to bound
/// event count; totals are conserved by [`KernelDesc::with_ctas`].
pub const MAX_SIM_CTAS: usize = 1024;

/// Physical location an operand moves through.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Loc {
    /// Round-trips main memory (BSP default, or a vertical-fusion spill).
    Dram,
    /// Passes through an L2-resident Kitsune queue.
    L2Queue,
    /// Stays in shared memory / registers (vertical-fusion tile residency).
    Smem,
}

/// Where each operand/result physically moves.
#[derive(Debug, Clone)]
pub struct IoPlacement {
    /// Per input.
    pub ins: Vec<Loc>,
    /// Result placement.
    pub out: Loc,
}

impl IoPlacement {
    /// Bulk-synchronous default: everything round-trips DRAM.
    pub fn bsp(n_inputs: usize) -> Self {
        IoPlacement { ins: vec![Loc::Dram; n_inputs], out: Loc::Dram }
    }
}

/// Number of CTAs an op naturally launches.
pub fn natural_ctas(node: &Node) -> usize {
    match &node.op {
        OpKind::Matmul { b, m, n, .. } => {
            let tiles = b * m.div_ceil(GEMM_TILE) * n.div_ceil(GEMM_TILE);
            tiles.max(1)
        }
        OpKind::Interaction { .. } => {
            let batch = node.out.shape.leading();
            batch.div_ceil(GEMM_TILE).max(1)
        }
        // Reductions: PyTorch's two-pass tree gives limited parallelism
        // (bounded fan-in per pass), far below the batch dimension's —
        // the paper's Fig 2(b) starvation, softened to be fair to BSP.
        OpKind::Reduce { factor, .. } => {
            let out_ctas = node.out.numel().div_ceil(REDUCE_OUTS_PER_CTA);
            (out_ctas * (*factor).min(8)).max(1)
        }
        OpKind::Loss | OpKind::OptimizerUpdate => {
            node.out.numel().div_ceil(SIMT_ELEMS_PER_CTA).max(1)
        }
        _ => node.out.numel().div_ceil(SIMT_ELEMS_PER_CTA).max(1),
    }
}

/// Shared-memory footprint per CTA.
pub fn smem_per_cta(node: &Node) -> usize {
    match &node.op {
        // Double-buffered A/B input tiles (bf16): 2 × 2 × 128×128×2B = 128KB
        // is the asymptote; small GEMMs take less.
        OpKind::Matmul { m, n, k, .. } => {
            let tm = (*m).min(GEMM_TILE);
            let tn = (*n).min(GEMM_TILE);
            let tk = (*k).min(64);
            (2 * (tm * tk + tk * tn) * 2).min(160 * 1024)
        }
        OpKind::Interaction { features, dim } => (features * dim * 2).min(96 * 1024),
        OpKind::Softmax | OpKind::LayerNorm => 16 * 1024,
        OpKind::Reduce { .. } => 8 * 1024,
        _ => 4 * 1024,
    }
}

/// Issue-pipe utilization `u`: the fraction of its primary pipe's issue
/// bandwidth the kernel sustains *while running* (tile quantization and
/// occupancy effects — memory boundedness is modeled separately by the
/// simulator's bandwidth pools, so it must NOT be folded in here).
pub fn pipe_utilization(node: &Node) -> f64 {
    match &node.op {
        OpKind::Matmul { m, n, k, .. } => {
            // Tile-quantization efficiency in each dimension, times the
            // ~85% practical ceiling of real GEMM kernels.
            let em = *m as f64 / (m.div_ceil(GEMM_TILE) * GEMM_TILE) as f64;
            let en = *n as f64 / (n.div_ceil(GEMM_TILE) * GEMM_TILE) as f64;
            let ek = (*k as f64 / 32.0).min(1.0);
            (0.85 * em * en * ek).clamp(0.02, 1.0)
        }
        OpKind::Interaction { .. } => 0.5,
        // SIMT ops sustain most of the vector pipe when not memory bound.
        OpKind::Elementwise(_) | OpKind::Concat { .. } => 0.9,
        OpKind::Softmax | OpKind::LayerNorm => 0.7,
        OpKind::Reduce { .. } => 0.8,
        OpKind::Gather { .. } | OpKind::Scatter => 0.3,
        OpKind::Loss | OpKind::OptimizerUpdate => 0.8,
        OpKind::Input | OpKind::Param | OpKind::Queue { .. } => 1.0,
    }
}

/// L2 reuse multiplier: bytes served from L2 per DRAM byte (tile re-reads
/// of GEMM panels, two-pass normalizations).
fn l2_reuse(node: &Node) -> f64 {
    match &node.op {
        OpKind::Matmul { .. } | OpKind::Interaction { .. } => 3.0,
        OpKind::Softmax | OpKind::LayerNorm => 2.0,
        _ => 1.0,
    }
}

/// DRAM/L2 byte traffic for a node under an I/O placement.
/// Returns `(dram_bytes, l2_bytes)`.
pub fn traffic(node: &Node, graph: &Graph, io: &IoPlacement) -> (f64, f64) {
    let mut dram = 0.0;
    let mut l2 = 0.0;
    for (i, &inp) in node.inputs.iter().enumerate() {
        let full = graph.node(inp).out.bytes() as f64;
        let bytes = match (&node.op, i) {
            // Embedding gather touches only the looked-up rows, not the
            // whole table.
            (OpKind::Gather { .. }, 1) => full.min(node.out.bytes() as f64),
            // Sparse optimizer step (embedding tables): reads only the
            // rows the gradient touches.
            (OpKind::OptimizerUpdate, 0) => {
                let grad = node
                    .inputs
                    .get(1)
                    .map(|g2| graph.node(*g2).out.bytes() as f64)
                    .unwrap_or(full);
                full.min(grad)
            }
            _ => full,
        };
        match io.ins.get(i).copied().unwrap_or(Loc::Dram) {
            Loc::Dram => dram += bytes,
            // Queue hop: producer wrote it to L2; we read it from L2.
            Loc::L2Queue => l2 += bytes,
            Loc::Smem => {}
        }
    }
    let out_bytes = match &node.op {
        // Scatter-add (embedding backward / GNN aggregation) writes only
        // the rows its input touches.
        OpKind::Scatter => {
            let inp = node
                .inputs
                .first()
                .map(|i| graph.node(*i).out.bytes() as f64)
                .unwrap_or(node.out.bytes() as f64);
            (node.out.bytes() as f64).min(inp)
        }
        OpKind::OptimizerUpdate => {
            let grad = node
                .inputs
                .get(1)
                .map(|g2| graph.node(*g2).out.bytes() as f64)
                .unwrap_or(node.out.bytes() as f64);
            (node.out.bytes() as f64).min(grad)
        }
        _ => node.out.bytes() as f64,
    };
    match io.out {
        Loc::Dram => dram += out_bytes,
        Loc::L2Queue => l2 += out_bytes,
        Loc::Smem => {}
    }
    // Reuse traffic inside the op (panel re-reads etc.) hits L2.
    l2 += dram * (l2_reuse(node) - 1.0);
    (dram, l2)
}

/// Lower a node to a BSP kernel description (everything via DRAM).
pub fn bsp_kernel(node: &Node, graph: &Graph, cfg: &GpuConfig) -> KernelDesc {
    kernel_with_io(node, graph, cfg, &IoPlacement::bsp(node.inputs.len()))
}

/// Lower a node to a kernel description under an explicit I/O placement
/// (the dataflow executor routes intermediates through queues).
pub fn kernel_with_io(
    node: &Node,
    graph: &Graph,
    _cfg: &GpuConfig,
    io: &IoPlacement,
) -> KernelDesc {
    let (dram, l2) = traffic(node, graph, io);
    let n = natural_ctas(node);
    let k = KernelDesc {
        name: node.name.clone(),
        class: node.resource_class(),
        n_ctas: n,
        flops_per_cta: node.flops() / n as f64,
        dram_bytes_per_cta: dram / n as f64,
        l2_bytes_per_cta: l2 / n as f64,
        smem_per_cta: smem_per_cta(node),
        pipe_utilization: pipe_utilization(node),
    };
    if n > MAX_SIM_CTAS {
        k.with_ctas(MAX_SIM_CTAS)
    } else {
        k
    }
}

/// The paper's measured BSP throughput `t_i`, here analytic: work items
/// per second when the op runs alone on the machine (roofline over its
/// limiting resource). Used by the ILP (§5.3).
pub fn bsp_throughput(node: &Node, graph: &Graph, cfg: &GpuConfig) -> f64 {
    let io = IoPlacement::bsp(node.inputs.len());
    let (dram, l2) = traffic(node, graph, &io);
    let flops = node.flops();
    let pipe = match node.resource_class() {
        ResourceClass::Tensor => cfg.tensor_flops,
        ResourceClass::Simt => cfg.simt_flops,
    };
    // Parallelism-limited pipe fraction: a reduction with 1 CTA can only
    // use 1/108th of the machine's SIMT pipe (Fig 2(b)).
    let n = natural_ctas(node);
    let par_frac = ((n as f64) / cfg.sm_count as f64).min(1.0);
    let u = pipe_utilization(node);
    let t_compute = flops / (pipe * par_frac * u).max(1.0);
    let t_dram = dram / cfg.dram_bw;
    let t_l2 = l2 / cfg.l2_bw;
    let t = t_compute.max(t_dram).max(t_l2).max(1e-12);
    1.0 / t
}

/// Whether an op's on-chip working set per batch-tile exceeds the shared
/// memory budget — the paper's Fig 2(a) vertical-fusion spill criterion
/// (e.g. MLP hidden dim ≥ 768 on A100's 192 KB scratchpad).
pub fn vf_tile_spills(hidden_dim: usize, dtype_bytes: usize, cfg: &GpuConfig) -> bool {
    // Per-CTA tile: GEMM_TILE rows of the full hidden dimension, double
    // buffered, both the pre- and post-activation tile live on chip.
    let tile_bytes = 2 * GEMM_TILE * hidden_dim * dtype_bytes;
    tile_bytes > cfg.smem_per_sm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, GraphKind};

    fn mk() -> (Graph, GpuConfig) {
        let mut b = GraphBuilder::new("t", GraphKind::Inference);
        let x = b.input(&[2048, 1024], "x");
        let h = b.linear(x, 4096, false, "up");
        let a = b.relu(h, "act");
        let _ = b.linear(a, 1024, false, "down");
        (b.finish(), GpuConfig::a100())
    }

    #[test]
    fn gemm_ctas_are_output_tiles() {
        let (g, _) = mk();
        let up = g.nodes().iter().find(|n| n.name == "up").unwrap();
        // 2048/128 * 4096/128 = 16 * 32 = 512 tiles
        assert_eq!(natural_ctas(up), 512);
    }

    #[test]
    fn bsp_traffic_counts_all_operands() {
        let (g, cfg) = mk();
        let up = g.nodes().iter().find(|n| n.name == "up").unwrap();
        let k = bsp_kernel(up, &g, &cfg);
        let want = (2048 * 1024 + 1024 * 4096 + 2048 * 4096) as f64 * 2.0;
        assert!((k.total_dram_bytes() - want).abs() < 1.0, "{}", k.total_dram_bytes());
    }

    #[test]
    fn queue_io_moves_traffic_to_l2() {
        let (g, cfg) = mk();
        let act = g.nodes().iter().find(|n| n.name == "act").unwrap();
        let bsp = bsp_kernel(act, &g, &cfg);
        let io = IoPlacement { ins: vec![Loc::L2Queue], out: Loc::L2Queue };
        let df = kernel_with_io(act, &g, &cfg, &io);
        assert!(df.total_dram_bytes() < 1.0, "{}", df.total_dram_bytes());
        assert!(df.total_l2_bytes() > bsp.total_l2_bytes());
        // Work conserved.
        assert!((df.total_flops() - bsp.total_flops()).abs() < 1e-6);
    }

    #[test]
    fn reduce_has_few_ctas() {
        use crate::graph::{EwKind, OpKind, ReduceAxis, TensorDesc};
        let mut b = GraphBuilder::new("r", GraphKind::Inference);
        let x = b.input(&[8192, 768], "x");
        let r = b.reduce(x, ReduceAxis::Batch, 8192, &[768], "bias_grad");
        let g = b.finish();
        let node = g.node(r);
        // Limited two-pass parallelism only — far below the 8192-deep
        // batch dimension (Fig 2(b) starvation, softened for BSP).
        assert!(natural_ctas(node) <= 8, "batch reduce is parallelism-starved");
        let _ = (OpKind::Elementwise(EwKind::Relu), TensorDesc::bf16(&[1]));
    }

    #[test]
    fn gemm_utilization_degrades_for_skinny_shapes() {
        let (g, _) = mk();
        let up = g.nodes().iter().find(|n| n.name == "up").unwrap();
        let fat = pipe_utilization(up);
        let mut b = GraphBuilder::new("s", GraphKind::Inference);
        let x = b.input(&[1, 1024], "x"); // batch-1 decode-style GEMM
        let y = b.linear(x, 4096, false, "skinny");
        let g2 = b.finish();
        let skinny = pipe_utilization(g2.node(y));
        let _ = y;
        assert!(skinny < fat * 0.05, "skinny {skinny} vs fat {fat}");
    }

    #[test]
    fn spill_criterion_matches_paper_768() {
        let cfg = GpuConfig::a100();
        // Paper §3: "MLP with N >= 768 on an A100 with 192 KB" spills (fp32).
        assert!(vf_tile_spills(768, 4, &cfg));
        assert!(!vf_tile_spills(256, 2, &cfg));
    }

    #[test]
    fn bsp_throughput_prefers_parallel_ops() {
        let (g, cfg) = mk();
        let up = g.nodes().iter().find(|n| n.name == "up").unwrap();
        let t_gemm = bsp_throughput(up, &g, &cfg);
        assert!(t_gemm > 0.0);
        // The skinny reduce from `reduce_has_few_ctas` is far slower per
        // unit work; just sanity-check finiteness here.
        assert!(t_gemm.is_finite());
    }
}
