//! Per-operator performance model: the "dictionary of kernel
//! characteristics" the paper's §5.3 deployment discussion calls for —
//! grid sizes, work streams, shared-memory footprints, issue utilization
//! `u`, and analytic BSP throughput `t_i` for the load-balancing ILP.

pub mod optable;

pub use optable::{
    bsp_kernel, bsp_throughput, kernel_with_io, natural_ctas, pipe_utilization, smem_per_cta,
    traffic, vf_tile_spills, IoPlacement, Loc, GEMM_TILE, MAX_SIM_CTAS,
};
