//! The `cudaPipeline` analog (paper Fig 6): declare a collection of
//! stage kernels that must be co-resident, each tagged with the dynamic
//! resource class it needs, connected by ring queues.
//!
//! On the GPU the stages are CUDA kernels and the queues live in L2; in
//! this host-level realization the stages are AOT-compiled XLA
//! executables (see `python/compile/aot.py`) and the queues are the
//! lock-free rings of [`crate::queue::host`] — the same acquire/release
//! protocol, same execution model: a stage runs when data is available
//! in its input queue and stalls when its output queue is full.

use crate::graph::ResourceClass;
use crate::runtime::Tensor;
use std::sync::Arc;

/// One pipeline stage: an artifact entry plus bound weights.
#[derive(Debug, Clone)]
pub struct StageSpec {
    pub name: String,
    /// Artifact entry name (manifest.txt).
    pub entry: String,
    /// Fig 6's kernel-header resource tag (SIMT / TENSOR).
    pub class: ResourceClass,
    /// Trailing executable arguments (weights), bound at configure time.
    /// `Arc`-shared: stage workers borrow them per tile and cloning a
    /// `StageSpec` (or spawning another worker) never copies tensor data.
    pub weights: Arc<Vec<Tensor>>,
    /// Worker threads for this stage — the host analog of the ILP's
    /// per-stage CTA allocation `a_i`.
    pub workers: usize,
}

/// One explicit queue edge of a DAG-shaped pipeline (paper Fig 2(b)/(c):
/// multicast fan-out and skip links). `from`/`to` of `None` denote the
/// pipeline source / sink; ports index a stage's streamed outputs /
/// inputs (a stage kernel may consume and produce several streams).
///
/// Several edges sharing the same `(from, from_port)` are a **multicast**
/// — the producer's tile is delivered to every consumer queue. An edge
/// whose `to` stage is more than one position downstream of `from` is a
/// **skip link** — a saved forward activation bypassing intermediate
/// stages to its backward consumer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PipeEdge {
    /// Producing stage index; `None` = the pipeline source.
    pub from: Option<usize>,
    /// Producer output port (source port index when `from` is `None`).
    pub from_port: usize,
    /// Consuming stage index; `None` = the pipeline sink.
    pub to: Option<usize>,
    /// Consumer input port (sink tap index when `to` is `None`).
    pub to_port: usize,
    /// Ring entries for this edge; skip links get deeper rings so the
    /// bypassed stages' in-flight window never wedges the producer.
    pub capacity: usize,
}

impl PipeEdge {
    /// Stages this edge spans (1 = adjacent; >1 = skip link). Source and
    /// sink endpoints count as one position outside the stage range.
    pub fn span(&self, n_stages: usize) -> usize {
        let from = self.from.map(|s| s as isize).unwrap_or(-1);
        let to = self.to.map(|s| s as isize).unwrap_or(n_stages as isize);
        (to - from).max(1) as usize
    }
}

/// A declared spatial pipeline: a linear chain of stages when `edges` is
/// empty (the classic Fig 6 shape every queue connects stage i to i+1),
/// or an explicit DAG of queue [`PipeEdge`]s — the shape backward graphs
/// lower to (multicast fan-out, skip links). The linear runners
/// ([`crate::coordinator::run_streaming`] / [`crate::session::PipelineService`])
/// execute only the former; DAG pipelines run on [`crate::train`]'s
/// executor.
#[derive(Debug, Clone)]
pub struct SpatialPipeline {
    pub name: String,
    pub stages: Vec<StageSpec>,
    /// Ring-queue capacity between adjacent stages (entries; 2 =
    /// double-buffering, as in paper Fig 4).
    pub queue_capacity: usize,
    /// Explicit DAG queue edges; empty = implicit linear chain.
    pub edges: Vec<PipeEdge>,
}

/// Builder mirroring the Fig 6 host-code flow:
/// `cudaPipelineCreate` → `cudaPipelineAddKernel` → launch.
pub struct PipelineBuilder {
    pipeline: SpatialPipeline,
}

impl SpatialPipeline {
    pub fn builder(name: impl Into<String>) -> PipelineBuilder {
        PipelineBuilder {
            pipeline: SpatialPipeline {
                name: name.into(),
                stages: Vec::new(),
                queue_capacity: 8,
                edges: Vec::new(),
            },
        }
    }
}

impl PipelineBuilder {
    /// `cudaPipelineAddKernel(pipe, kernel, type, ...)`.
    pub fn add_stage(
        mut self,
        name: impl Into<String>,
        entry: impl Into<String>,
        class: ResourceClass,
        weights: Vec<Tensor>,
    ) -> Self {
        self.pipeline.stages.push(StageSpec {
            name: name.into(),
            entry: entry.into(),
            class,
            weights: Arc::new(weights),
            workers: 1,
        });
        self
    }

    /// Set the worker count (`a_i`) of the most recently added stage.
    ///
    /// # Panics
    /// Panics when called before any [`Self::add_stage`] — there is no
    /// stage to configure, and silently dropping the setting (the old
    /// behavior) hid real mis-use.
    pub fn workers(mut self, n: usize) -> Self {
        let stage = self
            .pipeline
            .stages
            .last_mut()
            .expect("PipelineBuilder::workers called before add_stage — add a stage first");
        stage.workers = n.max(1);
        self
    }

    /// Ring-queue capacity between adjacent stages (pipeline-wide).
    ///
    /// # Panics
    /// Panics when called before any [`Self::add_stage`], to keep the
    /// builder's call order unambiguous (matching [`Self::workers`]).
    pub fn queue_capacity(mut self, entries: usize) -> Self {
        assert!(
            !self.pipeline.stages.is_empty(),
            "PipelineBuilder::queue_capacity called before add_stage — add a stage first"
        );
        self.pipeline.queue_capacity = entries.max(2);
        self
    }

    pub fn build(self) -> SpatialPipeline {
        assert!(
            !self.pipeline.stages.is_empty(),
            "pipeline needs at least one stage"
        );
        self.pipeline
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains() {
        let p = SpatialPipeline::builder("demo")
            .add_stage("a", "stage_trunk0", ResourceClass::Tensor, vec![])
            .workers(2)
            .add_stage("b", "stage_head", ResourceClass::Simt, vec![])
            .queue_capacity(4)
            .build();
        assert_eq!(p.stages.len(), 2);
        assert_eq!(p.stages[0].workers, 2);
        assert_eq!(p.stages[1].workers, 1);
        assert_eq!(p.queue_capacity, 4);
    }

    #[test]
    fn pipe_edge_span_counts_skipped_stages() {
        let mk = |from, to| PipeEdge { from, from_port: 0, to, to_port: 0, capacity: 8 };
        assert_eq!(mk(Some(0), Some(1)).span(5), 1, "adjacent");
        assert_eq!(mk(Some(0), Some(3)).span(5), 3, "skip link");
        assert_eq!(mk(None, Some(0)).span(5), 1, "source edge");
        assert_eq!(mk(Some(4), None).span(5), 1, "sink edge");
        assert_eq!(mk(None, None).span(5), 6, "source-to-sink bypass");
    }

    #[test]
    #[should_panic(expected = "at least one stage")]
    fn empty_pipeline_panics() {
        let _ = SpatialPipeline::builder("x").build();
    }

    #[test]
    #[should_panic(expected = "workers called before add_stage")]
    fn workers_before_any_stage_panics() {
        let _ = SpatialPipeline::builder("x").workers(4);
    }

    #[test]
    #[should_panic(expected = "queue_capacity called before add_stage")]
    fn queue_capacity_before_any_stage_panics() {
        let _ = SpatialPipeline::builder("x").queue_capacity(4);
    }
}
