//! L3 coordinator: the real spatial-pipeline runtime.
//!
//! The simulator (`crate::sim`) answers the paper's *timing* questions;
//! this module demonstrates the *execution model* for real — AOT-compiled
//! XLA stage kernels co-resident as threads, communicating tiles through
//! the §4.1 acquire/release ring queues with backpressure, tagged with
//! the §4.2 SIMT/TENSOR resource classes.

pub mod cli;
pub mod pipeline;
pub mod runner;

pub use pipeline::{PipeEdge, PipelineBuilder, SpatialPipeline, StageSpec};
pub use runner::{run_serial, run_streaming, PipelineRun, StageMetrics};
