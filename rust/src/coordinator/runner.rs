//! Streaming pipeline runner: one thread pool per stage, stages linked by
//! the §4.1 ring queues, executing real AOT-compiled XLA stage kernels.
//!
//! **Deprecation path:** [`run_streaming`] spawns and joins a fresh
//! thread scope per call, so there is no warm serving — prefer
//! [`crate::session::Session`], which stands the same stage pools up
//! once at build and accepts concurrent batch submissions. This function
//! remains as the one-shot/batch primitive (and the reference
//! implementation the session's service is tested against); new callers
//! should reach it through the session façade.
//!
//! This is the host-level realization of Kitsune's execution model: a
//! stage worker acquires a tile from its input queue (spinning when
//! empty), runs its compiled kernel, and releases the result into the
//! next queue (stalling when full — backpressure). The first stage reads
//! the caller-supplied input stream; the last writes the output stream.

use super::pipeline::SpatialPipeline;
use crate::graph::ResourceClass;
use crate::queue::{PushError, RingQueue};
use crate::runtime::{ArtifactStore, Tensor};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// A sequence-tagged tile flowing through the queues (tags let multi-
/// worker stages process out of order; the sink restores order).
type Tile = (usize, Tensor);

/// The linear runners execute only chain-shaped pipelines: a pipeline
/// carrying explicit DAG edges (multicast fan-out / skip links — the
/// shape training graphs lower to) must run on `kitsune::train`'s
/// executor instead.
fn ensure_linear(pipeline: &SpatialPipeline) -> Result<()> {
    if !pipeline.edges.is_empty() {
        return Err(anyhow!(
            "pipeline `{}` has {} explicit queue edges (multicast/skip links); \
             the linear runner cannot execute a DAG — drive it through kitsune::train",
            pipeline.name,
            pipeline.edges.len()
        ));
    }
    Ok(())
}

/// Per-stage runtime metrics.
#[derive(Debug, Clone)]
pub struct StageMetrics {
    pub name: String,
    pub class: ResourceClass,
    pub workers: usize,
    pub tiles: usize,
    /// Seconds spent executing the stage kernel.
    pub busy_s: f64,
    /// Seconds spent blocked on empty input / full output queues.
    pub wait_s: f64,
}

impl StageMetrics {
    /// Fraction of wall time this stage's workers were busy.
    pub fn utilization(&self) -> f64 {
        let tot = self.busy_s + self.wait_s;
        if tot > 0.0 {
            self.busy_s / tot
        } else {
            0.0
        }
    }
}

/// Result of one streaming run.
#[derive(Debug, Clone)]
pub struct PipelineRun {
    /// Outputs in input order.
    pub outputs: Vec<Tensor>,
    pub metrics: Vec<StageMetrics>,
    pub elapsed_s: f64,
    pub tiles: usize,
}

impl PipelineRun {
    pub fn tiles_per_sec(&self) -> f64 {
        self.tiles as f64 / self.elapsed_s.max(1e-12)
    }
}

/// Run `inputs` through the pipeline, streaming tiles through the ring
/// queues. Returns outputs in input order plus per-stage metrics.
pub fn run_streaming(
    store: &ArtifactStore,
    pipeline: &SpatialPipeline,
    inputs: Vec<Tensor>,
) -> Result<PipelineRun> {
    ensure_linear(pipeline)?;
    let n_stages = pipeline.stages.len();
    let n_tiles = inputs.len();
    // Queues: q[0] feeds stage 0, q[i+1] connects stage i -> i+1,
    // q[n] collects outputs.
    let queues: Vec<Arc<RingQueue<Tile>>> = (0..=n_stages)
        .map(|_| RingQueue::with_capacity(pipeline.queue_capacity))
        .collect();
    let failed = Arc::new(AtomicBool::new(false));

    let start = Instant::now();
    let mut metrics: Vec<StageMetrics> = pipeline
        .stages
        .iter()
        .map(|s| StageMetrics {
            name: s.name.clone(),
            class: s.class,
            workers: s.workers,
            tiles: 0,
            busy_s: 0.0,
            wait_s: 0.0,
        })
        .collect();

    let mut outputs: Vec<Option<Tensor>> = vec![None; n_tiles];
    crate::sched::dedicated_scope(|scope| -> Result<()> {
        // `ArtifactStore` is `Sync` by the Backend/Executable contract, so
        // stage threads share it directly.
        let failed = &failed;
        // Stage workers. The *last* worker of a stage to exit closes the
        // downstream queue (countdown latch), so sibling workers' pushes
        // are never cut off.
        let mut handles = Vec::new();
        for (si, stage) in pipeline.stages.iter().enumerate() {
            let remaining = Arc::new(std::sync::atomic::AtomicUsize::new(stage.workers));
            for _ in 0..stage.workers {
                let in_q = Arc::clone(&queues[si]);
                let out_q = Arc::clone(&queues[si + 1]);
                let remaining = Arc::clone(&remaining);
                let entry = stage.entry.clone();
                // Arc bump, not a tensor copy — and per tile the weights
                // are only borrowed (zero-copy stage boundary).
                let weights = Arc::clone(&stage.weights);
                handles.push((si, scope.spawn(move || -> Result<(usize, f64, f64)> {
                    let mut tiles = 0usize;
                    let mut busy = 0.0f64;
                    let mut wait = 0.0f64;
                    loop {
                        let w0 = Instant::now();
                        let Some((seq, tile)) = in_q.pop() else { break };
                        wait += w0.elapsed().as_secs_f64();
                        let b0 = Instant::now();
                        let result = {
                            let mut args: Vec<&Tensor> = Vec::with_capacity(1 + weights.len());
                            args.push(&tile);
                            args.extend(weights.iter());
                            store.run_f32_ref(&entry, &args)
                        };
                        let out = match result {
                            Ok(outs) => outs
                                .into_iter()
                                .next()
                                .ok_or_else(|| anyhow!("{entry}: no output"))?,
                            Err(e) => {
                                failed.store(true, Ordering::Release);
                                in_q.close();
                                out_q.close();
                                return Err(e);
                            }
                        };
                        busy += b0.elapsed().as_secs_f64();
                        tiles += 1;
                        let w1 = Instant::now();
                        if let Err(PushError::Closed(_)) = out_q.push((seq, out)) {
                            break; // downstream closed (failure path)
                        }
                        wait += w1.elapsed().as_secs_f64();
                    }
                    // Countdown latch: only the stage's last worker closes.
                    if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                        out_q.close();
                    }
                    Ok((tiles, busy, wait))
                })));
            }
        }

        // Feed the source queue from its own thread — the sink must be
        // drained concurrently or the bounded queues fill up and the
        // whole pipeline deadlocks (backpressure reaches the feeder).
        let src = Arc::clone(&queues[0]);
        let feeder = scope.spawn(move || {
            for (seq, t) in inputs.into_iter().enumerate() {
                // First stage shut down (a kernel failed): stop feeding.
                if let Err(PushError::Closed(_)) = src.push((seq, t)) {
                    break;
                }
            }
            src.close();
        });

        // Drain the sink.
        while let Some((seq, t)) = queues[n_stages].pop() {
            outputs[seq] = Some(t);
        }
        feeder.join().map_err(|_| anyhow!("feeder panicked"))?;

        for (si, h) in handles {
            let (tiles, busy, wait) = h.join().map_err(|_| anyhow!("stage panicked"))??;
            metrics[si].tiles += tiles;
            metrics[si].busy_s += busy;
            metrics[si].wait_s += wait;
        }
        Ok(())
    })?;

    if failed.load(Ordering::Acquire) {
        return Err(anyhow!("pipeline stage failed"));
    }
    let outputs: Option<Vec<Tensor>> = outputs.into_iter().collect();
    Ok(PipelineRun {
        outputs: outputs.ok_or_else(|| anyhow!("missing output tiles"))?,
        metrics,
        elapsed_s: start.elapsed().as_secs_f64(),
        tiles: n_tiles,
    })
}

/// Serial baseline: the same stages run back-to-back in one thread —
/// the host analog of bulk-synchronous execution, for speedup reporting.
pub fn run_serial(
    store: &ArtifactStore,
    pipeline: &SpatialPipeline,
    inputs: Vec<Tensor>,
) -> Result<PipelineRun> {
    ensure_linear(pipeline)?;
    let start = Instant::now();
    let n_tiles = inputs.len();
    let mut outputs = Vec::with_capacity(n_tiles);
    for (seq, t) in inputs.into_iter().enumerate() {
        let mut cur = t;
        for (si, stage) in pipeline.stages.iter().enumerate() {
            // Same supervision contract as the pipeline pumps: a stage
            // panic surfaces as a typed StageFailure, not an unwind.
            let outs = crate::fault::catch_stage(&stage.entry, Some(si), Some(seq as u64), || {
                let mut args: Vec<&Tensor> = Vec::with_capacity(1 + stage.weights.len());
                args.push(&cur);
                args.extend(stage.weights.iter());
                store.run_f32_ref(&stage.entry, &args)
            })
            .map_err(|f| f.into_error())?;
            cur = outs.into_iter().next().ok_or_else(|| anyhow!("no output"))?;
        }
        outputs.push(cur);
    }
    Ok(PipelineRun {
        outputs,
        metrics: Vec::new(),
        elapsed_s: start.elapsed().as_secs_f64(),
        tiles: n_tiles,
    })
}
