//! `kitsune serve` — the serving tier on the warm spatial pipeline:
//! the NeRF-class trunk graph is compiled (subgraph selection →
//! pipeline design → ILP), lowered to a spatial pipeline with
//! synthesized stage kernels, registered in a [`crate::serve`]
//! [`ModelRegistry`], and driven by closed-loop concurrent clients
//! through the continuous-batching, deadline-aware [`Server`] —
//! reported against the serial (bulk-sync analog) baseline with
//! latency percentiles, queue depth, and shed counters.

use super::pipeline::SpatialPipeline;
use crate::graph::ResourceClass;
use crate::runtime::{ArtifactStore, Rng, Tensor};
use crate::serve::{BatchPolicy, ModelRegistry, ServeConfig, ServeError, Server};
use crate::session::{nerf_trunk_graph, Session};
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

/// Every CLI subcommand — quoted by `kitsune help` and by the
/// unknown-subcommand error so both stay in sync with the dispatcher.
pub const SUBCOMMANDS: &[&str] = &[
    "table1",
    "table2",
    "fig3",
    "fig5",
    "fig10",
    "fig11",
    "fig12",
    "fig13",
    "fig14",
    "sensitivity",
    "ablation",
    "all",
    "apps",
    "compile",
    "serve",
    "trace",
    "help",
];

/// Legacy hand-built demo pipeline over the AOT artifact entries
/// (`stage_trunk0/1`, `stage_head`), with He-init weights when no
/// checkpoint is given.
///
/// **Deprecation path:** this is the hand-stitched stage list the
/// session façade replaces — `kitsune serve` now lowers a compiled plan
/// instead. Kept for the artifact-backed integration tests, which
/// exercise AOT entries the compiler does not synthesize.
pub fn build_nerf_pipeline(store: &ArtifactStore, workers: usize) -> Result<SpatialPipeline> {
    let mut rng = Rng::new(0xC0FFEE);
    let mut weights_for = |entry: &str| -> Result<Vec<Tensor>> {
        let spec = store.spec(entry)?;
        // Input 0 is the streamed tile; the rest are weights.
        Ok(spec.inputs[1..].iter().map(|t| rng.he_tensor(&t.dims)).collect())
    };
    Ok(SpatialPipeline::builder("nerf-trunk")
        .add_stage("trunk0", "stage_trunk0", ResourceClass::Tensor, weights_for("stage_trunk0")?)
        .workers(workers)
        .add_stage("trunk1", "stage_trunk1", ResourceClass::Tensor, weights_for("stage_trunk1")?)
        .workers(workers)
        .add_stage("head", "stage_head", ResourceClass::Simt, weights_for("stage_head")?)
        .workers(1)
        .queue_capacity(8)
        .build())
}

/// Generate `n` input tiles matching the first stage's tile spec
/// (legacy artifact path; session users call `Session::make_tiles`).
pub fn input_tiles(store: &ArtifactStore, entry: &str, n: usize) -> Result<Vec<Tensor>> {
    let spec = store.spec(entry)?;
    let dims = spec.inputs[0].dims.clone();
    let mut rng = Rng::new(0xFEED);
    Ok((0..n)
        .map(|_| {
            let numel: usize = dims.iter().product();
            Tensor {
                dims: dims.clone(),
                data: (0..numel).map(|_| rng.normal()).collect(),
                prec: crate::runtime::Precision::F32,
            }
        })
        .collect())
}

/// Every `kitsune serve` flag with its argument shape — printed by
/// `--help` and by the unknown-flag error so misspellings name the
/// valid options instead of being ignored.
pub const SERVE_FLAGS: &[(&str, &str)] = &[
    ("--tiles N", "total tiles per client batch round (default 64)"),
    ("--workers N", "worker pumps per TENSOR stage (default 2)"),
    ("--hidden N", "trunk hidden width (default 64)"),
    ("--clients N", "concurrent closed-loop clients (default 4)"),
    ("--requests N", "requests per client (default 4)"),
    ("--deadline-ms N", "per-request deadline; 0 = none (default 0)"),
    ("--max-batch N", "batching window: max tiles per round (default 32)"),
    ("--max-delay-us N", "batching window: max coalescing delay (default 2000)"),
    ("--queue-depth N", "admission queue bound in requests (default 256)"),
    ("--models N", "trunk variants resident at once (default 1)"),
    ("--mem-budget-mb N", "registry memory budget; 0 = unlimited (default 0)"),
];

fn serve_usage() -> String {
    let mut s = String::from("kitsune serve options:\n");
    for (flag, desc) in SERVE_FLAGS {
        s.push_str(&format!("  {flag:<20} {desc}\n"));
    }
    s
}

pub fn serve(args: &[&str]) -> Result<()> {
    let mut tiles = 64usize;
    let mut workers = 2usize;
    let mut hidden = 64usize;
    let mut clients = 4usize;
    let mut requests = 4usize;
    let mut deadline_ms = 0u64;
    let mut max_batch = 32usize;
    let mut max_delay_us = 2_000u64;
    let mut queue_depth = 256usize;
    let mut models = 1usize;
    let mut mem_budget_mb = 0u64;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match *a {
            "--tiles" => tiles = it.next().context("--tiles N")?.parse()?,
            "--workers" => workers = it.next().context("--workers N")?.parse()?,
            "--hidden" => hidden = it.next().context("--hidden N")?.parse()?,
            "--clients" => clients = it.next().context("--clients N")?.parse()?,
            "--requests" => requests = it.next().context("--requests N")?.parse()?,
            "--deadline-ms" => deadline_ms = it.next().context("--deadline-ms N")?.parse()?,
            "--max-batch" => max_batch = it.next().context("--max-batch N")?.parse()?,
            "--max-delay-us" => max_delay_us = it.next().context("--max-delay-us N")?.parse()?,
            "--queue-depth" => queue_depth = it.next().context("--queue-depth N")?.parse()?,
            "--models" => models = it.next().context("--models N")?.parse()?,
            "--mem-budget-mb" => {
                mem_budget_mb = it.next().context("--mem-budget-mb N")?.parse()?
            }
            "--help" | "-h" => {
                print!("{}", serve_usage());
                return Ok(());
            }
            other => anyhow::bail!("unknown serve flag {other}\n{}", serve_usage()),
        }
    }
    let clients = clients.max(1);
    let requests = requests.max(1);
    let models = models.max(1);

    // Stand up the registry: `models` trunk variants (halving hidden
    // width), each its own compiled + lowered warm pipeline.
    let budget = if mem_budget_mb == 0 { None } else { Some(mem_budget_mb * 1024 * 1024) };
    let registry = Arc::new(ModelRegistry::new(budget));
    let mut model_names: Vec<String> = Vec::new();
    for m in 0..models {
        let h = (hidden >> m).max(8);
        let name = if m == 0 { "nerf-trunk".to_string() } else { format!("nerf-trunk-h{h}") };
        let session = Arc::new(
            Session::builder()
                .graph(nerf_trunk_graph(8192, 60, h, 3))
                .workers(workers)
                .tile_rows(128)
                .build()?,
        );
        if m == 0 {
            let compiled = session.compiled().expect("session has a graph");
            let pipeline = session.pipeline().expect("trunk graph streams");
            println!(
                "compiled {}: {} sf-node(s) -> {} pipeline stages, {} worker threads (warm)",
                session.name(),
                compiled.pipelines.len(),
                pipeline.stages.len(),
                session.threads_spawned()
            );
            let allocs: Vec<usize> = compiled
                .pipelines
                .iter()
                .flat_map(|lp| lp.balanced.alloc.iter().copied())
                .collect();
            for (s, a) in pipeline.stages.iter().zip(&allocs) {
                println!(
                    "  stage {:<10} [{:?}] entry {:<28} workers={} (ILP a_i={a})",
                    s.name, s.class, s.entry, s.workers
                );
            }
        }
        let evicted = registry.insert(name.clone(), session).map_err(|e| anyhow::anyhow!(e))?;
        if !evicted.is_empty() {
            println!("  evicted {} to fit memory budget", evicted.join(", "));
        }
        model_names.push(name);
    }
    for (name, bytes) in registry.accounting() {
        println!("  model {name:<16} resident {:>8.2} MiB", bytes as f64 / (1024.0 * 1024.0));
    }

    // Serial (bulk-sync analog) baseline + warm correctness check on the
    // primary model.
    let primary = registry.get(&model_names[0]).map_err(|e| anyhow::anyhow!(e))?;
    let inputs = primary.make_tiles(tiles, 0xFEED)?;
    println!("\nserial (bulk-sync analog), {tiles} tiles:");
    let serial = primary.run_serial(inputs.clone())?;
    println!("  {:.1} ms  ({:.1} tiles/s)", serial.elapsed_s * 1e3, serial.tiles_per_sec());
    let run = primary.run(inputs)?;
    println!("warm spatial pipeline, 1 client:");
    println!(
        "  {:.1} ms  ({:.1} tiles/s)  speedup {:.2}x",
        run.elapsed_s * 1e3,
        run.tiles_per_sec(),
        serial.elapsed_s / run.elapsed_s.max(1e-12)
    );
    let max_err = run
        .outputs
        .iter()
        .zip(&serial.outputs)
        .flat_map(|(a, b)| a.data.iter().zip(&b.data).map(|(x, y)| (x - y).abs()))
        .fold(0.0f32, f32::max);
    anyhow::ensure!(max_err < 1e-5, "pipeline output mismatch: {max_err:.2e}");

    // The serving tier: continuous batching + EDF deadlines over the
    // registry, driven by closed-loop concurrent clients.
    let server = Server::new(
        Arc::clone(&registry),
        ServeConfig {
            batch: BatchPolicy {
                max_tiles: max_batch,
                max_delay: Duration::from_micros(max_delay_us),
            },
            queue_depth,
            default_deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
            ..ServeConfig::default()
        },
    );
    let threads_before = primary.threads_spawned();
    let per_client = (tiles / clients).max(1);
    let t0 = std::time::Instant::now();
    let mut served_tiles = 0usize;
    let mut shed = 0usize;
    std::thread::scope(|scope| -> Result<()> {
        let mut joins = Vec::new();
        for c in 0..clients {
            let server = &server;
            let model_names = &model_names;
            let primary = &primary;
            joins.push(scope.spawn(move || -> Result<(usize, usize)> {
                let model = &model_names[c % model_names.len()];
                let mut ok = 0usize;
                let mut shed = 0usize;
                for r in 0..requests {
                    let batch =
                        primary.make_tiles(per_client, 0xBEEF + (c * requests + r) as u64)?;
                    match server.submit(model, batch, None) {
                        Ok(handle) => match handle.wait() {
                            Ok(reply) => ok += reply.outputs.len(),
                            Err(
                                ServeError::DeadlineExceeded { .. } | ServeError::ShuttingDown,
                            ) => shed += 1,
                            Err(e) => anyhow::bail!("client {c} request {r}: {e}"),
                        },
                        Err(
                            ServeError::DeadlineExceeded { .. }
                            | ServeError::AdmissionRejected { .. },
                        ) => shed += 1,
                        Err(e) => anyhow::bail!("client {c} request {r}: {e}"),
                    }
                }
                Ok((ok, shed))
            }));
        }
        for j in joins {
            let (ok, s) = j.join().map_err(|_| anyhow::anyhow!("client panicked"))??;
            served_tiles += ok;
            shed += s;
        }
        Ok(())
    })?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "serve tier, {clients} clients x {requests} requests x {per_client} tiles:\n  \
         {:.1} ms  ({:.1} tiles/s aggregate, {shed} shed)",
        wall * 1e3,
        served_tiles as f64 / wall.max(1e-12)
    );
    anyhow::ensure!(
        primary.threads_spawned() == threads_before,
        "submit must never spawn stage threads"
    );

    let stats = server.stats();
    println!(
        "  admitted {}  completed {}  rejected {}  shed(deadline {} + shutdown {})  failed {}",
        stats.admitted,
        stats.completed,
        stats.rejected,
        stats.shed_deadline,
        stats.shed_shutdown,
        stats.failed
    );
    println!(
        "  latency p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms  max {:.2} ms  \
         (est {:.0} us/tile, queue {} deep, {} tiles in flight)",
        stats.latency.p50_ms,
        stats.latency.p95_ms,
        stats.latency.p99_ms,
        stats.latency.max_ms,
        stats.est_tile_us,
        stats.queue_depth,
        stats.in_flight_tiles
    );
    for m in &primary.metrics() {
        println!(
            "  stage {:<10} [{:?}] workers={} tiles={} busy {:>7.1} ms  wait {:>7.1} ms  util {:>4.0}%",
            m.name,
            m.class,
            m.workers,
            m.tiles,
            m.busy_s * 1e3,
            m.wait_s * 1e3,
            m.utilization() * 100.0
        );
    }
    println!(
        "max |pipeline - serial| = {max_err:.2e}; threads spawned: {threads_before} (all at build)"
    );
    server.shutdown();
    anyhow::ensure!(primary.in_flight() == 0, "in-flight table must drain at shutdown");
    registry.shutdown_all();
    Ok(())
}

/// Every `kitsune trace` flag with its argument shape — printed by
/// `--help` and by the unknown-flag error.
pub const TRACE_FLAGS: &[(&str, &str)] = &[
    ("--out PATH", "trace file (default: $KITSUNE_TRACE, else kitsune_trace.json)"),
    ("--tiles N", "tiles streamed through the warm inference pipeline (default 32)"),
    ("--workers N", "worker pumps per TENSOR stage (default 2)"),
    ("--steps N", "traced training steps on the reduced NeRF DAG; 0 skips (default 1)"),
];

fn trace_usage() -> String {
    let mut s = String::from(
        "kitsune trace <APP> — record a Chrome-trace/Perfetto timeline of the warm\n\
         pipeline (and a training step, when the app trains), plus the dataflow\n\
         traffic accounting. Open the JSON in ui.perfetto.dev or chrome://tracing.\n\
         options:\n",
    );
    for (flag, desc) in TRACE_FLAGS {
        s.push_str(&format!("  {flag:<14} {desc}\n"));
    }
    s
}

/// `kitsune trace <app>` — arm the span sink, stream tiles through the
/// app's warm pipeline (falling back to the NeRF trunk when the app is
/// simulation-only), run traced training steps on a reduced NeRF DAG,
/// then flush the Chrome-trace JSON and print the traffic accounting.
pub fn trace(args: &[&str]) -> Result<()> {
    let mut out: Option<PathBuf> = None;
    let mut tiles = 32usize;
    let mut workers = 2usize;
    let mut steps = 1usize;
    let mut app: Option<&str> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match *a {
            "--out" => out = Some(PathBuf::from(it.next().context("--out PATH")?)),
            "--tiles" => tiles = it.next().context("--tiles N")?.parse()?,
            "--workers" => workers = it.next().context("--workers N")?.parse()?,
            "--steps" => steps = it.next().context("--steps N")?.parse()?,
            "--help" | "-h" => {
                print!("{}", trace_usage());
                return Ok(());
            }
            other if other.starts_with('-') => {
                anyhow::bail!("unknown trace flag {other}\n{}", trace_usage())
            }
            other => app = Some(other),
        }
    }
    let app = app.unwrap_or("nerf");
    let tiles = tiles.max(1);

    // Arm the sink before any session is built: it latches on first
    // span, so a later `enable` could not redirect it.
    let path = out
        .or_else(|| {
            std::env::var("KITSUNE_TRACE")
                .ok()
                .filter(|s| !s.trim().is_empty())
                .map(PathBuf::from)
        })
        .unwrap_or_else(|| PathBuf::from("kitsune_trace.json"));
    let armed = crate::telemetry::trace::enable(&path)
        .ok_or_else(|| anyhow::anyhow!("tracing is latched off (KITSUNE_TRACE set but empty)"))?;
    println!("tracing to {}", armed.display());

    // Inference: the app's own pipeline when it streams, else the
    // canonical NeRF trunk so the trace is never empty.
    let session = Session::builder().app(app).workers(workers).build()?;
    let session = if session.pipeline().is_some() {
        session
    } else {
        println!(
            "{}: {} — tracing the NeRF trunk pipeline instead",
            session.name(),
            session.not_streamable_reason().unwrap_or("not streamable")
        );
        Session::builder()
            .graph(nerf_trunk_graph(4096, 60, 64, 3))
            .workers(workers)
            .tile_rows(128)
            .build()?
    };
    let inputs = session.make_tiles(tiles, 0xFEED)?;
    let run = session.run(inputs)?;
    println!(
        "  {}: {tiles} tiles in {:.1} ms ({:.0} tiles/s) across {} stages",
        session.name(),
        run.elapsed_s * 1e3,
        run.tiles_per_sec(),
        session.pipeline().map(|p| p.stages.len()).unwrap_or(0)
    );
    if let Some(t) = session.telemetry() {
        let s = t.traffic.snapshot();
        println!(
            "  traffic: dataflow {:.1} KiB off-chip vs serial oracle {:.1} KiB — {:.0}% reduction",
            s.dataflow_offchip_bytes() as f64 / 1024.0,
            s.serial_offchip_bytes() as f64 / 1024.0,
            s.reduction() * 100.0
        );
    }
    session.shutdown();

    // Training: traced steps on an interpreter-scale NeRF-class training
    // DAG (skip concat + multicast backward in play — the suite training
    // graphs at paper scale are not interpreter-feasible in a smoke
    // trace). `--steps 0` skips the leg.
    if steps > 0 {
        let tgraph = crate::apps::nerf::training(&crate::apps::nerf::NerfConfig {
            batch: 256,
            pos_enc: 16,
            dir_enc: 8,
            hidden: 32,
            depth: 4,
            skip_at: 2,
        });
        let tsession = Session::builder().graph(tgraph).tile_rows(32).build()?;
        let batch = tsession.make_train_batch(0xBEEF)?;
        let mut trainer = tsession.trainer()?;
        for step in 0..steps {
            let stats = trainer.step(&batch)?;
            println!("  train step {step}: loss {:.4} ({} tiles)", stats.loss, stats.tiles);
        }
        if let Some(t) = tsession.telemetry() {
            let s = t.traffic.snapshot();
            println!(
                "  train traffic: dataflow {:.1} KiB off-chip vs serial oracle {:.1} KiB — \
                 {:.0}% reduction",
                s.dataflow_offchip_bytes() as f64 / 1024.0,
                s.serial_offchip_bytes() as f64 / 1024.0,
                s.reduction() * 100.0
            );
        }
        tsession.shutdown();
    }

    let written = crate::telemetry::trace::flush()?.expect("sink armed above");
    println!(
        "trace written to {} (open in ui.perfetto.dev or chrome://tracing)",
        written.display()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // The help/unknown-subcommand vocabulary and the trace usage text are
    // plain strings the dispatcher quotes; keep their content honest.
    #[test]
    fn subcommand_vocabulary_lists_trace_and_serve() {
        assert!(SUBCOMMANDS.contains(&"trace"));
        assert!(SUBCOMMANDS.contains(&"serve"));
        assert!(SUBCOMMANDS.contains(&"help"));
        // The dispatcher quotes this list verbatim — no duplicates.
        let mut sorted: Vec<&str> = SUBCOMMANDS.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), SUBCOMMANDS.len(), "duplicate subcommand");
    }

    #[test]
    fn trace_usage_names_every_flag_and_the_env_knob() {
        let usage = trace_usage();
        for (flag, _) in TRACE_FLAGS {
            let name = flag.split_whitespace().next().unwrap();
            assert!(usage.contains(name), "usage missing {name}");
        }
        assert!(usage.contains("KITSUNE_TRACE"), "usage must name the env knob");
        assert!(usage.contains("perfetto"), "usage must say where to open the trace");
    }

    #[test]
    fn serve_usage_names_every_flag() {
        let usage = serve_usage();
        for (flag, _) in SERVE_FLAGS {
            let name = flag.split_whitespace().next().unwrap();
            assert!(usage.contains(name), "usage missing {name}");
        }
    }
}
