//! `kitsune serve` — the real spatial-pipeline coordinator, driven
//! end-to-end through the [`crate::session`] façade: the NeRF-class
//! trunk graph is compiled (subgraph selection → pipeline design → ILP),
//! the compiled plan is lowered to a spatial pipeline with synthesized
//! stage kernels, and a *warm* worker pool serves streamed tiles from
//! concurrent clients — reported against the serial (bulk-sync analog)
//! baseline.

use super::pipeline::SpatialPipeline;
use crate::graph::ResourceClass;
use crate::runtime::{ArtifactStore, Rng, Tensor};
use crate::session::{nerf_trunk_graph, Session};
use anyhow::{Context, Result};

/// Legacy hand-built demo pipeline over the AOT artifact entries
/// (`stage_trunk0/1`, `stage_head`), with He-init weights when no
/// checkpoint is given.
///
/// **Deprecation path:** this is the hand-stitched stage list the
/// session façade replaces — `kitsune serve` now lowers a compiled plan
/// instead. Kept for the artifact-backed integration tests, which
/// exercise AOT entries the compiler does not synthesize.
pub fn build_nerf_pipeline(store: &ArtifactStore, workers: usize) -> Result<SpatialPipeline> {
    let mut rng = Rng::new(0xC0FFEE);
    let mut weights_for = |entry: &str| -> Result<Vec<Tensor>> {
        let spec = store.spec(entry)?;
        // Input 0 is the streamed tile; the rest are weights.
        Ok(spec.inputs[1..].iter().map(|t| rng.he_tensor(&t.dims)).collect())
    };
    Ok(SpatialPipeline::builder("nerf-trunk")
        .add_stage("trunk0", "stage_trunk0", ResourceClass::Tensor, weights_for("stage_trunk0")?)
        .workers(workers)
        .add_stage("trunk1", "stage_trunk1", ResourceClass::Tensor, weights_for("stage_trunk1")?)
        .workers(workers)
        .add_stage("head", "stage_head", ResourceClass::Simt, weights_for("stage_head")?)
        .workers(1)
        .queue_capacity(8)
        .build())
}

/// Generate `n` input tiles matching the first stage's tile spec
/// (legacy artifact path; session users call `Session::make_tiles`).
pub fn input_tiles(store: &ArtifactStore, entry: &str, n: usize) -> Result<Vec<Tensor>> {
    let spec = store.spec(entry)?;
    let dims = spec.inputs[0].dims.clone();
    let mut rng = Rng::new(0xFEED);
    Ok((0..n)
        .map(|_| {
            let numel: usize = dims.iter().product();
            Tensor {
                dims: dims.clone(),
                data: (0..numel).map(|_| rng.normal()).collect(),
            }
        })
        .collect())
}

pub fn serve(args: &[&str]) -> Result<()> {
    let mut tiles = 64usize;
    let mut workers = 2usize;
    let mut hidden = 64usize;
    let mut clients = 4usize;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match *a {
            "--tiles" => tiles = it.next().context("--tiles N")?.parse()?,
            "--workers" => workers = it.next().context("--workers N")?.parse()?,
            "--hidden" => hidden = it.next().context("--hidden N")?.parse()?,
            "--clients" => clients = it.next().context("--clients N")?.parse()?,
            other => anyhow::bail!("unknown serve flag {other}"),
        }
    }
    let clients = clients.max(1);

    // One façade from graph to execution: compile once, lower the plan,
    // stand up the persistent pipeline.
    let session = Session::builder()
        .graph(nerf_trunk_graph(8192, 60, hidden, 3))
        .workers(workers)
        .tile_rows(128)
        .build()?;
    let compiled = session.compiled().expect("session has a graph");
    let pipeline = session.pipeline().expect("trunk graph streams");
    println!(
        "compiled {}: {} sf-node(s) -> {} pipeline stages, {} worker threads (warm)",
        session.name(),
        compiled.pipelines.len(),
        pipeline.stages.len(),
        session.threads_spawned()
    );
    let allocs: Vec<usize> = compiled
        .pipelines
        .iter()
        .flat_map(|lp| lp.balanced.alloc.iter().copied())
        .collect();
    for (s, a) in pipeline.stages.iter().zip(&allocs) {
        println!(
            "  stage {:<10} [{:?}] entry {:<28} workers={} (ILP a_i={a})",
            s.name, s.class, s.entry, s.workers
        );
    }

    let inputs = session.make_tiles(tiles, 0xFEED)?;

    println!("\nserial (bulk-sync analog), {tiles} tiles:");
    let serial = session.run_serial(inputs.clone())?;
    println!(
        "  {:.1} ms  ({:.1} tiles/s)",
        serial.elapsed_s * 1e3,
        serial.tiles_per_sec()
    );

    // Warm single-caller batch.
    let run = session.run(inputs)?;
    println!("warm spatial pipeline, 1 client:");
    println!(
        "  {:.1} ms  ({:.1} tiles/s)  speedup {:.2}x",
        run.elapsed_s * 1e3,
        run.tiles_per_sec(),
        serial.elapsed_s / run.elapsed_s.max(1e-12)
    );

    // Correctness: pipeline output must equal serial output exactly.
    let max_err = run
        .outputs
        .iter()
        .zip(&serial.outputs)
        .flat_map(|(a, b)| a.data.iter().zip(&b.data).map(|(x, y)| (x - y).abs()))
        .fold(0.0f32, f32::max);
    anyhow::ensure!(max_err < 1e-5, "pipeline output mismatch: {max_err:.2e}");

    // Concurrent clients through the same warm pipeline.
    let threads_before = session.threads_spawned();
    let per_client = (tiles / clients).max(1);
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let mut joins = Vec::new();
        for c in 0..clients {
            let session = &session;
            joins.push(scope.spawn(move || -> Result<usize> {
                let batch = session.make_tiles(per_client, 0xBEEF + c as u64)?;
                let out = session.submit(batch)?.wait()?;
                Ok(out.outputs.len())
            }));
        }
        let mut total = 0usize;
        for j in joins {
            total += j.join().map_err(|_| anyhow::anyhow!("client panicked"))??;
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "warm spatial pipeline, {clients} concurrent clients x {per_client} tiles:\n  \
             {:.1} ms  ({:.1} tiles/s aggregate)",
            wall * 1e3,
            total as f64 / wall.max(1e-12)
        );
        Ok(())
    })?;
    anyhow::ensure!(
        session.threads_spawned() == threads_before,
        "submit must never spawn stage threads"
    );

    for m in &session.metrics() {
        println!(
            "  stage {:<10} [{:?}] workers={} tiles={} busy {:>7.1} ms  wait {:>7.1} ms  util {:>4.0}%",
            m.name,
            m.class,
            m.workers,
            m.tiles,
            m.busy_s * 1e3,
            m.wait_s * 1e3,
            m.utilization() * 100.0
        );
    }
    println!("max |pipeline - serial| = {max_err:.2e}; threads spawned: {threads_before} (all at build)");
    session.shutdown();
    Ok(())
}
