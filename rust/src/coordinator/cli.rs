//! `kitsune serve` — run the real spatial-pipeline coordinator over the
//! AOT artifacts: the NeRF-class trunk as a three-stage pipeline
//! (TENSOR, TENSOR, SIMT), streamed tiles, ring-queue backpressure,
//! reported against the serial (bulk-sync analog) baseline.

use super::pipeline::SpatialPipeline;
use super::runner::{run_serial, run_streaming};
use crate::graph::ResourceClass;
use crate::runtime::{ArtifactStore, Rng, Tensor};
use anyhow::{Context, Result};

/// Build the demo pipeline from the artifact manifest, with He-init
/// weights when no checkpoint is given.
pub fn build_nerf_pipeline(store: &ArtifactStore, workers: usize) -> Result<SpatialPipeline> {
    let mut rng = Rng::new(0xC0FFEE);
    let mut weights_for = |entry: &str| -> Result<Vec<Tensor>> {
        let spec = store.spec(entry)?;
        // Input 0 is the streamed tile; the rest are weights.
        Ok(spec.inputs[1..].iter().map(|t| rng.he_tensor(&t.dims)).collect())
    };
    Ok(SpatialPipeline::builder("nerf-trunk")
        .add_stage("trunk0", "stage_trunk0", ResourceClass::Tensor, weights_for("stage_trunk0")?)
        .workers(workers)
        .add_stage("trunk1", "stage_trunk1", ResourceClass::Tensor, weights_for("stage_trunk1")?)
        .workers(workers)
        .add_stage("head", "stage_head", ResourceClass::Simt, weights_for("stage_head")?)
        .workers(1)
        .queue_capacity(8)
        .build())
}

/// Generate `n` input tiles matching the first stage's tile spec.
pub fn input_tiles(store: &ArtifactStore, entry: &str, n: usize) -> Result<Vec<Tensor>> {
    let spec = store.spec(entry)?;
    let dims = spec.inputs[0].dims.clone();
    let mut rng = Rng::new(0xFEED);
    Ok((0..n)
        .map(|_| {
            let numel: usize = dims.iter().product();
            Tensor {
                dims: dims.clone(),
                data: (0..numel).map(|_| rng.normal()).collect(),
            }
        })
        .collect())
}

pub fn serve(args: &[&str]) -> Result<()> {
    let mut tiles = 64usize;
    let mut workers = 2usize;
    let mut artifacts = "artifacts".to_string();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match *a {
            "--tiles" => tiles = it.next().context("--tiles N")?.parse()?,
            "--workers" => workers = it.next().context("--workers N")?.parse()?,
            "--artifacts" => artifacts = it.next().context("--artifacts DIR")?.to_string(),
            other => anyhow::bail!("unknown serve flag {other}"),
        }
    }

    println!("loading artifacts from {artifacts}/ ...");
    let store = ArtifactStore::load(&artifacts)?;
    println!("platform: {}; entries: {:?}", store.platform(), store.entry_names());

    let pipeline = build_nerf_pipeline(&store, workers)?;
    let inputs = input_tiles(&store, "stage_trunk0", tiles)?;

    println!("\nserial (bulk-sync analog), {tiles} tiles:");
    let serial = run_serial(&store, &pipeline, inputs.clone())?;
    println!(
        "  {:.1} ms  ({:.1} tiles/s)",
        serial.elapsed_s * 1e3,
        serial.tiles_per_sec()
    );

    println!("spatial pipeline ({} stages, {workers} workers/GEMM stage):", pipeline.stages.len());
    let run = run_streaming(&store, &pipeline, inputs)?;
    println!(
        "  {:.1} ms  ({:.1} tiles/s)  speedup {:.2}x",
        run.elapsed_s * 1e3,
        run.tiles_per_sec(),
        serial.elapsed_s / run.elapsed_s
    );
    for m in &run.metrics {
        println!(
            "  stage {:<8} [{:?}] workers={} tiles={} busy {:>6.1} ms  wait {:>6.1} ms  util {:>4.0}%",
            m.name,
            m.class,
            m.workers,
            m.tiles,
            m.busy_s * 1e3,
            m.wait_s * 1e3,
            m.utilization() * 100.0
        );
    }
    // Correctness: pipeline output must equal serial output exactly.
    let max_err = run
        .outputs
        .iter()
        .zip(&serial.outputs)
        .flat_map(|(a, b)| a.data.iter().zip(&b.data).map(|(x, y)| (x - y).abs()))
        .fold(0.0f32, f32::max);
    println!("max |pipeline - serial| = {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-5, "pipeline output mismatch");
    Ok(())
}
