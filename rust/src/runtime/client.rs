//! Backend-agnostic artifact store: parse `artifacts/manifest.txt`,
//! compile every entry on the active [`Backend`] (once, at load time —
//! never on the request path), and dispatch validated `run_f32` calls.

use super::backend::{default_backend, Backend, Executable};
use super::error::RuntimeError;
use super::manifest::{parse_manifest, EntrySpec};
use super::tensor::Tensor;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::Path;

/// All compiled entry points from one artifact directory.
///
/// `Send + Sync` by construction ([`Backend`] and [`Executable`] require
/// it), so the coordinator shares `&ArtifactStore` across stage threads
/// directly.
pub struct ArtifactStore {
    backend: Box<dyn Backend>,
    entries: HashMap<String, (Box<dyn Executable>, EntrySpec)>,
}

/// Backend tag for stores assembled in memory from already-compiled
/// executables (the session façade's lowered stage programs). It cannot
/// compile anything new — every entry is handed in pre-built.
struct PrecompiledBackend(&'static str);

impl Backend for PrecompiledBackend {
    fn name(&self) -> &'static str {
        self.0
    }

    fn compile(&self, spec: &EntrySpec) -> Result<Box<dyn Executable>> {
        Err(RuntimeError::UnsupportedEntry { name: spec.name.clone(), backend: self.0 }.into())
    }
}

impl ArtifactStore {
    /// Assemble a store directly from compiled executables — no manifest
    /// on disk. This is how [`crate::session`] registers the stage
    /// programs it lowers from a `CompiledApp`: the coordinator then
    /// dispatches them exactly like AOT artifact entries.
    pub fn from_executables(
        platform: &'static str,
        entries: Vec<(EntrySpec, Box<dyn Executable>)>,
    ) -> Self {
        let entries = entries
            .into_iter()
            .map(|(spec, exe)| (spec.name.clone(), (exe, spec)))
            .collect();
        ArtifactStore { backend: Box::new(PrecompiledBackend(platform)), entries }
    }

    /// Load `dir/manifest.txt` on the default backend (PJRT under the
    /// `pjrt` feature, the pure-Rust interpreter otherwise; override with
    /// `KITSUNE_BACKEND`).
    ///
    /// A missing artifact directory is the *expected* state of a fresh
    /// checkout and surfaces as the typed
    /// [`RuntimeError::ArtifactsMissing`], which tests and examples use
    /// as their skip signal.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        // Check for artifacts before touching the backend: a fresh
        // checkout must report ArtifactsMissing (the skip signal) even if
        // the configured backend cannot initialize.
        if !dir.join("manifest.txt").is_file() {
            return Err(RuntimeError::ArtifactsMissing { dir: dir.to_path_buf() }.into());
        }
        Self::load_with(dir, default_backend()?)
    }

    /// Load on an explicit backend.
    pub fn load_with(dir: impl AsRef<Path>, backend: Box<dyn Backend>) -> Result<Self> {
        let dir = dir.as_ref();
        if !dir.join("manifest.txt").is_file() {
            return Err(RuntimeError::ArtifactsMissing { dir: dir.to_path_buf() }.into());
        }
        let mut entries = HashMap::new();
        for spec in parse_manifest(dir)? {
            let exe = backend.compile(&spec)?;
            entries.insert(spec.name.clone(), (exe, spec));
        }
        Ok(ArtifactStore { backend, entries })
    }

    /// Platform string of the active backend (`"interp"`, or the PJRT
    /// plugin platform name).
    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Short identifier of the active backend.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn entry_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(String::as_str).collect();
        v.sort();
        v
    }

    pub fn spec(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .map(|(_, s)| s)
            .ok_or_else(|| RuntimeError::UnknownEntry { name: name.to_string() }.into())
    }

    /// Execute an entry with f32 tensors. Inputs are validated against the
    /// manifest before reaching the backend.
    pub fn run_f32(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.lookup_validated(name, inputs.iter())?.run_f32(inputs)
    }

    /// Borrowed-input execution — the zero-copy hot path: stage workers
    /// pass `[&tile, &w, &b]` and nothing is cloned per tile. Validation
    /// is identical to [`Self::run_f32`].
    pub fn run_f32_ref(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.lookup_validated(name, inputs.iter().copied())?.run_f32_ref(inputs)
    }

    /// Resolve `name` and validate arity + per-input dims against the
    /// manifest — the one validator behind both the owned and borrowed
    /// entry points.
    fn lookup_validated<'t>(
        &self,
        name: &str,
        inputs: impl ExactSizeIterator<Item = &'t Tensor>,
    ) -> Result<&dyn Executable> {
        let (exe, spec) = self
            .entries
            .get(name)
            .ok_or_else(|| -> anyhow::Error {
                RuntimeError::UnknownEntry { name: name.to_string() }.into()
            })?;
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "{name}: got {} inputs, manifest says {}",
                inputs.len(),
                spec.inputs.len()
            ));
        }
        for (t, ispec) in inputs.zip(&spec.inputs) {
            if t.dims != ispec.dims {
                return Err(anyhow!(
                    "{name}: input dims {:?} != manifest {:?}",
                    t.dims,
                    ispec.dims
                ));
            }
        }
        Ok(exe.as_ref())
    }
}
