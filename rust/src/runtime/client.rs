//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! Wraps the `xla` crate (PJRT C API). HLO *text* is the interchange
//! format (see `python/compile/aot.py` and /opt/xla-example/README.md —
//! serialized protos from jax ≥ 0.5 carry 64-bit instruction ids the
//! bundled xla_extension 0.5.1 rejects; the text parser reassigns ids).

use super::manifest::{parse_manifest, EntrySpec};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// All compiled entry points from one artifact directory.
pub struct ArtifactStore {
    client: xla::PjRtClient,
    entries: HashMap<String, (xla::PjRtLoadedExecutable, EntrySpec)>,
}

impl ArtifactStore {
    /// Load and compile every entry in `dir/manifest.txt` on the PJRT CPU
    /// client. Compilation happens once, here — never on the request path.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        let mut entries = HashMap::new();
        for spec in parse_manifest(dir)? {
            let proto = xla::HloModuleProto::from_text_file(
                spec.hlo_path.to_str().context("non-utf8 path")?,
            )
            .map_err(wrap)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(wrap)?;
            entries.insert(spec.name.clone(), (exe, spec));
        }
        Ok(ArtifactStore { client, entries })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn entry_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(String::as_str).collect();
        v.sort();
        v
    }

    pub fn spec(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .map(|(_, s)| s)
            .ok_or_else(|| anyhow!("unknown artifact entry {name}"))
    }

    /// Execute an entry with f32 tensors. Inputs are validated against the
    /// manifest; outputs are decomposed from the return tuple.
    pub fn run_f32(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let (exe, spec) = self
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact entry {name}"))?;
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "{name}: got {} inputs, manifest says {}",
                inputs.len(),
                spec.inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, ispec) in inputs.iter().zip(&spec.inputs) {
            if t.dims != ispec.dims {
                return Err(anyhow!(
                    "{name}: input dims {:?} != manifest {:?}",
                    t.dims,
                    ispec.dims
                ));
            }
            literals.push(t.to_literal()?);
        }
        let result = exe.execute::<xla::Literal>(&literals).map_err(wrap)?;
        let lit = result[0][0].to_literal_sync().map_err(wrap)?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = lit.to_tuple().map_err(wrap)?;
        parts.into_iter().map(Tensor::from_literal).collect()
    }
}

/// Plain-old-data f32 tensor crossing the queue/runtime boundary.
/// (Queues carry `Tensor`, not `xla::Literal` — literals wrap raw
/// pointers and stay thread-local.)
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let numel: usize = dims.iter().product::<usize>().max(1);
        if data.len() != numel {
            return Err(anyhow!("tensor data {} != numel {numel}", data.len()));
        }
        Ok(Tensor { dims, data })
    }

    pub fn zeros(dims: &[usize]) -> Self {
        let numel: usize = dims.iter().product::<usize>().max(1);
        Tensor { dims: dims.to_vec(), data: vec![0.0; numel] }
    }

    pub fn scalar_value(&self) -> f32 {
        self.data.first().copied().unwrap_or(f32::NAN)
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&self.data).reshape(&dims).map_err(wrap)
    }

    fn from_literal(lit: xla::Literal) -> Result<Self> {
        let shape = lit.array_shape().map_err(wrap)?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        // Scalars and non-f32 outputs are converted to f32.
        let lit = lit.convert(xla::PrimitiveType::F32).map_err(wrap)?;
        let data = lit.to_vec::<f32>().map_err(wrap)?;
        Tensor::new(dims, data)
    }
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

/// Deterministic parameter/data generator (xorshift + Box-Muller): the
/// Rust-side analog of the model's He initialization, used by examples
/// and the coordinator when no checkpoint is supplied.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// He-initialized tensor for a `[fan_in, out]` weight (or zeros bias).
    pub fn he_tensor(&mut self, dims: &[usize]) -> Tensor {
        if dims.len() < 2 {
            return Tensor::zeros(dims);
        }
        let fan_in = dims[0] as f32;
        let scale = (2.0 / fan_in).sqrt();
        let numel: usize = dims.iter().product();
        let data = (0..numel).map(|_| self.normal() * scale).collect();
        Tensor { dims: dims.to_vec(), data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_validates_numel() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn rng_deterministic_and_normalish() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut r = Rng::new(7);
        let xs: Vec<f32> = (0..10_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 1.0).abs() < 0.1, "{var}");
    }

    #[test]
    fn he_scaling() {
        let mut r = Rng::new(9);
        let t = r.he_tensor(&[256, 64]);
        let var = t.data.iter().map(|x| x * x).sum::<f32>() / t.data.len() as f32;
        let want = 2.0 / 256.0;
        assert!((var - want).abs() / want < 0.2, "{var} vs {want}");
        let b = r.he_tensor(&[64]);
        assert!(b.data.iter().all(|&x| x == 0.0));
    }
}
