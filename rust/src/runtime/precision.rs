//! Storage precision for tensors crossing pipeline edges: `f32` (the
//! default), or the two 16-bit floating formats — `bf16` (f32's top 16
//! bits: 8 exponent bits, 7 mantissa bits) and IEEE `f16` (5 exponent
//! bits, 10 mantissa bits).
//!
//! The host analog keeps every [`crate::runtime::Tensor`] payload as
//! `Vec<f32>` — a 16-bit storage mode means the *values* are rounded to
//! the 16-bit grid (round-to-nearest-even, exactly the bit conversions
//! below) at the storage boundaries — weight creation and ring-queue
//! pushes — while kernels accumulate in full f32 and the optimizer keeps
//! f32 master weights. Byte accounting (`Tensor::payload_bytes`,
//! telemetry edge counters, the serve registry) charges the reduced
//! width, so `BENCH_traffic.json` shows the bandwidth the narrower
//! format buys. Rounding twice to the same grid is the identity, so
//! re-quantizing at every edge crossing is safe.
//!
//! The conversions are exact reimplementations of the IEEE-754
//! `binary32 -> binary16`/`bfloat16` round-to-nearest-even narrowing,
//! including subnormals, signed zero, overflow-to-infinity, and NaN
//! quieting (payload top bits preserved, never collapsed to infinity).

use std::sync::atomic::{AtomicU8, Ordering};

/// Storage width for weights and inter-stage tiles.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Full f32 storage — values untouched, 4 bytes/element.
    #[default]
    F32,
    /// bfloat16 storage: f32 range, 8 bits of mantissa (incl. hidden).
    Bf16,
    /// IEEE binary16 storage: ±65504 range, 11 bits of mantissa.
    F16,
}

impl Precision {
    /// Bytes per element at this storage width.
    pub fn bytes(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::Bf16 | Precision::F16 => 2,
        }
    }

    /// Canonical lowercase name (the `KITSUNE_PRECISION` vocabulary).
    pub fn label(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
            Precision::F16 => "f16",
        }
    }

    /// Parse a precision name (case-insensitive; `fp32`/`fp16` aliases
    /// accepted). `None` for anything else.
    pub fn parse(s: &str) -> Option<Precision> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Some(Precision::F32),
            "bf16" | "bfloat16" => Some(Precision::Bf16),
            "f16" | "fp16" | "float16" | "half" => Some(Precision::F16),
            _ => None,
        }
    }

    /// Round one value to this storage grid (round-to-nearest-even).
    #[inline]
    pub fn quantize(self, x: f32) -> f32 {
        match self {
            Precision::F32 => x,
            Precision::Bf16 => bf16_bits_to_f32(f32_to_bf16_bits(x)),
            Precision::F16 => f16_bits_to_f32(f32_to_f16_bits(x)),
        }
    }

    /// Round a slice in place to this storage grid.
    pub fn quantize_slice(self, xs: &mut [f32]) {
        match self {
            Precision::F32 => {}
            Precision::Bf16 => {
                for x in xs {
                    *x = bf16_bits_to_f32(f32_to_bf16_bits(*x));
                }
            }
            Precision::F16 => {
                for x in xs {
                    *x = f16_bits_to_f32(f32_to_f16_bits(*x));
                }
            }
        }
    }
}

/// Resolve one precision environment override against its raw string
/// value: a recognized name wins, anything else warns once (via the
/// shared [`crate::sched::warn_env_once`] policy) and yields `fallback`.
/// Split from [`env_precision`] so the parse/warn policy is unit
/// testable without mutating the process environment.
pub fn resolve_env_precision(var: &str, raw: &str, fallback: Precision) -> Precision {
    match Precision::parse(raw) {
        Some(p) => p,
        None => {
            crate::sched::warn_env_once(var, &precision_warn_msg(var, raw, fallback));
            fallback
        }
    }
}

/// The exact warning line [`resolve_env_precision`] emits — split out so
/// the message contract (bad value named, expected vocabulary, fallback)
/// is unit testable without capturing stderr.
pub fn precision_warn_msg(var: &str, raw: &str, fallback: Precision) -> String {
    format!(
        "kitsune: ignoring {var}={raw:?} (expected f32|bf16|f16); falling back to {}",
        fallback.label()
    )
}

/// Read a precision knob from the environment: unset yields `fallback`,
/// set-but-unrecognized warns once and yields `fallback`.
pub fn env_precision(var: &str, fallback: Precision) -> Precision {
    match std::env::var(var) {
        Ok(raw) => resolve_env_precision(var, &raw, fallback),
        Err(_) => fallback,
    }
}

/// The process-default storage precision (`KITSUNE_PRECISION`, default
/// f32), resolved once — [`crate::session::SessionBuilder`] seeds its
/// precision from this, `.precision(..)` overrides per session.
pub fn default_precision() -> Precision {
    // 0 = unresolved, else 1 + discriminant.
    static CACHE: AtomicU8 = AtomicU8::new(0);
    match CACHE.load(Ordering::Relaxed) {
        1 => Precision::F32,
        2 => Precision::Bf16,
        3 => Precision::F16,
        _ => {
            let p = env_precision("KITSUNE_PRECISION", Precision::F32);
            let code = match p {
                Precision::F32 => 1,
                Precision::Bf16 => 2,
                Precision::F16 => 3,
            };
            CACHE.store(code, Ordering::Relaxed);
            p
        }
    }
}

// ---------------------------------------------------------------------
// f32 <-> bf16
// ---------------------------------------------------------------------

/// Narrow f32 to bfloat16 bits with round-to-nearest-even. NaNs are
/// quieted with their top payload bits preserved (never rounded up into
/// an infinity); everything else — including subnormals, which bf16
/// represents at the same exponents as f32 — goes through the RNE
/// increment, with overflow carrying naturally into the Inf encoding.
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Keep sign + top mantissa bits; force a quiet, nonzero payload.
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    (((bits + 0x7FFF + lsb) >> 16) & 0xFFFF) as u16
}

/// Widen bfloat16 bits to f32 — exact (bf16 is f32's top half).
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

// ---------------------------------------------------------------------
// f32 <-> f16
// ---------------------------------------------------------------------

/// Narrow f32 to IEEE binary16 bits with round-to-nearest-even,
/// handling subnormal results, underflow to signed zero, overflow to
/// infinity (the RNE cutover is 65520, not the max finite 65504), and
/// NaN quieting with the top payload bits preserved.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7FFF_FFFF;

    if abs > 0x7F80_0000 {
        // NaN: quiet bit forced, top 9 payload bits kept.
        return sign | 0x7E00 | ((abs >> 13) & 0x01FF) as u16;
    }
    if abs >= 0x477F_F000 {
        // Inf, or finite >= 65520 (rounds up past the max finite 65504).
        return sign | 0x7C00;
    }
    let exp = (abs >> 23) as i32 - 127;
    let man = abs & 0x007F_FFFF;
    if exp >= -14 {
        // Normal f16. Drop 13 mantissa bits with RNE; a mantissa carry
        // overflows into the exponent field, which is exactly right.
        let half = (((exp + 15) as u32) << 10) | (man >> 13);
        let round = (man >> 12) & 1;
        let sticky = u32::from(man & 0x0FFF != 0);
        let lsb = (man >> 13) & 1;
        sign | (half + (round & (sticky | lsb))) as u16
    } else if exp >= -25 {
        // Subnormal f16: the hidden bit becomes explicit, then RNE on
        // the variable-width shift. `kept + up` may carry into the
        // smallest normal — also exactly right.
        let man = man | 0x0080_0000;
        let shift = (13 - 14 - exp) as u32;
        let kept = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let up = u32::from(rem > halfway || (rem == halfway && kept & 1 == 1));
        sign | (kept + up) as u16
    } else {
        // Below half the smallest subnormal: signed zero.
        sign
    }
}

/// Widen IEEE binary16 bits to f32 — exact for every f16 value,
/// including subnormals (renormalized) and NaN payloads.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = match (exp, man) {
        (0, 0) => sign,
        (0, m) => {
            // Subnormal: value = m * 2^-24; renormalize around the
            // highest set bit p (0..=9).
            let p = 31 - m.leading_zeros();
            sign | ((p + 103) << 23) | ((m << (23 - p)) & 0x007F_FFFF)
        }
        (31, 0) => sign | 0x7F80_0000,
        (31, m) => sign | 0x7F80_0000 | 0x0040_0000 | (m << 13),
        (e, m) => sign | ((e + 112) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_labels_round_trip() {
        for p in [Precision::F32, Precision::Bf16, Precision::F16] {
            assert_eq!(Precision::parse(p.label()), Some(p));
        }
        assert_eq!(Precision::parse(" FP16 "), Some(Precision::F16));
        assert_eq!(Precision::parse("bfloat16"), Some(Precision::Bf16));
        assert_eq!(Precision::parse("int8"), None);
        assert_eq!(Precision::F32.bytes(), 4);
        assert_eq!(Precision::Bf16.bytes(), 2);
        assert_eq!(Precision::F16.bytes(), 2);
    }

    #[test]
    fn unparseable_precision_env_warns_with_fallback_in_message() {
        let p = resolve_env_precision("KITSUNE_PRECISION_TEST_BAD", "int8", Precision::F32);
        assert_eq!(p, Precision::F32);
        let p = resolve_env_precision("KITSUNE_PRECISION_TEST_OK", "bf16", Precision::F32);
        assert_eq!(p, Precision::Bf16);
        // The message names the variable, the bad value, the expected
        // vocabulary, and the fallback actually in use (the once-per-var
        // contract lives in sched's tests).
        let msg = precision_warn_msg("KITSUNE_PRECISION", "int8", Precision::F32);
        assert!(msg.contains("KITSUNE_PRECISION=\"int8\""), "{msg}");
        assert!(msg.contains("f32|bf16|f16"), "{msg}");
        assert!(msg.contains("falling back to f32"), "{msg}");
    }

    #[test]
    fn quantize_is_idempotent() {
        let mut rng = crate::runtime::Rng::new(0xBEEF);
        for p in [Precision::Bf16, Precision::F16] {
            for _ in 0..2000 {
                let x = (rng.normal()) * 100.0;
                let q = p.quantize(x);
                assert_eq!(q.to_bits(), p.quantize(q).to_bits(), "{p:?} {x}");
            }
        }
    }
}
