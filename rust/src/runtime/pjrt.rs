//! PJRT runtime backend (cargo feature `pjrt`): load AOT HLO-text
//! artifacts, compile once through the PJRT C API, execute many.
//!
//! Written against the `xla` crate surface (xla-rs lineage). HLO *text*
//! is the interchange format (see `python/compile/aot.py` — serialized
//! protos from jax ≥ 0.5 carry 64-bit instruction ids the bundled
//! xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!
//! In offline builds the `xla` dependency alias resolves to the
//! `vendor/xla-stub` crate: this module still type-checks (the point of
//! `cargo check --features pjrt`) but client construction returns a clear
//! "PJRT unavailable" error at run time. Point the alias at the real xla
//! crate to execute artifacts.

use super::backend::{Backend, Executable};
use super::manifest::EntrySpec;
use super::tensor::Tensor;
use anyhow::{anyhow, Context, Result};

/// Backend wrapping one PJRT client.
pub struct PjrtBackend {
    client: xla::PjRtClient,
}

// SAFETY: PJRT's C API is thread-safe for concurrent `Execute` calls on
// one client (the CPU plugin serializes internally where needed); the
// impls exist only because the raw-pointer-holding xla types don't derive
// Send/Sync.
unsafe impl Send for PjrtBackend {}
unsafe impl Sync for PjrtBackend {}

impl PjrtBackend {
    /// Construct over the PJRT CPU client.
    pub fn new() -> Result<Self> {
        Ok(PjrtBackend { client: xla::PjRtClient::cpu().map_err(wrap)? })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, spec: &EntrySpec) -> Result<Box<dyn Executable>> {
        let proto = xla::HloModuleProto::from_text_file(
            spec.hlo_path.to_str().context("non-utf8 path")?,
        )
        .map_err(wrap)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(wrap)?;
        Ok(Box::new(PjrtExecutable { exe }))
    }
}

/// One compiled entry point.
struct PjrtExecutable {
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: see `PjrtBackend` — concurrent Execute on one loaded
// executable is supported by the PJRT plugin contract.
unsafe impl Send for PjrtExecutable {}
unsafe impl Sync for PjrtExecutable {}

impl Executable for PjrtExecutable {
    fn run_f32(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals).map_err(wrap)?;
        let lit = result[0][0].to_literal_sync().map_err(wrap)?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = lit.to_tuple().map_err(wrap)?;
        parts.into_iter().map(from_literal).collect()
    }
}

fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(&t.data).reshape(&dims).map_err(wrap)
}

fn from_literal(lit: xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(wrap)?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    // Scalars and non-f32 outputs are converted to f32.
    let lit = lit.convert(xla::PrimitiveType::F32).map_err(wrap)?;
    let data = lit.to_vec::<f32>().map_err(wrap)?;
    Tensor::new(dims, data)
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}
