//! The pluggable runtime backend boundary.
//!
//! The coordinator, examples and tests program against [`Backend`] /
//! [`Executable`] only; which engine actually runs an artifact entry is a
//! build/deploy decision:
//!
//! * [`super::interp::InterpBackend`] (default, always available) — a
//!   pure-Rust tensor-program interpreter implementing the reference
//!   semantics of every shipped AOT entry. No XLA, no Python, no
//!   artifacts beyond `manifest.txt`.
//! * `super::pjrt::PjrtBackend` (cargo feature `pjrt`) — compiles the
//!   `artifacts/*.hlo.txt` HLO text through the PJRT C API and can run
//!   arbitrary entries. Off by default so a fresh offline checkout
//!   builds and tests green.
//!
//! Selection: the `pjrt` feature makes PJRT the default; the
//! `KITSUNE_BACKEND` environment variable (`interp` / `pjrt`) overrides.

use super::manifest::EntrySpec;
use super::tensor::Tensor;
use crate::Result;

/// Environment variable overriding the backend choice (`interp`/`pjrt`).
pub const BACKEND_ENV: &str = "KITSUNE_BACKEND";

/// A runtime engine that can compile manifest entries into executables.
pub trait Backend: Send + Sync {
    /// Short identifier (`"interp"`, `"pjrt"`).
    fn name(&self) -> &'static str;

    /// Human-readable platform string (PJRT reports its plugin platform).
    fn platform(&self) -> String {
        self.name().to_string()
    }

    /// Compile one manifest entry. Called once at load time — never on
    /// the request path.
    fn compile(&self, spec: &EntrySpec) -> Result<Box<dyn Executable>>;
}

/// A compiled artifact entry: f32 tensors in, f32 tensors out.
///
/// `Send + Sync` is part of the contract — the coordinator shares one
/// executable across all worker threads of a pipeline stage.
pub trait Executable: Send + Sync {
    fn run_f32(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Borrowed-input variant of [`Self::run_f32`] — the zero-copy hot
    /// path: stage workers pass `[&tile, &w, &b]` without cloning weights
    /// per tile. The default clones into owned tensors for backends whose
    /// native ABI needs them (PJRT buffer upload); the interpreter
    /// overrides it to execute directly on the borrows.
    fn run_f32_ref(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let owned: Vec<Tensor> = inputs.iter().map(|&t| t.clone()).collect();
        self.run_f32(&owned)
    }
}

/// Build the default backend for this binary: PJRT when the `pjrt`
/// feature is enabled (unless `KITSUNE_BACKEND=interp`), the pure-Rust
/// interpreter otherwise.
pub fn default_backend() -> Result<Box<dyn Backend>> {
    let choice = std::env::var(BACKEND_ENV).unwrap_or_default();
    match choice.as_str() {
        "" | "interp" | "pjrt" => {}
        other => anyhow::bail!("{BACKEND_ENV}={other} is not a backend (use `interp` or `pjrt`)"),
    }
    #[cfg(feature = "pjrt")]
    if choice != "interp" {
        return Ok(Box::new(super::pjrt::PjrtBackend::new()?));
    }
    #[cfg(not(feature = "pjrt"))]
    if choice == "pjrt" {
        anyhow::bail!(
            "{BACKEND_ENV}=pjrt requested but this binary was built without the `pjrt` feature"
        );
    }
    Ok(Box::new(super::interp::InterpBackend::new()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_backend_resolves() {
        // Without the pjrt feature this is always the interpreter; with it,
        // the stub client may fail to construct — both are valid outcomes,
        // the call must simply not panic.
        match default_backend() {
            Ok(b) => assert!(!b.name().is_empty()),
            Err(e) => assert!(e.to_string().contains("PJRT"), "{e}"),
        }
    }
}
