//! Typed runtime errors.
//!
//! `ArtifactStore::load` and friends surface *expected* failure modes —
//! a checkout without `artifacts/`, an entry a backend cannot execute —
//! as [`RuntimeError`] values that callers can `downcast_ref` out of the
//! `anyhow` chain, instead of pattern-matching message strings or raw io
//! error chains.

use std::fmt;
use std::path::PathBuf;

/// Expected runtime failure modes, downcastable from `anyhow::Error`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RuntimeError {
    /// The artifact directory has no `manifest.txt` — a fresh checkout.
    /// Tests and examples treat this as "skip the real-runtime path".
    ArtifactsMissing { dir: PathBuf },
    /// A requested entry name is not in the loaded manifest.
    UnknownEntry { name: String },
    /// The manifest names an entry the active backend cannot execute
    /// (e.g. an arbitrary HLO program under the interpreter backend).
    UnsupportedEntry { name: String, backend: &'static str },
    /// A pipeline stage died while this tile/step was in flight: the
    /// payload records which stage, where, and why. Produced by the
    /// supervised session/train pumps via [`crate::fault::catch_stage`];
    /// callers that want to react to the taxonomy (panic vs kernel error
    /// vs non-finite vs shutdown) downcast and match on
    /// [`crate::fault::FailureCause`].
    StageFailed(crate::fault::StageFailure),
    /// An SSA program read a register after its value was moved out
    /// (in-place consumption or output extraction). The interpreter's
    /// liveness pass makes this unreachable for well-formed programs, so
    /// hitting it means the program (or a hand-forged execution plan)
    /// is malformed — surfaced as a typed error instead of silently
    /// yielding an empty placeholder tensor.
    DeadRegister { reg: usize },
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RuntimeError::ArtifactsMissing { dir } => write!(
                f,
                "artifacts missing: no manifest.txt under {} — run `make artifacts` to \
                 generate them (optional: only the real-runtime demos need them)",
                dir.display()
            ),
            RuntimeError::UnknownEntry { name } => {
                write!(f, "unknown artifact entry {name}")
            }
            RuntimeError::UnsupportedEntry { name, backend } => write!(
                f,
                "artifact entry `{name}` is not supported by the `{backend}` backend — \
                 build with `--features pjrt` (and the real xla crate) to execute \
                 arbitrary HLO entries"
            ),
            RuntimeError::StageFailed(failure) => write!(f, "{failure}"),
            RuntimeError::DeadRegister { reg } => write!(
                f,
                "register {reg} was moved out of the value file before this read — \
                 the SSA program (or a mismatched execution plan) is malformed"
            ),
        }
    }
}

impl std::error::Error for RuntimeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_missing_message_names_the_fix() {
        let e = RuntimeError::ArtifactsMissing { dir: PathBuf::from("artifacts") };
        let s = e.to_string();
        assert!(s.contains("make artifacts"), "{s}");
        assert!(s.contains("artifacts"), "{s}");
    }

    #[test]
    fn stage_failed_downcasts_and_displays() {
        use crate::fault::{FailureCause, StageFailure};
        let failure = StageFailure::new("stage2", FailureCause::Panic("boom".into()))
            .at_index(2)
            .at_tile(7);
        let any = failure.clone().into_error();
        match any.downcast_ref::<RuntimeError>() {
            Some(RuntimeError::StageFailed(got)) => assert_eq!(*got, failure),
            other => panic!("expected StageFailed, got {other:?}"),
        }
        assert!(any.to_string().contains("panicked: boom"), "{any}");
    }

    #[test]
    fn downcasts_through_anyhow() {
        let any: anyhow::Error = RuntimeError::UnknownEntry { name: "x".into() }.into();
        assert!(matches!(
            any.downcast_ref::<RuntimeError>(),
            Some(RuntimeError::UnknownEntry { .. })
        ));
    }
}
