//! Plain-old-data tensors and the deterministic parameter generator —
//! the value types crossing the queue/runtime boundary. Backend-agnostic:
//! both the interpreter and the PJRT backend consume and produce these.

use super::precision::Precision;
use anyhow::{anyhow, Result};

/// Plain-old-data f32 tensor crossing the queue/runtime boundary.
/// (Queues carry `Tensor`, never backend-native buffers — PJRT literals
/// wrap raw pointers and stay thread-local inside the `pjrt` backend.)
///
/// `data` is always `Vec<f32>`; a 16-bit storage mode ([`Precision`])
/// means the values have been rounded to that format's grid and `prec`
/// tags the width every byte accountant (telemetry edge counters, the
/// serve registry) must charge for this payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
    /// Storage width this payload is held at (values on that grid).
    pub prec: Precision,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let numel: usize = dims.iter().product::<usize>().max(1);
        if data.len() != numel {
            return Err(anyhow!("tensor data {} != numel {numel}", data.len()));
        }
        Ok(Tensor { dims, data, prec: Precision::F32 })
    }

    pub fn zeros(dims: &[usize]) -> Self {
        let numel: usize = dims.iter().product::<usize>().max(1);
        Tensor { dims: dims.to_vec(), data: vec![0.0; numel], prec: Precision::F32 }
    }

    pub fn scalar_value(&self) -> f32 {
        self.data.first().copied().unwrap_or(f32::NAN)
    }

    /// Number of elements (equals `data.len()`; the data buffer may carry
    /// extra *capacity* when it came from the interpreter's buffer pool —
    /// never extra length).
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Bytes one element occupies at this tensor's storage width.
    pub fn element_bytes(&self) -> usize {
        self.prec.bytes()
    }

    /// Bytes this payload occupies at its storage width — what an edge
    /// crossing or a resident-memory accountant should charge.
    pub fn payload_bytes(&self) -> u64 {
        (self.numel() * self.element_bytes()) as u64
    }

    /// Round the values to `prec`'s storage grid and tag the tensor.
    /// Idempotent (re-rounding a grid value is the identity); a no-op
    /// for [`Precision::F32`].
    pub fn quantize(&mut self, prec: Precision) {
        if prec != Precision::F32 {
            prec.quantize_slice(&mut self.data);
        }
        self.prec = prec;
    }

    /// A copy rounded to `prec`'s grid (no copy avoidance for F32 — use
    /// at lowering boundaries, not per element).
    pub fn quantized(&self, prec: Precision) -> Tensor {
        let mut t = self.clone();
        t.quantize(prec);
        t
    }
}

/// Deterministic parameter/data generator (xorshift + Box-Muller): the
/// Rust-side analog of the model's He initialization, used by examples
/// and the coordinator when no checkpoint is supplied.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// He-initialized tensor for a `[fan_in, out]` weight (or zeros bias).
    pub fn he_tensor(&mut self, dims: &[usize]) -> Tensor {
        if dims.len() < 2 {
            return Tensor::zeros(dims);
        }
        let fan_in = dims[0] as f32;
        let scale = (2.0 / fan_in).sqrt();
        let numel: usize = dims.iter().product();
        let data = (0..numel).map(|_| self.normal() * scale).collect();
        Tensor { dims: dims.to_vec(), data, prec: Precision::F32 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_validates_numel() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn scalar_tensor_roundtrip() {
        let t = Tensor::new(vec![], vec![4.5]).unwrap();
        assert_eq!(t.scalar_value(), 4.5);
        assert_eq!(Tensor::zeros(&[]).data.len(), 1);
    }

    #[test]
    fn rng_deterministic_and_normalish() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut r = Rng::new(7);
        let xs: Vec<f32> = (0..10_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 1.0).abs() < 0.1, "{var}");
    }

    #[test]
    fn he_scaling() {
        let mut r = Rng::new(9);
        let t = r.he_tensor(&[256, 64]);
        let var = t.data.iter().map(|x| x * x).sum::<f32>() / t.data.len() as f32;
        let want = 2.0 / 256.0;
        assert!((var - want).abs() / want < 0.2, "{var} vs {want}");
        let b = r.he_tensor(&[64]);
        assert!(b.data.iter().all(|&x| x == 0.0));
    }
}
