//! Explicit 8-lane f32 vector layer for the interpreter's hot kernels.
//!
//! Two dispatch levels, both decided at run time:
//!
//! 1. **Engine level** — [`vector_enabled`] reads `KITSUNE_SIMD=0|1`
//!    (default on) through the shared warn-once env policy. When off,
//!    `runtime::interp` executes its original scalar kernels untouched,
//!    preserving the bitwise-oracle contract exactly as before this
//!    layer existed ([`Equivalence::Bitwise`]).
//! 2. **CPU level** — on x86_64 with AVX2+FMA detected
//!    (`is_x86_feature_detected!`), each kernel runs a
//!    `#[target_feature]` intrinsics path (256-bit loads, fused
//!    multiply-add); everywhere else a portable 8-lane-chunked Rust
//!    path that the compiler is free to autovectorize.
//!
//! The FMA paths fuse each multiply-add into a single rounding, which
//! re-associates nothing but *does* change low-order bits versus the
//! scalar `mul` + `add` sequence — the accumulation still runs
//! `kk = 0..k` in order, so the divergence is bounded to a few ULP per
//! element. [`Equivalence::Ulp`] is the explicit contract for that tier:
//! `tests/kernel_equivalence.rs` verifies the vector engine ULP-bounded
//! against the scalar oracle, and bitwise with `KITSUNE_SIMD=0`.
//! [`engine_equivalence`] returns the tier matching the live dispatch.

use std::sync::atomic::{AtomicU8, Ordering};

/// Lanes per vector: one 256-bit register of f32.
pub const LANES: usize = 8;

// ---------------------------------------------------------------------
// Dispatch
// ---------------------------------------------------------------------

/// `KITSUNE_SIMD` state: 0 unresolved, 1 forced off, 2 on.
static VECTOR_MODE: AtomicU8 = AtomicU8::new(0);

/// Whether the vector kernels are selected (the `KITSUNE_SIMD` knob,
/// default on). Resolved from the environment once; override with
/// [`set_vector_enabled`].
pub fn vector_enabled() -> bool {
    match VECTOR_MODE.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => {
            let on = crate::sched::env_switch("KITSUNE_SIMD", true);
            VECTOR_MODE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
            on
        }
    }
}

/// Force the engine-level dispatch (tests and benches compare both
/// paths in one process; mirrors `interp::set_matmul_par_threshold`).
pub fn set_vector_enabled(on: bool) {
    VECTOR_MODE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
}

#[cfg(target_arch = "x86_64")]
fn detect_fused() -> bool {
    std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_fused() -> bool {
    false
}

/// Whether the CPU-level AVX2+FMA paths are active (single-rounding
/// fused multiply-add — the only numeric divergence from scalar).
pub fn fused_madd() -> bool {
    // 0 unresolved, 1 no, 2 yes.
    static FUSED: AtomicU8 = AtomicU8::new(0);
    match FUSED.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => {
            let f = detect_fused();
            FUSED.store(if f { 2 } else { 1 }, Ordering::Relaxed);
            f
        }
    }
}

/// The live kernel path, for bench/telemetry labels.
pub fn dispatch_label() -> &'static str {
    if !vector_enabled() {
        "scalar"
    } else if fused_madd() {
        "avx2+fma"
    } else {
        "portable"
    }
}

// ---------------------------------------------------------------------
// Equivalence contract
// ---------------------------------------------------------------------

/// ULP bound for the vector engine against the scalar oracle. Each
/// fused multiply-add differs from mul+add by at most one rounding and
/// the contraction order is unchanged, so per-kernel drift is a few ULP;
/// 64 leaves headroom for values flowing through several fused GEMMs.
pub const VECTOR_ULP_BOUND: u32 = 64;

/// Absolute escape hatch under [`Equivalence::Ulp`]: when two
/// accumulations cancel to near zero, an eps-scale absolute difference
/// can be millions of ULP (subnormal spacing) while being numerically
/// meaningless. Differences at or below this magnitude always pass.
pub const ULP_ABS_FLOOR: f32 = 1e-6;

/// How strongly an engine's results must match the scalar oracle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Equivalence {
    /// Every element identical down to the bit pattern (NaN included) —
    /// the scalar engine's retained contract.
    Bitwise,
    /// Every element within `bound` ULP of the oracle (NaN must pair
    /// with NaN; differences ≤ [`ULP_ABS_FLOOR`] always pass) — the
    /// vector engine's contract.
    Ulp(u32),
}

/// Distance between two f32s in units-in-the-last-place, via the
/// monotonic sign-magnitude integer mapping (so the measure is exact
/// across exponent boundaries, and ±0 are 0 apart). `Some(0)` when both
/// are NaN (any payloads); `None` when exactly one is — incomparable.
pub fn ulp_diff(a: f32, b: f32) -> Option<u64> {
    if a.is_nan() || b.is_nan() {
        return if a.is_nan() && b.is_nan() { Some(0) } else { None };
    }
    fn key(x: f32) -> i64 {
        let b = x.to_bits();
        if b & 0x8000_0000 != 0 {
            -((b & 0x7FFF_FFFF) as i64)
        } else {
            b as i64
        }
    }
    Some((key(a) - key(b)).unsigned_abs())
}

impl Equivalence {
    /// Check `got` against the oracle `want`, reporting the first
    /// violating element.
    pub fn check(&self, got: &[f32], want: &[f32]) -> std::result::Result<(), String> {
        if got.len() != want.len() {
            return Err(format!("length mismatch: got {} want {}", got.len(), want.len()));
        }
        for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
            match *self {
                Equivalence::Bitwise => {
                    if g.to_bits() != w.to_bits() {
                        return Err(format!(
                            "bitwise mismatch at [{i}]: got {g:?} ({:#010x}) want {w:?} ({:#010x})",
                            g.to_bits(),
                            w.to_bits()
                        ));
                    }
                }
                Equivalence::Ulp(bound) => match ulp_diff(g, w) {
                    Some(d) if d <= u64::from(bound) => {}
                    d => {
                        if (g - w).abs() <= ULP_ABS_FLOOR {
                            continue;
                        }
                        return Err(format!(
                            "ulp mismatch at [{i}]: got {g:?} want {w:?} \
                             ({} ULP, bound {bound})",
                            d.map_or_else(|| "NaN vs number".to_string(), |d| d.to_string())
                        ));
                    }
                },
            }
        }
        Ok(())
    }
}

/// The equivalence tier the *current* optimized engine owes the scalar
/// oracle: bitwise when the vector layer is disabled (or running the
/// portable fallback, which keeps scalar op order), ULP-bounded when
/// the FMA paths are live.
pub fn engine_equivalence() -> Equivalence {
    if vector_enabled() && fused_madd() {
        Equivalence::Ulp(VECTOR_ULP_BOUND)
    } else {
        Equivalence::Bitwise
    }
}

// ---------------------------------------------------------------------
// Matmul micro-kernel panel
// ---------------------------------------------------------------------

const MR: usize = 4;
const NR: usize = LANES;

/// Vector twin of `interp::matmul_panel`: compute output rows `i0..i1`
/// into `out` (row-major `[i1-i0, n]`), contraction strictly `kk = 0..k`
/// in order per element, no zero-skip (NaN propagates), optional fused
/// bias epilogue after the full sum. Full MR×NR blocks run 8-wide; edge
/// blocks and the transposed-B lane gather stay scalar.
#[allow(clippy::too_many_arguments)]
pub fn matmul_panel(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    i0: usize,
    i1: usize,
    k: usize,
    n: usize,
    lda: usize,
    ldb: usize,
    ta: bool,
    tb: bool,
    bias: Option<&[f32]>,
) {
    #[cfg(target_arch = "x86_64")]
    if fused_madd() {
        // SAFETY: AVX2+FMA presence checked at run time.
        unsafe { matmul_panel_avx(a, b, out, i0, i1, k, n, lda, ldb, ta, tb, bias) };
        return;
    }
    matmul_panel_portable(a, b, out, i0, i1, k, n, lda, ldb, ta, tb, bias);
}

/// Scalar edge block shared by both vector paths: rows `ib0..ib1`
/// (panel-relative) × cols `jb..jb+nr`, identical accumulation order to
/// the scalar engine's edge handling.
#[allow(clippy::too_many_arguments)]
fn edge_block(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    i0: usize,
    ib0: usize,
    ib1: usize,
    jb: usize,
    nr: usize,
    k: usize,
    n: usize,
    lda: usize,
    ldb: usize,
    ta: bool,
    tb: bool,
) {
    for r in ib0..ib1 {
        let i = i0 + r;
        for c in 0..nr {
            let j = jb + c;
            let mut acc = 0.0f32;
            for kk in 0..k {
                let av = if ta { a[kk * lda + i] } else { a[i * lda + kk] };
                let bvc = if tb { b[j * ldb + kk] } else { b[kk * ldb + j] };
                acc += av * bvc;
            }
            out[r * n + j] = acc;
        }
    }
}

/// Bias epilogue shared by both vector paths — one exact add per
/// element after the full contraction, same as the scalar engine.
fn bias_epilogue(out: &mut [f32], n: usize, bias: Option<&[f32]>) {
    if n == 0 {
        return;
    }
    if let Some(bias) = bias {
        for row in out.chunks_exact_mut(n) {
            add_rows_portable(row, bias);
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
#[allow(clippy::too_many_arguments)]
unsafe fn matmul_panel_avx(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    i0: usize,
    i1: usize,
    k: usize,
    n: usize,
    lda: usize,
    ldb: usize,
    ta: bool,
    tb: bool,
    bias: Option<&[f32]>,
) {
    use core::arch::x86_64::*;
    let rows = i1 - i0;
    let mut jb = 0;
    while jb + NR <= n {
        let mut ib = 0;
        while ib + MR <= rows {
            let mut acc = [_mm256_setzero_ps(); MR];
            for kk in 0..k {
                let bv = if tb {
                    // Lane gather down the transposed columns (high lane
                    // first in `set_ps` operand order).
                    _mm256_set_ps(
                        b[(jb + 7) * ldb + kk],
                        b[(jb + 6) * ldb + kk],
                        b[(jb + 5) * ldb + kk],
                        b[(jb + 4) * ldb + kk],
                        b[(jb + 3) * ldb + kk],
                        b[(jb + 2) * ldb + kk],
                        b[(jb + 1) * ldb + kk],
                        b[jb * ldb + kk],
                    )
                } else {
                    _mm256_loadu_ps(b.as_ptr().add(kk * ldb + jb))
                };
                for (r, slot) in acc.iter_mut().enumerate() {
                    let i = i0 + ib + r;
                    let av =
                        _mm256_set1_ps(if ta { a[kk * lda + i] } else { a[i * lda + kk] });
                    *slot = _mm256_fmadd_ps(av, bv, *slot);
                }
            }
            for (r, slot) in acc.iter().enumerate() {
                _mm256_storeu_ps(out.as_mut_ptr().add((ib + r) * n + jb), *slot);
            }
            ib += MR;
        }
        edge_block(a, b, out, i0, ib, rows, jb, NR, k, n, lda, ldb, ta, tb);
        jb += NR;
    }
    if jb < n {
        edge_block(a, b, out, i0, 0, rows, jb, n - jb, k, n, lda, ldb, ta, tb);
    }
    bias_epilogue(out, n, bias);
}

/// Portable fallback: the same MR×NR register blocking with plain
/// mul+add over `[f32; 8]` chunks — bitwise-identical to the scalar
/// engine (same op sequence), and autovectorizable where the target
/// allows.
#[allow(clippy::too_many_arguments)]
fn matmul_panel_portable(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    i0: usize,
    i1: usize,
    k: usize,
    n: usize,
    lda: usize,
    ldb: usize,
    ta: bool,
    tb: bool,
    bias: Option<&[f32]>,
) {
    let rows = i1 - i0;
    let mut jb = 0;
    while jb + NR <= n {
        let mut ib = 0;
        while ib + MR <= rows {
            let mut acc = [[0.0f32; NR]; MR];
            for kk in 0..k {
                let mut bv = [0.0f32; NR];
                if tb {
                    for (c, slot) in bv.iter_mut().enumerate() {
                        *slot = b[(jb + c) * ldb + kk];
                    }
                } else {
                    bv.copy_from_slice(&b[kk * ldb + jb..kk * ldb + jb + NR]);
                }
                for (r, acc_row) in acc.iter_mut().enumerate() {
                    let i = i0 + ib + r;
                    let av = if ta { a[kk * lda + i] } else { a[i * lda + kk] };
                    for (o, &bvc) in acc_row.iter_mut().zip(&bv) {
                        *o += av * bvc;
                    }
                }
            }
            for (r, acc_row) in acc.iter().enumerate() {
                let base = (ib + r) * n + jb;
                out[base..base + NR].copy_from_slice(acc_row);
            }
            ib += MR;
        }
        edge_block(a, b, out, i0, ib, rows, jb, NR, k, n, lda, ldb, ta, tb);
        jb += NR;
    }
    if jb < n {
        edge_block(a, b, out, i0, 0, rows, jb, n - jb, k, n, lda, ldb, ta, tb);
    }
    bias_epilogue(out, n, bias);
}

// ---------------------------------------------------------------------
// Elementwise assign-kernels
// ---------------------------------------------------------------------
//
// All elementwise vector kernels are *assign* style: the destination
// slice arrives holding the first operand's values (in-place execution
// passes the owned buffer directly; out-of-place copies first — a
// memcpy plus one vector sweep still beats the scalar element loop).
// AVX remainder lanes (< 8 trailing elements) use `f32::mul_add` where
// the vector op fuses, keeping one rounding semantics per element
// across the whole slice.

/// Per-row bias add: `x[r*n + j] += bias[j]` — `x.len()` must be a
/// multiple of `bias.len()`.
pub fn add_bias_assign(x: &mut [f32], bias: &[f32]) {
    debug_assert!(!bias.is_empty() && x.len() % bias.len() == 0);
    #[cfg(target_arch = "x86_64")]
    if fused_madd() {
        for row in x.chunks_exact_mut(bias.len()) {
            // SAFETY: AVX2 presence checked at run time.
            unsafe { add_rows_avx(row, bias) };
        }
        return;
    }
    for row in x.chunks_exact_mut(bias.len()) {
        add_rows_portable(row, bias);
    }
}

fn add_rows_portable(x: &mut [f32], b: &[f32]) {
    for (v, &bv) in x.iter_mut().zip(b) {
        *v += bv;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn add_rows_avx(x: &mut [f32], b: &[f32]) {
    use core::arch::x86_64::*;
    let mut i = 0;
    while i + LANES <= x.len() {
        let v = _mm256_add_ps(
            _mm256_loadu_ps(x.as_ptr().add(i)),
            _mm256_loadu_ps(b.as_ptr().add(i)),
        );
        _mm256_storeu_ps(x.as_mut_ptr().add(i), v);
        i += LANES;
    }
    while i < x.len() {
        x[i] += b[i];
        i += 1;
    }
}

/// `x[i] = if x[i] > 0 { x[i] } else { 0.0 }` — the Relu sweep.
/// NaN maps to 0.0, exactly like the scalar `Act::apply`.
pub fn relu_assign(x: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if fused_madd() {
        // SAFETY: AVX2 presence checked at run time.
        unsafe { relu_avx(x) };
        return;
    }
    for v in x {
        *v = if *v > 0.0 { *v } else { 0.0 };
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn relu_avx(x: &mut [f32]) {
    use core::arch::x86_64::*;
    let zero = _mm256_setzero_ps();
    let mut i = 0;
    while i + LANES <= x.len() {
        let v = _mm256_loadu_ps(x.as_ptr().add(i));
        // Mask of lanes strictly > 0 (NaN compares false -> 0.0, the
        // scalar kernel's NaN behavior).
        let mask = _mm256_cmp_ps::<_CMP_GT_OQ>(v, zero);
        _mm256_storeu_ps(x.as_mut_ptr().add(i), _mm256_and_ps(v, mask));
        i += LANES;
    }
    while i < x.len() {
        x[i] = if x[i] > 0.0 { x[i] } else { 0.0 };
        i += 1;
    }
}

/// `g[i] = if x[i] > 0 { g[i] } else { 0.0 }` — the ReluGrad sweep.
pub fn relu_grad_assign(g: &mut [f32], x: &[f32]) {
    debug_assert_eq!(g.len(), x.len());
    #[cfg(target_arch = "x86_64")]
    if fused_madd() {
        // SAFETY: AVX2 presence checked at run time.
        unsafe { relu_grad_avx(g, x) };
        return;
    }
    for (gv, &xv) in g.iter_mut().zip(x) {
        *gv = if xv > 0.0 { *gv } else { 0.0 };
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn relu_grad_avx(g: &mut [f32], x: &[f32]) {
    use core::arch::x86_64::*;
    let zero = _mm256_setzero_ps();
    let mut i = 0;
    while i + LANES <= g.len() {
        let gv = _mm256_loadu_ps(g.as_ptr().add(i));
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let mask = _mm256_cmp_ps::<_CMP_GT_OQ>(xv, zero);
        _mm256_storeu_ps(g.as_mut_ptr().add(i), _mm256_and_ps(gv, mask));
        i += LANES;
    }
    while i < g.len() {
        g[i] = if x[i] > 0.0 { g[i] } else { 0.0 };
        i += 1;
    }
}

/// `g[i] = g[i] * (if x[i] > 0 { 1.0 } else { 0.0 })` — the ActGradI
/// sweep for Relu. Unlike [`relu_grad_assign`] this *multiplies* by the
/// 0/1 gate (the scalar `g * Act::grad_at(x)` sequence), so `g = NaN`
/// stays NaN and negative `g` yields `-0.0` in the dead region — exact.
pub fn relu_act_grad_assign(g: &mut [f32], x: &[f32]) {
    debug_assert_eq!(g.len(), x.len());
    #[cfg(target_arch = "x86_64")]
    if fused_madd() {
        // SAFETY: AVX2 presence checked at run time.
        unsafe { relu_act_grad_avx(g, x) };
        return;
    }
    for (gv, &xv) in g.iter_mut().zip(x) {
        *gv *= if xv > 0.0 { 1.0 } else { 0.0 };
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn relu_act_grad_avx(g: &mut [f32], x: &[f32]) {
    use core::arch::x86_64::*;
    let zero = _mm256_setzero_ps();
    let one = _mm256_set1_ps(1.0);
    let mut i = 0;
    while i + LANES <= g.len() {
        let gv = _mm256_loadu_ps(g.as_ptr().add(i));
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        // 1.0/0.0 gate, then a real multiply — keeps gv's NaN and sign.
        let gate = _mm256_and_ps(one, _mm256_cmp_ps::<_CMP_GT_OQ>(xv, zero));
        _mm256_storeu_ps(g.as_mut_ptr().add(i), _mm256_mul_ps(gv, gate));
        i += LANES;
    }
    while i < g.len() {
        g[i] *= if x[i] > 0.0 { 1.0 } else { 0.0 };
        i += 1;
    }
}

/// `x[i] = x[i] + c * b[i]` — the Axpy kernel (fused on AVX).
pub fn axpy_assign(x: &mut [f32], b: &[f32], c: f32) {
    debug_assert_eq!(x.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if fused_madd() {
        // SAFETY: AVX2+FMA presence checked at run time.
        unsafe { axpy_avx(x, b, c) };
        return;
    }
    for (xv, &bv) in x.iter_mut().zip(b) {
        *xv += c * bv;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy_avx(x: &mut [f32], b: &[f32], c: f32) {
    use core::arch::x86_64::*;
    let cv = _mm256_set1_ps(c);
    let mut i = 0;
    while i + LANES <= x.len() {
        let v = _mm256_fmadd_ps(
            cv,
            _mm256_loadu_ps(b.as_ptr().add(i)),
            _mm256_loadu_ps(x.as_ptr().add(i)),
        );
        _mm256_storeu_ps(x.as_mut_ptr().add(i), v);
        i += LANES;
    }
    while i < x.len() {
        x[i] = c.mul_add(b[i], x[i]);
        i += 1;
    }
}

/// `x[i] = c * x[i]` — the Scale sweep (exact; no fusion involved).
pub fn scale_assign(x: &mut [f32], c: f32) {
    #[cfg(target_arch = "x86_64")]
    if fused_madd() {
        // SAFETY: AVX2 presence checked at run time.
        unsafe { scale_avx(x, c) };
        return;
    }
    for v in x {
        *v = c * *v;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scale_avx(x: &mut [f32], c: f32) {
    use core::arch::x86_64::*;
    let cv = _mm256_set1_ps(c);
    let mut i = 0;
    while i + LANES <= x.len() {
        let v = _mm256_mul_ps(cv, _mm256_loadu_ps(x.as_ptr().add(i)));
        _mm256_storeu_ps(x.as_mut_ptr().add(i), v);
        i += LANES;
    }
    while i < x.len() {
        x[i] = c * x[i];
        i += 1;
    }
}

/// `x[i] = x[i] * b[i]` — the Mul sweep (exact).
pub fn mul_assign(x: &mut [f32], b: &[f32]) {
    debug_assert_eq!(x.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if fused_madd() {
        // SAFETY: AVX2 presence checked at run time.
        unsafe { mul_avx(x, b) };
        return;
    }
    for (xv, &bv) in x.iter_mut().zip(b) {
        *xv *= bv;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mul_avx(x: &mut [f32], b: &[f32]) {
    use core::arch::x86_64::*;
    let mut i = 0;
    while i + LANES <= x.len() {
        let v = _mm256_mul_ps(
            _mm256_loadu_ps(x.as_ptr().add(i)),
            _mm256_loadu_ps(b.as_ptr().add(i)),
        );
        _mm256_storeu_ps(x.as_mut_ptr().add(i), v);
        i += LANES;
    }
    while i < x.len() {
        x[i] *= b[i];
        i += 1;
    }
}

/// `x[i] = beta * x[i] + (1 - beta) * b[i]` — the Blend (momentum)
/// kernel; the second product fuses into the first on AVX.
pub fn blend_assign(x: &mut [f32], b: &[f32], beta: f32) {
    debug_assert_eq!(x.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if fused_madd() {
        // SAFETY: AVX2+FMA presence checked at run time.
        unsafe { blend_avx(x, b, beta) };
        return;
    }
    let ib = 1.0 - beta;
    for (xv, &bv) in x.iter_mut().zip(b) {
        *xv = beta * *xv + ib * bv;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn blend_avx(x: &mut [f32], b: &[f32], beta: f32) {
    use core::arch::x86_64::*;
    let betav = _mm256_set1_ps(beta);
    let ibv = _mm256_set1_ps(1.0 - beta);
    let mut i = 0;
    while i + LANES <= x.len() {
        let tail = _mm256_mul_ps(ibv, _mm256_loadu_ps(b.as_ptr().add(i)));
        let v = _mm256_fmadd_ps(betav, _mm256_loadu_ps(x.as_ptr().add(i)), tail);
        _mm256_storeu_ps(x.as_mut_ptr().add(i), v);
        i += LANES;
    }
    let ib = 1.0 - beta;
    while i < x.len() {
        x[i] = beta.mul_add(x[i], ib * b[i]);
        i += 1;
    }
}

/// `d[i] = d[i] * y[i] * (1 - y[i])` — the SigmoidGrad sweep, same op
/// order as the scalar kernel (exact: no fusion).
pub fn sigmoid_grad_assign(d: &mut [f32], y: &[f32]) {
    debug_assert_eq!(d.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if fused_madd() {
        // SAFETY: AVX2 presence checked at run time.
        unsafe { sigmoid_grad_avx(d, y) };
        return;
    }
    for (dv, &yv) in d.iter_mut().zip(y) {
        *dv = *dv * yv * (1.0 - yv);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sigmoid_grad_avx(d: &mut [f32], y: &[f32]) {
    use core::arch::x86_64::*;
    let one = _mm256_set1_ps(1.0);
    let mut i = 0;
    while i + LANES <= d.len() {
        let dv = _mm256_loadu_ps(d.as_ptr().add(i));
        let yv = _mm256_loadu_ps(y.as_ptr().add(i));
        let v = _mm256_mul_ps(_mm256_mul_ps(dv, yv), _mm256_sub_ps(one, yv));
        _mm256_storeu_ps(d.as_mut_ptr().add(i), v);
        i += LANES;
    }
    while i < d.len() {
        d[i] = d[i] * y[i] * (1.0 - y[i]);
        i += 1;
    }
}

/// `p[i] = p[i] - lr * (m[i] / bc1) / (sqrt(v[i] / bc2) + eps)` — the
/// AdamStep update. Division and square root are correctly rounded, so
/// the vector path is exact versus the scalar kernel.
#[allow(clippy::too_many_arguments)]
pub fn adam_assign(p: &mut [f32], m: &[f32], v: &[f32], lr: f32, bc1: f32, bc2: f32, eps: f32) {
    debug_assert!(p.len() == m.len() && p.len() == v.len());
    #[cfg(target_arch = "x86_64")]
    if fused_madd() {
        // SAFETY: AVX2 presence checked at run time.
        unsafe { adam_avx(p, m, v, lr, bc1, bc2, eps) };
        return;
    }
    for ((pv, &mv), &vv) in p.iter_mut().zip(m).zip(v) {
        *pv -= lr * (mv / bc1) / ((vv / bc2).sqrt() + eps);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn adam_avx(p: &mut [f32], m: &[f32], v: &[f32], lr: f32, bc1: f32, bc2: f32, eps: f32) {
    use core::arch::x86_64::*;
    let lrv = _mm256_set1_ps(lr);
    let bc1v = _mm256_set1_ps(bc1);
    let bc2v = _mm256_set1_ps(bc2);
    let epsv = _mm256_set1_ps(eps);
    let mut i = 0;
    while i + LANES <= p.len() {
        let pv = _mm256_loadu_ps(p.as_ptr().add(i));
        let mv = _mm256_loadu_ps(m.as_ptr().add(i));
        let vv = _mm256_loadu_ps(v.as_ptr().add(i));
        let mhat = _mm256_div_ps(mv, bc1v);
        let denom = _mm256_add_ps(_mm256_sqrt_ps(_mm256_div_ps(vv, bc2v)), epsv);
        let step = _mm256_div_ps(_mm256_mul_ps(lrv, mhat), denom);
        _mm256_storeu_ps(p.as_mut_ptr().add(i), _mm256_sub_ps(pv, step));
        i += LANES;
    }
    while i < p.len() {
        p[i] -= lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + eps);
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vals(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = crate::runtime::Rng::new(seed);
        (0..n).map(|_| rng.normal() * 2.0).collect()
    }

    #[test]
    fn ulp_diff_properties() {
        assert_eq!(ulp_diff(1.0, 1.0), Some(0));
        assert_eq!(ulp_diff(0.0, -0.0), Some(0));
        assert_eq!(ulp_diff(1.0, f32::from_bits(1.0f32.to_bits() + 1)), Some(1));
        // Monotonic across the sign boundary.
        assert_eq!(ulp_diff(f32::from_bits(1), -f32::from_bits(1)), Some(2));
        assert_eq!(ulp_diff(f32::NAN, f32::NAN), Some(0));
        assert_eq!(ulp_diff(f32::NAN, 1.0), None);
    }

    #[test]
    fn equivalence_tiers_accept_and_reject() {
        let a = [1.0f32, 2.0, f32::NAN];
        let b = [1.0f32, 2.0, f32::NAN];
        Equivalence::Bitwise.check(&a, &b).unwrap();
        Equivalence::Ulp(0).check(&a, &b).unwrap();
        let nudged = [1.0f32, f32::from_bits(2.0f32.to_bits() + 3), f32::NAN];
        assert!(Equivalence::Bitwise.check(&nudged, &b).is_err());
        Equivalence::Ulp(4).check(&nudged, &b).unwrap();
        assert!(Equivalence::Ulp(2).check(&nudged, &b).is_err());
        // Near-zero cancellation passes on the absolute floor.
        Equivalence::Ulp(1).check(&[1e-8], &[-1e-8]).unwrap();
        // One-sided NaN never passes.
        assert!(Equivalence::Ulp(u32::MAX).check(&[f32::NAN], &[1.0]).is_err());
    }

    #[test]
    fn elementwise_kernels_match_scalar_within_ulp() {
        let n = 61; // force remainder lanes
        let x0 = vals(1, n);
        let b = vals(2, n);
        let tol = Equivalence::Ulp(1);

        let mut x = x0.clone();
        axpy_assign(&mut x, &b, 0.37);
        let want: Vec<f32> = x0.iter().zip(&b).map(|(&a, &bv)| a + 0.37 * bv).collect();
        tol.check(&x, &want).unwrap();

        let mut x = x0.clone();
        blend_assign(&mut x, &b, 0.9);
        let want: Vec<f32> =
            x0.iter().zip(&b).map(|(&a, &bv)| 0.9 * a + (1.0 - 0.9) * bv).collect();
        tol.check(&x, &want).unwrap();

        // Exact sweeps: mul/scale/relu/relu-grad/sigmoid-grad/adam are
        // unfused, so the vector paths must be bitwise.
        let exact = Equivalence::Bitwise;
        let mut x = x0.clone();
        mul_assign(&mut x, &b);
        let want: Vec<f32> = x0.iter().zip(&b).map(|(&a, &bv)| a * bv).collect();
        exact.check(&x, &want).unwrap();

        let mut x = x0.clone();
        scale_assign(&mut x, -1.25);
        let want: Vec<f32> = x0.iter().map(|&a| -1.25 * a).collect();
        exact.check(&x, &want).unwrap();

        let mut x = x0.clone();
        x[3] = f32::NAN; // NaN lane must map to 0.0 like the scalar kernel
        let nan_in = x.clone();
        relu_assign(&mut x);
        let want: Vec<f32> =
            nan_in.iter().map(|&a| if a > 0.0 { a } else { 0.0 }).collect();
        exact.check(&x, &want).unwrap();

        let mut g = x0.clone();
        relu_grad_assign(&mut g, &b);
        let want: Vec<f32> = x0
            .iter()
            .zip(&b)
            .map(|(&gv, &xv)| if xv > 0.0 { gv } else { 0.0 })
            .collect();
        exact.check(&g, &want).unwrap();

        let mut g = x0.clone();
        g[5] = f32::NAN; // ActGradI keeps g's NaN even in the dead region
        let g_in = g.clone();
        relu_act_grad_assign(&mut g, &b);
        let want: Vec<f32> = g_in
            .iter()
            .zip(&b)
            .map(|(&gv, &xv)| gv * if xv > 0.0 { 1.0 } else { 0.0 })
            .collect();
        exact.check(&g, &want).unwrap();

        let mut d = x0.clone();
        let y: Vec<f32> = b.iter().map(|&v| 1.0 / (1.0 + (-v).exp())).collect();
        sigmoid_grad_assign(&mut d, &y);
        let want: Vec<f32> =
            x0.iter().zip(&y).map(|(&dv, &yv)| dv * yv * (1.0 - yv)).collect();
        exact.check(&d, &want).unwrap();

        let mut p = x0.clone();
        let m = vals(3, n);
        let v: Vec<f32> = vals(4, n).iter().map(|&x| x * x).collect();
        adam_assign(&mut p, &m, &v, 1e-3, 0.9, 0.99, 1e-8);
        let want: Vec<f32> = x0
            .iter()
            .zip(&m)
            .zip(&v)
            .map(|((&pv, &mv), &vv)| pv - 1e-3 * (mv / 0.9) / ((vv / 0.99).sqrt() + 1e-8))
            .collect();
        exact.check(&p, &want).unwrap();

        let bias = vals(5, 7);
        let mut x = vals(6, 7 * 9);
        let want: Vec<f32> = x
            .chunks_exact(7)
            .flat_map(|row| row.iter().zip(&bias).map(|(&v, &bv)| v + bv))
            .collect();
        add_bias_assign(&mut x, &bias);
        exact.check(&x, &want).unwrap();
    }

    #[test]
    fn vector_matmul_panel_is_ulp_bounded_against_scalar_order() {
        for (m, k, n, ta, tb) in
            [(13, 31, 23, false, false), (9, 17, 11, true, false), (12, 19, 16, false, true)]
        {
            // Entries scaled to ~[-0.25, 0.25]: worst-case FMA drift at
            // k<=31 then sits far inside the tier's absolute floor, so
            // the bound holds even on outputs that cancel toward zero.
            let shrink = |v: Vec<f32>| -> Vec<f32> { v.iter().map(|x| x * 0.03125).collect() };
            let a = shrink(vals(10 + m as u64, m * k));
            let b = shrink(vals(20 + n as u64, k * n));
            let bias = shrink(vals(30, n));
            let (lda, ldb) = if ta { (m, n) } else { (k, n) };
            let (lda, ldb) = if tb { (lda, k) } else { (lda, ldb) };
            let mut got = vec![0.0f32; m * n];
            matmul_panel(&a, &b, &mut got, 0, m, k, n, lda, ldb, ta, tb, Some(&bias));
            // Scalar oracle: plain kk-order triple loop + bias.
            let mut want = vec![0.0f32; m * n];
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for kk in 0..k {
                        let av = if ta { a[kk * lda + i] } else { a[i * lda + kk] };
                        let bv = if tb { b[j * ldb + kk] } else { b[kk * ldb + j] };
                        acc += av * bv;
                    }
                    want[i * n + j] = acc + bias[j];
                }
            }
            Equivalence::Ulp(VECTOR_ULP_BOUND).check(&got, &want).unwrap();
        }
    }
}
