//! PJRT runtime: load `artifacts/*.hlo.txt` (AOT-lowered by
//! `python/compile/aot.py`), compile once on the PJRT CPU client, execute
//! from the L3 hot path. Python never runs at request time.

pub mod client;
pub mod manifest;

pub use client::{ArtifactStore, Rng, Tensor};
pub use manifest::{parse_manifest, EntrySpec, TensorSpec};
