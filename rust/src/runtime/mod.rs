//! Runtime layer: load `artifacts/manifest.txt` entries and execute them
//! through a pluggable [`Backend`].
//!
//! Two backends implement the boundary:
//!
//! * [`interp`] — pure-Rust tensor-program interpreter, the **default**.
//!   Runs every shipped AOT entry (forward, train step, pipeline stages)
//!   with no XLA runtime, no Python, and no network — a fresh offline
//!   checkout builds, tests, and serves.
//! * [`pjrt`] (cargo feature `pjrt`, off by default) — compiles the
//!   `artifacts/*.hlo.txt` lowered by `python/compile/aot.py` through the
//!   PJRT C API (`xla` crate) and can execute arbitrary HLO entries.
//!   Offline builds link a type-level stub; see README.md for swapping in
//!   the real crate.
//!
//! Python appears at build time only: `python/compile/aot.py` lowers the
//! L2 model and L1 kernels to HLO *text* under `artifacts/`. Nothing on
//! the request path imports Python.

pub mod backend;
pub mod client;
pub mod error;
pub mod interp;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod precision;
pub mod simd;
pub mod tensor;

pub use backend::{default_backend, Backend, Executable, BACKEND_ENV};
pub use client::ArtifactStore;
pub use error::RuntimeError;
pub use interp::{bound_executable, program_executable, InterpBackend};
pub use manifest::{parse_manifest, EntrySpec, TensorSpec};
pub use precision::Precision;
pub use simd::{engine_equivalence, ulp_diff, Equivalence};
pub use tensor::{Rng, Tensor};
