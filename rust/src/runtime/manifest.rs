//! Artifact manifest parsing.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt`, one line per
//! AOT entry point:
//!
//! ```text
//! name<TAB>file.hlo.txt<TAB>in=f32[1024,60],f32[60,256],...<TAB>out=9
//! ```

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Tensor spec of one executable input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    fn parse(s: &str) -> Result<Self> {
        let open = s.find('[').context("missing [ in tensor spec")?;
        if !s.ends_with(']') {
            bail!("missing ] in tensor spec {s}");
        }
        let dtype = s[..open].to_string();
        let dims_str = &s[open + 1..s.len() - 1];
        let dims = if dims_str.is_empty() {
            Vec::new()
        } else {
            dims_str
                .split(',')
                .map(|d| d.parse::<usize>().context("bad dim"))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSpec { dtype, dims })
    }
}

/// One AOT entry point.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub hlo_path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub n_outputs: usize,
}

/// Parse `manifest.txt` from an artifact directory.
pub fn parse_manifest(dir: &Path) -> Result<Vec<EntrySpec>> {
    let path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
    let mut entries = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split('\t').collect();
        if fields.len() != 4 {
            bail!("manifest line {} malformed: {line}", lineno + 1);
        }
        let ins = fields[2]
            .strip_prefix("in=")
            .with_context(|| format!("line {}: missing in=", lineno + 1))?;
        // Split on ',' only at type boundaries: specs look like
        // `f32[a,b]` so we split on "],".
        let mut inputs = Vec::new();
        let mut rest = ins;
        while !rest.is_empty() {
            match rest.find("],") {
                Some(i) => {
                    inputs.push(TensorSpec::parse(&rest[..=i])?);
                    rest = &rest[i + 2..];
                }
                None => {
                    inputs.push(TensorSpec::parse(rest)?);
                    break;
                }
            }
        }
        let n_outputs: usize = fields[3]
            .strip_prefix("out=")
            .with_context(|| format!("line {}: missing out=", lineno + 1))?
            .parse()?;
        entries.push(EntrySpec {
            name: fields[0].to_string(),
            hlo_path: dir.join(fields[1]),
            inputs,
            n_outputs,
        });
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tensor_specs() {
        let t = TensorSpec::parse("f32[1024,60]").unwrap();
        assert_eq!(t.dtype, "f32");
        assert_eq!(t.dims, vec![1024, 60]);
        assert_eq!(t.numel(), 1024 * 60);
        let s = TensorSpec::parse("f32[]").unwrap();
        assert_eq!(s.numel(), 1);
    }

    #[test]
    fn parses_manifest_line() {
        let dir = std::env::temp_dir().join("kitsune_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "fwd\tfwd.hlo.txt\tin=f32[128,60],f32[60,256],f32[256]\tout=1\n",
        )
        .unwrap();
        let entries = parse_manifest(&dir).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].name, "fwd");
        assert_eq!(entries[0].inputs.len(), 3);
        assert_eq!(entries[0].inputs[2].dims, vec![256]);
        assert_eq!(entries[0].n_outputs, 1);
    }

    #[test]
    fn rejects_malformed() {
        assert!(TensorSpec::parse("f32[3").is_err());
        assert!(TensorSpec::parse("nodims").is_err());
    }
}
