//! Pure-Rust interpreter backend — the default runtime engine.
//!
//! Each AOT entry in `artifacts/manifest.txt` lowers to a straight-line
//! SSA tensor [`Program`] (matmuls, bias adds, activations, their VJPs,
//! and the SGD update) which this module interprets over [`Tensor`]s.
//! The programs implement the reference semantics of
//! `python/compile/model.py` — the same math the HLO artifacts encode —
//! so the full coordinator/example/test stack runs on a fresh offline
//! checkout with no XLA runtime and no Python. Shapes are read from the
//! operands at run time, so the same program serves the real AOT shapes
//! and the small synthetic manifests the tests use.
//!
//! Gradient programs are hand-derived reverse-mode; the test suite checks
//! them against central finite differences (see `entry_program` tests),
//! and the PJRT integration tests cross-check numerics whenever real
//! artifacts plus the `pjrt` feature are present.

use super::backend::{Backend, Executable};
use super::error::RuntimeError;
use super::manifest::EntrySpec;
use super::tensor::Tensor;
use crate::Result;
use anyhow::{anyhow, ensure, Context};

/// Register index into an executing program's value file.
pub type Reg = usize;

/// SGD learning rate baked into the `train_step` entry (mirrors
/// `python/compile/model.py::LR`).
pub const LR: f32 = 1e-2;

/// One SSA instruction. Every instruction reads existing registers and
/// defines exactly one new register.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Instr {
    /// `out = a @ b` — `[m,k] x [k,n] -> [m,n]`.
    Matmul { a: Reg, b: Reg },
    /// `out = aT @ b` — `a:[k,m], b:[k,n] -> [m,n]` (weight gradients:
    /// contraction over the batch dimension).
    MatmulTn { a: Reg, b: Reg },
    /// `out = a @ bT` — `a:[m,n], b:[k,n] -> [m,k]` (data gradients).
    MatmulNt { a: Reg, b: Reg },
    /// `out[i,j] = a[i,j] + bias[j]`.
    AddBias { a: Reg, bias: Reg },
    /// `out = max(a, 0)`.
    Relu { a: Reg },
    /// `out = 1 / (1 + exp(-a))`.
    Sigmoid { a: Reg },
    /// `out = 0.5 a (1 + tanh(√(2/π)(a + 0.044715 a³)))` — tanh GELU.
    Gelu { a: Reg },
    /// `out = tanh(a)`.
    Tanh { a: Reg },
    /// `out = a · sigmoid(a)` — SiLU / swish.
    Silu { a: Reg },
    /// `out = exp(a)`.
    Exp { a: Reg },
    /// `out = g * 1[act > 0]` — ReLU VJP against the saved activation.
    ReluGrad { g: Reg, act: Reg },
    /// `out = dy * y * (1 - y)` — sigmoid VJP against the saved output.
    SigmoidGrad { dy: Reg, y: Reg },
    /// `out = mean((y - t)^2)` as a scalar tensor.
    MseLoss { y: Reg, t: Reg },
    /// `out = 2 * (y - t) / numel` — MSE VJP.
    MseGrad { y: Reg, t: Reg },
    /// `out[j] = sum_i a[i,j]` — batch reduction (bias gradients).
    ColSum { a: Reg },
    /// `out = a + c * b` (same shape) — the SGD update with `c = -LR`.
    Axpy { a: Reg, b: Reg, c: f32 },
}

/// A straight-line SSA tensor program. Registers `0..n_inputs` are the
/// entry inputs; instruction `i` defines register `n_inputs + i`.
#[derive(Debug, Clone)]
pub struct Program {
    pub n_inputs: usize,
    pub instrs: Vec<Instr>,
    pub outputs: Vec<Reg>,
}

/// A register value: input registers borrow the caller's tensors (the
/// coordinator re-binds the same weight tensors every tile — copying them
/// per invocation would dominate the hot path), instruction results are
/// owned.
enum Value<'a> {
    In(&'a Tensor),
    Owned(Tensor),
}

impl Value<'_> {
    fn tensor(&self) -> &Tensor {
        match self {
            Value::In(t) => t,
            Value::Owned(t) => t,
        }
    }
}

impl Program {
    /// Execute over the given inputs, returning the output registers.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.run_bound(inputs, &[])
    }

    /// Execute with `bound` tensors appended after `inputs` as additional
    /// input registers. The session façade binds stage weights once at
    /// build time this way, so the per-tile call passes only the streamed
    /// tile — no weight cloning on the hot path.
    pub fn run_bound(&self, inputs: &[Tensor], bound: &[Tensor]) -> Result<Vec<Tensor>> {
        ensure!(
            inputs.len() + bound.len() == self.n_inputs,
            "program expects {} inputs, got {} (+{} bound)",
            self.n_inputs,
            inputs.len(),
            bound.len()
        );
        let mut regs: Vec<Value> = Vec::with_capacity(self.n_inputs + self.instrs.len());
        regs.extend(inputs.iter().map(Value::In));
        regs.extend(bound.iter().map(Value::In));
        for instr in &self.instrs {
            let value = eval(instr, &regs)?;
            regs.push(Value::Owned(value));
        }
        // Move owned result tensors out; clone only inputs echoed as
        // outputs or registers listed more than once (train_step returns
        // every updated parameter — cloning them all would double the
        // step's memory traffic for nothing).
        let mut results = Vec::with_capacity(self.outputs.len());
        for (oi, &r) in self.outputs.iter().enumerate() {
            let listed_again = self.outputs[oi + 1..].contains(&r);
            let value = regs.get_mut(r).ok_or_else(|| anyhow!("output register {r} out of range"))?;
            let tensor = match value {
                Value::In(t) => (**t).clone(),
                Value::Owned(t) if listed_again => t.clone(),
                Value::Owned(t) => std::mem::replace(t, Tensor::zeros(&[])),
            };
            results.push(tensor);
        }
        Ok(results)
    }
}

/// Incremental program construction (registers allocated in SSA order).
struct ProgramBuilder {
    n_inputs: usize,
    instrs: Vec<Instr>,
}

impl ProgramBuilder {
    fn new(n_inputs: usize) -> Self {
        ProgramBuilder { n_inputs, instrs: Vec::new() }
    }

    fn push(&mut self, instr: Instr) -> Reg {
        let reg = self.n_inputs + self.instrs.len();
        self.instrs.push(instr);
        reg
    }

    /// `x @ w + b`.
    fn linear(&mut self, x: Reg, w: Reg, b: Reg) -> Reg {
        let mm = self.push(Instr::Matmul { a: x, b: w });
        self.push(Instr::AddBias { a: mm, bias: b })
    }

    fn finish(self, outputs: Vec<Reg>) -> Program {
        Program { n_inputs: self.n_inputs, instrs: self.instrs, outputs }
    }
}

/// Forward pass of the NeRF-class MLP (`nerf_forward`, both variants —
/// the Pallas and reference paths are numerically identical by design):
/// three ReLU trunk layers + sigmoid head.
fn forward_program() -> Program {
    let mut p = ProgramBuilder::new(9);
    let (x, w1, b1, w2, b2, w3, b3, w4, b4) = (0, 1, 2, 3, 4, 5, 6, 7, 8);
    let z1 = p.linear(x, w1, b1);
    let a1 = p.push(Instr::Relu { a: z1 });
    let z2 = p.linear(a1, w2, b2);
    let a2 = p.push(Instr::Relu { a: z2 });
    let z3 = p.linear(a2, w3, b3);
    let a3 = p.push(Instr::Relu { a: z3 });
    let z4 = p.linear(a3, w4, b4);
    let y = p.push(Instr::Sigmoid { a: z4 });
    p.finish(vec![y])
}

/// One SGD step: forward, MSE loss, hand-derived reverse-mode backward,
/// parameter update. ABI matches `model.train_step`:
/// `(x, y, *params) -> (loss, *new_params)`.
fn train_step_program() -> Program {
    let mut p = ProgramBuilder::new(10);
    let (x, t) = (0, 1);
    let (w1, b1, w2, b2, w3, b3, w4, b4) = (2, 3, 4, 5, 6, 7, 8, 9);

    // Forward (saving activations for the VJPs).
    let z1 = p.linear(x, w1, b1);
    let a1 = p.push(Instr::Relu { a: z1 });
    let z2 = p.linear(a1, w2, b2);
    let a2 = p.push(Instr::Relu { a: z2 });
    let z3 = p.linear(a2, w3, b3);
    let a3 = p.push(Instr::Relu { a: z3 });
    let z4 = p.linear(a3, w4, b4);
    let y = p.push(Instr::Sigmoid { a: z4 });
    let loss = p.push(Instr::MseLoss { y, t });

    // Backward: dL/dy, then layer by layer. The weight-gradient GEMMs
    // contract over the batch dimension and the bias gradients are batch
    // reductions — exactly the Fig 2(b) structures the paper pipelines.
    let dy = p.push(Instr::MseGrad { y, t });
    let dz4 = p.push(Instr::SigmoidGrad { dy, y });
    let dw4 = p.push(Instr::MatmulTn { a: a3, b: dz4 });
    let db4 = p.push(Instr::ColSum { a: dz4 });
    let da3 = p.push(Instr::MatmulNt { a: dz4, b: w4 });
    let dz3 = p.push(Instr::ReluGrad { g: da3, act: a3 });
    let dw3 = p.push(Instr::MatmulTn { a: a2, b: dz3 });
    let db3 = p.push(Instr::ColSum { a: dz3 });
    let da2 = p.push(Instr::MatmulNt { a: dz3, b: w3 });
    let dz2 = p.push(Instr::ReluGrad { g: da2, act: a2 });
    let dw2 = p.push(Instr::MatmulTn { a: a1, b: dz2 });
    let db2 = p.push(Instr::ColSum { a: dz2 });
    let da1 = p.push(Instr::MatmulNt { a: dz2, b: w2 });
    let dz1 = p.push(Instr::ReluGrad { g: da1, act: a1 });
    let dw1 = p.push(Instr::MatmulTn { a: x, b: dz1 });
    let db1 = p.push(Instr::ColSum { a: dz1 });

    // SGD update.
    let step = |p: &mut ProgramBuilder, param: Reg, grad: Reg| {
        p.push(Instr::Axpy { a: param, b: grad, c: -LR })
    };
    let nw1 = step(&mut p, w1, dw1);
    let nb1 = step(&mut p, b1, db1);
    let nw2 = step(&mut p, w2, dw2);
    let nb2 = step(&mut p, b2, db2);
    let nw3 = step(&mut p, w3, dw3);
    let nb3 = step(&mut p, b3, db3);
    let nw4 = step(&mut p, w4, dw4);
    let nb4 = step(&mut p, b4, db4);

    p.finish(vec![loss, nw1, nb1, nw2, nb2, nw3, nb3, nw4, nb4])
}

/// Pipeline stage 0 (`stage_trunk0`): `relu(fused_mlp(x, w1, b1, w2, b2))`
/// = `relu(relu(x@w1+b1) @ w2 + b2)`.
fn stage_trunk0_program() -> Program {
    let mut p = ProgramBuilder::new(5);
    let (x, w1, b1, w2, b2) = (0, 1, 2, 3, 4);
    let z1 = p.linear(x, w1, b1);
    let a1 = p.push(Instr::Relu { a: z1 });
    let z2 = p.linear(a1, w2, b2);
    let a2 = p.push(Instr::Relu { a: z2 });
    p.finish(vec![a2])
}

/// Pipeline stage 1 (`stage_trunk1`): `relu(h @ w3 + b3)`.
fn stage_trunk1_program() -> Program {
    let mut p = ProgramBuilder::new(3);
    let z = p.linear(0, 1, 2);
    let a = p.push(Instr::Relu { a: z });
    p.finish(vec![a])
}

/// Pipeline stage 2 (`stage_head`): `sigmoid(h @ w4 + b4)`.
fn stage_head_program() -> Program {
    let mut p = ProgramBuilder::new(3);
    let z = p.linear(0, 1, 2);
    let y = p.push(Instr::Sigmoid { a: z });
    p.finish(vec![y])
}

/// Resolve a manifest entry to its interpreter program, validating the
/// declared ABI (input arity, output count) against the program.
pub fn entry_program(spec: &EntrySpec) -> Result<Program> {
    let program = match spec.name.as_str() {
        "nerf_forward" | "nerf_forward_pallas" => forward_program(),
        "train_step" => train_step_program(),
        "stage_trunk0" => stage_trunk0_program(),
        "stage_trunk1" => stage_trunk1_program(),
        "stage_head" => stage_head_program(),
        _ => {
            return Err(RuntimeError::UnsupportedEntry {
                name: spec.name.clone(),
                backend: "interp",
            }
            .into())
        }
    };
    ensure!(
        program.n_inputs == spec.inputs.len(),
        "{}: manifest declares {} inputs, interpreter program expects {}",
        spec.name,
        spec.inputs.len(),
        program.n_inputs
    );
    ensure!(
        program.outputs.len() == spec.n_outputs,
        "{}: manifest declares {} outputs, interpreter program produces {}",
        spec.name,
        spec.n_outputs,
        program.outputs.len()
    );
    Ok(program)
}

/// The pure-Rust interpreter backend (always available, the default).
#[derive(Debug, Clone, Default)]
pub struct InterpBackend;

impl InterpBackend {
    pub fn new() -> Self {
        InterpBackend
    }
}

impl Backend for InterpBackend {
    fn name(&self) -> &'static str {
        "interp"
    }

    fn compile(&self, spec: &EntrySpec) -> Result<Box<dyn Executable>> {
        let program = entry_program(spec)?;
        Ok(Box::new(InterpExecutable { name: spec.name.clone(), program }))
    }
}

struct InterpExecutable {
    name: String,
    program: Program,
}

impl Executable for InterpExecutable {
    fn run_f32(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.program.run(inputs).with_context(|| format!("interp entry {}", self.name))
    }
}

/// Wrap a synthesized [`Program`] as a runnable [`Executable`] — how the
/// session façade turns lowered compiler stages into stage kernels
/// without any on-disk manifest entry.
pub fn program_executable(name: impl Into<String>, program: Program) -> Box<dyn Executable> {
    Box::new(InterpExecutable { name: name.into(), program })
}

/// Like [`program_executable`], but with `bound` tensors (stage weights)
/// fixed at construction: callers pass only the streamed tile.
pub fn bound_executable(
    name: impl Into<String>,
    program: Program,
    bound: Vec<Tensor>,
) -> Box<dyn Executable> {
    Box::new(BoundExecutable { name: name.into(), program, bound })
}

struct BoundExecutable {
    name: String,
    program: Program,
    bound: Vec<Tensor>,
}

impl Executable for BoundExecutable {
    fn run_f32(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.program
            .run_bound(inputs, &self.bound)
            .with_context(|| format!("interp entry {}", self.name))
    }
}

// ---- tensor kernels ----

fn eval(instr: &Instr, regs: &[Value]) -> Result<Tensor> {
    let r = |i: Reg| regs[i].tensor();
    match *instr {
        Instr::Matmul { a, b } => matmul(r(a), r(b), false, false),
        Instr::MatmulTn { a, b } => matmul(r(a), r(b), true, false),
        Instr::MatmulNt { a, b } => matmul(r(a), r(b), false, true),
        Instr::AddBias { a, bias } => add_bias(r(a), r(bias)),
        Instr::Relu { a } => Ok(map1(r(a), |v| v.max(0.0))),
        Instr::Sigmoid { a } => Ok(map1(r(a), |v| 1.0 / (1.0 + (-v).exp()))),
        Instr::Gelu { a } => Ok(map1(r(a), |v| {
            let c = std::f32::consts::FRAC_2_SQRT_PI / std::f32::consts::SQRT_2; // √(2/π)
            0.5 * v * (1.0 + (c * (v + 0.044_715 * v * v * v)).tanh())
        })),
        Instr::Tanh { a } => Ok(map1(r(a), |v| v.tanh())),
        Instr::Silu { a } => Ok(map1(r(a), |v| v / (1.0 + (-v).exp()))),
        Instr::Exp { a } => Ok(map1(r(a), |v| v.exp())),
        Instr::ReluGrad { g, act } => {
            map2(r(g), r(act), |gv, av| if av > 0.0 { gv } else { 0.0 })
        }
        Instr::SigmoidGrad { dy, y } => map2(r(dy), r(y), |d, yv| d * yv * (1.0 - yv)),
        Instr::MseLoss { y, t } => mse_loss(r(y), r(t)),
        Instr::MseGrad { y, t } => {
            let n = r(y).data.len().max(1) as f32;
            map2(r(y), r(t), move |yv, tv| 2.0 * (yv - tv) / n)
        }
        Instr::ColSum { a } => col_sum(r(a)),
        Instr::Axpy { a, b, c } => map2(r(a), r(b), move |av, bv| av + c * bv),
    }
}

/// `a (T?) @ b (T?)`. Logical shapes are derived from the physical dims
/// plus the transpose flags; everything is validated.
fn matmul(a: &Tensor, b: &Tensor, ta: bool, tb: bool) -> Result<Tensor> {
    ensure!(
        a.dims.len() == 2 && b.dims.len() == 2,
        "matmul needs rank-2 operands, got {:?} x {:?}",
        a.dims,
        b.dims
    );
    let (m, k) = if ta { (a.dims[1], a.dims[0]) } else { (a.dims[0], a.dims[1]) };
    let (k2, n) = if tb { (b.dims[1], b.dims[0]) } else { (b.dims[0], b.dims[1]) };
    ensure!(
        k == k2,
        "matmul contraction mismatch: {:?}{} x {:?}{}",
        a.dims,
        if ta { "ᵀ" } else { "" },
        b.dims,
        if tb { "ᵀ" } else { "" }
    );
    let (lda, ldb) = (a.dims[1], b.dims[1]);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            // No zero-skip: 0 * NaN must stay NaN so diverged values
            // propagate exactly as they do through the XLA backend.
            let av = if ta { a.data[kk * lda + i] } else { a.data[i * lda + kk] };
            let row = &mut out[i * n..(i + 1) * n];
            if tb {
                for (j, o) in row.iter_mut().enumerate() {
                    *o += av * b.data[j * ldb + kk];
                }
            } else {
                let brow = &b.data[kk * ldb..kk * ldb + n];
                for (o, &bv) in row.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

fn add_bias(a: &Tensor, bias: &Tensor) -> Result<Tensor> {
    ensure!(a.dims.len() == 2, "bias add needs a rank-2 lhs, got {:?}", a.dims);
    let n = a.dims[1];
    ensure!(
        bias.dims == [n],
        "bias shape {:?} does not broadcast over {:?}",
        bias.dims,
        a.dims
    );
    let data = a
        .data
        .iter()
        .enumerate()
        .map(|(idx, &v)| v + bias.data[idx % n])
        .collect();
    Tensor::new(a.dims.clone(), data)
}

fn map1(a: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    Tensor { dims: a.dims.clone(), data: a.data.iter().map(|&v| f(v)).collect() }
}

fn map2(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
    ensure!(a.dims == b.dims, "elementwise shape mismatch: {:?} vs {:?}", a.dims, b.dims);
    let data = a.data.iter().zip(&b.data).map(|(&x, &y)| f(x, y)).collect();
    Tensor::new(a.dims.clone(), data)
}

fn mse_loss(y: &Tensor, t: &Tensor) -> Result<Tensor> {
    ensure!(y.dims == t.dims, "mse shape mismatch: {:?} vs {:?}", y.dims, t.dims);
    let n = y.data.len().max(1) as f64;
    let sum: f64 = y.data.iter().zip(&t.data).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
    Tensor::new(Vec::new(), vec![(sum / n) as f32])
}

fn col_sum(a: &Tensor) -> Result<Tensor> {
    ensure!(a.dims.len() == 2, "column sum needs rank 2, got {:?}", a.dims);
    let (m, n) = (a.dims[0], a.dims[1]);
    let mut out = vec![0.0f32; n];
    for i in 0..m {
        for j in 0..n {
            out[j] += a.data[i * n + j];
        }
    }
    Tensor::new(vec![n], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::manifest::TensorSpec;
    use super::super::tensor::Rng;
    use std::path::PathBuf;

    fn t(dims: &[usize], data: &[f32]) -> Tensor {
        Tensor::new(dims.to_vec(), data.to_vec()).unwrap()
    }

    fn spec(name: &str, ins: &[Vec<usize>], outs: usize) -> EntrySpec {
        EntrySpec {
            name: name.to_string(),
            hlo_path: PathBuf::from(format!("{name}.hlo.txt")),
            inputs: ins
                .iter()
                .map(|d| TensorSpec { dtype: "f32".to_string(), dims: d.clone() })
                .collect(),
            n_outputs: outs,
        }
    }

    #[test]
    fn matmul_plain_and_transposed() {
        let a = t(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = t(&[3, 2], &[7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = matmul(&a, &b, false, false).unwrap();
        assert_eq!(c.dims, vec![2, 2]);
        assert_eq!(c.data, vec![58.0, 64.0, 139.0, 154.0]);
        // Gram-matrix symmetry exercises both transpose flags.
        let g1 = matmul(&a, &a, true, false).unwrap(); // aT a : [3,3]
        let g2 = matmul(&a, &a, false, true).unwrap(); // a aT : [2,2]
        assert_eq!(g1.dims, vec![3, 3]);
        assert_eq!(g2.dims, vec![2, 2]);
        assert_eq!(g1.data[1], g1.data[3]); // symmetric
        assert_eq!(g2.data[1], g2.data[2]);
        // Tn/Nt agree with matmul against an explicitly transposed operand.
        let at = t(&[3, 2], &[1.0, 4.0, 2.0, 5.0, 3.0, 6.0]); // aT materialized
        let c = t(&[2, 2], &[1.0, -1.0, 2.0, 0.5]);
        let tn = matmul(&a, &c, true, false).unwrap(); // aT @ c : [3,2]
        let explicit = matmul(&at, &c, false, false).unwrap();
        assert_eq!(tn.data, explicit.data);
        let ct = t(&[2, 2], &[1.0, 2.0, -1.0, 0.5]); // cT materialized
        let nt = matmul(&at, &c, false, true).unwrap(); // aT @ cT : [3,2]
        let explicit2 = matmul(&at, &ct, false, false).unwrap();
        assert_eq!(nt.data, explicit2.data);
        // Contraction mismatches are rejected.
        assert!(matmul(&a, &b, true, false).is_err());
    }

    #[test]
    fn bias_and_colsum() {
        let a = t(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = add_bias(&a, &t(&[3], &[10.0, 20.0, 30.0])).unwrap();
        assert_eq!(b.data, vec![11.0, 22.0, 33.0, 14.0, 25.0, 36.0]);
        let s = col_sum(&a).unwrap();
        assert_eq!(s.dims, vec![3]);
        assert_eq!(s.data, vec![5.0, 7.0, 9.0]);
        assert!(add_bias(&a, &t(&[2], &[0.0, 0.0])).is_err());
    }

    #[test]
    fn forward_program_outputs_unit_range() {
        let prog = forward_program();
        let mut rng = Rng::new(11);
        let dims: Vec<Vec<usize>> = vec![
            vec![16, 6],
            vec![6, 8],
            vec![8],
            vec![8, 8],
            vec![8],
            vec![8, 8],
            vec![8],
            vec![8, 3],
            vec![3],
        ];
        let inputs: Vec<Tensor> = dims
            .iter()
            .enumerate()
            .map(|(i, d)| {
                if i == 0 {
                    let numel: usize = d.iter().product();
                    Tensor {
                        dims: d.clone(),
                        data: (0..numel).map(|_| rng.normal()).collect(),
                    }
                } else {
                    rng.he_tensor(d)
                }
            })
            .collect();
        let out = prog.run(&inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims, vec![16, 3]);
        assert!(out[0].data.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // Deterministic.
        assert_eq!(prog.run(&inputs).unwrap()[0].data, out[0].data);
    }

    #[test]
    fn stage_composition_equals_forward() {
        // trunk0 -> trunk1 -> head must reproduce nerf_forward exactly:
        // the coordinator's pipeline is a factorization of the monolith.
        let mut rng = Rng::new(23);
        let x = Tensor {
            dims: vec![8, 6],
            data: (0..48).map(|_| rng.normal()).collect(),
        };
        let params: Vec<Tensor> = [
            vec![6usize, 8],
            vec![8],
            vec![8, 8],
            vec![8],
            vec![8, 8],
            vec![8],
            vec![8, 3],
            vec![3],
        ]
        .iter()
        .map(|d| rng.he_tensor(d))
        .collect();

        let mut fwd_in = vec![x.clone()];
        fwd_in.extend(params.iter().cloned());
        let y_fwd = forward_program().run(&fwd_in).unwrap().remove(0);

        let t0 = stage_trunk0_program()
            .run(&[
                x,
                params[0].clone(),
                params[1].clone(),
                params[2].clone(),
                params[3].clone(),
            ])
            .unwrap()
            .remove(0);
        let t1 = stage_trunk1_program()
            .run(&[t0, params[4].clone(), params[5].clone()])
            .unwrap()
            .remove(0);
        let y_staged = stage_head_program()
            .run(&[t1, params[6].clone(), params[7].clone()])
            .unwrap()
            .remove(0);
        assert_eq!(y_fwd.dims, y_staged.dims);
        assert_eq!(y_fwd.data, y_staged.data, "stages must compose bit-identically");
    }

    #[test]
    fn train_step_gradients_match_finite_differences() {
        let prog = train_step_program();
        let mut rng = Rng::new(31);
        let (batch, din, hidden, dout) = (8usize, 3usize, 4usize, 2usize);
        let x = Tensor {
            dims: vec![batch, din],
            data: (0..batch * din).map(|_| rng.normal()).collect(),
        };
        let t_out = Tensor {
            dims: vec![batch, dout],
            data: (0..batch * dout).map(|_| rng.uniform()).collect(),
        };
        let param_dims: Vec<Vec<usize>> = vec![
            vec![din, hidden],
            vec![hidden],
            vec![hidden, hidden],
            vec![hidden],
            vec![hidden, hidden],
            vec![hidden],
            vec![hidden, dout],
            vec![dout],
        ];
        // Non-zero biases so their gradients are exercised off the origin.
        let params: Vec<Tensor> = param_dims
            .iter()
            .map(|d| {
                let mut p = rng.he_tensor(d);
                if d.len() == 1 {
                    p.data.iter_mut().for_each(|v| *v = 0.1 * rng.normal());
                }
                p
            })
            .collect();

        let loss_at = |params: &[Tensor]| -> f64 {
            let mut args = vec![x.clone(), t_out.clone()];
            args.extend(params.iter().cloned());
            prog.run(&args).unwrap()[0].scalar_value() as f64
        };
        let run = {
            let mut args = vec![x.clone(), t_out.clone()];
            args.extend(params.iter().cloned());
            prog.run(&args).unwrap()
        };
        assert_eq!(run.len(), 9);

        // Analytic gradient recovered from the SGD update: g = (p - p')/LR.
        let eps = 1e-3f64;
        for (pi, pdims) in param_dims.iter().enumerate() {
            let numel: usize = pdims.iter().product();
            for &k in &[0usize, numel / 2, numel - 1] {
                let analytic =
                    ((params[pi].data[k] - run[1 + pi].data[k]) / LR) as f64;
                let mut plus = params.clone();
                plus[pi].data[k] += eps as f32;
                let mut minus = params.clone();
                minus[pi].data[k] -= eps as f32;
                let fd = (loss_at(&plus) - loss_at(&minus)) / (2.0 * eps);
                assert!(
                    (fd - analytic).abs() < 1e-3 + 0.08 * analytic.abs(),
                    "param {pi}[{k}]: finite-diff {fd} vs analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn train_step_descends_on_fixed_batch() {
        let prog = train_step_program();
        let mut rng = Rng::new(99);
        let (batch, din, hidden, dout) = (32usize, 6usize, 16usize, 3usize);
        let x = Tensor {
            dims: vec![batch, din],
            data: (0..batch * din).map(|_| rng.normal()).collect(),
        };
        let t_out = Tensor {
            dims: vec![batch, dout],
            data: (0..batch * dout).map(|_| rng.uniform()).collect(),
        };
        let mut params: Vec<Tensor> = [
            vec![din, hidden],
            vec![hidden],
            vec![hidden, hidden],
            vec![hidden],
            vec![hidden, hidden],
            vec![hidden],
            vec![hidden, dout],
            vec![dout],
        ]
        .iter()
        .map(|d| rng.he_tensor(d))
        .collect();
        let mut losses = Vec::new();
        for _ in 0..150 {
            let mut args = vec![x.clone(), t_out.clone()];
            args.extend(params.iter().cloned());
            let mut out = prog.run(&args).unwrap();
            losses.push(out.remove(0).scalar_value());
            params = out;
        }
        assert!(losses.iter().all(|l| l.is_finite()));
        // Full-batch SGD with a small step descends monotonically here.
        for w in losses.windows(2) {
            assert!(w[1] <= w[0] + 1e-7, "loss rose: {} -> {}", w[0], w[1]);
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.95),
            "no meaningful descent: {} -> {}",
            losses[0],
            losses.last().unwrap()
        );
    }

    #[test]
    fn extended_activations_match_reference_math() {
        let mk = |instr: fn(Reg) -> Instr| Program {
            n_inputs: 1,
            instrs: vec![instr(0)],
            outputs: vec![1],
        };
        let x = t(&[1, 4], &[-2.0, -0.5, 0.5, 2.0]);
        let gelu = mk(|a| Instr::Gelu { a }).run(&[x.clone()]).unwrap();
        // tanh-GELU reference values.
        for (got, want) in gelu[0].data.iter().zip([-0.0454f32, -0.1543, 0.3457, 1.9546]) {
            assert!((got - want).abs() < 1e-3, "gelu {got} vs {want}");
        }
        let tanh = mk(|a| Instr::Tanh { a }).run(&[x.clone()]).unwrap();
        assert!((tanh[0].data[3] - 2.0f32.tanh()).abs() < 1e-6);
        let silu = mk(|a| Instr::Silu { a }).run(&[x.clone()]).unwrap();
        let sig = |v: f32| 1.0 / (1.0 + (-v).exp());
        assert!((silu[0].data[0] - (-2.0 * sig(-2.0))).abs() < 1e-6);
        let exp = mk(|a| Instr::Exp { a }).run(&[x]).unwrap();
        assert!((exp[0].data[2] - 0.5f32.exp()).abs() < 1e-6);
    }

    #[test]
    fn bound_execution_matches_plain_run() {
        // stage_trunk1 with weights bound at construction must agree with
        // the same program run with weights passed per call.
        let prog = stage_trunk1_program();
        let mut rng = Rng::new(77);
        let x = Tensor {
            dims: vec![4, 8],
            data: (0..32).map(|_| rng.normal()).collect(),
        };
        let w = rng.he_tensor(&[8, 8]);
        let b = rng.he_tensor(&[8]);
        let plain = prog.run(&[x.clone(), w.clone(), b.clone()]).unwrap();
        let bound = prog.run_bound(&[x.clone()], &[w.clone(), b.clone()]).unwrap();
        assert_eq!(plain[0].data, bound[0].data);
        let exe = bound_executable("t1", prog, vec![w, b]);
        let via_exe = exe.run_f32(&[x]).unwrap();
        assert_eq!(plain[0].data, via_exe[0].data);
        // Wrong arity still rejected.
        assert!(exe.run_f32(&[]).is_err());
    }

    #[test]
    fn entry_program_validates_manifest_abi() {
        let nine: Vec<Vec<usize>> = vec![
            vec![4, 6],
            vec![6, 8],
            vec![8],
            vec![8, 8],
            vec![8],
            vec![8, 8],
            vec![8],
            vec![8, 3],
            vec![3],
        ];
        assert!(entry_program(&spec("nerf_forward", &nine, 1)).is_ok());
        // Wrong arity rejected.
        assert!(entry_program(&spec("nerf_forward", &nine[..5].to_vec(), 1)).is_err());
        // Wrong output count rejected.
        assert!(entry_program(&spec("nerf_forward", &nine, 2)).is_err());
        // Unknown entries produce the typed unsupported error.
        let err = entry_program(&spec("weird_entry", &nine, 1)).unwrap_err();
        match err.downcast_ref::<RuntimeError>() {
            Some(RuntimeError::UnsupportedEntry { name, backend }) => {
                assert_eq!(name, "weird_entry");
                assert_eq!(*backend, "interp");
            }
            other => panic!("expected UnsupportedEntry, got {other:?}"),
        }
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
