//! Pure-Rust interpreter backend — the default runtime engine.
//!
//! Each AOT entry in `artifacts/manifest.txt` lowers to a straight-line
//! SSA tensor [`Program`] (matmuls, bias adds, activations, their VJPs,
//! and the SGD update) which this module interprets over [`Tensor`]s.
//! The programs implement the reference semantics of
//! `python/compile/model.py` — the same math the HLO artifacts encode —
//! so the full coordinator/example/test stack runs on a fresh offline
//! checkout with no XLA runtime and no Python. Shapes are read from the
//! operands at run time, so the same program serves the real AOT shapes
//! and the small synthetic manifests the tests use.
//!
//! # Execution engines
//!
//! Two engines evaluate the same ISA:
//!
//! * the **optimized engine** ([`Program::run`] / [`Program::run_with_plan`])
//!   — register-blocked matmul micro-kernels that split row panels across
//!   a small scoped-thread worker set above a FLOP threshold, a last-use
//!   liveness pass ([`Program::plan`]) that executes elementwise
//!   instructions in place when their source register is owned and dead,
//!   and a buffer pool that recycles dead registers' allocations into
//!   upcoming results. This is the hot path behind every stage kernel.
//! * the **scalar reference oracle** ([`Program::run_reference`]) — naive
//!   triple-loop kernels, fresh allocation per instruction, no fusion, no
//!   threads, no in-place writes. Slow and obviously correct.
//!
//! The optimized engine owes the oracle a **two-tier equivalence
//! contract** ([`crate::runtime::simd::Equivalence`]):
//!
//! * with the vector layer disabled (`KITSUNE_SIMD=0`) the engines are
//!   **bitwise-identical by construction**: every optimized kernel
//!   performs the exact f32 operation sequence of its reference
//!   counterpart (contractions always run `kk = 0..k` in increasing
//!   order — which is also why there is no k-blocking with per-block
//!   partial sums: that would re-associate the adds);
//! * with the vector layer on (the default), the hot kernels dispatch
//!   through [`crate::runtime::simd`] — 8-lane AVX2/FMA paths when the
//!   CPU has them, a bitwise-equal portable fallback otherwise. The FMA
//!   paths fuse each multiply-add into a single rounding (contraction
//!   order unchanged), so results are **ULP-bounded** against the
//!   oracle ([`crate::runtime::simd::VECTOR_ULP_BOUND`]) instead of
//!   bitwise; [`crate::runtime::simd::engine_equivalence`] names the
//!   live tier.
//!
//! `tests/kernel_equivalence.rs` property-tests both tiers over
//! randomized programs and shapes, including NaN propagation (no
//! zero-skip anywhere).
//!
//! Gradient programs are hand-derived reverse-mode; the test suite checks
//! them against central finite differences (see `entry_program` tests),
//! and the PJRT integration tests cross-check numerics whenever real
//! artifacts plus the `pjrt` feature are present.

use super::backend::{Backend, Executable};
use super::error::RuntimeError;
use super::manifest::EntrySpec;
use super::simd;
use super::tensor::Tensor;
use crate::Result;
use anyhow::{anyhow, ensure, Context};

/// Register index into an executing program's value file.
pub type Reg = usize;

/// Default SGD learning rate of the legacy `train_step` entry, routed
/// through the training subsystem's optimizer config
/// ([`crate::train::DEFAULT_LR`], which mirrors
/// `python/compile/model.py::LR`).
///
/// **Compat shim:** new code should configure the rate through
/// [`crate::train::OptimizerKind`] (and [`train_step_program`] takes the
/// rate explicitly); this constant exists only so the AOT `train_step`
/// manifest entry keeps its historical ABI and numerics.
pub const LR: f32 = crate::train::DEFAULT_LR;

/// Elementwise activation kind, shared by the standalone activation
/// instructions and the fused [`Instr::BiasAct`] epilogue. Both engines
/// (and both fused and unfused forms) call the one [`Act::apply`], which
/// is what makes them bitwise-identical by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Relu,
    Sigmoid,
    Gelu,
    Tanh,
    Silu,
    Exp,
}

impl Act {
    /// The scalar activation — single source of truth for every engine.
    #[inline(always)]
    pub fn apply(self, v: f32) -> f32 {
        match self {
            Act::Relu => v.max(0.0),
            Act::Sigmoid => 1.0 / (1.0 + (-v).exp()),
            Act::Gelu => {
                let c = std::f32::consts::FRAC_2_SQRT_PI / std::f32::consts::SQRT_2; // √(2/π)
                0.5 * v * (1.0 + (c * (v + 0.044_715 * v * v * v)).tanh())
            }
            Act::Tanh => v.tanh(),
            Act::Silu => v / (1.0 + (-v).exp()),
            Act::Exp => v.exp(),
        }
    }

    /// The scalar derivative `f'(x)` evaluated at the saved *input* `x` —
    /// the single source of truth behind [`Instr::ActGradI`] on both
    /// engines (the training lowering re-derives activations from their
    /// pre-activation inputs, which is what autodiff graphs save).
    #[inline(always)]
    pub fn grad_at(self, x: f32) -> f32 {
        match self {
            Act::Relu => {
                if x > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Act::Sigmoid => {
                let s = 1.0 / (1.0 + (-x).exp());
                s * (1.0 - s)
            }
            Act::Gelu => {
                let c = std::f32::consts::FRAC_2_SQRT_PI / std::f32::consts::SQRT_2; // √(2/π)
                let u = c * (x + 0.044_715 * x * x * x);
                let t = u.tanh();
                let du = c * (1.0 + 3.0 * 0.044_715 * x * x);
                0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
            }
            Act::Tanh => {
                let t = x.tanh();
                1.0 - t * t
            }
            Act::Silu => {
                let s = 1.0 / (1.0 + (-x).exp());
                s * (1.0 + x * (1.0 - s))
            }
            Act::Exp => x.exp(),
        }
    }
}

/// One SSA instruction. Every instruction reads existing registers and
/// defines exactly one new register.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Instr {
    /// `out = a @ b` — `[m,k] x [k,n] -> [m,n]`.
    Matmul { a: Reg, b: Reg },
    /// `out = aT @ b` — `a:[k,m], b:[k,n] -> [m,n]` (weight gradients:
    /// contraction over the batch dimension).
    MatmulTn { a: Reg, b: Reg },
    /// `out = a @ bT` — `a:[m,n], b:[k,n] -> [m,k]` (data gradients).
    MatmulNt { a: Reg, b: Reg },
    /// `out = a @ b + bias` — [`Instr::Matmul`] with the bias epilogue
    /// applied in the kernel's output sweep (the peephole-fused form;
    /// bitwise-identical to `Matmul` then `AddBias`).
    MatmulBias { a: Reg, b: Reg, bias: Reg },
    /// `out[i,j] = a[i,j] + bias[j]`.
    AddBias { a: Reg, bias: Reg },
    /// `out[i,j] = act(a[i,j] + bias[j])` — fused bias + activation
    /// epilogue in one pass over the rows (bitwise-identical to
    /// `AddBias` then the standalone activation).
    BiasAct { a: Reg, bias: Reg, act: Act },
    /// `out = max(a, 0)`.
    Relu { a: Reg },
    /// `out = 1 / (1 + exp(-a))`.
    Sigmoid { a: Reg },
    /// `out = 0.5 a (1 + tanh(√(2/π)(a + 0.044715 a³)))` — tanh GELU.
    Gelu { a: Reg },
    /// `out = tanh(a)`.
    Tanh { a: Reg },
    /// `out = a · sigmoid(a)` — SiLU / swish.
    Silu { a: Reg },
    /// `out = exp(a)`.
    Exp { a: Reg },
    /// `out = g * 1[act > 0]` — ReLU VJP against the saved activation.
    ReluGrad { g: Reg, act: Reg },
    /// `out = dy * y * (1 - y)` — sigmoid VJP against the saved output.
    SigmoidGrad { dy: Reg, y: Reg },
    /// `out = mean((y - t)^2)` as a scalar tensor.
    MseLoss { y: Reg, t: Reg },
    /// `out = 2 * (y - t) / numel` — MSE VJP.
    MseGrad { y: Reg, t: Reg },
    /// `out[j] = sum_i a[i,j]` — batch reduction (bias gradients).
    ColSum { a: Reg },
    /// `out = a + c * b` (same shape) — the SGD update with `c = -lr`,
    /// gradient accumulation with `c = 1`, and (via the `train`
    /// subsystem) the momentum blend `g + momentum * v`.
    Axpy { a: Reg, b: Reg, c: f32 },
    /// `out = c * a` — scalar scale (gradient averaging, LR folding).
    Scale { a: Reg, c: f32 },
    /// `out = a * b` elementwise — Adam's `g²` and generic mul VJPs.
    Mul { a: Reg, b: Reg },
    /// `out = beta * a + (1 - beta) * b` — the Adam moment EMA update.
    Blend { a: Reg, b: Reg, beta: f32 },
    /// `out = g * f'(x)` — activation VJP against the saved *input* `x`
    /// (autodiff graphs save pre-activations; [`Instr::ReluGrad`] /
    /// [`Instr::SigmoidGrad`] remain for the output-saving AOT entries).
    ActGradI { g: Reg, x: Reg, act: Act },
    /// `out = [a | b]` — row-wise concat along the trailing dim
    /// (NeRF skip links, DLRM feature concat). N-ary concats chain.
    Concat2 { a: Reg, b: Reg },
    /// `out = a[:, start..start+len]` — column slice (concat VJP).
    SliceCols { a: Reg, start: usize, len: usize },
    /// One Adam parameter update:
    /// `out = p - lr * (m / bc1) / (sqrt(v / bc2) + eps)` where
    /// `bc1 = 1 - β1ᵗ`, `bc2 = 1 - β2ᵗ` are the bias corrections —
    /// `m`/`v` are the already-blended first/second moments.
    AdamStep { p: Reg, m: Reg, v: Reg, lr: f32, bc1: f32, bc2: f32, eps: f32 },
}

impl Instr {
    /// Registers this instruction reads (operands, in order).
    pub fn reads(&self) -> Vec<Reg> {
        match *self {
            Instr::Matmul { a, b } | Instr::MatmulTn { a, b } | Instr::MatmulNt { a, b } => {
                vec![a, b]
            }
            Instr::MatmulBias { a, b, bias } => vec![a, b, bias],
            Instr::AddBias { a, bias } => vec![a, bias],
            Instr::BiasAct { a, bias, .. } => vec![a, bias],
            Instr::Relu { a }
            | Instr::Sigmoid { a }
            | Instr::Gelu { a }
            | Instr::Tanh { a }
            | Instr::Silu { a }
            | Instr::Exp { a }
            | Instr::ColSum { a } => vec![a],
            Instr::ReluGrad { g, act } => vec![g, act],
            Instr::SigmoidGrad { dy, y } => vec![dy, y],
            Instr::MseLoss { y, t } | Instr::MseGrad { y, t } => vec![y, t],
            Instr::Axpy { a, b, .. } => vec![a, b],
            Instr::Scale { a, .. } | Instr::SliceCols { a, .. } => vec![a],
            Instr::Mul { a, b } | Instr::Blend { a, b, .. } | Instr::Concat2 { a, b } => {
                vec![a, b]
            }
            Instr::ActGradI { g, x, .. } => vec![g, x],
            Instr::AdamStep { p, m, v, .. } => vec![p, m, v],
        }
    }

    /// This instruction with every operand register rewritten through
    /// `f` (the defining register is implicit in SSA order). Used by the
    /// session's peephole fuser when deleted producers shift registers.
    pub fn remap(self, f: impl Fn(Reg) -> Reg) -> Instr {
        match self {
            Instr::Matmul { a, b } => Instr::Matmul { a: f(a), b: f(b) },
            Instr::MatmulTn { a, b } => Instr::MatmulTn { a: f(a), b: f(b) },
            Instr::MatmulNt { a, b } => Instr::MatmulNt { a: f(a), b: f(b) },
            Instr::MatmulBias { a, b, bias } => {
                Instr::MatmulBias { a: f(a), b: f(b), bias: f(bias) }
            }
            Instr::AddBias { a, bias } => Instr::AddBias { a: f(a), bias: f(bias) },
            Instr::BiasAct { a, bias, act } => Instr::BiasAct { a: f(a), bias: f(bias), act },
            Instr::Relu { a } => Instr::Relu { a: f(a) },
            Instr::Sigmoid { a } => Instr::Sigmoid { a: f(a) },
            Instr::Gelu { a } => Instr::Gelu { a: f(a) },
            Instr::Tanh { a } => Instr::Tanh { a: f(a) },
            Instr::Silu { a } => Instr::Silu { a: f(a) },
            Instr::Exp { a } => Instr::Exp { a: f(a) },
            Instr::ReluGrad { g, act } => Instr::ReluGrad { g: f(g), act: f(act) },
            Instr::SigmoidGrad { dy, y } => Instr::SigmoidGrad { dy: f(dy), y: f(y) },
            Instr::MseLoss { y, t } => Instr::MseLoss { y: f(y), t: f(t) },
            Instr::MseGrad { y, t } => Instr::MseGrad { y: f(y), t: f(t) },
            Instr::ColSum { a } => Instr::ColSum { a: f(a) },
            Instr::Axpy { a, b, c } => Instr::Axpy { a: f(a), b: f(b), c },
            Instr::Scale { a, c } => Instr::Scale { a: f(a), c },
            Instr::Mul { a, b } => Instr::Mul { a: f(a), b: f(b) },
            Instr::Blend { a, b, beta } => Instr::Blend { a: f(a), b: f(b), beta },
            Instr::ActGradI { g, x, act } => Instr::ActGradI { g: f(g), x: f(x), act },
            Instr::Concat2 { a, b } => Instr::Concat2 { a: f(a), b: f(b) },
            Instr::SliceCols { a, start, len } => Instr::SliceCols { a: f(a), start, len },
            Instr::AdamStep { p, m, v, lr, bc1, bc2, eps } => {
                Instr::AdamStep { p: f(p), m: f(m), v: f(v), lr, bc1, bc2, eps }
            }
        }
    }
}

/// A straight-line SSA tensor program. Registers `0..n_inputs` are the
/// entry inputs; instruction `i` defines register `n_inputs + i`.
#[derive(Debug, Clone)]
pub struct Program {
    pub n_inputs: usize,
    pub instrs: Vec<Instr>,
    pub outputs: Vec<Reg>,
}

/// Last-use liveness over one SSA [`Program`], computed once (executables
/// cache it) and reused across tiles. It drives the engine's in-place and
/// buffer-recycling decisions: a register may be written in place or
/// recycled only at its last read, and never when it is a program output.
#[derive(Debug, Clone)]
pub struct ExecPlan {
    /// For each register, the index of the last instruction that reads it
    /// (`None` when no instruction reads it).
    pub last_read: Vec<Option<usize>>,
    /// Registers listed in [`Program::outputs`] — never written in place,
    /// never recycled.
    pub is_output: Vec<bool>,
    /// `retire[i]`: owned registers whose last read is instruction `i`
    /// and which are not outputs; their buffers return to the pool right
    /// after `i` executes.
    pub retire: Vec<Vec<Reg>>,
}

impl Program {
    /// Compute the last-use liveness plan for this program.
    pub fn plan(&self) -> ExecPlan {
        let n_regs = self.n_inputs + self.instrs.len();
        let mut last_read: Vec<Option<usize>> = vec![None; n_regs];
        for (i, instr) in self.instrs.iter().enumerate() {
            for r in instr.reads() {
                if r < n_regs {
                    last_read[r] = Some(i);
                }
            }
        }
        let mut is_output = vec![false; n_regs];
        for &r in &self.outputs {
            if r < n_regs {
                is_output[r] = true;
            }
        }
        let mut retire: Vec<Vec<Reg>> = vec![Vec::new(); self.instrs.len()];
        for r in self.n_inputs..n_regs {
            if is_output[r] {
                continue;
            }
            if let Some(i) = last_read[r] {
                retire[i].push(r);
            }
        }
        ExecPlan { last_read, is_output, retire }
    }

    /// Execute over the given inputs, returning the output registers.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.run_bound(inputs, &[])
    }

    /// Execute with `bound` tensors appended after `inputs` as additional
    /// input registers. The session façade binds stage weights once at
    /// build time this way, so the per-tile call passes only the streamed
    /// tile — no weight cloning on the hot path.
    pub fn run_bound(&self, inputs: &[Tensor], bound: &[Tensor]) -> Result<Vec<Tensor>> {
        let plan = self.plan();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.run_with_plan(&refs, bound, &plan)
    }

    /// The optimized engine: borrowed inputs (the zero-copy hot path), a
    /// precomputed liveness [`ExecPlan`], pooled result buffers, and
    /// in-place elementwise execution wherever the source register is
    /// owned and dead. Bitwise-identical to [`Program::run_reference`].
    pub fn run_with_plan(
        &self,
        inputs: &[&Tensor],
        bound: &[Tensor],
        plan: &ExecPlan,
    ) -> Result<Vec<Tensor>> {
        ensure!(
            inputs.len() + bound.len() == self.n_inputs,
            "program expects {} inputs, got {} (+{} bound)",
            self.n_inputs,
            inputs.len(),
            bound.len()
        );
        let n_regs = self.n_inputs + self.instrs.len();
        ensure!(
            plan.last_read.len() == n_regs
                && plan.is_output.len() == n_regs
                && plan.retire.len() == self.instrs.len(),
            "execution plan does not match program shape"
        );
        let mut regs: Vec<Option<Value>> = Vec::with_capacity(n_regs);
        regs.extend(inputs.iter().map(|&t| Some(Value::In(t))));
        regs.extend(bound.iter().map(|t| Some(Value::In(t))));
        let mut pool = BufferPool::default();
        for (idx, instr) in self.instrs.iter().enumerate() {
            let value = eval_opt(instr, idx, &mut regs, plan, &mut pool)?;
            regs.push(Some(Value::Owned(value)));
            // Retire registers whose last use was this instruction; their
            // buffers seed the pool for upcoming results. (An in-place
            // consumer already took its operand — that slot is `None`.)
            for &r in &plan.retire[idx] {
                if let Some(slot) = regs.get_mut(r) {
                    if let Some(Value::Owned(t)) = slot.take() {
                        pool.recycle(t.data);
                    }
                }
            }
        }
        // Move owned result tensors out; clone only inputs echoed as
        // outputs or registers listed more than once (train_step returns
        // every updated parameter — cloning them all would double the
        // step's memory traffic for nothing). A register that was moved
        // out (malformed plan/program) surfaces as the typed
        // [`RuntimeError::DeadRegister`] instead of an empty placeholder.
        let mut results = Vec::with_capacity(self.outputs.len());
        for (oi, &r) in self.outputs.iter().enumerate() {
            let listed_again = self.outputs[oi + 1..].contains(&r);
            let slot = regs
                .get_mut(r)
                .ok_or_else(|| anyhow!("output register {r} out of range"))?;
            let tensor = match slot.take() {
                None => return Err(RuntimeError::DeadRegister { reg: r }.into()),
                Some(Value::In(t)) => {
                    *slot = Some(Value::In(t));
                    t.clone()
                }
                Some(Value::Owned(t)) => {
                    if listed_again {
                        let copy = t.clone();
                        *slot = Some(Value::Owned(t));
                        copy
                    } else {
                        t
                    }
                }
            };
            results.push(tensor);
        }
        Ok(results)
    }

    /// Scalar-reference oracle: executes the program with naive kernels —
    /// triple-loop matmul, fresh allocation per instruction, no fusion,
    /// no threads, no in-place writes. Slow; retained to *prove* the
    /// optimized engine bitwise-identical (`tests/kernel_equivalence.rs`)
    /// and as the pre-optimization baseline the benches report against.
    /// Fused instructions evaluate as their unfused pair, which defines
    /// their semantics.
    pub fn run_reference(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.run_reference_bound(&refs, &[])
    }

    /// Borrow-aware reference execution with `bound` tensors appended
    /// after `inputs` — the pre-overhaul `run_bound` reproduced exactly
    /// (inputs and bound weights *borrowed*, naive kernels, a fresh
    /// allocation per instruction), so baseline measurements never pay
    /// copies the old engine didn't make.
    pub fn run_reference_bound(&self, inputs: &[&Tensor], bound: &[Tensor]) -> Result<Vec<Tensor>> {
        ensure!(
            inputs.len() + bound.len() == self.n_inputs,
            "program expects {} inputs, got {} (+{} bound)",
            self.n_inputs,
            inputs.len(),
            bound.len()
        );
        let mut regs: Vec<Value> = Vec::with_capacity(self.n_inputs + self.instrs.len());
        regs.extend(inputs.iter().map(|&t| Value::In(t)));
        regs.extend(bound.iter().map(Value::In));
        for instr in &self.instrs {
            let value = eval_reference(instr, &regs)?;
            regs.push(Value::Owned(value));
        }
        let mut results = Vec::with_capacity(self.outputs.len());
        for &r in &self.outputs {
            let v = regs
                .get(r)
                .ok_or_else(|| anyhow!("output register {r} out of range"))?;
            results.push(v.tensor().clone());
        }
        Ok(results)
    }
}

/// A register value: input registers borrow the caller's tensors (the
/// coordinator re-binds the same weight tensors every tile — copying them
/// per invocation would dominate the hot path), instruction results are
/// owned. A `None` slot in the register file marks a value that was moved
/// out (in-place consumption, retirement, or output extraction).
enum Value<'a> {
    In(&'a Tensor),
    Owned(Tensor),
}

impl Value<'_> {
    fn tensor(&self) -> &Tensor {
        match self {
            Value::In(t) => t,
            Value::Owned(t) => t,
        }
    }
}

/// Read register `r`, surfacing moved-out registers as the typed
/// [`RuntimeError::DeadRegister`] instead of silently yielding an empty
/// placeholder tensor.
fn read_reg<'r, 'a>(regs: &'r [Option<Value<'a>>], r: Reg) -> Result<&'r Tensor> {
    match regs.get(r) {
        Some(Some(v)) => Ok(v.tensor()),
        Some(None) => Err(RuntimeError::DeadRegister { reg: r }.into()),
        None => Err(anyhow!("register {r} out of range")),
    }
}

/// Take register `r`'s owned tensor for in-place reuse — only when the
/// liveness plan proves it dead after instruction `idx` and it is not a
/// program output. Returns `None` (leaving the register untouched) in
/// every other case; the caller then falls back to the copying kernel.
fn take_if_dead<'a>(
    regs: &mut [Option<Value<'a>>],
    plan: &ExecPlan,
    idx: usize,
    r: Reg,
) -> Option<Tensor> {
    if r >= plan.last_read.len() || plan.last_read[r] != Some(idx) || plan.is_output[r] {
        return None;
    }
    let slot = regs.get_mut(r)?;
    if matches!(slot, Some(Value::Owned(_))) {
        match slot.take() {
            Some(Value::Owned(t)) => Some(t),
            _ => None,
        }
    } else {
        None
    }
}

/// Small free-list of result buffers, refilled as registers die: the
/// engine's register-file arena. Bounded so long programs cannot hoard.
#[derive(Default)]
struct BufferPool {
    free: Vec<Vec<f32>>,
}

/// Max buffers the pool retains (beyond this, dead buffers just drop).
const POOL_MAX: usize = 8;

impl BufferPool {
    /// An empty buffer with capacity for at least `n` elements. Best-fit
    /// over the free list, and a buffer more than ~4x oversized is left
    /// in the pool — results (which may leave the engine as program
    /// outputs and live on in serving batches) never carry a wildly
    /// larger allocation than their length.
    fn empty(&mut self, n: usize) -> Vec<f32> {
        let limit = n.saturating_mul(4).max(64);
        let mut best: Option<(usize, usize)> = None;
        for (i, b) in self.free.iter().enumerate() {
            let cap = b.capacity();
            if cap >= n && cap <= limit && best.map_or(true, |(_, c)| cap < c) {
                best = Some((i, cap));
            }
        }
        match best {
            Some((i, _)) => {
                let mut b = self.free.swap_remove(i);
                b.clear();
                b
            }
            None => Vec::with_capacity(n),
        }
    }

    /// A zero-filled buffer of exactly `n` elements.
    fn zeroed(&mut self, n: usize) -> Vec<f32> {
        let mut b = self.empty(n);
        b.resize(n, 0.0);
        b
    }

    /// Return a dead register's buffer for reuse.
    fn recycle(&mut self, data: Vec<f32>) {
        if self.free.len() < POOL_MAX && data.capacity() > 0 {
            self.free.push(data);
        }
    }
}

// ---- shared scalar math (one definition per op, used by BOTH engines
// so the optimized/reference pair cannot drift) ----

#[inline(always)]
fn relu_grad_f(gv: f32, av: f32) -> f32 {
    if av > 0.0 {
        gv
    } else {
        0.0
    }
}

#[inline(always)]
fn sigmoid_grad_f(d: f32, yv: f32) -> f32 {
    d * yv * (1.0 - yv)
}

#[inline(always)]
fn mse_grad_f(n: f32) -> impl Fn(f32, f32) -> f32 {
    move |yv, tv| 2.0 * (yv - tv) / n
}

#[inline(always)]
fn axpy_f(c: f32) -> impl Fn(f32, f32) -> f32 {
    move |av, bv| av + c * bv
}

#[inline(always)]
fn blend_f(beta: f32) -> impl Fn(f32, f32) -> f32 {
    move |av, bv| beta * av + (1.0 - beta) * bv
}

#[inline(always)]
fn act_grad_input_f(act: Act) -> impl Fn(f32, f32) -> f32 {
    move |gv, xv| gv * act.grad_at(xv)
}

#[inline(always)]
fn adam_step_f(lr: f32, bc1: f32, bc2: f32, eps: f32) -> impl Fn(f32, f32, f32) -> f32 {
    move |pv, mv, vv| pv - lr * (mv / bc1) / ((vv / bc2).sqrt() + eps)
}

/// `[a | b]` row-wise concat along the trailing dim — one implementation
/// serving both engines (pure copies: bitwise identity is structural).
fn concat_cols(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    ensure!(
        a.dims.len() == 2 && b.dims.len() == 2 && a.dims[0] == b.dims[0],
        "concat needs rank-2 operands with equal rows, got {:?} | {:?}",
        a.dims,
        b.dims
    );
    let (m, na, nb) = (a.dims[0], a.dims[1], b.dims[1]);
    ensure!(na > 0 && nb > 0, "concat needs non-empty columns, got {na} | {nb}");
    let mut data = Vec::with_capacity(m * (na + nb));
    for (ra, rb) in a.data.chunks_exact(na).zip(b.data.chunks_exact(nb)) {
        data.extend_from_slice(ra);
        data.extend_from_slice(rb);
    }
    Tensor::new(vec![m, na + nb], data)
}

/// `a[:, start..start+len]` column slice — shared by both engines.
fn slice_cols(a: &Tensor, start: usize, len: usize) -> Result<Tensor> {
    ensure!(a.dims.len() == 2, "column slice needs rank 2, got {:?}", a.dims);
    let (m, n) = (a.dims[0], a.dims[1]);
    ensure!(
        n > 0 && len > 0 && start + len <= n,
        "slice {start}..{} out of bounds for trailing dim {n}",
        start + len
    );
    let mut data = Vec::with_capacity(m * len);
    for row in a.data.chunks_exact(n) {
        data.extend_from_slice(&row[start..start + len]);
    }
    Tensor::new(vec![m, len], data)
}

/// Three-operand elementwise map (the Adam update) — fresh allocation,
/// identical scalar sequence on both engines.
fn map3(a: &Tensor, b: &Tensor, c: &Tensor, f: impl Fn(f32, f32, f32) -> f32) -> Result<Tensor> {
    ensure!(
        a.dims == b.dims && a.dims == c.dims,
        "elementwise shape mismatch: {:?} vs {:?} vs {:?}",
        a.dims,
        b.dims,
        c.dims
    );
    let data = a
        .data
        .iter()
        .zip(&b.data)
        .zip(&c.data)
        .map(|((&x, &y), &z)| f(x, y, z))
        .collect();
    Tensor::new(a.dims.clone(), data)
}

// ---- optimized engine ----

/// Evaluate one instruction on the optimized engine. Operand registers
/// may be consumed (moved out) when the liveness plan proves them dead
/// after this instruction — the in-place path. Every kernel here
/// matches its counterpart in [`eval_reference`] under the engine's
/// live equivalence tier ([`simd::engine_equivalence`]): bitwise with
/// the vector layer off, ULP-bounded on the FMA paths.
fn eval_opt<'a>(
    instr: &Instr,
    idx: usize,
    regs: &mut Vec<Option<Value<'a>>>,
    plan: &ExecPlan,
    pool: &mut BufferPool,
) -> Result<Tensor> {
    match *instr {
        Instr::Matmul { a, b } => {
            matmul_opt(read_reg(regs, a)?, read_reg(regs, b)?, false, false, None, pool)
        }
        Instr::MatmulTn { a, b } => {
            matmul_opt(read_reg(regs, a)?, read_reg(regs, b)?, true, false, None, pool)
        }
        Instr::MatmulNt { a, b } => {
            matmul_opt(read_reg(regs, a)?, read_reg(regs, b)?, false, true, None, pool)
        }
        Instr::MatmulBias { a, b, bias } => matmul_opt(
            read_reg(regs, a)?,
            read_reg(regs, b)?,
            false,
            false,
            Some(read_reg(regs, bias)?),
            pool,
        ),
        Instr::AddBias { a, bias } => {
            if a != bias {
                if let Some(t) = take_if_dead(regs, plan, idx, a) {
                    return add_bias_inplace(t, read_reg(regs, bias)?);
                }
            }
            add_bias_opt(read_reg(regs, a)?, read_reg(regs, bias)?, pool)
        }
        Instr::BiasAct { a, bias, act } => {
            if a != bias {
                if let Some(t) = take_if_dead(regs, plan, idx, a) {
                    return bias_act_inplace(t, read_reg(regs, bias)?, act);
                }
            }
            bias_act_opt(read_reg(regs, a)?, read_reg(regs, bias)?, act, pool)
        }
        Instr::Relu { a } => unary_opt(regs, plan, idx, pool, a, Act::Relu),
        Instr::Sigmoid { a } => unary_opt(regs, plan, idx, pool, a, Act::Sigmoid),
        Instr::Gelu { a } => unary_opt(regs, plan, idx, pool, a, Act::Gelu),
        Instr::Tanh { a } => unary_opt(regs, plan, idx, pool, a, Act::Tanh),
        Instr::Silu { a } => unary_opt(regs, plan, idx, pool, a, Act::Silu),
        Instr::Exp { a } => unary_opt(regs, plan, idx, pool, a, Act::Exp),
        Instr::ReluGrad { g, act } => {
            if simd::vector_enabled() {
                assign2_opt(regs, plan, idx, pool, g, act, simd::relu_grad_assign)
            } else {
                map2_opt(regs, plan, idx, pool, g, act, relu_grad_f)
            }
        }
        Instr::SigmoidGrad { dy, y } => {
            if simd::vector_enabled() {
                assign2_opt(regs, plan, idx, pool, dy, y, simd::sigmoid_grad_assign)
            } else {
                map2_opt(regs, plan, idx, pool, dy, y, sigmoid_grad_f)
            }
        }
        Instr::MseLoss { y, t } => mse_loss(read_reg(regs, y)?, read_reg(regs, t)?),
        Instr::MseGrad { y, t } => {
            let n = read_reg(regs, y)?.numel().max(1) as f32;
            map2_opt(regs, plan, idx, pool, y, t, mse_grad_f(n))
        }
        Instr::ColSum { a } => col_sum_opt(read_reg(regs, a)?, pool),
        Instr::Axpy { a, b, c } => {
            if simd::vector_enabled() {
                assign2_opt(regs, plan, idx, pool, a, b, |x, y| simd::axpy_assign(x, y, c))
            } else {
                map2_opt(regs, plan, idx, pool, a, b, axpy_f(c))
            }
        }
        Instr::Scale { a, c } => {
            if let Some(mut t) = take_if_dead(regs, plan, idx, a) {
                if simd::vector_enabled() {
                    simd::scale_assign(&mut t.data, c);
                } else {
                    for v in &mut t.data {
                        *v = c * *v;
                    }
                }
                return Ok(t);
            }
            let src = read_reg(regs, a)?;
            let mut data = pool.empty(src.numel());
            if simd::vector_enabled() {
                data.extend_from_slice(&src.data);
                simd::scale_assign(&mut data, c);
            } else {
                data.extend(src.data.iter().map(|&v| c * v));
            }
            Ok(Tensor { dims: src.dims.clone(), data, prec: crate::runtime::Precision::F32 })
        }
        Instr::Mul { a, b } => {
            if simd::vector_enabled() {
                assign2_opt(regs, plan, idx, pool, a, b, simd::mul_assign)
            } else {
                map2_opt(regs, plan, idx, pool, a, b, |x, y| x * y)
            }
        }
        Instr::Blend { a, b, beta } => {
            if simd::vector_enabled() {
                assign2_opt(regs, plan, idx, pool, a, b, |x, y| simd::blend_assign(x, y, beta))
            } else {
                map2_opt(regs, plan, idx, pool, a, b, blend_f(beta))
            }
        }
        Instr::ActGradI { g, x, act } => {
            if act == Act::Relu && simd::vector_enabled() {
                assign2_opt(regs, plan, idx, pool, g, x, simd::relu_act_grad_assign)
            } else {
                map2_opt(regs, plan, idx, pool, g, x, act_grad_input_f(act))
            }
        }
        Instr::Concat2 { a, b } => concat_cols(read_reg(regs, a)?, read_reg(regs, b)?),
        Instr::SliceCols { a, start, len } => slice_cols(read_reg(regs, a)?, start, len),
        Instr::AdamStep { p, m, v, lr, bc1, bc2, eps } => {
            if simd::vector_enabled() {
                let (pt, mt, vt) = (read_reg(regs, p)?, read_reg(regs, m)?, read_reg(regs, v)?);
                adam_opt(pt, mt, vt, lr, bc1, bc2, eps)
            } else {
                map3(
                    read_reg(regs, p)?,
                    read_reg(regs, m)?,
                    read_reg(regs, v)?,
                    adam_step_f(lr, bc1, bc2, eps),
                )
            }
        }
    }
}

/// Vector AdamStep: fresh allocation like [`map3`], one
/// [`simd::adam_assign`] sweep over the copied parameter buffer.
fn adam_opt(
    p: &Tensor,
    m: &Tensor,
    v: &Tensor,
    lr: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
) -> Result<Tensor> {
    ensure!(
        p.dims == m.dims && p.dims == v.dims,
        "elementwise shape mismatch: {:?} vs {:?} vs {:?}",
        p.dims,
        m.dims,
        v.dims
    );
    let mut data = p.data.clone();
    simd::adam_assign(&mut data, &m.data, &v.data, lr, bc1, bc2, eps);
    Tensor::new(p.dims.clone(), data)
}

/// Binary elementwise op on the vector layer: same in-place/pooled
/// policy as [`map2_opt`], but the kernel is a slice-level assign sweep
/// (`dst` arrives holding the first operand) instead of a per-element
/// closure. Out-of-place pays one memcpy plus the vector sweep.
fn assign2_opt<'a>(
    regs: &mut Vec<Option<Value<'a>>>,
    plan: &ExecPlan,
    idx: usize,
    pool: &mut BufferPool,
    a: Reg,
    b: Reg,
    f: impl Fn(&mut [f32], &[f32]),
) -> Result<Tensor> {
    if a != b {
        if let Some(mut t) = take_if_dead(regs, plan, idx, a) {
            let other = read_reg(regs, b)?;
            ensure!(
                t.dims == other.dims,
                "elementwise shape mismatch: {:?} vs {:?}",
                t.dims,
                other.dims
            );
            f(&mut t.data, &other.data);
            return Ok(t);
        }
    }
    let at = read_reg(regs, a)?;
    let bt = read_reg(regs, b)?;
    ensure!(
        at.dims == bt.dims,
        "elementwise shape mismatch: {:?} vs {:?}",
        at.dims,
        bt.dims
    );
    let mut data = pool.empty(at.numel());
    data.extend_from_slice(&at.data);
    f(&mut data, &bt.data);
    Ok(Tensor { dims: at.dims.clone(), data, prec: crate::runtime::Precision::F32 })
}

/// Unary elementwise op: in place when the operand is owned and dead,
/// else one pass into a pooled buffer. Same `Act::apply` either way.
fn unary_opt<'a>(
    regs: &mut Vec<Option<Value<'a>>>,
    plan: &ExecPlan,
    idx: usize,
    pool: &mut BufferPool,
    a: Reg,
    act: Act,
) -> Result<Tensor> {
    let vector = act == Act::Relu && simd::vector_enabled();
    if let Some(mut t) = take_if_dead(regs, plan, idx, a) {
        if vector {
            simd::relu_assign(&mut t.data);
        } else {
            for v in &mut t.data {
                *v = act.apply(*v);
            }
        }
        return Ok(t);
    }
    let src = read_reg(regs, a)?;
    let mut data = pool.empty(src.numel());
    if vector {
        data.extend_from_slice(&src.data);
        simd::relu_assign(&mut data);
    } else {
        data.extend(src.data.iter().map(|&v| act.apply(v)));
    }
    Ok(Tensor { dims: src.dims.clone(), data, prec: crate::runtime::Precision::F32 })
}

/// Binary elementwise op writing into the first operand's buffer when it
/// is owned and dead (and distinct from the second operand).
fn map2_opt<'a>(
    regs: &mut Vec<Option<Value<'a>>>,
    plan: &ExecPlan,
    idx: usize,
    pool: &mut BufferPool,
    a: Reg,
    b: Reg,
    f: impl Fn(f32, f32) -> f32,
) -> Result<Tensor> {
    if a != b {
        if let Some(mut t) = take_if_dead(regs, plan, idx, a) {
            let other = read_reg(regs, b)?;
            ensure!(
                t.dims == other.dims,
                "elementwise shape mismatch: {:?} vs {:?}",
                t.dims,
                other.dims
            );
            for (x, &y) in t.data.iter_mut().zip(&other.data) {
                *x = f(*x, y);
            }
            return Ok(t);
        }
    }
    let at = read_reg(regs, a)?;
    let bt = read_reg(regs, b)?;
    ensure!(
        at.dims == bt.dims,
        "elementwise shape mismatch: {:?} vs {:?}",
        at.dims,
        bt.dims
    );
    let mut data = pool.empty(at.numel());
    data.extend(at.data.iter().zip(&bt.data).map(|(&x, &y)| f(x, y)));
    Ok(Tensor { dims: at.dims.clone(), data, prec: crate::runtime::Precision::F32 })
}

/// Validate a `[m,n] (+) [n]` bias broadcast, returning `n`.
fn check_bias(a: &Tensor, bias: &Tensor) -> Result<usize> {
    ensure!(a.dims.len() == 2, "bias add needs a rank-2 lhs, got {:?}", a.dims);
    let n = a.dims[1];
    ensure!(n > 0, "bias add needs a non-empty trailing dim, got {:?}", a.dims);
    ensure!(
        bias.dims == [n],
        "bias shape {:?} does not broadcast over {:?}",
        bias.dims,
        a.dims
    );
    Ok(n)
}

fn add_bias_opt(a: &Tensor, bias: &Tensor, pool: &mut BufferPool) -> Result<Tensor> {
    let n = check_bias(a, bias)?;
    let mut data = pool.empty(a.numel());
    if simd::vector_enabled() {
        data.extend_from_slice(&a.data);
        simd::add_bias_assign(&mut data, &bias.data);
        return Tensor::new(a.dims.clone(), data);
    }
    // Row chunks: a straight fused loop per row instead of a per-element
    // `idx % n` division.
    for row in a.data.chunks_exact(n) {
        data.extend(row.iter().zip(&bias.data).map(|(&v, &b)| v + b));
    }
    Tensor::new(a.dims.clone(), data)
}

fn add_bias_inplace(mut a: Tensor, bias: &Tensor) -> Result<Tensor> {
    let n = check_bias(&a, bias)?;
    if simd::vector_enabled() {
        simd::add_bias_assign(&mut a.data, &bias.data);
        return Ok(a);
    }
    for row in a.data.chunks_exact_mut(n) {
        for (v, &b) in row.iter_mut().zip(&bias.data) {
            *v += b;
        }
    }
    Ok(a)
}

/// Vector BiasAct: one bias-add sweep, then the activation sweep (Relu
/// stays 8-wide; transcendentals run `Act::apply` per lane). The add is
/// exact, so splitting the fused scalar `act(v + b)` into two passes
/// feeds `apply` the identical inputs — same values out.
fn bias_act_sweep(data: &mut [f32], bias: &[f32], act: Act) {
    simd::add_bias_assign(data, bias);
    if act == Act::Relu {
        simd::relu_assign(data);
    } else {
        for v in data {
            *v = act.apply(*v);
        }
    }
}

fn bias_act_opt(a: &Tensor, bias: &Tensor, act: Act, pool: &mut BufferPool) -> Result<Tensor> {
    let n = check_bias(a, bias)?;
    let mut data = pool.empty(a.numel());
    if simd::vector_enabled() {
        data.extend_from_slice(&a.data);
        bias_act_sweep(&mut data, &bias.data, act);
        return Tensor::new(a.dims.clone(), data);
    }
    for row in a.data.chunks_exact(n) {
        data.extend(row.iter().zip(&bias.data).map(|(&v, &b)| act.apply(v + b)));
    }
    Tensor::new(a.dims.clone(), data)
}

fn bias_act_inplace(mut a: Tensor, bias: &Tensor, act: Act) -> Result<Tensor> {
    let n = check_bias(&a, bias)?;
    if simd::vector_enabled() {
        bias_act_sweep(&mut a.data, &bias.data, act);
        return Ok(a);
    }
    for row in a.data.chunks_exact_mut(n) {
        for (v, &b) in row.iter_mut().zip(&bias.data) {
            *v = act.apply(*v + b);
        }
    }
    Ok(a)
}

fn col_sum_opt(a: &Tensor, pool: &mut BufferPool) -> Result<Tensor> {
    ensure!(a.dims.len() == 2, "column sum needs rank 2, got {:?}", a.dims);
    let n = a.dims[1];
    let mut out = pool.zeroed(n);
    if n > 0 {
        // Rows in increasing order — the reference's accumulation order.
        for row in a.data.chunks_exact(n) {
            for (o, &v) in out.iter_mut().zip(row) {
                *o += v;
            }
        }
    }
    Tensor::new(vec![n], out)
}

// ---- blocked / parallel matmul ----

/// Micro-kernel tile: MR×NR accumulators held in registers across the
/// whole contraction (8 SSE registers of f32x4 at 4×8), so each output
/// element is stored once instead of loaded+stored per multiply-add.
const MR: usize = 4;
/// See [`MR`].
const NR: usize = 8;

/// Default FLOP count below which a matmul stays on the calling thread:
/// one streamed NeRF-trunk tile (and every unit-test shape) is far
/// cheaper than a fork-join, and the pipeline already runs stages as
/// pool tasks.
const DEFAULT_PAR_MIN_FLOPS: usize = 1 << 21;

/// Cap on row-panel tasks for a single matmul call.
const PAR_MAX_WORKERS: usize = 4;

/// Current parallel threshold; 0 means "not initialized yet" (first
/// read consults `KITSUNE_MATMUL_THRESHOLD`, then the default).
static PAR_THRESHOLD: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// The FLOP threshold (2·m·k·n) at or above which matmuls fan out into
/// row-panel tasks on the shared scheduler. Initialized from the
/// `KITSUNE_MATMUL_THRESHOLD` env var on first use (falling back to
/// ~2 MFLOP); override programmatically with
/// [`set_matmul_par_threshold`]. Both sides of the threshold are
/// bitwise-identical — this knob trades fork-join overhead against
/// panel parallelism, never numerics.
pub fn matmul_par_threshold() -> usize {
    let cur = PAR_THRESHOLD.load(std::sync::atomic::Ordering::Relaxed);
    if cur != 0 {
        return cur;
    }
    let init = std::env::var("KITSUNE_MATMUL_THRESHOLD")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&v| v >= 1)
        .unwrap_or(DEFAULT_PAR_MIN_FLOPS);
    PAR_THRESHOLD.store(init, std::sync::atomic::Ordering::Relaxed);
    init
}

/// Set the parallel-matmul FLOP threshold (clamped to ≥ 1; 1 forces
/// every ≥2-row matmul parallel, `usize::MAX` forces serial).
pub fn set_matmul_par_threshold(flops: usize) {
    PAR_THRESHOLD.store(flops.max(1), std::sync::atomic::Ordering::Relaxed);
}

/// Worker count the kernel will use for an `m x k x n` matmul: 1
/// (serial) below [`matmul_par_threshold`], else up to
/// [`PAR_MAX_WORKERS`] row panels (bounded by the current scheduler's
/// worker count and by `m`).
pub fn matmul_workers(m: usize, k: usize, n: usize) -> usize {
    let flops = 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n);
    if flops < matmul_par_threshold() || m < 2 {
        return 1;
    }
    crate::sched::current().workers().min(PAR_MAX_WORKERS).min(m)
}

/// `a (T?) @ b (T?) (+ bias)`. Logical shapes are derived from the
/// physical dims plus the transpose flags; everything is validated.
/// Matches [`matmul_ref`] + [`add_bias_ref`] under the live equivalence
/// tier: the blocked, parallel, fused, and vector variants all run the
/// contraction `kk = 0..k` in increasing order per output element, with
/// the bias added after the full sum — scalar paths bitwise, the AVX
/// FMA path within [`simd::VECTOR_ULP_BOUND`] ULP.
fn matmul_opt(
    a: &Tensor,
    b: &Tensor,
    ta: bool,
    tb: bool,
    bias: Option<&Tensor>,
    pool: &mut BufferPool,
) -> Result<Tensor> {
    ensure!(
        a.dims.len() == 2 && b.dims.len() == 2,
        "matmul needs rank-2 operands, got {:?} x {:?}",
        a.dims,
        b.dims
    );
    let (m, k) = if ta { (a.dims[1], a.dims[0]) } else { (a.dims[0], a.dims[1]) };
    let (k2, n) = if tb { (b.dims[1], b.dims[0]) } else { (b.dims[0], b.dims[1]) };
    ensure!(
        k == k2,
        "matmul contraction mismatch: {:?}{} x {:?}{}",
        a.dims,
        if ta { "ᵀ" } else { "" },
        b.dims,
        if tb { "ᵀ" } else { "" }
    );
    if let Some(bias) = bias {
        // Mirror `check_bias` exactly, so the fused form errs whenever
        // the unfused `Matmul` + `AddBias` pair would.
        ensure!(n > 0, "bias add needs a non-empty trailing dim, got [{m}, {n}]");
        ensure!(
            bias.dims == [n],
            "bias shape {:?} does not broadcast over [{m}, {n}]",
            bias.dims
        );
    }
    let (lda, ldb) = (a.dims[1], b.dims[1]);
    let mut out = pool.zeroed(m * n);
    let bias_data = bias.map(|t| t.data.as_slice());
    let workers = matmul_workers(m, k, n);
    // Engine-level dispatch: the vector micro-kernel shares the panel
    // decomposition and contraction order, so the choice composes with
    // the parallel split below without touching the row partitioning.
    let vector = simd::vector_enabled();
    let panel_kernel = if vector { simd::matmul_panel } else { matmul_panel };
    if workers <= 1 || n == 0 {
        panel_kernel(&a.data, &b.data, &mut out, 0, m, k, n, lda, ldb, ta, tb, bias_data);
    } else {
        // Row-panel split over a fork-join scope on the shared
        // scheduler: each task owns a disjoint slice of output rows, so
        // no synchronization beyond the join, and per-element math is
        // untouched. The panel decomposition is identical to the serial
        // path's single full-range call, keeping results bitwise equal.
        let rows_per = m.div_ceil(workers);
        let a_data = a.data.as_slice();
        let b_data = b.data.as_slice();
        crate::sched::scope(|scope| {
            for (pi, panel) in out.chunks_mut(rows_per * n).enumerate() {
                let i0 = pi * rows_per;
                let rows = panel.len() / n;
                // Label each panel with its output-row range so a panic
                // inside one names the dying panel at the join.
                scope.spawn_labeled(format!("gemm panel rows {i0}..{}", i0 + rows), move || {
                    panel_kernel(
                        a_data,
                        b_data,
                        panel,
                        i0,
                        i0 + rows,
                        k,
                        n,
                        lda,
                        ldb,
                        ta,
                        tb,
                        bias_data,
                    );
                });
            }
        });
    }
    Tensor::new(vec![m, n], out)
}

/// Compute output rows `i0..i1` of the matmul into `out` (the panel's
/// rows only, row-major `[i1-i0, n]`).
///
/// Register-blocked: an MR×NR accumulator block lives in registers for
/// the whole `kk` loop; the `b` block (`k × NR` values) stays hot in L1
/// across every row of the panel (`jb` is the outer loop). No zero-skip
/// — `0 * NaN` must stay NaN so diverged values propagate exactly as
/// they do through the XLA backend — and no k-blocking, which would
/// re-associate the f32 adds and break bitwise equality.
#[allow(clippy::too_many_arguments)]
fn matmul_panel(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    i0: usize,
    i1: usize,
    k: usize,
    n: usize,
    lda: usize,
    ldb: usize,
    ta: bool,
    tb: bool,
    bias: Option<&[f32]>,
) {
    let rows = i1 - i0;
    let mut jb = 0;
    while jb < n {
        let nr = NR.min(n - jb);
        let mut ib = 0;
        while ib < rows {
            let mr = MR.min(rows - ib);
            if mr == MR && nr == NR {
                let mut acc = [[0.0f32; NR]; MR];
                for kk in 0..k {
                    let mut bv = [0.0f32; NR];
                    if tb {
                        for (c, slot) in bv.iter_mut().enumerate() {
                            *slot = b[(jb + c) * ldb + kk];
                        }
                    } else {
                        bv.copy_from_slice(&b[kk * ldb + jb..kk * ldb + jb + NR]);
                    }
                    for (r, acc_row) in acc.iter_mut().enumerate() {
                        let i = i0 + ib + r;
                        let av = if ta { a[kk * lda + i] } else { a[i * lda + kk] };
                        for (o, &bvc) in acc_row.iter_mut().zip(&bv) {
                            *o += av * bvc;
                        }
                    }
                }
                for (r, acc_row) in acc.iter().enumerate() {
                    let base = (ib + r) * n + jb;
                    out[base..base + NR].copy_from_slice(acc_row);
                }
            } else {
                // Edge block: same accumulation order, dynamic bounds.
                for r in 0..mr {
                    let i = i0 + ib + r;
                    for c in 0..nr {
                        let j = jb + c;
                        let mut acc = 0.0f32;
                        for kk in 0..k {
                            let av = if ta { a[kk * lda + i] } else { a[i * lda + kk] };
                            let bvc = if tb { b[j * ldb + kk] } else { b[kk * ldb + j] };
                            acc += av * bvc;
                        }
                        out[(ib + r) * n + j] = acc;
                    }
                }
            }
            ib += mr;
        }
        jb += nr;
    }
    if n == 0 {
        return;
    }
    if let Some(bias) = bias {
        // Fused epilogue: the bias joins after the full contraction, so
        // the sum's rounding sequence matches the unfused pair exactly.
        for row in out.chunks_exact_mut(n) {
            for (o, &bv) in row.iter_mut().zip(bias) {
                *o += bv;
            }
        }
    }
}

// ---- scalar reference kernels (the retained oracle) ----

/// Evaluate one instruction with the naive scalar kernels.
fn eval_reference(instr: &Instr, regs: &[Value]) -> Result<Tensor> {
    let r = |i: Reg| -> Result<&Tensor> {
        regs.get(i)
            .map(Value::tensor)
            .ok_or_else(|| anyhow!("register {i} out of range"))
    };
    match *instr {
        Instr::Matmul { a, b } => matmul_ref(r(a)?, r(b)?, false, false),
        Instr::MatmulTn { a, b } => matmul_ref(r(a)?, r(b)?, true, false),
        Instr::MatmulNt { a, b } => matmul_ref(r(a)?, r(b)?, false, true),
        Instr::MatmulBias { a, b, bias } => {
            let mm = matmul_ref(r(a)?, r(b)?, false, false)?;
            add_bias_ref(&mm, r(bias)?)
        }
        Instr::AddBias { a, bias } => add_bias_ref(r(a)?, r(bias)?),
        Instr::BiasAct { a, bias, act } => {
            let z = add_bias_ref(r(a)?, r(bias)?)?;
            Ok(map1_ref(&z, |v| act.apply(v)))
        }
        Instr::Relu { a } => Ok(map1_ref(r(a)?, |v| Act::Relu.apply(v))),
        Instr::Sigmoid { a } => Ok(map1_ref(r(a)?, |v| Act::Sigmoid.apply(v))),
        Instr::Gelu { a } => Ok(map1_ref(r(a)?, |v| Act::Gelu.apply(v))),
        Instr::Tanh { a } => Ok(map1_ref(r(a)?, |v| Act::Tanh.apply(v))),
        Instr::Silu { a } => Ok(map1_ref(r(a)?, |v| Act::Silu.apply(v))),
        Instr::Exp { a } => Ok(map1_ref(r(a)?, |v| Act::Exp.apply(v))),
        Instr::ReluGrad { g, act } => map2_ref(r(g)?, r(act)?, relu_grad_f),
        Instr::SigmoidGrad { dy, y } => map2_ref(r(dy)?, r(y)?, sigmoid_grad_f),
        Instr::MseLoss { y, t } => mse_loss(r(y)?, r(t)?),
        Instr::MseGrad { y, t } => {
            let n = r(y)?.numel().max(1) as f32;
            map2_ref(r(y)?, r(t)?, mse_grad_f(n))
        }
        Instr::ColSum { a } => col_sum_ref(r(a)?),
        Instr::Axpy { a, b, c } => map2_ref(r(a)?, r(b)?, axpy_f(c)),
        Instr::Scale { a, c } => Ok(map1_ref(r(a)?, |v| c * v)),
        Instr::Mul { a, b } => map2_ref(r(a)?, r(b)?, |x, y| x * y),
        Instr::Blend { a, b, beta } => map2_ref(r(a)?, r(b)?, blend_f(beta)),
        Instr::ActGradI { g, x, act } => map2_ref(r(g)?, r(x)?, act_grad_input_f(act)),
        Instr::Concat2 { a, b } => concat_cols(r(a)?, r(b)?),
        Instr::SliceCols { a, start, len } => slice_cols(r(a)?, start, len),
        Instr::AdamStep { p, m, v, lr, bc1, bc2, eps } => {
            map3(r(p)?, r(m)?, r(v)?, adam_step_f(lr, bc1, bc2, eps))
        }
    }
}

/// Naive triple-loop `a (T?) @ b (T?)` — the reference contraction.
fn matmul_ref(a: &Tensor, b: &Tensor, ta: bool, tb: bool) -> Result<Tensor> {
    ensure!(
        a.dims.len() == 2 && b.dims.len() == 2,
        "matmul needs rank-2 operands, got {:?} x {:?}",
        a.dims,
        b.dims
    );
    let (m, k) = if ta { (a.dims[1], a.dims[0]) } else { (a.dims[0], a.dims[1]) };
    let (k2, n) = if tb { (b.dims[1], b.dims[0]) } else { (b.dims[0], b.dims[1]) };
    ensure!(
        k == k2,
        "matmul contraction mismatch: {:?}{} x {:?}{}",
        a.dims,
        if ta { "ᵀ" } else { "" },
        b.dims,
        if tb { "ᵀ" } else { "" }
    );
    let (lda, ldb) = (a.dims[1], b.dims[1]);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            // No zero-skip: 0 * NaN must stay NaN so diverged values
            // propagate exactly as they do through the XLA backend.
            let av = if ta { a.data[kk * lda + i] } else { a.data[i * lda + kk] };
            let row = &mut out[i * n..(i + 1) * n];
            if tb {
                for (j, o) in row.iter_mut().enumerate() {
                    *o += av * b.data[j * ldb + kk];
                }
            } else {
                let brow = &b.data[kk * ldb..kk * ldb + n];
                for (o, &bv) in row.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

fn add_bias_ref(a: &Tensor, bias: &Tensor) -> Result<Tensor> {
    let n = check_bias(a, bias)?;
    let mut data = Vec::with_capacity(a.data.len());
    for row in a.data.chunks_exact(n) {
        for (&v, &b) in row.iter().zip(&bias.data) {
            data.push(v + b);
        }
    }
    Tensor::new(a.dims.clone(), data)
}

fn map1_ref(a: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    Tensor {
        dims: a.dims.clone(),
        data: a.data.iter().map(|&v| f(v)).collect(),
        prec: crate::runtime::Precision::F32,
    }
}

fn map2_ref(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
    ensure!(a.dims == b.dims, "elementwise shape mismatch: {:?} vs {:?}", a.dims, b.dims);
    let data = a.data.iter().zip(&b.data).map(|(&x, &y)| f(x, y)).collect();
    Tensor::new(a.dims.clone(), data)
}

fn mse_loss(y: &Tensor, t: &Tensor) -> Result<Tensor> {
    ensure!(y.dims == t.dims, "mse shape mismatch: {:?} vs {:?}", y.dims, t.dims);
    let n = y.numel().max(1) as f64;
    let sum: f64 = y.data.iter().zip(&t.data).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
    Tensor::new(Vec::new(), vec![(sum / n) as f32])
}

fn col_sum_ref(a: &Tensor) -> Result<Tensor> {
    ensure!(a.dims.len() == 2, "column sum needs rank 2, got {:?}", a.dims);
    let (m, n) = (a.dims[0], a.dims[1]);
    let mut out = vec![0.0f32; n];
    for i in 0..m {
        for j in 0..n {
            out[j] += a.data[i * n + j];
        }
    }
    Tensor::new(vec![n], out)
}

// ---- program construction ----

/// Incremental program construction (registers allocated in SSA order).
struct ProgramBuilder {
    n_inputs: usize,
    instrs: Vec<Instr>,
}

impl ProgramBuilder {
    fn new(n_inputs: usize) -> Self {
        ProgramBuilder { n_inputs, instrs: Vec::new() }
    }

    fn push(&mut self, instr: Instr) -> Reg {
        let reg = self.n_inputs + self.instrs.len();
        self.instrs.push(instr);
        reg
    }

    /// `x @ w + b`.
    fn linear(&mut self, x: Reg, w: Reg, b: Reg) -> Reg {
        let mm = self.push(Instr::Matmul { a: x, b: w });
        self.push(Instr::AddBias { a: mm, bias: b })
    }

    fn finish(self, outputs: Vec<Reg>) -> Program {
        Program { n_inputs: self.n_inputs, instrs: self.instrs, outputs }
    }
}

/// Forward pass of the NeRF-class MLP (`nerf_forward`, both variants —
/// the Pallas and reference paths are numerically identical by design):
/// three ReLU trunk layers + sigmoid head.
fn forward_program() -> Program {
    let mut p = ProgramBuilder::new(9);
    let (x, w1, b1, w2, b2, w3, b3, w4, b4) = (0, 1, 2, 3, 4, 5, 6, 7, 8);
    let z1 = p.linear(x, w1, b1);
    let a1 = p.push(Instr::Relu { a: z1 });
    let z2 = p.linear(a1, w2, b2);
    let a2 = p.push(Instr::Relu { a: z2 });
    let z3 = p.linear(a2, w3, b3);
    let a3 = p.push(Instr::Relu { a: z3 });
    let z4 = p.linear(a3, w4, b4);
    let y = p.push(Instr::Sigmoid { a: z4 });
    p.finish(vec![y])
}

/// One SGD step at the given learning rate: forward, MSE loss,
/// hand-derived reverse-mode backward, parameter update. ABI matches
/// `model.train_step`: `(x, y, *params) -> (loss, *new_params)`.
///
/// The legacy `train_step` manifest entry instantiates this at the
/// compat [`LR`]; the training subsystem ([`crate::train`]) passes the
/// configured rate instead — the hardcoded constant is no longer the
/// only way to train.
pub fn train_step_program(lr: f32) -> Program {
    let mut p = ProgramBuilder::new(10);
    let (x, t) = (0, 1);
    let (w1, b1, w2, b2, w3, b3, w4, b4) = (2, 3, 4, 5, 6, 7, 8, 9);

    // Forward (saving activations for the VJPs).
    let z1 = p.linear(x, w1, b1);
    let a1 = p.push(Instr::Relu { a: z1 });
    let z2 = p.linear(a1, w2, b2);
    let a2 = p.push(Instr::Relu { a: z2 });
    let z3 = p.linear(a2, w3, b3);
    let a3 = p.push(Instr::Relu { a: z3 });
    let z4 = p.linear(a3, w4, b4);
    let y = p.push(Instr::Sigmoid { a: z4 });
    let loss = p.push(Instr::MseLoss { y, t });

    // Backward: dL/dy, then layer by layer. The weight-gradient GEMMs
    // contract over the batch dimension and the bias gradients are batch
    // reductions — exactly the Fig 2(b) structures the paper pipelines.
    let dy = p.push(Instr::MseGrad { y, t });
    let dz4 = p.push(Instr::SigmoidGrad { dy, y });
    let dw4 = p.push(Instr::MatmulTn { a: a3, b: dz4 });
    let db4 = p.push(Instr::ColSum { a: dz4 });
    let da3 = p.push(Instr::MatmulNt { a: dz4, b: w4 });
    let dz3 = p.push(Instr::ReluGrad { g: da3, act: a3 });
    let dw3 = p.push(Instr::MatmulTn { a: a2, b: dz3 });
    let db3 = p.push(Instr::ColSum { a: dz3 });
    let da2 = p.push(Instr::MatmulNt { a: dz3, b: w3 });
    let dz2 = p.push(Instr::ReluGrad { g: da2, act: a2 });
    let dw2 = p.push(Instr::MatmulTn { a: a1, b: dz2 });
    let db2 = p.push(Instr::ColSum { a: dz2 });
    let da1 = p.push(Instr::MatmulNt { a: dz2, b: w2 });
    let dz1 = p.push(Instr::ReluGrad { g: da1, act: a1 });
    let dw1 = p.push(Instr::MatmulTn { a: x, b: dz1 });
    let db1 = p.push(Instr::ColSum { a: dz1 });

    // SGD update.
    let step = |p: &mut ProgramBuilder, param: Reg, grad: Reg| {
        p.push(Instr::Axpy { a: param, b: grad, c: -lr })
    };
    let nw1 = step(&mut p, w1, dw1);
    let nb1 = step(&mut p, b1, db1);
    let nw2 = step(&mut p, w2, dw2);
    let nb2 = step(&mut p, b2, db2);
    let nw3 = step(&mut p, w3, dw3);
    let nb3 = step(&mut p, b3, db3);
    let nw4 = step(&mut p, w4, dw4);
    let nb4 = step(&mut p, b4, db4);

    p.finish(vec![loss, nw1, nb1, nw2, nb2, nw3, nb3, nw4, nb4])
}

/// Pipeline stage 0 (`stage_trunk0`): `relu(fused_mlp(x, w1, b1, w2, b2))`
/// = `relu(relu(x@w1+b1) @ w2 + b2)`.
fn stage_trunk0_program() -> Program {
    let mut p = ProgramBuilder::new(5);
    let (x, w1, b1, w2, b2) = (0, 1, 2, 3, 4);
    let z1 = p.linear(x, w1, b1);
    let a1 = p.push(Instr::Relu { a: z1 });
    let z2 = p.linear(a1, w2, b2);
    let a2 = p.push(Instr::Relu { a: z2 });
    p.finish(vec![a2])
}

/// Pipeline stage 1 (`stage_trunk1`): `relu(h @ w3 + b3)`.
fn stage_trunk1_program() -> Program {
    let mut p = ProgramBuilder::new(3);
    let z = p.linear(0, 1, 2);
    let a = p.push(Instr::Relu { a: z });
    p.finish(vec![a])
}

/// Pipeline stage 2 (`stage_head`): `sigmoid(h @ w4 + b4)`.
fn stage_head_program() -> Program {
    let mut p = ProgramBuilder::new(3);
    let z = p.linear(0, 1, 2);
    let y = p.push(Instr::Sigmoid { a: z });
    p.finish(vec![y])
}

/// Resolve a manifest entry to its interpreter program, validating the
/// declared ABI (input arity, output count) against the program.
pub fn entry_program(spec: &EntrySpec) -> Result<Program> {
    let program = match spec.name.as_str() {
        "nerf_forward" | "nerf_forward_pallas" => forward_program(),
        // Compat shim: the AOT entry keeps its baked-in default rate; the
        // configurable path is `kitsune::train` (see `train_step_program`).
        "train_step" => train_step_program(LR),
        "stage_trunk0" => stage_trunk0_program(),
        "stage_trunk1" => stage_trunk1_program(),
        "stage_head" => stage_head_program(),
        _ => {
            return Err(RuntimeError::UnsupportedEntry {
                name: spec.name.clone(),
                backend: "interp",
            }
            .into())
        }
    };
    ensure!(
        program.n_inputs == spec.inputs.len(),
        "{}: manifest declares {} inputs, interpreter program expects {}",
        spec.name,
        spec.inputs.len(),
        program.n_inputs
    );
    ensure!(
        program.outputs.len() == spec.n_outputs,
        "{}: manifest declares {} outputs, interpreter program produces {}",
        spec.name,
        spec.n_outputs,
        program.outputs.len()
    );
    Ok(program)
}

/// The pure-Rust interpreter backend (always available, the default).
#[derive(Debug, Clone, Default)]
pub struct InterpBackend;

impl InterpBackend {
    pub fn new() -> Self {
        InterpBackend
    }
}

impl Backend for InterpBackend {
    fn name(&self) -> &'static str {
        "interp"
    }

    fn compile(&self, spec: &EntrySpec) -> Result<Box<dyn Executable>> {
        let program = entry_program(spec)?;
        let plan = program.plan();
        Ok(Box::new(InterpExecutable { name: spec.name.clone(), program, plan }))
    }
}

struct InterpExecutable {
    name: String,
    program: Program,
    /// Liveness, computed once at compile time — never per tile.
    plan: ExecPlan,
}

impl Executable for InterpExecutable {
    fn run_f32(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.program
            .run_with_plan(&refs, &[], &self.plan)
            .with_context(|| format!("interp entry {}", self.name))
    }

    fn run_f32_ref(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.program
            .run_with_plan(inputs, &[], &self.plan)
            .with_context(|| format!("interp entry {}", self.name))
    }
}

/// Wrap a synthesized [`Program`] as a runnable [`Executable`] — how the
/// session façade turns lowered compiler stages into stage kernels
/// without any on-disk manifest entry.
pub fn program_executable(name: impl Into<String>, program: Program) -> Box<dyn Executable> {
    let plan = program.plan();
    Box::new(InterpExecutable { name: name.into(), program, plan })
}

/// Like [`program_executable`], but with `bound` tensors (stage weights)
/// fixed at construction: callers pass only the streamed tile.
pub fn bound_executable(
    name: impl Into<String>,
    program: Program,
    bound: Vec<Tensor>,
) -> Box<dyn Executable> {
    let plan = program.plan();
    Box::new(BoundExecutable { name: name.into(), program, bound, plan })
}

struct BoundExecutable {
    name: String,
    program: Program,
    bound: Vec<Tensor>,
    /// Liveness, computed once at build time — never per tile.
    plan: ExecPlan,
}

impl Executable for BoundExecutable {
    fn run_f32(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.program
            .run_with_plan(&refs, &self.bound, &self.plan)
            .with_context(|| format!("interp entry {}", self.name))
    }

    fn run_f32_ref(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.program
            .run_with_plan(inputs, &self.bound, &self.plan)
            .with_context(|| format!("interp entry {}", self.name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::manifest::TensorSpec;
    use super::super::tensor::Rng;
    use std::path::PathBuf;

    fn t(dims: &[usize], data: &[f32]) -> Tensor {
        Tensor::new(dims.to_vec(), data.to_vec()).unwrap()
    }

    fn spec(name: &str, ins: &[Vec<usize>], outs: usize) -> EntrySpec {
        EntrySpec {
            name: name.to_string(),
            hlo_path: PathBuf::from(format!("{name}.hlo.txt")),
            inputs: ins
                .iter()
                .map(|d| TensorSpec { dtype: "f32".to_string(), dims: d.clone() })
                .collect(),
            n_outputs: outs,
        }
    }

    /// Run a 2-operand matmul variant through the optimized engine.
    fn matmul_opt_via_program(a: &Tensor, b: &Tensor, ta: bool, tb: bool) -> Result<Tensor> {
        let instr = match (ta, tb) {
            (false, false) => Instr::Matmul { a: 0, b: 1 },
            (true, false) => Instr::MatmulTn { a: 0, b: 1 },
            (false, true) => Instr::MatmulNt { a: 0, b: 1 },
            (true, true) => unreachable!("no TT variant in the ISA"),
        };
        let p = Program { n_inputs: 2, instrs: vec![instr], outputs: vec![2] };
        Ok(p.run(&[a.clone(), b.clone()])?.remove(0))
    }

    #[test]
    fn matmul_plain_and_transposed() {
        let a = t(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = t(&[3, 2], &[7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = matmul_ref(&a, &b, false, false).unwrap();
        assert_eq!(c.dims, vec![2, 2]);
        assert_eq!(c.data, vec![58.0, 64.0, 139.0, 154.0]);
        // The optimized engine agrees exactly.
        let c_opt = matmul_opt_via_program(&a, &b, false, false).unwrap();
        assert_eq!(c.data, c_opt.data);
        // Gram-matrix symmetry exercises both transpose flags.
        let g1 = matmul_ref(&a, &a, true, false).unwrap(); // aT a : [3,3]
        let g2 = matmul_ref(&a, &a, false, true).unwrap(); // a aT : [2,2]
        assert_eq!(g1.dims, vec![3, 3]);
        assert_eq!(g2.dims, vec![2, 2]);
        assert_eq!(g1.data[1], g1.data[3]); // symmetric
        assert_eq!(g2.data[1], g2.data[2]);
        assert_eq!(g1.data, matmul_opt_via_program(&a, &a, true, false).unwrap().data);
        assert_eq!(g2.data, matmul_opt_via_program(&a, &a, false, true).unwrap().data);
        // Tn/Nt agree with matmul against an explicitly transposed operand.
        let at = t(&[3, 2], &[1.0, 4.0, 2.0, 5.0, 3.0, 6.0]); // aT materialized
        let c = t(&[2, 2], &[1.0, -1.0, 2.0, 0.5]);
        let tn = matmul_ref(&a, &c, true, false).unwrap(); // aT @ c : [3,2]
        let explicit = matmul_ref(&at, &c, false, false).unwrap();
        assert_eq!(tn.data, explicit.data);
        let ct = t(&[2, 2], &[1.0, 2.0, -1.0, 0.5]); // cT materialized
        let nt = matmul_ref(&at, &c, false, true).unwrap(); // aT @ cT : [3,2]
        let explicit2 = matmul_ref(&at, &ct, false, false).unwrap();
        assert_eq!(nt.data, explicit2.data);
        // Contraction mismatches are rejected by both engines.
        assert!(matmul_ref(&a, &b, true, false).is_err());
        assert!(matmul_opt_via_program(&a, &b, true, false).is_err());
    }

    #[test]
    fn bias_and_colsum() {
        let a = t(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = add_bias_ref(&a, &t(&[3], &[10.0, 20.0, 30.0])).unwrap();
        assert_eq!(b.data, vec![11.0, 22.0, 33.0, 14.0, 25.0, 36.0]);
        let s = col_sum_ref(&a).unwrap();
        assert_eq!(s.dims, vec![3]);
        assert_eq!(s.data, vec![5.0, 7.0, 9.0]);
        assert!(add_bias_ref(&a, &t(&[2], &[0.0, 0.0])).is_err());
        // The optimized standalone path matches (row-chunked, no idx % n).
        let p = Program {
            n_inputs: 2,
            instrs: vec![Instr::AddBias { a: 0, bias: 1 }],
            outputs: vec![2],
        };
        let b_opt = p.run(&[a.clone(), t(&[3], &[10.0, 20.0, 30.0])]).unwrap();
        assert_eq!(b.data, b_opt[0].data);
    }

    #[test]
    fn fused_instrs_match_their_unfused_pairs_bitwise() {
        let mut rng = Rng::new(5);
        let x = Tensor {
            dims: vec![5, 7],
            data: (0..35).map(|_| rng.normal()).collect(),
            prec: crate::runtime::Precision::F32,
        };
        let w = rng.he_tensor(&[7, 3]);
        let mut b = rng.he_tensor(&[3]);
        b.data.iter_mut().for_each(|v| *v = rng.normal() * 0.3);
        let inputs = [x, w, b];

        let unfused = Program {
            n_inputs: 3,
            instrs: vec![
                Instr::Matmul { a: 0, b: 1 },
                Instr::AddBias { a: 3, bias: 2 },
                Instr::Gelu { a: 4 },
            ],
            outputs: vec![5],
        };
        let matmul_bias = Program {
            n_inputs: 3,
            instrs: vec![
                Instr::MatmulBias { a: 0, b: 1, bias: 2 },
                Instr::Gelu { a: 3 },
            ],
            outputs: vec![4],
        };
        let bias_act = Program {
            n_inputs: 3,
            instrs: vec![
                Instr::Matmul { a: 0, b: 1 },
                Instr::BiasAct { a: 3, bias: 2, act: Act::Gelu },
            ],
            outputs: vec![4],
        };
        let want = unfused.run_reference(&inputs).unwrap();
        let tier = simd::engine_equivalence();
        for p in [&unfused, &matmul_bias, &bias_act] {
            let got = p.run(&inputs).unwrap();
            assert_eq!(got.len(), 1);
            assert_eq!(got[0].dims, want[0].dims);
            // Cross-engine: bitwise with the vector layer off, ULP-bounded
            // on the FMA paths (the two-tier contract).
            tier.check(&got[0].data, &want[0].data).expect("fused form vs oracle");
        }
        // The fused forms must agree with the *unfused optimized* form
        // bitwise regardless of tier: all three run the same kernels in
        // the same order on the same engine.
        let base = unfused.run(&inputs).unwrap();
        for p in [&matmul_bias, &bias_act] {
            let got = p.run(&inputs).unwrap();
            simd::Equivalence::Bitwise
                .check(&got[0].data, &base[0].data)
                .expect("fused forms must be bitwise-identical to unfused");
        }
    }

    #[test]
    fn outputs_survive_inplace_execution() {
        // z is both an output and the activation's input: the engine must
        // not mutate it in place.
        let p = Program {
            n_inputs: 2,
            instrs: vec![Instr::Matmul { a: 0, b: 1 }, Instr::Relu { a: 2 }],
            outputs: vec![2, 3],
        };
        let a = t(&[2, 2], &[1.0, -2.0, 3.0, -4.0]);
        let b = t(&[2, 2], &[1.0, 0.0, 0.0, 1.0]);
        let want = p.run_reference(&[a.clone(), b.clone()]).unwrap();
        let got = p.run(&[a, b]).unwrap();
        assert_eq!(got[0].data, want[0].data, "pre-activation output intact");
        assert_eq!(got[1].data, want[1].data);
        assert_eq!(got[0].data, vec![1.0, -2.0, 3.0, -4.0]);
        assert_eq!(got[1].data, vec![1.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn dead_register_read_is_a_typed_error() {
        // A forged plan that claims reg 2 dies at instruction 1 makes the
        // in-place path consume it; the later read must surface the typed
        // DeadRegister error, not an empty tensor.
        let p = Program {
            n_inputs: 1,
            instrs: vec![
                Instr::Relu { a: 0 },
                Instr::Relu { a: 1 },
                Instr::Axpy { a: 1, b: 2, c: 1.0 },
            ],
            outputs: vec![3],
        };
        let mut plan = p.plan();
        assert_eq!(plan.last_read[1], Some(2), "sane plan: reg 1 read by Axpy");
        plan.last_read[1] = Some(1); // forged: "dies" at the second Relu
        plan.retire[2].retain(|&r| r != 1);
        let x = t(&[1, 2], &[1.0, 2.0]);
        let err = p.run_with_plan(&[&x], &[], &plan).unwrap_err();
        match err.downcast_ref::<RuntimeError>() {
            Some(RuntimeError::DeadRegister { reg }) => assert_eq!(*reg, 1),
            other => panic!("expected DeadRegister, got {other:?}"),
        }
    }

    #[test]
    fn liveness_plan_marks_last_uses() {
        let p = stage_trunk1_program(); // matmul, addbias, relu
        let plan = p.plan();
        // The streamed input is last read by the matmul (instr 0).
        assert_eq!(plan.last_read[0], Some(0));
        // The matmul result (reg 3) is last read by the bias add (1).
        assert_eq!(plan.last_read[3], Some(1));
        assert!(plan.retire[1].contains(&3));
        // The program output is never retired.
        assert!(plan.is_output[5]);
        assert!(plan.retire.iter().all(|rs| !rs.contains(&5)));
    }

    /// Serializes tests that read or write the global parallel-matmul
    /// threshold (cargo runs tests on parallel threads).
    static THRESHOLD_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn matmul_worker_threshold() {
        let _g = THRESHOLD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_matmul_par_threshold(DEFAULT_PAR_MIN_FLOPS);
        // Tiny shapes stay serial (bitwise identity is vacuous there; the
        // point is to not pay fork-join cost per unit-test-sized tile).
        assert_eq!(matmul_workers(4, 4, 4), 1);
        assert_eq!(matmul_workers(64, 60, 64), 1);
        assert_eq!(matmul_workers(1, 4096, 4096), 1);
        // Big shapes may go parallel, bounded by the cap.
        let w = matmul_workers(512, 512, 512);
        assert!((1..=4).contains(&w));
    }

    #[test]
    fn matmul_threshold_both_sides_bitwise_equal() {
        // The threshold knob moves work between the serial path and the
        // scheduler's row-panel path; it must never move a single bit.
        let _g = THRESHOLD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        struct Restore;
        impl Drop for Restore {
            fn drop(&mut self) {
                set_matmul_par_threshold(DEFAULT_PAR_MIN_FLOPS);
            }
        }
        let _restore = Restore;
        let mut rng = Rng::new(23);
        let a = Tensor {
            dims: vec![96, 80],
            data: (0..96 * 80).map(|_| rng.normal()).collect(),
            prec: crate::runtime::Precision::F32,
        };
        let b = Tensor {
            dims: vec![80, 72],
            data: (0..80 * 72).map(|_| rng.normal()).collect(),
            prec: crate::runtime::Precision::F32,
        };
        let p = Program { n_inputs: 2, instrs: vec![Instr::Matmul { a: 0, b: 1 }], outputs: vec![2] };
        // Far side: threshold above the shape's FLOPs → serial.
        set_matmul_par_threshold(usize::MAX);
        let serial = p.run(&[a.clone(), b.clone()]).unwrap();
        // Near side: threshold 1 → row panels, on a pool wide enough to
        // actually split even on a single-core host.
        set_matmul_par_threshold(1);
        let pool = crate::sched::Scheduler::with_workers(4);
        let par = crate::sched::with_scheduler(&pool, || p.run(&[a, b])).unwrap();
        pool.shutdown();
        let sb: Vec<u32> = serial[0].data.iter().map(|v| v.to_bits()).collect();
        let pb: Vec<u32> = par[0].data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(sb, pb, "threshold must not change numerics");
    }

    #[test]
    fn parallel_matmul_matches_reference_bitwise() {
        // Above the FLOP threshold the row-panel path engages (when the
        // host has >1 core); either way the result must match the oracle
        // under the live equivalence tier (bitwise with the vector layer
        // off, ULP-bounded on the FMA paths). Entries are scaled to
        // ~[-0.2, 0.2] so the k=128 contraction's worst-case FMA drift
        // (≤ k/2 · ulp(max |a·b|)) provably stays inside the tier's
        // absolute floor even where outputs cancel toward zero — a
        // relative ULP bound alone is meaningless on a cancelled sum.
        let mut rng = Rng::new(17);
        let a = Tensor {
            dims: vec![160, 128],
            data: (0..160 * 128).map(|_| rng.normal() * 0.03125).collect(),
            prec: crate::runtime::Precision::F32,
        };
        let b = Tensor {
            dims: vec![128, 96],
            data: (0..128 * 96).map(|_| rng.normal() * 0.03125).collect(),
            prec: crate::runtime::Precision::F32,
        };
        let p = Program { n_inputs: 2, instrs: vec![Instr::Matmul { a: 0, b: 1 }], outputs: vec![2] };
        let want = p.run_reference(&[a.clone(), b.clone()]).unwrap();
        let got = p.run(&[a, b]).unwrap();
        simd::engine_equivalence().check(&got[0].data, &want[0].data).expect("vs oracle");
    }

    #[test]
    fn forward_program_outputs_unit_range() {
        let prog = forward_program();
        let mut rng = Rng::new(11);
        let dims: Vec<Vec<usize>> = vec![
            vec![16, 6],
            vec![6, 8],
            vec![8],
            vec![8, 8],
            vec![8],
            vec![8, 8],
            vec![8],
            vec![8, 3],
            vec![3],
        ];
        let inputs: Vec<Tensor> = dims
            .iter()
            .enumerate()
            .map(|(i, d)| {
                if i == 0 {
                    let numel: usize = d.iter().product();
                    Tensor {
                        dims: d.clone(),
                        data: (0..numel).map(|_| rng.normal()).collect(),
                        prec: crate::runtime::Precision::F32,
                    }
                } else {
                    rng.he_tensor(d)
                }
            })
            .collect();
        let out = prog.run(&inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims, vec![16, 3]);
        assert!(out[0].data.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // Deterministic.
        assert_eq!(prog.run(&inputs).unwrap()[0].data, out[0].data);
        // And matches the scalar reference oracle under the live tier.
        simd::engine_equivalence()
            .check(&out[0].data, &prog.run_reference(&inputs).unwrap()[0].data)
            .expect("vs oracle");
    }

    #[test]
    fn stage_composition_equals_forward() {
        // trunk0 -> trunk1 -> head must reproduce nerf_forward exactly:
        // the coordinator's pipeline is a factorization of the monolith.
        let mut rng = Rng::new(23);
        let x = Tensor {
            dims: vec![8, 6],
            data: (0..48).map(|_| rng.normal()).collect(),
            prec: crate::runtime::Precision::F32,
        };
        let params: Vec<Tensor> = [
            vec![6usize, 8],
            vec![8],
            vec![8, 8],
            vec![8],
            vec![8, 8],
            vec![8],
            vec![8, 3],
            vec![3],
        ]
        .iter()
        .map(|d| rng.he_tensor(d))
        .collect();

        let mut fwd_in = vec![x.clone()];
        fwd_in.extend(params.iter().cloned());
        let y_fwd = forward_program().run(&fwd_in).unwrap().remove(0);

        let t0 = stage_trunk0_program()
            .run(&[
                x,
                params[0].clone(),
                params[1].clone(),
                params[2].clone(),
                params[3].clone(),
            ])
            .unwrap()
            .remove(0);
        let t1 = stage_trunk1_program()
            .run(&[t0, params[4].clone(), params[5].clone()])
            .unwrap()
            .remove(0);
        let y_staged = stage_head_program()
            .run(&[t1, params[6].clone(), params[7].clone()])
            .unwrap()
            .remove(0);
        assert_eq!(y_fwd.dims, y_staged.dims);
        assert_eq!(y_fwd.data, y_staged.data, "stages must compose bit-identically");
    }

    #[test]
    fn train_step_gradients_match_finite_differences() {
        let prog = train_step_program(LR);
        let mut rng = Rng::new(31);
        let (batch, din, hidden, dout) = (8usize, 3usize, 4usize, 2usize);
        let x = Tensor {
            dims: vec![batch, din],
            data: (0..batch * din).map(|_| rng.normal()).collect(),
            prec: crate::runtime::Precision::F32,
        };
        let t_out = Tensor {
            dims: vec![batch, dout],
            data: (0..batch * dout).map(|_| rng.uniform()).collect(),
            prec: crate::runtime::Precision::F32,
        };
        let param_dims: Vec<Vec<usize>> = vec![
            vec![din, hidden],
            vec![hidden],
            vec![hidden, hidden],
            vec![hidden],
            vec![hidden, hidden],
            vec![hidden],
            vec![hidden, dout],
            vec![dout],
        ];
        // Non-zero biases so their gradients are exercised off the origin.
        let params: Vec<Tensor> = param_dims
            .iter()
            .map(|d| {
                let mut p = rng.he_tensor(d);
                if d.len() == 1 {
                    p.data.iter_mut().for_each(|v| *v = 0.1 * rng.normal());
                }
                p
            })
            .collect();

        let loss_at = |params: &[Tensor]| -> f64 {
            let mut args = vec![x.clone(), t_out.clone()];
            args.extend(params.iter().cloned());
            prog.run(&args).unwrap()[0].scalar_value() as f64
        };
        let run = {
            let mut args = vec![x.clone(), t_out.clone()];
            args.extend(params.iter().cloned());
            prog.run(&args).unwrap()
        };
        assert_eq!(run.len(), 9);

        // Analytic gradient recovered from the SGD update: g = (p - p')/LR.
        let eps = 1e-3f64;
        for (pi, pdims) in param_dims.iter().enumerate() {
            let numel: usize = pdims.iter().product();
            for &k in &[0usize, numel / 2, numel - 1] {
                let analytic =
                    ((params[pi].data[k] - run[1 + pi].data[k]) / LR) as f64;
                let mut plus = params.clone();
                plus[pi].data[k] += eps as f32;
                let mut minus = params.clone();
                minus[pi].data[k] -= eps as f32;
                let fd = (loss_at(&plus) - loss_at(&minus)) / (2.0 * eps);
                assert!(
                    (fd - analytic).abs() < 1e-3 + 0.08 * analytic.abs(),
                    "param {pi}[{k}]: finite-diff {fd} vs analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn train_step_descends_on_fixed_batch() {
        let prog = train_step_program(LR);
        let mut rng = Rng::new(99);
        let (batch, din, hidden, dout) = (32usize, 6usize, 16usize, 3usize);
        let x = Tensor {
            dims: vec![batch, din],
            data: (0..batch * din).map(|_| rng.normal()).collect(),
            prec: crate::runtime::Precision::F32,
        };
        let t_out = Tensor {
            dims: vec![batch, dout],
            data: (0..batch * dout).map(|_| rng.uniform()).collect(),
            prec: crate::runtime::Precision::F32,
        };
        let mut params: Vec<Tensor> = [
            vec![din, hidden],
            vec![hidden],
            vec![hidden, hidden],
            vec![hidden],
            vec![hidden, hidden],
            vec![hidden],
            vec![hidden, dout],
            vec![dout],
        ]
        .iter()
        .map(|d| rng.he_tensor(d))
        .collect();
        let mut losses = Vec::new();
        for _ in 0..150 {
            let mut args = vec![x.clone(), t_out.clone()];
            args.extend(params.iter().cloned());
            let mut out = prog.run(&args).unwrap();
            losses.push(out.remove(0).scalar_value());
            params = out;
        }
        assert!(losses.iter().all(|l| l.is_finite()));
        // Full-batch SGD with a small step descends monotonically here.
        for w in losses.windows(2) {
            assert!(w[1] <= w[0] + 1e-7, "loss rose: {} -> {}", w[0], w[1]);
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.95),
            "no meaningful descent: {} -> {}",
            losses[0],
            losses.last().unwrap()
        );
    }

    #[test]
    fn extended_activations_match_reference_math() {
        let mk = |instr: fn(Reg) -> Instr| Program {
            n_inputs: 1,
            instrs: vec![instr(0)],
            outputs: vec![1],
        };
        let x = t(&[1, 4], &[-2.0, -0.5, 0.5, 2.0]);
        let gelu = mk(|a| Instr::Gelu { a }).run(&[x.clone()]).unwrap();
        // tanh-GELU reference values.
        for (got, want) in gelu[0].data.iter().zip([-0.0454f32, -0.1543, 0.3457, 1.9546]) {
            assert!((got - want).abs() < 1e-3, "gelu {got} vs {want}");
        }
        let tanh = mk(|a| Instr::Tanh { a }).run(&[x.clone()]).unwrap();
        assert!((tanh[0].data[3] - 2.0f32.tanh()).abs() < 1e-6);
        let silu = mk(|a| Instr::Silu { a }).run(&[x.clone()]).unwrap();
        let sig = |v: f32| 1.0 / (1.0 + (-v).exp());
        assert!((silu[0].data[0] - (-2.0 * sig(-2.0))).abs() < 1e-6);
        let exp = mk(|a| Instr::Exp { a }).run(&[x]).unwrap();
        assert!((exp[0].data[2] - 0.5f32.exp()).abs() < 1e-6);
    }

    #[test]
    fn bound_execution_matches_plain_run() {
        // stage_trunk1 with weights bound at construction must agree with
        // the same program run with weights passed per call.
        let prog = stage_trunk1_program();
        let mut rng = Rng::new(77);
        let x = Tensor {
            dims: vec![4, 8],
            data: (0..32).map(|_| rng.normal()).collect(),
            prec: crate::runtime::Precision::F32,
        };
        let w = rng.he_tensor(&[8, 8]);
        let b = rng.he_tensor(&[8]);
        let plain = prog.run(&[x.clone(), w.clone(), b.clone()]).unwrap();
        let bound = prog.run_bound(&[x.clone()], &[w.clone(), b.clone()]).unwrap();
        assert_eq!(plain[0].data, bound[0].data);
        let exe = bound_executable("t1", prog, vec![w.clone(), b.clone()]);
        let via_exe = exe.run_f32(&[x.clone()]).unwrap();
        assert_eq!(plain[0].data, via_exe[0].data);
        // The borrowed-input (zero-copy) entry point agrees too.
        let via_ref = exe.run_f32_ref(&[&x]).unwrap();
        assert_eq!(plain[0].data, via_ref[0].data);
        // Wrong arity still rejected.
        assert!(exe.run_f32(&[]).is_err());
    }

    #[test]
    fn training_instrs_match_reference_bitwise() {
        // Every new training/optimizer instruction: optimized engine ==
        // scalar reference oracle under the live equivalence tier — bit
        // for bit with the vector layer off; Axpy/Blend pick up single
        // FMA roundings on the AVX paths (the kernel_equivalence
        // contract extended to the train ISA).
        let mut rng = Rng::new(1213);
        let a = Tensor {
            dims: vec![5, 4],
            data: (0..20).map(|_| rng.normal()).collect(),
            prec: crate::runtime::Precision::F32,
        };
        let b = Tensor {
            dims: vec![5, 4],
            data: (0..20).map(|_| rng.normal()).collect(),
            prec: crate::runtime::Precision::F32,
        };
        let c = Tensor {
            dims: vec![5, 4],
            data: (0..20).map(|_| rng.normal().abs() + 0.1).collect(),
            prec: crate::runtime::Precision::F32,
        };
        let binaries = [
            Instr::Mul { a: 0, b: 1 },
            Instr::Blend { a: 0, b: 1, beta: 0.9 },
            Instr::Scale { a: 0, c: -0.125 },
            Instr::Axpy { a: 0, b: 1, c: 1.0 },
            Instr::Concat2 { a: 0, b: 1 },
            Instr::SliceCols { a: 0, start: 1, len: 2 },
            Instr::AdamStep { p: 0, m: 1, v: 2, lr: 1e-3, bc1: 0.1, bc2: 0.01, eps: 1e-8 },
        ];
        for instr in binaries {
            let p = Program { n_inputs: 3, instrs: vec![instr], outputs: vec![3] };
            let inputs = [a.clone(), b.clone(), c.clone()];
            let want = p.run_reference(&inputs).unwrap();
            let got = p.run(&inputs).unwrap();
            assert_eq!(got[0].dims, want[0].dims, "{instr:?}");
            simd::engine_equivalence()
                .check(&got[0].data, &want[0].data)
                .unwrap_or_else(|e| panic!("{instr:?} vs oracle: {e}"));
        }
        for act in [Act::Relu, Act::Sigmoid, Act::Gelu, Act::Tanh, Act::Silu, Act::Exp] {
            let p = Program {
                n_inputs: 2,
                instrs: vec![Instr::ActGradI { g: 0, x: 1, act }],
                outputs: vec![2],
            };
            let inputs = [a.clone(), b.clone()];
            let want = p.run_reference(&inputs).unwrap();
            let got = p.run(&inputs).unwrap();
            simd::engine_equivalence()
                .check(&got[0].data, &want[0].data)
                .unwrap_or_else(|e| panic!("{act:?} input-grad vs oracle: {e}"));
        }
    }

    #[test]
    fn act_grad_at_matches_finite_differences() {
        // f'(x) from Act::grad_at vs central differences of Act::apply.
        let xs = [-1.7f32, -0.4, 0.3, 1.9];
        let eps = 1e-3f64;
        for act in [Act::Sigmoid, Act::Gelu, Act::Tanh, Act::Silu, Act::Exp] {
            for &x in &xs {
                let fd = (act.apply(x + eps as f32) as f64 - act.apply(x - eps as f32) as f64)
                    / (2.0 * eps);
                let an = act.grad_at(x) as f64;
                assert!(
                    (fd - an).abs() < 1e-3 + 0.02 * an.abs(),
                    "{act:?}'({x}): fd {fd} vs analytic {an}"
                );
            }
        }
        // ReLU subgradient convention: 0 at the kink, 1 above, 0 below.
        assert_eq!(Act::Relu.grad_at(2.0), 1.0);
        assert_eq!(Act::Relu.grad_at(-2.0), 0.0);
        assert_eq!(Act::Relu.grad_at(0.0), 0.0);
    }

    #[test]
    fn concat_slice_roundtrip() {
        let a = t(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = t(&[2, 2], &[7.0, 8.0, 9.0, 10.0]);
        let p = Program {
            n_inputs: 2,
            instrs: vec![
                Instr::Concat2 { a: 0, b: 1 },
                Instr::SliceCols { a: 2, start: 0, len: 3 },
                Instr::SliceCols { a: 2, start: 3, len: 2 },
            ],
            outputs: vec![2, 3, 4],
        };
        let out = p.run(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(out[0].dims, vec![2, 5]);
        assert_eq!(out[0].data, vec![1.0, 2.0, 3.0, 7.0, 8.0, 4.0, 5.0, 6.0, 9.0, 10.0]);
        assert_eq!(out[1].data, a.data, "left slice recovers the left operand");
        assert_eq!(out[2].data, b.data, "right slice recovers the right operand");
        // Out-of-range slices are rejected.
        let bad = Program {
            n_inputs: 1,
            instrs: vec![Instr::SliceCols { a: 0, start: 2, len: 2 }],
            outputs: vec![1],
        };
        assert!(bad.run(&[a]).is_err());
    }

    #[test]
    fn adam_step_values() {
        // Hand-checked single element: p=1, m=0.1, v=0.04, lr=0.1,
        // bc1=0.5, bc2=0.2, eps=0 -> p - 0.1 * (0.2 / sqrt(0.2)).
        let p = Program {
            n_inputs: 3,
            instrs: vec![Instr::AdamStep {
                p: 0,
                m: 1,
                v: 2,
                lr: 0.1,
                bc1: 0.5,
                bc2: 0.2,
                eps: 0.0,
            }],
            outputs: vec![3],
        };
        let out = p
            .run(&[
                t(&[1], &[1.0]),
                t(&[1], &[0.1]),
                t(&[1], &[0.04]),
            ])
            .unwrap();
        let want = 1.0 - 0.1 * (0.1 / 0.5) / (0.04f32 / 0.2).sqrt();
        assert!((out[0].data[0] - want).abs() < 1e-6, "{} vs {want}", out[0].data[0]);
    }

    #[test]
    fn entry_program_validates_manifest_abi() {
        let nine: Vec<Vec<usize>> = vec![
            vec![4, 6],
            vec![6, 8],
            vec![8],
            vec![8, 8],
            vec![8],
            vec![8, 8],
            vec![8],
            vec![8, 3],
            vec![3],
        ];
        assert!(entry_program(&spec("nerf_forward", &nine, 1)).is_ok());
        // Wrong arity rejected.
        assert!(entry_program(&spec("nerf_forward", &nine[..5].to_vec(), 1)).is_err());
        // Wrong output count rejected.
        assert!(entry_program(&spec("nerf_forward", &nine, 2)).is_err());
        // Unknown entries produce the typed unsupported error.
        let err = entry_program(&spec("weird_entry", &nine, 1)).unwrap_err();
        match err.downcast_ref::<RuntimeError>() {
            Some(RuntimeError::UnsupportedEntry { name, backend }) => {
                assert_eq!(name, "weird_entry");
                assert_eq!(*backend, "interp");
            }
            other => panic!("expected UnsupportedEntry, got {other:?}"),
        }
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
