//! Operator definitions for the DL graph IR.
//!
//! Operators are modeled at the granularity the paper's compiler works at
//! (PyTorch/Dynamo aten-level): GEMM-family ops that can use TensorCores,
//! and SIMT-family ops (elementwise, reductions, normalization, gathers).
//! Each op knows its FLOP count and byte traffic, which feed the
//! [`crate::perfmodel`] roofline and the simulator.

use super::tensor::TensorDesc;
use std::fmt;

/// The dynamic resource an op's kernel primarily occupies — the paper's
/// §4.2 kernel-header tag consumed by the dual-arbiter grid scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ResourceClass {
    /// GEMM-family: issues to TensorCores (MXU on TPU).
    Tensor,
    /// Everything else: SIMT/vector pipelines.
    Simt,
}

impl fmt::Display for ResourceClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ResourceClass::Tensor => write!(f, "TENSOR"),
            ResourceClass::Simt => write!(f, "SIMT"),
        }
    }
}

/// Elementwise operator kinds (unary and binary).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EwKind {
    Relu,
    Gelu,
    Silu,
    Sigmoid,
    Tanh,
    Add,
    Sub,
    Mul,
    /// Backward of an activation: grad * f'(saved input).
    ActGrad,
    /// Dropout / masking style op.
    Mask,
    /// Type cast (bf16 <-> f32).
    Cast,
    /// Positional / rotary embedding application.
    Rope,
    Exp,
    Scale,
    /// Column slice `[.., start..start+len]` — the concat VJP (each
    /// concat input's gradient is a contiguous column window of the
    /// output gradient). Carries its offsets so the training lowering
    /// can stream it without re-deriving concat layouts.
    Slice { start: usize, len: usize },
}

impl EwKind {
    /// Number of data inputs consumed.
    pub fn arity(self) -> usize {
        match self {
            EwKind::Add | EwKind::Sub | EwKind::Mul | EwKind::ActGrad | EwKind::Mask => 2,
            _ => 1,
        }
    }

    /// Rough FLOPs per output element (transcendentals cost more SIMT work).
    pub fn flops_per_elem(self) -> f64 {
        match self {
            EwKind::Relu | EwKind::Mask | EwKind::Cast | EwKind::Slice { .. } => 1.0,
            EwKind::Add | EwKind::Sub | EwKind::Mul | EwKind::Scale => 1.0,
            EwKind::ActGrad => 2.0,
            EwKind::Sigmoid | EwKind::Tanh | EwKind::Exp => 4.0,
            EwKind::Gelu | EwKind::Silu => 8.0,
            EwKind::Rope => 6.0,
        }
    }
}

/// What a [`OpKind::Reduce`] reduces over — the paper distinguishes batch
/// reductions (gradient accumulation, Fig 2(b)) from feature reductions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReduceAxis {
    /// Reduce over the batch/leading dimension (weight-gradient style).
    Batch,
    /// Reduce over the trailing/feature dimension (softmax-denominator style).
    Feature,
    /// Reduce over split-K partial sums produced by a partitioned GEMM.
    SplitK,
}

/// Operator kinds at DL-framework granularity.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// Graph input (activation from a preceding subgraph / host).
    Input,
    /// Learned parameter resident in DRAM.
    Param,
    /// GEMM: `[b, m, k] x [k, n] -> [b, m, n]` (b=1 for plain 2-D).
    /// `Linear`, attention score/value matmuls, and convolution (im2col)
    /// all lower to this — as the paper notes, "GEMMs are colloquially used
    /// to express the entirety of work done by these operators".
    Matmul { b: usize, m: usize, n: usize, k: usize },
    /// Elementwise map over the output shape.
    Elementwise(EwKind),
    /// Reduction (sum unless noted) over `axis`, `factor`-way.
    Reduce { axis: ReduceAxis, factor: usize },
    /// Row softmax over the trailing dimension.
    Softmax,
    /// LayerNorm / RMSNorm over the trailing dimension.
    LayerNorm,
    /// Embedding-table gather (DLRM sparse features, GNN node gathers).
    /// Excluded from sf-nodes by the paper's §5.1 rules.
    Gather { table_rows: usize },
    /// Scatter-add (embedding backward, GNN message aggregation).
    Scatter,
    /// Concatenation of inputs along the trailing dim (NeRF skip links,
    /// DLRM feature interaction input, MGN edge features).
    Concat { n_inputs: usize },
    /// Batched pairwise dot-product feature interaction (DLRM).
    Interaction { features: usize, dim: usize },
    /// Loss head (cross-entropy / MSE): produces scalar + grad seed.
    Loss,
    /// Optimizer update (SGD/Adam step) applied to a parameter.
    OptimizerUpdate,
    /// Inter-stage ring queue inserted by pipeline design (§5.2).
    /// Not a compute op: payload tiles flow producer→consumer through L2.
    Queue { payload_bytes: usize, entries: usize },
}

impl OpKind {
    /// Short mnemonic used by pattern matching (§5.1) and reports.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            OpKind::Input => "in",
            OpKind::Param => "param",
            OpKind::Matmul { .. } => "matmul",
            OpKind::Elementwise(_) => "ew",
            OpKind::Reduce { .. } => "reduce",
            OpKind::Softmax => "softmax",
            OpKind::LayerNorm => "layernorm",
            OpKind::Gather { .. } => "gather",
            OpKind::Scatter => "scatter",
            OpKind::Concat { .. } => "concat",
            OpKind::Interaction { .. } => "interaction",
            OpKind::Loss => "loss",
            OpKind::OptimizerUpdate => "optstep",
            OpKind::Queue { .. } => "queue",
        }
    }

    /// Is this a compute operator (occupies SMs), as opposed to a graph
    /// placeholder (Input/Param) or a queue node?
    pub fn is_compute(&self) -> bool {
        !matches!(self, OpKind::Input | OpKind::Param | OpKind::Queue { .. })
    }

    /// Resource class for the §4.2 scheduler tag.
    pub fn resource_class(&self) -> ResourceClass {
        match self {
            OpKind::Matmul { .. } | OpKind::Interaction { .. } => ResourceClass::Tensor,
            _ => ResourceClass::Simt,
        }
    }

    /// FLOPs performed by the op, given its output descriptor.
    pub fn flops(&self, out: &TensorDesc) -> f64 {
        match self {
            OpKind::Matmul { b, m, n, k } => 2.0 * (*b as f64) * (*m as f64) * (*n as f64) * (*k as f64),
            OpKind::Elementwise(ew) => ew.flops_per_elem() * out.numel() as f64,
            OpKind::Reduce { factor, .. } => (*factor as f64) * out.numel() as f64,
            OpKind::Softmax => 8.0 * out.numel() as f64,
            OpKind::LayerNorm => 8.0 * out.numel() as f64,
            OpKind::Gather { .. } => out.numel() as f64,
            OpKind::Scatter => 2.0 * out.numel() as f64,
            OpKind::Concat { .. } => out.numel() as f64,
            OpKind::Interaction { features, dim } => {
                // pairwise dots: batch * F*F * dim MACs, batch = leading
                2.0 * out.shape.leading() as f64 * (*features as f64) * (*features as f64) * (*dim as f64)
            }
            OpKind::Loss => 10.0 * out.numel() as f64,
            OpKind::OptimizerUpdate => 4.0 * out.numel() as f64,
            OpKind::Input | OpKind::Param | OpKind::Queue { .. } => 0.0,
        }
    }

    /// True for ops the paper's §5.1 rules exclude from sf-nodes:
    /// "nodes that are bulk-sync friendly and nodes that index / gather
    /// across all data".
    pub fn excluded_from_subgraphs(&self) -> bool {
        matches!(
            self,
            OpKind::Gather { .. } | OpKind::Scatter | OpKind::Input | OpKind::Param
        )
    }
}

impl fmt::Display for OpKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OpKind::Matmul { b, m, n, k } => write!(f, "matmul[b{b} {m}x{k}x{n}]"),
            OpKind::Elementwise(ew) => write!(f, "ew:{ew:?}"),
            OpKind::Reduce { axis, factor } => write!(f, "reduce:{axis:?}x{factor}"),
            OpKind::Queue { payload_bytes, entries } => {
                write!(f, "queue[{}KBx{}]", payload_bytes / 1024, entries)
            }
            other => write!(f, "{}", other.mnemonic()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tensor::TensorDesc;

    #[test]
    fn matmul_flops() {
        let op = OpKind::Matmul { b: 1, m: 128, n: 256, k: 64 };
        let out = TensorDesc::bf16(&[128, 256]);
        assert_eq!(op.flops(&out), 2.0 * 128.0 * 256.0 * 64.0);
    }

    #[test]
    fn resource_classes() {
        assert_eq!(OpKind::Matmul { b: 1, m: 1, n: 1, k: 1 }.resource_class(), ResourceClass::Tensor);
        assert_eq!(OpKind::Elementwise(EwKind::Relu).resource_class(), ResourceClass::Simt);
        assert_eq!(OpKind::Softmax.resource_class(), ResourceClass::Simt);
        assert_eq!(
            OpKind::Interaction { features: 26, dim: 128 }.resource_class(),
            ResourceClass::Tensor
        );
    }

    #[test]
    fn exclusion_rules() {
        assert!(OpKind::Gather { table_rows: 10 }.excluded_from_subgraphs());
        assert!(OpKind::Scatter.excluded_from_subgraphs());
        assert!(!OpKind::Matmul { b: 1, m: 1, n: 1, k: 1 }.excluded_from_subgraphs());
        assert!(!OpKind::Softmax.excluded_from_subgraphs());
    }

    #[test]
    fn queue_is_not_compute() {
        assert!(!OpKind::Queue { payload_bytes: 65536, entries: 2 }.is_compute());
        assert!(!OpKind::Input.is_compute());
        assert!(OpKind::Loss.is_compute());
    }

    #[test]
    fn ew_arity() {
        assert_eq!(EwKind::Add.arity(), 2);
        assert_eq!(EwKind::Relu.arity(), 1);
        assert_eq!(EwKind::ActGrad.arity(), 2);
    }
}
