//! Operator-graph IR — the role PyTorch Dynamo's captured FX graph plays in
//! the paper's compiler stack (§5), plus reverse-mode autodiff so training
//! graphs exist without PyTorch.

pub mod tensor;
pub mod op;
#[allow(clippy::module_inception)]
pub mod graph;
pub mod builder;
pub mod autodiff;

pub use autodiff::{training_graph, AutodiffOptions};
pub use builder::GraphBuilder;
pub use graph::{Graph, GraphKind, Node, NodeId};
pub use op::{EwKind, OpKind, ReduceAxis, ResourceClass};
pub use tensor::{DType, Shape, TensorDesc};
