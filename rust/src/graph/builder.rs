//! Ergonomic graph construction — the role PyTorch model code plays in the
//! paper (the compiler sees only the captured graph, never this builder).

use super::graph::{Graph, GraphKind, NodeId};
use super::op::{EwKind, OpKind, ReduceAxis};
use super::tensor::{DType, TensorDesc};

/// Builder over a [`Graph`] with convenience composites (linear layers,
/// MLPs, attention blocks) that lower to the aten-level ops the paper's
/// compiler consumes.
pub struct GraphBuilder {
    pub g: Graph,
    pub dtype: DType,
}

impl GraphBuilder {
    pub fn new(name: impl Into<String>, kind: GraphKind) -> Self {
        GraphBuilder { g: Graph::new(name, kind), dtype: DType::BF16 }
    }

    pub fn finish(self) -> Graph {
        debug_assert!(self.g.validate().is_empty(), "{:?}", self.g.validate());
        self.g
    }

    fn desc(&self, dims: &[usize]) -> TensorDesc {
        TensorDesc::new(dims, self.dtype)
    }

    pub fn out_dims(&self, id: NodeId) -> Vec<usize> {
        self.g.node(id).out.shape.dims().to_vec()
    }

    /// Graph input (activation arriving from DRAM / preceding subgraph).
    pub fn input(&mut self, dims: &[usize], name: &str) -> NodeId {
        let d = self.desc(dims);
        self.g.add(OpKind::Input, &[], d, name)
    }

    /// Learned parameter.
    pub fn param(&mut self, dims: &[usize], name: &str) -> NodeId {
        let d = self.desc(dims);
        self.g.add(OpKind::Param, &[], d, name)
    }

    /// `y = x @ W (+ b)` — x: `[..., k]`, W: `[k, n]`. Lowers to a single
    /// `addmm`-style node (bias folded as a third input), matching how
    /// PyTorch/Dynamo captures `nn.Linear` as one aten op.
    /// Convention: `inputs[0]` is the activation, `inputs[1]` the weight,
    /// optional `inputs[2]` the bias (autodiff relies on this ordering).
    pub fn linear(&mut self, x: NodeId, n: usize, bias: bool, name: &str) -> NodeId {
        let xd = self.out_dims(x);
        let k = *xd.last().expect("linear input needs rank >= 1");
        let m: usize = xd[..xd.len() - 1].iter().product::<usize>().max(1);
        let w = self.param(&[k, n], &format!("{name}.w"));
        let mut od = xd.clone();
        *od.last_mut().unwrap() = n;
        let out = self.desc(&od);
        let mut inputs = vec![x, w];
        if bias {
            inputs.push(self.param(&[n], &format!("{name}.b")));
        }
        self.g.add(OpKind::Matmul { b: 1, m, n, k }, &inputs, out, name)
    }

    /// Explicit batched matmul `a[b,m,k] @ c[k,n]` for attention scores etc.
    pub fn matmul(&mut self, a: NodeId, c: NodeId, b: usize, m: usize, n: usize, k: usize, name: &str) -> NodeId {
        let out = if b == 1 { self.desc(&[m, n]) } else { self.desc(&[b, m, n]) };
        self.g.add(OpKind::Matmul { b, m, n, k }, &[a, c], out, name)
    }

    pub fn ew1(&mut self, kind: EwKind, x: NodeId, name: &str) -> NodeId {
        let out = self.g.node(x).out.clone();
        self.g.add(OpKind::Elementwise(kind), &[x], out, name)
    }

    pub fn ew2(&mut self, kind: EwKind, a: NodeId, b: NodeId, name: &str) -> NodeId {
        let out = self.g.node(a).out.clone();
        self.g.add(OpKind::Elementwise(kind), &[a, b], out, name)
    }

    pub fn relu(&mut self, x: NodeId, name: &str) -> NodeId {
        self.ew1(EwKind::Relu, x, name)
    }

    pub fn layernorm(&mut self, x: NodeId, name: &str) -> NodeId {
        let out = self.g.node(x).out.clone();
        self.g.add(OpKind::LayerNorm, &[x], out, name)
    }

    pub fn softmax(&mut self, x: NodeId, name: &str) -> NodeId {
        let out = self.g.node(x).out.clone();
        self.g.add(OpKind::Softmax, &[x], out, name)
    }

    /// Reduce over `axis` by `factor`, producing `out_dims`.
    pub fn reduce(&mut self, x: NodeId, axis: ReduceAxis, factor: usize, out_dims: &[usize], name: &str) -> NodeId {
        let out = self.desc(out_dims);
        self.g.add(OpKind::Reduce { axis, factor }, &[x], out, name)
    }

    /// Concat along the trailing dimension.
    pub fn concat(&mut self, xs: &[NodeId], name: &str) -> NodeId {
        assert!(!xs.is_empty());
        let mut dims = self.out_dims(xs[0]);
        let total: usize = xs.iter().map(|&x| *self.out_dims(x).last().unwrap()).sum();
        *dims.last_mut().unwrap() = total;
        let out = self.desc(&dims);
        self.g.add(OpKind::Concat { n_inputs: xs.len() }, xs, out, name)
    }

    /// Embedding gather: `[batch] -> [batch, dim]` per table.
    pub fn gather(&mut self, idx: NodeId, table_rows: usize, dim: usize, name: &str) -> NodeId {
        let batch = self.out_dims(idx)[0];
        let table = self.param(&[table_rows, dim], &format!("{name}.table"));
        let out = self.desc(&[batch, dim]);
        self.g.add(OpKind::Gather { table_rows }, &[idx, table], out, name)
    }

    /// DLRM pairwise feature interaction over `features` vectors of `dim`.
    pub fn interaction(&mut self, x: NodeId, features: usize, dim: usize, name: &str) -> NodeId {
        let batch = self.out_dims(x)[0];
        let out = self.desc(&[batch, features * (features + 1) / 2]);
        self.g.add(OpKind::Interaction { features, dim }, &[x], out, name)
    }

    /// Scalar loss head.
    pub fn loss(&mut self, x: NodeId, name: &str) -> NodeId {
        let out = TensorDesc::f32(&[1]);
        self.g.add(OpKind::Loss, &[x], out, name)
    }

    /// `layers`-deep MLP with uniform hidden width and an activation
    /// between layers — the paper's Fig 2(a) pattern generator.
    pub fn mlp(
        &mut self,
        mut x: NodeId,
        widths: &[usize],
        act: EwKind,
        bias: bool,
        name: &str,
    ) -> NodeId {
        for (i, &w) in widths.iter().enumerate() {
            x = self.linear(x, w, bias, &format!("{name}.{i}.linear"));
            if i + 1 < widths.len() {
                x = self.ew1(act, x, &format!("{name}.{i}.act"));
            }
        }
        x
    }

    /// Multi-head self-attention at aten granularity: QKV projection,
    /// score matmul, softmax, value matmul, output projection.
    pub fn attention(&mut self, x: NodeId, seq: usize, d_model: usize, heads: usize, name: &str) -> NodeId {
        let dh = d_model / heads;
        let q = self.linear(x, d_model, false, &format!("{name}.q"));
        let k = self.linear(x, d_model, false, &format!("{name}.k"));
        let v = self.linear(x, d_model, false, &format!("{name}.v"));
        let rq = self.ew1(EwKind::Rope, q, &format!("{name}.rope_q"));
        let rk = self.ew1(EwKind::Rope, k, &format!("{name}.rope_k"));
        // scores: [heads, seq, seq]
        let scores = self.g.add(
            OpKind::Matmul { b: heads, m: seq, n: seq, k: dh },
            &[rq, rk],
            self.desc(&[heads, seq, seq]),
            format!("{name}.scores"),
        );
        let probs = self.softmax(scores, &format!("{name}.softmax"));
        let ctx = self.g.add(
            OpKind::Matmul { b: heads, m: seq, n: dh, k: seq },
            &[probs, v],
            self.desc(&[seq, d_model]),
            format!("{name}.ctx"),
        );
        self.linear(ctx, d_model, false, &format!("{name}.out"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_shapes() {
        let mut b = GraphBuilder::new("t", GraphKind::Inference);
        let x = b.input(&[32, 64], "x");
        let y = b.linear(x, 128, true, "fc");
        assert_eq!(b.out_dims(y), vec![32, 128]);
        let g = b.finish();
        assert!(g.validate().is_empty());
    }

    #[test]
    fn mlp_composition() {
        let mut b = GraphBuilder::new("t", GraphKind::Inference);
        let x = b.input(&[16, 256], "x");
        let y = b.mlp(x, &[1024, 256], EwKind::Relu, false, "ffn");
        assert_eq!(b.out_dims(y), vec![16, 256]);
        let g = b.finish();
        // 2 matmuls + 1 act
        assert_eq!(g.n_compute_ops(), 3);
    }

    #[test]
    fn concat_trailing() {
        let mut b = GraphBuilder::new("t", GraphKind::Inference);
        let x = b.input(&[8, 60], "x");
        let y = b.input(&[8, 4], "y");
        let c = b.concat(&[x, y], "cat");
        assert_eq!(b.out_dims(c), vec![8, 64]);
    }

    #[test]
    fn attention_op_count() {
        let mut b = GraphBuilder::new("t", GraphKind::Inference);
        let x = b.input(&[128, 512], "x");
        let _ = b.attention(x, 128, 512, 8, "attn");
        let g = b.finish();
        // 4 linears + 2 rope + 2 bmm + softmax = 9 compute ops
        assert_eq!(g.n_compute_ops(), 9);
        assert!(g.validate().is_empty());
    }

    #[test]
    fn interaction_output_shape() {
        let mut b = GraphBuilder::new("t", GraphKind::Inference);
        let x = b.input(&[2048, 27 * 128], "feat");
        let y = b.interaction(x, 27, 128, "int");
        assert_eq!(b.out_dims(y), vec![2048, 27 * 28 / 2]);
    }
}
