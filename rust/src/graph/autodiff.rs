//! Reverse-mode autodiff over the operator graph.
//!
//! Stands in for PyTorch autograd + Dynamo's backward-graph capture: given a
//! forward graph, emit `forward ++ backward (++ optimizer)` as one training
//! graph. The backward region reproduces the structures the paper calls out:
//!
//! * weight-gradient GEMMs whose contraction runs over the batch dimension
//!   (split-K / Fig 2(b) parallel-reduction opportunity),
//! * bias gradients as explicit batch [`OpKind::Reduce`] nodes,
//! * activation-gradient nodes that *multicast* to two gradient GEMMs
//!   (Fig 2(c)) — this falls out of the VJP rules, it is not special-cased.

use super::graph::{Graph, GraphKind, NodeId};
use super::op::{EwKind, OpKind, ReduceAxis};
use super::tensor::TensorDesc;
use std::collections::HashMap;

/// Options for training-graph generation.
#[derive(Debug, Clone, Copy)]
pub struct AutodiffOptions {
    /// Append one [`OpKind::OptimizerUpdate`] per parameter (SGD/Adam step).
    pub optimizer_updates: bool,
}

impl Default for AutodiffOptions {
    fn default() -> Self {
        AutodiffOptions { optimizer_updates: true }
    }
}

/// Build the training graph for `fwd`.
///
/// The forward nodes are replayed first (same ids, same order), then
/// `backward_start` marks the boundary and backward/optimizer nodes follow.
pub fn training_graph(fwd: &Graph, opts: AutodiffOptions) -> Graph {
    let mut g = Graph::new(format!("{}-train", fwd.name), GraphKind::Training);
    // Replay forward nodes; ids are preserved because insertion order is id.
    for n in fwd.nodes() {
        let id = g.add(n.op.clone(), &n.inputs, n.out.clone(), n.name.clone());
        debug_assert_eq!(id, n.id);
    }
    g.backward_start = Some(g.len());

    let mut diff = Diff { g, grads: HashMap::new(), param_grads: Vec::new() };

    // Seed: if the terminal compute node is a Loss, seed its input with the
    // loss-grad op; otherwise inject a synthetic `dout` input for the last
    // node's output (subgraph-level training capture).
    let last = NodeId(fwd.len() - 1);
    match &fwd.node(last).op {
        OpKind::Loss => {
            let seed = diff.g.add(
                OpKind::Elementwise(EwKind::Scale),
                &[last],
                fwd.node(fwd.node(last).inputs[0]).out.clone(),
                "loss_grad",
            );
            diff.accumulate(fwd.node(last).inputs[0], seed);
        }
        _ => {
            let dout = diff.g.add(OpKind::Input, &[], fwd.node(last).out.clone(), "dout");
            diff.accumulate(last, dout);
        }
    }

    // Reverse-topological sweep emitting VJPs.
    for idx in (0..fwd.len()).rev() {
        let id = NodeId(idx);
        let Some(&dy) = diff.grads.get(&id) else { continue };
        diff.vjp(fwd, id, dy);
    }

    if opts.optimizer_updates {
        let param_grads = std::mem::take(&mut diff.param_grads);
        for (param, grad) in param_grads {
            let out = diff.g.node(param).out.clone();
            let name = format!("{}.optstep", diff.g.node(param).name);
            diff.g.add(OpKind::OptimizerUpdate, &[param, grad], out, name);
        }
    }

    debug_assert!(diff.g.validate().is_empty(), "{:?}", diff.g.validate());
    diff.g
}

struct Diff {
    g: Graph,
    grads: HashMap<NodeId, NodeId>,
    param_grads: Vec<(NodeId, NodeId)>,
}

impl Diff {
    /// Record `grad` as (part of) d/d`target`, emitting an accumulation Add
    /// when the target already has a gradient (fan-out in the forward pass).
    fn accumulate(&mut self, target: NodeId, grad: NodeId) {
        if let Some(&prev) = self.grads.get(&target) {
            let out = self.g.node(grad).out.clone();
            let sum = self.g.add(
                OpKind::Elementwise(EwKind::Add),
                &[prev, grad],
                out,
                format!("accum_grad.{}", target.0),
            );
            self.grads.insert(target, sum);
        } else {
            self.grads.insert(target, grad);
        }
        if matches!(self.g.node(target).op, OpKind::Param) {
            // Track latest accumulated grad for the optimizer pass.
            let g = self.grads[&target];
            if let Some(e) = self.param_grads.iter_mut().find(|(p, _)| *p == target) {
                e.1 = g;
            } else {
                self.param_grads.push((target, g));
            }
        }
    }

    fn desc_of(&self, id: NodeId) -> TensorDesc {
        self.g.node(id).out.clone()
    }

    /// Emit the vector-Jacobian product of node `id` given output grad `dy`.
    fn vjp(&mut self, fwd: &Graph, id: NodeId, dy: NodeId) {
        let node = fwd.node(id).clone();
        let nm = |s: &str| format!("{}.{}", node.name, s);
        match node.op {
            OpKind::Matmul { b, m, n, k } => {
                let x = node.inputs[0];
                let w = node.inputs[1];
                // dX = dY @ W^T : [b,m,n] x [n,k]
                let dx = self.g.add(
                    OpKind::Matmul { b, m, n: k, k: n },
                    &[dy, w],
                    self.desc_of(x),
                    nm("dgrad"),
                );
                self.accumulate(x, dx);
                // dW = X^T @ dY : contraction over b*m — the batch-dimension
                // reduction the paper's split-K pipeline parallelizes.
                let dw = self.g.add(
                    OpKind::Matmul { b: 1, m: k, n, k: b * m },
                    &[x, dy],
                    self.desc_of(w),
                    nm("wgrad"),
                );
                self.accumulate(w, dw);
                // Folded bias (addmm): gradient is an explicit batch
                // reduction of dy — the paper's Fig 2(b) pattern.
                if let Some(&bias) = node.inputs.get(2) {
                    let db = self.g.add(
                        OpKind::Reduce { axis: ReduceAxis::Batch, factor: b * m },
                        &[dy],
                        self.desc_of(bias),
                        nm("bias_grad"),
                    );
                    self.accumulate(bias, db);
                }
            }
            OpKind::Elementwise(EwKind::Add) => {
                let a = node.inputs[0];
                let bb = node.inputs[1];
                // Residual/bias add: grads flow through; a broadcast bias
                // parameter gets an explicit batch reduction (Fig 2(b)).
                self.accumulate(a, dy);
                let a_numel = self.g.node(a).out.numel();
                let b_numel = self.g.node(bb).out.numel();
                if b_numel < a_numel {
                    let factor = a_numel / b_numel.max(1);
                    let db = self.g.add(
                        OpKind::Reduce { axis: ReduceAxis::Batch, factor },
                        &[dy],
                        self.desc_of(bb),
                        nm("bias_grad"),
                    );
                    self.accumulate(bb, db);
                } else {
                    self.accumulate(bb, dy);
                }
            }
            OpKind::Elementwise(EwKind::Sub) => {
                let a = node.inputs[0];
                let bb = node.inputs[1];
                self.accumulate(a, dy);
                let neg = self.g.add(
                    OpKind::Elementwise(EwKind::Scale),
                    &[dy],
                    self.desc_of(bb),
                    nm("neg_grad"),
                );
                self.accumulate(bb, neg);
            }
            OpKind::Elementwise(EwKind::Mul) => {
                let a = node.inputs[0];
                let bb = node.inputs[1];
                let da = self.g.add(
                    OpKind::Elementwise(EwKind::Mul),
                    &[dy, bb],
                    self.desc_of(a),
                    nm("mul_grad_a"),
                );
                self.accumulate(a, da);
                let db = self.g.add(
                    OpKind::Elementwise(EwKind::Mul),
                    &[dy, a],
                    self.desc_of(bb),
                    nm("mul_grad_b"),
                );
                self.accumulate(bb, db);
            }
            OpKind::Elementwise(kind) => {
                // Unary activation (or binary mask-style): dx = dy * f'(x).
                // The fwd input is re-read here — the Fig 2(c) multicast.
                let x = node.inputs[0];
                let dx = self.g.add(
                    OpKind::Elementwise(EwKind::ActGrad),
                    &[dy, x],
                    self.desc_of(x),
                    nm(&format!("{kind:?}_bwd").to_lowercase()),
                );
                self.accumulate(x, dx);
            }
            OpKind::Softmax => {
                let x = node.inputs[0];
                // rowsum(dy * y) then dx = y * (dy - rowsum)
                let t = self.g.node(x).out.shape.trailing();
                let mut dims = self.g.node(x).out.shape.dims().to_vec();
                *dims.last_mut().unwrap() = 1;
                let rowsum = self.g.add(
                    OpKind::Reduce { axis: ReduceAxis::Feature, factor: t },
                    &[dy, id],
                    TensorDesc::new(&dims, self.g.node(x).out.dtype),
                    nm("softmax_rowsum"),
                );
                let dx = self.g.add(
                    OpKind::Elementwise(EwKind::ActGrad),
                    &[dy, rowsum],
                    self.desc_of(x),
                    nm("softmax_bwd"),
                );
                self.accumulate(x, dx);
            }
            OpKind::LayerNorm => {
                let x = node.inputs[0];
                let t = self.g.node(x).out.shape.trailing();
                let mut dims = self.g.node(x).out.shape.dims().to_vec();
                *dims.last_mut().unwrap() = 1;
                let stats = self.g.add(
                    OpKind::Reduce { axis: ReduceAxis::Feature, factor: t },
                    &[dy, x],
                    TensorDesc::new(&dims, self.g.node(x).out.dtype),
                    nm("ln_stats_bwd"),
                );
                let dx = self.g.add(
                    OpKind::Elementwise(EwKind::ActGrad),
                    &[dy, stats],
                    self.desc_of(x),
                    nm("ln_bwd"),
                );
                self.accumulate(x, dx);
            }
            OpKind::Concat { n_inputs } => {
                // Each input's gradient is a contiguous column window of
                // dy; the explicit offsets make the node streamable (the
                // training lowering maps it to one SliceCols kernel).
                let mut start = 0usize;
                for i in 0..n_inputs {
                    let src = node.inputs[i];
                    let len = self.g.node(src).out.shape.trailing();
                    let slice = self.g.add(
                        OpKind::Elementwise(EwKind::Slice { start, len }),
                        &[dy],
                        self.desc_of(src),
                        nm(&format!("slice_grad.{i}")),
                    );
                    start += len;
                    self.accumulate(src, slice);
                }
            }
            OpKind::Gather { .. } => {
                // Embedding backward: scatter-add into the table. Excluded
                // from sf-nodes (§5.1) but present in the training graph.
                let table = node.inputs[1];
                let ds = self.g.add(OpKind::Scatter, &[dy], self.desc_of(table), nm("scatter_grad"));
                self.accumulate(table, ds);
            }
            OpKind::Interaction { features, dim } => {
                let x = node.inputs[0];
                let dx = self.g.add(
                    OpKind::Interaction { features, dim },
                    &[dy],
                    self.desc_of(x),
                    nm("interaction_bwd"),
                );
                self.accumulate(x, dx);
            }
            OpKind::Reduce { .. } => {
                // Broadcast the grad back to the un-reduced shape.
                let x = node.inputs[0];
                let bx = self.g.add(
                    OpKind::Elementwise(EwKind::Scale),
                    &[dy],
                    self.desc_of(x),
                    nm("bcast_grad"),
                );
                self.accumulate(x, bx);
            }
            OpKind::Loss | OpKind::Input | OpKind::Param => {}
            OpKind::Scatter | OpKind::OptimizerUpdate | OpKind::Queue { .. } => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::op::ResourceClass;

    fn linear_relu_graph() -> Graph {
        let mut b = GraphBuilder::new("lr", GraphKind::Inference);
        let x = b.input(&[64, 128], "x");
        let h = b.linear(x, 256, true, "fc1");
        let a = b.relu(h, "act");
        let y = b.linear(a, 10, false, "fc2");
        b.loss(y, "loss");
        b.finish()
    }

    #[test]
    fn training_graph_valid_and_larger() {
        let fwd = linear_relu_graph();
        let tg = training_graph(&fwd, AutodiffOptions::default());
        assert!(tg.validate().is_empty(), "{:?}", tg.validate());
        assert!(tg.n_compute_ops() > 2 * fwd.n_compute_ops());
        assert_eq!(tg.kind, GraphKind::Training);
        assert!(tg.backward_start.is_some());
    }

    #[test]
    fn wgrad_contracts_over_batch() -> crate::Result<()> {
        let fwd = linear_relu_graph();
        let tg = training_graph(&fwd, AutodiffOptions { optimizer_updates: false });
        // Find the fc1 wgrad GEMM: must contract over the batch (k = 64).
        let wgrad = tg
            .nodes()
            .iter()
            .find(|n| n.name == "fc1.wgrad")
            .expect("fc1 wgrad emitted");
        match wgrad.op {
            OpKind::Matmul { k, .. } => assert_eq!(k, 64),
            ref other => anyhow::bail!("fc1 wgrad is {other:?}, not a matmul"),
        }
        Ok(())
    }

    #[test]
    fn bias_grad_is_batch_reduce() -> crate::Result<()> {
        let fwd = linear_relu_graph();
        let tg = training_graph(&fwd, AutodiffOptions { optimizer_updates: false });
        let bias_grad = tg
            .nodes()
            .iter()
            .find(|n| n.name == "fc1.bias_grad")
            .expect("bias grad emitted");
        match bias_grad.op {
            OpKind::Reduce { axis: ReduceAxis::Batch, factor } => assert_eq!(factor, 64),
            ref other => anyhow::bail!("fc1 bias grad is {other:?}, not a batch reduce"),
        }
        Ok(())
    }

    #[test]
    fn act_grad_multicasts_to_two_gemms() {
        // Fig 2(c): the activation-grad output feeds the dgrad GEMM of fc2's
        // input *and* fc2's wgrad GEMM.
        let fwd = linear_relu_graph();
        let tg = training_graph(&fwd, AutodiffOptions { optimizer_updates: false });
        let act_bwd = tg
            .nodes()
            .iter()
            .find(|n| n.name.contains("relu_bwd") || n.name.contains("act.relu_bwd"))
            .expect("relu bwd emitted");
        // Its *input* dy (the fc2 dgrad output) must have fanned out; more
        // directly: the saved fwd activation `act` output feeds relu fwd
        // consumer AND the fc2 wgrad GEMM.
        let act_fwd = tg.nodes().iter().find(|n| n.name == "act").unwrap();
        let consumers = tg.consumers(act_fwd.id);
        let gemm_consumers = consumers
            .iter()
            .filter(|&&c| matches!(tg.node(c).op, OpKind::Matmul { .. }))
            .count();
        assert!(gemm_consumers >= 2, "activation should feed ≥2 GEMMs, got {consumers:?}");
        let _ = act_bwd;
    }

    #[test]
    fn optimizer_updates_one_per_param() {
        let fwd = linear_relu_graph();
        let tg = training_graph(&fwd, AutodiffOptions { optimizer_updates: true });
        let n_params = fwd
            .nodes()
            .iter()
            .filter(|n| matches!(n.op, OpKind::Param))
            .count();
        let n_updates = tg
            .nodes()
            .iter()
            .filter(|n| matches!(n.op, OpKind::OptimizerUpdate))
            .count();
        assert_eq!(n_params, n_updates);
    }

    #[test]
    fn concat_backward_emits_column_slices() {
        let mut b = GraphBuilder::new("cat", GraphKind::Inference);
        let x = b.input(&[16, 6], "x");
        let y_in = b.input(&[16, 4], "y");
        let c = b.concat(&[x, y_in], "cat");
        let h = b.linear(c, 8, false, "fc");
        b.loss(h, "loss");
        let g = b.finish();
        let tg = training_graph(&g, AutodiffOptions { optimizer_updates: false });
        let slices: Vec<&OpKind> = tg
            .nodes()
            .iter()
            .filter(|n| matches!(n.op, OpKind::Elementwise(EwKind::Slice { .. })))
            .map(|n| &n.op)
            .collect();
        // One slice per concat input, with cumulative column offsets.
        assert_eq!(
            slices,
            vec![
                &OpKind::Elementwise(EwKind::Slice { start: 0, len: 6 }),
                &OpKind::Elementwise(EwKind::Slice { start: 6, len: 4 }),
            ],
        );
    }

    #[test]
    fn backward_has_tensor_and_simt_work() {
        let fwd = linear_relu_graph();
        let tg = training_graph(&fwd, AutodiffOptions::default());
        let start = tg.backward_start.unwrap();
        let bwd: Vec<_> = tg.nodes()[start..].iter().filter(|n| n.op.is_compute()).collect();
        assert!(bwd.iter().any(|n| n.resource_class() == ResourceClass::Tensor));
        assert!(bwd.iter().any(|n| n.resource_class() == ResourceClass::Simt));
    }
}
