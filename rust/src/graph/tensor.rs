//! Tensor descriptors: dtypes and shapes for the operator-graph IR.

use std::fmt;

/// Element datatype. The paper's production scenarios are TensorCore
/// (bf16/fp16) GEMMs with fp32 accumulation; we default to BF16 activations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    BF16,
    F16,
    I32,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::BF16 | DType::F16 => 2,
        }
    }

    /// Whether this dtype is eligible for TensorCore (MXU) issue.
    pub fn tensor_core_eligible(self) -> bool {
        matches!(self, DType::BF16 | DType::F16)
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            DType::F32 => "f32",
            DType::BF16 => "bf16",
            DType::F16 => "f16",
            DType::I32 => "i32",
        };
        write!(f, "{s}")
    }
}

/// A dense row-major shape.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn new(dims: &[usize]) -> Self {
        Shape(dims.to_vec())
    }

    /// Total number of elements.
    pub fn numel(&self) -> usize {
        self.0.iter().product::<usize>().max(if self.0.is_empty() { 1 } else { 0 })
    }

    pub fn rank(&self) -> usize {
        self.0.len()
    }

    pub fn dims(&self) -> &[usize] {
        &self.0
    }

    /// Leading (batch-like) dimension, or 1 for scalars.
    pub fn leading(&self) -> usize {
        self.0.first().copied().unwrap_or(1)
    }

    /// Trailing (feature-like) dimension, or 1 for scalars.
    pub fn trailing(&self) -> usize {
        self.0.last().copied().unwrap_or(1)
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

/// Full tensor descriptor: shape + dtype.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TensorDesc {
    pub shape: Shape,
    pub dtype: DType,
}

impl TensorDesc {
    pub fn new(dims: &[usize], dtype: DType) -> Self {
        TensorDesc { shape: Shape::new(dims), dtype }
    }

    pub fn bf16(dims: &[usize]) -> Self {
        Self::new(dims, DType::BF16)
    }

    pub fn f32(dims: &[usize]) -> Self {
        Self::new(dims, DType::F32)
    }

    /// Total size in bytes.
    pub fn bytes(&self) -> usize {
        self.shape.numel() * self.dtype.size_bytes()
    }

    pub fn numel(&self) -> usize {
        self.shape.numel()
    }
}

impl fmt::Display for TensorDesc {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}{}", self.dtype, self.shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::BF16.size_bytes(), 2);
        assert_eq!(DType::F16.size_bytes(), 2);
        assert_eq!(DType::I32.size_bytes(), 4);
    }

    #[test]
    fn tensor_core_eligibility() {
        assert!(DType::BF16.tensor_core_eligible());
        assert!(DType::F16.tensor_core_eligible());
        assert!(!DType::F32.tensor_core_eligible());
    }

    #[test]
    fn shape_numel() {
        assert_eq!(Shape::new(&[2, 3, 4]).numel(), 24);
        assert_eq!(Shape::new(&[]).numel(), 1);
        assert_eq!(Shape::new(&[5]).numel(), 5);
    }

    #[test]
    fn shape_leading_trailing() {
        let s = Shape::new(&[8, 128, 256]);
        assert_eq!(s.leading(), 8);
        assert_eq!(s.trailing(), 256);
        assert_eq!(s.rank(), 3);
    }

    #[test]
    fn tensor_bytes() {
        let t = TensorDesc::bf16(&[1024, 768]);
        assert_eq!(t.bytes(), 1024 * 768 * 2);
        let t = TensorDesc::f32(&[1024, 768]);
        assert_eq!(t.bytes(), 1024 * 768 * 4);
    }

    #[test]
    fn display() {
        let t = TensorDesc::bf16(&[4, 5]);
        assert_eq!(format!("{t}"), "bf16[4,5]");
    }
}
