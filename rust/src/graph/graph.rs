//! The operator graph: a DAG of [`Node`]s, analogous to the FX graph
//! PyTorch Dynamo hands the paper's compiler backend.
//!
//! Nodes are stored in construction order, which is a valid topological
//! order (builders may only reference already-inserted nodes). The paper's
//! §5.1 pattern matcher deliberately "operates at the topological order
//! which linearizes the graph into a list in PyTorch Dynamo (which is
//! deterministic)" — we preserve exactly that property.

use super::op::{OpKind, ResourceClass};
use super::tensor::TensorDesc;
use std::collections::HashMap;
use std::fmt;

/// Index of a node within its graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "%{}", self.0)
    }
}

/// One operator instance.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub op: OpKind,
    /// Data inputs (producers), in operator-argument order.
    pub inputs: Vec<NodeId>,
    /// Single output descriptor (multi-consumer = fan-out edges).
    pub out: TensorDesc,
    /// Human-readable name, e.g. `"ffn.0.linear"`.
    pub name: String,
}

impl Node {
    pub fn resource_class(&self) -> ResourceClass {
        self.op.resource_class()
    }

    pub fn flops(&self) -> f64 {
        self.op.flops(&self.out)
    }
}

/// Whether a graph is a forward-only (inference) capture or includes the
/// backward pass (training), mirroring Dynamo's fwd/bwd graph extraction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphKind {
    Inference,
    Training,
}

/// A DAG of operators in deterministic topological order.
#[derive(Debug, Clone)]
pub struct Graph {
    pub name: String,
    pub kind: GraphKind,
    nodes: Vec<Node>,
    /// Consumers of each node (reverse edges), kept in sync on insert.
    consumers: Vec<Vec<NodeId>>,
    /// First backward-pass node index, if `kind == Training`.
    pub backward_start: Option<usize>,
}

impl Graph {
    pub fn new(name: impl Into<String>, kind: GraphKind) -> Self {
        Graph {
            name: name.into(),
            kind,
            nodes: Vec::new(),
            consumers: Vec::new(),
            backward_start: None,
        }
    }

    /// Insert a node whose inputs must already exist. Returns its id.
    ///
    /// # Panics
    /// Panics if any input id is out of range (forward reference), which
    /// would break the topological-order invariant.
    pub fn add(
        &mut self,
        op: OpKind,
        inputs: &[NodeId],
        out: TensorDesc,
        name: impl Into<String>,
    ) -> NodeId {
        let id = NodeId(self.nodes.len());
        for &i in inputs {
            assert!(
                i.0 < self.nodes.len(),
                "forward reference {i} while adding node {id}"
            );
            self.consumers[i.0].push(id);
        }
        self.nodes.push(Node {
            id,
            op,
            inputs: inputs.to_vec(),
            out,
            name: name.into(),
        });
        self.consumers.push(Vec::new());
        id
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0]
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Consumers (fan-out) of a node. Fan-out > 1 is the paper's Fig 2(c)
    /// multicast pattern.
    pub fn consumers(&self, id: NodeId) -> &[NodeId] {
        &self.consumers[id.0]
    }

    /// Ids in topological order (construction order by invariant).
    pub fn topo_order(&self) -> impl Iterator<Item = NodeId> + '_ {
        (0..self.nodes.len()).map(NodeId)
    }

    /// Compute operators only (excludes Input/Param/Queue).
    pub fn compute_nodes(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(|n| n.op.is_compute())
    }

    /// Number of compute operators — the paper's Table 2 "# Ops" column.
    pub fn n_compute_ops(&self) -> usize {
        self.compute_nodes().count()
    }

    /// Whether `id` belongs to the backward pass of a training graph.
    pub fn is_backward(&self, id: NodeId) -> bool {
        match self.backward_start {
            Some(start) => id.0 >= start,
            None => false,
        }
    }

    /// Validate DAG invariants: inputs precede uses, consumer lists match,
    /// arity is plausible. Returns the list of violations (empty = valid).
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        let mut seen_consumers: HashMap<NodeId, Vec<NodeId>> = HashMap::new();
        for n in &self.nodes {
            for &i in &n.inputs {
                if i.0 >= n.id.0 {
                    errs.push(format!("node {} uses non-preceding input {}", n.id, i));
                }
                seen_consumers.entry(i).or_default().push(n.id);
            }
            match &n.op {
                OpKind::Input | OpKind::Param => {
                    if !n.inputs.is_empty() {
                        errs.push(format!("source node {} has inputs", n.id));
                    }
                }
                OpKind::Concat { n_inputs } => {
                    if n.inputs.len() != *n_inputs {
                        errs.push(format!(
                            "concat {} declares {} inputs, has {}",
                            n.id,
                            n_inputs,
                            n.inputs.len()
                        ));
                    }
                }
                _ => {
                    if n.op.is_compute() && n.inputs.is_empty() {
                        errs.push(format!("compute node {} ({}) has no inputs", n.id, n.op));
                    }
                }
            }
        }
        for (id, mut want) in seen_consumers {
            want.sort();
            let mut got = self.consumers[id.0].clone();
            got.sort();
            if want != got {
                errs.push(format!("consumer list mismatch at {id}"));
            }
        }
        errs
    }

    /// Total FLOPs over compute nodes.
    pub fn total_flops(&self) -> f64 {
        self.compute_nodes().map(|n| n.flops()).sum()
    }

    /// Pretty multi-line dump (for `kitsune apps --dump`).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("graph {} ({:?}, {} nodes)\n", self.name, self.kind, self.len()));
        for n in &self.nodes {
            let ins: Vec<String> = n.inputs.iter().map(|i| i.to_string()).collect();
            s.push_str(&format!(
                "  {} = {} ({}) -> {}  # {}\n",
                n.id,
                n.op,
                ins.join(", "),
                n.out,
                n.name
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::EwKind;
    use crate::graph::tensor::TensorDesc;

    fn tiny() -> Graph {
        let mut g = Graph::new("t", GraphKind::Inference);
        let x = g.add(OpKind::Input, &[], TensorDesc::bf16(&[8, 16]), "x");
        let w = g.add(OpKind::Param, &[], TensorDesc::bf16(&[16, 32]), "w");
        let mm = g.add(
            OpKind::Matmul { b: 1, m: 8, n: 32, k: 16 },
            &[x, w],
            TensorDesc::bf16(&[8, 32]),
            "mm",
        );
        g.add(
            OpKind::Elementwise(EwKind::Relu),
            &[mm],
            TensorDesc::bf16(&[8, 32]),
            "relu",
        );
        g
    }

    #[test]
    fn build_and_validate() {
        let g = tiny();
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        assert_eq!(g.len(), 4);
        assert_eq!(g.n_compute_ops(), 2);
    }

    #[test]
    fn consumers_tracked() {
        let g = tiny();
        assert_eq!(g.consumers(NodeId(2)), &[NodeId(3)]);
        assert_eq!(g.consumers(NodeId(0)), &[NodeId(2)]);
        assert!(g.consumers(NodeId(3)).is_empty());
    }

    #[test]
    #[should_panic(expected = "forward reference")]
    fn forward_reference_panics() {
        let mut g = Graph::new("bad", GraphKind::Inference);
        g.add(
            OpKind::Elementwise(EwKind::Relu),
            &[NodeId(5)],
            TensorDesc::bf16(&[1]),
            "bad",
        );
    }

    #[test]
    fn topo_order_is_insertion_order() {
        let g = tiny();
        let ids: Vec<usize> = g.topo_order().map(|i| i.0).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn backward_marking() {
        let mut g = tiny();
        g.kind = GraphKind::Training;
        g.backward_start = Some(3);
        assert!(!g.is_backward(NodeId(2)));
        assert!(g.is_backward(NodeId(3)));
    }

    #[test]
    fn flops_sum() {
        let g = tiny();
        let mm_flops = 2.0 * 8.0 * 32.0 * 16.0;
        let relu_flops = 8.0 * 32.0;
        assert_eq!(g.total_flops(), mm_flops + relu_flops);
    }
}
