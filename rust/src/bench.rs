//! Minimal benchmark harness (criterion is unavailable offline): warmup +
//! timed iterations, mean/min/max/σ reporting, and a no-inline sink.
//!
//! Every `rust/benches/*.rs` target (one per paper table/figure) uses
//! this: it both *times* the experiment driver and *prints* the
//! regenerated table/figure, so `cargo bench` reproduces the paper's
//! evaluation artifacts end to end.

use std::time::Instant;

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Timing statistics over the measured iterations.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub stddev_s: f64,
}

impl Stats {
    fn from_samples(samples: &[f64]) -> Stats {
        let n = samples.len().max(1) as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
        Stats {
            iters: samples.len(),
            mean_s: mean,
            min_s: samples.iter().copied().fold(f64::INFINITY, f64::min),
            max_s: samples.iter().copied().fold(0.0, f64::max),
            stddev_s: var.sqrt(),
        }
    }
}

fn fmt_t(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

/// Run `f` with `warmup` unmeasured and `iters` measured iterations,
/// printing a criterion-style line.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let st = Stats::from_samples(&samples);
    println!(
        "bench {name:<42} {:>12} mean  [{} .. {}]  σ {}  ({} iters)",
        fmt_t(st.mean_s),
        fmt_t(st.min_s),
        fmt_t(st.max_s),
        fmt_t(st.stddev_s),
        st.iters
    );
    st
}

/// Repo root for benchmark artifacts (`BENCH_*.json`): cargo runs bench
/// binaries with CWD = the package dir (`rust/`), so the repo root is
/// the parent; fall back to the CWD when the layout is unexpected (e.g.
/// the binary was invoked by hand elsewhere).
pub fn artifact_root() -> std::path::PathBuf {
    let cwd = std::env::current_dir().unwrap_or_else(|_| std::path::PathBuf::from("."));
    let parent = cwd.join("..");
    if parent.join("ROADMAP.md").is_file() && !cwd.join("ROADMAP.md").is_file() {
        parent
    } else {
        cwd
    }
}

/// True when `BENCH_SMOKE` is set non-empty (CI smoke mode: benches run
/// a few tiny iterations just to prove the path and emit the JSON).
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_sane() {
        let st = bench("noop", 1, 5, || 42u64);
        assert_eq!(st.iters, 5);
        assert!(st.min_s <= st.mean_s && st.mean_s <= st.max_s);
    }
}
