//! Kitsune dataflow execution: run the compiled plan — spatial pipelines
//! through the dual-arbiter simulator, leftover operators bulk-sync.

use super::bsp::LAUNCH_OVERHEAD_S;
use super::report::{ExecMode, ExecReport, RegionResult};
use crate::compiler::{CompiledApp, PlanItem};
use crate::graph::Graph;
use crate::perfmodel;
use crate::sim::{Engine, SimReport};
use anyhow::Result;
use std::collections::HashMap;

/// Execute a compiled application under Kitsune dataflow.
/// `per_node_bsp` supplies BSP per-op times for region speedups.
pub fn run_dataflow(
    g: &Graph,
    app: &CompiledApp,
    engine: &Engine,
    per_node_bsp: &HashMap<crate::graph::NodeId, f64>,
) -> Result<ExecReport> {
    let mut total = SimReport::default();
    let mut regions = Vec::new();
    let mut unfused_s = 0.0;

    for item in &app.plan {
        match item {
            PlanItem::Pipeline(pi) => {
                let lp = &app.pipelines[*pi];
                let mut r = engine.run_pipeline(&lp.desc)?;
                // One spatial-pipeline launch (cudaPipelineLaunch, Fig 6).
                r.elapsed_s += LAUNCH_OVERHEAD_S;
                r.quadrants.add_sample(0.0, 0.0, LAUNCH_OVERHEAD_S);
                let bsp_s: f64 = lp.nodes.iter().map(|n| per_node_bsp[n]).sum();
                regions.push(RegionResult {
                    name: lp.desc.name.clone(),
                    n_ops: lp.nodes.len(),
                    elapsed_s: r.elapsed_s,
                    bsp_s,
                    backward: lp.nodes.iter().any(|&n| g.is_backward(n)),
                });
                total = total.chain(&r);
            }
            PlanItem::Bsp(nid) => {
                let node = g.node(*nid);
                let k = perfmodel::bsp_kernel(node, g, &engine.cfg);
                let mut r = engine.run_kernel(&k)?;
                r.elapsed_s += LAUNCH_OVERHEAD_S;
                unfused_s += r.elapsed_s;
                total = total.chain(&r);
            }
        }
    }

    Ok(ExecReport {
        mode: ExecMode::Kitsune,
        app: g.name.clone(),
        sim: total,
        regions,
        unfused_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, SelectOptions};
    use crate::exec::bsp::run_bsp_detailed;
    use crate::graph::{EwKind, GraphBuilder, GraphKind};
    use crate::sim::{GpuConfig, SchedPolicy};

    fn ffn() -> Graph {
        let mut b = GraphBuilder::new("ffn", GraphKind::Inference);
        let x = b.input(&[4096, 1024], "x");
        b.mlp(x, &[4096, 4096, 1024], EwKind::Gelu, false, "ffn");
        b.finish()
    }

    #[test]
    fn dataflow_beats_bsp_on_mlp() {
        let g = ffn();
        let cfg = GpuConfig::a100();
        let app = compile(&g, &cfg, &SelectOptions::default()).unwrap();
        let bsp_engine = Engine::new(cfg.clone(), SchedPolicy::RoundRobin);
        let df_engine = Engine::new(cfg, SchedPolicy::DualArbiter);
        let (bsp, per_node) = run_bsp_detailed(&g, &bsp_engine).unwrap();
        let df = run_dataflow(&g, &app, &df_engine, &per_node).unwrap();
        let speedup = df.speedup_over(&bsp);
        assert!(speedup > 1.0, "kitsune speedup {speedup}");
        assert!(df.traffic_reduction_vs(&bsp) > 0.2, "{}", df.traffic_reduction_vs(&bsp));
    }

    #[test]
    fn regions_cover_fused_nodes() {
        let g = ffn();
        let cfg = GpuConfig::a100();
        let app = compile(&g, &cfg, &SelectOptions::default()).unwrap();
        let e = Engine::new(cfg, SchedPolicy::DualArbiter);
        let (_, per_node) = run_bsp_detailed(&g, &e).unwrap();
        let df = run_dataflow(&g, &app, &e, &per_node).unwrap();
        let region_ops: usize = df.regions.iter().map(|r| r.n_ops).sum();
        assert_eq!(region_ops, app.n_fused_ops());
    }
}
