//! Vertical-fusion execution — the paper's state-of-art baseline, a
//! composite of TensorRT, AStitch and Welder mechanisms (§6.1):
//!
//! * operators fuse into a single "mega kernel" along single-consumer
//!   producer chains, temporally multiplexing the SM between regions;
//! * intermediate tiles stay in shared memory / registers when they fit;
//!   when the hidden dimension overruns the scratchpad (Fig 2(a):
//!   `N >= 768` fp32 on A100's 192 KB), the tile **spills** to DRAM and
//!   the consumer pays the round-trip latency;
//! * reductions cannot be parallelized beyond their natural CTA count
//!   (Fig 2(b)) and break fusion;
//! * the forward pass only — "none of the academic work or TensorRT have
//!   demonstrated execution of training" (paper footnote 2); backward
//!   nodes run bulk-synchronously.

use super::bsp::LAUNCH_OVERHEAD_S;
use super::report::{ExecMode, ExecReport, RegionResult};
use crate::graph::{Graph, Node, NodeId, OpKind};
use crate::perfmodel::{self, IoPlacement, Loc};
use crate::sim::{Engine, SimReport};
use anyhow::Result;
use std::collections::HashMap;

/// Max operators per vertically fused kernel (code-size/register limits).
pub const MAX_VF_GROUP: usize = 8;

/// A vertical fusion group: consecutive chain of node ids.
#[derive(Debug, Clone)]
pub struct VfGroup {
    pub nodes: Vec<NodeId>,
    /// Edges (by consumer index into `nodes`) that spill to DRAM.
    pub spilled: Vec<bool>,
}

/// Can this op participate in a vertically fused kernel?
fn vf_fusable(node: &Node) -> bool {
    matches!(
        node.op,
        OpKind::Matmul { .. }
            | OpKind::Elementwise(_)
            | OpKind::Softmax
            | OpKind::LayerNorm
            | OpKind::Concat { .. }
    )
}

/// Does the intermediate between `prod` and its consumer fit on chip for
/// a data-parallel VF tile? (Fig 2(a) criterion.)
fn edge_spills(prod: &Node, cfg: &crate::sim::GpuConfig) -> bool {
    let hidden = prod.out.shape.trailing();
    perfmodel::vf_tile_spills(hidden, prod.out.dtype.size_bytes(), cfg)
}

/// Partition the eligible (forward-pass) nodes into fusion groups.
pub fn vf_groups(g: &Graph, cfg: &crate::sim::GpuConfig) -> Vec<VfGroup> {
    let fwd_end = g.backward_start.unwrap_or(g.len());
    let mut groups: Vec<VfGroup> = Vec::new();
    let mut current: Vec<NodeId> = Vec::new();
    let mut spilled: Vec<bool> = Vec::new();

    let mut flush = |current: &mut Vec<NodeId>, spilled: &mut Vec<bool>| {
        if current.len() >= 2 {
            groups.push(VfGroup { nodes: std::mem::take(current), spilled: std::mem::take(spilled) });
        } else {
            current.clear();
            spilled.clear();
        }
    };

    for node in g.nodes() {
        if node.id.0 >= fwd_end {
            break;
        }
        if !node.op.is_compute() {
            continue;
        }
        if !vf_fusable(node) || current.len() >= MAX_VF_GROUP {
            flush(&mut current, &mut spilled);
            if !vf_fusable(node) {
                continue;
            }
        }
        // Chain rule: the node must consume the previous member's output,
        // and that output must have no other consumer (pure chain — VF
        // cannot multicast across CTAs). A GEMM can only *anchor* a group:
        // GEMM→GEMM chains are beyond TensorRT-class epilogue fusion, and
        // Welder/AStitch-style stitching across a second GEMM forces the
        // intermediate tile through memory anyway (Fig 2(a)) — modeled by
        // starting a new group (with a spill if the tile overruns smem).
        let is_gemm = matches!(node.op, OpKind::Matmul { .. });
        if let Some(&prev) = current.last() {
            let consumes_prev = node.inputs.contains(&prev);
            let prev_single = g.consumers(prev).len() == 1;
            if consumes_prev && prev_single && !is_gemm {
                spilled.push(edge_spills(g.node(prev), cfg));
                current.push(node.id);
            } else {
                flush(&mut current, &mut spilled);
                current.push(node.id);
            }
        } else {
            current.push(node.id);
        }
    }
    flush(&mut current, &mut spilled);
    groups
}

/// Execute the graph under vertical fusion.
/// `per_node_bsp` supplies the BSP baseline times for region speedups.
pub fn run_vertical(
    g: &Graph,
    engine: &Engine,
    per_node_bsp: &HashMap<NodeId, f64>,
) -> Result<ExecReport> {
    let cfg = &engine.cfg;
    let groups = vf_groups(g, cfg);
    let in_group: HashMap<NodeId, usize> = groups
        .iter()
        .enumerate()
        .flat_map(|(gi, grp)| grp.nodes.iter().map(move |&n| (n, gi)))
        .collect();

    let mut total = SimReport::default();
    let mut regions = Vec::new();
    let mut unfused_s = 0.0;
    let mut done_groups: Vec<bool> = vec![false; groups.len()];

    for node in g.compute_nodes() {
        match in_group.get(&node.id) {
            Some(&gi) => {
                if done_groups[gi] {
                    continue;
                }
                done_groups[gi] = true;
                let grp = &groups[gi];
                // Fused kernel: members run as temporally-multiplexed
                // regions — sequential, sharing one launch; internal edges
                // free (smem) or spilled (DRAM + round trip).
                let mut group_sim = SimReport::default();
                for (i, &nid) in grp.nodes.iter().enumerate() {
                    let n = g.node(nid);
                    let mut io = IoPlacement::bsp(n.inputs.len());
                    // Input from the previous member: on-chip or spilled.
                    if i > 0 {
                        let prev = grp.nodes[i - 1];
                        let spill = grp.spilled[i - 1];
                        for (slot, &inp) in n.inputs.iter().enumerate() {
                            if inp == prev {
                                io.ins[slot] = if spill { Loc::Dram } else { Loc::Smem };
                            }
                        }
                    }
                    // Output to the next member: on-chip or spilled.
                    if i + 1 < grp.nodes.len() && !grp.spilled[i] {
                        io.out = Loc::Smem;
                    }
                    let k = perfmodel::kernel_with_io(n, g, cfg, &io);
                    let latency = if i > 0 && grp.spilled[i - 1] { cfg.dram_latency_s } else { 0.0 };
                    let r = engine.run_kernel_with_latency(&k, latency)?;
                    group_sim = group_sim.chain(&r);
                }
                group_sim.elapsed_s += LAUNCH_OVERHEAD_S; // one launch per group
                group_sim.quadrants.add_sample(0.0, 0.0, LAUNCH_OVERHEAD_S);
                let bsp_s: f64 = grp.nodes.iter().map(|n| per_node_bsp[n]).sum();
                regions.push(RegionResult {
                    name: format!("vf{}", gi),
                    n_ops: grp.nodes.len(),
                    elapsed_s: group_sim.elapsed_s,
                    bsp_s,
                    backward: false,
                });
                total = total.chain(&group_sim);
            }
            None => {
                let k = perfmodel::bsp_kernel(node, g, cfg);
                let mut r = engine.run_kernel(&k)?;
                r.elapsed_s += LAUNCH_OVERHEAD_S;
                r.quadrants.add_sample(0.0, 0.0, LAUNCH_OVERHEAD_S);
                unfused_s += r.elapsed_s;
                total = total.chain(&r);
            }
        }
    }

    Ok(ExecReport {
        mode: ExecMode::Vertical,
        app: g.name.clone(),
        sim: total,
        regions,
        unfused_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::bsp::run_bsp_detailed;
    use crate::graph::{training_graph, AutodiffOptions, EwKind, GraphBuilder, GraphKind};
    use crate::sim::{GpuConfig, SchedPolicy};

    fn engine() -> Engine {
        Engine::new(GpuConfig::a100(), SchedPolicy::RoundRobin)
    }

    fn small_mlp(hidden: usize) -> Graph {
        let mut b = GraphBuilder::new("m", GraphKind::Inference);
        let x = b.input(&[4096, 256], "x");
        b.mlp(x, &[hidden, 256], EwKind::Relu, false, "net");
        b.finish()
    }

    #[test]
    fn groups_form_chains() {
        // GEMM-anchored epilogue fusion: [linear relu] fuse; the second
        // linear starts a new (singleton, hence dropped) group.
        let g = small_mlp(256);
        let groups = vf_groups(&g, &GpuConfig::a100());
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].nodes.len(), 2); // linear + relu epilogue
    }

    #[test]
    fn narrow_hidden_stays_on_chip_wide_spills() {
        let cfg = GpuConfig::a100();
        let narrow = vf_groups(&small_mlp(256), &cfg);
        assert!(narrow[0].spilled.iter().all(|&s| !s), "{narrow:?}");
        let wide = vf_groups(&small_mlp(4096), &cfg);
        assert!(wide[0].spilled.iter().any(|&s| s), "{wide:?}");
    }

    #[test]
    fn vertical_beats_bsp_on_fusable_graph() {
        let g = small_mlp(256);
        let e = engine();
        let (bsp, per_node) = run_bsp_detailed(&g, &e).unwrap();
        let vf = run_vertical(&g, &e, &per_node).unwrap();
        assert!(
            vf.sim.elapsed_s < bsp.sim.elapsed_s,
            "vf {} vs bsp {}",
            vf.sim.elapsed_s,
            bsp.sim.elapsed_s
        );
        assert!(vf.traffic_reduction_vs(&bsp) > 0.0);
    }

    #[test]
    fn backward_pass_not_fused() {
        let mut b = GraphBuilder::new("t", GraphKind::Inference);
        let x = b.input(&[1024, 256], "x");
        let h = b.mlp(x, &[256, 64], EwKind::Relu, false, "net");
        b.loss(h, "loss");
        let fwd = b.finish();
        let tg = training_graph(&fwd, AutodiffOptions::default());
        let groups = vf_groups(&tg, &GpuConfig::a100());
        let bwd_start = tg.backward_start.unwrap();
        for grp in &groups {
            for &n in &grp.nodes {
                assert!(n.0 < bwd_start, "backward node fused by VF");
            }
        }
    }
}
