//! Execution reports shared by the three backends.

use crate::sim::SimReport;

/// Which execution model produced a report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Bulk-synchronous (unfused PyTorch) — the paper's baseline.
    Bsp,
    /// State-of-art vertical fusion (TensorRT ∪ AStitch ∪ Welder model).
    Vertical,
    /// Kitsune spatial dataflow.
    Kitsune,
}

impl std::fmt::Display for ExecMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecMode::Bsp => write!(f, "bulk-sync"),
            ExecMode::Vertical => write!(f, "vertical"),
            ExecMode::Kitsune => write!(f, "kitsune"),
        }
    }
}

/// Result for one fused region (sf-node / vertical group) — rows of the
/// paper's Fig 10/12 subgraph charts.
#[derive(Debug, Clone)]
pub struct RegionResult {
    pub name: String,
    /// Ops covered by the region.
    pub n_ops: usize,
    /// Time under this execution mode.
    pub elapsed_s: f64,
    /// Time the same ops take under plain BSP (for speedup).
    pub bsp_s: f64,
    /// Whether the region ran in the backward pass (training splits).
    pub backward: bool,
}

impl RegionResult {
    pub fn speedup(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.bsp_s / self.elapsed_s
        } else {
            1.0
        }
    }
}

/// Whole-application execution result.
#[derive(Debug, Clone)]
pub struct ExecReport {
    pub mode: ExecMode,
    pub app: String,
    pub sim: SimReport,
    /// Fused regions (empty for pure BSP).
    pub regions: Vec<RegionResult>,
    /// Time spent in operators running bulk-synchronously (the gray
    /// portions of the paper's Fig 11 timelines).
    pub unfused_s: f64,
}

impl ExecReport {
    /// End-to-end speedup of this report over a baseline report.
    pub fn speedup_over(&self, baseline: &ExecReport) -> f64 {
        baseline.sim.elapsed_s / self.sim.elapsed_s.max(1e-30)
    }

    /// Traffic reduction vs a baseline (Table 2's "Traffic Red." column).
    pub fn traffic_reduction_vs(&self, baseline: &ExecReport) -> f64 {
        if baseline.sim.dram_bytes <= 0.0 {
            return 0.0;
        }
        1.0 - self.sim.dram_bytes / baseline.sim.dram_bytes
    }

    /// Fraction of runtime covered by fused regions.
    pub fn region_time_coverage(&self) -> f64 {
        let fused: f64 = self.regions.iter().map(|r| r.elapsed_s).sum();
        let total = self.sim.elapsed_s.max(1e-30);
        (fused / total).min(1.0)
    }

    /// Geomean speedup of the fused regions.
    pub fn region_geomean_speedup(&self) -> f64 {
        if self.regions.is_empty() {
            return 1.0;
        }
        let log_sum: f64 = self.regions.iter().map(|r| r.speedup().max(1e-12).ln()).sum();
        (log_sum / self.regions.len() as f64).exp()
    }
}

/// Geometric mean helper for cross-application summaries.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    (xs.iter().map(|x| x.max(1e-12).ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_math() {
        let r = RegionResult {
            name: "r".into(),
            n_ops: 3,
            elapsed_s: 0.5,
            bsp_s: 1.0,
            backward: false,
        };
        assert!((r.speedup() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[]) - 1.0).abs() < 1e-12);
    }
}
