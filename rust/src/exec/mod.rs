//! Execution-model backends over the simulator: bulk-synchronous (BSP),
//! vertical fusion (composite SOTA baseline), and Kitsune dataflow —
//! the three columns of the paper's evaluation.

pub mod bsp;
pub mod vertical;
pub mod dataflow;
pub mod report;

pub use bsp::{run_bsp, run_bsp_detailed, LAUNCH_OVERHEAD_S};
pub use dataflow::run_dataflow;
pub use report::{geomean, ExecMode, ExecReport, RegionResult};
pub use vertical::{run_vertical, vf_groups, VfGroup};
