//! Bulk-synchronous execution — the unfused PyTorch baseline: one kernel
//! per operator, global barrier and launch overhead between kernels.

use super::report::{ExecMode, ExecReport};
use crate::graph::{Graph, NodeId};
use crate::perfmodel;
use crate::sim::{Engine, SimReport};
use anyhow::Result;
use std::collections::HashMap;

/// Kernel launch + barrier overhead between BSP operators (driver +
/// grid-drain; the cost vertical fusion amortizes).
pub const LAUNCH_OVERHEAD_S: f64 = 4e-6;

/// Run the whole graph bulk-synchronously. Also returns per-node times,
/// which the other backends use as their speedup baselines.
pub fn run_bsp_detailed(g: &Graph, engine: &Engine) -> Result<(ExecReport, HashMap<NodeId, f64>)> {
    let mut total = SimReport::default();
    let mut per_node = HashMap::new();
    for node in g.compute_nodes() {
        let k = perfmodel::bsp_kernel(node, g, &engine.cfg);
        let mut r = engine.run_kernel(&k)?;
        r.elapsed_s += LAUNCH_OVERHEAD_S;
        // The launch/barrier gap is idle time (both resources low).
        r.quadrants.add_sample(0.0, 0.0, LAUNCH_OVERHEAD_S);
        per_node.insert(node.id, r.elapsed_s);
        total = total.chain(&r);
    }
    let unfused_s = total.elapsed_s;
    Ok((
        ExecReport {
            mode: ExecMode::Bsp,
            app: g.name.clone(),
            sim: total,
            regions: Vec::new(),
            unfused_s,
        },
        per_node,
    ))
}

/// Convenience wrapper without the per-node map.
pub fn run_bsp(g: &Graph, engine: &Engine) -> Result<ExecReport> {
    Ok(run_bsp_detailed(g, engine)?.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EwKind, GraphBuilder, GraphKind};
    use crate::sim::{GpuConfig, SchedPolicy};

    fn engine() -> Engine {
        Engine::new(GpuConfig::a100(), SchedPolicy::RoundRobin)
    }

    fn mlp() -> Graph {
        let mut b = GraphBuilder::new("m", GraphKind::Inference);
        let x = b.input(&[2048, 512], "x");
        b.mlp(x, &[2048, 512], EwKind::Relu, false, "net");
        b.finish()
    }

    #[test]
    fn bsp_times_every_compute_node() {
        let g = mlp();
        let (rep, per_node) = run_bsp_detailed(&g, &engine()).unwrap();
        assert_eq!(per_node.len(), g.n_compute_ops());
        let sum: f64 = per_node.values().sum();
        assert!((sum - rep.sim.elapsed_s).abs() / sum < 1e-9);
    }

    #[test]
    fn bsp_includes_launch_overhead() {
        let g = mlp();
        let (rep, _) = run_bsp_detailed(&g, &engine()).unwrap();
        assert!(rep.sim.elapsed_s > g.n_compute_ops() as f64 * LAUNCH_OVERHEAD_S);
    }

    #[test]
    fn bsp_flops_match_graph() {
        let g = mlp();
        let (rep, _) = run_bsp_detailed(&g, &engine()).unwrap();
        assert!((rep.sim.flops - g.total_flops()).abs() / g.total_flops() < 1e-3);
    }
}
