//! The paper's §4.1 primitive: a synchronized ring queue for inter-CTA
//! producer/consumer communication.
//!
//! Two faces:
//! * [`model`] — analytic bandwidth model calibrated to the paper's A100
//!   silicon measurements (regenerates Fig 5);
//! * [`host`] — a real lock-free implementation of the acquire/release
//!   protocol, used by the L3 coordinator's spatial-pipeline runtime.

pub mod host;
pub mod model;

pub use crate::fault::Envelope;
pub use host::{PopError, PushError, RingQueue, Waker};
pub use model::{QueueModel, QueuePoint, ATOMICS_PER_HANDOFF, DEFAULT_ENTRIES};
