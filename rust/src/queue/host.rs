//! A real implementation of the paper's §4.1 synchronized ring queue.
//!
//! This is the same algorithm as Fig 4 — a bounded ring of entries, each
//! carrying a sequence number updated with atomic operations; producers
//! and consumers `acquire` an entry by spinning until its sequence matches
//! their ticket, then `release` it by bumping the sequence — implemented
//! for host CPUs (the coordinator's spatial-pipeline runtime uses it to
//! connect stage threads). On the GPU the sequence metadata lives in
//! L2-pinned cache lines; here each slot's sequence word is padded to a
//! cache line for the same false-sharing reason the paper pads its
//! synchronization variables.
//!
//! The algorithm is the classic bounded MPMC sequence queue (Vyukov),
//! which is exactly the paper's acquire/release protocol generalized to
//! multiple producers/consumers — one-to-many (multicast) and many-to-one
//! (reduction) patterns use one queue per edge, as in the paper.

use crate::telemetry::{EdgeStats, QUEUE};
use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{fence, AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// A one-shot callback registered with [`RingQueue::park_on_item`] /
/// [`RingQueue::park_on_space`]: fired (exactly once) when the queue
/// becomes non-empty / non-full respectively, or when it closes.
/// Cooperative stage pumps use wakers to return their scheduler worker
/// to the pool instead of blocking it on an empty or full edge.
pub type Waker = Box<dyn FnOnce() + Send + 'static>;

/// Spin iterations before a *blocking* `push`/`pop` parks on the queue's
/// condvar (first a short `spin_loop` burst, then yields).
///
/// Blocking-path spin iterations are tallied process-wide in
/// [`crate::telemetry::QUEUE`]`.idle_spins` — the observability hook
/// behind the "an idle warm pipeline burns ~0 CPU" regression test
/// (`tests/idle_cpu.rs`). Cooperative pumps never spin here (they park
/// via wakers); only legacy blocking `push`/`pop` callers contribute.
const SPIN_LIMIT: u32 = 256;

/// Pad to a cache line to avoid false sharing (paper: "synchronization
/// variables are all padded to the size of a cache line").
#[repr(align(128))]
struct CachePadded<T>(T);

struct Slot<T> {
    /// Sequence number: `ticket` when free for the producer with that
    /// ticket, `ticket + 1` when filled for the consumer with that ticket.
    seq: CachePadded<AtomicUsize>,
    value: UnsafeCell<MaybeUninit<T>>,
}

/// Waker lists, guarded by one mutex (shared with both condvars).
struct Waiters {
    on_item: Vec<Waker>,
    on_space: Vec<Waker>,
}

/// Bounded multi-producer multi-consumer ring queue.
pub struct RingQueue<T> {
    slots: Box<[Slot<T>]>,
    mask: usize,
    /// Producer ticket counter (wr in Fig 4).
    tail: CachePadded<AtomicUsize>,
    /// Consumer ticket counter (rd in Fig 4).
    head: CachePadded<AtomicUsize>,
    closed: AtomicBool,
    /// Registered wakers (cooperative pumps) for each side.
    waiters: Mutex<Waiters>,
    /// Parked or registered waiters per side: condvar sleepers plus
    /// registered wakers. Producers/consumers check this on the fast
    /// path (after a SeqCst fence) and skip the lock when it is zero.
    item_waiters: AtomicUsize,
    space_waiters: AtomicUsize,
    /// Condvars for *blocking* `pop`/`push` callers, paired with
    /// `waiters`' mutex.
    item_cv: Condvar,
    space_cv: Condvar,
    /// Per-edge telemetry, attached once by the owning service (the
    /// queue is generic, so byte accounting stays with the producer —
    /// push/pop/stall counts are recorded here).
    stats: OnceLock<Arc<EdgeStats>>,
}

unsafe impl<T: Send> Send for RingQueue<T> {}
unsafe impl<T: Send> Sync for RingQueue<T> {}

/// Error returned by push operations. Both variants hand the rejected
/// value back to the producer — in particular, a closed queue returns
/// [`PushError::Closed`] rather than masquerading as full, so producers
/// can distinguish backpressure (retry) from shutdown (stop).
///
/// Memory-model caveat: `close()` is advisory, not a barrier. A push
/// that passed the closed-check *concurrently with* `close()` may still
/// land its value; a consumer that has already observed end-of-stream
/// will never pop it (the value is reclaimed by the queue's `Drop`, not
/// leaked). Orderly shutdown therefore closes from the producer side
/// after all pushes complete — exactly what the coordinator's countdown
/// latch does. Only pushes that *begin* after `close()` is observed are
/// guaranteed to return `Closed`.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// Queue full (producer would block); retry after a consumer pops.
    Full(T),
    /// Queue closed; this push did not (and will never) deliver.
    Closed(T),
}

impl<T> PushError<T> {
    /// Recover the value that could not be pushed.
    pub fn into_inner(self) -> T {
        match self {
            PushError::Full(v) | PushError::Closed(v) => v,
        }
    }
}

/// Error returned by non-blocking pops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PopError {
    /// Queue empty (consumer would block); data may still arrive.
    Empty,
    /// Queue closed *and* drained: end of stream.
    Closed,
}

impl<T> RingQueue<T> {
    /// Create a queue with `capacity` entries (rounded up to a power of
    /// two, min 2 — the paper's double-buffered queue is `capacity = 2`).
    pub fn with_capacity(capacity: usize) -> Arc<Self> {
        let cap = capacity.max(2).next_power_of_two();
        let slots: Box<[Slot<T>]> = (0..cap)
            .map(|i| Slot {
                seq: CachePadded(AtomicUsize::new(i)),
                value: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        Arc::new(RingQueue {
            slots,
            mask: cap - 1,
            tail: CachePadded(AtomicUsize::new(0)),
            head: CachePadded(AtomicUsize::new(0)),
            closed: AtomicBool::new(false),
            waiters: Mutex::new(Waiters { on_item: Vec::new(), on_space: Vec::new() }),
            item_waiters: AtomicUsize::new(0),
            space_waiters: AtomicUsize::new(0),
            item_cv: Condvar::new(),
            space_cv: Condvar::new(),
            stats: OnceLock::new(),
        })
    }

    /// Attach per-edge telemetry (first attach wins; later calls are
    /// ignored — a queue belongs to exactly one pipeline edge).
    pub fn attach_telemetry(&self, stats: Arc<EdgeStats>) {
        let _ = self.stats.set(stats);
    }

    /// The edge telemetry attached to this queue, if any. Producers use
    /// it to record payload bytes next to the queue's own push counts.
    pub fn telemetry(&self) -> Option<&Arc<EdgeStats>> {
        self.stats.get()
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Entries currently occupied (racy snapshot; exact when quiescent).
    pub fn len(&self) -> usize {
        let tail = self.tail.0.load(Ordering::Relaxed);
        let head = self.head.0.load(Ordering::Relaxed);
        tail.saturating_sub(head)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `wr_acquire` + write + `wr_release` as one non-blocking attempt.
    pub fn try_push(&self, value: T) -> Result<(), PushError<T>> {
        if self.closed.load(Ordering::Acquire) {
            return Err(PushError::Closed(value));
        }
        let mut ticket = self.tail.0.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[ticket & self.mask];
            let seq = slot.seq.0.load(Ordering::Acquire);
            if seq == ticket {
                // Entry free for this ticket: claim it.
                match self.tail.0.compare_exchange_weak(
                    ticket,
                    ticket + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        unsafe { (*slot.value.get()).write(value) };
                        // wr_release: publish to the consumer with ticket+1.
                        slot.seq.0.store(ticket + 1, Ordering::Release);
                        QUEUE.pushes.inc();
                        if let Some(s) = self.stats.get() {
                            s.pushes.inc();
                            s.sample_depth(self.len());
                        }
                        self.notify_item();
                        return Ok(());
                    }
                    Err(t) => ticket = t,
                }
            } else if seq < ticket {
                // Ring is full (consumer hasn't freed this entry yet).
                QUEUE.full_stalls.inc();
                if let Some(s) = self.stats.get() {
                    s.full_stalls.inc();
                }
                return Err(PushError::Full(value));
            } else {
                ticket = self.tail.0.load(Ordering::Relaxed);
            }
        }
    }

    /// `rd_acquire` + read + `rd_release` as one non-blocking attempt.
    pub fn try_pop(&self) -> Result<T, PopError> {
        let mut ticket = self.head.0.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[ticket & self.mask];
            let seq = slot.seq.0.load(Ordering::Acquire);
            let expected = ticket + 1;
            if seq == expected {
                match self.head.0.compare_exchange_weak(
                    ticket,
                    ticket + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        let value = unsafe { (*slot.value.get()).assume_init_read() };
                        // rd_release: free the entry for the producer one
                        // lap ahead.
                        slot.seq.0.store(ticket + self.mask + 1, Ordering::Release);
                        QUEUE.pops.inc();
                        if let Some(s) = self.stats.get() {
                            s.pops.inc();
                        }
                        self.notify_space();
                        return Ok(value);
                    }
                    Err(t) => ticket = t,
                }
            } else if seq < expected {
                return if self.closed.load(Ordering::Acquire) && self.is_empty() {
                    Err(PopError::Closed)
                } else {
                    QUEUE.empty_stalls.inc();
                    if let Some(s) = self.stats.get() {
                        s.empty_stalls.inc();
                    }
                    Err(PopError::Empty)
                };
            } else {
                ticket = self.head.0.load(Ordering::Relaxed);
            }
        }
    }

    /// Blocking push: spins briefly while the ring is full — mirroring
    /// the producer CTA spinning in `wr_acquire` — then *parks* on the
    /// queue's condvar until a consumer frees a slot (no sleep-tier
    /// spin burn). Returns [`PushError::Closed`] (with the value) once
    /// the queue is closed: the only error a blocking producer can
    /// observe.
    pub fn push(&self, mut value: T) -> Result<(), PushError<T>> {
        let mut spins = 0u32;
        loop {
            match self.try_push(value) {
                Ok(()) => return Ok(()),
                Err(PushError::Closed(v)) => return Err(PushError::Closed(v)),
                Err(PushError::Full(v)) => {
                    value = v;
                    if spins < SPIN_LIMIT {
                        spins += 1;
                        QUEUE.idle_spins.inc();
                        if spins < 64 {
                            std::hint::spin_loop();
                        } else {
                            std::thread::yield_now();
                        }
                    } else {
                        self.wait_space();
                    }
                }
            }
        }
    }

    /// Blocking pop: spins briefly, then parks until data arrives;
    /// returns `None` once the queue is closed *and* drained (pipeline
    /// shutdown).
    pub fn pop(&self) -> Option<T> {
        let mut spins = 0u32;
        loop {
            match self.try_pop() {
                Ok(v) => return Some(v),
                Err(PopError::Closed) => return None,
                Err(PopError::Empty) => {
                    if spins < SPIN_LIMIT {
                        spins += 1;
                        QUEUE.idle_spins.inc();
                        if spins < 64 {
                            std::hint::spin_loop();
                        } else {
                            std::thread::yield_now();
                        }
                    } else {
                        self.wait_item();
                    }
                }
            }
        }
    }

    /// Batched blocking dequeue: block for the *first* value, then
    /// greedily drain whatever else is already buffered — up to `max`
    /// values total — without re-entering the backoff path per value.
    /// Warm pipeline workers use this to drain bursts at one backoff
    /// cycle per burst instead of one per tile.
    ///
    /// Appends to `out` and returns the number appended; `0` means the
    /// queue is closed and drained (end of stream) or `max == 0`.
    pub fn pop_many(&self, out: &mut Vec<T>, max: usize) -> usize {
        if max == 0 {
            return 0;
        }
        let Some(first) = self.pop() else { return 0 };
        out.push(first);
        let mut n = 1;
        while n < max {
            match self.try_pop() {
                Ok(v) => {
                    out.push(v);
                    n += 1;
                }
                // Empty or closed: hand back the burst we have — the
                // next call blocks (or observes end-of-stream) normally.
                Err(_) => break,
            }
        }
        n
    }

    /// Non-blocking batched dequeue: drain up to `max` buffered values
    /// into `out` without ever waiting. Returns the number appended
    /// (possibly less than `max`); errors only when *nothing* could be
    /// popped — `Empty` (park and retry) or `Closed` (end of stream).
    pub fn try_pop_many(&self, out: &mut Vec<T>, max: usize) -> Result<usize, PopError> {
        let mut n = 0;
        while n < max {
            match self.try_pop() {
                Ok(v) => {
                    out.push(v);
                    n += 1;
                }
                Err(e) => {
                    if n == 0 {
                        return Err(e);
                    }
                    break;
                }
            }
        }
        Ok(n)
    }

    /// Register a one-shot waker fired when the queue becomes non-empty
    /// (or closes). If it is *already* non-empty or closed, the waker
    /// fires immediately on this thread. Exactly-once semantics: each
    /// registered waker is invoked once, by whichever of
    /// push/close/immediate-recheck gets there first.
    ///
    /// The consumer must observe `Empty` *before* registering; the SeqCst
    /// fence pairing with [`Self::notify_item`] guarantees that a push
    /// racing with registration is seen by at least one side (Dekker
    /// store-buffering argument), so no wakeup is lost.
    pub fn park_on_item(&self, waker: Waker) {
        let fire_now = {
            let mut g = self.waiters.lock().unwrap();
            self.item_waiters.fetch_add(1, Ordering::SeqCst);
            fence(Ordering::SeqCst);
            if !self.is_empty() || self.is_closed() {
                self.item_waiters.fetch_sub(1, Ordering::SeqCst);
                true
            } else {
                g.on_item.push(waker);
                return;
            }
        };
        if fire_now {
            waker();
        }
    }

    /// Register a one-shot waker fired when the queue has free space (or
    /// closes). Mirror of [`Self::park_on_item`] for producers.
    pub fn park_on_space(&self, waker: Waker) {
        let fire_now = {
            let mut g = self.waiters.lock().unwrap();
            self.space_waiters.fetch_add(1, Ordering::SeqCst);
            fence(Ordering::SeqCst);
            if self.len() < self.capacity() || self.is_closed() {
                self.space_waiters.fetch_sub(1, Ordering::SeqCst);
                true
            } else {
                g.on_space.push(waker);
                return;
            }
        };
        if fire_now {
            waker();
        }
    }

    /// Park the calling thread until the queue likely has space, the
    /// queue closes, or a short timeout elapses — a bounded wait for
    /// producers that must interleave a cancellation check (e.g. the
    /// training feeder polling the pipeline's dead flag) with
    /// backpressure. Never misses a wakeup (same fence protocol as
    /// [`Self::park_on_space`]); the timeout only bounds the recheck.
    pub fn wait_space(&self) {
        let t0 = Instant::now();
        let guard = self.waiters.lock().unwrap();
        self.space_waiters.fetch_add(1, Ordering::SeqCst);
        fence(Ordering::SeqCst);
        if self.len() >= self.capacity() && !self.is_closed() {
            let _ = self.space_cv.wait_timeout(guard, Duration::from_millis(20)).unwrap();
        }
        self.space_waiters.fetch_sub(1, Ordering::SeqCst);
        if let Some(s) = self.stats.get() {
            s.full_stall_ns.add(t0.elapsed().as_nanos().min(u128::from(u64::MAX)) as u64);
        }
    }

    /// Park the calling thread until the queue is likely non-empty, the
    /// queue closes, or a short timeout elapses. Consumer mirror of
    /// [`Self::wait_space`].
    pub fn wait_item(&self) {
        let t0 = Instant::now();
        let guard = self.waiters.lock().unwrap();
        self.item_waiters.fetch_add(1, Ordering::SeqCst);
        fence(Ordering::SeqCst);
        if self.is_empty() && !self.is_closed() {
            let _ = self.item_cv.wait_timeout(guard, Duration::from_millis(20)).unwrap();
        }
        self.item_waiters.fetch_sub(1, Ordering::SeqCst);
        if let Some(s) = self.stats.get() {
            s.empty_stall_ns.add(t0.elapsed().as_nanos().min(u128::from(u64::MAX)) as u64);
        }
    }

    /// Wake the item side: drain registered item wakers and signal
    /// parked blocking consumers. Fast path (no waiters) is a fence +
    /// one relaxed load.
    fn notify_item(&self) {
        fence(Ordering::SeqCst);
        if self.item_waiters.load(Ordering::Relaxed) == 0 {
            return;
        }
        let fired = {
            let mut g = self.waiters.lock().unwrap();
            let fired = std::mem::take(&mut g.on_item);
            self.item_waiters.fetch_sub(fired.len(), Ordering::SeqCst);
            self.item_cv.notify_all();
            fired
        };
        // Fire outside the lock: wakers reschedule pump tasks and must
        // not re-enter queue state under our waiter mutex.
        for w in fired {
            w();
        }
    }

    /// Wake the space side. Mirror of [`Self::notify_item`].
    fn notify_space(&self) {
        fence(Ordering::SeqCst);
        if self.space_waiters.load(Ordering::Relaxed) == 0 {
            return;
        }
        let fired = {
            let mut g = self.waiters.lock().unwrap();
            let fired = std::mem::take(&mut g.on_space);
            self.space_waiters.fetch_sub(fired.len(), Ordering::SeqCst);
            self.space_cv.notify_all();
            fired
        };
        for w in fired {
            w();
        }
    }

    /// Close the queue: subsequent producers fail, consumers drain then
    /// observe end. Fires every registered waker and wakes every parked
    /// thread, on both sides. See [`PushError`] for the
    /// concurrent-close caveat.
    pub fn close(&self) {
        // SeqCst store: `park_on_*` does W(waiter count) → fence →
        // R(closed) while close does W(closed) → fence (in notify_*) →
        // R(waiter count). Keeping the closed store in the SeqCst total
        // order makes the no-lost-wakeup Dekker argument hold on its
        // own, without leaning on the waiter-mutex ordering — a parker
        // that misses the flag is guaranteed to be seen (and fired) by
        // the notify pass, even when close races the registration.
        self.closed.store(true, Ordering::SeqCst);
        self.notify_item();
        self.notify_space();
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Relaxed)
    }
}

impl<T> Drop for RingQueue<T> {
    fn drop(&mut self) {
        // Drain any un-popped initialized values.
        let head = self.head.0.load(Ordering::Relaxed);
        let tail = self.tail.0.load(Ordering::Relaxed);
        for t in head..tail {
            let slot = &self.slots[t & self.mask];
            unsafe { (*slot.value.get()).assume_init_drop() };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn capacity_rounds_to_pow2_min2() {
        assert_eq!(RingQueue::<u32>::with_capacity(0).capacity(), 2);
        assert_eq!(RingQueue::<u32>::with_capacity(2).capacity(), 2);
        assert_eq!(RingQueue::<u32>::with_capacity(3).capacity(), 4);
        assert_eq!(RingQueue::<u32>::with_capacity(5).capacity(), 8);
    }

    #[test]
    fn spsc_fifo_order() {
        let q = RingQueue::with_capacity(4);
        let p = Arc::clone(&q);
        let producer = thread::spawn(move || {
            for i in 0..10_000u64 {
                p.push(i).unwrap();
            }
            p.close();
        });
        let mut expect = 0u64;
        while let Some(v) = q.pop() {
            assert_eq!(v, expect);
            expect += 1;
        }
        assert_eq!(expect, 10_000);
        producer.join().unwrap();
    }

    #[test]
    fn bounded_never_exceeds_capacity() {
        let q = RingQueue::with_capacity(2);
        q.try_push(1u32).unwrap();
        q.try_push(2).unwrap();
        assert!(matches!(q.try_push(3), Err(PushError::Full(3))));
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop().unwrap(), 1);
        q.try_push(3).unwrap();
        assert!(matches!(q.try_push(4), Err(PushError::Full(4))));
    }

    #[test]
    fn mpmc_conserves_tokens() {
        // 4 producers x 4 consumers, checksum conservation — the paper's
        // many-to-one reduction pattern at the protocol level.
        let q: Arc<RingQueue<u64>> = RingQueue::with_capacity(8);
        let n_per = 25_000u64;
        let mut producers = Vec::new();
        for p in 0..4u64 {
            let q = Arc::clone(&q);
            producers.push(thread::spawn(move || {
                for i in 0..n_per {
                    q.push(p * n_per + i).unwrap();
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..4 {
            let q = Arc::clone(&q);
            consumers.push(thread::spawn(move || {
                let mut sum = 0u64;
                let mut count = 0u64;
                while let Some(v) = q.pop() {
                    sum += v;
                    count += 1;
                }
                (sum, count)
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let (mut sum, mut count) = (0u64, 0u64);
        for c in consumers {
            let (s, n) = c.join().unwrap();
            sum += s;
            count += n;
        }
        let total = 4 * n_per;
        assert_eq!(count, total);
        assert_eq!(sum, total * (total - 1) / 2);
    }

    #[test]
    fn close_drains_then_ends() {
        let q = RingQueue::with_capacity(4);
        q.push(1u32).unwrap();
        q.push(2).unwrap();
        q.close();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        // Closed — not Full — and the value comes back to the producer.
        assert!(matches!(q.try_push(9), Err(PushError::Closed(9))));
        assert!(matches!(q.push(9), Err(PushError::Closed(9))), "push after close fails");
    }

    #[test]
    fn close_while_full_signals_closed_not_full() {
        // A queue that is BOTH full and closed must report Closed to
        // producers (shutdown wins over backpressure), while consumers
        // still drain the buffered entries before seeing end-of-stream.
        let q = RingQueue::with_capacity(2);
        q.try_push(1u32).unwrap();
        q.try_push(2).unwrap();
        assert!(matches!(q.try_push(3), Err(PushError::Full(3))), "full before close");
        q.close();
        assert!(matches!(q.try_push(3), Err(PushError::Closed(3))), "closed after close");
        assert_eq!(q.try_pop().unwrap(), 1);
        assert_eq!(q.try_pop().unwrap(), 2);
        assert_eq!(q.try_pop(), Err(PopError::Closed));
    }

    #[test]
    fn pop_many_drains_bursts_in_order() {
        let q = RingQueue::with_capacity(8);
        for i in 0..5u32 {
            q.push(i).unwrap();
        }
        let mut out = Vec::new();
        // Bounded by max…
        assert_eq!(q.pop_many(&mut out, 3), 3);
        assert_eq!(out, vec![0, 1, 2]);
        // …then by what's buffered.
        assert_eq!(q.pop_many(&mut out, 10), 2);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
        // max == 0 never blocks.
        assert_eq!(q.pop_many(&mut out, 0), 0);
        // Closed + drained = end of stream.
        q.push(9).unwrap();
        q.close();
        let mut tail = Vec::new();
        assert_eq!(q.pop_many(&mut tail, 4), 1);
        assert_eq!(tail, vec![9]);
        assert_eq!(q.pop_many(&mut tail, 4), 0);
    }

    #[test]
    fn drop_releases_unpopped_values() {
        // Arc payloads: if Drop leaked, the strong count would stay high.
        let token = Arc::new(());
        {
            let q = RingQueue::with_capacity(4);
            q.push(Arc::clone(&token)).unwrap();
            q.push(Arc::clone(&token)).unwrap();
            assert_eq!(Arc::strong_count(&token), 3);
        }
        assert_eq!(Arc::strong_count(&token), 1);
    }

    #[test]
    fn try_pop_many_never_blocks() {
        let q: Arc<RingQueue<u32>> = RingQueue::with_capacity(8);
        let mut out = Vec::new();
        assert_eq!(q.try_pop_many(&mut out, 4), Err(PopError::Empty));
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        assert_eq!(q.try_pop_many(&mut out, 3), Ok(3));
        assert_eq!(out, vec![0, 1, 2]);
        assert_eq!(q.try_pop_many(&mut out, 10), Ok(2));
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
        q.close();
        assert_eq!(q.try_pop_many(&mut out, 4), Err(PopError::Closed));
    }

    #[test]
    fn item_waker_fires_on_push_or_immediately() {
        let q: Arc<RingQueue<u32>> = RingQueue::with_capacity(4);
        let fired = Arc::new(AtomicUsize::new(0));
        // Empty queue: waker is deferred until the next push.
        let f = Arc::clone(&fired);
        q.park_on_item(Box::new(move || {
            f.fetch_add(1, Ordering::SeqCst);
        }));
        assert_eq!(fired.load(Ordering::SeqCst), 0, "no data yet");
        q.try_push(7).unwrap();
        assert_eq!(fired.load(Ordering::SeqCst), 1, "push fires the waker");
        // Non-empty queue: waker fires immediately at registration.
        let f = Arc::clone(&fired);
        q.park_on_item(Box::new(move || {
            f.fetch_add(1, Ordering::SeqCst);
        }));
        assert_eq!(fired.load(Ordering::SeqCst), 2);
        // Exactly once: a second push does not re-fire consumed wakers.
        q.try_push(8).unwrap();
        assert_eq!(fired.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn space_waker_fires_on_pop_and_close_fires_all() {
        let q: Arc<RingQueue<u32>> = RingQueue::with_capacity(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        let fired = Arc::new(AtomicUsize::new(0));
        let f = Arc::clone(&fired);
        q.park_on_space(Box::new(move || {
            f.fetch_add(1, Ordering::SeqCst);
        }));
        assert_eq!(fired.load(Ordering::SeqCst), 0, "queue full, waker parked");
        assert_eq!(q.try_pop().unwrap(), 1);
        assert_eq!(fired.load(Ordering::SeqCst), 1, "pop fires the space waker");
        // Refill, park both sides, then close: everything fires.
        q.try_push(3).unwrap();
        let f1 = Arc::clone(&fired);
        q.park_on_space(Box::new(move || {
            f1.fetch_add(1, Ordering::SeqCst);
        }));
        let q2: Arc<RingQueue<u32>> = RingQueue::with_capacity(2);
        let f2 = Arc::clone(&fired);
        q2.park_on_item(Box::new(move || {
            f2.fetch_add(1, Ordering::SeqCst);
        }));
        q.close();
        q2.close();
        assert_eq!(fired.load(Ordering::SeqCst), 3, "close fires parked wakers");
    }

    #[test]
    fn parked_blocking_pop_wakes_on_push() {
        let q: Arc<RingQueue<u64>> = RingQueue::with_capacity(4);
        let c = Arc::clone(&q);
        let consumer = thread::spawn(move || c.pop());
        // Give the consumer time to spin down and park on the condvar.
        thread::sleep(Duration::from_millis(30));
        q.push(42).unwrap();
        assert_eq!(consumer.join().unwrap(), Some(42));
    }

    /// Mini property test (no proptest offline): randomized interleavings
    /// driven by a deterministic xorshift RNG.
    #[test]
    fn randomized_spsc_interleavings() {
        let mut seed = 0x2545F4914F6CDD1Du64;
        let mut rng = move || {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            seed
        };
        for trial in 0..50 {
            let cap = 2 + (rng() % 7) as usize;
            let n = 100 + (rng() % 400) as usize;
            let q: Arc<RingQueue<usize>> = RingQueue::with_capacity(cap);
            let p = Arc::clone(&q);
            let producer = thread::spawn(move || {
                for i in 0..n {
                    p.push(i).unwrap();
                }
                p.close();
            });
            let mut got = Vec::new();
            while let Some(v) = q.pop() {
                got.push(v);
            }
            producer.join().unwrap();
            assert_eq!(got, (0..n).collect::<Vec<_>>(), "trial {trial}");
        }
    }
}
