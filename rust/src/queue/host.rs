//! A real implementation of the paper's §4.1 synchronized ring queue.
//!
//! This is the same algorithm as Fig 4 — a bounded ring of entries, each
//! carrying a sequence number updated with atomic operations; producers
//! and consumers `acquire` an entry by spinning until its sequence matches
//! their ticket, then `release` it by bumping the sequence — implemented
//! for host CPUs (the coordinator's spatial-pipeline runtime uses it to
//! connect stage threads). On the GPU the sequence metadata lives in
//! L2-pinned cache lines; here each slot's sequence word is padded to a
//! cache line for the same false-sharing reason the paper pads its
//! synchronization variables.
//!
//! The algorithm is the classic bounded MPMC sequence queue (Vyukov),
//! which is exactly the paper's acquire/release protocol generalized to
//! multiple producers/consumers — one-to-many (multicast) and many-to-one
//! (reduction) patterns use one queue per edge, as in the paper.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// Pad to a cache line to avoid false sharing (paper: "synchronization
/// variables are all padded to the size of a cache line").
#[repr(align(128))]
struct CachePadded<T>(T);

struct Slot<T> {
    /// Sequence number: `ticket` when free for the producer with that
    /// ticket, `ticket + 1` when filled for the consumer with that ticket.
    seq: CachePadded<AtomicUsize>,
    value: UnsafeCell<MaybeUninit<T>>,
}

/// Bounded multi-producer multi-consumer ring queue.
pub struct RingQueue<T> {
    slots: Box<[Slot<T>]>,
    mask: usize,
    /// Producer ticket counter (wr in Fig 4).
    tail: CachePadded<AtomicUsize>,
    /// Consumer ticket counter (rd in Fig 4).
    head: CachePadded<AtomicUsize>,
    closed: AtomicBool,
}

unsafe impl<T: Send> Send for RingQueue<T> {}
unsafe impl<T: Send> Sync for RingQueue<T> {}

/// Error returned by push operations. Both variants hand the rejected
/// value back to the producer — in particular, a closed queue returns
/// [`PushError::Closed`] rather than masquerading as full, so producers
/// can distinguish backpressure (retry) from shutdown (stop).
///
/// Memory-model caveat: `close()` is advisory, not a barrier. A push
/// that passed the closed-check *concurrently with* `close()` may still
/// land its value; a consumer that has already observed end-of-stream
/// will never pop it (the value is reclaimed by the queue's `Drop`, not
/// leaked). Orderly shutdown therefore closes from the producer side
/// after all pushes complete — exactly what the coordinator's countdown
/// latch does. Only pushes that *begin* after `close()` is observed are
/// guaranteed to return `Closed`.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// Queue full (producer would block); retry after a consumer pops.
    Full(T),
    /// Queue closed; this push did not (and will never) deliver.
    Closed(T),
}

impl<T> PushError<T> {
    /// Recover the value that could not be pushed.
    pub fn into_inner(self) -> T {
        match self {
            PushError::Full(v) | PushError::Closed(v) => v,
        }
    }
}

/// Error returned by non-blocking pops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PopError {
    /// Queue empty (consumer would block); data may still arrive.
    Empty,
    /// Queue closed *and* drained: end of stream.
    Closed,
}

impl<T> RingQueue<T> {
    /// Create a queue with `capacity` entries (rounded up to a power of
    /// two, min 2 — the paper's double-buffered queue is `capacity = 2`).
    pub fn with_capacity(capacity: usize) -> Arc<Self> {
        let cap = capacity.max(2).next_power_of_two();
        let slots: Box<[Slot<T>]> = (0..cap)
            .map(|i| Slot {
                seq: CachePadded(AtomicUsize::new(i)),
                value: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        Arc::new(RingQueue {
            slots,
            mask: cap - 1,
            tail: CachePadded(AtomicUsize::new(0)),
            head: CachePadded(AtomicUsize::new(0)),
            closed: AtomicBool::new(false),
        })
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Entries currently occupied (racy snapshot; exact when quiescent).
    pub fn len(&self) -> usize {
        let tail = self.tail.0.load(Ordering::Relaxed);
        let head = self.head.0.load(Ordering::Relaxed);
        tail.saturating_sub(head)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `wr_acquire` + write + `wr_release` as one non-blocking attempt.
    pub fn try_push(&self, value: T) -> Result<(), PushError<T>> {
        if self.closed.load(Ordering::Acquire) {
            return Err(PushError::Closed(value));
        }
        let mut ticket = self.tail.0.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[ticket & self.mask];
            let seq = slot.seq.0.load(Ordering::Acquire);
            if seq == ticket {
                // Entry free for this ticket: claim it.
                match self.tail.0.compare_exchange_weak(
                    ticket,
                    ticket + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        unsafe { (*slot.value.get()).write(value) };
                        // wr_release: publish to the consumer with ticket+1.
                        slot.seq.0.store(ticket + 1, Ordering::Release);
                        return Ok(());
                    }
                    Err(t) => ticket = t,
                }
            } else if seq < ticket {
                // Ring is full (consumer hasn't freed this entry yet).
                return Err(PushError::Full(value));
            } else {
                ticket = self.tail.0.load(Ordering::Relaxed);
            }
        }
    }

    /// `rd_acquire` + read + `rd_release` as one non-blocking attempt.
    pub fn try_pop(&self) -> Result<T, PopError> {
        let mut ticket = self.head.0.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[ticket & self.mask];
            let seq = slot.seq.0.load(Ordering::Acquire);
            let expected = ticket + 1;
            if seq == expected {
                match self.head.0.compare_exchange_weak(
                    ticket,
                    ticket + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        let value = unsafe { (*slot.value.get()).assume_init_read() };
                        // rd_release: free the entry for the producer one
                        // lap ahead.
                        slot.seq.0.store(ticket + self.mask + 1, Ordering::Release);
                        return Ok(value);
                    }
                    Err(t) => ticket = t,
                }
            } else if seq < expected {
                return if self.closed.load(Ordering::Acquire) && self.is_empty() {
                    Err(PopError::Closed)
                } else {
                    Err(PopError::Empty)
                };
            } else {
                ticket = self.head.0.load(Ordering::Relaxed);
            }
        }
    }

    /// Blocking push: spins (with yields) while the ring is full —
    /// mirrors the producer CTA spinning in `wr_acquire`. Returns
    /// [`PushError::Closed`] (with the value) once the queue is closed:
    /// the only error a blocking producer can observe.
    pub fn push(&self, mut value: T) -> Result<(), PushError<T>> {
        let mut spins = 0u32;
        loop {
            match self.try_push(value) {
                Ok(()) => return Ok(()),
                Err(PushError::Closed(v)) => return Err(PushError::Closed(v)),
                Err(PushError::Full(v)) => {
                    value = v;
                    backoff(&mut spins);
                }
            }
        }
    }

    /// Blocking pop: spins until data arrives; returns `None` once the
    /// queue is closed *and* drained (pipeline shutdown).
    pub fn pop(&self) -> Option<T> {
        let mut spins = 0u32;
        loop {
            match self.try_pop() {
                Ok(v) => return Some(v),
                Err(PopError::Closed) => return None,
                Err(PopError::Empty) => backoff(&mut spins),
            }
        }
    }

    /// Batched blocking dequeue: block for the *first* value, then
    /// greedily drain whatever else is already buffered — up to `max`
    /// values total — without re-entering the backoff path per value.
    /// Warm pipeline workers use this to drain bursts at one backoff
    /// cycle per burst instead of one per tile.
    ///
    /// Appends to `out` and returns the number appended; `0` means the
    /// queue is closed and drained (end of stream) or `max == 0`.
    pub fn pop_many(&self, out: &mut Vec<T>, max: usize) -> usize {
        if max == 0 {
            return 0;
        }
        let Some(first) = self.pop() else { return 0 };
        out.push(first);
        let mut n = 1;
        while n < max {
            match self.try_pop() {
                Ok(v) => {
                    out.push(v);
                    n += 1;
                }
                // Empty or closed: hand back the burst we have — the
                // next call blocks (or observes end-of-stream) normally.
                Err(_) => break,
            }
        }
        n
    }

    /// Close the queue: subsequent producers fail, consumers drain then
    /// observe end. See [`PushError`] for the concurrent-close caveat.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Relaxed)
    }
}

impl<T> Drop for RingQueue<T> {
    fn drop(&mut self) {
        // Drain any un-popped initialized values.
        let head = self.head.0.load(Ordering::Relaxed);
        let tail = self.tail.0.load(Ordering::Relaxed);
        for t in head..tail {
            let slot = &self.slots[t & self.mask];
            unsafe { (*slot.value.get()).assume_init_drop() };
        }
    }
}

fn backoff(spins: &mut u32) {
    *spins += 1;
    if *spins < 64 {
        std::hint::spin_loop();
    } else if *spins < 4096 {
        std::thread::yield_now();
    } else {
        // Long-idle tier: a persistent session's warm worker pool parks
        // here between batches instead of burning a core per worker. The
        // 50µs nap is noise next to a stage kernel but caps idle CPU.
        std::thread::sleep(std::time::Duration::from_micros(50));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn capacity_rounds_to_pow2_min2() {
        assert_eq!(RingQueue::<u32>::with_capacity(0).capacity(), 2);
        assert_eq!(RingQueue::<u32>::with_capacity(2).capacity(), 2);
        assert_eq!(RingQueue::<u32>::with_capacity(3).capacity(), 4);
        assert_eq!(RingQueue::<u32>::with_capacity(5).capacity(), 8);
    }

    #[test]
    fn spsc_fifo_order() {
        let q = RingQueue::with_capacity(4);
        let p = Arc::clone(&q);
        let producer = thread::spawn(move || {
            for i in 0..10_000u64 {
                p.push(i).unwrap();
            }
            p.close();
        });
        let mut expect = 0u64;
        while let Some(v) = q.pop() {
            assert_eq!(v, expect);
            expect += 1;
        }
        assert_eq!(expect, 10_000);
        producer.join().unwrap();
    }

    #[test]
    fn bounded_never_exceeds_capacity() {
        let q = RingQueue::with_capacity(2);
        q.try_push(1u32).unwrap();
        q.try_push(2).unwrap();
        assert!(matches!(q.try_push(3), Err(PushError::Full(3))));
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop().unwrap(), 1);
        q.try_push(3).unwrap();
        assert!(matches!(q.try_push(4), Err(PushError::Full(4))));
    }

    #[test]
    fn mpmc_conserves_tokens() {
        // 4 producers x 4 consumers, checksum conservation — the paper's
        // many-to-one reduction pattern at the protocol level.
        let q: Arc<RingQueue<u64>> = RingQueue::with_capacity(8);
        let n_per = 25_000u64;
        let mut producers = Vec::new();
        for p in 0..4u64 {
            let q = Arc::clone(&q);
            producers.push(thread::spawn(move || {
                for i in 0..n_per {
                    q.push(p * n_per + i).unwrap();
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..4 {
            let q = Arc::clone(&q);
            consumers.push(thread::spawn(move || {
                let mut sum = 0u64;
                let mut count = 0u64;
                while let Some(v) = q.pop() {
                    sum += v;
                    count += 1;
                }
                (sum, count)
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let (mut sum, mut count) = (0u64, 0u64);
        for c in consumers {
            let (s, n) = c.join().unwrap();
            sum += s;
            count += n;
        }
        let total = 4 * n_per;
        assert_eq!(count, total);
        assert_eq!(sum, total * (total - 1) / 2);
    }

    #[test]
    fn close_drains_then_ends() {
        let q = RingQueue::with_capacity(4);
        q.push(1u32).unwrap();
        q.push(2).unwrap();
        q.close();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        // Closed — not Full — and the value comes back to the producer.
        assert!(matches!(q.try_push(9), Err(PushError::Closed(9))));
        assert!(matches!(q.push(9), Err(PushError::Closed(9))), "push after close fails");
    }

    #[test]
    fn close_while_full_signals_closed_not_full() {
        // A queue that is BOTH full and closed must report Closed to
        // producers (shutdown wins over backpressure), while consumers
        // still drain the buffered entries before seeing end-of-stream.
        let q = RingQueue::with_capacity(2);
        q.try_push(1u32).unwrap();
        q.try_push(2).unwrap();
        assert!(matches!(q.try_push(3), Err(PushError::Full(3))), "full before close");
        q.close();
        assert!(matches!(q.try_push(3), Err(PushError::Closed(3))), "closed after close");
        assert_eq!(q.try_pop().unwrap(), 1);
        assert_eq!(q.try_pop().unwrap(), 2);
        assert_eq!(q.try_pop(), Err(PopError::Closed));
    }

    #[test]
    fn pop_many_drains_bursts_in_order() {
        let q = RingQueue::with_capacity(8);
        for i in 0..5u32 {
            q.push(i).unwrap();
        }
        let mut out = Vec::new();
        // Bounded by max…
        assert_eq!(q.pop_many(&mut out, 3), 3);
        assert_eq!(out, vec![0, 1, 2]);
        // …then by what's buffered.
        assert_eq!(q.pop_many(&mut out, 10), 2);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
        // max == 0 never blocks.
        assert_eq!(q.pop_many(&mut out, 0), 0);
        // Closed + drained = end of stream.
        q.push(9).unwrap();
        q.close();
        let mut tail = Vec::new();
        assert_eq!(q.pop_many(&mut tail, 4), 1);
        assert_eq!(tail, vec![9]);
        assert_eq!(q.pop_many(&mut tail, 4), 0);
    }

    #[test]
    fn drop_releases_unpopped_values() {
        // Arc payloads: if Drop leaked, the strong count would stay high.
        let token = Arc::new(());
        {
            let q = RingQueue::with_capacity(4);
            q.push(Arc::clone(&token)).unwrap();
            q.push(Arc::clone(&token)).unwrap();
            assert_eq!(Arc::strong_count(&token), 3);
        }
        assert_eq!(Arc::strong_count(&token), 1);
    }

    /// Mini property test (no proptest offline): randomized interleavings
    /// driven by a deterministic xorshift RNG.
    #[test]
    fn randomized_spsc_interleavings() {
        let mut seed = 0x2545F4914F6CDD1Du64;
        let mut rng = move || {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            seed
        };
        for trial in 0..50 {
            let cap = 2 + (rng() % 7) as usize;
            let n = 100 + (rng() % 400) as usize;
            let q: Arc<RingQueue<usize>> = RingQueue::with_capacity(cap);
            let p = Arc::clone(&q);
            let producer = thread::spawn(move || {
                for i in 0..n {
                    p.push(i).unwrap();
                }
                p.close();
            });
            let mut got = Vec::new();
            while let Some(v) = q.pop() {
                got.push(v);
            }
            producer.join().unwrap();
            assert_eq!(got, (0..n).collect::<Vec<_>>(), "trial {trial}");
        }
    }
}
