//! Analytic performance model of the §4.1 ring queue — regenerates Fig 5
//! and the section's headline numbers.
//!
//! Calibration constants come straight from the paper's silicon
//! measurements on A100:
//!
//! * 100 M global atomics / sec / CTA under no contention;
//! * acquire/release = 4 atomics per side per entry handoff (sequence
//!   check + metadata update, Fig 4(c)), plus an L2 round trip for the
//!   spin-loop to observe the released entry;
//! * payload moves through the L2: one write + one read per byte, so the
//!   aggregate payload pool is ≈ L2_bw / 2 ≈ 2 TB/s on A100 — exactly the
//!   plateau Fig 5 shows for 128–256 KB payloads;
//! * when the aggregate queue footprint exceeds L2 capacity, traffic
//!   spills to HBM and the pool drops to DRAM bandwidth (1.5 TB/s) — the
//!   fall-off Fig 5 shows past 256 KB.

use crate::sim::GpuConfig;

/// Atomic operations per entry handoff, per side (Fig 4(c): sequence
/// check, head/tail bump, release add, plus the CTA-barrier flag).
pub const ATOMICS_PER_HANDOFF: f64 = 4.0;

/// Double buffering (two entries) as in paper Fig 4(a).
pub const DEFAULT_ENTRIES: usize = 2;

/// Result of evaluating the model at one (payload, n_queues) point.
#[derive(Debug, Clone, Copy)]
pub struct QueuePoint {
    pub payload_bytes: usize,
    pub n_queues: usize,
    /// Per-queue sustained bandwidth, bytes/s.
    pub per_queue_bw: f64,
    /// Aggregate across all queues, bytes/s.
    pub aggregate_bw: f64,
    /// Whether the queue set spilled out of L2 to HBM.
    pub spills_to_hbm: bool,
    /// Seconds per entry handoff spent on synchronization.
    pub sync_time_s: f64,
}

/// Analytic model over a machine config.
#[derive(Debug, Clone)]
pub struct QueueModel {
    pub cfg: GpuConfig,
    pub entries: usize,
}

impl QueueModel {
    pub fn new(cfg: GpuConfig) -> Self {
        QueueModel { cfg, entries: DEFAULT_ENTRIES }
    }

    /// Synchronization time per entry handoff: producer + consumer atomics
    /// (serialized on the metadata line) plus the consumer's spin-loop L2
    /// observation latency.
    pub fn sync_time(&self) -> f64 {
        2.0 * ATOMICS_PER_HANDOFF / self.cfg.atomics_per_sec_per_cta + self.cfg.l2_latency_s
    }

    /// The §4.1 "upper bound per queue" from atomics throughput alone:
    /// `payload * atomics_rate / atomics_per_handoff`. For 16–64 KB
    /// payloads on A100 this is the paper's 385–1541 GB/s band.
    pub fn atomics_bound(&self, payload_bytes: usize) -> f64 {
        payload_bytes as f64 * self.cfg.atomics_per_sec_per_cta / ATOMICS_PER_HANDOFF
    }

    /// Aggregate L2 payload pool: each payload byte is written then read.
    fn l2_pool(&self) -> f64 {
        self.cfg.l2_bw / 2.0
    }

    /// Do `n_queues` queues of `payload` fit in L2 alongside ~25% of L2
    /// reserved for normal caching?
    pub fn fits_l2(&self, payload_bytes: usize, n_queues: usize) -> bool {
        let footprint = n_queues * self.entries * (payload_bytes + 4 * 128);
        footprint as f64 <= 0.75 * self.cfg.l2_capacity as f64
    }

    /// Evaluate the model. `sync=false` measures raw data movement with
    /// synchronizing atomics disabled (Fig 5's upper series).
    pub fn evaluate(&self, payload_bytes: usize, n_queues: usize, sync: bool) -> QueuePoint {
        let spills = !self.fits_l2(payload_bytes, n_queues);
        // Payload pool: L2-resident queues copy at the L2 pool rate; spilled
        // queues are limited by DRAM bandwidth (round trip).
        let pool = if spills { self.cfg.dram_bw } else { self.l2_pool() };
        let fair_share = pool / n_queues as f64;
        let data_time = payload_bytes as f64 / fair_share;
        let sync_time = if sync { self.sync_time() } else { 0.0 };
        // Spilled accesses also eat the HBM round-trip latency per entry.
        let spill_lat = if spills { self.cfg.dram_latency_s } else { 0.0 };
        let handoff = data_time + sync_time + spill_lat;
        let mut per_queue = payload_bytes as f64 / handoff;
        if sync {
            per_queue = per_queue.min(self.atomics_bound(payload_bytes));
        }
        QueuePoint {
            payload_bytes,
            n_queues,
            per_queue_bw: per_queue,
            aggregate_bw: per_queue * n_queues as f64,
            spills_to_hbm: spills,
            sync_time_s: sync_time,
        }
    }

    /// The Fig 5 sweep: payload sizes at the paper's 54-queue operating
    /// point (108 CTAs on 108 SMs), sync on and off.
    pub fn fig5_sweep(&self, n_queues: usize) -> Vec<(QueuePoint, QueuePoint)> {
        let payloads = [
            1usize << 10,
            1 << 11,
            1 << 12,
            1 << 13,
            1 << 14,
            1 << 15,
            1 << 16,
            1 << 17,
            1 << 18,
            1 << 19,
            1 << 20,
        ];
        payloads
            .iter()
            .map(|&p| (self.evaluate(p, n_queues, true), self.evaluate(p, n_queues, false)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> QueueModel {
        QueueModel::new(GpuConfig::a100())
    }

    #[test]
    fn atomics_bound_matches_paper_band() {
        // Paper §4.1: "upper bound of 385-1541 GB/s per queue".
        let m = model();
        let lo = m.atomics_bound(16 * 1024);
        let hi = m.atomics_bound(64 * 1024);
        assert!((lo / 1e9 - 385.0).abs() / 385.0 < 0.1, "{}", lo / 1e9);
        assert!((hi / 1e9 - 1541.0).abs() / 1541.0 < 0.1, "{}", hi / 1e9);
    }

    #[test]
    fn aggregate_plateau_near_2tbs_at_128_256kb() {
        // Paper: "with 128-256 KB payloads, aggregate bandwidth reaches
        // 2 TB/s (37 GB/s/queue)".
        let m = model();
        let p = m.evaluate(128 * 1024, 54, true);
        assert!(!p.spills_to_hbm);
        assert!(p.aggregate_bw > 1.6e12 && p.aggregate_bw < 2.4e12, "{}", p.aggregate_bw);
        assert!(p.per_queue_bw > 30e9 && p.per_queue_bw < 45e9, "{}", p.per_queue_bw);
    }

    #[test]
    fn spills_past_256kb_and_drops() {
        // Paper: "Beyond 256 KB, performance drops due to queue sizes
        // reaching the L2 capacity ... Limiting us to 1.5 TB/s".
        let m = model();
        let in_l2 = m.evaluate(256 * 1024, 54, true);
        let spilled = m.evaluate(512 * 1024, 54, true);
        assert!(!in_l2.spills_to_hbm);
        assert!(spilled.spills_to_hbm);
        assert!(spilled.aggregate_bw < in_l2.aggregate_bw);
        assert!(spilled.aggregate_bw <= 1.56e12);
    }

    #[test]
    fn sync_overhead_large_small_payloads() {
        // Paper: "12x reduction in bandwidth for 1KB payloads" and
        // "less than 63% for >= 64KB payloads".
        let m = model();
        let sync = m.evaluate(1024, 54, true);
        let nosync = m.evaluate(1024, 54, false);
        let ratio = nosync.per_queue_bw / sync.per_queue_bw;
        assert!(ratio > 6.0 && ratio < 20.0, "small-payload overhead ratio {ratio}");
        let sync64 = m.evaluate(64 * 1024, 54, true);
        let nosync64 = m.evaluate(64 * 1024, 54, false);
        let overhead = nosync64.per_queue_bw / sync64.per_queue_bw - 1.0;
        assert!(overhead < 0.63, "64KB overhead {overhead}");
    }

    #[test]
    fn sweep_is_monotone_until_spill() {
        let m = model();
        let sweep = m.fig5_sweep(54);
        // Aggregate with sync rises with payload until the spill point.
        let agg: Vec<f64> = sweep.iter().map(|(s, _)| s.aggregate_bw).collect();
        let spill_idx = sweep.iter().position(|(s, _)| s.spills_to_hbm).unwrap();
        for i in 1..spill_idx {
            assert!(agg[i] >= agg[i - 1], "non-monotone before spill at {i}");
        }
        assert!(agg[spill_idx] < agg[spill_idx - 1], "no drop at spill");
    }
}
