//! Lowering autodiff training graphs onto the coordinator's DAG
//! pipeline: forward, backward, and loss nodes become stages connected
//! by explicit [`PipeEdge`]s — including the two shapes the linear
//! session lowering rejects and the paper's training evaluation lives
//! on (§6.4, Figs 12/14):
//!
//! * **multicast fan-out** (Fig 2(c)) — a saved activation feeds its
//!   forward consumer *and* the paired gradient GEMMs, so one producer
//!   port drives several queues;
//! * **skip links** (Fig 2(b) pipelines) — a forward value bypasses
//!   every intermediate stage straight to its backward consumer
//!   (weight-gradient GEMMs contract a stage-0 activation against a
//!   late-stage gradient).
//!
//! The unit of streaming is a row tile: every graph input, the training
//! target, and every intermediate streams `[tile_rows, d]` slices.
//! Per-tile parameter gradients leave the pipeline through sink taps and
//! are averaged across the microbatch *in tile order*
//! ([`crate::train::accumulate`]), so a serial re-execution of the same
//! stage programs reproduces the pipeline's gradients bitwise.
//!
//! Graphs whose live training region contains ops without streaming
//! kernels (gathers/scatters, batched attention matmuls, softmax /
//! layernorm backward) produce a typed
//! [`SessionError::NotStreamable`](crate::session::SessionError) whose
//! reason names the concrete node and op — those apps keep
//! `Session::simulate()`.

use crate::coordinator::{PipeEdge, SpatialPipeline, StageSpec};
use crate::graph::{EwKind, Graph, NodeId, OpKind, ReduceAxis, ResourceClass};
use crate::runtime::interp::{Act, Instr, Program, Reg};
use crate::runtime::{Precision, Rng, Tensor};
use crate::session::lower::{fuse_program, not_streamable, LowerOptions};
use crate::Result;
use std::collections::{HashMap, HashSet};
use std::sync::Arc;

/// One lowered pipeline stage: a synthesized SSA program whose inputs
/// are `n_stream` streamed ports followed by the stage's parameters
/// (resolved through [`TrainPlan::params`] via `param_idx`).
#[derive(Debug, Clone)]
pub struct StagePlan {
    pub name: String,
    pub program: Program,
    /// Streamed input ports (program inputs `0..n_stream`).
    pub n_stream: usize,
    /// Global parameter indices bound as program inputs `n_stream..`.
    pub param_idx: Vec<usize>,
}

/// A named learnable parameter with its deterministic initial value.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub init: Tensor,
}

/// One streamed pipeline source: a graph input, or the synthesized
/// training target (always the last source). Dims are full-batch;
/// the trainer slices `[tile_rows, d]` row tiles from them.
#[derive(Debug, Clone)]
pub struct SourceSpec {
    pub name: String,
    pub dims: Vec<usize>,
}

/// What a sink tap carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TapKind {
    /// Per-tile MSE loss (scalar).
    Loss,
    /// Per-tile gradient of `params[param]`.
    Grad { param: usize },
}

/// One sink tap of the training pipeline.
#[derive(Debug, Clone)]
pub struct TapSpec {
    pub name: String,
    pub kind: TapKind,
}

/// A training graph lowered to runnable DAG-pipeline form.
#[derive(Debug, Clone)]
pub struct TrainPlan {
    /// The coordinator pipeline: stage specs plus explicit DAG queue
    /// edges (multicast fan-out, skip links, source/sink edges).
    pub pipeline: SpatialPipeline,
    /// Per-stage synthesized programs, parallel to `pipeline.stages`.
    pub stages: Vec<StagePlan>,
    /// Named parameters in deterministic first-use (stage) order.
    pub params: Vec<ParamSpec>,
    /// Streamed sources (graph inputs ++ target).
    pub sources: Vec<SourceSpec>,
    /// Sink taps: `taps[0]` is the loss, the rest parameter gradients.
    pub taps: Vec<TapSpec>,
    /// Rows per streamed tile.
    pub tile_rows: usize,
    /// Full-batch rows (every source's leading dim).
    pub batch_rows: usize,
    /// Storage width for streamed tiles and the stages' *compute* copy
    /// of the parameters. The optimizer always keeps f32 master weights
    /// ([`ParamSpec::init`] is never quantized); in a 16-bit mode the
    /// executor re-quantizes the compute copy after each update.
    pub prec: Precision,
}

impl TrainPlan {
    /// Tiles per microbatch step.
    pub fn n_tiles(&self) -> usize {
        (self.batch_rows / self.tile_rows).max(1)
    }

    /// Stage-to-stage edges that skip at least one intermediate stage
    /// (saved-activation links).
    pub fn n_skip_links(&self) -> usize {
        let n = self.pipeline.stages.len();
        self.pipeline
            .edges
            .iter()
            .filter(|e| e.from.is_some() && e.to.is_some() && e.span(n) > 1)
            .count()
    }

    /// Producer ports feeding more than one queue (Fig 2(c) fan-out).
    pub fn n_multicasts(&self) -> usize {
        let mut count: HashMap<(Option<usize>, usize), usize> = HashMap::new();
        for e in &self.pipeline.edges {
            *count.entry((e.from, e.from_port)).or_insert(0) += 1;
        }
        count.values().filter(|&&c| c > 1).count()
    }
}

/// External (streamed) value a stage consumes: another node's output or
/// the synthesized training target.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum ExtKey {
    Node(NodeId),
    Target,
}

/// Per-stage synthesis output.
struct StageBuild {
    anchor: NodeId,
    /// Streamed inputs in port order.
    ext: Vec<ExtKey>,
    /// Param nodes bound after the streamed ports, in program order.
    params: Vec<NodeId>,
    program: Program,
    /// Output ports: member nodes whose value leaves the stage, id order.
    out_nodes: Vec<NodeId>,
}

/// Lower a training graph (forward ++ backward ++ optimizer markers, as
/// produced by [`crate::graph::training_graph`]) into a [`TrainPlan`].
pub fn lower_training(g: &Graph, opts: &LowerOptions) -> Result<TrainPlan> {
    if g.backward_start.is_none() {
        return Err(not_streamable(format!(
            "graph `{}` has no backward pass; use the inference lowering",
            g.name
        )));
    }

    // 1. The optimizer markers name the parameters and their final
    //    accumulated gradients; the updates themselves run in the
    //    trainer's weight-update stage (`train::Optimizer`), not here.
    let mut grad_of_param: Vec<(NodeId, NodeId)> = Vec::new(); // (param, grad)
    for n in g.nodes() {
        if matches!(n.op, OpKind::OptimizerUpdate) {
            grad_of_param.push((n.inputs[0], n.inputs[1]));
        }
    }
    if grad_of_param.is_empty() {
        return Err(not_streamable(format!(
            "training graph `{}` has no optimizer-update nodes, so no parameter \
             gradients can be tapped",
            g.name
        )));
    }

    // 2. Loss head: exactly one Loss node, consumed only by its seed
    //    (the autodiff `loss_grad` Scale node).
    let losses: Vec<NodeId> = g
        .nodes()
        .iter()
        .filter(|n| matches!(n.op, OpKind::Loss))
        .map(|n| n.id)
        .collect();
    let loss = match losses.as_slice() {
        [one] => *one,
        [] => {
            return Err(not_streamable(format!(
                "training graph `{}` has no Loss head; streaming training needs one",
                g.name
            )))
        }
        many => {
            return Err(not_streamable(format!(
                "training graph `{}` has {} Loss heads; streaming training needs exactly 1",
                g.name,
                many.len()
            )))
        }
    };
    let y_node = g.node(loss).inputs[0];
    let seed = match g.consumers(loss) {
        [one] if matches!(g.node(*one).op, OpKind::Elementwise(EwKind::Scale)) => *one,
        other => {
            return Err(not_streamable(format!(
                "loss `{}` must feed exactly one gradient seed, found consumers {other:?}",
                g.node(loss).name
            )))
        }
    };

    // 3. Liveness: only nodes that actually reach the loss or a tapped
    //    parameter gradient are lowered (dead heads like NeRF's unused
    //    sigma branch and the useless input-gradient chains are pruned,
    //    exactly like an eager autograd engine skips them).
    let mut live: HashSet<NodeId> = HashSet::new();
    let mut work: Vec<NodeId> = vec![loss];
    work.extend(grad_of_param.iter().map(|&(_, grad)| grad));
    while let Some(nid) = work.pop() {
        if live.insert(nid) {
            work.extend(g.node(nid).inputs.iter().copied());
        }
    }

    // 3b. Name the op that blocks streaming *before* shape checks, so
    //     fallback reasons point at the §5.1 exclusion (the gather), not
    //     at its index input's rank.
    for n in g.nodes() {
        if !live.contains(&n.id) {
            continue;
        }
        match &n.op {
            OpKind::Gather { .. } | OpKind::Scatter => {
                return Err(not_streamable(format!(
                    "op `{}` ({}) indexes across all data (§5.1 exclusion); the \
                     training pipeline cannot stream it — Session::simulate() still \
                     covers this app",
                    n.name,
                    n.op.mnemonic()
                )))
            }
            OpKind::Interaction { .. } | OpKind::Softmax | OpKind::LayerNorm => {
                return Err(not_streamable(format!(
                    "op `{}` ({}) has no streaming training kernel yet",
                    n.name,
                    n.op.mnemonic()
                )))
            }
            _ => {}
        }
    }

    // 4. Streamed sources: live graph inputs (row-major `[batch, d]`)
    //    plus the synthesized target, which shares the prediction's dims.
    let input_ids: Vec<NodeId> = g
        .nodes()
        .iter()
        .filter(|n| matches!(n.op, OpKind::Input) && live.contains(&n.id))
        .map(|n| n.id)
        .collect();
    if input_ids.is_empty() {
        return Err(not_streamable(format!(
            "training graph `{}` has no live inputs to stream",
            g.name
        )));
    }
    for &i in &input_ids {
        let n = g.node(i);
        if n.out.shape.dims().len() != 2 {
            return Err(not_streamable(format!(
                "input `{}` has rank-{} shape {:?}; row streaming needs rank 2",
                n.name,
                n.out.shape.dims().len(),
                n.out.shape.dims()
            )));
        }
    }
    let batch_rows = g.node(input_ids[0]).out.shape.leading();
    for &i in &input_ids {
        if g.node(i).out.shape.leading() != batch_rows {
            return Err(not_streamable(format!(
                "input `{}` has {} rows; all streamed inputs must share the batch \
                 dimension ({batch_rows})",
                g.node(i).name,
                g.node(i).out.shape.leading()
            )));
        }
    }
    let y_dims = g.node(y_node).out.shape.dims().to_vec();
    if y_dims.len() != 2 || y_dims[0] != batch_rows {
        return Err(not_streamable(format!(
            "prediction `{}` has shape {y_dims:?}; streaming training needs `[batch, d]` \
             with batch = {batch_rows}",
            g.node(y_node).name
        )));
    }
    // Default tile size: the largest divisor of the batch at or below
    // batch/16 (1 always divides, so the default never rejects a graph);
    // an explicit .tile_rows() must divide exactly.
    let tile_rows = opts.tile_rows.unwrap_or_else(|| {
        let mut t = (batch_rows / 16).max(1);
        while batch_rows % t != 0 {
            t -= 1;
        }
        t
    });
    let tile_rows = tile_rows.max(1);
    if batch_rows % tile_rows != 0 {
        return Err(not_streamable(format!(
            "tile_rows {tile_rows} does not divide the batch ({batch_rows} rows); \
             gradient averaging needs equal tiles"
        )));
    }
    let mut sources: Vec<SourceSpec> = input_ids
        .iter()
        .map(|&i| SourceSpec {
            name: g.node(i).name.clone(),
            dims: g.node(i).out.shape.dims().to_vec(),
        })
        .collect();
    sources.push(SourceSpec { name: "target".to_string(), dims: y_dims });
    let mut src_port: HashMap<ExtKey, usize> = input_ids
        .iter()
        .enumerate()
        .map(|(p, &i)| (ExtKey::Node(i), p))
        .collect();
    src_port.insert(ExtKey::Target, input_ids.len());

    // 5. Stage partition: one stage per live compute node in topological
    //    order (the loss and its seed share one stage; optimizer markers
    //    are not lowered). The linear lowering's epilogue fusion never
    //    fires in training graphs — every pre-activation is also read by
    //    its activation-gradient node — so stages stay one-op.
    let mut stage_members: Vec<Vec<NodeId>> = Vec::new();
    let mut stage_of: HashMap<NodeId, usize> = HashMap::new();
    for n in g.nodes() {
        if !live.contains(&n.id)
            || !n.op.is_compute()
            || matches!(n.op, OpKind::OptimizerUpdate)
        {
            continue;
        }
        if n.id == seed {
            // Rides in the loss stage created when `loss` was visited.
            let si = stage_of[&loss];
            stage_members[si].push(n.id);
            stage_of.insert(n.id, si);
            continue;
        }
        let si = stage_members.len();
        stage_members.push(vec![n.id]);
        stage_of.insert(n.id, si);
    }

    let tapped: HashSet<NodeId> = std::iter::once(loss)
        .chain(grad_of_param.iter().map(|&(_, grad)| grad))
        .collect();

    // 6. Synthesize each stage's SSA program.
    let mut builds: Vec<StageBuild> = Vec::with_capacity(stage_members.len());
    for (si, members) in stage_members.iter().enumerate() {
        builds.push(synth_train_stage(
            g, si, members, &stage_of, &live, loss, seed, &tapped,
        )?);
    }

    // 7. Parameter registry in first-use (stage) order, He-initialized
    //    deterministically from the session seed.
    let mut param_ids: Vec<NodeId> = Vec::new();
    let mut param_pos: HashMap<NodeId, usize> = HashMap::new();
    for b in &builds {
        for &p in &b.params {
            param_pos.entry(p).or_insert_with(|| {
                param_ids.push(p);
                param_ids.len() - 1
            });
        }
    }
    let mut rng = Rng::new(opts.seed);
    let mut seen_names: HashSet<String> = HashSet::new();
    let params: Vec<ParamSpec> = param_ids
        .iter()
        .map(|&p| {
            let mut name = g.node(p).name.clone();
            if !seen_names.insert(name.clone()) {
                // Duplicate label in the user graph: disambiguate by node
                // id so optimizer state (keyed by name) stays
                // per-parameter instead of silently shared.
                name = format!("{name}#{}", p.0);
                seen_names.insert(name.clone());
            }
            ParamSpec { name, init: rng.he_tensor(g.node(p).out.shape.dims()) }
        })
        .collect();

    // 8. Taps: loss first, then parameter gradients in optimizer order.
    let mut taps: Vec<TapSpec> = vec![TapSpec { name: "loss".to_string(), kind: TapKind::Loss }];
    let mut tap_edges: Vec<(usize, NodeId)> = vec![(0, loss)]; // (tap idx, producer node)
    for &(p, grad) in &grad_of_param {
        // A parameter whose forward use was pruned cannot carry a live
        // gradient (liveness seeds from the gradient itself), so the
        // lookup only misses on malformed graphs; skip rather than panic.
        let Some(&pi) = param_pos.get(&p) else { continue };
        taps.push(TapSpec { name: params[pi].name.clone(), kind: TapKind::Grad { param: pi } });
        tap_edges.push((taps.len() - 1, grad));
    }

    // 9. Queue edges: stage ext ports, source fan-out, sink taps. Skip
    //    links get rings deepened by their span so the bypassed stages'
    //    in-flight window cannot wedge the producer.
    let base_cap = opts.queue_capacity.max(2);
    let mut out_port_of: HashMap<(usize, NodeId), usize> = HashMap::new();
    for (si, b) in builds.iter().enumerate() {
        for (p, &nid) in b.out_nodes.iter().enumerate() {
            out_port_of.insert((si, nid), p);
        }
    }
    let n_stages = builds.len();
    let mut edges: Vec<PipeEdge> = Vec::new();
    for (si, b) in builds.iter().enumerate() {
        for (q, key) in b.ext.iter().enumerate() {
            let (from, from_port) = match key {
                ExtKey::Target => (None, src_port[&ExtKey::Target]),
                ExtKey::Node(nid) if matches!(g.node(*nid).op, OpKind::Input) => {
                    (None, src_port[key])
                }
                ExtKey::Node(nid) => {
                    let ps = stage_of[nid];
                    (Some(ps), out_port_of[&(ps, *nid)])
                }
            };
            let mut edge =
                PipeEdge { from, from_port, to: Some(si), to_port: q, capacity: base_cap };
            edge.capacity = (base_cap * edge.span(n_stages)).min(base_cap * 8);
            edges.push(edge);
        }
    }
    for &(tap, nid) in &tap_edges {
        let ps = stage_of[&nid];
        edges.push(PipeEdge {
            from: Some(ps),
            from_port: out_port_of[&(ps, nid)],
            to: None,
            to_port: tap,
            capacity: base_cap,
        });
    }

    // 10. Assemble the coordinator pipeline + parallel stage plans.
    let mut stage_specs: Vec<StageSpec> = Vec::with_capacity(builds.len());
    let mut stage_plans: Vec<StagePlan> = Vec::with_capacity(builds.len());
    for (si, b) in builds.into_iter().enumerate() {
        let anchor = g.node(b.anchor);
        let name = format!("t{si}.{}", anchor.name);
        let class = if matches!(anchor.op, OpKind::Matmul { .. }) {
            ResourceClass::Tensor
        } else {
            ResourceClass::Simt
        };
        stage_specs.push(StageSpec {
            name: name.clone(),
            entry: name.clone(),
            class,
            weights: Arc::new(Vec::new()),
            // Pumps per stage: tiles may compute out of order when >1;
            // the executor's sequence reorder buffer restores FIFO
            // emission order, so results stay bitwise-identical.
            workers: opts.train_workers.max(1),
        });
        stage_plans.push(StagePlan {
            name,
            program: b.program,
            n_stream: b.ext.len(),
            param_idx: b.params.iter().map(|p| param_pos[p]).collect(),
        });
    }

    Ok(TrainPlan {
        pipeline: SpatialPipeline {
            name: format!("{}::train", g.name),
            stages: stage_specs,
            queue_capacity: base_cap,
            edges,
        },
        stages: stage_plans,
        params,
        sources,
        taps,
        tile_rows,
        batch_rows,
        prec: opts.precision,
    })
}

/// Synthesize one stage's program. `members` is one live compute node —
/// or `[loss, seed]` for the loss stage, which emits the MSE loss and
/// its gradient against the streamed target in a single pass.
#[allow(clippy::too_many_arguments)]
fn synth_train_stage(
    g: &Graph,
    si: usize,
    members: &[NodeId],
    stage_of: &HashMap<NodeId, usize>,
    live: &HashSet<NodeId>,
    loss: NodeId,
    seed: NodeId,
    tapped: &HashSet<NodeId>,
) -> Result<StageBuild> {
    let in_stage: HashSet<NodeId> = members.iter().copied().collect();

    // Pre-scan: streamed externals and parameters in first-use order.
    let mut ext: Vec<ExtKey> = Vec::new();
    let mut ext_map: HashMap<ExtKey, usize> = HashMap::new();
    let mut params: Vec<NodeId> = Vec::new();
    for &nid in members {
        if nid == seed {
            continue; // reads the same y/target ports as the loss below
        }
        for &i in &g.node(nid).inputs {
            if in_stage.contains(&i) {
                continue;
            }
            if matches!(g.node(i).op, OpKind::Param) {
                if !params.contains(&i) {
                    params.push(i);
                }
            } else if !ext_map.contains_key(&ExtKey::Node(i)) {
                ext_map.insert(ExtKey::Node(i), ext.len());
                ext.push(ExtKey::Node(i));
            }
        }
        if nid == loss && !ext_map.contains_key(&ExtKey::Target) {
            ext_map.insert(ExtKey::Target, ext.len());
            ext.push(ExtKey::Target);
        }
    }
    let n_inputs = ext.len() + params.len();
    let param_reg: HashMap<NodeId, Reg> =
        params.iter().enumerate().map(|(k, &p)| (p, ext.len() + k)).collect();

    let mut instrs: Vec<Instr> = Vec::new();
    let mut reg_of: HashMap<NodeId, Reg> = HashMap::new();
    for &nid in members {
        let node = g.node(nid);
        let resolve = |i: NodeId| -> Result<Reg> {
            if let Some(&r) = reg_of.get(&i) {
                return Ok(r);
            }
            if let Some(&r) = param_reg.get(&i) {
                return Ok(r);
            }
            ext_map.get(&ExtKey::Node(i)).copied().ok_or_else(|| {
                not_streamable(format!(
                    "stage op `{}` consumes `{}`, which reached no streamed port",
                    node.name,
                    g.node(i).name
                ))
            })
        };
        let mut push = |instr: Instr| -> Reg {
            let r = n_inputs + instrs.len();
            instrs.push(instr);
            r
        };
        let reg = match &node.op {
            OpKind::Loss => {
                let y = resolve(node.inputs[0])?;
                let t = ext_map[&ExtKey::Target];
                push(Instr::MseLoss { y, t })
            }
            // The seed (`loss_grad`): dL/dy of the same MSE, against the
            // streamed target — this is where the graph's abstract Scale
            // node becomes a concrete kernel.
            OpKind::Elementwise(EwKind::Scale) if nid == seed => {
                let y = resolve(g.node(loss).inputs[0])?;
                let t = ext_map[&ExtKey::Target];
                push(Instr::MseGrad { y, t })
            }
            OpKind::Matmul { b, m, n, k } => {
                let (b, m, n, k) = (*b, *m, *n, *k);
                if b != 1 {
                    return Err(not_streamable(format!(
                        "batched matmul `{}` (b={b}) cannot stream row tiles",
                        node.name
                    )));
                }
                let x = node.inputs[0];
                let w = node.inputs[1];
                let xd = g.node(x).out.shape.dims().to_vec();
                let wd = g.node(w).out.shape.dims().to_vec();
                if !g.is_backward(nid) {
                    // Forward linear: weight (and optional bias) are params.
                    let wreg = *param_reg.get(&w).ok_or_else(|| {
                        not_streamable(format!(
                            "matmul `{}` weight `{}` is not a parameter; only linear \
                             layers stream",
                            node.name,
                            g.node(w).name
                        ))
                    })?;
                    let xr = resolve(x)?;
                    let mut r = push(Instr::Matmul { a: xr, b: wreg });
                    if let Some(&bias) = node.inputs.get(2) {
                        let breg = *param_reg.get(&bias).ok_or_else(|| {
                            not_streamable(format!(
                                "matmul `{}` bias is not a parameter",
                                node.name
                            ))
                        })?;
                        r = push(Instr::AddBias { a: r, bias: breg });
                    }
                    r
                } else if matches!(g.node(w).op, OpKind::Param) {
                    // Data gradient: dX = dY @ Wᵀ (W stored `[k_fwd, n_fwd]`,
                    // i.e. `[n, k]` in this node's declared dims).
                    if wd != [n, k] {
                        return Err(not_streamable(format!(
                            "backward matmul `{}` operand shapes {xd:?} x {wd:?} do \
                             not match a data-gradient GEMM",
                            node.name
                        )));
                    }
                    let dyr = resolve(x)?;
                    push(Instr::MatmulNt { a: dyr, b: param_reg[&w] })
                } else {
                    // Weight gradient: dW = Xᵀ @ dY, contracting the batch
                    // (per-tile partial sums, averaged at the sink).
                    if xd != [k, m] || wd != [k, n] {
                        return Err(not_streamable(format!(
                            "backward matmul `{}` operand shapes {xd:?} x {wd:?} do \
                             not match a weight-gradient GEMM",
                            node.name
                        )));
                    }
                    let xr = resolve(x)?;
                    let dyr = resolve(w)?;
                    push(Instr::MatmulTn { a: xr, b: dyr })
                }
            }
            OpKind::Elementwise(EwKind::ActGrad) => {
                let dy = node.inputs[0];
                let x = node.inputs[1];
                let mut kinds: Vec<Act> = Vec::new();
                for &c in g.consumers(x) {
                    if g.is_backward(c) {
                        continue;
                    }
                    if let OpKind::Elementwise(ew) = g.node(c).op {
                        if let Some(k) = act_of(ew) {
                            if !kinds.contains(&k) {
                                kinds.push(k);
                            }
                        }
                    }
                }
                let act = match kinds.as_slice() {
                    [one] => *one,
                    _ => {
                        return Err(not_streamable(format!(
                            "activation gradient `{}` cannot identify a unique forward \
                             activation of `{}` (found {} candidates)",
                            node.name,
                            g.node(x).name,
                            kinds.len()
                        )))
                    }
                };
                let gr = resolve(dy)?;
                let xr = resolve(x)?;
                push(Instr::ActGradI { g: gr, x: xr, act })
            }
            OpKind::Elementwise(EwKind::Slice { start, len }) => {
                let a = resolve(node.inputs[0])?;
                push(Instr::SliceCols { a, start: *start, len: *len })
            }
            OpKind::Elementwise(EwKind::Add) => {
                let a = resolve(node.inputs[0])?;
                let b = resolve(node.inputs[1])?;
                push(Instr::Axpy { a, b, c: 1.0 })
            }
            OpKind::Elementwise(EwKind::Sub) => {
                let a = resolve(node.inputs[0])?;
                let b = resolve(node.inputs[1])?;
                push(Instr::Axpy { a, b, c: -1.0 })
            }
            OpKind::Elementwise(EwKind::Mul) => {
                let a = resolve(node.inputs[0])?;
                let b = resolve(node.inputs[1])?;
                push(Instr::Mul { a, b })
            }
            OpKind::Elementwise(ew) => match act_of(*ew) {
                Some(act) if node.inputs.len() == 1 => {
                    let a = resolve(node.inputs[0])?;
                    push(match act {
                        Act::Relu => Instr::Relu { a },
                        Act::Sigmoid => Instr::Sigmoid { a },
                        Act::Gelu => Instr::Gelu { a },
                        Act::Tanh => Instr::Tanh { a },
                        Act::Silu => Instr::Silu { a },
                        Act::Exp => Instr::Exp { a },
                    })
                }
                _ => {
                    return Err(not_streamable(format!(
                        "op `{}` (ew:{ew:?}) has no streaming lowering in the training \
                         pipeline (stage {si})",
                        node.name
                    )))
                }
            },
            OpKind::Reduce { axis, .. } => {
                if !matches!(axis, ReduceAxis::Batch)
                    || g.node(node.inputs[0]).out.shape.dims().len() != 2
                    || node.out.shape.dims().len() != 1
                {
                    return Err(not_streamable(format!(
                        "reduce `{}` ({}) is not a streamable batch reduction",
                        node.name, node.op
                    )));
                }
                let a = resolve(node.inputs[0])?;
                push(Instr::ColSum { a })
            }
            OpKind::Concat { .. } => {
                let mut r = resolve(node.inputs[0])?;
                for &i in &node.inputs[1..] {
                    let b = resolve(i)?;
                    r = push(Instr::Concat2 { a: r, b });
                }
                r
            }
            other => {
                return Err(not_streamable(format!(
                    "op `{}` ({}) has no streaming lowering in the training pipeline \
                     (stage {si})",
                    node.name,
                    other.mnemonic()
                )))
            }
        };
        reg_of.insert(nid, reg);
    }

    // Output ports: values leaving the stage (live external consumers,
    // excluding optimizer markers, or sink taps), in id order.
    let out_nodes: Vec<NodeId> = members
        .iter()
        .copied()
        .filter(|&nid| {
            tapped.contains(&nid)
                || g.consumers(nid).iter().any(|&c| {
                    live.contains(&c)
                        && !matches!(g.node(c).op, OpKind::OptimizerUpdate)
                        && stage_of.get(&c) != Some(&si)
                })
        })
        .collect();
    if out_nodes.is_empty() {
        return Err(not_streamable(format!(
            "stage `{}` produces no consumed value",
            g.node(members[0]).name
        )));
    }
    let outputs: Vec<Reg> = out_nodes.iter().map(|nid| reg_of[nid]).collect();
    let program = fuse_program(&Program { n_inputs, instrs, outputs });
    Ok(StageBuild { anchor: members[0], ext, params, program, out_nodes })
}

/// Graph elementwise kind → interpreter activation, when one exists.
fn act_of(ew: EwKind) -> Option<Act> {
    match ew {
        EwKind::Relu => Some(Act::Relu),
        EwKind::Sigmoid => Some(Act::Sigmoid),
        EwKind::Gelu => Some(Act::Gelu),
        EwKind::Tanh => Some(Act::Tanh),
        EwKind::Silu => Some(Act::Silu),
        EwKind::Exp => Some(Act::Exp),
        _ => None,
    }
}
