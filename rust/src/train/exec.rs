//! The persistent DAG-pipeline executor behind [`crate::train::Trainer`]:
//! stage worker threads and per-edge ring queues stood up once, serving
//! microbatch training steps until shutdown — the training counterpart
//! of [`crate::session::PipelineService`], generalized from a linear
//! chain to the multicast / skip-link DAG a [`TrainPlan`] describes.
//!
//! Execution model: every stage runs **one** worker; each queue edge has
//! one producer and one consumer, so FIFO order delivers tile `seq`s in
//! lockstep and a multi-input stage simply pops one tile from each input
//! edge — no reorder buffer. Multicast producers push a clone per
//! consumer queue. Parameters live in one shared `RwLock` store: stage
//! workers take read locks per tile; the trainer write-locks between
//! steps (the pipeline is drained then, so updates never race a kernel).
//!
//! [`serial_step`] re-executes the same stage programs tile-by-tile on
//! the calling thread and folds taps through the same accumulator — the
//! bitwise oracle the pipeline is tested against, and the baseline
//! `benches/train_throughput.rs` reports speedups over.

use super::accumulate::mean_in_order;
use super::lower::{TapKind, TrainPlan};
use crate::queue::{PushError, RingQueue};
use crate::runtime::interp::ExecPlan;
use crate::runtime::Tensor;
use crate::Result;
use anyhow::{anyhow, ensure};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;

/// A sequence-tagged tile on one queue edge.
type SeqTile = (usize, Tensor);

/// A tap delivery routed to the sink: `(tap index, seq, payload)`.
type SinkItem = (usize, usize, Tensor);

/// Result of one microbatch step: mean per-tile loss and mean per-tile
/// parameter gradients (slot `i` pairs with `TrainPlan::params[i]`;
/// `None` only for parameters without a tapped gradient).
#[derive(Debug, Clone)]
pub struct StepOutput {
    pub loss: f32,
    pub grads: Vec<Option<Tensor>>,
}

/// Where a stage output port's tiles go.
enum Route {
    Queue(Arc<RingQueue<SeqTile>>),
    Sink(usize),
}

/// In-flight step accounting: slots filled by the sink thread, folded by
/// the submitting thread once every tap delivered every tile.
struct StepTable {
    state: Mutex<StepState>,
    done: Condvar,
}

struct StepState {
    /// `slots[tap][seq]`.
    slots: Vec<Vec<Option<Tensor>>>,
    remaining: usize,
    error: Option<String>,
    active: bool,
}

impl StepTable {
    fn new() -> Self {
        StepTable {
            state: Mutex::new(StepState {
                slots: Vec::new(),
                remaining: 0,
                error: None,
                active: false,
            }),
            done: Condvar::new(),
        }
    }

    fn begin(&self, n_taps: usize, n_tiles: usize) {
        let mut s = self.state.lock().unwrap();
        s.slots = vec![vec![None; n_tiles]; n_taps];
        s.remaining = n_taps * n_tiles;
        s.active = true;
    }

    fn complete(&self, tap: usize, seq: usize, t: Tensor) {
        let mut s = self.state.lock().unwrap();
        if !s.active {
            return; // stale delivery from a failed step
        }
        let Some(slot) = s.slots.get_mut(tap).and_then(|row| row.get_mut(seq)) else {
            s.error = Some(format!("sink delivery out of range: tap {tap} seq {seq}"));
            self.done.notify_all();
            return;
        };
        if slot.is_none() {
            *slot = Some(t);
            s.remaining -= 1;
            if s.remaining == 0 {
                self.done.notify_all();
            }
        }
    }

    fn fail(&self, msg: String) {
        let mut s = self.state.lock().unwrap();
        if s.error.is_none() {
            s.error = Some(msg);
        }
        self.done.notify_all();
    }

    fn wait(&self) -> Result<Vec<Vec<Option<Tensor>>>> {
        let mut s = self.state.lock().unwrap();
        while s.remaining > 0 && s.error.is_none() {
            s = self.done.wait(s).unwrap();
        }
        s.active = false;
        if let Some(e) = s.error.take() {
            return Err(anyhow!(e));
        }
        Ok(std::mem::take(&mut s.slots))
    }
}

/// Persistent training pipeline: per-edge ring queues, one worker thread
/// per stage, a sink thread routing taps into the step table, and the
/// shared mutable parameter store.
pub struct TrainService {
    plan: Arc<TrainPlan>,
    pub(crate) params: Arc<RwLock<Vec<Tensor>>>,
    /// Per source port: the queues its tiles fan out to.
    src_routes: Vec<Vec<Arc<RingQueue<SeqTile>>>>,
    table: Arc<StepTable>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    spawned: usize,
    /// One step in flight at a time; shutdown waits out the current one.
    step_lock: Mutex<()>,
    dead: Arc<AtomicBool>,
    shut: AtomicBool,
}

impl TrainService {
    /// Stand up the DAG: queues from the plan's edges, one worker per
    /// stage, the sink, and the parameter store seeded from the plan's
    /// deterministic initial values. Threads are created here — never on
    /// the step path.
    pub fn start(plan: Arc<TrainPlan>) -> Result<TrainService> {
        let n_stages = plan.stages.len();
        ensure!(n_stages > 0, "training pipeline needs at least one stage");

        // Wire queues from the explicit edges.
        for (si, sp) in plan.stages.iter().enumerate() {
            ensure!(
                sp.n_stream > 0,
                "train stage {si} (`{}`) has no streamed inputs",
                sp.name
            );
        }
        let mut stage_in: Vec<Vec<Option<Arc<RingQueue<SeqTile>>>>> = plan
            .stages
            .iter()
            .map(|s| vec![None; s.n_stream])
            .collect();
        let mut out_routes: Vec<Vec<Vec<Route>>> = plan
            .stages
            .iter()
            .map(|sp| (0..sp.program.outputs.len()).map(|_| Vec::new()).collect())
            .collect();
        let mut src_routes: Vec<Vec<Arc<RingQueue<SeqTile>>>> =
            vec![Vec::new(); plan.sources.len()];
        let sink_q: Arc<RingQueue<SinkItem>> =
            RingQueue::with_capacity(plan.pipeline.queue_capacity * 4);
        for e in &plan.pipeline.edges {
            match e.to {
                Some(to) => {
                    let q = RingQueue::with_capacity(e.capacity.max(2));
                    let slot = stage_in
                        .get_mut(to)
                        .and_then(|ports| ports.get_mut(e.to_port))
                        .ok_or_else(|| anyhow!("edge targets missing port: {e:?}"))?;
                    ensure!(slot.is_none(), "duplicate edge into port: {e:?}");
                    *slot = Some(Arc::clone(&q));
                    match e.from {
                        Some(from) => out_routes[from][e.from_port].push(Route::Queue(q)),
                        None => src_routes[e.from_port].push(q),
                    }
                }
                None => {
                    let from = e
                        .from
                        .ok_or_else(|| anyhow!("source-to-sink edge unsupported: {e:?}"))?;
                    out_routes[from][e.from_port].push(Route::Sink(e.to_port));
                }
            }
        }
        for (si, ports) in stage_in.iter().enumerate() {
            for (p, q) in ports.iter().enumerate() {
                ensure!(q.is_some(), "stage {si} input port {p} has no feeding edge");
            }
        }

        let params = Arc::new(RwLock::new(
            plan.params.iter().map(|p| p.init.clone()).collect::<Vec<Tensor>>(),
        ));
        let table = Arc::new(StepTable::new());
        let dead = Arc::new(AtomicBool::new(false));
        let latch = Arc::new(AtomicUsize::new(n_stages));
        let mut handles = Vec::with_capacity(n_stages + 1);

        let mut out_routes_iter = out_routes.into_iter();
        let mut stage_in_iter = stage_in.into_iter();
        for (si, sp) in plan.stages.iter().enumerate() {
            let in_queues: Vec<Arc<RingQueue<SeqTile>>> = stage_in_iter
                .next()
                .expect("stage_in parallel to stages")
                .into_iter()
                .map(|q| q.expect("validated above"))
                .collect();
            let routes = out_routes_iter.next().expect("out_routes parallel to stages");
            let program = sp.program.clone();
            let exec_plan = program.plan();
            let param_idx = sp.param_idx.clone();
            let name = sp.name.clone();
            let params = Arc::clone(&params);
            let table = Arc::clone(&table);
            let dead = Arc::clone(&dead);
            let latch = Arc::clone(&latch);
            let sink_q = Arc::clone(&sink_q);
            let handle = std::thread::Builder::new()
                .name(format!("kitsune-train-{si}"))
                .spawn(move || {
                    stage_worker(
                        &name, &program, &exec_plan, &param_idx, &params, &in_queues,
                        &routes, &sink_q, &table, &dead,
                    );
                    // Cascade the exit both ways: downstream consumers see
                    // end-of-stream, and upstream producers blocked pushing
                    // into this stage observe Closed instead of hanging.
                    for q in &in_queues {
                        q.close();
                    }
                    for port in &routes {
                        for r in port {
                            if let Route::Queue(q) = r {
                                q.close();
                            }
                        }
                    }
                    if latch.fetch_sub(1, Ordering::AcqRel) == 1 {
                        sink_q.close();
                    }
                })
                .map_err(|e| anyhow!("spawning train stage worker: {e}"))?;
            handles.push(handle);
        }

        // Sink: route tap deliveries into the step table.
        let sink_table = Arc::clone(&table);
        let sink_handle = std::thread::Builder::new()
            .name("kitsune-train-sink".to_string())
            .spawn(move || {
                while let Some((tap, seq, t)) = sink_q.pop() {
                    sink_table.complete(tap, seq, t);
                }
            })
            .map_err(|e| anyhow!("spawning train sink: {e}"))?;
        handles.push(sink_handle);
        let spawned = n_stages + 1;

        Ok(TrainService {
            plan,
            params,
            src_routes,
            table,
            handles: Mutex::new(handles),
            spawned,
            step_lock: Mutex::new(()),
            dead,
            shut: AtomicBool::new(false),
        })
    }

    pub fn plan(&self) -> &TrainPlan {
        &self.plan
    }

    /// Snapshot of the current parameter values (plan order).
    pub fn param_values(&self) -> Vec<Tensor> {
        self.params.read().unwrap().clone()
    }

    /// Threads this service spawned (stage workers + sink).
    pub fn threads_spawned(&self) -> usize {
        self.spawned
    }

    /// Run one microbatch step: `tiles[port][seq]` per source port.
    /// Blocks until every tap drained, then folds gradients/loss in tile
    /// order. One step runs at a time; parameter updates happen outside
    /// (see [`crate::train::Trainer`]).
    pub fn run_step(&self, tiles: Vec<Vec<Tensor>>) -> Result<StepOutput> {
        let _step = self.step_lock.lock().unwrap();
        ensure!(
            !self.dead.load(Ordering::Acquire) && !self.shut.load(Ordering::Acquire),
            "training pipeline is shut down"
        );
        let n_tiles = validate_tiles(&self.plan, &tiles)?;
        self.table.begin(self.plan.taps.len(), n_tiles);
        'feed: for seq in 0..n_tiles {
            for (port, routes) in self.src_routes.iter().enumerate() {
                for q in routes {
                    let payload = (seq, tiles[port][seq].clone());
                    if let Err(PushError::Closed(_)) = q.push(payload) {
                        self.table.fail("training pipeline closed during feed".to_string());
                        break 'feed;
                    }
                }
            }
        }
        let slots = self.table.wait()?;
        fold_taps(&self.plan, slots)
    }

    /// Close every source queue and join the workers. Idempotent; waits
    /// out an in-flight step first.
    pub fn shutdown(&self) {
        {
            let _step = self.step_lock.lock().unwrap();
            if self.shut.swap(true, Ordering::AcqRel) {
                return;
            }
            for routes in &self.src_routes {
                for q in routes {
                    q.close();
                }
            }
        }
        let mut handles = self.handles.lock().unwrap();
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for TrainService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One stage worker: pop one tile per input edge (sequence-aligned by
/// FIFO construction), run the stage program against the current
/// parameters, route each output port (cloning per extra consumer).
#[allow(clippy::too_many_arguments)]
fn stage_worker(
    name: &str,
    program: &crate::runtime::interp::Program,
    exec_plan: &ExecPlan,
    param_idx: &[usize],
    params: &RwLock<Vec<Tensor>>,
    in_queues: &[Arc<RingQueue<SeqTile>>],
    routes: &[Vec<Route>],
    sink_q: &RingQueue<SinkItem>,
    table: &StepTable,
    dead: &AtomicBool,
) {
    let mut ins: Vec<SeqTile> = Vec::with_capacity(in_queues.len());
    'serve: loop {
        ins.clear();
        for q in in_queues {
            match q.pop() {
                Some(v) => ins.push(v),
                None => break 'serve,
            }
        }
        let seq = ins[0].0;
        if ins.iter().any(|(s, _)| *s != seq) {
            dead.store(true, Ordering::Release);
            table.fail(format!("stage {name}: input streams desynchronized"));
            break 'serve;
        }
        let result = {
            let guard = params.read().unwrap();
            let mut args: Vec<&Tensor> = ins.iter().map(|(_, t)| t).collect();
            args.extend(param_idx.iter().map(|&i| &guard[i]));
            program.run_with_plan(&args, &[], exec_plan)
        };
        let outs = match result {
            Ok(outs) => outs,
            Err(e) => {
                dead.store(true, Ordering::Release);
                table.fail(format!("train stage {name} failed: {e:#}"));
                break 'serve;
            }
        };
        if outs.len() != routes.len() {
            dead.store(true, Ordering::Release);
            table.fail(format!(
                "train stage {name}: {} outputs for {} ports",
                outs.len(),
                routes.len()
            ));
            break 'serve;
        }
        for (port, out) in outs.into_iter().enumerate() {
            let port_routes = &routes[port];
            let n = port_routes.len();
            if n == 0 {
                continue;
            }
            // Multicast: clone for every consumer but the last.
            for r in &port_routes[..n - 1] {
                if !send(r, seq, out.clone(), sink_q) {
                    break 'serve;
                }
            }
            if !send(&port_routes[n - 1], seq, out, sink_q) {
                break 'serve;
            }
        }
    }
}

/// Deliver one tile along a route; `false` means the destination closed
/// (shutdown or failure cascade) and the worker should exit.
fn send(route: &Route, seq: usize, t: Tensor, sink_q: &RingQueue<SinkItem>) -> bool {
    match route {
        Route::Queue(q) => q.push((seq, t)).is_ok(),
        Route::Sink(tap) => sink_q.push((*tap, seq, t)).is_ok(),
    }
}

/// Check one step's tile table against the plan: every source supplies
/// the same number of `[tile_rows, d]` tiles. Returns the tile count.
fn validate_tiles(plan: &TrainPlan, tiles: &[Vec<Tensor>]) -> Result<usize> {
    ensure!(
        tiles.len() == plan.sources.len(),
        "step supplies {} sources, plan has {} ({:?})",
        tiles.len(),
        plan.sources.len(),
        plan.sources.iter().map(|s| s.name.as_str()).collect::<Vec<_>>()
    );
    let n_tiles = tiles.first().map(|t| t.len()).unwrap_or(0);
    ensure!(n_tiles > 0, "step needs at least one tile");
    for (port, (per_src, spec)) in tiles.iter().zip(&plan.sources).enumerate() {
        ensure!(
            per_src.len() == n_tiles,
            "source `{}` supplies {} tiles, expected {n_tiles}",
            spec.name,
            per_src.len()
        );
        let want = vec![plan.tile_rows, spec.dims[1]];
        for t in per_src {
            ensure!(
                t.dims == want,
                "source `{}` (port {port}) tile dims {:?} != {want:?}",
                spec.name,
                t.dims
            );
        }
    }
    Ok(n_tiles)
}

/// Fold completed tap slots into the step result — mean over tiles in
/// tile order, identical for the pipeline and the serial oracle.
fn fold_taps(plan: &TrainPlan, mut slots: Vec<Vec<Option<Tensor>>>) -> Result<StepOutput> {
    let mut loss = f32::NAN;
    let mut grads: Vec<Option<Tensor>> = vec![None; plan.params.len()];
    for (tap, spec) in plan.taps.iter().enumerate() {
        let folded = mean_in_order(std::mem::take(&mut slots[tap]))?;
        match spec.kind {
            TapKind::Loss => loss = folded.scalar_value(),
            TapKind::Grad { param } => grads[param] = Some(folded),
        }
    }
    Ok(StepOutput { loss, grads })
}

/// Serial oracle / baseline: execute the same stage programs tile by
/// tile on the calling thread (explicit `params`, plan order) and fold
/// the same taps. Bitwise-identical to the pipeline by construction —
/// same programs, same per-tile values, same fold order.
pub fn serial_step(
    plan: &TrainPlan,
    params: &[Tensor],
    tiles: &[Vec<Tensor>],
) -> Result<StepOutput> {
    ensure!(
        params.len() == plan.params.len(),
        "serial step got {} params, plan has {}",
        params.len(),
        plan.params.len()
    );
    let n_tiles = validate_tiles(plan, tiles)?;
    let exec_plans: Vec<ExecPlan> = plan.stages.iter().map(|s| s.program.plan()).collect();
    // Per-stage input edges by port, plus the sink edges.
    let mut in_edges: Vec<Vec<&crate::coordinator::PipeEdge>> =
        vec![Vec::new(); plan.stages.len()];
    let mut sink_edges: Vec<&crate::coordinator::PipeEdge> = Vec::new();
    for e in &plan.pipeline.edges {
        match e.to {
            Some(to) => in_edges[to].push(e),
            None => sink_edges.push(e),
        }
    }
    for edges in &mut in_edges {
        edges.sort_by_key(|e| e.to_port);
    }

    let mut slots: Vec<Vec<Option<Tensor>>> = vec![vec![None; n_tiles]; plan.taps.len()];
    for seq in 0..n_tiles {
        let mut vals: HashMap<(usize, usize), Tensor> = HashMap::new();
        for (si, sp) in plan.stages.iter().enumerate() {
            let outs = {
                let mut args: Vec<&Tensor> = Vec::with_capacity(sp.n_stream + sp.param_idx.len());
                for e in &in_edges[si] {
                    let v = match e.from {
                        None => &tiles[e.from_port][seq],
                        Some(ps) => vals
                            .get(&(ps, e.from_port))
                            .ok_or_else(|| anyhow!("edge {e:?} has no produced value"))?,
                    };
                    args.push(v);
                }
                args.extend(sp.param_idx.iter().map(|&i| &params[i]));
                sp.program.run_with_plan(&args, &[], &exec_plans[si])?
            };
            for (p, o) in outs.into_iter().enumerate() {
                vals.insert((si, p), o);
            }
        }
        for e in &sink_edges {
            let from = e.from.expect("sink edges originate at stages");
            let v = vals
                .get(&(from, e.from_port))
                .ok_or_else(|| anyhow!("sink edge {e:?} has no produced value"))?
                .clone();
            slots[e.to_port][seq] = Some(v);
        }
    }
    fold_taps(plan, slots)
}
