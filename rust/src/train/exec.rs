//! The persistent DAG-pipeline executor behind [`crate::train::Trainer`]:
//! cooperative stage pumps and per-edge ring queues stood up once,
//! serving microbatch training steps until shutdown — the training
//! counterpart of [`crate::session::PipelineService`], generalized from
//! a linear chain to the multicast / skip-link DAG a [`TrainPlan`]
//! describes.
//!
//! Execution model: each stage runs one or more **pumps** — cooperative
//! tasks on the shared [`crate::sched`] work-stealing pool that never
//! block a worker (empty/full edges register queue wakers instead).
//! Each queue edge has one producing and one consuming stage, so FIFO
//! order delivers tile `seq`s in lockstep; a multi-input stage pops one
//! tile from each input edge under its intake lock. With several pumps
//! per stage, tiles may *complete* out of order inside the stage, so
//! emission goes through a per-stage **sequence reorder buffer**: intake
//! assigns each gathered tile a monotonic arrival index, and outputs are
//! routed strictly in arrival order (which equals input FIFO order, and
//! therefore `seq` order within a step) — preserving the bitwise
//! pipeline==serial-oracle contract. Multicast producers push a clone
//! per consumer queue, in the same route order as the single-worker
//! executor. Parameters live in one shared `RwLock` store: pumps take
//! read locks per tile; the trainer write-locks between steps (the
//! pipeline is drained then, so updates never race a kernel).
//!
//! **Fault containment**: every tile crosses edges inside an
//! [`Envelope`] — a stage whose kernel panics or errors emits
//! `Poison(StageFailure)` on all its output ports at that arrival index
//! instead of dying, so the reorder buffer stays gapless and every
//! downstream consumer (including skip links) stays seq-aligned.
//! Poisoned sets skip compute and forward; the sink records the first
//! failure on the step table, and `run_step` surfaces it as a typed
//! [`crate::runtime::RuntimeError::StageFailed`] once the step fully
//! drains — the *next* step runs on a clean pipeline. Only structural
//! faults (desynchronized inputs, wrong output arity, the sink stream
//! closing mid-step) kill the pipeline, via the `dead` latch.
//!
//! [`serial_step`] re-executes the same stage programs tile-by-tile on
//! the calling thread and folds taps through the same accumulator — the
//! bitwise oracle the pipeline is tested against, and the baseline
//! `benches/train_throughput.rs` reports speedups over.

use super::accumulate::mean_in_order;
use super::lower::{TapKind, TrainPlan};
use crate::fault::{
    catch_stage, Envelope, FailureCause, FaultPlan, Health, HealthState, StageFailure,
};
use crate::queue::{PopError, PushError, RingQueue};
use crate::runtime::interp::{ExecPlan, Program};
use crate::runtime::{Precision, Tensor};
use crate::sched::{self, LiveCount, Scheduler};
use crate::telemetry::{
    trace, EdgeKind, EdgeStats, PipelineTelemetry, StageTelemetry, TrafficStats,
};
use crate::Result;
use anyhow::{anyhow, ensure};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Instant;

/// Payload bytes of one envelope (poison moves no tensor data).
/// Charged at the tensor's *storage* width — a bf16/f16 tile crossing an
/// edge moves half the bytes of its f32 twin.
fn env_payload_bytes(env: &Envelope<Tensor>) -> u64 {
    match env {
        Envelope::Ok(t) => t.payload_bytes(),
        Envelope::Poison(_) => 0,
    }
}

/// Account a successful push's payload against the queue's attached
/// edge stats and the pipeline's traffic classification. Generic over
/// the queue's item type: the edge kind lives on the attached stats.
fn account_push<T>(q: &RingQueue<T>, traffic: &TrafficStats, bytes: u64) {
    if let Some(e) = q.telemetry() {
        e.bytes.add(bytes);
        traffic.record_edge(e.kind, bytes);
    }
}

/// A sequence-tagged envelope on one queue edge: live tile or poison.
type SeqTile = (usize, Envelope<Tensor>);

/// A tap delivery routed to the sink: `(tap index, seq, payload)`.
type SinkItem = (usize, usize, Envelope<Tensor>);

/// Result of one microbatch step: mean per-tile loss and mean per-tile
/// parameter gradients (slot `i` pairs with `TrainPlan::params[i]`;
/// `None` only for parameters without a tapped gradient).
#[derive(Debug, Clone)]
pub struct StepOutput {
    pub loss: f32,
    pub grads: Vec<Option<Tensor>>,
}

/// Where a stage output port's tiles go.
enum Route {
    Queue(Arc<RingQueue<SeqTile>>),
    Sink(usize),
}

/// In-flight step accounting: slots filled by the sink thread, folded by
/// the submitting thread once every tap delivered every tile.
struct StepTable {
    state: Mutex<StepState>,
    done: Condvar,
}

struct StepState {
    /// `slots[tap][seq]`.
    slots: Vec<Vec<Option<Tensor>>>,
    /// `resolved[tap][seq]`: delivered exactly once, live or poison.
    resolved: Vec<Vec<bool>>,
    remaining: usize,
    /// First poison delivery of the step. The step still waits for the
    /// full drain (neighbor tiles finish; the pipeline is clean for the
    /// next step), then surfaces this as the step error.
    failure: Option<StageFailure>,
    /// Structural failure: remaining deliveries will never arrive, so
    /// the waiter is unblocked immediately.
    abort: Option<StageFailure>,
    active: bool,
}

impl StepTable {
    fn new() -> Self {
        StepTable {
            state: Mutex::new(StepState {
                slots: Vec::new(),
                resolved: Vec::new(),
                remaining: 0,
                failure: None,
                abort: None,
                active: false,
            }),
            done: Condvar::new(),
        }
    }

    fn begin(&self, n_taps: usize, n_tiles: usize) {
        let mut s = self.state.lock().unwrap();
        s.slots = vec![vec![None; n_tiles]; n_taps];
        s.resolved = vec![vec![false; n_tiles]; n_taps];
        s.remaining = n_taps * n_tiles;
        s.failure = None;
        s.abort = None;
        s.active = true;
    }

    fn complete(&self, tap: usize, seq: usize, t: Tensor) {
        let mut s = self.state.lock().unwrap();
        if !s.active {
            return; // stale delivery from a failed step
        }
        let st = &mut *s;
        let Some(done) = st.resolved.get_mut(tap).and_then(|row| row.get_mut(seq)) else {
            Self::abort_locked(st, tap, seq);
            self.done.notify_all();
            return;
        };
        if !*done {
            *done = true;
            st.slots[tap][seq] = Some(t);
            st.remaining -= 1;
            if st.remaining == 0 {
                self.done.notify_all();
            }
        }
    }

    /// A poison envelope reached the sink: the slot resolves with no
    /// tensor, the failure is recorded, and the step keeps draining.
    fn poison(&self, tap: usize, seq: usize, f: StageFailure) {
        let mut s = self.state.lock().unwrap();
        if !s.active {
            return;
        }
        let st = &mut *s;
        let Some(done) = st.resolved.get_mut(tap).and_then(|row| row.get_mut(seq)) else {
            Self::abort_locked(st, tap, seq);
            self.done.notify_all();
            return;
        };
        if !*done {
            *done = true;
            st.remaining -= 1;
            if st.failure.is_none() {
                st.failure = Some(f);
            }
            if st.remaining == 0 {
                self.done.notify_all();
            }
        }
    }

    /// Record an out-of-range delivery as a structural abort (lock held
    /// by the caller — no re-entry into `fail`).
    fn abort_locked(st: &mut StepState, tap: usize, seq: usize) {
        if st.abort.is_none() {
            st.abort = Some(StageFailure::new(
                "sink",
                FailureCause::Kernel(format!("sink delivery out of range: tap {tap} seq {seq}")),
            ));
        }
    }

    /// Structural failure: unblock the waiter now — outstanding
    /// deliveries will never arrive. No-op between steps, so the
    /// shutdown cascade (which also closes the sink) stays silent.
    fn fail(&self, f: StageFailure) {
        let mut s = self.state.lock().unwrap();
        if s.active && s.abort.is_none() {
            s.abort = Some(f);
        }
        self.done.notify_all();
    }

    fn wait(&self) -> Result<Vec<Vec<Option<Tensor>>>> {
        let mut s = self.state.lock().unwrap();
        while s.remaining > 0 && s.abort.is_none() {
            s = self.done.wait(s).unwrap();
        }
        s.active = false;
        let abort = s.abort.take();
        let failure = s.failure.take();
        if let Some(f) = abort.or(failure) {
            return Err(f.into_error());
        }
        Ok(std::mem::take(&mut s.slots))
    }
}

/// Persistent training pipeline: per-edge ring queues, one or more
/// pumps per stage on the shared scheduler, a sink pump routing taps
/// into the step table, and the shared mutable parameter store.
pub struct TrainService {
    plan: Arc<TrainPlan>,
    /// Master parameters — always full f32; the trainer's optimizer
    /// updates these between steps.
    pub(crate) params: Arc<RwLock<Vec<Tensor>>>,
    /// The *compute* copy of the parameters stage kernels bind: the same
    /// store as `params` when the plan runs f32, a separate store
    /// quantized to the plan's 16-bit grid otherwise (refreshed from the
    /// masters at every step start, after the optimizer has run).
    cparams: Arc<RwLock<Vec<Tensor>>>,
    /// Per source port: the queues its tiles fan out to.
    src_routes: Vec<Vec<Arc<RingQueue<SeqTile>>>>,
    table: Arc<StepTable>,
    /// Countdown of live pump tasks; shutdown (and Drop) drain it to
    /// zero so no scheduler task still references stage state after.
    svc_live: Arc<LiveCount>,
    spawned: usize,
    /// One step in flight at a time; shutdown waits out the current one.
    step_lock: Mutex<()>,
    dead: Arc<AtomicBool>,
    shut: AtomicBool,
    /// Deterministic fault-injection plan (inert when empty).
    fault: Arc<FaultPlan>,
    health: Arc<HealthState>,
    /// Monotonic step counter — the coordinate `nan:loss:step=N` /
    /// `nan:grad:step=N` fault specs key on.
    steps: AtomicU64,
    /// Per-stage/per-edge metrics and traffic accounting, registered
    /// with [`crate::telemetry::snapshot`] for the service's lifetime.
    telemetry: Arc<PipelineTelemetry>,
}

impl TrainService {
    /// Stand up the DAG: queues from the plan's edges, the per-stage
    /// pump tasks on the shared scheduler (`workers` per stage from the
    /// lowering), the sink pump, and the parameter store seeded from the
    /// plan's deterministic initial values. Tasks are spawned here —
    /// never on the step path.
    pub fn start(plan: Arc<TrainPlan>, fault: Arc<FaultPlan>) -> Result<TrainService> {
        let n_stages = plan.stages.len();
        ensure!(n_stages > 0, "training pipeline needs at least one stage");

        // Wire queues from the explicit edges.
        for (si, sp) in plan.stages.iter().enumerate() {
            ensure!(
                sp.n_stream > 0,
                "train stage {si} (`{}`) has no streamed inputs",
                sp.name
            );
        }
        let mut stage_in: Vec<Vec<Option<Arc<RingQueue<SeqTile>>>>> = plan
            .stages
            .iter()
            .map(|s| vec![None; s.n_stream])
            .collect();
        let mut out_routes: Vec<Vec<Vec<Route>>> = plan
            .stages
            .iter()
            .map(|sp| (0..sp.program.outputs.len()).map(|_| Vec::new()).collect())
            .collect();
        let mut src_routes: Vec<Vec<Arc<RingQueue<SeqTile>>>> =
            vec![Vec::new(); plan.sources.len()];
        let mut edge_queues: Vec<(usize, Arc<RingQueue<SeqTile>>)> = Vec::new();
        // Per-edge telemetry: source-feed edges are off-chip-analog
        // injection, stage-to-stage edges are the on-chip-analog
        // crossings dataflow execution saves, and the shared tap stream
        // into the sink is the off-chip-analog drain.
        let mut edge_stats: Vec<Arc<EdgeStats>> = Vec::new();
        let sink_q: Arc<RingQueue<SinkItem>> =
            RingQueue::with_capacity(plan.pipeline.queue_capacity * 4);
        for (ei, e) in plan.pipeline.edges.iter().enumerate() {
            match e.to {
                Some(to) => {
                    let q = RingQueue::with_capacity(e.capacity.max(2));
                    edge_queues.push((ei, Arc::clone(&q)));
                    let (from_name, kind) = match e.from {
                        Some(f) => (plan.stages[f].name.as_str(), EdgeKind::Interior),
                        None => ("source", EdgeKind::Source),
                    };
                    let es = Arc::new(EdgeStats::new(
                        format!("{from_name}->{}", plan.stages[to].name),
                        kind,
                        q.capacity(),
                    ));
                    q.attach_telemetry(Arc::clone(&es));
                    edge_stats.push(es);
                    let slot = stage_in
                        .get_mut(to)
                        .and_then(|ports| ports.get_mut(e.to_port))
                        .ok_or_else(|| anyhow!("edge targets missing port: {e:?}"))?;
                    ensure!(slot.is_none(), "duplicate edge into port: {e:?}");
                    *slot = Some(Arc::clone(&q));
                    match e.from {
                        Some(from) => out_routes[from][e.from_port].push(Route::Queue(q)),
                        None => src_routes[e.from_port].push(q),
                    }
                }
                None => {
                    let from = e
                        .from
                        .ok_or_else(|| anyhow!("source-to-sink edge unsupported: {e:?}"))?;
                    out_routes[from][e.from_port].push(Route::Sink(e.to_port));
                }
            }
        }
        for (si, ports) in stage_in.iter().enumerate() {
            for (p, q) in ports.iter().enumerate() {
                ensure!(q.is_some(), "stage {si} input port {p} has no feeding edge");
            }
        }

        let health = Arc::new(HealthState::default());
        // Injected edge failures fire before any traffic: the affected
        // stages observe end-of-stream, the cascade retires the DAG, and
        // every subsequent step fails typed (QueueClosed) — never hangs.
        for ei in fault.take_queue_closes() {
            for (idx, q) in &edge_queues {
                if *idx == ei {
                    q.close();
                    health.fail(&format!("edge {ei}"));
                }
            }
        }
        drop(edge_queues);

        let params = Arc::new(RwLock::new(
            plan.params.iter().map(|p| p.init.clone()).collect::<Vec<Tensor>>(),
        ));
        // f32 plans bind kernels straight to the master store (an Arc
        // bump); 16-bit plans get a distinct quantized compute store.
        let cparams = if plan.prec == Precision::F32 {
            Arc::clone(&params)
        } else {
            Arc::new(RwLock::new(
                plan.params
                    .iter()
                    .map(|p| p.init.quantized(plan.prec))
                    .collect::<Vec<Tensor>>(),
            ))
        };
        let table = Arc::new(StepTable::new());
        let dead = Arc::new(AtomicBool::new(false));
        let all_latch = Arc::new(AtomicUsize::new(n_stages));
        let scheduler = sched::current();

        // Pump census: the lowering sets per-stage worker counts on the
        // pipeline's stage specs (default 1 — see `LowerOptions::
        // train_workers`); plus one sink pump.
        let workers_of = |si: usize| -> usize {
            plan.pipeline.stages.get(si).map(|s| s.workers).unwrap_or(1).max(1)
        };
        let spawned = (0..n_stages).map(&workers_of).sum::<usize>() + 1;
        let svc_live = LiveCount::new(spawned);

        let sink_stats =
            Arc::new(EdgeStats::new("taps->sink", EdgeKind::Sink, sink_q.capacity()));
        sink_q.attach_telemetry(Arc::clone(&sink_stats));
        edge_stats.push(sink_stats);
        let stage_telems: Vec<StageTelemetry> = plan
            .stages
            .iter()
            .enumerate()
            .map(|(si, sp)| {
                let class = plan
                    .pipeline
                    .stages
                    .get(si)
                    .map(|s| format!("{:?}", s.class).to_lowercase())
                    .unwrap_or_else(|| "stage".to_string());
                // Weight traffic is charged at the *compute copy*'s
                // storage width — the masters stay f32 but never move.
                let weight_bytes = sp
                    .param_idx
                    .iter()
                    .map(|&i| (plan.params[i].init.data.len() * plan.prec.bytes()) as u64)
                    .sum();
                StageTelemetry::new(sp.name.clone(), class, workers_of(si), weight_bytes)
            })
            .collect();
        let telemetry =
            PipelineTelemetry::register(plan.pipeline.name.clone(), stage_telems, edge_stats);

        let mut out_routes_iter = out_routes.into_iter();
        let mut stage_in_iter = stage_in.into_iter();
        for (si, sp) in plan.stages.iter().enumerate() {
            let in_queues: Vec<Arc<RingQueue<SeqTile>>> = stage_in_iter
                .next()
                .expect("stage_in parallel to stages")
                .into_iter()
                .map(|q| q.expect("validated above"))
                .collect();
            let routes = out_routes_iter.next().expect("out_routes parallel to stages");
            let n_ports = in_queues.len();
            let workers = workers_of(si);
            let shared = Arc::new(TrainStageShared {
                name: sp.name.clone(),
                si,
                program: sp.program.clone(),
                exec_plan: sp.program.plan(),
                param_idx: sp.param_idx.clone(),
                params: Arc::clone(&cparams),
                prec: plan.prec,
                in_queues,
                routes,
                sink_q: Arc::clone(&sink_q),
                table: Arc::clone(&table),
                dead: Arc::clone(&dead),
                fault: Arc::clone(&fault),
                health: Arc::clone(&health),
                tiles_seen: AtomicU64::new(0),
                intake: Mutex::new(Intake {
                    counter: 0,
                    partial: (0..n_ports).map(|_| None).collect(),
                    closing: false,
                }),
                emit: Mutex::new(Emit {
                    next: 0,
                    ready: BTreeMap::new(),
                    inflight: None,
                    poisoned: false,
                }),
                live: AtomicUsize::new(workers),
                all_latch: Arc::clone(&all_latch),
                svc_live: Arc::clone(&svc_live),
                sched: Arc::clone(&scheduler),
                telemetry: Arc::clone(&telemetry),
            });
            for _ in 0..workers {
                let pump =
                    TrainPump { shared: Arc::clone(&shared), closer: false, parked: None };
                scheduler.spawn(Box::new(move || pump.run()));
            }
        }

        // Sink pump: route tap deliveries into the step table.
        let sink = TrainSinkPump {
            q: Arc::clone(&sink_q),
            table: Arc::clone(&table),
            svc_live: Arc::clone(&svc_live),
            sched: Arc::clone(&scheduler),
        };
        scheduler.spawn(Box::new(move || sink.run()));

        Ok(TrainService {
            plan,
            params,
            cparams,
            src_routes,
            table,
            svc_live,
            spawned,
            step_lock: Mutex::new(()),
            dead,
            shut: AtomicBool::new(false),
            fault,
            health,
            steps: AtomicU64::new(0),
            telemetry,
        })
    }

    pub fn plan(&self) -> &TrainPlan {
        &self.plan
    }

    /// Snapshot of the current parameter values (plan order).
    pub fn param_values(&self) -> Vec<Tensor> {
        self.params.read().unwrap().clone()
    }

    /// Pump tasks this service spawned (stage pumps + sink) — kept
    /// under the historical name from the dedicated-thread runtime.
    pub fn threads_spawned(&self) -> usize {
        self.spawned
    }

    /// Current supervision state of the pipeline.
    pub fn health(&self) -> Health {
        self.health.snapshot()
    }

    /// Shared handle to the supervision state machine.
    pub fn health_state(&self) -> Arc<HealthState> {
        Arc::clone(&self.health)
    }

    /// This pipeline's full telemetry (stages, edges, traffic) — also
    /// reachable process-wide via [`crate::telemetry::snapshot`].
    pub fn telemetry(&self) -> &Arc<PipelineTelemetry> {
        &self.telemetry
    }

    /// Run one microbatch step: `tiles[port][seq]` per source port.
    /// Blocks until every tap drained, then folds gradients/loss in tile
    /// order. One step runs at a time; parameter updates happen outside
    /// (see [`crate::train::Trainer`]).
    ///
    /// A stage failure mid-step poisons only this step's afflicted
    /// tiles: the step drains fully, returns the typed
    /// [`crate::runtime::RuntimeError::StageFailed`], and the next step
    /// runs on a clean pipeline.
    pub fn run_step(&self, tiles: Vec<Vec<Tensor>>) -> Result<StepOutput> {
        let _step = self.step_lock.lock().unwrap();
        ensure!(
            !self.dead.load(Ordering::Acquire) && !self.shut.load(Ordering::Acquire),
            "training pipeline is shut down"
        );
        let step = self.steps.fetch_add(1, Ordering::Relaxed);
        let mut tiles = tiles;
        let n_tiles = validate_tiles(&self.plan, &tiles)?;
        if self.plan.prec != Precision::F32 {
            // Storage boundaries: refresh the stages' compute copy from
            // the f32 masters (the optimizer ran since the last step),
            // and round the source tiles to the storage grid before they
            // enter the pipeline. The pipeline is drained between steps,
            // so no kernel holds the compute store here.
            {
                let master = self.params.read().unwrap();
                let mut compute = self.cparams.write().unwrap();
                for (c, m) in compute.iter_mut().zip(master.iter()) {
                    *c = m.quantized(self.plan.prec);
                }
            }
            for per_src in &mut tiles {
                for t in per_src {
                    t.quantize(self.plan.prec);
                }
            }
        }
        self.table.begin(self.plan.taps.len(), n_tiles);
        'feed: for seq in 0..n_tiles {
            for (port, routes) in self.src_routes.iter().enumerate() {
                for q in routes {
                    let bytes = tiles[port][seq].payload_bytes();
                    let mut payload = (seq, Envelope::Ok(tiles[port][seq].clone()));
                    loop {
                        match q.try_push(payload) {
                            Ok(()) => {
                                account_push(q, &self.telemetry.traffic, bytes);
                                break;
                            }
                            Err(PushError::Closed(_)) => {
                                self.table.fail(StageFailure::closed("source feed"));
                                break 'feed;
                            }
                            Err(PushError::Full(p)) => {
                                // A dead pipeline stops draining; bail out
                                // instead of blocking on a full queue. (The
                                // killing pump recorded the real cause
                                // first — this fail is its fallback.)
                                if self.dead.load(Ordering::Acquire) {
                                    self.table.fail(StageFailure::closed("source feed"));
                                    break 'feed;
                                }
                                payload = p;
                                q.wait_space();
                            }
                        }
                    }
                }
            }
        }
        let slots = self.table.wait()?;
        let mut out = fold_taps(&self.plan, slots)?;
        // Deterministic numeric-fault injection (`nan:loss:step=N` /
        // `nan:grad:step=N`): corrupt the folded step result so the
        // trainer's non-finite guard is exercised end to end.
        if self.fault.take_nan_loss(step) {
            out.loss = f32::NAN;
        }
        if self.fault.take_nan_grad(step) {
            if let Some(g) = out.grads.iter_mut().flatten().next() {
                if let Some(v) = g.data.first_mut() {
                    *v = f32::NAN;
                }
            }
        }
        // A fully drained, fully live step proves the stage recovered.
        self.health.restore();
        Ok(out)
    }

    /// Close every source queue and drain the pump tasks. Idempotent;
    /// waits out an in-flight step first. Must be called from outside
    /// the scheduler's worker pool (the step/Drop path always is).
    pub fn shutdown(&self) {
        {
            let _step = self.step_lock.lock().unwrap();
            if self.shut.swap(true, Ordering::AcqRel) {
                return;
            }
            for routes in &self.src_routes {
                for q in routes {
                    q.close();
                }
            }
        }
        self.svc_live.wait_zero();
    }
}

impl Drop for TrainService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Tiles a pump processes before requeueing itself (FIFO) so sibling
/// pumps and other stages get scheduler time.
const TRAIN_PUMP_YIELD: usize = 8;
/// Sink pump batch size per `try_pop_many` call.
const TRAIN_SINK_BURST: usize = 64;

/// Intake side of a stage, under one lock: gather one tile from every
/// input port, then stamp the complete set with a monotonic arrival
/// index. Queue edges are FIFO and single-consumer-locked here, so
/// arrival order equals submission order — within a step, `seq` order.
struct Intake {
    /// Next arrival index (monotonic across steps; never reset).
    counter: usize,
    /// Partially gathered set: one slot per input port.
    partial: Vec<Option<SeqTile>>,
    /// An input edge closed; no further sets will be gathered.
    closing: bool,
}

/// Emission side of a stage: the sequence reorder buffer. Pumps insert
/// computed outputs keyed by arrival index; `flush` routes them
/// strictly in arrival order, so multi-worker stages emit exactly the
/// single-worker (and serial-oracle) tile order.
struct Emit {
    /// Arrival index the next emission must carry.
    next: usize,
    /// Completed, not-yet-emitted outputs keyed by arrival index.
    ready: BTreeMap<usize, EmitItem>,
    /// An emission mid-route that hit a full queue; resumed before any
    /// later arrival is considered (single-emitter invariant).
    inflight: Option<Inflight>,
    /// A downstream queue closed (shutdown or failure cascade); later
    /// emissions are dropped instead of routed.
    poisoned: bool,
}

struct EmitItem {
    seq: usize,
    outs: Vec<Envelope<Tensor>>,
}

/// Routing cursor for one emission: `outs[port]` is taken by the last
/// route of that port (earlier routes clone), and `(port, route)` marks
/// where to resume after a Full stall.
struct Inflight {
    seq: usize,
    outs: Vec<Option<Envelope<Tensor>>>,
    port: usize,
    route: usize,
}

enum GatherResult {
    /// A complete, sequence-aligned input set.
    Ready { arrival: usize, seq: usize, tiles: Vec<Envelope<Tensor>> },
    /// Input port `.0` has nothing buffered yet.
    Empty(usize),
    /// An input edge closed: end of stream.
    Closed,
    /// Input edges delivered mismatched `seq`s — a wiring bug.
    Desync,
}

/// Which queue event a stalled pump must wait for.
enum Parked {
    Item(Arc<RingQueue<SeqTile>>),
    Space(Arc<RingQueue<SeqTile>>),
    SinkSpace(Arc<RingQueue<SinkItem>>),
}

enum FlushOutcome {
    /// Nothing further to emit right now (buffer empty or gap at `next`).
    Clear,
    /// Emission blocked on a full downstream queue.
    Stall(Parked),
}

enum RouteOutcome {
    Done { saw_closed: bool },
    Stall(Inflight, Parked),
}

/// Everything a stage's pumps share.
struct TrainStageShared {
    name: String,
    /// Stage index in `TrainPlan::stages` — the coordinate
    /// `panic:stage=N` fault specs key on.
    si: usize,
    program: Program,
    exec_plan: ExecPlan,
    param_idx: Vec<usize>,
    params: Arc<RwLock<Vec<Tensor>>>,
    /// Storage width for this stage's emitted tiles (tiles are rounded
    /// to the grid before crossing any edge; identity for f32).
    prec: Precision,
    in_queues: Vec<Arc<RingQueue<SeqTile>>>,
    routes: Vec<Vec<Route>>,
    sink_q: Arc<RingQueue<SinkItem>>,
    table: Arc<StepTable>,
    dead: Arc<AtomicBool>,
    fault: Arc<FaultPlan>,
    health: Arc<HealthState>,
    /// Live tile sets this stage has computed — the `tile=N` injection
    /// coordinate (monotonic across steps; poisoned sets don't count).
    tiles_seen: AtomicU64,
    intake: Mutex<Intake>,
    emit: Mutex<Emit>,
    /// Pumps of this stage still running; the last to retire drains the
    /// reorder buffer and cascades the close downstream.
    live: AtomicUsize,
    /// Stages not yet fully retired; the last one closes the sink queue.
    all_latch: Arc<AtomicUsize>,
    svc_live: Arc<LiveCount>,
    sched: Arc<Scheduler>,
    telemetry: Arc<PipelineTelemetry>,
}

impl TrainStageShared {
    fn stat(&self) -> &StageTelemetry {
        &self.telemetry.stages[self.si]
    }

    /// Try to gather one sequence-aligned tile set under the intake lock.
    fn gather(&self) -> GatherResult {
        let mut intake = self.intake.lock().unwrap();
        if intake.closing {
            return GatherResult::Closed;
        }
        for (p, q) in self.in_queues.iter().enumerate() {
            if intake.partial[p].is_some() {
                continue;
            }
            match q.try_pop() {
                Ok(v) => intake.partial[p] = Some(v),
                Err(PopError::Empty) => return GatherResult::Empty(p),
                Err(PopError::Closed) => {
                    intake.closing = true;
                    return GatherResult::Closed;
                }
            }
        }
        let seq = intake.partial[0].as_ref().expect("slot filled above").0;
        if intake.partial.iter().any(|t| t.as_ref().expect("filled").0 != seq) {
            return GatherResult::Desync;
        }
        let arrival = intake.counter;
        intake.counter += 1;
        let tiles = intake
            .partial
            .iter_mut()
            .map(|t| t.take().expect("filled").1)
            .collect();
        GatherResult::Ready { arrival, seq, tiles }
    }

    /// Run the stage program on one gathered tile set against the
    /// current parameters (read lock held only for the kernel), under
    /// panic supervision: a panicking or erroring kernel becomes a
    /// typed [`StageFailure`] instead of unwinding into the scheduler.
    fn compute(&self, tile_seq: u64, tiles: &[Tensor]) -> std::result::Result<Vec<Tensor>, StageFailure> {
        catch_stage(&self.name, Some(self.si), Some(tile_seq), || {
            self.fault.maybe_panic(self.si, tile_seq);
            let guard = self.params.read().unwrap();
            let mut args: Vec<&Tensor> = tiles.iter().collect();
            args.extend(self.param_idx.iter().map(|&i| &guard[i]));
            self.program.run_with_plan(&args, &[], &self.exec_plan)
        })
    }

    /// Park a computed tile set in the reorder buffer.
    fn insert(&self, arrival: usize, seq: usize, outs: Vec<Envelope<Tensor>>) {
        let mut emit = self.emit.lock().unwrap();
        emit.ready.insert(arrival, EmitItem { seq, outs });
    }

    /// Drain the reorder buffer in arrival order. The emit lock is held
    /// only to take/advance; routing happens outside it. Because `next`
    /// advances only after an item is fully routed, at most one pump
    /// routes at a time — concurrent callers see a gap and return
    /// `Clear`.
    fn flush(&self) -> FlushOutcome {
        loop {
            let (inflight, poisoned) = {
                let mut emit = self.emit.lock().unwrap();
                let inf = match emit.inflight.take() {
                    Some(inf) => inf,
                    None => {
                        let next = emit.next;
                        match emit.ready.remove(&next) {
                            Some(item) => Inflight {
                                seq: item.seq,
                                outs: item.outs.into_iter().map(Some).collect(),
                                port: 0,
                                route: 0,
                            },
                            None => return FlushOutcome::Clear,
                        }
                    }
                };
                (inf, emit.poisoned)
            };
            let outcome = if poisoned {
                // Downstream already closed; drop the payload.
                RouteOutcome::Done { saw_closed: true }
            } else {
                self.route_inflight(inflight)
            };
            match outcome {
                RouteOutcome::Done { saw_closed } => {
                    let mut emit = self.emit.lock().unwrap();
                    emit.next += 1;
                    if saw_closed {
                        emit.poisoned = true;
                    }
                }
                RouteOutcome::Stall(inf, parked) => {
                    self.emit.lock().unwrap().inflight = Some(inf);
                    return FlushOutcome::Stall(parked);
                }
            }
        }
    }

    /// Route one emission from its cursor: per output port, clone for
    /// every consumer but the last (same multicast order as the serial
    /// executor). `Closed` destinations swallow the payload — that only
    /// happens during a shutdown or failure cascade, when no step is
    /// waiting on the tiles.
    fn route_inflight(&self, mut inf: Inflight) -> RouteOutcome {
        let mut saw_closed = false;
        while inf.port < self.routes.len() {
            let port_routes = &self.routes[inf.port];
            let n = port_routes.len();
            if n == 0 || inf.outs[inf.port].is_none() {
                inf.port += 1;
                inf.route = 0;
                continue;
            }
            while inf.route < n {
                let last = inf.route == n - 1;
                let payload = if last {
                    inf.outs[inf.port].take().expect("checked above")
                } else {
                    inf.outs[inf.port].as_ref().expect("checked above").clone()
                };
                let bytes = env_payload_bytes(&payload);
                match &port_routes[inf.route] {
                    Route::Queue(q) => match q.try_push((inf.seq, payload)) {
                        Ok(()) => account_push(q, &self.telemetry.traffic, bytes),
                        Err(PushError::Closed(_)) => saw_closed = true,
                        Err(PushError::Full((_, p))) => {
                            if last {
                                inf.outs[inf.port] = Some(p);
                            }
                            return RouteOutcome::Stall(inf, Parked::Space(Arc::clone(q)));
                        }
                    },
                    Route::Sink(tap) => match self.sink_q.try_push((*tap, inf.seq, payload)) {
                        Ok(()) => account_push(&self.sink_q, &self.telemetry.traffic, bytes),
                        Err(PushError::Closed(_)) => saw_closed = true,
                        Err(PushError::Full((_, _, p))) => {
                            if last {
                                inf.outs[inf.port] = Some(p);
                            }
                            return RouteOutcome::Stall(
                                inf,
                                Parked::SinkSpace(Arc::clone(&self.sink_q)),
                            );
                        }
                    },
                }
                inf.route += 1;
            }
            inf.port += 1;
            inf.route = 0;
        }
        RouteOutcome::Done { saw_closed }
    }
}

/// One cooperative stage worker. Runs as a scheduler task: it never
/// blocks a pool thread — on an empty input or full output it registers
/// a queue waker that respawns it, and returns. The pump that retires
/// last flips into *closer* mode: it drains the reorder buffer, then
/// cascades the close to downstream edges.
struct TrainPump {
    shared: Arc<TrainStageShared>,
    closer: bool,
    /// When and where the pump parked, for wait-time attribution on
    /// resume: input starvation (queue-wait) vs downstream backpressure
    /// (emit).
    parked: Option<(Instant, Parked)>,
}

impl TrainPump {
    fn run(mut self) {
        if let Some((p0, side)) = self.parked.take() {
            let waited = p0.elapsed();
            let ns = waited.as_nanos().min(u128::from(u64::MAX)) as u64;
            match side {
                Parked::Item(q) => {
                    self.shared.stat().queue_wait.record(waited);
                    if let Some(e) = q.telemetry() {
                        e.empty_stall_ns.add(ns);
                    }
                }
                Parked::Space(q) => {
                    self.shared.stat().emit.record(waited);
                    if let Some(e) = q.telemetry() {
                        e.full_stall_ns.add(ns);
                    }
                }
                Parked::SinkSpace(q) => {
                    self.shared.stat().emit.record(waited);
                    if let Some(e) = q.telemetry() {
                        e.full_stall_ns.add(ns);
                    }
                }
            }
        }
        if self.closer {
            match self.shared.flush() {
                // A gap at `next` here means the pump that owned that
                // arrival died (structural failure) — abandon the rest.
                FlushOutcome::Clear => self.cascade_close(),
                FlushOutcome::Stall(parked) => self.park(parked),
            }
            return;
        }
        let mut quota = TRAIN_PUMP_YIELD;
        loop {
            if let FlushOutcome::Stall(parked) = self.shared.flush() {
                return self.park(parked);
            }
            match self.shared.gather() {
                GatherResult::Ready { arrival, seq, tiles } => {
                    let n_ports = self.shared.routes.len();
                    // Merge the input envelopes: any poison skips
                    // compute and forwards on every port, keeping the
                    // reorder buffer gapless and consumers seq-aligned.
                    let mut poison: Option<StageFailure> = None;
                    let mut live: Vec<Tensor> = Vec::with_capacity(tiles.len());
                    for env in tiles {
                        match env {
                            Envelope::Ok(t) => live.push(t),
                            Envelope::Poison(f) => {
                                if poison.is_none() {
                                    poison = Some(f);
                                }
                            }
                        }
                    }
                    let outs: Vec<Envelope<Tensor>> = match poison {
                        Some(f) => vec![Envelope::Poison(f); n_ports],
                        None => {
                            let tile_seq =
                                self.shared.tiles_seen.fetch_add(1, Ordering::Relaxed);
                            self.shared.stat().tiles_in.inc();
                            let b0 = Instant::now();
                            match self.shared.compute(tile_seq, &live) {
                                Ok(outs) if outs.len() == n_ports => {
                                    let stat = self.shared.stat();
                                    stat.compute.record(b0.elapsed());
                                    stat.tiles_out.inc();
                                    self.shared
                                        .telemetry
                                        .traffic
                                        .weight_bytes
                                        .add(stat.weight_bytes_per_tile);
                                    trace::span("train", &stat.name, Some(tile_seq), b0);
                                    // Storage boundary: outputs cross
                                    // edges at the plan's storage width.
                                    outs.into_iter()
                                        .map(|mut t| {
                                            t.quantize(self.shared.prec);
                                            Envelope::Ok(t)
                                        })
                                        .collect()
                                }
                                Ok(outs) => {
                                    // Wrong arity is a wiring bug, not a
                                    // per-tile fault: downstream port
                                    // accounting is unsalvageable.
                                    self.shared.dead.store(true, Ordering::Release);
                                    self.shared.health.fail(&self.shared.name);
                                    self.shared.table.fail(
                                        StageFailure::new(
                                            &self.shared.name,
                                            FailureCause::Kernel(format!(
                                                "{} outputs for {n_ports} ports",
                                                outs.len()
                                            )),
                                        )
                                        .at_index(self.shared.si),
                                    );
                                    return self.retire();
                                }
                                Err(failure) => {
                                    // Contained: this tile set becomes
                                    // poison; the pump (and the step's
                                    // other tiles) keep going.
                                    self.shared.health.degrade(&self.shared.name);
                                    vec![Envelope::Poison(failure); n_ports]
                                }
                            }
                        }
                    };
                    self.shared.insert(arrival, seq, outs);
                    quota -= 1;
                    if quota == 0 {
                        // Requeue FIFO so siblings and other stages run.
                        let sched = Arc::clone(&self.shared.sched);
                        sched.spawn(Box::new(move || self.run()));
                        return;
                    }
                }
                GatherResult::Empty(p) => {
                    let q = Arc::clone(&self.shared.in_queues[p]);
                    return self.park(Parked::Item(q));
                }
                GatherResult::Desync => {
                    self.shared.dead.store(true, Ordering::Release);
                    self.shared.health.fail(&self.shared.name);
                    self.shared.table.fail(
                        StageFailure::new(
                            &self.shared.name,
                            FailureCause::Kernel("input streams desynchronized".to_string()),
                        )
                        .at_index(self.shared.si),
                    );
                    return self.retire();
                }
                GatherResult::Closed => return self.retire(),
            }
        }
    }

    /// Register a waker that respawns this pump when the queue event
    /// fires, then yield the pool thread. Parked pumps still count as
    /// live: `close()` fires all registered wakers, so a shutdown or
    /// failure cascade always resumes (and then retires) them.
    fn park(mut self, parked: Parked) {
        // Stash a second handle to the stalled edge so the resume path
        // can attribute the wait (stage queue-wait vs emit histogram,
        // per-edge stall time).
        let resume = match &parked {
            Parked::Item(q) => Parked::Item(Arc::clone(q)),
            Parked::Space(q) => Parked::Space(Arc::clone(q)),
            Parked::SinkSpace(q) => Parked::SinkSpace(Arc::clone(q)),
        };
        self.parked = Some((Instant::now(), resume));
        let sched = Arc::clone(&self.shared.sched);
        let waker = Box::new(move || {
            sched.spawn(Box::new(move || self.run()));
        });
        match parked {
            Parked::Item(q) => q.park_on_item(waker),
            Parked::Space(q) => q.park_on_space(waker),
            Parked::SinkSpace(q) => q.park_on_space(waker),
        }
    }

    /// This pump is done serving. The last of a stage's pumps re-enters
    /// as the closer (recursion depth one: closer mode never retires).
    fn retire(mut self) {
        if self.shared.live.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.closer = true;
            self.run();
        } else {
            self.shared.svc_live.done();
        }
    }

    /// Cascade the stage's exit both ways: upstream producers blocked on
    /// our inputs observe Closed instead of hanging, downstream
    /// consumers see end-of-stream. The last stage overall closes the
    /// sink queue.
    fn cascade_close(&self) {
        for q in &self.shared.in_queues {
            q.close();
        }
        for port in &self.shared.routes {
            for r in port {
                if let Route::Queue(q) = r {
                    q.close();
                }
            }
        }
        if self.shared.all_latch.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.shared.sink_q.close();
        }
        self.shared.svc_live.done();
    }
}

/// Cooperative sink pump: drains tap deliveries into the step table in
/// bursts, parking on the sink queue when it runs dry.
struct TrainSinkPump {
    q: Arc<RingQueue<SinkItem>>,
    table: Arc<StepTable>,
    svc_live: Arc<LiveCount>,
    sched: Arc<Scheduler>,
}

impl TrainSinkPump {
    fn run(self) {
        let mut buf: Vec<SinkItem> = Vec::with_capacity(TRAIN_SINK_BURST);
        for _ in 0..TRAIN_PUMP_YIELD {
            match self.q.try_pop_many(&mut buf, TRAIN_SINK_BURST) {
                Ok(_) => {
                    for (tap, seq, env) in buf.drain(..) {
                        match env {
                            Envelope::Ok(t) => self.table.complete(tap, seq, t),
                            Envelope::Poison(f) => self.table.poison(tap, seq, f),
                        }
                    }
                }
                Err(PopError::Empty) => {
                    let sched = Arc::clone(&self.sched);
                    let q = Arc::clone(&self.q);
                    q.park_on_item(Box::new(move || {
                        sched.spawn(Box::new(move || self.run()));
                    }));
                    return;
                }
                Err(PopError::Closed) => {
                    // If a step is mid-flight when the sink stream ends,
                    // its outstanding deliveries will never arrive —
                    // unblock the waiter with a typed shutdown failure
                    // instead of hanging it. (No-op between steps, so
                    // orderly shutdown stays silent.)
                    self.table.fail(StageFailure::closed("sink"));
                    self.svc_live.done();
                    return;
                }
            }
        }
        let sched = Arc::clone(&self.sched);
        sched.spawn(Box::new(move || self.run()));
    }
}

/// Check one step's tile table against the plan: every source supplies
/// the same number of `[tile_rows, d]` tiles. Returns the tile count.
fn validate_tiles(plan: &TrainPlan, tiles: &[Vec<Tensor>]) -> Result<usize> {
    ensure!(
        tiles.len() == plan.sources.len(),
        "step supplies {} sources, plan has {} ({:?})",
        tiles.len(),
        plan.sources.len(),
        plan.sources.iter().map(|s| s.name.as_str()).collect::<Vec<_>>()
    );
    let n_tiles = tiles.first().map(|t| t.len()).unwrap_or(0);
    ensure!(n_tiles > 0, "step needs at least one tile");
    for (port, (per_src, spec)) in tiles.iter().zip(&plan.sources).enumerate() {
        ensure!(
            per_src.len() == n_tiles,
            "source `{}` supplies {} tiles, expected {n_tiles}",
            spec.name,
            per_src.len()
        );
        let want = vec![plan.tile_rows, spec.dims[1]];
        for t in per_src {
            ensure!(
                t.dims == want,
                "source `{}` (port {port}) tile dims {:?} != {want:?}",
                spec.name,
                t.dims
            );
        }
    }
    Ok(n_tiles)
}

/// Fold completed tap slots into the step result — mean over tiles in
/// tile order, identical for the pipeline and the serial oracle.
fn fold_taps(plan: &TrainPlan, mut slots: Vec<Vec<Option<Tensor>>>) -> Result<StepOutput> {
    let mut loss = f32::NAN;
    let mut grads: Vec<Option<Tensor>> = vec![None; plan.params.len()];
    for (tap, spec) in plan.taps.iter().enumerate() {
        let folded = mean_in_order(std::mem::take(&mut slots[tap]))?;
        match spec.kind {
            TapKind::Loss => loss = folded.scalar_value(),
            TapKind::Grad { param } => grads[param] = Some(folded),
        }
    }
    Ok(StepOutput { loss, grads })
}

/// Serial oracle / baseline: execute the same stage programs tile by
/// tile on the calling thread (explicit `params`, plan order) and fold
/// the same taps. Bitwise-identical to the pipeline by construction —
/// same programs, same per-tile values, same fold order. Stage panics
/// are supervised the same way as in the pipeline: converted to a typed
/// [`StageFailure`] instead of unwinding into the caller.
pub fn serial_step(
    plan: &TrainPlan,
    params: &[Tensor],
    tiles: &[Vec<Tensor>],
) -> Result<StepOutput> {
    ensure!(
        params.len() == plan.params.len(),
        "serial step got {} params, plan has {}",
        params.len(),
        plan.params.len()
    );
    let n_tiles = validate_tiles(plan, tiles)?;
    // Mirror the pipeline's storage boundaries exactly: quantized
    // compute copies of the params, quantized source tiles, and (below)
    // quantized stage outputs — so pipeline == serial stays bitwise in
    // every precision mode. All three are identity for f32.
    let qparams: Option<Vec<Tensor>> = (plan.prec != Precision::F32)
        .then(|| params.iter().map(|p| p.quantized(plan.prec)).collect());
    let params: &[Tensor] = qparams.as_deref().unwrap_or(params);
    let qtiles: Option<Vec<Vec<Tensor>>> = (plan.prec != Precision::F32).then(|| {
        tiles
            .iter()
            .map(|per_src| per_src.iter().map(|t| t.quantized(plan.prec)).collect())
            .collect()
    });
    let tiles: &[Vec<Tensor>] = qtiles.as_deref().unwrap_or(tiles);
    let exec_plans: Vec<ExecPlan> = plan.stages.iter().map(|s| s.program.plan()).collect();
    // Per-stage input edges by port, plus the sink edges.
    let mut in_edges: Vec<Vec<&crate::coordinator::PipeEdge>> =
        vec![Vec::new(); plan.stages.len()];
    let mut sink_edges: Vec<&crate::coordinator::PipeEdge> = Vec::new();
    for e in &plan.pipeline.edges {
        match e.to {
            Some(to) => in_edges[to].push(e),
            None => sink_edges.push(e),
        }
    }
    for edges in &mut in_edges {
        edges.sort_by_key(|e| e.to_port);
    }

    let mut slots: Vec<Vec<Option<Tensor>>> = vec![vec![None; n_tiles]; plan.taps.len()];
    for seq in 0..n_tiles {
        let mut vals: HashMap<(usize, usize), Tensor> = HashMap::new();
        for (si, sp) in plan.stages.iter().enumerate() {
            let outs = {
                let mut args: Vec<&Tensor> = Vec::with_capacity(sp.n_stream + sp.param_idx.len());
                for e in &in_edges[si] {
                    let v = match e.from {
                        None => &tiles[e.from_port][seq],
                        Some(ps) => vals
                            .get(&(ps, e.from_port))
                            .ok_or_else(|| anyhow!("edge {e:?} has no produced value"))?,
                    };
                    args.push(v);
                }
                args.extend(sp.param_idx.iter().map(|&i| &params[i]));
                catch_stage(&sp.name, Some(si), Some(seq as u64), || {
                    sp.program.run_with_plan(&args, &[], &exec_plans[si])
                })
                .map_err(|f| f.into_error())?
            };
            for (p, mut o) in outs.into_iter().enumerate() {
                o.quantize(plan.prec);
                vals.insert((si, p), o);
            }
        }
        for e in &sink_edges {
            let from = e.from.expect("sink edges originate at stages");
            let v = vals
                .get(&(from, e.from_port))
                .ok_or_else(|| anyhow!("sink edge {e:?} has no produced value"))?
                .clone();
            slots[e.to_port][seq] = Some(v);
        }
    }
    fold_taps(plan, slots)
}
