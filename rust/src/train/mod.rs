//! `kitsune::train` — end-to-end dataflow training on the real pipeline.
//!
//! Where [`crate::session`] serves *inference* graphs through a linear
//! warm pipeline, this module executes *training* graphs — forward,
//! backward, loss, and optimizer — on a persistent DAG pipeline with the
//! multicast fan-out and skip-link queue edges backward passes need
//! (paper §6.4: training is where dataflow execution wins most, 1.1×–2.4×
//! and 16%–42% traffic reduction in Figs 12/14).
//!
//! ```no_run
//! use kitsune::apps::nerf;
//! use kitsune::session::Session;
//! use kitsune::train::OptimizerKind;
//!
//! let cfg = nerf::NerfConfig {
//!     batch: 256, pos_enc: 12, dir_enc: 8, hidden: 32, depth: 4, skip_at: 2,
//! };
//! let session = Session::builder().graph(nerf::training(&cfg)).build()?;
//! let mut trainer = session.trainer_with(OptimizerKind::adam(1e-3))?;
//! let batch = session.make_train_batch(0xDA7A)?;
//! for step in 0..100 {
//!     let stats = trainer.step(&batch)?;
//!     println!("step {step}: loss {:.6}", stats.loss);
//! }
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! The pieces:
//!
//! * [`lower::lower_training`] — autodiff graph → [`TrainPlan`] (DAG
//!   [`SpatialPipeline`](crate::coordinator::SpatialPipeline) + per-stage
//!   SSA programs + parameter/tap registry);
//! * [`exec::TrainService`] — persistent per-stage workers and per-edge
//!   ring queues, one microbatch step at a time; [`exec::serial_step`]
//!   is the bitwise serial oracle and the speedup baseline;
//! * [`accumulate`] — tile-order gradient averaging at the sink;
//! * [`optimizer`] — `Sgd { momentum }` / `Adam` over named parameter
//!   state, applied as interpreter programs in the weight-update stage;
//! * [`Trainer`] — the loop driver: step → accumulate → update → next.

pub mod accumulate;
pub mod exec;
pub mod lower;
pub mod optimizer;

pub use accumulate::mean_in_order;
pub use exec::{serial_step, StepOutput, TrainService};
pub use lower::{
    lower_training, ParamSpec, SourceSpec, StagePlan, TapKind, TapSpec, TrainPlan,
};
pub use optimizer::{Optimizer, OptimizerKind, DEFAULT_LR};

use crate::runtime::{Rng, Tensor};
use crate::Result;
use anyhow::ensure;
use std::time::Instant;

/// One full-batch training input set: `inputs[i]` pairs with
/// `TrainPlan::sources[i]` (graph inputs ++ target), each `[batch, d]`.
#[derive(Debug, Clone)]
pub struct TrainBatch {
    pub inputs: Vec<Tensor>,
}

impl TrainBatch {
    /// Deterministic synthetic batch for a plan: normal data for graph
    /// inputs, uniform `[0, 1)` targets (the suite's heads are
    /// sigmoid-bounded, so the regression is learnable).
    pub fn synthetic(plan: &TrainPlan, seed: u64) -> TrainBatch {
        let mut rng = Rng::new(seed);
        let inputs = plan
            .sources
            .iter()
            .map(|src| {
                let numel: usize = src.dims.iter().product();
                let data: Vec<f32> = if src.name == "target" {
                    (0..numel).map(|_| rng.uniform()).collect()
                } else {
                    (0..numel).map(|_| rng.normal()).collect()
                };
                Tensor { dims: src.dims.clone(), data, prec: crate::runtime::Precision::F32 }
            })
            .collect();
        TrainBatch { inputs }
    }
}

/// Slice a full batch into the plan's `[tile_rows, d]` row tiles:
/// `result[port][seq]`. Shared by the pipeline path, the serial oracle,
/// and the benches so all three stream identical tiles.
pub fn split_batch(plan: &TrainPlan, batch: &TrainBatch) -> Result<Vec<Vec<Tensor>>> {
    ensure!(
        batch.inputs.len() == plan.sources.len(),
        "batch supplies {} inputs, plan streams {} sources",
        batch.inputs.len(),
        plan.sources.len()
    );
    let mut out = Vec::with_capacity(batch.inputs.len());
    for (t, src) in batch.inputs.iter().zip(&plan.sources) {
        ensure!(
            t.dims == src.dims,
            "source `{}` dims {:?} != plan dims {:?}",
            src.name,
            t.dims,
            src.dims
        );
        let d = src.dims[1];
        let rows = plan.tile_rows;
        ensure!(rows * d > 0, "source `{}` has an empty tile shape [{rows}, {d}]", src.name);
        let tiles: Vec<Tensor> = t
            .data
            .chunks(rows * d)
            .map(|chunk| Tensor {
                dims: vec![rows, d],
                data: chunk.to_vec(),
                prec: crate::runtime::Precision::F32,
            })
            .collect();
        out.push(tiles);
    }
    Ok(out)
}

/// Whether a step's optimizer update was applied, or skipped by the
/// non-finite guard.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StepOutcome {
    /// Loss and gradients were finite; the update was applied.
    Applied,
    /// A non-finite loss or gradient was detected: the optimizer update
    /// (and its step count) was skipped and the parameters are bitwise
    /// unchanged — one bad microbatch never corrupts the weights.
    Skipped { reason: String },
}

/// Statistics of one optimizer step.
#[derive(Debug, Clone)]
pub struct StepStats {
    /// Mean per-tile loss of the microbatch.
    pub loss: f32,
    /// The averaged gradients applied this step (tap order: one entry
    /// per tapped parameter, named). Empty when the step was skipped.
    pub grads: Vec<(String, Tensor)>,
    /// Tiles streamed through the pipeline this step.
    pub tiles: usize,
    /// Wall time from submit to parameters updated.
    pub elapsed_s: f64,
    /// Applied, or skipped by the non-finite guard.
    pub outcome: StepOutcome,
}

/// The training loop driver: streams microbatches through the warm DAG
/// pipeline, folds gradients, and applies the optimizer to the shared
/// parameter store — step → accumulate → update → next step, with the
/// worker pools persistent across all of it.
pub struct Trainer<'s> {
    service: &'s TrainService,
    optimizer: Optimizer,
}

impl<'s> Trainer<'s> {
    /// Wrap a running [`TrainService`] with an optimizer.
    pub fn new(service: &'s TrainService, kind: OptimizerKind) -> Trainer<'s> {
        Trainer { service, optimizer: Optimizer::new(kind) }
    }

    pub fn plan(&self) -> &TrainPlan {
        self.service.plan()
    }

    pub fn optimizer(&self) -> &Optimizer {
        &self.optimizer
    }

    /// Completed optimizer steps.
    pub fn steps(&self) -> usize {
        self.optimizer.step_count()
    }

    /// Snapshot of the current parameters, named (plan order).
    pub fn params(&self) -> Vec<(String, Tensor)> {
        let names = self.plan().params.iter().map(|p| p.name.clone());
        names.zip(self.service.param_values()).collect()
    }

    /// One optimizer step over `batch`: split into tiles, stream through
    /// the pipeline, average gradients in tile order, apply the
    /// optimizer update to every tapped parameter.
    pub fn step(&mut self, batch: &TrainBatch) -> Result<StepStats> {
        let t0 = Instant::now();
        let plan = self.service.plan();
        let tiles = split_batch(plan, batch)?;
        let n_tiles = tiles[0].len();
        let StepOutput { loss, grads } = self.service.run_step(tiles)?;

        // Non-finite guard: a NaN/Inf loss or gradient (numeric blowup,
        // or an injected `nan:loss` fault) must never reach the
        // optimizer — skip the update, report it, keep training.
        let non_finite = if !loss.is_finite() {
            Some(format!("loss is {loss}"))
        } else {
            grads.iter().enumerate().find_map(|(i, grad)| {
                grad.as_ref().and_then(|g| {
                    g.data.iter().any(|v| !v.is_finite()).then(|| {
                        format!(
                            "gradient for `{}` has a non-finite element",
                            plan.params[i].name
                        )
                    })
                })
            })
        };
        if let Some(reason) = non_finite {
            return Ok(StepStats {
                loss,
                grads: Vec::new(),
                tiles: n_tiles,
                elapsed_s: t0.elapsed().as_secs_f64(),
                outcome: StepOutcome::Skipped { reason },
            });
        }

        // Weight-update stage: the pipeline is drained, so the write
        // lock is uncontended and stage workers see the new parameters
        // on the next step's first tile.
        let mut named: Vec<(String, Tensor)> = Vec::new();
        {
            let mut store = self.service.params.write().unwrap();
            for (i, grad) in grads.into_iter().enumerate() {
                let Some(grad) = grad else { continue };
                let name = plan.params[i].name.clone();
                store[i] = self.optimizer.update(&name, &store[i], &grad)?;
                named.push((name, grad));
            }
        }
        self.optimizer.end_step();
        Ok(StepStats {
            loss,
            grads: named,
            tiles: n_tiles,
            elapsed_s: t0.elapsed().as_secs_f64(),
            outcome: StepOutcome::Applied,
        })
    }
}
