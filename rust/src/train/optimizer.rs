//! Optimizers over named parameter state, executed as interpreter
//! programs: the weight-update stage of the training loop.
//!
//! Each update is a tiny SSA [`Program`] built from the optimizer
//! instructions ([`Instr::Axpy`], [`Instr::Blend`], [`Instr::Mul`],
//! [`Instr::AdamStep`]) and run on the same engine as the stage kernels
//! — the baked-in learning rate of the legacy `train_step` entry is
//! retired in favor of this configurable path (the entry survives as a
//! compat shim pinned to [`DEFAULT_LR`]).

use crate::runtime::interp::{Instr, Program};
use crate::runtime::Tensor;
use crate::Result;
use std::collections::HashMap;

/// The historical SGD learning rate (mirrors
/// `python/compile/model.py::LR`); default for [`OptimizerKind::Sgd`]
/// and the rate the legacy `train_step` entry is pinned to.
pub const DEFAULT_LR: f32 = 1e-2;

/// Optimizer configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OptimizerKind {
    /// SGD with optional momentum: `v' = momentum·v + g`,
    /// `p' = p - lr·v'` (momentum 0 = plain SGD, no state).
    Sgd { lr: f32, momentum: f32 },
    /// Adam (Kingma & Ba): EMA first/second moments with bias
    /// correction.
    Adam { lr: f32, beta1: f32, beta2: f32, eps: f32 },
}

impl Default for OptimizerKind {
    fn default() -> Self {
        OptimizerKind::Sgd { lr: DEFAULT_LR, momentum: 0.0 }
    }
}

impl OptimizerKind {
    /// Plain SGD at `lr`.
    pub fn sgd(lr: f32) -> Self {
        OptimizerKind::Sgd { lr, momentum: 0.0 }
    }

    /// Adam at `lr` with the standard β₁=0.9, β₂=0.999, ε=1e-8.
    pub fn adam(lr: f32) -> Self {
        OptimizerKind::Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// Optimizer state over named parameters. One [`Optimizer`] drives one
/// training run; state slots are created lazily per parameter name.
#[derive(Debug, Clone)]
pub struct Optimizer {
    kind: OptimizerKind,
    /// Completed optimizer steps (drives Adam's bias correction).
    t: usize,
    /// Per-parameter state: `[v]` for momentum SGD, `[m, v]` for Adam.
    state: HashMap<String, Vec<Tensor>>,
}

impl Optimizer {
    pub fn new(kind: OptimizerKind) -> Self {
        Optimizer { kind, t: 0, state: HashMap::new() }
    }

    pub fn kind(&self) -> OptimizerKind {
        self.kind
    }

    /// Completed optimizer steps.
    pub fn step_count(&self) -> usize {
        self.t
    }

    /// Apply one update for `name`, returning the new parameter value
    /// and advancing the parameter's state slots in place. Call
    /// [`Optimizer::end_step`] once after updating every parameter.
    ///
    /// Inputs are *borrowed* into the engine (`run_with_plan`), so the
    /// update never clones the parameter, gradient, or state tensors —
    /// the same zero-copy contract the stage kernels run under.
    pub fn update(&mut self, name: &str, param: &Tensor, grad: &Tensor) -> Result<Tensor> {
        match self.kind {
            OptimizerKind::Sgd { lr, momentum } if momentum == 0.0 => {
                let p = Program {
                    n_inputs: 2,
                    instrs: vec![Instr::Axpy { a: 0, b: 1, c: -lr }],
                    outputs: vec![2],
                };
                let plan = p.plan();
                Ok(p.run_with_plan(&[param, grad], &[], &plan)?.remove(0))
            }
            OptimizerKind::Sgd { lr, momentum } => {
                let v = self
                    .state
                    .entry(name.to_string())
                    .or_insert_with(|| vec![Tensor::zeros(&param.dims)]);
                let p = Program {
                    n_inputs: 3,
                    instrs: vec![
                        // v' = g + momentum·v
                        Instr::Axpy { a: 1, b: 2, c: momentum },
                        // p' = p - lr·v'
                        Instr::Axpy { a: 0, b: 3, c: -lr },
                    ],
                    outputs: vec![4, 3],
                };
                let plan = p.plan();
                let mut out = p.run_with_plan(&[param, grad, &v[0]], &[], &plan)?;
                v[0] = out.remove(1);
                Ok(out.remove(0))
            }
            OptimizerKind::Adam { lr, beta1, beta2, eps } => {
                let slots = self.state.entry(name.to_string()).or_insert_with(|| {
                    vec![Tensor::zeros(&param.dims), Tensor::zeros(&param.dims)]
                });
                let bc1 = 1.0 - beta1.powi(self.t as i32 + 1);
                let bc2 = 1.0 - beta2.powi(self.t as i32 + 1);
                let p = Program {
                    n_inputs: 4,
                    instrs: vec![
                        // m' = β₁·m + (1-β₁)·g
                        Instr::Blend { a: 2, b: 1, beta: beta1 },
                        // g²
                        Instr::Mul { a: 1, b: 1 },
                        // v' = β₂·v + (1-β₂)·g²
                        Instr::Blend { a: 3, b: 5, beta: beta2 },
                        // p' = p - lr·(m'/bc1)/(√(v'/bc2)+ε)
                        Instr::AdamStep { p: 0, m: 4, v: 6, lr, bc1, bc2, eps },
                    ],
                    outputs: vec![7, 4, 6],
                };
                let plan = p.plan();
                let mut out =
                    p.run_with_plan(&[param, grad, &slots[0], &slots[1]], &[], &plan)?;
                slots[1] = out.remove(2);
                slots[0] = out.remove(1);
                Ok(out.remove(0))
            }
        }
    }

    /// Advance the optimizer clock; call once per optimizer step after
    /// every parameter's [`Optimizer::update`].
    pub fn end_step(&mut self) {
        self.t += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: &[f32]) -> Tensor {
        Tensor { dims: vec![v.len()], data: v.to_vec(), prec: crate::runtime::Precision::F32 }
    }

    #[test]
    fn plain_sgd_matches_axpy() {
        let mut opt = Optimizer::new(OptimizerKind::sgd(0.1));
        let p = opt.update("w", &t(&[1.0, -2.0]), &t(&[10.0, 10.0])).unwrap();
        assert_eq!(p.data, vec![0.0, -3.0]);
        opt.end_step();
        assert_eq!(opt.step_count(), 1);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut opt = Optimizer::new(OptimizerKind::Sgd { lr: 1.0, momentum: 0.5 });
        // Step 1: v = g = 1 -> p = 1 - 1 = 0.
        let p1 = opt.update("w", &t(&[1.0]), &t(&[1.0])).unwrap();
        opt.end_step();
        assert_eq!(p1.data, vec![0.0]);
        // Step 2: v = 1 + 0.5*1 = 1.5 -> p = 0 - 1.5 = -1.5.
        let p2 = opt.update("w", &p1, &t(&[1.0])).unwrap();
        opt.end_step();
        assert_eq!(p2.data, vec![-1.5]);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // With bias correction, the first Adam step is ≈ lr * sign(g).
        let mut opt = Optimizer::new(OptimizerKind::adam(0.01));
        let p = opt.update("w", &t(&[1.0, 1.0]), &t(&[0.5, -3.0])).unwrap();
        opt.end_step();
        assert!((p.data[0] - (1.0 - 0.01)).abs() < 1e-4, "{:?}", p.data);
        assert!((p.data[1] - (1.0 + 0.01)).abs() < 1e-4, "{:?}", p.data);
        // Per-parameter state exists (m and v).
        assert_eq!(opt.state["w"].len(), 2);
    }

    #[test]
    fn state_is_per_parameter_name() {
        let mut opt = Optimizer::new(OptimizerKind::Sgd { lr: 0.1, momentum: 0.9 });
        opt.update("a", &t(&[1.0]), &t(&[1.0])).unwrap();
        opt.update("b", &t(&[1.0]), &t(&[2.0])).unwrap();
        opt.end_step();
        assert_eq!(opt.state["a"][0].data, vec![1.0]);
        assert_eq!(opt.state["b"][0].data, vec![2.0]);
    }
}
