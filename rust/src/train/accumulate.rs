//! Microbatch gradient accumulation: per-tile gradients (and losses)
//! arriving at the pipeline sink are folded **in tile order** and
//! averaged, so the pipeline's result is reproducible — a serial
//! re-execution of the same stage programs folds through this exact
//! function and matches bitwise. (A full-batch oracle differs only by
//! f32 re-association across the tile boundary; `tests/train_e2e.rs`
//! checks that case against finite differences instead.)

use crate::runtime::Tensor;
use crate::Result;
use anyhow::{anyhow, ensure};

/// Sum `tiles` in index order, then scale by `1 / tiles.len()` — the
/// mean per-tile contribution. Every slot must be filled and all tiles
/// must share dims.
pub fn mean_in_order(tiles: Vec<Option<Tensor>>) -> Result<Tensor> {
    let n = tiles.len();
    ensure!(n > 0, "gradient accumulation over zero tiles");
    let mut iter = tiles.into_iter().enumerate();
    let (_, first) = iter.next().expect("n > 0");
    let mut acc = first.ok_or_else(|| anyhow!("tile 0 missing from accumulation"))?;
    for (i, t) in iter {
        let t = t.ok_or_else(|| anyhow!("tile {i} missing from accumulation"))?;
        ensure!(
            t.dims == acc.dims,
            "tile {i} dims {:?} != accumulator dims {:?}",
            t.dims,
            acc.dims
        );
        for (a, &v) in acc.data.iter_mut().zip(&t.data) {
            *a += v;
        }
    }
    let inv = 1.0 / n as f32;
    for a in &mut acc.data {
        *a *= inv;
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: &[f32]) -> Option<Tensor> {
        Some(Tensor { dims: vec![v.len()], data: v.to_vec(), prec: crate::runtime::Precision::F32 })
    }

    #[test]
    fn means_in_tile_order() {
        let out = mean_in_order(vec![t(&[1.0, 2.0]), t(&[3.0, 4.0]), t(&[5.0, 6.0])]).unwrap();
        assert_eq!(out.data, vec![3.0, 4.0]);
    }

    #[test]
    fn missing_or_mismatched_tiles_are_errors() {
        assert!(mean_in_order(vec![t(&[1.0]), None]).is_err());
        assert!(mean_in_order(Vec::new()).is_err());
        let bad = vec![t(&[1.0, 2.0]), Some(Tensor::new(vec![1], vec![3.0]).unwrap())];
        assert!(mean_in_order(bad).is_err());
    }

    #[test]
    fn single_tile_is_identity_scaled() {
        let out = mean_in_order(vec![t(&[2.0, 4.0])]).unwrap();
        assert_eq!(out.data, vec![2.0, 4.0]);
    }
}
