//! Discrete-event, fluid-rate GPU timing simulator (the NVAS stand-in).
//!
//! Execution is simulated at CTA/tile granularity. Every resident CTA owns
//! three *work streams* — FLOPs on its issue pipe, DRAM bytes, L2 bytes —
//! that drain concurrently (compute/memory overlap, as on a real SM). Rates
//! are fluid: a pipe is shared equally by the co-resident CTAs of its class
//! on that SM; DRAM and L2 are global bandwidth pools shared by all CTAs
//! with outstanding traffic. Events occur when any stream drains or a queue
//! changes state; rates are recomputed at each event. This is exactly the
//! first-order model the paper's effects live in:
//!
//! * BSP: one kernel's CTAs at a time, global barrier between kernels.
//! * Vertical fusion: one fused kernel with serialized region work and
//!   (when tiles spill) extra DRAM round-trip latency per tile.
//! * Kitsune: co-resident stage kernels streaming tiles through bounded
//!   queues — producers stall when full, consumers when empty — with the
//!   §4.2 dual-arbiter scheduler pairing heterogeneous CTAs per SM.

use super::config::GpuConfig;
use super::kernel::{KernelDesc, PipelineDesc};
use super::scheduler::{GridScheduler, SchedPolicy};
use super::sm::SmState;
use super::stats::SimReport;
use crate::graph::ResourceClass;
use anyhow::{bail, Result};

const EPS: f64 = 1e-9;

/// Simulator facade: a machine config plus a scheduling policy.
#[derive(Debug, Clone)]
pub struct Engine {
    pub cfg: GpuConfig,
    pub policy: SchedPolicy,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CtaState {
    /// Draining work streams.
    Running,
    /// Stalled on an empty input queue.
    WaitInput,
    /// Stalled on a full output queue.
    WaitOutput,
}

#[derive(Debug, Clone)]
struct Cta {
    stage: usize,
    class: ResourceClass,
    smem: usize,
    sm: usize,
    u: f64,
    /// Tiles still to process (including the current one).
    tiles_left: usize,
    /// Per-tile work: [flops, dram bytes, l2 bytes].
    tile_work: [f64; 3],
    /// Remaining work in the current tile.
    cur: [f64; 3],
    /// Serial (non-overlappable) latency left in the current tile:
    /// queue hop latency, spill round-trips.
    latency_left: f64,
    tile_latency: f64,
    state: CtaState,
    /// Output-queue pushes still owed for the finished tile.
    pending_pushes: Vec<usize>,
    /// Whether the current tile's inputs have been acquired.
    acquired: bool,
    waited_s: f64,
}

#[derive(Debug, Clone)]
struct QueueState {
    entries: usize,
    count: usize,
}

struct Sim<'a> {
    cfg: &'a GpuConfig,
    sched: GridScheduler,
    sms: Vec<SmState>,
    ctas: Vec<Cta>,
    queues: Vec<QueueState>,
    /// Stage input/output queue tables.
    stage_inputs: Vec<Vec<usize>>,
    stage_outputs: Vec<Vec<usize>>,
    /// (stage, per-CTA tiles) awaiting dispatch, FIFO.
    pending: std::collections::VecDeque<(usize, PendingCta)>,
    /// Running/blocked CTA ids.
    resident: Vec<usize>,
    report: SimReport,
    now: f64,
    /// Reusable per-event scratch (perf: §Perf L3 pass — no per-event
    /// allocation on the hot path).
    scratch_pipe_users: Vec<[usize; 2]>,
    scratch_rates: Vec<(usize, [f64; 3])>,
    scratch_sm_busy: Vec<bool>,
}

#[derive(Debug, Clone)]
struct PendingCta {
    class: ResourceClass,
    smem: usize,
    u: f64,
    tiles: usize,
    tile_work: [f64; 3],
    tile_latency: f64,
}

impl Engine {
    pub fn new(cfg: GpuConfig, policy: SchedPolicy) -> Self {
        Engine { cfg, policy }
    }

    /// Simulate one BSP kernel launch (all CTAs, waves as needed).
    pub fn run_kernel(&self, k: &KernelDesc) -> Result<SimReport> {
        self.run_kernel_with_latency(k, 0.0)
    }

    /// BSP kernel with extra serial latency per CTA (vertical-fusion spill
    /// round-trips are modeled this way).
    pub fn run_kernel_with_latency(&self, k: &KernelDesc, latency: f64) -> Result<SimReport> {
        // One tile per CTA: a BSP CTA runs its whole work quantum then exits.
        let stages = vec![(k.clone(), k.n_ctas.max(1), latency)];
        self.simulate(&stages, &[], &[], &[])
    }

    /// Simulate a sequence of kernels with global barriers between them —
    /// bulk-synchronous execution of an operator list.
    pub fn run_kernels_bsp(&self, ks: &[KernelDesc]) -> Result<SimReport> {
        let mut total = SimReport::default();
        for k in ks {
            total = total.chain(&self.run_kernel(k)?);
        }
        Ok(total)
    }

    /// Simulate a Kitsune spatial pipeline: all stages co-resident,
    /// streaming `n_tiles` tiles through the connecting queues.
    pub fn run_pipeline(&self, p: &PipelineDesc) -> Result<SimReport> {
        // Capacity check: the calling load balancer must have sized the
        // pipeline to be co-resident (paper §4.2: "calling code is
        // responsible for limiting the number of CTAs launched").
        let cap = self.cfg.sm_count * self.cfg.max_ctas_per_sm;
        if p.total_ctas() > cap {
            bail!(
                "pipeline {} wants {} CTAs > capacity {}",
                p.name,
                p.total_ctas(),
                cap
            );
        }
        if p.queue_footprint() > self.cfg.l2_capacity {
            bail!(
                "pipeline {} queue footprint {} exceeds L2 capacity {}",
                p.name,
                p.queue_footprint(),
                self.cfg.l2_capacity
            );
        }
        let n_tiles = p.stages.first().map(|s| s.n_tiles).unwrap_or(1);
        for s in &p.stages {
            debug_assert_eq!(s.n_tiles, n_tiles, "stages must stream equal tile counts");
        }
        // Queue hop cost: acquire+release ≈ 4 atomics + an L2 round trip.
        let hop = self.cfg.l2_latency_s + 4.0 / self.cfg.atomics_per_sec_per_cta;
        let stages: Vec<(KernelDesc, usize, f64)> = p
            .stages
            .iter()
            .map(|s| (s.kernel.clone(), s.n_tiles, if s.input_queues.is_empty() { 0.0 } else { hop }))
            .collect();
        let ins: Vec<Vec<usize>> = p.stages.iter().map(|s| s.input_queues.clone()).collect();
        let outs: Vec<Vec<usize>> = p.stages.iter().map(|s| s.output_queues.clone()).collect();
        let queues: Vec<QueueState> = p
            .queues
            .iter()
            .map(|q| QueueState { entries: q.entries.max(1), count: 0 })
            .collect();
        self.simulate(&stages, &queues, &ins, &outs)
    }

    /// Core event loop. `stages[i] = (kernel, n_tiles_total, tile_latency)`.
    fn simulate(
        &self,
        stages: &[(KernelDesc, usize, f64)],
        queues: &[QueueState],
        stage_inputs: &[Vec<usize>],
        stage_outputs: &[Vec<usize>],
    ) -> Result<SimReport> {
        let mut sim = Sim {
            cfg: &self.cfg,
            sched: GridScheduler::new(self.policy),
            sms: vec![SmState::default(); self.cfg.sm_count],
            ctas: Vec::new(),
            queues: queues.to_vec(),
            stage_inputs: if stage_inputs.is_empty() {
                vec![Vec::new(); stages.len()]
            } else {
                stage_inputs.to_vec()
            },
            stage_outputs: if stage_outputs.is_empty() {
                vec![Vec::new(); stages.len()]
            } else {
                stage_outputs.to_vec()
            },
            pending: Default::default(),
            resident: Vec::new(),
            report: SimReport::default(),
            now: 0.0,
            scratch_pipe_users: vec![[0usize; 2]; self.cfg.sm_count],
            scratch_rates: Vec::new(),
            scratch_sm_busy: vec![false; self.cfg.sm_count],
        };

        // Enqueue CTAs round-robin across stages so pipelines co-reside.
        let mut per_stage: Vec<Vec<PendingCta>> = Vec::new();
        for (k, n_tiles, lat) in stages {
            let mut v = Vec::new();
            let n = k.n_ctas.max(1);
            let base = n_tiles / n;
            let extra = n_tiles % n;
            for i in 0..n {
                let tiles = base + usize::from(i < extra);
                if tiles == 0 {
                    // Fewer tiles than CTAs: surplus CTAs are never launched
                    // (token conservation through the queues requires the
                    // stage's pops/pushes to total exactly n_tiles).
                    continue;
                }
                // Work is partitioned by tiles: each CTA's tile has the
                // stage-average tile work.
                let tile_work = [
                    k.total_flops() / *n_tiles as f64,
                    k.total_dram_bytes() / *n_tiles as f64,
                    k.total_l2_bytes() / *n_tiles as f64,
                ];
                v.push(PendingCta {
                    class: k.class,
                    smem: k.smem_per_cta,
                    u: k.pipe_utilization.clamp(0.01, 1.0),
                    tiles,
                    tile_work,
                    tile_latency: *lat,
                });
            }
            per_stage.push(v);
        }
        let mut cursors: Vec<usize> = vec![0; per_stage.len()];
        loop {
            let mut progressed = false;
            for (s, stage_q) in per_stage.iter().enumerate() {
                if cursors[s] < stage_q.len() {
                    sim.pending.push_back((s, stage_q[cursors[s]].clone()));
                    cursors[s] += 1;
                    progressed = true;
                }
            }
            if !progressed {
                break;
            }
        }

        sim.run()?;
        Ok(sim.report)
    }
}

impl<'a> Sim<'a> {
    fn run(&mut self) -> Result<()> {
        self.dispatch();
        let mut guard = 0usize;
        loop {
            // Drain all zero-time transitions (tile completions, queue ops).
            while self.settle() {}
            if self.resident.is_empty() && self.pending.is_empty() {
                break;
            }
            let dt = self.advance()?;
            self.now += dt;
            guard += 1;
            if guard > 200_000_000 {
                bail!("simulation did not converge (deadlock?) at t={}", self.now);
            }
        }
        self.report.elapsed_s = self.now;
        if self.report.elapsed_s > 0.0 {
            self.report.avg_sm_util /= self.report.elapsed_s;
            self.report.avg_dram_util /= self.report.elapsed_s;
            let busy = self.report.paired_frac; // accumulated paired-time
            self.report.paired_frac = busy / self.report.elapsed_s;
        }
        Ok(())
    }

    /// Place pending CTAs onto SMs while slots remain.
    fn dispatch(&mut self) {
        while let Some((stage, p)) = self.pending.front().cloned() {
            let placed = self.sched.place(p.class, p.smem, &mut self.sms, self.cfg);
            match placed {
                Some(sm) => {
                    self.pending.pop_front();
                    let needs_input = !self.stage_inputs[stage].is_empty();
                    let cta = Cta {
                        stage,
                        class: p.class,
                        smem: p.smem,
                        sm,
                        u: p.u,
                        tiles_left: p.tiles,
                        tile_work: p.tile_work,
                        cur: p.tile_work,
                        latency_left: p.tile_latency,
                        tile_latency: p.tile_latency,
                        state: if needs_input { CtaState::WaitInput } else { CtaState::Running },
                        pending_pushes: Vec::new(),
                        acquired: !needs_input,
                        waited_s: 0.0,
                    };
                    let id = self.ctas.len();
                    self.ctas.push(cta);
                    self.resident.push(id);
                }
                None => break,
            }
        }
    }

    /// One pass of zero-time state transitions. Returns true if anything
    /// changed (caller loops to fixpoint).
    fn settle(&mut self) -> bool {
        let mut changed = false;
        // Index loop: try_transition never mutates `resident`.
        for i in 0..self.resident.len() {
            let id = self.resident[i];
            changed |= self.try_transition(id);
        }
        // Retire finished CTAs and refill SM slots.
        let before = self.resident.len();
        let mut retired = Vec::new();
        self.resident.retain(|&id| {
            let c = &self.ctas[id];
            let done = c.tiles_left == 0 && c.pending_pushes.is_empty();
            if done {
                retired.push(id);
            }
            !done
        });
        for id in retired {
            let (sm, class, smem) = {
                let c = &self.ctas[id];
                (c.sm, c.class, c.smem)
            };
            self.sms[sm].retire(class, smem);
        }
        if self.resident.len() != before {
            self.dispatch();
            changed = true;
        }
        changed
    }

    /// Attempt queue transitions for one CTA. Zero-time.
    fn try_transition(&mut self, id: usize) -> bool {
        // Fast path: mid-tile CTA with nothing owed — by far the common
        // case during the settle fixpoint (§Perf L3 pass).
        {
            let c = &self.ctas[id];
            if c.acquired
                && c.pending_pushes.is_empty()
                && c.tiles_left > 0
                && (c.latency_left > EPS || c.cur.iter().any(|&w| w > EPS))
            {
                return false;
            }
        }
        let mut changed = false;
        // 1. Complete owed pushes (retain the still-blocked ones in place).
        if !self.ctas[id].pending_pushes.is_empty() {
            let mut pushes = std::mem::take(&mut self.ctas[id].pending_pushes);
            pushes.retain(|&q| {
                if self.queues[q].count < self.queues[q].entries {
                    self.queues[q].count += 1;
                    changed = true;
                    false
                } else {
                    true
                }
            });
            self.ctas[id].pending_pushes = pushes;
            if self.ctas[id].pending_pushes.is_empty() {
                // Pushed everything; move on to the next tile (or finish).
                self.ctas[id].state = CtaState::Running;
                changed = true;
            } else {
                self.ctas[id].state = CtaState::WaitOutput;
            }
        }
        // 2. Acquire inputs for the current tile if not yet acquired.
        if self.ctas[id].pending_pushes.is_empty()
            && self.ctas[id].tiles_left > 0
            && !self.ctas[id].acquired
        {
            let stage = self.ctas[id].stage;
            let all_avail = self.stage_inputs[stage].iter().all(|&q| self.queues[q].count > 0);
            if all_avail {
                for qi in 0..self.stage_inputs[stage].len() {
                    let q = self.stage_inputs[stage][qi];
                    self.queues[q].count -= 1;
                }
                let c = &mut self.ctas[id];
                c.acquired = true;
                c.cur = c.tile_work;
                c.latency_left = c.tile_latency;
                c.state = CtaState::Running;
                changed = true;
            } else {
                self.ctas[id].state = CtaState::WaitInput;
            }
        }
        // 3. Tile completion: all streams drained.
        if self.ctas[id].acquired
            && self.ctas[id].tiles_left > 0
            && self.ctas[id].cur.iter().all(|&w| w <= EPS)
            && self.ctas[id].latency_left <= EPS
        {
            let stage = self.ctas[id].stage;
            // Reuse the (now empty) pending_pushes allocation.
            let mut pushes = std::mem::take(&mut self.ctas[id].pending_pushes);
            pushes.clear();
            pushes.extend_from_slice(&self.stage_outputs[stage]);
            let c = &mut self.ctas[id];
            c.tiles_left -= 1;
            c.acquired = false;
            c.pending_pushes = pushes;
            changed = true;
            // Pushes and next-tile acquire handled on the next settle pass.
        }
        changed
    }

    /// Advance simulated time to the next stream-drain event.
    fn advance(&mut self) -> Result<f64> {
        // --- compute rates ---
        // Pipe sharing: count running compute CTAs per (sm, class).
        let n_sms = self.sms.len();
        let pipe_users = &mut self.scratch_pipe_users;
        pipe_users.iter_mut().for_each(|p| *p = [0, 0]);
        let mut dram_users = 0usize;
        let mut l2_users = 0usize;
        for &id in &self.resident {
            let c = &self.ctas[id];
            if c.state != CtaState::Running || !c.acquired {
                continue;
            }
            if c.cur[0] > EPS {
                pipe_users[c.sm][class_idx(c.class)] += 1;
            }
            if c.cur[1] > EPS {
                dram_users += 1;
            }
            if c.cur[2] > EPS {
                l2_users += 1;
            }
        }
        let dram_share = if dram_users > 0 { self.cfg.dram_bw / dram_users as f64 } else { 0.0 };
        let l2_share = if l2_users > 0 { self.cfg.l2_bw / l2_users as f64 } else { 0.0 };
        let pipe_per_sm = [self.cfg.tensor_flops_per_sm(), self.cfg.simt_flops_per_sm()];

        // --- find min event horizon ---
        let mut dt = f64::INFINITY;
        let mut rates = std::mem::take(&mut self.scratch_rates);
        rates.clear();
        for &id in &self.resident {
            let c = &self.ctas[id];
            if c.state != CtaState::Running || !c.acquired {
                continue;
            }
            let ci = class_idx(c.class);
            let share = pipe_users[c.sm][ci].max(1) as f64;
            let r = [
                if c.cur[0] > EPS { pipe_per_sm[ci] / share * c.u } else { 0.0 },
                if c.cur[1] > EPS { dram_share } else { 0.0 },
                if c.cur[2] > EPS { l2_share } else { 0.0 },
            ];
            for s in 0..3 {
                if c.cur[s] > EPS && r[s] > 0.0 {
                    dt = dt.min(c.cur[s] / r[s]);
                }
            }
            if c.latency_left > EPS {
                dt = dt.min(c.latency_left);
            }
            rates.push((id, r));
        }
        if !dt.is_finite() {
            // Nothing runnable but residents exist -> real deadlock.
            bail!(
                "deadlock: {} resident CTAs, none runnable (queue sizing bug?)",
                self.resident.len()
            );
        }
        let dt = dt.max(1e-15);

        // --- advance streams & collect stats ---
        let mut flops_rate = [0.0f64; 2];
        let mut dram_rate = 0.0;
        let mut l2_rate = 0.0;
        for (id, r) in &rates {
            let c = &mut self.ctas[*id];
            for s in 0..3 {
                if c.cur[s] > EPS {
                    c.cur[s] = (c.cur[s] - r[s] * dt).max(0.0);
                }
            }
            if c.latency_left > EPS {
                c.latency_left = (c.latency_left - dt).max(0.0);
            }
            flops_rate[class_idx(c.class)] += r[0];
            dram_rate += r[1];
            l2_rate += r[2];
        }
        let mut n_waiting = 0usize;
        for &id in &self.resident {
            let c = &mut self.ctas[id];
            if c.state != CtaState::Running {
                c.waited_s += dt;
                n_waiting += 1;
            }
        }

        // "SM utilization" in the NSight sense the paper measures:
        // fraction of SMs with an actively issuing (non-stalled) CTA.
        // Reductions with few CTAs and queue-stalled pipeline stages show
        // up as low-SM exactly as in the paper's Figs 3/13.
        let sm_busy = &mut self.scratch_sm_busy;
        sm_busy.iter_mut().for_each(|b| *b = false);
        for &id in &self.resident {
            let c = &self.ctas[id];
            if c.state == CtaState::Running
                && c.acquired
                && (c.cur.iter().any(|&w| w > EPS) || c.latency_left > EPS)
            {
                sm_busy[c.sm] = true;
            }
        }
        let sm_util = sm_busy.iter().filter(|&&b| b).count() as f64 / n_sms as f64;
        let _ = flops_rate; // pipe rates still feed flops accounting below
        let dram_util = dram_rate / self.cfg.dram_bw;
        self.report.quadrants.add_sample(sm_util, dram_util, dt);
        self.report.avg_sm_util += sm_util * dt;
        self.report.avg_dram_util += dram_util * dt;
        self.report.dram_bytes += dram_rate * dt;
        self.report.l2_bytes += l2_rate * dt;
        self.report.flops += (flops_rate[0] + flops_rate[1]) * dt;
        self.report.queue_wait_s += dt * n_waiting as f64;
        let busy_sms = self.sms.iter().filter(|s| s.total_ctas() > 0).count();
        if busy_sms > 0 {
            let paired = self.sms.iter().filter(|s| s.is_paired()).count();
            self.report.paired_frac += dt * paired as f64 / busy_sms as f64;
        }
        self.scratch_rates = rates;
        Ok(dt)
    }
}

fn class_idx(c: ResourceClass) -> usize {
    match c {
        ResourceClass::Tensor => 0,
        ResourceClass::Simt => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::kernel::{QueueDesc, StageDesc};

    fn a100() -> Engine {
        Engine::new(GpuConfig::a100(), SchedPolicy::DualArbiter)
    }

    fn gemm_kernel(flops: f64, dram: f64, ctas: usize) -> KernelDesc {
        KernelDesc {
            name: "gemm".into(),
            class: ResourceClass::Tensor,
            n_ctas: ctas,
            flops_per_cta: flops / ctas as f64,
            dram_bytes_per_cta: dram / ctas as f64,
            l2_bytes_per_cta: 0.0,
            smem_per_cta: 64 * 1024,
            pipe_utilization: 1.0,
        }
    }

    #[test]
    fn compute_bound_kernel_time_matches_roofline() {
        // 108 CTAs of pure compute on the tensor pipe, 1 per SM => peak.
        let e = a100();
        let total = 312e9; // 1 ms of work at peak
        let r = e.run_kernel(&gemm_kernel(total, 0.0, 108)).unwrap();
        assert!((r.elapsed_s - 1e-3).abs() / 1e-3 < 0.01, "{}", r.elapsed_s);
        assert!((r.flops - total).abs() / total < 1e-6);
    }

    #[test]
    fn memory_bound_kernel_time_matches_bandwidth() {
        let e = a100();
        let bytes = 1.555e9; // 1 ms at peak DRAM BW
        let mut k = gemm_kernel(1e6, bytes, 108);
        k.class = ResourceClass::Simt;
        let r = e.run_kernel(&k).unwrap();
        assert!((r.elapsed_s - 1e-3).abs() / 1e-3 < 0.01, "{}", r.elapsed_s);
        assert!((r.dram_bytes - bytes).abs() / bytes < 1e-6);
    }

    #[test]
    fn waves_serialize_when_over_capacity() {
        // 432 CTAs of pure compute = 2 waves at 2 CTAs/SM; each wave has 2
        // CTAs/SM sharing the pipe, so time == 2 waves * (2x slowdown) ==
        // same as 4x one-CTA-per-SM wave time.
        let e = a100();
        let total = 312e9;
        let r1 = e.run_kernel(&gemm_kernel(total, 0.0, 108)).unwrap();
        let r4 = e.run_kernel(&gemm_kernel(4.0 * total, 0.0, 432)).unwrap();
        let ratio = r4.elapsed_s / r1.elapsed_s;
        assert!((ratio - 4.0).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn bsp_sequence_is_sum_of_kernels() {
        let e = a100();
        let k = gemm_kernel(312e9, 0.0, 108);
        let r1 = e.run_kernel(&k).unwrap();
        let r2 = e.run_kernels_bsp(&[k.clone(), k.clone()]).unwrap();
        assert!((r2.elapsed_s - 2.0 * r1.elapsed_s).abs() / r1.elapsed_s < 1e-6);
    }

    fn two_stage_pipeline(tiles: usize) -> PipelineDesc {
        // Stage 0: tensor GEMM producing tiles; stage 1: simt consumer.
        let producer = KernelDesc {
            name: "producer".into(),
            class: ResourceClass::Tensor,
            n_ctas: 54,
            flops_per_cta: 312e9 / 108.0,
            dram_bytes_per_cta: 1e6,
            l2_bytes_per_cta: 1e6,
            smem_per_cta: 32 * 1024,
            pipe_utilization: 0.8,
        };
        let consumer = KernelDesc {
            name: "consumer".into(),
            class: ResourceClass::Simt,
            n_ctas: 54,
            flops_per_cta: 19.5e9 / 108.0,
            dram_bytes_per_cta: 1e6,
            l2_bytes_per_cta: 1e6,
            smem_per_cta: 16 * 1024,
            pipe_utilization: 0.7,
        };
        PipelineDesc {
            name: "p".into(),
            stages: vec![
                StageDesc {
                    kernel: producer,
                    n_tiles: tiles,
                    input_queues: vec![],
                    output_queues: vec![0],
                },
                StageDesc {
                    kernel: consumer,
                    n_tiles: tiles,
                    input_queues: vec![0],
                    output_queues: vec![],
                },
            ],
            queues: vec![QueueDesc { payload_bytes: 128 * 1024, entries: 2, memory_backed: false }],
        }
    }

    #[test]
    fn pipeline_completes_and_pairs() {
        let e = a100();
        let r = e.run_pipeline(&two_stage_pipeline(216)).unwrap();
        assert!(r.elapsed_s > 0.0);
        // Dual arbiter should pair most SMs (54 tensor + 54 simt CTAs).
        assert!(r.paired_frac > 0.5, "paired {}", r.paired_frac);
    }

    #[test]
    fn pipeline_conserves_flops() {
        let e = a100();
        let p = two_stage_pipeline(108);
        let want: f64 = p.stages.iter().map(|s| s.kernel.total_flops()).sum();
        let r = e.run_pipeline(&p).unwrap();
        assert!((r.flops - want).abs() / want < 1e-3, "{} vs {want}", r.flops);
    }

    #[test]
    fn pipeline_rejects_over_capacity() {
        let e = a100();
        let mut p = two_stage_pipeline(16);
        p.stages[0].kernel.n_ctas = 400;
        assert!(e.run_pipeline(&p).is_err());
    }

    #[test]
    fn bounded_queue_throttles_producer() {
        // A fast producer + slow consumer must finish in ~consumer time,
        // not producer time (backpressure through the 2-entry queue).
        let e = a100();
        let mut p = two_stage_pipeline(216);
        // Make consumer 10x the work of default.
        p.stages[1].kernel.flops_per_cta *= 10.0;
        let r = e.run_pipeline(&p).unwrap();
        let consumer_alone = Engine::new(GpuConfig::a100(), SchedPolicy::DualArbiter)
            .run_kernel(&p.stages[1].kernel)
            .unwrap();
        assert!(
            r.elapsed_s >= consumer_alone.elapsed_s * 0.95,
            "{} vs {}",
            r.elapsed_s,
            consumer_alone.elapsed_s
        );
        // And producer stalled some of the time.
        assert!(r.queue_wait_s > 0.0);
    }

    #[test]
    fn deterministic() {
        let e = a100();
        let p = two_stage_pipeline(128);
        let a = e.run_pipeline(&p).unwrap();
        let b = e.run_pipeline(&p).unwrap();
        assert_eq!(a.elapsed_s, b.elapsed_s);
        assert_eq!(a.dram_bytes, b.dram_bytes);
    }
}
