//! Kernel and CTA work descriptors consumed by the simulator.
//!
//! A [`KernelDesc`] is the simulator's unit of dispatch — the analog of a
//! CUDA kernel launch. Under BSP a kernel's CTAs all run before the next
//! kernel starts; under Kitsune several kernels (pipeline stages) are
//! co-resident and stream tiles through queues.

use crate::graph::ResourceClass;

/// Static description of one kernel launch.
#[derive(Debug, Clone)]
pub struct KernelDesc {
    pub name: String,
    /// Scheduler tag from the §4.2 kernel-call header.
    pub class: ResourceClass,
    /// Number of CTAs in the grid.
    pub n_ctas: usize,
    /// Per-CTA work, split by resource stream. A CTA finishes when all
    /// three streams drain (compute and memory overlap, as on real SMs).
    pub flops_per_cta: f64,
    pub dram_bytes_per_cta: f64,
    pub l2_bytes_per_cta: f64,
    /// Shared-memory footprint per CTA (occupancy constraint).
    pub smem_per_cta: usize,
    /// Fraction of the CTA's issue bandwidth on its *primary* pipe that it
    /// can actually sustain (the paper's `u`, used for Speedup(a_i)=1/u).
    pub pipe_utilization: f64,
}

impl KernelDesc {
    /// Total FLOPs across the grid.
    pub fn total_flops(&self) -> f64 {
        self.flops_per_cta * self.n_ctas as f64
    }

    /// Total DRAM bytes across the grid.
    pub fn total_dram_bytes(&self) -> f64 {
        self.dram_bytes_per_cta * self.n_ctas as f64
    }

    /// Total L2 bytes across the grid.
    pub fn total_l2_bytes(&self) -> f64 {
        self.l2_bytes_per_cta * self.n_ctas as f64
    }

    /// Rescale to a different CTA count, conserving total work (used by the
    /// §5.3 load balancer when it allocates `a_i` CTAs to a stage).
    pub fn with_ctas(&self, n: usize) -> KernelDesc {
        assert!(n > 0, "kernel must have at least one CTA");
        let scale = self.n_ctas as f64 / n as f64;
        KernelDesc {
            name: self.name.clone(),
            class: self.class,
            n_ctas: n,
            flops_per_cta: self.flops_per_cta * scale,
            dram_bytes_per_cta: self.dram_bytes_per_cta * scale,
            l2_bytes_per_cta: self.l2_bytes_per_cta * scale,
            smem_per_cta: self.smem_per_cta,
            pipe_utilization: self.pipe_utilization,
        }
    }
}

/// A pipeline-stage instance: a kernel plus the queues it talks to.
#[derive(Debug, Clone)]
pub struct StageDesc {
    pub kernel: KernelDesc,
    /// Tiles this stage must process for the sf-node to complete.
    pub n_tiles: usize,
    /// Queue indices (into the pipeline's queue table) this stage pops from.
    pub input_queues: Vec<usize>,
    /// Queue indices this stage pushes to.
    pub output_queues: Vec<usize>,
}

/// A queue instance connecting pipeline stages (paper §4.1).
#[derive(Debug, Clone)]
pub struct QueueDesc {
    /// Payload bytes per entry (tile size).
    pub payload_bytes: usize,
    /// Entries (2 = double buffering, as in paper Fig 4).
    pub entries: usize,
    /// Memory-backed edge (fork-join skip): unbounded, not L2-pinned.
    pub memory_backed: bool,
}

impl QueueDesc {
    /// Total L2 footprint of the queue (payload + metadata lines).
    /// Memory-backed edges are not pinned in L2 and cost nothing here.
    pub fn footprint_bytes(&self) -> usize {
        if self.memory_backed {
            return 0;
        }
        // 4 cache lines of padded sync metadata per entry (Fig 4(a)).
        self.entries * (self.payload_bytes + 4 * 128)
    }
}

/// A spatial pipeline: co-resident stages + connecting queues (Fig 6's
/// `cudaPipeline` object, post load-balancing).
#[derive(Debug, Clone)]
pub struct PipelineDesc {
    pub name: String,
    pub stages: Vec<StageDesc>,
    pub queues: Vec<QueueDesc>,
}

impl PipelineDesc {
    /// Aggregate L2 footprint of all queues — must fit the L2 budget.
    pub fn queue_footprint(&self) -> usize {
        self.queues.iter().map(|q| q.footprint_bytes()).sum()
    }

    /// Total CTAs across stages (must co-reside on the GPU).
    pub fn total_ctas(&self) -> usize {
        self.stages.iter().map(|s| s.kernel.n_ctas).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k() -> KernelDesc {
        KernelDesc {
            name: "k".into(),
            class: ResourceClass::Tensor,
            n_ctas: 8,
            flops_per_cta: 100.0,
            dram_bytes_per_cta: 50.0,
            l2_bytes_per_cta: 200.0,
            smem_per_cta: 1024,
            pipe_utilization: 0.5,
        }
    }

    #[test]
    fn with_ctas_conserves_work() {
        let a = k();
        let b = a.with_ctas(4);
        assert!((a.total_flops() - b.total_flops()).abs() < 1e-9);
        assert!((a.total_dram_bytes() - b.total_dram_bytes()).abs() < 1e-9);
        assert_eq!(b.n_ctas, 4);
        assert_eq!(b.flops_per_cta, 200.0);
    }

    #[test]
    fn queue_footprint_includes_metadata() {
        let q = QueueDesc { payload_bytes: 64 * 1024, entries: 2, memory_backed: false };
        assert_eq!(q.footprint_bytes(), 2 * (64 * 1024 + 512));
    }

    #[test]
    #[should_panic(expected = "at least one CTA")]
    fn zero_ctas_rejected() {
        k().with_ctas(0);
    }
}
