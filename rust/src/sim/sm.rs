//! Per-SM occupancy state: resident CTA slots, shared-memory budget, and
//! the two issue pipes (TensorCore and SIMT) that Kitsune overlaps.

use crate::graph::ResourceClass;

/// Occupancy state of one SM, as the grid scheduler sees it.
#[derive(Debug, Clone, Default)]
pub struct SmState {
    /// Resident CTA count issuing to TensorCores.
    pub tensor_ctas: usize,
    /// Resident CTA count issuing to SIMT cores.
    pub simt_ctas: usize,
    /// Shared memory currently allocated, bytes.
    pub smem_used: usize,
}

impl SmState {
    pub fn total_ctas(&self) -> usize {
        self.tensor_ctas + self.simt_ctas
    }

    pub fn count(&self, class: ResourceClass) -> usize {
        match class {
            ResourceClass::Tensor => self.tensor_ctas,
            ResourceClass::Simt => self.simt_ctas,
        }
    }

    /// Can a CTA of `class` needing `smem` bytes be placed here?
    pub fn fits(&self, smem: usize, smem_capacity: usize, max_ctas: usize) -> bool {
        self.total_ctas() < max_ctas && self.smem_used + smem <= smem_capacity
    }

    pub fn admit(&mut self, class: ResourceClass, smem: usize) {
        match class {
            ResourceClass::Tensor => self.tensor_ctas += 1,
            ResourceClass::Simt => self.simt_ctas += 1,
        }
        self.smem_used += smem;
    }

    pub fn retire(&mut self, class: ResourceClass, smem: usize) {
        match class {
            ResourceClass::Tensor => {
                debug_assert!(self.tensor_ctas > 0);
                self.tensor_ctas -= 1;
            }
            ResourceClass::Simt => {
                debug_assert!(self.simt_ctas > 0);
                self.simt_ctas -= 1;
            }
        }
        debug_assert!(self.smem_used >= smem);
        self.smem_used -= smem;
    }

    /// True when both heterogeneous pipes are active — the overlap Kitsune's
    /// dual-arbiter scheduler engineers (paper §4.2).
    pub fn is_paired(&self) -> bool {
        self.tensor_ctas > 0 && self.simt_ctas > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_retire_roundtrip() {
        let mut sm = SmState::default();
        sm.admit(ResourceClass::Tensor, 4096);
        sm.admit(ResourceClass::Simt, 1024);
        assert!(sm.is_paired());
        assert_eq!(sm.total_ctas(), 2);
        assert_eq!(sm.smem_used, 5120);
        sm.retire(ResourceClass::Tensor, 4096);
        assert!(!sm.is_paired());
        assert_eq!(sm.smem_used, 1024);
    }

    #[test]
    fn fits_respects_limits() {
        let mut sm = SmState::default();
        assert!(sm.fits(1024, 192 * 1024, 2));
        sm.admit(ResourceClass::Simt, 190 * 1024);
        assert!(!sm.fits(4 * 1024, 192 * 1024, 2), "smem exhausted");
        assert!(sm.fits(1024, 192 * 1024, 2));
        sm.admit(ResourceClass::Tensor, 1024);
        assert!(!sm.fits(0, 192 * 1024, 2), "slot limit");
    }
}
