//! Simulation statistics: elapsed time, traffic, and the SM×DRAM
//! utilization-quadrant breakdown used by the paper's Figs 3 and 13.

/// "Low" utilization threshold — the paper uses <33% of peak.
pub const LOW_UTIL_THRESHOLD: f64 = 0.33;

/// Time-weighted breakdown of runtime into the four SM×DRAM utilization
/// quadrants (paper Figs 3/13). Fractions sum to 1.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct UtilQuadrants {
    /// SM < 33% and DRAM < 33% of peak.
    pub both_low: f64,
    /// SM < 33%, DRAM >= 33%.
    pub low_sm: f64,
    /// DRAM < 33%, SM >= 33%.
    pub low_dram: f64,
    /// Both >= 33%.
    pub neither_low: f64,
}

impl UtilQuadrants {
    pub fn add_sample(&mut self, sm_util: f64, dram_util: f64, dt: f64) {
        let sm_low = sm_util < LOW_UTIL_THRESHOLD;
        let dram_low = dram_util < LOW_UTIL_THRESHOLD;
        match (sm_low, dram_low) {
            (true, true) => self.both_low += dt,
            (true, false) => self.low_sm += dt,
            (false, true) => self.low_dram += dt,
            (false, false) => self.neither_low += dt,
        }
    }

    pub fn total(&self) -> f64 {
        self.both_low + self.low_sm + self.low_dram + self.neither_low
    }

    /// Normalize to fractions of total time.
    pub fn normalized(&self) -> UtilQuadrants {
        let t = self.total();
        if t <= 0.0 {
            return *self;
        }
        UtilQuadrants {
            both_low: self.both_low / t,
            low_sm: self.low_sm / t,
            low_dram: self.low_dram / t,
            neither_low: self.neither_low / t,
        }
    }

    /// Merge another breakdown (absolute-time weighted).
    pub fn merge(&mut self, other: &UtilQuadrants) {
        self.both_low += other.both_low;
        self.low_sm += other.low_sm;
        self.low_dram += other.low_dram;
        self.neither_low += other.neither_low;
    }
}

/// Result of simulating one phase / kernel / pipeline / application.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    /// Simulated wall-clock seconds.
    pub elapsed_s: f64,
    /// Total DRAM traffic, bytes.
    pub dram_bytes: f64,
    /// Total L2 traffic, bytes (includes queue payload + sync metadata).
    pub l2_bytes: f64,
    /// Time-weighted utilization quadrants (absolute seconds).
    pub quadrants: UtilQuadrants,
    /// Time-averaged SM utilization (max of the two pipes, NSight-style).
    pub avg_sm_util: f64,
    /// Time-averaged DRAM bandwidth utilization.
    pub avg_dram_util: f64,
    /// Fraction of busy SM-time spent with heterogeneous CTAs paired.
    pub paired_frac: f64,
    /// Total FLOPs retired (sanity: conserved across execution modes).
    pub flops: f64,
    /// Queue-wait seconds summed over pipeline CTAs (dataflow only).
    pub queue_wait_s: f64,
}

impl SimReport {
    /// Sequential composition (global barrier between parts — BSP phases
    /// and consecutive sf-nodes alike).
    pub fn chain(mut self, other: &SimReport) -> SimReport {
        let t0 = self.elapsed_s;
        let t1 = other.elapsed_s;
        let tot = (t0 + t1).max(1e-30);
        self.avg_sm_util = (self.avg_sm_util * t0 + other.avg_sm_util * t1) / tot;
        self.avg_dram_util = (self.avg_dram_util * t0 + other.avg_dram_util * t1) / tot;
        self.paired_frac = (self.paired_frac * t0 + other.paired_frac * t1) / tot;
        self.elapsed_s += other.elapsed_s;
        self.dram_bytes += other.dram_bytes;
        self.l2_bytes += other.l2_bytes;
        self.flops += other.flops;
        self.queue_wait_s += other.queue_wait_s;
        self.quadrants.merge(&other.quadrants);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadrant_classification() {
        let mut q = UtilQuadrants::default();
        q.add_sample(0.1, 0.1, 1.0); // both low
        q.add_sample(0.1, 0.9, 2.0); // low sm
        q.add_sample(0.9, 0.1, 3.0); // low dram
        q.add_sample(0.9, 0.9, 4.0); // neither
        assert_eq!(q.both_low, 1.0);
        assert_eq!(q.low_sm, 2.0);
        assert_eq!(q.low_dram, 3.0);
        assert_eq!(q.neither_low, 4.0);
        let n = q.normalized();
        assert!((n.total() - 1.0).abs() < 1e-12);
        assert!((n.neither_low - 0.4).abs() < 1e-12);
    }

    #[test]
    fn threshold_is_33_percent() {
        let mut q = UtilQuadrants::default();
        q.add_sample(0.329, 0.331, 1.0);
        assert_eq!(q.low_sm, 1.0);
    }

    #[test]
    fn chain_weights_averages_by_time() {
        let a = SimReport { elapsed_s: 1.0, avg_sm_util: 1.0, ..Default::default() };
        let b = SimReport { elapsed_s: 3.0, avg_sm_util: 0.0, ..Default::default() };
        let c = a.chain(&b);
        assert!((c.avg_sm_util - 0.25).abs() < 1e-12);
        assert_eq!(c.elapsed_s, 4.0);
    }
}
