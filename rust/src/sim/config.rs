//! GPU machine configurations for the timing simulator.
//!
//! The paper evaluates on NVAS configured as an A100; we expose the same
//! first-order machine parameters plus the sensitivity knobs used by its
//! §6 hardware-synergy study (scale SM count / L2 bandwidth / DRAM
//! bandwidth independently).

/// First-order GPU machine description.
#[derive(Debug, Clone)]
pub struct GpuConfig {
    pub name: String,
    /// Number of streaming multiprocessors.
    pub sm_count: usize,
    /// Core clock, GHz.
    pub clock_ghz: f64,
    /// Peak TensorCore throughput, FLOP/s (bf16/fp16 with fp32 accum).
    pub tensor_flops: f64,
    /// Peak SIMT (CUDA-core fp32) throughput, FLOP/s.
    pub simt_flops: f64,
    /// DRAM (HBM) bandwidth, bytes/s.
    pub dram_bw: f64,
    /// Aggregate L2 bandwidth, bytes/s (≈3× DRAM per the paper's §2).
    pub l2_bw: f64,
    /// L2 capacity, bytes.
    pub l2_capacity: usize,
    /// Shared memory (scratchpad) per SM, bytes.
    pub smem_per_sm: usize,
    /// Round-trip DRAM latency, seconds (paper: ≈409 ns on A100).
    pub dram_latency_s: f64,
    /// L2 hit latency, seconds (~200 cycles).
    pub l2_latency_s: f64,
    /// Sustained global-atomic rate per CTA under no contention
    /// (paper §4.1 microbenchmark: 100 M atomics/s/CTA on A100).
    pub atomics_per_sec_per_cta: f64,
    /// Max co-resident CTAs per SM (occupancy limit used by the grid
    /// scheduler; Kitsune pairs one SIMT CTA with one TENSOR CTA).
    pub max_ctas_per_sm: usize,
}

impl GpuConfig {
    /// NVIDIA A100-SXM4-40GB — the paper's evaluation target.
    pub fn a100() -> Self {
        GpuConfig {
            name: "A100".into(),
            sm_count: 108,
            clock_ghz: 1.41,
            tensor_flops: 312e12,
            simt_flops: 19.5e12,
            dram_bw: 1.555e12,
            l2_bw: 4.7e12, // ~3x DRAM (paper §2, [11-13])
            l2_capacity: 40 * 1024 * 1024,
            smem_per_sm: 192 * 1024, // paper §3 ("192 KB of shared memory")
            dram_latency_s: 409e-9,  // paper §3 (572 cycles @ 1.4 GHz)
            l2_latency_s: 142e-9,    // ~200 cycles
            atomics_per_sec_per_cta: 100e6, // paper §4.1
            max_ctas_per_sm: 2,
        }
    }

    /// NVIDIA V100-SXM2 (80 SMs) — used for Welder comparison context.
    pub fn v100() -> Self {
        GpuConfig {
            name: "V100".into(),
            sm_count: 80,
            clock_ghz: 1.38,
            tensor_flops: 125e12,
            simt_flops: 15.7e12,
            dram_bw: 0.9e12,
            l2_bw: 2.7e12,
            l2_capacity: 6 * 1024 * 1024,
            smem_per_sm: 96 * 1024,
            dram_latency_s: 440e-9,
            l2_latency_s: 150e-9,
            atomics_per_sec_per_cta: 60e6,
            max_ctas_per_sm: 2,
        }
    }

    /// NVIDIA H100-SXM5 (132 SMs).
    pub fn h100() -> Self {
        GpuConfig {
            name: "H100".into(),
            sm_count: 132,
            clock_ghz: 1.83,
            tensor_flops: 989e12,
            simt_flops: 67e12,
            dram_bw: 3.35e12,
            l2_bw: 10.0e12,
            l2_capacity: 50 * 1024 * 1024,
            smem_per_sm: 228 * 1024,
            dram_latency_s: 380e-9,
            l2_latency_s: 130e-9,
            atomics_per_sec_per_cta: 150e6,
            max_ctas_per_sm: 2,
        }
    }

    /// Sensitivity knob: scale on-chip compute (SM count and both pipes).
    pub fn scale_compute(mut self, f: f64) -> Self {
        self.sm_count = ((self.sm_count as f64) * f).round() as usize;
        self.tensor_flops *= f;
        self.simt_flops *= f;
        self.name = format!("{}+{:.0}%SM", self.name, (f - 1.0) * 100.0);
        self
    }

    /// Sensitivity knob: scale L2 (crossbar) bandwidth.
    pub fn scale_l2_bw(mut self, f: f64) -> Self {
        self.l2_bw *= f;
        self.name = format!("{}+{:.0}%L2", self.name, (f - 1.0) * 100.0);
        self
    }

    /// Sensitivity knob: scale DRAM bandwidth (the paper keeps this fixed
    /// in the hardware-synergy study — it is the expensive resource).
    pub fn scale_dram_bw(mut self, f: f64) -> Self {
        self.dram_bw *= f;
        self.name = format!("{}+{:.0}%HBM", self.name, (f - 1.0) * 100.0);
        self
    }

    /// Peak tensor FLOP/s of one SM.
    pub fn tensor_flops_per_sm(&self) -> f64 {
        self.tensor_flops / self.sm_count as f64
    }

    /// Peak SIMT FLOP/s of one SM.
    pub fn simt_flops_per_sm(&self) -> f64 {
        self.simt_flops / self.sm_count as f64
    }

    /// DRAM bandwidth available per SM if divided evenly — the paper quotes
    /// ≈61 GB/s per SM for L2+HBM headroom comparisons.
    pub fn dram_bw_per_sm(&self) -> f64 {
        self.dram_bw / self.sm_count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_matches_paper_constants() {
        let c = GpuConfig::a100();
        assert_eq!(c.sm_count, 108);
        assert_eq!(c.smem_per_sm, 192 * 1024);
        // L2 BW ≈ 3x DRAM BW (paper §2)
        let ratio = c.l2_bw / c.dram_bw;
        assert!(ratio > 2.5 && ratio < 3.5, "L2/DRAM ratio {ratio}");
        // DRAM round trip ≈ 572 cycles at 1.4 GHz (paper §3)
        let cycles = c.dram_latency_s * c.clock_ghz * 1e9;
        assert!((cycles - 572.0).abs() < 15.0, "{cycles} cycles");
    }

    #[test]
    fn scaling_knobs() {
        let c = GpuConfig::a100().scale_compute(2.0);
        assert_eq!(c.sm_count, 216);
        assert_eq!(c.tensor_flops, 624e12);
        let c = GpuConfig::a100().scale_l2_bw(2.0);
        assert!((c.l2_bw - 9.4e12).abs() < 1e9);
        assert!((c.dram_bw - 1.555e12).abs() < 1e9, "DRAM unchanged");
    }

    #[test]
    fn per_sm_rates() {
        let c = GpuConfig::a100();
        // ~61 GB/s DRAM headroom per SM when L2+HBM shared evenly — the
        // constant the paper quotes in §4.1 (1.555e12/108 ≈ 14.4 GB/s DRAM;
        // the paper's 61 GB/s figure combines L2+DRAM: 4.7e12+1.555e12)/108.
        let combined = (c.l2_bw + c.dram_bw) / c.sm_count as f64;
        assert!(combined > 55e9 && combined < 65e9, "{combined}");
    }
}
