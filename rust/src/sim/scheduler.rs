//! The GPU grid scheduler — baseline and Kitsune variants.
//!
//! Baseline GPUs "greedily find the first available SM for CTA dispatch
//! using a hardware arbiter (i.e., round-robin)" [paper §4.2, citing 48].
//! Kitsune's modest hardware change replaces the single arbiter with two,
//! one per resource class, so that CTAs of *different* types get paired on
//! the same SM and the TensorCore + SIMT pipes overlap.

use super::config::GpuConfig;
use super::sm::SmState;
use crate::graph::ResourceClass;

/// Dispatch policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Single round-robin arbiter, type-blind (current GPUs).
    RoundRobin,
    /// Kitsune: one arbiter per class; pairing-aware placement (§4.2).
    DualArbiter,
}

/// Grid-scheduler state: arbiter cursors over the SM array.
#[derive(Debug, Clone)]
pub struct GridScheduler {
    pub policy: SchedPolicy,
    /// Round-robin cursor for the type-blind arbiter.
    cursor: usize,
    /// Kitsune per-class cursors.
    cursor_tensor: usize,
    cursor_simt: usize,
}

impl GridScheduler {
    pub fn new(policy: SchedPolicy) -> Self {
        GridScheduler { policy, cursor: 0, cursor_tensor: 0, cursor_simt: 0 }
    }

    /// Pick an SM for a CTA of `class` needing `smem` bytes, or `None` if
    /// nothing fits (caller retries after a retirement). Updates occupancy.
    pub fn place(
        &mut self,
        class: ResourceClass,
        smem: usize,
        sms: &mut [SmState],
        cfg: &GpuConfig,
    ) -> Option<usize> {
        let n = sms.len();
        let pick = match self.policy {
            SchedPolicy::RoundRobin => {
                // First fit from the cursor, type-blind.
                let start = self.cursor;
                (0..n)
                    .map(|i| (start + i) % n)
                    .find(|&i| sms[i].fits(smem, cfg.smem_per_sm, cfg.max_ctas_per_sm))
            }
            SchedPolicy::DualArbiter => {
                let start = match class {
                    ResourceClass::Tensor => self.cursor_tensor,
                    ResourceClass::Simt => self.cursor_simt,
                };
                // Pass 1: prefer an SM already running the *other* class and
                // none of ours — this is what makes pairing systematic.
                let other = match class {
                    ResourceClass::Tensor => ResourceClass::Simt,
                    ResourceClass::Simt => ResourceClass::Tensor,
                };
                let paired = (0..n).map(|i| (start + i) % n).find(|&i| {
                    sms[i].fits(smem, cfg.smem_per_sm, cfg.max_ctas_per_sm)
                        && sms[i].count(other) > 0
                        && sms[i].count(class) == 0
                });
                // Pass 2: an SM with no CTA of our class (spread own class).
                let spread = paired.or_else(|| {
                    (0..n).map(|i| (start + i) % n).find(|&i| {
                        sms[i].fits(smem, cfg.smem_per_sm, cfg.max_ctas_per_sm)
                            && sms[i].count(class) == 0
                    })
                });
                // Pass 3: anything that fits.
                spread.or_else(|| {
                    (0..n)
                        .map(|i| (start + i) % n)
                        .find(|&i| sms[i].fits(smem, cfg.smem_per_sm, cfg.max_ctas_per_sm))
                })
            }
        };
        if let Some(i) = pick {
            sms[i].admit(class, smem);
            match self.policy {
                SchedPolicy::RoundRobin => self.cursor = (i + 1) % n,
                SchedPolicy::DualArbiter => match class {
                    ResourceClass::Tensor => self.cursor_tensor = (i + 1) % n,
                    ResourceClass::Simt => self.cursor_simt = (i + 1) % n,
                },
            }
        }
        pick
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n: usize) -> (Vec<SmState>, GpuConfig) {
        let mut cfg = GpuConfig::a100();
        cfg.sm_count = n;
        (vec![SmState::default(); n], cfg)
    }

    #[test]
    fn round_robin_spreads_in_order() {
        let (mut sms, cfg) = setup(4);
        let mut s = GridScheduler::new(SchedPolicy::RoundRobin);
        let picks: Vec<_> = (0..4)
            .map(|_| s.place(ResourceClass::Tensor, 0, &mut sms, &cfg).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 3]);
    }

    #[test]
    fn dual_arbiter_pairs_types() {
        let (mut sms, cfg) = setup(4);
        let mut s = GridScheduler::new(SchedPolicy::DualArbiter);
        // 4 tensor CTAs fill SMs 0..4, then 4 simt CTAs must land on the
        // same SMs (pairing), one each.
        for _ in 0..4 {
            s.place(ResourceClass::Tensor, 0, &mut sms, &cfg).unwrap();
        }
        for _ in 0..4 {
            s.place(ResourceClass::Simt, 0, &mut sms, &cfg).unwrap();
        }
        assert!(sms.iter().all(|sm| sm.is_paired()), "{sms:?}");
    }

    #[test]
    fn round_robin_does_not_guarantee_pairing() {
        // Interleaved arrivals with the type-blind arbiter stack same-type
        // CTAs: T,T arrive first and land on SM0, SM1; then S,S land on
        // SM2, SM3 — zero pairing. (This is the §4.2 motivation.)
        let (mut sms, cfg) = setup(2);
        let mut s = GridScheduler::new(SchedPolicy::RoundRobin);
        s.place(ResourceClass::Tensor, 0, &mut sms, &cfg).unwrap();
        s.place(ResourceClass::Tensor, 0, &mut sms, &cfg).unwrap();
        s.place(ResourceClass::Simt, 0, &mut sms, &cfg).unwrap();
        s.place(ResourceClass::Simt, 0, &mut sms, &cfg).unwrap();
        // With 2 slots per SM, RR packs T on 0, T on 1, S on 0, S on 1 —
        // accidental pairing CAN happen; assert only that DualArbiter is at
        // least as paired as RR for the adversarial order below.
        let rr_paired = sms.iter().filter(|sm| sm.is_paired()).count();

        let (mut sms2, cfg2) = setup(2);
        let mut s2 = GridScheduler::new(SchedPolicy::DualArbiter);
        s2.place(ResourceClass::Tensor, 0, &mut sms2, &cfg2).unwrap();
        s2.place(ResourceClass::Tensor, 0, &mut sms2, &cfg2).unwrap();
        s2.place(ResourceClass::Simt, 0, &mut sms2, &cfg2).unwrap();
        s2.place(ResourceClass::Simt, 0, &mut sms2, &cfg2).unwrap();
        let da_paired = sms2.iter().filter(|sm| sm.is_paired()).count();
        assert!(da_paired >= rr_paired);
        assert_eq!(da_paired, 2);
    }

    #[test]
    fn placement_respects_smem() {
        let (mut sms, cfg) = setup(2);
        let mut s = GridScheduler::new(SchedPolicy::DualArbiter);
        let big = cfg.smem_per_sm; // whole scratchpad
        assert!(s.place(ResourceClass::Tensor, big, &mut sms, &cfg).is_some());
        assert!(s.place(ResourceClass::Tensor, big, &mut sms, &cfg).is_some());
        assert!(s.place(ResourceClass::Tensor, big, &mut sms, &cfg).is_none());
    }
}
