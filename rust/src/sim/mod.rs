//! GPU timing simulator — the NVAS substitute (DESIGN.md §1).
//!
//! Event-driven, fluid-rate simulation of an A100-class GPU: SMs with
//! separate TensorCore/SIMT pipes, a grid scheduler (baseline round-robin
//! or Kitsune's §4.2 dual arbiter), shared L2/DRAM bandwidth pools, and
//! bounded inter-CTA queues for spatial pipelines.

pub mod config;
pub mod kernel;
pub mod scheduler;
pub mod sm;
pub mod engine;
pub mod stats;

pub use config::GpuConfig;
pub use engine::Engine;
pub use kernel::{KernelDesc, PipelineDesc, QueueDesc, StageDesc};
pub use scheduler::{GridScheduler, SchedPolicy};
pub use sm::SmState;
pub use stats::{SimReport, UtilQuadrants, LOW_UTIL_THRESHOLD};

