//! Continuous/dynamic batching policy: how the dispatcher coalesces
//! queued requests into dispatch rounds.
//!
//! A round opens when the first request is pulled from the admission
//! queue and closes when either `max_tiles` tiles have been gathered or
//! `max_delay` has elapsed since the round opened — the classic
//! max-batch/max-delay window. Rounds are *continuous*: a new round
//! opens immediately, so the pipeline never waits for the previous
//! round to drain (no head-of-line blocking between rounds; the
//! in-flight high-water mark in the dispatcher bounds pipeline
//! occupancy instead).

use std::time::{Duration, Instant};

/// The coalescing window for the serve tier's dispatcher.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Close a round once this many tiles have been gathered (also the
    /// dispatcher's in-flight refill increment).
    pub max_tiles: usize,
    /// Close a round this long after its first request even if under
    /// `max_tiles` — bounds the queueing latency batching can add.
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_tiles: 32, max_delay: Duration::from_millis(2) }
    }
}

impl BatchPolicy {
    /// Clamp degenerate configurations (a zero-tile window would never
    /// dispatch anything).
    pub fn normalized(self) -> Self {
        BatchPolicy { max_tiles: self.max_tiles.max(1), max_delay: self.max_delay }
    }
}

/// Pure round-accumulation state machine, driven by the dispatcher and
/// unit-tested on its own: tracks tiles gathered this round and when
/// the round opened.
#[derive(Debug)]
pub struct BatchBuilder {
    policy: BatchPolicy,
    tiles: usize,
    opened: Option<Instant>,
}

impl BatchBuilder {
    pub fn new(policy: BatchPolicy) -> Self {
        BatchBuilder { policy: policy.normalized(), tiles: 0, opened: None }
    }

    /// Account one admitted request of `n_tiles`; opens the round on the
    /// first call.
    pub fn admit(&mut self, n_tiles: usize, now: Instant) {
        if self.opened.is_none() {
            self.opened = Some(now);
        }
        self.tiles += n_tiles;
    }

    /// Tiles gathered in the current round.
    pub fn tiles(&self) -> usize {
        self.tiles
    }

    /// Is a round open (at least one request admitted)?
    pub fn is_open(&self) -> bool {
        self.opened.is_some()
    }

    /// Should the open round be dispatched now? True once full
    /// (`max_tiles`) or once `max_delay` has elapsed since it opened.
    pub fn should_dispatch(&self, now: Instant) -> bool {
        match self.opened {
            None => false,
            Some(t0) => {
                self.tiles >= self.policy.max_tiles
                    || now.duration_since(t0) >= self.policy.max_delay
            }
        }
    }

    /// Time left in the delay window (how long the dispatcher may keep
    /// waiting for more requests). Zero when the round must dispatch.
    pub fn remaining_delay(&self, now: Instant) -> Duration {
        match self.opened {
            None => self.policy.max_delay,
            Some(_) if self.tiles >= self.policy.max_tiles => Duration::ZERO,
            Some(t0) => self.policy.max_delay.saturating_sub(now.duration_since(t0)),
        }
    }

    /// Close the round, resetting for the next one.
    pub fn reset(&mut self) {
        self.tiles = 0;
        self.opened = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_closes_on_max_tiles() {
        let mut b = BatchBuilder::new(BatchPolicy {
            max_tiles: 4,
            max_delay: Duration::from_secs(60),
        });
        let t0 = Instant::now();
        assert!(!b.should_dispatch(t0));
        b.admit(2, t0);
        assert!(!b.should_dispatch(t0));
        assert!(b.remaining_delay(t0) > Duration::ZERO);
        b.admit(2, t0);
        assert!(b.should_dispatch(t0), "full round must dispatch");
        assert_eq!(b.remaining_delay(t0), Duration::ZERO);
        b.reset();
        assert!(!b.is_open());
        assert_eq!(b.tiles(), 0);
    }

    #[test]
    fn round_closes_on_max_delay() {
        let mut b = BatchBuilder::new(BatchPolicy {
            max_tiles: 1_000,
            max_delay: Duration::from_millis(5),
        });
        let t0 = Instant::now();
        b.admit(1, t0);
        assert!(!b.should_dispatch(t0));
        let later = t0 + Duration::from_millis(6);
        assert!(b.should_dispatch(later), "expired window must dispatch");
        assert_eq!(b.remaining_delay(later), Duration::ZERO);
    }

    #[test]
    fn zero_max_tiles_is_normalized() {
        let b = BatchBuilder::new(BatchPolicy { max_tiles: 0, max_delay: Duration::ZERO });
        assert_eq!(b.policy.max_tiles, 1);
    }
}
