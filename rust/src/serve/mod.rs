//! `kitsune::serve` — a continuous-batching, SLO-aware serving tier on
//! the warm pipeline.
//!
//! The paper's spatial pipelines shine when many independent requests
//! stream through one persistent pipeline instead of being serialized
//! per client (the scheduling shape Opara argues for). This module
//! turns the session facade's ticketed submission into that serving
//! system:
//!
//! * **continuous/dynamic batching** ([`batch`]): an admission queue
//!   coalesces queued requests into dispatch rounds up to a
//!   max-batch/max-delay window ([`BatchPolicy`]), keeping the pipeline
//!   fed without head-of-line blocking between rounds;
//! * **deadline + SLO-aware scheduling** ([`admission`]): requests
//!   carry optional deadlines, dispatch order is earliest-deadline-first,
//!   and load is shed with typed [`ServeError::DeadlineExceeded`] /
//!   [`ServeError::AdmissionRejected`] when the queue depth or the
//!   estimated wait exceeds budget — backpressure reaches callers
//!   through the bounded [`Server::try_submit`];
//! * **multi-model residency** ([`registry`]): several warm sessions
//!   resident at once under one memory budget with LRU eviction;
//! * **observability** ([`stats`]): per-request latency histograms
//!   (p50/p95/p99), queue-depth and shed counters via [`Server::stats`].
//!
//! ```no_run
//! use kitsune::serve::{Server, ServeConfig};
//! use kitsune::session::{nerf_trunk_graph, Session};
//! use std::sync::Arc;
//! use std::time::Duration;
//!
//! let session = Arc::new(
//!     Session::builder().graph(nerf_trunk_graph(8192, 60, 64, 3)).tile_rows(128).build()?,
//! );
//! let server = Server::single("nerf", session, ServeConfig::default());
//! let tiles = server.registry().get("nerf")?.make_tiles(4, 7)?;
//! let handle = server.try_submit("nerf", tiles, Some(Duration::from_millis(250)))?;
//! let reply = handle.wait()?;
//! println!("{} tiles in {:?}  p99 {:.2} ms",
//!          reply.outputs.len(), reply.latency, server.stats().latency.p99_ms);
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! The dispatcher is one control-plane OS thread per server: it never
//! computes (stage kernels still run as cooperative pumps on
//! [`crate::sched`]); it only moves requests between the admission
//! queue and the pipelines and reaps finished tickets via the
//! non-blocking [`crate::session::Ticket::try_wait`].

pub mod admission;
pub mod batch;
pub mod registry;
pub mod stats;

pub use batch::{BatchBuilder, BatchPolicy};
pub use registry::{session_resident_bytes, ModelRegistry};
pub use stats::{LatencyHistogram, LatencySnapshot, ServeStats, StatsSnapshot};

use admission::{AdmitError, AdmissionQueue, Pending, PopOutcome};
use crate::fault::Health;
use crate::runtime::Tensor;
use crate::sched::env_usize;
use crate::session::{Session, Ticket};
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Typed serving failure modes. Every admitted request resolves as
/// exactly one of completed / shed / failed; submission itself can be
/// refused with the first three variants.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Admission queue at capacity — backpressure; retry later.
    AdmissionRejected { depth: usize, capacity: usize },
    /// The request's deadline cannot (or could not) be met; shed.
    DeadlineExceeded { deadline_ms: u64 },
    /// No model registered under this name.
    UnknownModel { name: String, available: Vec<String> },
    /// Registering the model would exceed the registry's memory budget
    /// even after evicting every idle model.
    BudgetExceeded { requested: u64, resident: u64, budget: u64 },
    /// Malformed request (tile dims, non-streamable model).
    BadRequest(String),
    /// The server is shutting down.
    ShuttingDown,
    /// A stage kernel failed while serving the request.
    Stage(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::AdmissionRejected { depth, capacity } => {
                write!(f, "admission rejected: queue depth {depth} at capacity {capacity}")
            }
            ServeError::DeadlineExceeded { deadline_ms } => {
                write!(f, "deadline exceeded: {deadline_ms} ms budget cannot be met; request shed")
            }
            ServeError::UnknownModel { name, available } => {
                write!(f, "unknown model `{name}` — registered: {}", available.join(", "))
            }
            ServeError::BudgetExceeded { requested, resident, budget } => write!(
                f,
                "memory budget exceeded: model needs {requested} B, {resident} B resident \
                 of {budget} B budget (nothing idle left to evict)"
            ),
            ServeError::BadRequest(msg) => write!(f, "bad request: {msg}"),
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::Stage(msg) => write!(f, "stage failure: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// A served request's successful outcome.
#[derive(Debug, Clone)]
pub struct ServeResult {
    /// Output tiles, in the request's submission order.
    pub outputs: Vec<Tensor>,
    /// End-to-end latency: admission to delivery.
    pub latency: Duration,
}

/// Exactly-once resolution slot shared between the caller's handle and
/// the dispatcher.
struct ResponseShared {
    state: Mutex<Option<Result<ServeResult, ServeError>>>,
    cv: Condvar,
}

impl ResponseShared {
    fn new() -> Arc<Self> {
        Arc::new(ResponseShared { state: Mutex::new(None), cv: Condvar::new() })
    }

    /// First resolution wins; later calls are ignored (the dispatcher's
    /// paths are disjoint per request, so a second call is a logic bug).
    fn resolve(&self, r: Result<ServeResult, ServeError>) {
        let mut s = self.state.lock().unwrap();
        debug_assert!(s.is_none(), "response resolved twice");
        if s.is_none() {
            *s = Some(r);
            self.cv.notify_all();
        }
    }
}

/// Caller's handle to one admitted request.
pub struct ResponseHandle {
    shared: Arc<ResponseShared>,
}

impl ResponseHandle {
    /// Block until the request resolves (completed, shed, or failed).
    pub fn wait(self) -> Result<ServeResult, ServeError> {
        let mut s = self.shared.state.lock().unwrap();
        loop {
            if let Some(r) = s.take() {
                return r;
            }
            s = self.shared.cv.wait(s).unwrap();
        }
    }

    /// Non-consuming poll: has the request resolved?
    pub fn is_done(&self) -> bool {
        self.shared.state.lock().unwrap().is_some()
    }
}

/// Serving-tier configuration. Environment knobs (`KITSUNE_SERVE_*`)
/// seed the defaults; unparseable values warn once and fall back, the
/// same policy as `KITSUNE_WORKERS` (see [`crate::sched::env_usize`]).
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Coalescing window (`KITSUNE_SERVE_MAX_BATCH` tiles /
    /// `KITSUNE_SERVE_MAX_DELAY_US`).
    pub batch: BatchPolicy,
    /// Admission queue bound (`KITSUNE_SERVE_QUEUE_DEPTH` requests).
    pub queue_depth: usize,
    /// Deadline applied to requests that do not carry one (None: no SLO).
    pub default_deadline: Option<Duration>,
    /// Failed attempts a request may retry (`KITSUNE_SERVE_RETRIES`).
    /// Retries re-enter the admission queue (EDF order) and stay
    /// deadline-aware: a blown deadline sheds instead of retrying.
    pub max_retries: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            batch: BatchPolicy {
                max_tiles: env_usize("KITSUNE_SERVE_MAX_BATCH", 32, 1 << 16),
                max_delay: Duration::from_micros(
                    env_usize("KITSUNE_SERVE_MAX_DELAY_US", 2_000, 10_000_000) as u64,
                ),
            },
            queue_depth: env_usize("KITSUNE_SERVE_QUEUE_DEPTH", 256, 1 << 20),
            default_deadline: None,
            max_retries: env_usize("KITSUNE_SERVE_RETRIES", 1, 16),
        }
    }
}

/// Request payload carried through the admission queue.
struct RequestPayload {
    model: String,
    tiles: Vec<Tensor>,
    handle: Arc<ResponseShared>,
    enqueued: Instant,
    /// Failed attempts this request may still retry.
    retries_left: usize,
}

type Req = Pending<RequestPayload>;

/// State shared between submitters and the dispatcher.
struct Shared {
    registry: Arc<ModelRegistry>,
    queue: AdmissionQueue<RequestPayload>,
    stats: ServeStats,
    /// EWMA of per-tile service time (ns); 0 until the first completion.
    est_tile_ns: AtomicU64,
    /// Tiles dispatched into pipelines and not yet reaped.
    inflight_tiles: AtomicUsize,
    cfg: ServeConfig,
    seq: AtomicU64,
    closing: AtomicBool,
}

impl Shared {
    fn est_tile_ns(&self) -> u64 {
        self.est_tile_ns.load(Ordering::Relaxed)
    }

    /// Fold one completed batch into the per-tile service-time EWMA.
    fn observe_service(&self, elapsed: Duration, n_tiles: usize) {
        if n_tiles == 0 {
            return;
        }
        let sample = (elapsed.as_nanos() / n_tiles as u128).min(u128::from(u64::MAX)) as u64;
        let old = self.est_tile_ns.load(Ordering::Relaxed);
        let new = if old == 0 { sample } else { (old * 4 + sample) / 5 };
        self.est_tile_ns.store(new, Ordering::Relaxed);
    }

    /// Estimated wait for a new request of `n_tiles`, from everything
    /// queued ahead of it plus tiles already in flight. Conservative: it
    /// assumes serial drain (pipeline overlap only makes it finish
    /// sooner).
    fn estimated_wait(&self, n_tiles: usize) -> Duration {
        let est = self.est_tile_ns();
        if est == 0 {
            return Duration::ZERO;
        }
        let tiles =
            self.queue.queued_tiles() + self.inflight_tiles.load(Ordering::SeqCst) + n_tiles;
        Duration::from_nanos(est.saturating_mul(tiles as u64))
    }
}

/// One request dispatched into a pipeline, awaiting its ticket.
struct InFlight {
    ticket: Ticket,
    ctx: ReqCtx,
}

/// Everything needed to resolve (or retry) a dispatched request once
/// its ticket settles.
struct ReqCtx {
    handle: Arc<ResponseShared>,
    n_tiles: usize,
    enqueued: Instant,
    model: String,
    deadline: Option<Instant>,
    /// Cloned input tiles kept for a retry — populated only while the
    /// target pipeline is Degraded (the no-fault fast path never pays
    /// for the clone).
    retry_tiles: Option<Vec<Tensor>>,
    retries_left: usize,
}

/// The serving tier: admission queue + dispatcher over a
/// [`ModelRegistry`] of warm sessions.
pub struct Server {
    shared: Arc<Shared>,
    dispatcher: Mutex<Option<JoinHandle<()>>>,
}

impl Server {
    /// Stand up the serving tier over `registry`: spawns the dispatcher
    /// (one control-plane thread; all compute stays on the scheduler's
    /// pumps).
    pub fn new(registry: Arc<ModelRegistry>, cfg: ServeConfig) -> Server {
        let cfg = ServeConfig { batch: cfg.batch.normalized(), ..cfg };
        let shared = Arc::new(Shared {
            registry,
            queue: AdmissionQueue::new(cfg.queue_depth),
            stats: ServeStats::default(),
            est_tile_ns: AtomicU64::new(0),
            inflight_tiles: AtomicUsize::new(0),
            cfg,
            seq: AtomicU64::new(0),
            closing: AtomicBool::new(false),
        });
        let dispatcher = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("kitsune-serve-dispatch".to_string())
                .spawn(move || dispatch_loop(shared))
                .expect("spawn serve dispatcher")
        };
        Server { shared, dispatcher: Mutex::new(Some(dispatcher)) }
    }

    /// Convenience: a server over a single model (budget-less registry).
    pub fn single(name: impl Into<String>, session: Arc<Session>, cfg: ServeConfig) -> Server {
        Server::new(ModelRegistry::single(name, session), cfg)
    }

    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.shared.registry
    }

    pub fn config(&self) -> &ServeConfig {
        &self.shared.cfg
    }

    /// Bounded, non-blocking submission — the backpressure surface.
    /// Refuses with [`ServeError::AdmissionRejected`] when the queue is
    /// at capacity and with [`ServeError::DeadlineExceeded`] when the
    /// estimated wait already blows the deadline's slack.
    pub fn try_submit(
        &self,
        model: &str,
        tiles: Vec<Tensor>,
        deadline: Option<Duration>,
    ) -> Result<ResponseHandle, ServeError> {
        self.submit_inner(model, tiles, deadline, false)
    }

    /// Like [`Server::try_submit`], but blocks while the queue is full
    /// instead of refusing (still sheds on hopeless deadlines).
    pub fn submit(
        &self,
        model: &str,
        tiles: Vec<Tensor>,
        deadline: Option<Duration>,
    ) -> Result<ResponseHandle, ServeError> {
        self.submit_inner(model, tiles, deadline, true)
    }

    fn submit_inner(
        &self,
        model: &str,
        tiles: Vec<Tensor>,
        deadline: Option<Duration>,
        block: bool,
    ) -> Result<ResponseHandle, ServeError> {
        let shared = &self.shared;
        if shared.closing.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown);
        }
        if tiles.is_empty() {
            return Err(ServeError::BadRequest("empty request (no tiles)".to_string()));
        }
        let session = shared.registry.get(model)?;
        let Some(dims) = session.tile_dims() else {
            return Err(ServeError::BadRequest(format!(
                "model `{model}` is not streamable (no warm pipeline)"
            )));
        };
        for t in &tiles {
            if t.dims != dims {
                return Err(ServeError::BadRequest(format!(
                    "tile dims {:?} != model `{model}` input {:?}",
                    t.dims, dims
                )));
            }
        }
        let budget = deadline.or(shared.cfg.default_deadline);
        let now = Instant::now();
        if let Some(d) = budget {
            // SLO-aware shed at admission: if everything already queued
            // or in flight is estimated to take longer than this
            // request's whole budget, admitting it only wastes capacity.
            let est = shared.estimated_wait(tiles.len());
            if est > d {
                shared.stats.refused_deadline.fetch_add(1, Ordering::Relaxed);
                return Err(ServeError::DeadlineExceeded { deadline_ms: d.as_millis() as u64 });
            }
        }
        let handle = ResponseShared::new();
        let mut req = Req {
            seq: shared.seq.fetch_add(1, Ordering::SeqCst),
            deadline: budget.map(|d| now + d),
            tiles: tiles.len(),
            payload: RequestPayload {
                model: model.to_string(),
                tiles,
                handle: Arc::clone(&handle),
                enqueued: now,
                retries_left: shared.cfg.max_retries,
            },
        };
        loop {
            match shared.queue.try_push(req) {
                Ok(()) => {
                    shared.stats.admitted.fetch_add(1, Ordering::Relaxed);
                    return Ok(ResponseHandle { shared: handle });
                }
                Err(AdmitError::Closed(_)) => return Err(ServeError::ShuttingDown),
                Err(AdmitError::Full(r)) => {
                    if !block {
                        shared.stats.rejected.fetch_add(1, Ordering::Relaxed);
                        return Err(ServeError::AdmissionRejected {
                            depth: shared.queue.len(),
                            capacity: shared.queue.capacity(),
                        });
                    }
                    req = r;
                    shared.queue.wait_space(Duration::from_millis(5));
                }
            }
        }
    }

    /// Point-in-time snapshot of the serving tier's counters, queue
    /// depth, in-flight tiles, and latency percentiles.
    pub fn stats(&self) -> StatsSnapshot {
        let shared = &self.shared;
        shared.stats.snapshot(
            shared.queue.len(),
            shared.inflight_tiles.load(Ordering::SeqCst),
            shared.est_tile_ns() as f64 / 1_000.0,
        )
    }

    /// The serving tier + the whole process in the Prometheus text
    /// exposition format (version 0.0.4): serve counters/latency
    /// quantiles first, then every layer of
    /// [`crate::telemetry::snapshot`] — queue aggregates, scheduler
    /// workers, per-pipeline stage/edge/traffic series.
    pub fn prometheus(&self) -> String {
        use std::fmt::Write as _;
        let s = self.stats();
        let mut out = String::new();
        out.push_str("# TYPE kitsune_serve_requests_total counter\n");
        for (state, n) in [
            ("admitted", s.admitted),
            ("rejected", s.rejected),
            ("refused_deadline", s.refused_deadline),
            ("shed_deadline", s.shed_deadline),
            ("shed_shutdown", s.shed_shutdown),
            ("completed", s.completed),
            ("failed", s.failed),
            ("retried", s.retried),
        ] {
            let _ = writeln!(out, "kitsune_serve_requests_total{{state=\"{state}\"}} {n}");
        }
        out.push_str("# TYPE kitsune_serve_queue_depth gauge\n");
        let _ = writeln!(out, "kitsune_serve_queue_depth {}", s.queue_depth);
        out.push_str("# TYPE kitsune_serve_inflight_tiles gauge\n");
        let _ = writeln!(out, "kitsune_serve_inflight_tiles {}", s.in_flight_tiles);
        out.push_str("# TYPE kitsune_serve_latency_ms summary\n");
        for (q, ms) in [
            ("0.5", s.latency.p50_ms),
            ("0.95", s.latency.p95_ms),
            ("0.99", s.latency.p99_ms),
        ] {
            let _ = writeln!(out, "kitsune_serve_latency_ms{{quantile=\"{q}\"}} {ms:.6}");
        }
        out.push_str(&crate::telemetry::prometheus());
        out
    }

    /// Requests queued for dispatch right now.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.len()
    }

    /// Tiles dispatched into pipelines and not yet reaped.
    pub fn in_flight_tiles(&self) -> usize {
        self.shared.inflight_tiles.load(Ordering::SeqCst)
    }

    /// Drain the tier: queued-but-undispatched requests are shed
    /// ([`ServeError::ShuttingDown`]), in-flight tiles drain to
    /// completion, the dispatcher retires. Idempotent; also runs on
    /// `Drop`. Registered sessions stay warm (the registry owns them).
    pub fn shutdown(&self) {
        self.shared.closing.store(true, Ordering::SeqCst);
        self.shared.queue.close();
        if let Some(h) = self.dispatcher.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Dispatcher poll granularity while requests are in flight.
const POLL: Duration = Duration::from_micros(200);
/// Dispatcher wait while fully idle (close() wakes it immediately).
const IDLE_WAIT: Duration = Duration::from_millis(10);

/// The dispatcher: pull EDF-ordered requests, coalesce them into
/// max-batch/max-delay rounds, shed hopeless deadlines, feed the
/// pipelines up to the in-flight high-water mark, and reap completed
/// tickets back to their handles.
fn dispatch_loop(shared: Arc<Shared>) {
    let mut inflight: Vec<InFlight> = Vec::new();
    let mut builder = BatchBuilder::new(shared.cfg.batch);
    let mut round: Vec<Req> = Vec::new();
    loop {
        reap(&shared, &mut inflight);
        if shared.closing.load(Ordering::SeqCst) {
            break;
        }
        let now = Instant::now();
        let wait = if builder.is_open() {
            builder.remaining_delay(now).min(POLL)
        } else if inflight.is_empty() {
            IDLE_WAIT
        } else {
            POLL
        };
        match shared.queue.pop_timeout(wait) {
            PopOutcome::Item(req) => {
                builder.admit(req.tiles, Instant::now());
                round.push(req);
            }
            PopOutcome::Empty => {}
            PopOutcome::Closed => break,
        }
        if builder.is_open() && builder.should_dispatch(Instant::now()) {
            dispatch_round(&shared, &mut round, &mut inflight);
            builder.reset();
        }
    }
    // Shutdown: shed the open round and everything still queued; drain
    // every in-flight ticket so no handle is left hanging and the
    // pipelines' in-flight tables return to empty.
    for req in round.drain(..) {
        shed_shutdown(&shared, req);
    }
    // Keep draining until the queue reports Closed (closed *and* empty):
    // a submitter that passed the closing check may still land one push
    // before `shutdown()` closes the queue, and stopping at Empty would
    // leave that request's handle unresolved forever.
    loop {
        match shared.queue.pop_timeout(Duration::from_millis(1)) {
            PopOutcome::Item(req) => shed_shutdown(&shared, req),
            PopOutcome::Empty => {}
            PopOutcome::Closed => break,
        }
    }
    while !inflight.is_empty() {
        reap_blocking(&shared, &mut inflight, Duration::from_millis(5));
    }
}

fn shed_shutdown(shared: &Shared, req: Req) {
    shared.stats.shed_shutdown.fetch_add(1, Ordering::Relaxed);
    req.payload.handle.resolve(Err(ServeError::ShuttingDown));
}

/// Dispatch one coalesced round in EDF order: per request, shed if its
/// deadline is already (or is estimated to be) unmeetable, otherwise
/// submit its tiles to the model's warm pipeline. Blocks (reaping) when
/// the in-flight high-water mark is hit, so a slow pipeline backs
/// pressure up into the admission queue instead of into unbounded
/// submissions.
fn dispatch_round(shared: &Arc<Shared>, round: &mut Vec<Req>, inflight: &mut Vec<InFlight>) {
    let high_water = shared.cfg.batch.max_tiles.saturating_mul(2).max(1);
    for req in round.drain(..) {
        if shared.closing.load(Ordering::SeqCst) {
            shed_shutdown(shared, req);
            continue;
        }
        let now = Instant::now();
        if let Some(deadline) = req.deadline {
            let est = Duration::from_nanos(
                shared.est_tile_ns().saturating_mul(
                    (shared.inflight_tiles.load(Ordering::SeqCst) + req.tiles) as u64,
                ),
            );
            if now >= deadline || now + est > deadline {
                shared.stats.shed_deadline.fetch_add(1, Ordering::Relaxed);
                req.payload.handle.resolve(Err(ServeError::DeadlineExceeded {
                    deadline_ms: deadline
                        .saturating_duration_since(req.payload.enqueued)
                        .as_millis() as u64,
                }));
                continue;
            }
        }
        while shared.inflight_tiles.load(Ordering::SeqCst) + req.tiles > high_water
            && !inflight.is_empty()
        {
            reap_blocking(shared, inflight, Duration::from_micros(500));
        }
        let deadline = req.deadline;
        let RequestPayload { model, tiles, handle, enqueued, retries_left } = req.payload;
        let n_tiles = tiles.len();
        let session = match shared.registry.get(&model) {
            Ok(s) => s,
            Err(e) => {
                shared.stats.failed.fetch_add(1, Ordering::Relaxed);
                handle.resolve(Err(e));
                continue;
            }
        };
        // Supervision gate: a Failed pipeline cannot serve — count the
        // failed attempt synchronously (its tiles never enter the
        // pipeline, so they stay available for the retry) and let the
        // retry/shed policy resolve the request.
        if let Health::Failed { stage } = session.health() {
            let payload = RequestPayload { model, tiles, handle, enqueued, retries_left };
            retry_or_resolve(
                shared,
                payload,
                deadline,
                ServeError::Stage(format!("pipeline failed at stage `{stage}`")),
            );
            continue;
        }
        // Keep a clone for retry only while supervision has flagged the
        // pipeline; the healthy fast path never pays for it.
        let retry_tiles = if retries_left > 0 && !session.health().is_healthy() {
            Some(tiles.clone())
        } else {
            None
        };
        match session.submit(tiles) {
            Ok(ticket) => {
                shared.inflight_tiles.fetch_add(n_tiles, Ordering::SeqCst);
                inflight.push(InFlight {
                    ticket,
                    ctx: ReqCtx {
                        handle,
                        n_tiles,
                        enqueued,
                        model,
                        deadline,
                        retry_tiles,
                        retries_left,
                    },
                });
            }
            Err(e) => {
                // `submit` consumed the tiles; a retry is possible only
                // from the Degraded-path clone.
                let payload = RequestPayload {
                    model,
                    tiles: retry_tiles.unwrap_or_default(),
                    handle,
                    enqueued,
                    retries_left,
                };
                retry_or_resolve(shared, payload, deadline, ServeError::Stage(format!("{e:#}")));
            }
        }
    }
}

/// A dispatched attempt failed. Shed on a blown deadline, re-enqueue
/// for another attempt while the retry budget and input tiles allow,
/// resolve failed otherwise. Retries ride the same admission queue, so
/// EDF ordering still holds against new arrivals; `admitted` is not
/// re-counted — every admitted request resolves exactly once.
fn retry_or_resolve(
    shared: &Shared,
    mut payload: RequestPayload,
    deadline: Option<Instant>,
    err: ServeError,
) {
    let now = Instant::now();
    if let Some(d) = deadline {
        if now >= d {
            shared.stats.shed_deadline.fetch_add(1, Ordering::Relaxed);
            payload.handle.resolve(Err(ServeError::DeadlineExceeded {
                deadline_ms: d.saturating_duration_since(payload.enqueued).as_millis() as u64,
            }));
            return;
        }
    }
    if payload.retries_left > 0
        && !payload.tiles.is_empty()
        && !shared.closing.load(Ordering::SeqCst)
    {
        payload.retries_left -= 1;
        let n_tiles = payload.tiles.len();
        let req = Req {
            seq: shared.seq.fetch_add(1, Ordering::SeqCst),
            deadline,
            tiles: n_tiles,
            payload,
        };
        match shared.queue.try_push(req) {
            Ok(()) => {
                shared.stats.retried.fetch_add(1, Ordering::Relaxed);
                return;
            }
            Err(AdmitError::Closed(r)) => {
                shed_shutdown(shared, r);
                return;
            }
            Err(AdmitError::Full(r)) => {
                // Queue saturated — the dispatcher must not block on
                // itself; resolve with the attempt's failure.
                shared.stats.failed.fetch_add(1, Ordering::Relaxed);
                r.payload.handle.resolve(Err(err));
                return;
            }
        }
    }
    shared.stats.failed.fetch_add(1, Ordering::Relaxed);
    payload.handle.resolve(Err(err));
}

/// Reap every completed in-flight ticket (non-blocking).
fn reap(shared: &Arc<Shared>, inflight: &mut Vec<InFlight>) {
    if inflight.is_empty() {
        return;
    }
    let mut still = Vec::with_capacity(inflight.len());
    for f in inflight.drain(..) {
        let InFlight { ticket, ctx } = f;
        match ticket.try_wait() {
            Ok(result) => finish(shared, ctx, result),
            Err(ticket) => still.push(InFlight { ticket, ctx }),
        }
    }
    *inflight = still;
}

/// Block up to `timeout` on the oldest in-flight ticket, then sweep the
/// rest non-blocking — used while waiting out the high-water mark and
/// during shutdown drain.
fn reap_blocking(shared: &Arc<Shared>, inflight: &mut Vec<InFlight>, timeout: Duration) {
    if inflight.is_empty() {
        return;
    }
    let InFlight { ticket, ctx } = inflight.remove(0);
    match ticket.wait_timeout(timeout) {
        Ok(result) => finish(shared, ctx, result),
        Err(ticket) => inflight.insert(0, InFlight { ticket, ctx }),
    }
    reap(shared, inflight);
}

/// Deliver one resolved ticket to its handle, updating counters, the
/// latency histogram, and the service-time estimate. A failed ticket
/// goes through the deadline-aware retry/shed policy.
fn finish(shared: &Arc<Shared>, ctx: ReqCtx, result: anyhow::Result<crate::session::BatchResult>) {
    let ReqCtx { handle, n_tiles, enqueued, model, deadline, retry_tiles, retries_left } = ctx;
    shared.inflight_tiles.fetch_sub(n_tiles, Ordering::SeqCst);
    match result {
        Ok(batch) => {
            let latency = enqueued.elapsed();
            shared.observe_service(Duration::from_secs_f64(batch.elapsed_s), n_tiles);
            shared.stats.completed.fetch_add(1, Ordering::Relaxed);
            shared.stats.latency.record(latency);
            handle.resolve(Ok(ServeResult { outputs: batch.outputs, latency }));
        }
        Err(e) => {
            let payload = RequestPayload {
                model,
                tiles: retry_tiles.unwrap_or_default(),
                handle,
                enqueued,
                retries_left,
            };
            retry_or_resolve(shared, payload, deadline, ServeError::Stage(format!("{e:#}")));
        }
    }
}
