//! Serving observability: the counter set behind
//! [`crate::serve::Server::stats`].
//!
//! The latency histogram is the crate-wide log-bucketed
//! [`crate::telemetry::Histogram`] (8 linear sub-buckets per
//! power-of-two octave, ≤ 12.5% quantile error, lock-free) — re-exported
//! here under its historical serving names. The serve tier was the
//! first user of that histogram; `kitsune::telemetry` generalized it so
//! per-stage compute/queue-wait timings and request latencies share one
//! implementation (and its unit tests, which live in
//! `telemetry::hist`).

use std::sync::atomic::{AtomicU64, Ordering};

/// The crate-wide log-bucketed duration histogram, under its historical
/// serving-tier name.
pub use crate::telemetry::Histogram as LatencyHistogram;
pub use crate::telemetry::LatencySnapshot;

/// The serve tier's counters + end-to-end latency histogram. All fields
/// are updated lock-free by the submit path and the dispatcher.
#[derive(Default)]
pub struct ServeStats {
    /// Requests that passed admission (a [`crate::serve::ResponseHandle`]
    /// was issued).
    pub admitted: AtomicU64,
    /// Requests refused at admission: queue depth over bound.
    pub rejected: AtomicU64,
    /// Requests refused at admission because the estimated wait already
    /// exceeded their deadline budget (never admitted — no handle).
    pub refused_deadline: AtomicU64,
    /// Admitted requests shed at dispatch for a deadline that could not
    /// be met.
    pub shed_deadline: AtomicU64,
    /// Admitted requests shed because the server shut down first.
    pub shed_shutdown: AtomicU64,
    /// Requests whose batch completed and was delivered.
    pub completed: AtomicU64,
    /// Requests whose stage kernel failed.
    pub failed: AtomicU64,
    /// Failed attempts re-enqueued for another try (supervision-aware
    /// retry). A retried request is still pending, so this counter is
    /// *not* part of the admitted == resolved invariant.
    pub retried: AtomicU64,
    /// End-to-end latency (enqueue → delivery) of completed requests.
    pub latency: LatencyHistogram,
}

/// Snapshot of the whole serving tier, returned by
/// [`crate::serve::Server::stats`].
#[derive(Debug, Clone, PartialEq)]
pub struct StatsSnapshot {
    pub admitted: u64,
    pub rejected: u64,
    pub refused_deadline: u64,
    pub shed_deadline: u64,
    pub shed_shutdown: u64,
    pub completed: u64,
    pub failed: u64,
    /// Failed attempts re-enqueued for another try (not a terminal
    /// state — excluded from [`StatsSnapshot::resolved`]).
    pub retried: u64,
    /// Requests queued for dispatch right now.
    pub queue_depth: usize,
    /// Tiles in flight through pipelines right now.
    pub in_flight_tiles: usize,
    /// Dispatcher's current EWMA of per-tile service time (0 until the
    /// first completion).
    pub est_tile_us: f64,
    pub latency: LatencySnapshot,
}

impl StatsSnapshot {
    /// Requests shed for any reason (deadline or shutdown).
    pub fn shed(&self) -> u64 {
        self.shed_deadline + self.shed_shutdown
    }

    /// Every admitted request must end in exactly one of these buckets
    /// (refusals never produce a handle); the stress test asserts
    /// `admitted == resolved()` once the tier drains.
    pub fn resolved(&self) -> u64 {
        self.completed + self.failed + self.shed()
    }
}

impl ServeStats {
    pub fn snapshot(
        &self,
        queue_depth: usize,
        in_flight_tiles: usize,
        est_tile_us: f64,
    ) -> StatsSnapshot {
        StatsSnapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            refused_deadline: self.refused_deadline.load(Ordering::Relaxed),
            shed_deadline: self.shed_deadline.load(Ordering::Relaxed),
            shed_shutdown: self.shed_shutdown.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            retried: self.retried.load(Ordering::Relaxed),
            queue_depth,
            in_flight_tiles,
            est_tile_us,
            latency: self.latency.snapshot(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    // Bucket-shape unit tests moved to `crate::telemetry::hist` with the
    // histogram itself; this exercises the serving-side re-export.
    #[test]
    fn latency_histogram_is_the_shared_telemetry_histogram() {
        let h = LatencyHistogram::default();
        for _ in 0..90 {
            h.record(Duration::from_millis(1));
        }
        for _ in 0..10 {
            h.record(Duration::from_millis(100));
        }
        let s: LatencySnapshot = h.snapshot();
        assert_eq!(s.count, 100);
        assert!(s.p50_ms >= 1.0 && s.p50_ms < 1.2, "p50 {}", s.p50_ms);
        assert!(s.p99_ms >= 100.0 && s.p99_ms < 120.0, "p99 {}", s.p99_ms);
    }

    #[test]
    fn resolved_counts_every_terminal_bucket() {
        let stats = ServeStats::default();
        stats.admitted.store(10, Ordering::Relaxed);
        stats.completed.store(6, Ordering::Relaxed);
        stats.failed.store(1, Ordering::Relaxed);
        stats.shed_deadline.store(2, Ordering::Relaxed);
        stats.shed_shutdown.store(1, Ordering::Relaxed);
        stats.retried.store(4, Ordering::Relaxed);
        let s = stats.snapshot(0, 0, 0.0);
        assert_eq!(s.shed(), 3);
        assert_eq!(s.resolved(), 10);
        assert_eq!(s.admitted, s.resolved(), "retries are not terminal");
    }
}
