//! Serving observability: a lock-free log-bucketed latency histogram
//! and the counter set behind [`crate::serve::Server::stats`].
//!
//! The histogram uses 8 linear sub-buckets per power-of-two octave of
//! nanoseconds (HDR-style), so percentile queries are accurate to
//! ≤ 12.5% across the full ns..minutes range with a fixed 512-slot
//! atomic array — recording is two atomic adds, cheap enough to sit on
//! the per-request completion path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Buckets: 8 exact slots for 0..8 ns, then 8 sub-buckets per octave.
const N_BUCKETS: usize = 512;

/// Lock-free latency histogram (concurrent `record`, snapshot reads).
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

/// Bucket index for a nanosecond value: identity below 8, then
/// `8 + octave*8 + top-3-bits-after-the-leading-1`.
fn bucket_of(ns: u64) -> usize {
    if ns < 8 {
        return ns as usize;
    }
    let msb = 63 - ns.leading_zeros() as u64; // >= 3
    let sub = (ns >> (msb - 3)) & 0x7;
    (8 + (msb - 3) * 8 + sub) as usize
}

/// Upper bound (ns) of a bucket — the value percentile queries report.
fn bucket_upper(idx: usize) -> u64 {
    if idx < 8 {
        return idx as u64 + 1;
    }
    let o = (idx - 8) / 8;
    let sub = ((idx - 8) % 8) as u64;
    ((8 + sub) << o) + (1u64 << o)
}

impl LatencyHistogram {
    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u128::from(u64::MAX)) as u64;
        let idx = bucket_of(ns).min(N_BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Latency at quantile `q` in `[0, 1]`, as the upper bound of the
    /// bucket where the cumulative count crosses `q * count` (≤ 12.5%
    /// overestimate). Zero when nothing has been recorded.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total = self.count.load(Ordering::Relaxed);
        if total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return bucket_upper(idx);
            }
        }
        self.max_ns.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> LatencySnapshot {
        let count = self.count.load(Ordering::Relaxed);
        let mean_ns = if count == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / count as f64
        };
        LatencySnapshot {
            count,
            mean_ms: mean_ns * 1e-6,
            p50_ms: self.quantile_ns(0.50) as f64 * 1e-6,
            p95_ms: self.quantile_ns(0.95) as f64 * 1e-6,
            p99_ms: self.quantile_ns(0.99) as f64 * 1e-6,
            max_ms: self.max_ns.load(Ordering::Relaxed) as f64 * 1e-6,
        }
    }
}

/// Point-in-time percentile summary of one histogram.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySnapshot {
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

/// The serve tier's counters + end-to-end latency histogram. All fields
/// are updated lock-free by the submit path and the dispatcher.
#[derive(Default)]
pub struct ServeStats {
    /// Requests that passed admission (a [`crate::serve::ResponseHandle`]
    /// was issued).
    pub admitted: AtomicU64,
    /// Requests refused at admission: queue depth over bound.
    pub rejected: AtomicU64,
    /// Requests refused at admission because the estimated wait already
    /// exceeded their deadline budget (never admitted — no handle).
    pub refused_deadline: AtomicU64,
    /// Admitted requests shed at dispatch for a deadline that could not
    /// be met.
    pub shed_deadline: AtomicU64,
    /// Admitted requests shed because the server shut down first.
    pub shed_shutdown: AtomicU64,
    /// Requests whose batch completed and was delivered.
    pub completed: AtomicU64,
    /// Requests whose stage kernel failed.
    pub failed: AtomicU64,
    /// Failed attempts re-enqueued for another try (supervision-aware
    /// retry). A retried request is still pending, so this counter is
    /// *not* part of the admitted == resolved invariant.
    pub retried: AtomicU64,
    /// End-to-end latency (enqueue → delivery) of completed requests.
    pub latency: LatencyHistogram,
}

/// Snapshot of the whole serving tier, returned by
/// [`crate::serve::Server::stats`].
#[derive(Debug, Clone, PartialEq)]
pub struct StatsSnapshot {
    pub admitted: u64,
    pub rejected: u64,
    pub refused_deadline: u64,
    pub shed_deadline: u64,
    pub shed_shutdown: u64,
    pub completed: u64,
    pub failed: u64,
    /// Failed attempts re-enqueued for another try (not a terminal
    /// state — excluded from [`StatsSnapshot::resolved`]).
    pub retried: u64,
    /// Requests queued for dispatch right now.
    pub queue_depth: usize,
    /// Tiles in flight through pipelines right now.
    pub in_flight_tiles: usize,
    /// Dispatcher's current EWMA of per-tile service time (0 until the
    /// first completion).
    pub est_tile_us: f64,
    pub latency: LatencySnapshot,
}

impl StatsSnapshot {
    /// Requests shed for any reason (deadline or shutdown).
    pub fn shed(&self) -> u64 {
        self.shed_deadline + self.shed_shutdown
    }

    /// Every admitted request must end in exactly one of these buckets
    /// (refusals never produce a handle); the stress test asserts
    /// `admitted == resolved()` once the tier drains.
    pub fn resolved(&self) -> u64 {
        self.completed + self.failed + self.shed()
    }
}

impl ServeStats {
    pub fn snapshot(
        &self,
        queue_depth: usize,
        in_flight_tiles: usize,
        est_tile_us: f64,
    ) -> StatsSnapshot {
        StatsSnapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            refused_deadline: self.refused_deadline.load(Ordering::Relaxed),
            shed_deadline: self.shed_deadline.load(Ordering::Relaxed),
            shed_shutdown: self.shed_shutdown.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            retried: self.retried.load(Ordering::Relaxed),
            queue_depth,
            in_flight_tiles,
            est_tile_us,
            latency: self.latency.snapshot(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_monotone_and_cover_range() {
        let mut prev = 0u64;
        for idx in 0..N_BUCKETS {
            let up = bucket_upper(idx);
            assert!(up > prev, "bucket {idx}: {up} <= {prev}");
            prev = up;
        }
        // Round trip: a value lands in a bucket whose bound is within
        // 12.5% above it.
        for ns in [1u64, 7, 8, 100, 1_000, 55_555, 1_000_000, 123_456_789] {
            let up = bucket_upper(bucket_of(ns));
            assert!(up > ns, "{ns} -> {up}");
            assert!((up as f64) <= ns as f64 * 1.125 + 1.0, "{ns} -> {up}");
        }
    }

    #[test]
    fn quantiles_track_recorded_distribution() {
        let h = LatencyHistogram::default();
        // 90 fast (1ms) + 10 slow (100ms).
        for _ in 0..90 {
            h.record(Duration::from_millis(1));
        }
        for _ in 0..10 {
            h.record(Duration::from_millis(100));
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert!(s.p50_ms >= 1.0 && s.p50_ms < 1.2, "p50 {}", s.p50_ms);
        assert!(s.p99_ms >= 100.0 && s.p99_ms < 120.0, "p99 {}", s.p99_ms);
        assert!(s.max_ms >= 100.0);
        assert!(s.mean_ms > 1.0 && s.mean_ms < 100.0);
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_ns(0.99), 0);
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p50_ms, 0.0);
    }
}
