//! Multi-model residency: several warm [`Session`] pipelines resident
//! at once, with per-model memory accounting and an LRU
//! eviction/refusal policy against a configured budget.
//!
//! Residency cost of a model is what its warm pipeline pins in host
//! memory: the sum of every stage's weight tensors plus the ring-queue
//! pool between stages (capacity × tile bytes per edge). When inserting
//! a model would exceed the budget, least-recently-used *idle* models
//! (zero tiles in flight) are evicted — shut down and dropped — and if
//! that still cannot make room the insert is refused with a typed
//! [`ServeError::BudgetExceeded`].

use super::ServeError;
use crate::session::Session;
use std::sync::{Arc, Mutex};

/// Bytes a warm session pins: stage weights + inter-stage queue pool.
/// Tile bytes are estimated from the input tile spec (stage output dims
/// vary but stay within the same order for the suite's pipelines).
/// Both terms are charged at the session's storage precision — a bf16
/// model pins half the bytes of its f32 twin.
pub fn session_resident_bytes(session: &Session) -> u64 {
    let Some(pipeline) = session.pipeline() else {
        return 0;
    };
    let weight_bytes: u64 = pipeline
        .stages
        .iter()
        .map(|s| s.weights.iter().map(|w| w.payload_bytes()).sum::<u64>())
        .sum();
    let elem = session.precision().bytes() as u64;
    let tile_bytes: u64 =
        session.tile_dims().map(|d| d.iter().product::<usize>() as u64 * elem).unwrap_or(0);
    let n_edges = pipeline.stages.len() as u64 + 1;
    weight_bytes + n_edges * pipeline.queue_capacity as u64 * tile_bytes
}

struct Model {
    name: String,
    session: Arc<Session>,
    bytes: u64,
    /// Logical LRU clock value of the last `get`.
    last_used: u64,
}

struct RegistryInner {
    models: Vec<Model>,
    tick: u64,
}

/// Named warm sessions under one memory budget.
pub struct ModelRegistry {
    budget: Option<u64>,
    inner: Mutex<RegistryInner>,
}

impl ModelRegistry {
    /// `budget_bytes: None` disables accounting-based refusal.
    pub fn new(budget_bytes: Option<u64>) -> Self {
        ModelRegistry {
            budget: budget_bytes,
            inner: Mutex::new(RegistryInner { models: Vec::new(), tick: 0 }),
        }
    }

    /// Convenience: a budget-less registry holding one model.
    pub fn single(name: impl Into<String>, session: Arc<Session>) -> Arc<Self> {
        let r = Arc::new(ModelRegistry::new(None));
        r.insert(name, session).expect("budget-less insert cannot fail");
        r
    }

    pub fn budget_bytes(&self) -> Option<u64> {
        self.budget
    }

    /// Register a warm session under `name` (replacing any same-named
    /// model). Evicts least-recently-used idle models as needed to fit
    /// the budget; returns the evicted names. Refuses (typed) when the
    /// budget cannot be met even after evicting everything idle.
    pub fn insert(
        &self,
        name: impl Into<String>,
        session: Arc<Session>,
    ) -> Result<Vec<String>, ServeError> {
        let name = name.into();
        let bytes = session_resident_bytes(&session);
        let mut evicted_sessions: Vec<(String, Arc<Session>)> = Vec::new();
        let mut g = self.inner.lock().unwrap();
        // Replacement frees the old entry's accounting first.
        if let Some(pos) = g.models.iter().position(|m| m.name == name) {
            let old = g.models.remove(pos);
            evicted_sessions.push((old.name.clone(), old.session));
        }
        if let Some(budget) = self.budget {
            let mut resident: u64 = g.models.iter().map(|m| m.bytes).sum();
            while resident + bytes > budget {
                // Oldest idle model goes first.
                let victim = g
                    .models
                    .iter()
                    .enumerate()
                    .filter(|(_, m)| m.session.in_flight() == 0)
                    .min_by_key(|(_, m)| m.last_used)
                    .map(|(i, _)| i);
                match victim {
                    Some(i) => {
                        let old = g.models.remove(i);
                        resident -= old.bytes;
                        evicted_sessions.push((old.name.clone(), old.session));
                    }
                    None => {
                        // Roll back the replacement removal? The old
                        // same-named model was already displaced by
                        // intent; refusal only blocks the new insert.
                        drop(g);
                        for (_, s) in &evicted_sessions {
                            s.shutdown();
                        }
                        return Err(ServeError::BudgetExceeded {
                            requested: bytes,
                            resident,
                            budget,
                        });
                    }
                }
            }
        }
        g.tick += 1;
        let last_used = g.tick;
        g.models.push(Model { name, session, bytes, last_used });
        drop(g);
        let mut names = Vec::new();
        for (n, s) in evicted_sessions {
            s.shutdown();
            names.push(n);
        }
        Ok(names)
    }

    /// Look up a model, bumping its LRU clock.
    pub fn get(&self, name: &str) -> Result<Arc<Session>, ServeError> {
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        match g.models.iter_mut().find(|m| m.name == name) {
            Some(m) => {
                m.last_used = tick;
                Ok(Arc::clone(&m.session))
            }
            None => Err(ServeError::UnknownModel {
                name: name.to_string(),
                available: g.models.iter().map(|m| m.name.clone()).collect(),
            }),
        }
    }

    /// Evict one model by name (shut down and dropped). `false` if absent.
    pub fn evict(&self, name: &str) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.models.iter().position(|m| m.name == name) {
            Some(i) => {
                let old = g.models.remove(i);
                drop(g);
                old.session.shutdown();
                true
            }
            None => false,
        }
    }

    /// Registered model names, in insertion order.
    pub fn names(&self) -> Vec<String> {
        self.inner.lock().unwrap().models.iter().map(|m| m.name.clone()).collect()
    }

    /// (name, resident bytes) per model.
    pub fn accounting(&self) -> Vec<(String, u64)> {
        self.inner.lock().unwrap().models.iter().map(|m| (m.name.clone(), m.bytes)).collect()
    }

    /// Total resident bytes across registered models.
    pub fn resident_bytes(&self) -> u64 {
        self.inner.lock().unwrap().models.iter().map(|m| m.bytes).sum()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Shut every registered session down (used at server shutdown).
    pub fn shutdown_all(&self) {
        let sessions: Vec<Arc<Session>> = {
            let g = self.inner.lock().unwrap();
            g.models.iter().map(|m| Arc::clone(&m.session)).collect()
        };
        for s in sessions {
            s.shutdown();
        }
    }
}
