//! Deadline-aware admission: a bounded EDF (earliest-deadline-first)
//! queue between the submit path and the dispatcher.
//!
//! Requests carry an optional absolute deadline; the dispatcher always
//! pulls the request with the least slack next (ties and deadline-free
//! requests fall back to FIFO by admission sequence). The queue is
//! bounded — `try_push` refuses above capacity, which is the
//! backpressure surface [`crate::serve::Server::try_submit`] exposes —
//! and closing it lets the dispatcher drain what is left for shedding.

use std::collections::BinaryHeap;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One admitted-but-not-yet-dispatched request. Generic over the
/// payload so the queue's ordering and bounds are unit-testable alone.
#[derive(Debug)]
pub struct Pending<T> {
    /// Admission sequence (FIFO tiebreak).
    pub seq: u64,
    /// Absolute deadline, if the request carries one.
    pub deadline: Option<Instant>,
    /// Tiles this request will occupy in the pipeline.
    pub tiles: usize,
    pub payload: T,
}

struct Entry<T>(Pending<T>);

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.0.seq == other.0.seq
    }
}
impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    /// Max-heap priority: earliest deadline wins; deadline-carrying
    /// requests outrank deadline-free ones; equal deadlines break FIFO.
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        let ord = match (self.0.deadline, other.0.deadline) {
            (Some(a), Some(b)) => b.cmp(&a),
            (Some(_), None) => std::cmp::Ordering::Greater,
            (None, Some(_)) => std::cmp::Ordering::Less,
            (None, None) => std::cmp::Ordering::Equal,
        };
        ord.then_with(|| other.0.seq.cmp(&self.0.seq))
    }
}

/// Why a push was refused.
#[derive(Debug)]
pub enum AdmitError<T> {
    /// Queue at capacity — backpressure; the request is handed back.
    Full(Pending<T>),
    /// Queue closed (server shutting down).
    Closed(Pending<T>),
}

/// Result of a bounded pop.
pub enum PopOutcome<T> {
    Item(Pending<T>),
    /// Nothing arrived within the timeout.
    Empty,
    /// Closed and fully drained — the dispatcher can retire.
    Closed,
}

struct Inner<T> {
    heap: BinaryHeap<Entry<T>>,
    tiles: usize,
    closed: bool,
}

/// Bounded EDF queue: one mutex + two condvars (item side for the
/// dispatcher, space side for blocking submitters).
pub struct AdmissionQueue<T> {
    inner: Mutex<Inner<T>>,
    item_cv: Condvar,
    space_cv: Condvar,
    capacity: usize,
}

impl<T> AdmissionQueue<T> {
    pub fn new(capacity: usize) -> Self {
        AdmissionQueue {
            inner: Mutex::new(Inner { heap: BinaryHeap::new(), tiles: 0, closed: false }),
            item_cv: Condvar::new(),
            space_cv: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Requests currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Tiles across all queued requests (admission wait estimation).
    pub fn queued_tiles(&self) -> usize {
        self.inner.lock().unwrap().tiles
    }

    /// Non-blocking bounded push.
    pub fn try_push(&self, req: Pending<T>) -> Result<(), AdmitError<T>> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(AdmitError::Closed(req));
        }
        if g.heap.len() >= self.capacity {
            return Err(AdmitError::Full(req));
        }
        g.tiles += req.tiles;
        g.heap.push(Entry(req));
        drop(g);
        self.item_cv.notify_one();
        Ok(())
    }

    /// Pop the highest-priority request, waiting up to `timeout` for one
    /// to arrive. Returns `Closed` only once closed *and* drained.
    pub fn pop_timeout(&self, timeout: Duration) -> PopOutcome<T> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(Entry(req)) = g.heap.pop() {
                g.tiles -= req.tiles;
                drop(g);
                self.space_cv.notify_one();
                return PopOutcome::Item(req);
            }
            if g.closed {
                return PopOutcome::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopOutcome::Empty;
            }
            let (guard, _) = self.item_cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Block up to `timeout` for the queue to have room (or close).
    /// Returns `true` if a subsequent `try_push` has a chance.
    pub fn wait_space(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed || g.heap.len() < self.capacity {
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self.space_cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Close the queue: further pushes fail, waiters wake; queued
    /// requests stay poppable so the dispatcher can shed them.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.item_cv.notify_all();
        self.space_cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(seq: u64, deadline_ms: Option<u64>, base: Instant) -> Pending<u64> {
        Pending {
            seq,
            deadline: deadline_ms.map(|ms| base + Duration::from_millis(ms)),
            tiles: 1,
            payload: seq,
        }
    }

    fn pop_now<T>(q: &AdmissionQueue<T>) -> Pending<T> {
        match q.pop_timeout(Duration::ZERO) {
            PopOutcome::Item(r) => r,
            _ => panic!("expected an item"),
        }
    }

    #[test]
    fn pops_in_edf_order_with_fifo_tiebreak() {
        let q = AdmissionQueue::new(16);
        let base = Instant::now();
        // Out-of-order deadlines; two without deadlines; a tie at 50ms.
        q.try_push(req(0, Some(200), base)).unwrap();
        q.try_push(req(1, None, base)).unwrap();
        q.try_push(req(2, Some(50), base)).unwrap();
        q.try_push(req(3, Some(50), base)).unwrap();
        q.try_push(req(4, None, base)).unwrap();
        q.try_push(req(5, Some(10), base)).unwrap();
        let order: Vec<u64> = (0..6).map(|_| pop_now(&q).seq).collect();
        assert_eq!(order, vec![5, 2, 3, 0, 1, 4], "EDF then FIFO");
    }

    #[test]
    fn bounded_push_refuses_above_capacity() {
        let q = AdmissionQueue::new(2);
        let base = Instant::now();
        q.try_push(req(0, None, base)).unwrap();
        q.try_push(req(1, None, base)).unwrap();
        assert_eq!(q.queued_tiles(), 2);
        match q.try_push(req(2, None, base)) {
            Err(AdmitError::Full(r)) => assert_eq!(r.seq, 2),
            _ => panic!("expected Full"),
        }
        // Popping frees space.
        let _ = pop_now(&q);
        q.try_push(req(3, None, base)).unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let q = AdmissionQueue::new(4);
        let base = Instant::now();
        q.try_push(req(0, None, base)).unwrap();
        q.close();
        match q.try_push(req(1, None, base)) {
            Err(AdmitError::Closed(_)) => {}
            _ => panic!("expected Closed"),
        }
        assert!(matches!(q.pop_timeout(Duration::ZERO), PopOutcome::Item(_)));
        assert!(matches!(q.pop_timeout(Duration::ZERO), PopOutcome::Closed));
        assert!(q.wait_space(Duration::ZERO), "closed queue never blocks submitters");
    }

    #[test]
    fn pop_timeout_times_out_empty() {
        let q: AdmissionQueue<()> = AdmissionQueue::new(4);
        let t0 = Instant::now();
        assert!(matches!(q.pop_timeout(Duration::from_millis(10)), PopOutcome::Empty));
        assert!(t0.elapsed() >= Duration::from_millis(8));
    }
}
