//! Text renderers: one function per paper table/figure, producing the
//! same rows/series the paper reports (shape-level reproduction).

use super::experiments::AppEval;
use crate::exec::geomean;
use crate::queue::QueueModel;
use crate::sim::{GpuConfig, UtilQuadrants};
use std::fmt::Write as _;

fn bar(frac: f64, width: usize) -> String {
    let n = ((frac.clamp(0.0, 1.0)) * width as f64).round() as usize;
    "#".repeat(n)
}

/// Table 1: the application list (static).
pub fn table1() -> String {
    let rows = [
        ("DLRM", "2019", "Predicting ad clicks"),
        ("MeshGraphNets", "2020", "Mesh based physical simulation"),
        ("NeRF", "2021", "View synthesis"),
        ("GraphCast", "2022", "Weather forecast prediction"),
        ("Llama 3 8B", "2024", "Language modeling"),
    ];
    let mut s = String::from("Table 1. Description of selected applications.\n");
    s.push_str(&format!("{:<15} {:<6} {}\n", "Application", "Year", "Use Case"));
    for (a, y, u) in rows {
        s.push_str(&format!("{a:<15} {y:<6} {u}\n"));
    }
    s
}

/// Table 2: fusion coverage and traffic reduction, vertical vs Kitsune.
pub fn table2(inference: &[AppEval], training: &[AppEval]) -> String {
    let mut s = String::from("Table 2. Summary of fusions and traffic reductions.\n");
    s.push_str(&format!(
        "{:<8} {:>5} | {:>14} {:>14} | {:>10} {:>10}\n",
        "App", "#Ops", "Vertical", "Kitsune", "Vert.", "Kitsu."
    ));
    let section = |title: &str, evals: &[AppEval], s: &mut String| {
        s.push_str(&format!("-- {title} --\n"));
        for e in evals {
            let vf_pct = 100.0 * e.vf_fused_ops as f64 / e.n_ops as f64;
            let ki_pct = 100.0 * e.kitsune_fused_ops as f64 / e.n_ops as f64;
            writeln!(
                s,
                "{:<8} {:>5} | {:>7} ({:>4.0}%) {:>7} ({:>4.0}%) | {:>9.2}% {:>9.2}%",
                e.name,
                e.n_ops,
                e.vf_fused_ops,
                vf_pct,
                e.kitsune_fused_ops,
                ki_pct,
                100.0 * e.vertical_traffic_reduction(),
                100.0 * e.kitsune_traffic_reduction()
            )
            .unwrap();
        }
    };
    section("Inference", inference, &mut s);
    section("Training", training, &mut s);
    s
}

fn quadrant_row(name: &str, mode: &str, q: &UtilQuadrants) -> String {
    let n = q.normalized();
    format!(
        "{name:<8} {mode:<10} | both-low {:>5.1}%  low-SM {:>5.1}%  low-DRAM {:>5.1}%  neither {:>5.1}%\n",
        100.0 * n.both_low,
        100.0 * n.low_sm,
        100.0 * n.low_dram,
        100.0 * n.neither_low
    )
}

/// Fig 3: runtime in SM×DRAM utilization quadrants, BSP + vertical fusion.
pub fn fig3(inference: &[AppEval], training: &[AppEval]) -> String {
    let mut s =
        String::from("Fig 3. Runtime by SM/DRAM utilization (low = <33% of peak), baseline execution.\n");
    s.push_str("-- Inference --\n");
    for e in inference {
        s.push_str(&quadrant_row(&e.name, "bulk-sync", &e.bsp.sim.quadrants));
        s.push_str(&quadrant_row(&e.name, "tensorrt", &e.vertical.sim.quadrants));
    }
    s.push_str("-- Training (bulk-sync only; TensorRT does not support training) --\n");
    for e in training {
        s.push_str(&quadrant_row(&e.name, "bulk-sync", &e.bsp.sim.quadrants));
    }
    s
}

/// Fig 13: same quadrants under Kitsune.
pub fn fig13(inference: &[AppEval], training: &[AppEval]) -> String {
    let mut s = String::from("Fig 13. Runtime by SM/DRAM utilization under Kitsune.\n");
    s.push_str("-- Inference --\n");
    for e in inference {
        s.push_str(&quadrant_row(&e.name, "kitsune", &e.kitsune.sim.quadrants));
    }
    s.push_str("-- Training --\n");
    for e in training {
        s.push_str(&quadrant_row(&e.name, "kitsune", &e.kitsune.sim.quadrants));
    }
    s
}

/// Fig 5: queue bandwidth sweep (sync on/off) at the 54-queue point.
pub fn fig5(cfg: &GpuConfig) -> String {
    let m = QueueModel::new(cfg.clone());
    let mut s = format!(
        "Fig 5. GPU atomics / queue performance on {} (54 queues, 108 CTAs).\n",
        cfg.name
    );
    s.push_str(&format!(
        "{:>9} | {:>12} {:>12} | {:>12} | {:>6}\n",
        "payload", "agg (sync)", "agg (nosync)", "per-q (sync)", "spill"
    ));
    for (sync, nosync) in m.fig5_sweep(54) {
        writeln!(
            s,
            "{:>7}KB | {:>10.2}GB/s {:>10.2}GB/s | {:>10.2}GB/s | {:>6}",
            sync.payload_bytes / 1024,
            sync.aggregate_bw / 1e9,
            nosync.aggregate_bw / 1e9,
            sync.per_queue_bw / 1e9,
            if sync.spills_to_hbm { "HBM" } else { "L2" }
        )
        .unwrap();
    }
    writeln!(
        s,
        "atomics bound per queue: 16KB={:.0}GB/s .. 64KB={:.0}GB/s (paper: 385-1541 GB/s)",
        m.atomics_bound(16 * 1024) / 1e9,
        m.atomics_bound(64 * 1024) / 1e9
    )
    .unwrap();
    s
}

/// Fig 10/12 rows: per-subgraph speedups, with sensitivity columns.
/// `evals_by_cfg[c][a]` = app `a` evaluated on config `c`.
pub fn subgraph_speedups(
    title: &str,
    cfg_names: &[String],
    evals_by_cfg: &[Vec<AppEval>],
    training_split: bool,
) -> String {
    let mut s = format!("{title}\n");
    let base = &evals_by_cfg[0];
    for (ai, e) in base.iter().enumerate() {
        writeln!(s, "{}:", e.name).unwrap();
        for (ri, r) in e.kitsune.regions.iter().enumerate() {
            let pass = if training_split {
                if r.backward {
                    " bwd"
                } else {
                    " fwd"
                }
            } else {
                ""
            };
            let mut cols = format!(
                "  sf{ri}{pass} ({} ops) {:>5.2}x {}",
                r.n_ops,
                r.speedup(),
                bar(r.speedup() / 4.0, 24)
            );
            for (ci, cname) in cfg_names.iter().enumerate().skip(1) {
                if let Some(r2) = evals_by_cfg[ci][ai].kitsune.regions.get(ri) {
                    write!(cols, "  [{cname}: {:.2}x]", r2.speedup()).unwrap();
                }
            }
            s.push_str(&cols);
            s.push('\n');
        }
        let sub: Vec<f64> = e.kitsune.regions.iter().map(|r| r.speedup()).collect();
        writeln!(s, "  geomean subgraph speedup: {:.2}x", geomean(&sub)).unwrap();
    }
    let all: Vec<f64> = base
        .iter()
        .flat_map(|e| e.kitsune.regions.iter().map(|r| r.speedup()))
        .collect();
    writeln!(s, "ALL subgraphs geomean: {:.2}x", geomean(&all)).unwrap();
    s
}

/// Fig 11/14: end-to-end speedups + time-coverage timeline summary.
pub fn e2e_speedups(title: &str, evals: &[AppEval]) -> String {
    let mut s = format!("{title}\n");
    s.push_str(&format!(
        "{:<8} {:>9} {:>9} | {:>8} {:>10} {:>12}\n",
        "App", "Vertical", "Kitsune", "sf time%", "#subgraphs", "unfused time"
    ));
    for e in evals {
        writeln!(
            s,
            "{:<8} {:>8.2}x {:>8.2}x | {:>7.0}% {:>10} {:>10.1}us  {}",
            e.name,
            e.vertical_speedup(),
            e.kitsune_speedup(),
            100.0 * e.kitsune.region_time_coverage(),
            e.kitsune.regions.len(),
            1e6 * e.kitsune.unfused_s,
            bar(e.kitsune_speedup() / 2.5, 20)
        )
        .unwrap();
    }
    let vf: Vec<f64> = evals.iter().map(|e| e.vertical_speedup()).collect();
    let ki: Vec<f64> = evals.iter().map(|e| e.kitsune_speedup()).collect();
    writeln!(s, "geomean: vertical {:.2}x, kitsune {:.2}x", geomean(&vf), geomean(&ki)).unwrap();
    s
}

/// §6 sensitivity: speedup of upgraded configs relative to the *baseline
/// machine*, for both bulk-sync and Kitsune execution.
pub fn sensitivity(cfg_names: &[String], evals_by_cfg: &[Vec<AppEval>]) -> String {
    let mut s = String::from(
        "Hardware synergy: 2x cheap resources (SMs, L2 BW), DRAM BW fixed.\nSpeedup vs same mode on baseline A100 (geomean over apps):\n",
    );
    let base = &evals_by_cfg[0];
    for (ci, cname) in cfg_names.iter().enumerate().skip(1) {
        let bsp_gain: Vec<f64> = base
            .iter()
            .zip(&evals_by_cfg[ci])
            .map(|(b, u)| b.bsp.sim.elapsed_s / u.bsp.sim.elapsed_s)
            .collect();
        let kitsune_gain: Vec<f64> = base
            .iter()
            .zip(&evals_by_cfg[ci])
            .map(|(b, u)| b.kitsune.sim.elapsed_s / u.kitsune.sim.elapsed_s)
            .collect();
        writeln!(
            s,
            "{cname:<16} baseline-exec +{:>4.0}%   kitsune +{:>4.0}%",
            100.0 * (geomean(&bsp_gain) - 1.0),
            100.0 * (geomean(&kitsune_gain) - 1.0)
        )
        .unwrap();
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_lists_all_apps() {
        let t = table1();
        for app in ["DLRM", "MeshGraphNets", "NeRF", "GraphCast", "Llama 3 8B"] {
            assert!(t.contains(app), "{t}");
        }
    }

    #[test]
    fn fig5_renders() {
        let s = fig5(&GpuConfig::a100());
        assert!(s.contains("payload"));
        assert!(s.contains("HBM"), "spill rows present:\n{s}");
    }

    #[test]
    fn bar_clamps() {
        assert_eq!(bar(2.0, 4), "####");
        assert_eq!(bar(-1.0, 4), "");
    }
}
