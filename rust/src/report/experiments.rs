//! Experiment drivers: run an application under all three execution
//! models on a machine config. Single source of truth for the CLI,
//! benches and integration tests.

use crate::compiler::{compile, CompiledApp, SelectOptions};
use crate::exec::{run_bsp_detailed, run_dataflow, run_vertical, ExecReport};
use crate::graph::Graph;
use crate::sim::{Engine, GpuConfig, SchedPolicy};
use anyhow::Result;

/// Full three-way evaluation of one application graph.
#[derive(Debug, Clone)]
pub struct AppEval {
    pub name: String,
    pub n_ops: usize,
    pub bsp: ExecReport,
    pub vertical: ExecReport,
    pub kitsune: ExecReport,
    /// Ops covered by vertical fusion groups.
    pub vf_fused_ops: usize,
    /// Ops covered by Kitsune sf-nodes.
    pub kitsune_fused_ops: usize,
    pub compiled: CompiledApp,
}

impl AppEval {
    pub fn kitsune_speedup(&self) -> f64 {
        self.kitsune.speedup_over(&self.bsp)
    }

    pub fn vertical_speedup(&self) -> f64 {
        self.vertical.speedup_over(&self.bsp)
    }

    pub fn kitsune_traffic_reduction(&self) -> f64 {
        self.kitsune.traffic_reduction_vs(&self.bsp)
    }

    pub fn vertical_traffic_reduction(&self) -> f64 {
        self.vertical.traffic_reduction_vs(&self.bsp)
    }
}

/// Evaluate `g` on `cfg` under BSP, vertical fusion and Kitsune.
pub fn evaluate_app(name: &str, g: &Graph, cfg: &GpuConfig) -> Result<AppEval> {
    let compiled = compile(g, cfg, &SelectOptions::default())?;
    evaluate_compiled(name, g, cfg, compiled)
}

/// Like [`evaluate_app`], but reusing an already-compiled plan — the
/// session façade compiles exactly once at `build()` and simulates from
/// that plan.
pub fn evaluate_compiled(
    name: &str,
    g: &Graph,
    cfg: &GpuConfig,
    compiled: CompiledApp,
) -> Result<AppEval> {
    let bsp_engine = Engine::new(cfg.clone(), SchedPolicy::RoundRobin);
    let kitsune_engine = Engine::new(cfg.clone(), SchedPolicy::DualArbiter);

    let (bsp, per_node) = run_bsp_detailed(g, &bsp_engine)?;
    let vertical = run_vertical(g, &bsp_engine, &per_node)?;
    let kitsune = run_dataflow(g, &compiled, &kitsune_engine, &per_node)?;

    let vf_fused_ops = vertical.regions.iter().map(|r| r.n_ops).sum();
    let kitsune_fused_ops = compiled.n_fused_ops();
    Ok(AppEval {
        name: name.to_string(),
        n_ops: g.n_compute_ops(),
        bsp,
        vertical,
        kitsune,
        vf_fused_ops,
        kitsune_fused_ops,
        compiled,
    })
}

/// Evaluate a whole suite (name, graph) on one config.
pub fn evaluate_suite(suite: &[(String, Graph)], cfg: &GpuConfig) -> Result<Vec<AppEval>> {
    suite
        .iter()
        .map(|(name, g)| evaluate_app(name, g, cfg))
        .collect()
}

/// The §6 sensitivity configs: baseline A100; 2× SM compute; 2× L2
/// bandwidth; both — with DRAM bandwidth (the expensive resource) fixed.
pub fn sensitivity_configs() -> Vec<GpuConfig> {
    vec![
        GpuConfig::a100(),
        GpuConfig::a100().scale_compute(2.0),
        GpuConfig::a100().scale_l2_bw(2.0),
        GpuConfig::a100().scale_compute(2.0).scale_l2_bw(2.0),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;

    #[test]
    fn nerf_inference_full_eval() {
        let cfg = GpuConfig::a100();
        let (name, g) = &apps::inference_suite()[3];
        assert_eq!(name, "NERF");
        let eval = evaluate_app(name, g, &cfg).unwrap();
        // Paper: NeRF inference ~2.3x subgraph speedup, huge traffic cut,
        // VF weaker than Kitsune.
        assert!(eval.kitsune_speedup() > 1.2, "kitsune {}", eval.kitsune_speedup());
        assert!(
            eval.kitsune_speedup() > eval.vertical_speedup(),
            "kitsune {} vs vf {}",
            eval.kitsune_speedup(),
            eval.vertical_speedup()
        );
        assert!(
            eval.kitsune_traffic_reduction() > eval.vertical_traffic_reduction(),
            "traffic k {} vf {}",
            eval.kitsune_traffic_reduction(),
            eval.vertical_traffic_reduction()
        );
    }
}
