//! Ablation studies over Kitsune's design choices (DESIGN.md §4):
//!
//! * **scheduler** — dual-arbiter pairing vs type-blind round-robin for
//!   the *same* compiled pipelines (isolates the §4.2 hardware change);
//! * **queue entries** — double-buffering vs deeper rings (isolates the
//!   §4.1 sizing choice);
//! * **tile granularity** — coarse vs fine streaming tiles (isolates the
//!   pipeline-design tiling choice);
//! * **load balancing** — ILP allocation vs naive equal-split (isolates
//!   Algorithm 2).

use crate::apps;
use crate::compiler::{compile, SelectOptions};
use crate::exec::{run_bsp_detailed, run_dataflow};
use crate::graph::Graph;
use crate::sim::{Engine, GpuConfig, SchedPolicy};
use anyhow::Result;
use std::fmt::Write as _;

/// One ablation row: variant name → end-to-end speedup over BSP.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub app: String,
    pub variant: String,
    pub speedup: f64,
}

fn eval_variant(
    g: &Graph,
    cfg: &GpuConfig,
    policy: SchedPolicy,
    mutate: impl Fn(&mut crate::compiler::CompiledApp),
) -> Result<f64> {
    let bsp_engine = Engine::new(cfg.clone(), SchedPolicy::RoundRobin);
    let (bsp, per_node) = run_bsp_detailed(g, &bsp_engine)?;
    let mut app = compile(g, cfg, &SelectOptions::default())?;
    mutate(&mut app);
    let engine = Engine::new(cfg.clone(), policy);
    let df = run_dataflow(g, &app, &engine, &per_node)?;
    Ok(df.speedup_over(&bsp))
}

/// Run the ablation matrix over a subset of the inference suite.
pub fn ablation_rows(cfg: &GpuConfig) -> Result<Vec<AblationRow>> {
    let suite = apps::inference_suite();
    let picks = ["NERF", "MGN", "GRC"];
    let mut rows = Vec::new();
    for (name, g) in suite.iter().filter(|(n, _)| picks.contains(&n.as_str())) {
        // Baseline: full Kitsune.
        let full = eval_variant(g, cfg, SchedPolicy::DualArbiter, |_| {})?;
        rows.push(AblationRow { app: name.clone(), variant: "kitsune (full)".into(), speedup: full });

        // -scheduler: same pipelines, type-blind round-robin dispatch.
        let no_sched = eval_variant(g, cfg, SchedPolicy::RoundRobin, |_| {})?;
        rows.push(AblationRow { app: name.clone(), variant: "-dual-arbiter".into(), speedup: no_sched });

        // -queue depth: force strict double buffering on every edge.
        let shallow = eval_variant(g, cfg, SchedPolicy::DualArbiter, |app| {
            for lp in &mut app.pipelines {
                for q in &mut lp.desc.queues {
                    if !q.memory_backed {
                        q.entries = 2;
                    }
                }
            }
        })?;
        rows.push(AblationRow { app: name.clone(), variant: "-queue-depth (2 entries)".into(), speedup: shallow });

        // -tiling: 4x coarser tiles (fewer, bigger payloads). This can
        // overflow the L2 queue budget — which is itself the finding: the
        // compiler's tile refinement is what keeps queues L2-resident.
        let coarse = eval_variant(g, cfg, SchedPolicy::DualArbiter, |app| {
            for lp in &mut app.pipelines {
                for s in &mut lp.desc.stages {
                    s.n_tiles = (s.n_tiles / 4).max(2);
                }
                for q in &mut lp.desc.queues {
                    q.payload_bytes *= 4;
                }
            }
        });
        match coarse {
            Ok(sp) => rows.push(AblationRow {
                app: name.clone(),
                variant: "-tiling (4x coarser)".into(),
                speedup: sp,
            }),
            Err(_) => rows.push(AblationRow {
                app: name.clone(),
                variant: "-tiling (4x coarser): INFEASIBLE (queues overflow L2)".into(),
                speedup: 0.0,
            }),
        }

        // -ILP: equal CTA split per class instead of Algorithm 2.
        let naive = eval_variant(g, cfg, SchedPolicy::DualArbiter, |app| {
            for lp in &mut app.pipelines {
                let n_stages = lp.desc.stages.len().max(1);
                let even = (cfg.sm_count / n_stages).max(1);
                for s in &mut lp.desc.stages {
                    let k = &s.kernel;
                    s.kernel = k.with_ctas(even.min(k.n_ctas * 8).max(1));
                }
            }
        })?;
        rows.push(AblationRow { app: name.clone(), variant: "-ILP (equal split)".into(), speedup: naive });
    }
    Ok(rows)
}

/// Render the ablation table.
pub fn ablation_table(cfg: &GpuConfig) -> Result<String> {
    let rows = ablation_rows(cfg)?;
    let mut s = String::from(
        "Ablation: contribution of each design choice (inference e2e speedup over bulk-sync).\n",
    );
    let mut last_app = String::new();
    for r in &rows {
        if r.app != last_app {
            writeln!(s, "{}:", r.app).unwrap();
            last_app = r.app.clone();
        }
        if r.speedup > 0.0 {
            writeln!(s, "  {:<28} {:>5.2}x", r.variant, r.speedup).unwrap();
        } else {
            writeln!(s, "  {}", r.variant).unwrap();
        }
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_kitsune_wins_ablations_on_nerf() {
        let cfg = GpuConfig::a100();
        let rows = ablation_rows(&cfg).unwrap();
        let nerf: Vec<_> = rows.iter().filter(|r| r.app == "NERF").collect();
        let full = nerf.iter().find(|r| r.variant.contains("full")).unwrap().speedup;
        for r in &nerf {
            assert!(
                full + 1e-9 >= r.speedup * 0.95,
                "variant {} ({:.2}x) should not decisively beat full kitsune ({full:.2}x)",
                r.variant,
                r.speedup
            );
        }
        // Naive allocation must actually cost something somewhere.
        let naive = nerf.iter().find(|r| r.variant.contains("ILP")).unwrap();
        assert!(naive.speedup <= full + 1e-9);
    }
}
