//! Experiment drivers + text renderers for every paper table and figure.

pub mod ablation;
pub mod experiments;
pub mod format;

pub use ablation::{ablation_rows, ablation_table, AblationRow};
pub use experiments::{
    evaluate_app, evaluate_compiled, evaluate_suite, sensitivity_configs, AppEval,
};
pub use format::{
    e2e_speedups, fig13, fig3, fig5, sensitivity, subgraph_speedups, table1, table2,
};
