//! Exact solver for the paper's §5.3 load-balancing ILP (Algorithm 2).
//!
//! The ILP has a special max-min structure:
//!
//! ```text
//! maximize   thrpt
//! s.t.       thrpt <= c_i * a_i          (c_i = r·s·t coefficient)
//!            sum_{i in class C} a_i = B_C  (one budget per resource class)
//!            1 <= a_i <= cap_i
//! ```
//!
//! For this structure, binary search over `thrpt` with a greedy
//! feasibility check (`a_i = clamp(ceil(thrpt / c_i))`) is *exact*: the
//! feasibility region in `thrpt` is a half-line, and for a fixed `thrpt`
//! the elementwise-minimal allocation is feasible iff any allocation is.
//! No external solver dependency needed.

/// One variable of the allocation problem.
#[derive(Debug, Clone)]
pub struct AllocVar {
    /// Throughput coefficient: stage throughput = `coeff * a_i`.
    pub coeff: f64,
    /// Which budget (resource class) this variable draws from.
    pub class: usize,
    /// Upper bound on `a_i` (e.g. the stage's natural CTA count).
    pub cap: usize,
}

/// Result of the max-min allocation.
#[derive(Debug, Clone)]
pub struct Allocation {
    /// Chosen `a_i`, parallel to the input vars.
    pub a: Vec<usize>,
    /// Achieved `min_i coeff_i * a_i`.
    pub throughput: f64,
}

/// Solve the max-min allocation. `budgets[c]` is the total CTAs available
/// to class `c`. Budgets are treated as *at most* (the paper writes
/// equality; leftover CTAs are then distributed to the bottleneck stages,
/// which preserves optimality while consuming the full budget).
///
/// Returns `None` when infeasible (more variables in a class than budget).
pub fn solve_maxmin(vars: &[AllocVar], budgets: &[usize]) -> Option<Allocation> {
    if vars.is_empty() {
        return Some(Allocation { a: vec![], throughput: f64::INFINITY });
    }
    let n_classes = budgets.len();
    for (c, &b) in budgets.iter().enumerate() {
        let need: usize = vars.iter().filter(|v| v.class == c).count();
        if need > b {
            return None;
        }
    }
    for v in vars {
        assert!(v.class < n_classes, "class out of range");
        assert!(v.cap >= 1, "cap must allow at least one CTA");
        assert!(v.coeff > 0.0, "coefficient must be positive");
    }

    // The objective is capped by every variable maxing its cap.
    let hi_bound = vars
        .iter()
        .map(|v| v.coeff * v.cap as f64)
        .fold(f64::INFINITY, f64::min);

    let feasible = |thrpt: f64| -> Option<Vec<usize>> {
        let mut a = Vec::with_capacity(vars.len());
        let mut used = vec![0usize; n_classes];
        for v in vars {
            let need = (thrpt / v.coeff).ceil().max(1.0) as usize;
            if need > v.cap {
                return None;
            }
            used[v.class] += need;
            a.push(need);
        }
        for c in 0..n_classes {
            if used[c] > budgets[c] {
                return None;
            }
        }
        Some(a)
    };

    // Binary search on thrpt over [lo, hi].
    let mut lo = 0.0f64;
    let mut hi = hi_bound;
    if feasible(hi).is_some() {
        lo = hi;
    } else {
        for _ in 0..64 {
            let mid = 0.5 * (lo + hi);
            if feasible(mid).is_some() {
                lo = mid;
            } else {
                hi = mid;
            }
        }
    }
    let mut a = feasible(lo)?;

    // Distribute leftover budget to current bottlenecks (paper's equality
    // constraint: all SMs get used).
    loop {
        let mut used = vec![0usize; n_classes];
        for (v, &ai) in vars.iter().zip(&a) {
            used[v.class] += ai;
        }
        // Pick the stage with the lowest current throughput that can still
        // grow within its class budget and cap.
        let mut best: Option<usize> = None;
        for (i, v) in vars.iter().enumerate() {
            if a[i] < v.cap && used[v.class] < budgets[v.class] {
                let t = v.coeff * a[i] as f64;
                if best.map_or(true, |b| t < vars[b].coeff * a[b] as f64) {
                    best = Some(i);
                }
            }
        }
        match best {
            Some(i) => a[i] += 1,
            None => break,
        }
    }

    let throughput = vars
        .iter()
        .zip(&a)
        .map(|(v, &ai)| v.coeff * ai as f64)
        .fold(f64::INFINITY, f64::min);
    Some(Allocation { a, throughput })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn var(coeff: f64, class: usize, cap: usize) -> AllocVar {
        AllocVar { coeff, class, cap }
    }

    #[test]
    fn single_stage_takes_full_budget() {
        let alloc = solve_maxmin(&[var(1.0, 0, 1000)], &[108]).unwrap();
        assert_eq!(alloc.a, vec![108]);
        assert!((alloc.throughput - 108.0).abs() < 1e-9);
    }

    #[test]
    fn balanced_stages_split_evenly() {
        let alloc = solve_maxmin(&[var(1.0, 0, 1000), var(1.0, 0, 1000)], &[108]).unwrap();
        assert_eq!(alloc.a.iter().sum::<usize>(), 108);
        assert!((alloc.a[0] as i64 - alloc.a[1] as i64).abs() <= 1);
    }

    #[test]
    fn slow_stage_gets_more_ctas() {
        // Stage 0 is 4x slower per CTA: it should get ~4x the CTAs.
        let alloc = solve_maxmin(&[var(0.25, 0, 1000), var(1.0, 0, 1000)], &[100]).unwrap();
        assert_eq!(alloc.a.iter().sum::<usize>(), 100);
        assert!(alloc.a[0] >= 75 && alloc.a[0] <= 85, "{:?}", alloc.a);
    }

    #[test]
    fn classes_have_independent_budgets() {
        // Tensor (class 0) and SIMT (class 1) each get their own #SMs —
        // the paper's over-subscription for heterogeneous overlap.
        let alloc = solve_maxmin(
            &[var(1.0, 0, 1000), var(1.0, 1, 1000)],
            &[108, 108],
        )
        .unwrap();
        assert_eq!(alloc.a, vec![108, 108]);
    }

    #[test]
    fn caps_respected() {
        let alloc = solve_maxmin(&[var(1.0, 0, 4), var(1.0, 0, 1000)], &[108]).unwrap();
        assert_eq!(alloc.a[0], 4);
        assert_eq!(alloc.a[1], 104);
    }

    #[test]
    fn infeasible_when_more_stages_than_budget() {
        let vars: Vec<_> = (0..5).map(|_| var(1.0, 0, 10)).collect();
        assert!(solve_maxmin(&vars, &[4]).is_none());
    }

    #[test]
    fn maxmin_optimality_vs_bruteforce() {
        // Exhaustive check on a small instance: 3 stages, budget 12.
        let vars = [var(0.5, 0, 12), var(1.0, 0, 12), var(2.0, 0, 12)];
        let got = solve_maxmin(&vars, &[12]).unwrap();
        let mut best = 0.0f64;
        for a0 in 1..=10 {
            for a1 in 1..=(11 - a0) {
                let a2 = 12 - a0 - a1;
                if a2 < 1 {
                    continue;
                }
                let t = (0.5 * a0 as f64).min(1.0 * a1 as f64).min(2.0 * a2 as f64);
                best = best.max(t);
            }
        }
        assert!(
            (got.throughput - best).abs() < 1e-9,
            "solver {} vs brute force {best}",
            got.throughput
        );
    }

    #[test]
    fn deterministic() {
        let vars = [var(0.3, 0, 50), var(1.7, 0, 50), var(0.9, 1, 50)];
        let a = solve_maxmin(&vars, &[30, 20]).unwrap();
        let b = solve_maxmin(&vars, &[30, 20]).unwrap();
        assert_eq!(a.a, b.a);
    }
}
