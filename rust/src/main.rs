//! `kitsune` — CLI for the Kitsune reproduction.
//!
//! One subcommand per paper table/figure plus utilities:
//!
//! ```text
//! kitsune table1|table2|fig3|fig5|fig10|fig11|fig12|fig13|fig14|sensitivity
//! kitsune all             # every experiment in order
//! kitsune apps [--dump]   # application graph inventory
//! kitsune compile <app>   # show compiler output for one app
//! kitsune serve ...       # serving tier: continuous batching + deadlines
//! kitsune trace <app>     # Chrome-trace/Perfetto timeline of the warm pipeline
//! ```

use anyhow::{bail, Result};
use kitsune::apps;
use kitsune::report;
use kitsune::session::Session;
use kitsune::sim::GpuConfig;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest: Vec<&str> = args.iter().skip(1).map(String::as_str).collect();
    match cmd {
        "table1" => print!("{}", report::table1()),
        "table2" => cmd_table2()?,
        "fig3" => cmd_fig3()?,
        "fig5" => print!("{}", report::fig5(&GpuConfig::a100())),
        "fig10" => cmd_subgraphs(false)?,
        "fig11" => cmd_e2e(false)?,
        "fig12" => cmd_subgraphs(true)?,
        "fig13" => cmd_fig13()?,
        "fig14" => cmd_e2e(true)?,
        "sensitivity" => cmd_sensitivity()?,
        "ablation" => print!("{}", report::ablation_table(&GpuConfig::a100())?),
        "all" => cmd_all()?,
        "apps" => cmd_apps(rest.contains(&"--dump"))?,
        "compile" => {
            if let Some(bad) = rest.iter().find(|a| a.starts_with("--") && **a != "--train") {
                bail!("unknown compile flag {bad} (only --train is accepted)");
            }
            cmd_compile(
                rest.iter().find(|a| !a.starts_with("--")).copied().unwrap_or("NERF"),
                rest.contains(&"--train"),
            )?
        }
        "serve" => kitsune::coordinator::cli::serve(&rest)?,
        "trace" => kitsune::coordinator::cli::trace(&rest)?,
        "help" | "--help" | "-h" => print_help(),
        other => bail!(
            "unknown subcommand `{other}` (expected one of: {})",
            kitsune::coordinator::cli::SUBCOMMANDS.join(" ")
        ),
    }
    Ok(())
}

fn print_help() {
    println!(
        "kitsune — dataflow execution on GPUs (paper reproduction)\n\n\
         experiments:\n\
         \x20 table1 table2 fig3 fig5 fig10 fig11 fig12 fig13 fig14 sensitivity ablation all\n\
         tools:\n\
         \x20 apps [--dump]       application graph inventory\n\
         \x20 compile <APP> [--train]\n\
         \x20                     compiler output (sf-nodes, stages, allocation);\n\
         \x20                     searches the inference suite, then training\n\
         \x20 serve [--tiles N] [--workers N] [--hidden N] [--clients N] [--requests N]\n\
         \x20       [--deadline-ms N] [--max-batch N] [--max-delay-us N] [--queue-depth N]\n\
         \x20       [--models N] [--mem-budget-mb N]\n\
         \x20                     serving tier on the warm spatial pipeline:\n\
         \x20                     continuous batching, EDF deadlines + load shedding,\n\
         \x20                     multi-model registry, latency percentiles\n\
         \x20                     (`serve --help` lists every flag)\n\
         \x20 trace <APP> [--out PATH] [--tiles N] [--workers N] [--steps N]\n\
         \x20                     record a Chrome-trace/Perfetto timeline of the\n\
         \x20                     warm pipeline + a training step, with dataflow\n\
         \x20                     traffic accounting (`trace --help` for flags;\n\
         \x20                     env: KITSUNE_TRACE=<path> arms tracing anywhere)"
    );
}

fn inf_evals(cfg: &GpuConfig) -> Result<Vec<report::AppEval>> {
    report::evaluate_suite(&apps::inference_suite(), cfg)
}

fn train_evals(cfg: &GpuConfig) -> Result<Vec<report::AppEval>> {
    report::evaluate_suite(&apps::training_suite(), cfg)
}

fn cmd_table2() -> Result<()> {
    let cfg = GpuConfig::a100();
    print!("{}", report::table2(&inf_evals(&cfg)?, &train_evals(&cfg)?));
    Ok(())
}

fn cmd_fig3() -> Result<()> {
    let cfg = GpuConfig::a100();
    print!("{}", report::fig3(&inf_evals(&cfg)?, &train_evals(&cfg)?));
    Ok(())
}

fn cmd_fig13() -> Result<()> {
    let cfg = GpuConfig::a100();
    print!("{}", report::fig13(&inf_evals(&cfg)?, &train_evals(&cfg)?));
    Ok(())
}

fn sweep(training: bool) -> Result<(Vec<String>, Vec<Vec<report::AppEval>>)> {
    let cfgs = report::sensitivity_configs();
    let names: Vec<String> = cfgs.iter().map(|c| c.name.clone()).collect();
    let mut evals = Vec::new();
    for c in &cfgs {
        evals.push(if training { train_evals(c)? } else { inf_evals(c)? });
    }
    Ok((names, evals))
}

fn cmd_subgraphs(training: bool) -> Result<()> {
    let (names, evals) = sweep(training)?;
    let title = if training {
        "Fig 12. Training subgraph speedups over bulk-sync (with sensitivity)."
    } else {
        "Fig 10. Inference subgraph speedups over bulk-sync (with sensitivity)."
    };
    print!("{}", report::subgraph_speedups(title, &names, &evals, training));
    Ok(())
}

fn cmd_e2e(training: bool) -> Result<()> {
    let cfg = GpuConfig::a100();
    let evals = if training { train_evals(&cfg)? } else { inf_evals(&cfg)? };
    let title = if training {
        "Fig 14. Training end-to-end speedup over bulk-sync."
    } else {
        "Fig 11. Inference end-to-end speedup over bulk-sync."
    };
    print!("{}", report::e2e_speedups(title, &evals));
    Ok(())
}

fn cmd_sensitivity() -> Result<()> {
    let (names, inf) = sweep(false)?;
    println!("== Inference ==");
    print!("{}", report::sensitivity(&names, &inf));
    let (names, tr) = sweep(true)?;
    println!("== Training ==");
    print!("{}", report::sensitivity(&names, &tr));
    Ok(())
}

fn cmd_all() -> Result<()> {
    println!("{}", report::table1());
    cmd_table2()?;
    println!();
    cmd_fig3()?;
    println!();
    print!("{}", report::fig5(&GpuConfig::a100()));
    println!();
    cmd_subgraphs(false)?;
    println!();
    cmd_e2e(false)?;
    println!();
    cmd_subgraphs(true)?;
    println!();
    cmd_fig13()?;
    println!();
    cmd_e2e(true)?;
    println!();
    cmd_sensitivity()?;
    println!();
    print!("{}", report::ablation_table(&GpuConfig::a100())?);
    Ok(())
}

fn cmd_apps(dump: bool) -> Result<()> {
    for (name, g) in apps::inference_suite().iter().chain(apps::training_suite().iter()) {
        println!(
            "{name:<8} {:?}  {} ops  {:.1} GFLOP",
            g.kind,
            g.n_compute_ops(),
            g.total_flops() / 1e9
        );
        if dump {
            println!("{}", g.dump());
        }
    }
    Ok(())
}

fn cmd_compile(app: &str, training: bool) -> Result<()> {
    // The session façade resolves the app (searching the inference suite,
    // then training) and compiles exactly once; `warm(false)` skips
    // standing up the serving pool. Unknown names produce the typed
    // `SessionError::UnknownApp`, which lists every valid name.
    let session = Session::builder().app(app).training(training).warm(false).build()?;
    let (name, g) = (session.name(), session.graph().expect("app session has a graph"));
    let compiled = session.compiled().expect("app session compiles at build");
    println!(
        "{name}: {} ops, {} sf-nodes, coverage {:.0}%",
        g.n_compute_ops(),
        compiled.pipelines.len(),
        100.0 * compiled.selection.coverage(g)
    );
    match (session.pipeline(), session.train_plan()) {
        (Some(p), _) => println!(
            "  streams: lowered to a {}-stage spatial pipeline (tile {:?})",
            p.stages.len(),
            session.tile_dims().unwrap_or_default()
        ),
        (None, Some(tp)) => println!(
            "  trains: lowered to a {}-stage DAG pipeline ({} queue edges, {} skip links, \
             {} multicast ports; {} gradient taps)",
            tp.pipeline.stages.len(),
            tp.pipeline.edges.len(),
            tp.n_skip_links(),
            tp.n_multicasts(),
            tp.taps.len().saturating_sub(1)
        ),
        (None, None) => println!(
            "  simulation-only: {}",
            session.not_streamable_reason().unwrap_or("not lowered")
        ),
    }
    for lp in &compiled.pipelines {
        println!(
            "  {} — {} stages, {} queues, tiles={}, ILP thrpt {:.1}/s",
            lp.desc.name,
            lp.desc.stages.len(),
            lp.desc.queues.len(),
            lp.desc.stages.first().map(|s| s.n_tiles).unwrap_or(0),
            lp.balanced.est_throughput
        );
        for (s, a) in lp.desc.stages.iter().zip(&lp.balanced.alloc) {
            println!(
                "    {:<40} {:?}  a_i={a:<4} {:>8.2} MFLOP/cta",
                s.kernel.name,
                s.kernel.class,
                s.kernel.flops_per_cta / 1e6
            );
        }
    }
    Ok(())
}
